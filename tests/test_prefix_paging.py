"""Property tests for copy-on-write prefix sharing (PageAllocator).

Randomized admit / complete / recycle schedules (via the hypothesis shim)
against a reference model of page CONTENTS, checking the invariants the
device side depends on:

  - a page is never on the free list while any slot maps it or the
    prefix index pins it (and the free list never holds duplicates);
  - refcounts are exactly (#slot mappings) + (1 if index-pinned) — no
    leak: a full drain (release every slot, drop the index) returns the
    pool to its pristine free count;
  - COW safety: a page mapped by more than one owner is never written —
    admission only writes positions past the adopted prefix, which land
    in strictly later, private pages;
  - a radix hit is honest: every adopted page's recorded contents equal
    the corresponding page_size chunk of the new prompt.
"""
import random

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.paging import GARBAGE_PAGE, PageAllocator, PagedConfig

PS = 4          # page_size
NPAGES = 24
PER_SLOT = 8
SLOTS = 3


def _check_structural(a: PageAllocator, contents):
    free = a._free
    assert len(set(free)) == len(free), "duplicate pages on the free list"
    mapped = {p for owned in a._owned for p in owned}
    pinned = set(a._radix_rev)
    assert not (set(free) & (mapped | pinned)), \
        "page freed while still mapped or pinned"
    assert GARBAGE_PAGE not in set(free) | mapped | pinned
    # refcount == slot mappings + pin, for every non-free page
    count = {}
    for owned in a._owned:
        for p in owned:
            count[p] = count.get(p, 0) + 1
    for p in pinned:
        count[p] = count.get(p, 0) + 1
    assert {p: c for p, c in count.items()} == dict(a._refs)
    # conservation: every page is free, held, or the garbage sink
    assert len(free) + a.held_pages == NPAGES - 1
    # the radix maps onto real contents: each indexed page's key tokens
    # are exactly what was written there
    for (parent, toks), page in a._radix.items():
        assert contents.get(page) == list(toks)


def _drive(seed: int) -> None:
    rnd = random.Random(seed)
    cfg = PagedConfig(page_size=PS, num_pages=NPAGES,
                      pages_per_slot=PER_SLOT)
    a = PageAllocator(cfg, slots=SLOTS, prefix_cache=True)
    contents = {}        # physical page -> the PS tokens written to it
    slot_req = {}        # slot -> (prompt, n_adopted)
    # tiny alphabet + a shared system prefix make radix hits common
    system = [7] * (2 * PS)

    def admit(slot):
        n = rnd.randint(1, 20)
        prompt = (system[:] if rnd.random() < 0.6 else []) + [
            rnd.choice((0, 1)) for _ in range(n)]
        prompt = prompt[: PER_SLOT * PS - 2]
        matched = list(a.match_prefix(prompt))
        # the server caps the match below the last prompt position so
        # the first-token logits are still computed
        matched = matched[: (len(prompt) - 1) // PS]
        for p in matched:     # a hit must be an honest content match
            assert a.refcount(p) >= 1
        a.adopt(slot, matched)
        for j, p in enumerate(matched):
            assert contents[p] == prompt[j * PS:(j + 1) * PS]
        if not a.ensure(slot, len(prompt)):
            a.release(slot)   # backpressure: roll the adoption back
            return
        owned = a.slot_pages(slot)
        # COW: only pages past the adopted prefix are written
        for j in range(len(matched), len(owned)):
            page = owned[j]
            assert a.refcount(page) == 1, \
                f"writing page {page} with refcount {a.refcount(page)}"
            contents[page] = prompt[j * PS:(j + 1) * PS]
        slot_req[slot] = (prompt, len(matched))

    def complete(slot):
        prompt, _ = slot_req.pop(slot)
        a.register_prefix(slot, prompt)
        a.release(slot)

    for _ in range(60):
        busy = [s for s in range(SLOTS) if s in slot_req]
        idle = [s for s in range(SLOTS) if s not in slot_req]
        ops = []
        if idle:
            ops += ["admit"] * 3
        if busy:
            ops += ["complete"] * 2
        ops += ["drop"]
        op = rnd.choice(ops)
        if op == "admit":
            admit(rnd.choice(idle))
        elif op == "complete":
            complete(rnd.choice(busy))
        else:
            a.drop_prefix_index()
        _check_structural(a, contents)

    # full drain: no refcount leak anywhere
    for slot in list(slot_req):
        complete(slot)
    a.drop_prefix_index()
    _check_structural(a, contents)
    assert a.free_pages == NPAGES - 1
    assert a._refs == {} and a.held_pages == 0


@settings(max_examples=30)
@given(st.integers(0, 2**32 - 1))
def test_prefix_allocator_invariants(seed):
    _drive(seed)


def test_identical_prompts_converge_on_one_copy():
    """Two same-prompt admissions share physical pages: the second maps
    the first's registered pages and allocates only the private tail."""
    cfg = PagedConfig(page_size=PS, num_pages=NPAGES,
                      pages_per_slot=PER_SLOT)
    a = PageAllocator(cfg, slots=2, prefix_cache=True)
    prompt = list(range(11))                      # 2 full pages + tail
    assert a.match_prefix(prompt) == ()
    assert a.ensure(0, len(prompt))
    a.register_prefix(0, prompt)
    a.release(0)
    first = a.slot_pages(0)
    assert first == () and a.pinned_pages == 2

    matched = list(a.match_prefix(prompt))[: (len(prompt) - 1) // PS]
    assert len(matched) == 2
    a.adopt(1, matched)
    assert a.ensure(1, len(prompt))
    assert a.slot_pages(1)[:2] == tuple(matched)
    assert all(a.refcount(p) == 2 for p in matched)   # slot + pin
    a.release(1)
    assert a.pinned_pages == 2 and a.free_pages == NPAGES - 1 - 2


def test_eviction_is_leaf_first_and_spares_mapped_pages():
    """Pool pressure evicts only index-held leaves: parents of surviving
    radix nodes and slot-mapped pages are never reclaimed."""
    cfg = PagedConfig(page_size=PS, num_pages=8, pages_per_slot=6)
    a = PageAllocator(cfg, slots=2, prefix_cache=True)
    prompt = list(range(16))                      # 4 full pages
    assert a.ensure(0, len(prompt))
    a.register_prefix(0, prompt)
    a.release(0)
    chain = list(a.match_prefix(prompt))
    assert len(chain) == 4 and a.free_pages == 3
    # a 6-page demand forces evicting 3 pinned pages — newest leaves
    # first, so the chain survives as its 1-page prefix
    assert a.ensure(1, 21)
    assert a.pinned_pages == 1
    assert a.match_prefix(prompt) == (chain[0],)
    # the survivor is still content-addressable while slot 1 runs
    assert a.refcount(chain[0]) == 1
