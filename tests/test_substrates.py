"""Optimizer (ZeRO-1 == plain AdamW), grad compression, data pipeline,
checkpoint manager (atomic commit + elastic reshard), trainer fault
tolerance + straggler watchdog."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.core.atp import make_context
from repro.core.mesh import MeshTopo
from repro.data.pipeline import DataConfig, Prefetcher, TokenSource
from repro.optim import adamw
from repro.optim.grad_compress import compressed_psum_mean

TOPO = MeshTopo((("data", 4), ("tp1", 2)))


def _toy(topo):
    mesh = topo.build(jax.devices()[: topo.size])
    ctx = make_context(topo)
    W = jax.random.normal(jax.random.PRNGKey(0), (8, 16)) * 0.1
    b = jnp.zeros((16,))
    X = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    Y = jax.random.normal(jax.random.PRNGKey(2), (16, 16))
    pspecs = {"W": P(None, "tp1"), "b": P("tp1")}
    return mesh, ctx, {"W": W, "b": b}, (X, Y), pspecs


def _run_steps(mode, n=5):
    topo = TOPO
    mesh, ctx, params, (X, Y), pspecs = _toy(topo)
    cfg = adamw.AdamWConfig(lr=1e-2, mode=mode, grad_clip=1.0,
                            warmup_steps=1, total_steps=100)
    opt = adamw.init_opt_state(params, pspecs, ctx, mode)
    ospecs = adamw.opt_state_specs(pspecs, ctx, mode)
    rep = adamw.replication_factors(pspecs, ctx)

    def step(params, opt, X, Y):
        def loss(p):
            pred = X @ p["W"] + p["b"]
            l = jnp.sum((pred - Y) ** 2)
            return jax.lax.psum(l, ("data", "tp1"))

        lval, grads = jax.value_and_grad(loss)(params)
        newp, newo, m = adamw.apply_adamw(cfg, ctx, params, grads, opt, rep)
        m["loss"] = lval
        return newp, newo, m

    mspec = {"loss": P(), "lr": P(), "grad_norm": P()}
    f = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ospecs, P("data", None), P("data", "tp1")),
        out_specs=(pspecs, ospecs, mspec), check_vma=True))
    losses = []
    for _ in range(n):
        params, opt, metrics = f(params, opt, X, Y)
        losses.append(float(metrics["loss"]))
    return params, losses


def test_zero1_matches_plain_adamw(devices8):
    p_plain, l_plain = _run_steps("plain")
    p_zero, l_zero = _run_steps("zero1")
    np.testing.assert_allclose(l_plain, l_zero, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(p_plain["W"]),
                               np.asarray(p_zero["W"]), rtol=1e-4, atol=1e-5)


def test_losses_decrease(devices8):
    _, losses = _run_steps("zero1", n=8)
    assert losses[-1] < losses[0] * 0.9


def test_compressed_psum_close_to_exact(devices8):
    topo = MeshTopo((("data", 8),))
    mesh = topo.build()
    g = jax.random.normal(jax.random.PRNGKey(3), (8, 64)) * 0.1

    def f(g):
        exact = jax.lax.pmean(g, "data")
        comp = compressed_psum_mean(g, ("data",))
        return jnp.max(jnp.abs(exact - comp)), jnp.max(jnp.abs(exact))

    h = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                          out_specs=(P(), P()), check_vma=False))
    err, scale = h(g)
    assert float(err) < 0.02 * float(scale) + 1e-3


class TestDataPipeline:
    def test_deterministic_replay(self):
        src = TokenSource(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
        a = src.global_batch(3)
        b = src.global_batch(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = src.global_batch(4)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_host_sharding_partitions_global(self):
        src = TokenSource(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
        g = src.global_batch(0)
        parts = [src.host_batch(0, i, 4)["tokens"] for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), g["tokens"])

    def test_labels_are_shifted_tokens(self):
        src = TokenSource(DataConfig(vocab_size=100, seq_len=16, global_batch=2))
        b = src.global_batch(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)

    def test_prefetcher_yields_in_order(self):
        src = TokenSource(DataConfig(vocab_size=50, seq_len=8, global_batch=2))
        pf = Prefetcher(src, start_step=5)
        it = iter(pf)
        s0, b0 = next(it)
        s1, b1 = next(it)
        pf.close()
        assert (s0, s1) == (5, 6)
        np.testing.assert_array_equal(b0["tokens"],
                                      src.host_batch(5, 0, 1)["tokens"])
