"""GPipe pipeline over the pod axis: forward == dense, grads == dense."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.compat import shard_map

from repro.core.pipeline import gpipe_forward, gpipe_loss


def _setup(S=4, M=8):
    mesh = compat.make_mesh((S,), ("pod",))
    # S stages, each one matmul + tanh; stacked stage params [S, d, d]
    d = 16
    Ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) * (0.5 / d ** 0.5)
    X = jax.random.normal(jax.random.PRNGKey(1), (M, 4, d))  # M microbatches
    return mesh, Ws, X


def _stage(w, x):
    return jnp.tanh(x @ w)


def _dense(Ws, X):
    y = X
    for i in range(Ws.shape[0]):
        y = _stage(Ws[i], y)
    return y


def test_gpipe_forward_matches_dense(devices8):
    mesh, Ws, X = _setup()

    def f(w, x):
        return gpipe_forward(_stage, w[0], x, "pod")

    g = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("pod"), P()),
                          out_specs=P(), check_vma=True))
    # output valid on the last stage; with out_specs P() + check_vma=True
    # the last stage's copy must equal the dense result after psum-style
    # selection; select it explicitly instead:
    def f2(w, x):
        outs = gpipe_forward(_stage, w[0], x, "pod")
        # broadcast the last stage's result to everyone for checking
        ok = (jax.lax.axis_index("pod") == compat.axis_size("pod") - 1)
        return jax.lax.psum(jnp.where(ok, outs, 0.0), "pod")

    g2 = jax.jit(shard_map(f2, mesh=mesh, in_specs=(P("pod"), P()),
                           out_specs=P(), check_vma=True))
    out = g2(Ws, X)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_dense(Ws, X)),
                               rtol=1e-5, atol=1e-6)


def test_gpipe_grads_match_dense(devices8):
    mesh, Ws, X = _setup()

    def loss_pipe(w, x):
        return gpipe_loss(_stage, lambda y: jnp.sum(y ** 2), w[0], x, "pod")

    def loss_dense(w, x):
        return jnp.sum(_dense(w, x) ** 2)

    g = jax.jit(shard_map(jax.grad(loss_pipe), mesh=mesh,
                          in_specs=(P("pod"), P()), out_specs=P("pod"),
                          check_vma=True))
    grads = g(Ws, X)
    ref = jax.grad(loss_dense)(Ws, X)
    np.testing.assert_allclose(np.asarray(grads), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_gpipe_bubble_cost_is_s_minus_1(devices8):
    """The schedule runs M + S - 1 ticks (GPipe bubble)."""
    mesh, Ws, X = _setup(S=4, M=8)
    ticks = {"n": 0}

    def counting_stage(w, x):
        ticks["n"] += 1  # traced once per scan body: structural check only
        return _stage(w, x)

    def f(w, x):
        outs = gpipe_forward(counting_stage, w[0], x, "pod")
        ok = (jax.lax.axis_index("pod") == compat.axis_size("pod") - 1)
        return jax.lax.psum(jnp.where(ok, outs, 0.0), "pod")

    hlo = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("pod"), P()),
                            out_specs=P(), check_vma=True)) \
        .lower(Ws, X).compile().as_text()
    assert ticks["n"] == 1  # one traced body
    assert '"known_trip_count":{"n":"11"}' in hlo  # M + S - 1 = 8 + 3