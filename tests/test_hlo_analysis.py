"""HLO analysis: trip-aware collective/FLOP/traffic accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.compat import shard_map

from repro.launch.hlo_analysis import (collective_bytes, full_analysis,
                                       shape_bytes)


def test_shape_bytes():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert shape_bytes("pred[]") == 1


def _compile(f, in_specs, out_specs, *args, mesh=None):
    mesh = mesh or compat.make_mesh((4,), ("m",))
    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             check_vma=False)).lower(*args).compile().as_text()


def test_collectives_counted_with_trip_multiplier(devices8):
    L = 7

    def f(x):
        def body(c, _):
            return lax.psum(c, "m"), None
        y, _ = lax.scan(body, x, None, length=L)
        return y

    hlo = _compile(f, P(), P(), jnp.ones((8, 16)))
    got = collective_bytes(hlo)
    # one 8x16 f32 psum per iteration
    assert got["per_op_bytes"]["all-reduce"] == 8 * 16 * 4 * L


def test_dot_flops_trip_aware(devices8):
    L, m, k, n = 5, 32, 64, 16

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=L)
        return y

    hlo = _compile(f, (P(), P()), P(), jnp.ones((m, k)),
                   jnp.ones((k, k)))
    got = full_analysis(hlo)
    assert got["dot_flops"] == 2 * m * k * k * L


def test_xla_cost_analysis_counts_loops_once():
    """The reason full_analysis exists: XLA's own flops ignore trip count."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = lax.scan(body, x, None, length=10)
        return y

    x, w = jnp.zeros((64, 64)), jnp.zeros((64, 64))
    c = jax.jit(f).lower(x, w).compile()
    one_iter = 2 * 64 * 64 * 64
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # list-of-dicts on 0.4
    got = ca.get("flops")
    assert one_iter <= got < 1.01 * one_iter, got  # ~1 iteration, NOT 10x


def test_paper_gpt_models_smoke():
    """The paper's M1..M4 eval configs instantiate and train-step (reduced)."""
    from repro.configs.registry import PAPER_MODELS
    from repro.core.atp import make_context
    from repro.core.mesh import MeshTopo
    from repro.models import lm

    cfg = PAPER_MODELS["gpt-m1"].reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    topo = MeshTopo((("data", 1),))
    mesh = topo.build(jax.devices()[:1])
    ctx = make_context(topo)
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "labels": jnp.zeros((2, 16), jnp.int32),
    }
    f = shard_map(lambda p, b: lm.train_loss(ctx, cfg, p, b, remat=False),
                  mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                  check_vma=True)
    loss = jax.jit(f)(params, batch)
    assert np.isfinite(float(loss))