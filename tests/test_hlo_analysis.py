"""HLO analysis: trip-aware collective/FLOP/traffic accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.compat import shard_map

from repro.launch.hlo_analysis import (collective_bytes, full_analysis,
                                       shape_bytes)


def test_shape_bytes():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert shape_bytes("pred[]") == 1


def test_shape_bytes_quantized_dtypes():
    assert shape_bytes("f8e5m2[16]") == 16
    assert shape_bytes("f8e4m3fn[16]") == 16
    assert shape_bytes("s4[16]") == 8     # two nibbles per byte
    assert shape_bytes("u4[7]") == 4      # packed: ceil(7/2)


_ASYNC_HLO = """\
HloModule async

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ars = f32[8,16]{1,0} all-reduce-start(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ard = f32[8,16]{1,0} all-reduce-done(%ars)
  %ags = (f32[8,16]{1,0}, f32[32,16]{1,0}) all-gather-start(%ard), replica_groups={{0,1,2,3}}, dimensions={0}
  %agd = f32[32,16]{1,0} all-gather-done(%ags)
  %rss = (f32[32,16]{1,0}, f32[8,16]{1,0}) reduce-scatter-start(%agd), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  %rsd = f32[8,16]{1,0} reduce-scatter-done(%rss)
  %a2a = f32[8,16]{1,0} all-to-all-start(%rsd), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2d = f32[8,16]{1,0} all-to-all-done(%a2a)
  %cps = (f32[8,16]{1,0}, f32[8,16]{1,0}, u32[], u32[]) collective-permute-start(%a2d), source_target_pairs={{0,1},{1,2}}
  ROOT %cpd = f32[8,16]{1,0} collective-permute-done(%cps)
}
"""


def test_async_pairs_counted_once_uniformly():
    """*-start carries the payload; *-done contributes nothing; async
    tuple results (operand, dest, contexts) are not double-counted."""
    got = collective_bytes(_ASYNC_HLO)["per_op_bytes"]
    buf = 8 * 16 * 4
    assert got["all-reduce"] == buf
    assert got["all-gather"] == 4 * buf          # result on each device
    assert got["reduce-scatter"] == 4 * buf      # shard x group = operand
    assert got["all-to-all"] == buf
    assert got["collective-permute"] == buf


def _compile(f, in_specs, out_specs, *args, mesh=None):
    mesh = mesh or compat.make_mesh((4,), ("m",))
    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             check_vma=False)).lower(*args).compile().as_text()


def test_collectives_counted_with_trip_multiplier(devices8):
    L = 7

    def f(x):
        def body(c, _):
            return lax.psum(c, "m"), None
        y, _ = lax.scan(body, x, None, length=L)
        return y

    hlo = _compile(f, P(), P(), jnp.ones((8, 16)))
    got = collective_bytes(hlo)
    # one 8x16 f32 psum per iteration
    assert got["per_op_bytes"]["all-reduce"] == 8 * 16 * 4 * L


def test_dot_flops_trip_aware(devices8):
    L, m, k, n = 5, 32, 64, 16

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=L)
        return y

    hlo = _compile(f, (P(), P()), P(), jnp.ones((m, k)),
                   jnp.ones((k, k)))
    got = full_analysis(hlo)
    assert got["dot_flops"] == 2 * m * k * k * L


def test_xla_cost_analysis_counts_loops_once():
    """The reason full_analysis exists: XLA's own flops ignore trip count."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = lax.scan(body, x, None, length=10)
        return y

    x, w = jnp.zeros((64, 64)), jnp.zeros((64, 64))
    c = jax.jit(f).lower(x, w).compile()
    one_iter = 2 * 64 * 64 * 64
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # list-of-dicts on 0.4
    got = ca.get("flops")
    assert one_iter <= got < 1.01 * one_iter, got  # ~1 iteration, NOT 10x


def test_paper_gpt_models_smoke():
    """The paper's M1..M4 eval configs instantiate and train-step (reduced)."""
    from repro.configs.registry import PAPER_MODELS
    from repro.core.atp import make_context
    from repro.core.mesh import MeshTopo
    from repro.models import lm

    cfg = PAPER_MODELS["gpt-m1"].reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    topo = MeshTopo((("data", 1),))
    mesh = topo.build(jax.devices()[:1])
    ctx = make_context(topo)
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "labels": jnp.zeros((2, 16), jnp.int32),
    }
    f = shard_map(lambda p, b: lm.train_loss(ctx, cfg, p, b, remat=False),
                  mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                  check_vma=True)
    loss = jax.jit(f)(params, batch)
    assert np.isfinite(float(loss))