"""Decode path: step-by-step decode with caches must reproduce the full
forward logits (per family: KV-cache, MLA latent cache, SSD/mLSTM state)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.configs.registry import get_config
from repro.core.atp import make_context
from repro.core.mesh import MeshTopo
from repro.models import lm

TOPO1 = MeshTopo((("data", 1),))

DECODE_ARCHS = ["llama3-8b", "gemma2-2b", "deepseek-v3-671b",
                "zamba2-7b", "xlstm-1.3b"]


def _forward_logits_all(cfg, params, tokens):
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)

    def f(p, b):
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
        h, _, _, _ = lm.forward(ctx, cfg, p, b["tokens"], pos)
        return lm.lm_logits(ctx, cfg, p, h)

    g = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                  check_vma=True)
    return jax.jit(g)(params, {"tokens": tokens})


def _decode_logits_seq(cfg, params, tokens, s_max):
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)
    B, S = tokens.shape
    caches, _ = lm.init_decode_caches(cfg, ctx, B, s_max, dtype=jnp.float32)

    def step(p, tok, pos, caches):
        return lm.decode_step(ctx, cfg, p, tok, pos, caches)

    g = jax.jit(shard_map(step, mesh=mesh, in_specs=(P(), P(), P(), P()),
                          out_specs=(P(), P()), check_vma=True))
    outs = []
    for t in range(S):
        logits, caches = g(params, tokens[:, t: t + 1], jnp.int32(t), caches)
        outs.append(logits)
    return jnp.stack(outs, axis=1)  # [B, S, V]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full = _forward_logits_all(cfg, params, tokens)
    dec = _decode_logits_seq(cfg, params, tokens, s_max=S + 4)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# Paged KV caches: block-paged decode must reproduce the dense path.
# ---------------------------------------------------------------------------

PAGED_ARCHS = ["llama3-8b", "deepseek-v3-671b"]  # attn-cache + MLA-latent


def _paged_step_fn(cfg, ctx, mesh):
    def step(p, tok, start, table, caches):
        return lm.paged_step(ctx, cfg, p, tok, start, table, caches)

    return jax.jit(shard_map(step, mesh=mesh,
                             in_specs=(P(), P(), P(), P(), P()),
                             out_specs=(P(), P()), check_vma=True))


def _paged_cfg():
    from repro.models.paging import PagedConfig

    return PagedConfig(page_size=4, num_pages=16, pages_per_slot=4)


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_decode_matches_dense_mixed_lengths(arch):
    """Per-slot lengths differ; every valid position's logits must match
    the dense token-by-token decode."""
    from repro.models.paging import PageAllocator

    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)
    B, S = 2, 12
    S1 = S - 5  # slot 1 stops early: independent lengths
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    ref = _decode_logits_seq(cfg, params, tokens, s_max=S + 4)

    pcfg = _paged_cfg()
    alloc = PageAllocator(pcfg, slots=B)
    caches, _ = lm.init_paged_caches(cfg, ctx, pcfg, dtype=jnp.float32)
    g = _paged_step_fn(cfg, ctx, mesh)
    outs = []
    for t in range(S):
        live1 = t < S1
        alloc.ensure(0, t + 1)
        if live1:
            alloc.ensure(1, t + 1)
        tok = np.zeros((B, 1), np.int32)
        tok[0, 0] = int(tokens[0, t])
        tok[1, 0] = int(tokens[1, t]) if live1 else 0
        start = np.array([t, t if live1 else 0], np.int32)
        table = alloc.table()
        if not live1:   # inactive slot writes route to the garbage page
            table[1, :] = 0
        logits, caches = g(params, jnp.asarray(tok), jnp.asarray(start),
                           jnp.asarray(table), caches)
        outs.append(logits[:, 0])
    outs = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref[0]),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(outs[1, :S1]),
                               np.asarray(ref[1, :S1]),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_prefill_chunks_match_full_forward(arch):
    """b=1 chunked prefill through the page pool == full-sequence logits
    (one compiled step reused across chunk starts)."""
    from repro.models.paging import PageAllocator

    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)
    S, C = 12, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                                cfg.vocab_size)
    full = _forward_logits_all(cfg, params, tokens)

    pcfg = _paged_cfg()
    alloc = PageAllocator(pcfg, slots=1)
    alloc.ensure(0, S)
    caches, _ = lm.init_paged_caches(cfg, ctx, pcfg, dtype=jnp.float32)
    g = _paged_step_fn(cfg, ctx, mesh)
    got = []
    for c0 in range(0, S, C):
        logits, caches = g(params, tokens[:, c0: c0 + C],
                           jnp.asarray(np.array([c0], np.int32)),
                           jnp.asarray(alloc.table()), caches)
        got.append(logits)
    got = jnp.concatenate(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_page_table_reuse_after_slot_recycle():
    """Pages released by a finished request and re-mapped to a new one
    must serve the new sequence exactly (stale contents fully masked)."""
    from repro.models.paging import PageAllocator

    cfg = get_config("llama3-8b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)
    S = 8
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    t2 = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0, cfg.vocab_size)
    ref2 = _forward_logits_all(cfg, params, t2)

    pcfg = _paged_cfg()
    alloc = PageAllocator(pcfg, slots=1)
    caches, _ = lm.init_paged_caches(cfg, ctx, pcfg, dtype=jnp.float32)
    g = _paged_step_fn(cfg, ctx, mesh)
    # request 1 occupies pages, then recycles
    alloc.ensure(0, S)
    pages_first = alloc.slot_pages(0)
    _, caches = g(params, t1, jnp.asarray(np.zeros(1, np.int32)),
                  jnp.asarray(alloc.table()), caches)
    alloc.release(0)
    # request 2 receives the SAME physical pages (LIFO free list)
    alloc.ensure(0, S)
    assert set(alloc.slot_pages(0)) == set(pages_first)
    logits, caches = g(params, t2, jnp.asarray(np.zeros(1, np.int32)),
                       jnp.asarray(alloc.table()), caches)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref2),
                               rtol=5e-3, atol=5e-3)


def test_decode_plan_knobs_thread_resolve_ctx():
    """The decode sub-plan's mesh-neutral knobs reach the decode context
    (and ONLY the decode context) through the resolve_ctx funnel."""
    from repro.core.atp import DecodePlan, SegmentPlan
    from repro.core.plan import ParallelPlan
    from repro.launch.steps import resolve_ctx

    plan = ParallelPlan(
        d1=2, d2=2, chunks=4, boundary_mode="ring", seq_parallel=True,
        segments=(SegmentPlan("dense", chunks=4, boundary_mode="ring",
                              seq_parallel=True),),
        decode=DecodePlan(d1=4, d2=1, boundary_mode="psum"))
    train_ctx = resolve_ctx(None, plan)
    assert (train_ctx.chunks, train_ctx.boundary_mode) == (4, "ring")
    assert train_ctx.for_segment("dense").seq_parallel is True
    dec_ctx = resolve_ctx(None, plan, decode=True)
    # decode sub-plan knobs replace the train knobs in every view...
    assert (dec_ctx.chunks, dec_ctx.boundary_mode) == (1, "psum")
    seg = dec_ctx.for_segment("dense")
    assert (seg.chunks, seg.boundary_mode, seg.seq_parallel) == \
        (1, "psum", False)
    # ...but the mesh stays the plan's: re-meshing is decode_view's job
    assert (dec_ctx.d1, dec_ctx.d2) == (2, 2)
    view = plan.decode_view()
    assert (view.d1, view.d2) == (4, 1)
    vctx = resolve_ctx(None, view, decode=True)
    assert (vctx.d1, vctx.d2) == (4, 1)
    assert vctx.boundary_mode == "psum" and vctx.chunks == 1


def test_prefill_into_cache_matches_stepwise():
    """Multi-token decode_step (serving prefill) == token-by-token."""
    cfg = get_config("llama3-8b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    def step(p, tok, pos, caches):
        return lm.decode_step(ctx, cfg, p, tok, pos, caches)

    g = jax.jit(shard_map(step, mesh=mesh, in_specs=(P(), P(), P(), P()),
                          out_specs=(P(), P()), check_vma=True),
                static_argnames=())
    caches, _ = lm.init_decode_caches(cfg, ctx, B, S + 4, dtype=jnp.float32)
    logits_bulk, caches_bulk = g(params, tokens, jnp.int32(0), caches)

    caches2, _ = lm.init_decode_caches(cfg, ctx, B, S + 4, dtype=jnp.float32)
    for t in range(S):
        logits_step, caches2 = g(params, tokens[:, t: t + 1], jnp.int32(t), caches2)
    np.testing.assert_allclose(np.asarray(logits_bulk), np.asarray(logits_step),
                               rtol=5e-3, atol=5e-3)
