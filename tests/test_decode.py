"""Decode path: step-by-step decode with caches must reproduce the full
forward logits (per family: KV-cache, MLA latent cache, SSD/mLSTM state)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.configs.registry import get_config
from repro.core.atp import make_context
from repro.core.mesh import MeshTopo
from repro.models import lm

TOPO1 = MeshTopo((("data", 1),))

DECODE_ARCHS = ["llama3-8b", "gemma2-2b", "deepseek-v3-671b",
                "zamba2-7b", "xlstm-1.3b"]


def _forward_logits_all(cfg, params, tokens):
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)

    def f(p, b):
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
        h, _, _, _ = lm.forward(ctx, cfg, p, b["tokens"], pos)
        return lm.lm_logits(ctx, cfg, p, h)

    g = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                  check_vma=True)
    return jax.jit(g)(params, {"tokens": tokens})


def _decode_logits_seq(cfg, params, tokens, s_max):
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)
    B, S = tokens.shape
    caches, _ = lm.init_decode_caches(cfg, ctx, B, s_max, dtype=jnp.float32)

    def step(p, tok, pos, caches):
        return lm.decode_step(ctx, cfg, p, tok, pos, caches)

    g = jax.jit(shard_map(step, mesh=mesh, in_specs=(P(), P(), P(), P()),
                          out_specs=(P(), P()), check_vma=True))
    outs = []
    for t in range(S):
        logits, caches = g(params, tokens[:, t: t + 1], jnp.int32(t), caches)
        outs.append(logits)
    return jnp.stack(outs, axis=1)  # [B, S, V]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full = _forward_logits_all(cfg, params, tokens)
    dec = _decode_logits_seq(cfg, params, tokens, s_max=S + 4)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_prefill_into_cache_matches_stepwise():
    """Multi-token decode_step (serving prefill) == token-by-token."""
    cfg = get_config("llama3-8b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    def step(p, tok, pos, caches):
        return lm.decode_step(ctx, cfg, p, tok, pos, caches)

    g = jax.jit(shard_map(step, mesh=mesh, in_specs=(P(), P(), P(), P()),
                          out_specs=(P(), P()), check_vma=True),
                static_argnames=())
    caches, _ = lm.init_decode_caches(cfg, ctx, B, S + 4, dtype=jnp.float32)
    logits_bulk, caches_bulk = g(params, tokens, jnp.int32(0), caches)

    caches2, _ = lm.init_decode_caches(cfg, ctx, B, S + 4, dtype=jnp.float32)
    for t in range(S):
        logits_step, caches2 = g(params, tokens[:, t: t + 1], jnp.int32(t), caches2)
    np.testing.assert_allclose(np.asarray(logits_bulk), np.asarray(logits_step),
                               rtol=5e-3, atol=5e-3)
