"""Checkpoint atomic commit, elastic reshard-on-restore, trainer failure
recovery, straggler watchdog (deliverable: large-scale runnability)."""
import os

import jax
from repro.core import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, TokenSource
from repro.runtime.trainer import StragglerWatchdog, Trainer, TrainerConfig


class TestOrphanTmpSweep:
    """A crashed/killed save() must not leak .tmp_* staging dirs forever."""

    def _orphan(self, tmp_path):
        d = os.path.join(str(tmp_path), ".tmp_dead")
        os.makedirs(d)
        with open(os.path.join(d, "arr_0.npy"), "w") as f:
            f.write("junk")
        return d

    def test_save_sweeps_orphans_on_entry(self, tmp_path):
        d = self._orphan(tmp_path)
        ckpt.save(str(tmp_path), 1, {"a": jnp.ones(3)})
        assert not os.path.exists(d)
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_prune_sweeps_orphans(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"a": jnp.ones(3)})
        d = self._orphan(tmp_path)
        ckpt.prune(str(tmp_path), keep=1)
        assert not os.path.exists(d)
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_sweep_missing_dir_is_noop(self, tmp_path):
        assert ckpt.sweep_orphan_tmps(os.path.join(str(tmp_path), "no")) == 0

    def test_failed_save_cleans_its_tmp(self, tmp_path):
        class Boom:
            def __array__(self, *a, **k):
                raise RuntimeError("boom")  # fails mid-save, inside try

        with pytest.raises(Exception):
            ckpt.save(str(tmp_path), 1, {"a": jnp.ones(3), "b": Boom()})
        assert not [n for n in os.listdir(str(tmp_path))
                    if n.startswith(".tmp_")]


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5)}}
        ckpt.save(str(tmp_path), 7, tree)
        out, meta = ckpt.restore(str(tmp_path), tree)
        assert meta["step"] == 7
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))

    def test_uncommitted_checkpoint_is_invisible(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        d = ckpt.save(str(tmp_path), 1, tree)
        os.remove(os.path.join(d, "COMMITTED"))
        assert ckpt.latest_step(str(tmp_path)) is None

    def test_latest_and_prune(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        for s in (1, 2, 3, 4):
            ckpt.save(str(tmp_path), s, tree)
        assert ckpt.latest_step(str(tmp_path)) == 4
        ckpt.prune(str(tmp_path), keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 4
        assert not os.path.exists(os.path.join(str(tmp_path), "step_00000001"))

    def test_elastic_reshard_on_restore(self, tmp_path, devices8):
        """Save under one mesh, restore under a different one."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh4 = compat.make_mesh((4,), ("data",))
        x = jax.device_put(jnp.arange(16.0),
                           NamedSharding(mesh4, P("data")))
        ckpt.save(str(tmp_path), 1, {"x": x})
        mesh8 = compat.make_mesh((8,), ("data",))
        tgt = NamedSharding(mesh8, P("data"))
        out, _ = ckpt.restore(str(tmp_path), {"x": jnp.zeros(16)},
                              shardings={"x": tgt})
        assert out["x"].sharding == tgt
        np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(16.0))

    def test_sharded_restore_casts_to_template_dtype(self, tmp_path, devices8):
        """The on-disk npy dtype must not leak through device_put: a bf16
        template restores at bf16 on BOTH branches (the sharded path used
        to skip the cast the unsharded path applies)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        ckpt.save(str(tmp_path), 1, {"x": jnp.arange(16.0),  # f32 on disk
                                     "y": jnp.arange(8.0)})
        mesh = compat.make_mesh((4,), ("data",))
        tgt = NamedSharding(mesh, P("data"))
        tmpl = {"x": jnp.zeros(16, jnp.bfloat16),
                "y": jnp.zeros(8, jnp.bfloat16)}
        out, _ = ckpt.restore(str(tmp_path), tmpl,
                              shardings={"x": tgt, "y": None})
        assert out["x"].dtype == jnp.bfloat16     # sharded branch
        assert out["y"].dtype == jnp.bfloat16     # unsharded branch
        assert out["x"].sharding == tgt
        np.testing.assert_array_equal(np.asarray(out["x"], np.float32),
                                      np.arange(16.0))

    def test_bf16_checkpoint_round_trips(self, tmp_path, devices8):
        """np.save writes bf16 as raw void bytes; restore must
        reinterpret via the recorded dtype (both branches), and casting
        to a different template dtype still works."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        x = jnp.arange(16.0, dtype=jnp.bfloat16) / 3
        ckpt.save(str(tmp_path), 1, {"x": x, "y": x})
        mesh = compat.make_mesh((4,), ("data",))
        tgt = NamedSharding(mesh, P("data"))
        tmpl = {"x": jnp.zeros(16, jnp.bfloat16),
                "y": jnp.zeros(16, jnp.bfloat16)}
        out, meta = ckpt.restore(str(tmp_path), tmpl,
                                 shardings={"x": tgt, "y": None})
        assert meta["dtypes"] == ["bfloat16", "bfloat16"]
        assert out["x"].dtype == jnp.bfloat16 and out["x"].sharding == tgt
        np.testing.assert_array_equal(np.asarray(out["x"], np.float32),
                                      np.asarray(x, np.float32))
        np.testing.assert_array_equal(np.asarray(out["y"], np.float32),
                                      np.asarray(x, np.float32))
        # bf16 on disk -> f32 template: bits recovered, then cast
        out32, _ = ckpt.restore(str(tmp_path),
                                {"x": jnp.zeros(16), "y": jnp.zeros(16)})
        np.testing.assert_array_equal(np.asarray(out32["x"]),
                                      np.asarray(x, np.float32))


class _Clock:
    def __init__(self):
        self.t = 0.0
        self.step_cost = 1.0

    def __call__(self):
        self.t += self.step_cost / 2
        return self.t


class TestTrainer:
    def _mk(self, tmp_path, total=8, ckpt_every=2):
        src = TokenSource(DataConfig(vocab_size=10, seq_len=4, global_batch=2))
        cfg = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path),
                            ckpt_every=ckpt_every, max_failures=3)
        state = {"w": jnp.zeros(())}

        def build_step():
            def step(params, opt, batch):
                w = params["w"] + jnp.sum(batch["tokens"]) * 0 + 1.0
                return {"w": w}, opt, {"loss": 1.0 / (w + 1)}
            return step

        def init_state():
            return dict(state), {"n": jnp.zeros(())}

        return Trainer(cfg, build_step, src, init_state, lambda b: {
            "tokens": jnp.asarray(b["tokens"])})

    def test_runs_to_completion(self, tmp_path):
        tr = self._mk(tmp_path)
        params, _ = tr.run()
        assert float(params["w"]) == 8.0
        assert ckpt.latest_step(str(tmp_path)) == 8

    def test_recovers_from_injected_failure(self, tmp_path):
        tr = self._mk(tmp_path)
        fired = {"n": 0}

        def injector(step):
            if step == 5 and fired["n"] == 0:
                fired["n"] = 1
                raise RuntimeError("simulated device failure")

        params, _ = tr.run(fail_injector=injector)
        assert tr.total_failures == 1
        assert tr.failures == 0  # consecutive counter decayed on recovery
        assert float(params["w"]) == 8.0  # deterministic replay -> same result

    def test_failure_counter_decays_after_recovery(self, tmp_path):
        """Sporadic transient faults over a long run must not accumulate
        into max_failures — the consecutive counter resets once a
        post-recovery step commits."""
        tr = self._mk(tmp_path)
        tr.cfg.max_failures = 1
        fails = {s: 1 for s in (2, 5, 7)}  # 3 separate transient faults

        def injector(step):
            if fails.get(step):
                fails[step] = 0
                raise RuntimeError("transient fault")

        params, _ = tr.run(fail_injector=injector)   # must NOT raise
        assert tr.total_failures == 3
        assert tr.failures == 0
        assert float(params["w"]) == 8.0

    def test_consecutive_failures_still_give_up(self, tmp_path):
        """Decay must not defeat max_failures for a persistent fault."""
        tr = self._mk(tmp_path)
        tr.cfg.max_failures = 2
        with pytest.raises(RuntimeError):
            tr.run(fail_injector=lambda step: (_ for _ in ()).throw(
                RuntimeError("persistent")))
        assert tr.failures == 3  # never decayed: no step ever committed

    def test_transient_fault_is_not_a_replan(self, tmp_path):
        """A replan hook that returns the live step unchanged (intact
        mesh) must not be recorded as a re-plan nor reset the watchdog."""
        tr = self._mk(tmp_path)
        step_fn = tr.build_step()
        tr.replan = lambda: (step_fn, None)
        tr.build_step = lambda: step_fn
        fired = {"n": 0}

        def injector(step):
            if step == 5 and fired["n"] == 0:
                fired["n"] = 1
                raise RuntimeError("transient fault, pool intact")

        tr.watchdog.ema = 123.0  # sentinel: must survive the recovery
        tr.run(fail_injector=injector)
        assert tr.replans == []
        assert tr.total_failures == 1

    def test_restore_threads_shardings(self, tmp_path, devices8):
        """_restore_or_init passes the current plan's shardings into
        ckpt.restore, so resumed state lands sharded, not replicated."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = compat.make_mesh((4,), ("data",))
        tgt = NamedSharding(mesh, P("data"))
        ckpt.save(str(tmp_path), 3, ({"w": jnp.arange(8.0)}, {}))
        src = TokenSource(DataConfig(vocab_size=10, seq_len=4, global_batch=2))
        tr = Trainer(
            TrainerConfig(total_steps=3, ckpt_dir=str(tmp_path)),
            build_step=lambda: None, source=src,
            init_state=lambda: ({"w": jnp.zeros(8)}, {}),
            put_batch=lambda b: b,
            restore_shardings=lambda: ({"w": tgt}, {}))
        params, _, step = tr._restore_or_init()
        assert step == 3
        assert params["w"].sharding == tgt
        np.testing.assert_array_equal(np.asarray(params["w"]), np.arange(8.0))

    def test_gives_up_after_max_failures(self, tmp_path):
        tr = self._mk(tmp_path)
        tr.cfg.max_failures = 1

        def injector(step):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError):
            tr.run(fail_injector=injector)


class TestStragglerWatchdog:
    def test_flags_slow_step(self):
        wd = StragglerWatchdog(factor=3.0, beta=0.5)
        for _ in range(5):
            assert not wd.observe(0, 1.0)
        assert wd.observe(5, 10.0)       # 10x the EMA
        assert wd.events and wd.events[0][0] == 5

    def test_outliers_do_not_poison_ema(self):
        wd = StragglerWatchdog(factor=3.0, beta=0.5)
        for _ in range(5):
            wd.observe(0, 1.0)
        wd.observe(5, 100.0)
        assert wd.ema == pytest.approx(1.0, rel=0.01)

    def test_reset_forgets_ema_keeps_events(self):
        wd = StragglerWatchdog(factor=3.0, beta=0.5)
        for _ in range(5):
            wd.observe(0, 1.0)
        wd.observe(5, 10.0)
        assert wd.events
        wd.reset()
        assert wd.ema is None and wd.events
        # the first post-reset step re-seeds the EMA instead of being
        # judged against the old mesh's timing
        assert not wd.observe(6, 5.0)
        assert wd.ema == 5.0

    def test_replan_resets_watchdog(self, tmp_path):
        """Slower steps on the surviving mesh must not be flagged against
        the pre-failure EMA (nor skew it) after an elastic re-plan."""
        src = TokenSource(DataConfig(vocab_size=10, seq_len=4, global_batch=2))
        cfg = TrainerConfig(total_steps=8, ckpt_dir=str(tmp_path),
                            ckpt_every=2, max_failures=2,
                            straggler_factor=3.0)
        clock = _Clock()
        fired = {"n": 0}

        def injector(step):
            if step == 4 and fired["n"] == 0:
                fired["n"] = 1
                clock.step_cost = 10.0   # surviving mesh is 10x slower
                raise RuntimeError("device loss")

        def build_step():
            def step(params, opt, batch):
                return {"w": params["w"] + 1}, opt, {"loss": 0.0}
            return step

        hooks = []
        tr = Trainer(cfg, build_step, src,
                     lambda: ({"w": jnp.zeros(())}, {}),
                     lambda b: b, mitigation_hook=hooks.append,
                     time_fn=clock, replan=build_step)
        tr.run(fail_injector=injector)
        assert tr.replans == [4]
        assert not hooks and not tr.watchdog.events, \
            "post-replan steps falsely flagged as stragglers"
        assert tr.watchdog.ema == pytest.approx(5.0), \
            "EMA must be re-seeded from surviving-mesh timings"

    def test_trainer_fires_mitigation_hook(self, tmp_path):
        src = TokenSource(DataConfig(vocab_size=10, seq_len=4, global_batch=2))
        cfg = TrainerConfig(total_steps=8, ckpt_dir=str(tmp_path), ckpt_every=100)
        clock = _Clock()
        hooks = []

        def build_step():
            def step(params, opt, batch):
                if int(params["w"]) == 5:
                    clock.step_cost = 50.0   # one slow step
                else:
                    clock.step_cost = 1.0
                return {"w": params["w"] + 1}, opt, {"loss": 0.0}
            return step

        tr = Trainer(cfg, build_step, src,
                     lambda: ({"w": jnp.zeros(())}, {}),
                     lambda b: b, mitigation_hook=hooks.append,
                     time_fn=clock)
        tr.run()
        assert hooks, "straggler mitigation hook should have fired"


# ---------------------------------------------------------------------------
# Elastic restart done right (PR 4): plan-independent zero1 checkpoints,
# surviving-mesh recalibration, and the failure -> shrink -> reshard loop.
# ---------------------------------------------------------------------------

from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.atp import make_context  # noqa: E402
from repro.core.calibrate import (CalibEntry, CalibrationTable,  # noqa: E402
                                  recalibrate_surviving, surviving_tp)
from repro.core.mesh import MeshTopo, atp_topo  # noqa: E402
from repro.core.plan import ParallelPlan, replan_elastic  # noqa: E402
from repro.optim import adamw  # noqa: E402


def _fake_entry(d1, d2):
    return CalibEntry(b1=10.0 * d1, b2=5.0 * d2, t_psum=1e-5, t_ring=2e-5,
                      alpha_s=1e-6)


class TestZero1CheckpointLayout:
    """zero1 state is checkpointed param-shaped; rebank restores the
    runtime layout on ANY plan (the (d1,d2)-change reshard path)."""

    PARAMS = {"W": jnp.arange(128.0).reshape(8, 16),
              "b": jnp.arange(16.0),
              "r": jnp.arange(24.0).reshape(4, 6)}  # TP-replicated leaf
    SPECS = {"W": P(None, "tp1"), "b": P("tp1"), "r": P(None, None)}

    def _rand_canonical(self, seed=0):
        rng = np.random.RandomState(seed)
        leaves = {k: {"m": rng.rand(*v.shape).astype(np.float32),
                      "v": rng.rand(*v.shape).astype(np.float32)}
                  for k, v in self.PARAMS.items()}
        return {"step": jnp.int32(7), "leaves": leaves}

    def test_rebank_unbank_round_trip_same_plan(self, devices8):
        ctx = make_context(atp_topo(2, 2, 1))
        canon = self._rand_canonical()
        banked = adamw.rebank_opt_state(self.PARAMS, canon, self.SPECS, ctx)
        assert banked["leaves"]["W"]["m"].shape == (2, 2, 32)  # [dp,tp,k]
        back = adamw.unbank_opt_state(self.PARAMS, banked, self.SPECS, ctx)
        for k in self.PARAMS:
            np.testing.assert_array_equal(back["leaves"][k]["m"],
                                          canon["leaves"][k]["m"])
            np.testing.assert_array_equal(back["leaves"][k]["v"],
                                          canon["leaves"][k]["v"])

    def test_rebank_across_plans_preserves_moments(self, devices8):
        """canonical -> bank on (dp=2, tp1=2) -> unbank -> bank on
        (dp=4, tp1=1)... every layout reads back the same moments."""
        canon = self._rand_canonical()
        specs_b = {"W": P(None, None), "b": P(None), "r": P(None, None)}
        for topo, specs in [(atp_topo(2, 2, 1), self.SPECS),
                            (atp_topo(4, 1, 1), specs_b),
                            (atp_topo(2, 1, 2),
                             {"W": P(None, "tp2"), "b": P("tp2"),
                              "r": P(None, None)})]:
            ctx = make_context(topo)
            banked = adamw.rebank_opt_state(self.PARAMS, canon, specs, ctx)
            back = adamw.unbank_opt_state(self.PARAMS, banked, specs, ctx)
            for k in self.PARAMS:
                np.testing.assert_array_equal(
                    back["leaves"][k]["m"], canon["leaves"][k]["m"],
                    err_msg=f"{topo} leaf {k}")

    def test_unbank_matches_plain_state_after_training(self, devices8):
        """The canonical view of trained zero1 state equals the plain-mode
        state for the same trajectory (moments preserved exactly where the
        parity test pins the updates)."""
        from repro.core.compat import shard_map
        topo = MeshTopo((("data", 4), ("tp1", 2)))
        mesh = topo.build(jax.devices()[:8])
        ctx = make_context(topo)
        X = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        Y = jax.random.normal(jax.random.PRNGKey(2), (16, 16))
        W = jax.random.normal(jax.random.PRNGKey(0), (8, 16)) * 0.1
        params = {"W": W, "b": jnp.zeros((16,))}
        pspecs = {"W": P(None, "tp1"), "b": P("tp1")}
        states = {}
        for mode in ("plain", "zero1"):
            cfg = adamw.AdamWConfig(lr=1e-2, mode=mode, warmup_steps=1,
                                    total_steps=100)
            opt = adamw.init_opt_state(params, pspecs, ctx, mode)
            ospecs = adamw.opt_state_specs(pspecs, ctx, mode)
            rep = adamw.replication_factors(pspecs, ctx)

            def step(p, o, X, Y):
                def loss(q):
                    pred = X @ q["W"] + q["b"]
                    return jax.lax.psum(jnp.sum((pred - Y) ** 2),
                                        ("data", "tp1"))
                _, g = jax.value_and_grad(loss)(p)
                np_, no_, _ = adamw.apply_adamw(cfg, ctx, p, g, o, rep)
                return np_, no_

            f = jax.jit(shard_map(step, mesh=mesh,
                                  in_specs=(pspecs, ospecs,
                                            P("data", None), P("data", "tp1")),
                                  out_specs=(pspecs, ospecs),
                                  check_vma=True))
            p, o = params, opt
            for _ in range(3):
                p, o = f(p, o, X, Y)
            states[mode] = (p, o)
        canon = adamw.unbank_opt_state(states["zero1"][0], states["zero1"][1],
                                       pspecs, ctx, "zero1")
        for k in ("W", "b"):
            np.testing.assert_allclose(
                np.asarray(canon["leaves"][k]["m"]),
                np.asarray(states["plain"][1]["leaves"][k]["m"]),
                rtol=1e-5, atol=1e-6, err_msg=f"m[{k}]")

    def test_zero1_without_dp_mirrors_params(self, devices8):
        """mode=zero1 with no data-parallel axis takes apply_adamw's
        full-state path, so the state must mirror the params (banking it
        crashed the elastic shrink-to-dp=1 recovery)."""
        ctx = make_context(atp_topo(1, 2, 1))
        opt = adamw.init_opt_state(self.PARAMS, self.SPECS, ctx, "zero1")
        assert opt["leaves"]["W"]["m"].shape == (8, 16)
        specs = adamw.opt_state_specs(self.SPECS, ctx, "zero1")
        assert specs["leaves"]["W"]["m"] == P(None, "tp1")
        # and the codec is the identity there
        assert adamw.unbank_opt_state(self.PARAMS, opt, self.SPECS,
                                      ctx, "zero1") is opt


class TestRecalibrateSurviving:
    def _plan(self):
        tab = CalibrationTable.from_pairs(
            {(2, 2): (1.0, 2.0), (4, 1): (0.5, 0.5)}, source="unit")
        return ParallelPlan(d1=2, d2=2, dp=2, topology="ic3",
                            calibration=tab)

    def test_surviving_tp_halves_until_fit(self):
        assert surviving_tp(8, 8) == 8
        assert surviving_tp(8, 5) == 4
        assert surviving_tp(8, 2) == 2
        assert surviving_tp(4, 1) == 1
        with pytest.raises(ValueError):
            surviving_tp(4, 0)

    def test_covers_tp(self):
        tab = CalibrationTable.from_pairs({(2, 2): (1.0, 2.0)})
        assert tab.covers_tp(4) and not tab.covers_tp(2)

    def test_recalibrate_merges_and_clears_stale(self):
        plan = self._plan()
        stale = replan_elastic(plan, 2)          # tp 4 -> 2: tagged stale
        assert stale.calibration_stale
        fresh = recalibrate_surviving(stale, devices=list(range(2)),
                                      measure=_fake_entry)
        assert not fresh.calibration_stale
        assert fresh.calibration.covers_tp(2)    # fresh surviving entries
        assert fresh.calibration.get(2, 2) is not None  # old keys kept
        assert any(k == "calibration" and v.startswith("recalibrated")
                   for k, v in fresh.provenance)

    def test_recalibrated_replan_is_not_stale(self):
        """The complete loop: shrink -> recalibrate -> re-plan carries a
        fresh table and no stale tag (the acceptance criterion)."""
        plan = self._plan()
        fresh = recalibrate_surviving(plan, devices=list(range(2)),
                                      measure=_fake_entry)
        new = replan_elastic(fresh, 2)
        assert new.tp == 2
        assert not new.calibration_stale
        assert new.calibration.covers_tp(2)

    def test_unrecalibrated_replan_still_stale(self):
        new = replan_elastic(self._plan(), 2)
        assert new.tp == 2 and new.calibration_stale

    def test_fresh_measurements_override_old_keys(self):
        plan = self._plan().with_(d1=4, d2=1, dp=1)  # tp=4 on 4 devices
        fresh = recalibrate_surviving(plan, devices=list(range(4)),
                                      measure=_fake_entry)
        # same tp: the (2,2)/(4,1) keys are re-measured, new values win
        assert fresh.calibration.get(2, 2).b1 == pytest.approx(20.0)
        assert fresh.calibration.get(4, 1).b1 == pytest.approx(40.0)


class TestElasticReshardRoundTrip:
    def test_failure_shrink_reshard_round_trip(self, tmp_path, devices8):
        """End-to-end: fail at step 3, lose 4 of 8 devices, recover under
        a re-searched plan across a (d1,d2) change with the checkpoint
        re-banked + resharded, and match the uninterrupted trajectory."""
        from repro.configs.base import ModelConfig
        from repro.launch.train import make_elastic_trainer
        from repro.runtime.trainer import TrainerConfig

        # num_heads must cover the initial tp=4 (fewer heads than TP
        # ranks takes a padded attention path whose loss is not
        # factorization-invariant — not this test's subject)
        cfg = ModelConfig(name="rt", family="dense", num_layers=1,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=64, head_dim=16, dtype="float32")
        plan = ParallelPlan(
            d1=2, d2=2, dp=2, topology="ic3",
            calibration=CalibrationTable.from_pairs({(2, 2): (1.0, 1.0)},
                                                    source="unit"))

        def one_run(ckpt_dir, shrink):
            pool = {"n": 8}
            fired = {"n": 0}

            def injector(step):
                if shrink and step == 3 and fired["n"] == 0:
                    fired["n"] = 1
                    pool["n"] = 2  # dp absorbs 8->4; 2 forces a TP change
                    raise RuntimeError("injected device loss")

            src = TokenSource(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=16, global_batch=4))
            trainer, live = make_elastic_trainer(
                cfg, plan,
                adamw.AdamWConfig(lr=1e-3, mode="zero1", total_steps=5),
                TrainerConfig(total_steps=5, ckpt_dir=str(ckpt_dir),
                              ckpt_every=2, max_failures=2),
                src, batch=4, seq=16,
                devices_fn=lambda: jax.devices()[: pool["n"]],
                measure=_fake_entry)
            params, _ = trainer.run(fail_injector=injector)
            return trainer, live, params, \
                {h["step"]: h["loss"] for h in trainer.history}

        _, _, _, base = one_run(tmp_path / "base", shrink=False)
        tr, live, params, elas = one_run(tmp_path / "elastic", shrink=True)

        new_plan = live["plan"]
        assert tr.replans == [3]
        assert new_plan.tp == 2 and (new_plan.d1, new_plan.d2) != (2, 2)
        assert not new_plan.calibration_stale
        assert new_plan.calibration.covers_tp(2)
        # restored + trained state carries the new plan's shardings
        inf = live["info"]
        want = jax.tree.leaves(inf.sharding(inf.pspecs))
        for got, w in zip(jax.tree.leaves(params), want):
            assert got.sharding == w
        # loss continuity: deterministic replay across the (d1,d2) change
        for s, l in base.items():
            assert abs(elas[s] - l) <= 5e-4 * max(1.0, abs(l)), \
                f"step {s}: {elas[s]} vs {l}"

    def test_dead_mesh_device_with_spares_triggers_rebuild(self, tmp_path,
                                                           devices8):
        """'Intact' is membership, not head-count: losing a device the
        live mesh runs on must rebuild onto the spares even when the pool
        is still large enough."""
        from repro.configs.base import ModelConfig
        from repro.launch.train import make_elastic_trainer
        from repro.runtime.trainer import TrainerConfig

        cfg = ModelConfig(name="mb", family="dense", num_layers=1,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=64, head_dim=16, dtype="float32")
        plan = ParallelPlan(d1=2, d2=2, dp=1, topology="ic3")
        pool = {"lo": 0}
        fired = {"n": 0}

        def injector(step):
            if step == 1 and fired["n"] == 0:
                fired["n"] = 1
                pool["lo"] = 1   # device 0 (in the live mesh) died
                raise RuntimeError("device 0 lost")

        src = TokenSource(DataConfig(vocab_size=cfg.vocab_size,
                                     seq_len=16, global_batch=4))
        trainer, live = make_elastic_trainer(
            cfg, plan,
            adamw.AdamWConfig(lr=1e-3, mode="zero1", total_steps=3),
            TrainerConfig(total_steps=3, ckpt_dir=str(tmp_path),
                          ckpt_every=1, max_failures=2),
            src, batch=4, seq=16,
            devices_fn=lambda: jax.devices()[pool["lo"]:],
            recalibrate=False)
        trainer.run(fail_injector=injector)
        assert trainer.replans == [1]        # rebuilt despite 7 >= 4
        assert live["plan"].tp == 4          # strategy itself unchanged
        used = {d.id for d in live["info"].mesh.devices.flat}
        assert 0 not in used, "rebuilt mesh must avoid the dead device"
