"""Checkpoint atomic commit, elastic reshard-on-restore, trainer failure
recovery, straggler watchdog (deliverable: large-scale runnability)."""
import os

import jax
from repro.core import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, TokenSource
from repro.runtime.trainer import StragglerWatchdog, Trainer, TrainerConfig


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5)}}
        ckpt.save(str(tmp_path), 7, tree)
        out, meta = ckpt.restore(str(tmp_path), tree)
        assert meta["step"] == 7
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))

    def test_uncommitted_checkpoint_is_invisible(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        d = ckpt.save(str(tmp_path), 1, tree)
        os.remove(os.path.join(d, "COMMITTED"))
        assert ckpt.latest_step(str(tmp_path)) is None

    def test_latest_and_prune(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        for s in (1, 2, 3, 4):
            ckpt.save(str(tmp_path), s, tree)
        assert ckpt.latest_step(str(tmp_path)) == 4
        ckpt.prune(str(tmp_path), keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 4
        assert not os.path.exists(os.path.join(str(tmp_path), "step_00000001"))

    def test_elastic_reshard_on_restore(self, tmp_path, devices8):
        """Save under one mesh, restore under a different one."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh4 = compat.make_mesh((4,), ("data",))
        x = jax.device_put(jnp.arange(16.0),
                           NamedSharding(mesh4, P("data")))
        ckpt.save(str(tmp_path), 1, {"x": x})
        mesh8 = compat.make_mesh((8,), ("data",))
        tgt = NamedSharding(mesh8, P("data"))
        out, _ = ckpt.restore(str(tmp_path), {"x": jnp.zeros(16)},
                              shardings={"x": tgt})
        assert out["x"].sharding == tgt
        np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(16.0))


class _Clock:
    def __init__(self):
        self.t = 0.0
        self.step_cost = 1.0

    def __call__(self):
        self.t += self.step_cost / 2
        return self.t


class TestTrainer:
    def _mk(self, tmp_path, total=8, ckpt_every=2):
        src = TokenSource(DataConfig(vocab_size=10, seq_len=4, global_batch=2))
        cfg = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path),
                            ckpt_every=ckpt_every, max_failures=3)
        state = {"w": jnp.zeros(())}

        def build_step():
            def step(params, opt, batch):
                w = params["w"] + jnp.sum(batch["tokens"]) * 0 + 1.0
                return {"w": w}, opt, {"loss": 1.0 / (w + 1)}
            return step

        def init_state():
            return dict(state), {"n": jnp.zeros(())}

        return Trainer(cfg, build_step, src, init_state, lambda b: {
            "tokens": jnp.asarray(b["tokens"])})

    def test_runs_to_completion(self, tmp_path):
        tr = self._mk(tmp_path)
        params, _ = tr.run()
        assert float(params["w"]) == 8.0
        assert ckpt.latest_step(str(tmp_path)) == 8

    def test_recovers_from_injected_failure(self, tmp_path):
        tr = self._mk(tmp_path)
        fired = {"n": 0}

        def injector(step):
            if step == 5 and fired["n"] == 0:
                fired["n"] = 1
                raise RuntimeError("simulated device failure")

        params, _ = tr.run(fail_injector=injector)
        assert tr.failures == 1
        assert float(params["w"]) == 8.0  # deterministic replay -> same result

    def test_gives_up_after_max_failures(self, tmp_path):
        tr = self._mk(tmp_path)
        tr.cfg.max_failures = 1

        def injector(step):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError):
            tr.run(fail_injector=injector)


class TestStragglerWatchdog:
    def test_flags_slow_step(self):
        wd = StragglerWatchdog(factor=3.0, beta=0.5)
        for _ in range(5):
            assert not wd.observe(0, 1.0)
        assert wd.observe(5, 10.0)       # 10x the EMA
        assert wd.events and wd.events[0][0] == 5

    def test_outliers_do_not_poison_ema(self):
        wd = StragglerWatchdog(factor=3.0, beta=0.5)
        for _ in range(5):
            wd.observe(0, 1.0)
        wd.observe(5, 100.0)
        assert wd.ema == pytest.approx(1.0, rel=0.01)

    def test_trainer_fires_mitigation_hook(self, tmp_path):
        src = TokenSource(DataConfig(vocab_size=10, seq_len=4, global_batch=2))
        cfg = TrainerConfig(total_steps=8, ckpt_dir=str(tmp_path), ckpt_every=100)
        clock = _Clock()
        hooks = []

        def build_step():
            def step(params, opt, batch):
                if int(params["w"]) == 5:
                    clock.step_cost = 50.0   # one slow step
                else:
                    clock.step_cost = 1.0
                return {"w": params["w"] + 1}, opt, {"loss": 0.0}
            return step

        tr = Trainer(cfg, build_step, src,
                     lambda: ({"w": jnp.zeros(())}, {}),
                     lambda b: b, mitigation_hook=hooks.append,
                     time_fn=clock)
        tr.run()
        assert hooks, "straggler mitigation hook should have fired"
