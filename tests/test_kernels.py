"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape/dtype
sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.models.mamba2 import ssd_chunked


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("sq,sk,hq,hkv,d", [
        (128, 128, 4, 4, 64),
        (256, 256, 4, 2, 64),     # GQA
        (96, 96, 2, 1, 32),       # non-128-aligned (padding path)
        (64, 192, 2, 2, 128),     # kv longer than q
    ])
    def test_matches_ref(self, sq, sk, hq, hkv, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, sq, hq, d), dtype)
        k = jax.random.normal(ks[1], (2, sk, hkv, d), dtype)
        v = jax.random.normal(ks[2], (2, sk, hkv, d), dtype)
        o = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                                interpret=True)
        rep = hq // hkv
        kr, vr = jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2)
        f = lambda t: t.transpose(0, 2, 1, 3).reshape(2 * hq, t.shape[1], d)
        r = ref.attention_ref(f(q), f(kr), f(vr), causal=False)
        r = r.reshape(2, hq, sq, d).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("window,softcap", [(0, 0.0), (64, 0.0),
                                                (0, 30.0), (32, 50.0)])
    def test_causal_window_softcap(self, window, softcap):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
        o = ops.flash_attention(q, k, v, causal=True, window=window,
                                softcap=softcap, block_q=64, block_k=64,
                                interpret=True)
        f = lambda t: t.transpose(0, 2, 1, 3).reshape(2, 128, 64)
        r = ref.attention_ref(f(q), f(k), f(v), causal=True, window=window,
                              softcap=softcap)
        r = r.reshape(1, 2, 128, 64).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-4, atol=2e-5)


class TestRMSNorm:
    @given(rows=st.integers(1, 300), h=st.sampled_from([64, 128, 512]),
           bf16=st.booleans())
    @settings(max_examples=10, deadline=None)
    def test_matches_ref(self, rows, h, bf16):
        dt = jnp.bfloat16 if bf16 else jnp.float32
        x = jax.random.normal(jax.random.PRNGKey(rows), (rows, h), dt)
        g = jax.random.normal(jax.random.PRNGKey(h), (h,), jnp.float32)
        o = ops.rmsnorm(x, g, block_rows=64, interpret=True)
        r = ref.rmsnorm_ref(x, g)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32), **_tol(dt))


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (200, 300, 150),
                                       (64, 512, 96)])
    @pytest.mark.parametrize("act", [None, "gelu", "silu"])
    def test_matches_ref(self, m, k, n, act):
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        o = ops.matmul(a, b, activation=act, block_m=64, block_n=64,
                       block_k=64, interpret=True)
        r = ref.matmul_ref(a, b, activation=act)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-4, atol=2e-4)


class TestSSD:
    @pytest.mark.parametrize("s,chunk", [(64, 32), (128, 64), (96, 32)])
    def test_kernel_matches_sequential_ref(self, s, chunk):
        b, nh, hd, ds = 2, 3, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = jax.random.normal(ks[0], (b, s, nh, hd), jnp.float32) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
        A_log = jax.random.normal(ks[2], (nh,)) * 0.3
        B = jax.random.normal(ks[3], (b, s, ds)) * 0.5
        C = jax.random.normal(ks[4], (b, s, ds)) * 0.5
        D = jnp.ones((nh,))
        y = ops.ssd_scan(x, dt, A_log, B, C, D, chunk=chunk, interpret=True)
        yr, _ = ref.ssd_ref(x, dt, A_log, B, C, D)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)

    def test_model_chunked_path_matches_ref_and_state(self):
        b, s, nh, hd, ds = 1, 64, 2, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(7), 5)
        x = jax.random.normal(ks[0], (b, s, nh, hd)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
        A_log = jax.random.normal(ks[2], (nh,)) * 0.3
        B = jax.random.normal(ks[3], (b, s, ds)) * 0.5
        C = jax.random.normal(ks[4], (b, s, ds)) * 0.5
        D = jnp.ones((nh,))
        y, st = ssd_chunked(x, dt, A_log, B, C, D, chunk=16)
        yr, str_ = ref.ssd_ref(x, dt, A_log, B, C, D)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                                   rtol=1e-4, atol=1e-4)
