"""Ring-decomposed boundary collectives (repro.core.overlap) and the
sequence-parallel block I/O spec: numerical equivalence vs the monolithic
lax collectives (fwd + grads) on 8 simulated devices, bitwise logits
parity for a 2-layer model on a 2x2 mesh, and the overlap-aware cost
model/search properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import comm_matrix as cm
from repro.core import overlap
from repro.core.atp import atp_linear, make_context
from repro.core.compat import shard_map
from repro.core.cost_model import LayerCommProfile, t_comm_overlap
from repro.core.mesh import MeshTopo
from repro.core.search import search_strategy, search_strategy_overlap

D = 8


def _mesh8():
    return MeshTopo((("i", D),)).build()


def _x():
    return jax.random.normal(jax.random.PRNGKey(0), (D, 16, 32))


# ring collectives run with check_vma=False: their custom_vjp pins the
# transpose schedule explicitly, which the 0.4 replication checker cannot
# type (the lax reference ops get the same setting for a fair comparison).
def _run(f, in_specs, out_specs, *args):
    g = shard_map(f, mesh=_mesh8(), in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    return jax.jit(g)(*args)


RING_CASES = {
    "all_reduce": (
        lambda v: overlap.ring_all_reduce(v, "i", D),
        lambda v: lax.psum(v, "i")),
    "reduce_scatter": (
        lambda v: overlap.ring_reduce_scatter(v, "i", D, 1),
        lambda v: lax.psum_scatter(v, "i", scatter_dimension=1, tiled=True)),
    "all_gather": (
        lambda v: overlap.ring_all_gather(v, "i", D, 1),
        lambda v: lax.all_gather(v, "i", axis=1, tiled=True)),
}


@pytest.mark.parametrize("name", sorted(RING_CASES))
def test_ring_collective_matches_lax_forward(devices8, name):
    ring, ref = RING_CASES[name]
    a = _run(ring, P("i"), P("i"), _x())
    b = _run(ref, P("i"), P("i"), _x())
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("name", sorted(RING_CASES))
def test_ring_collective_matches_lax_grads(devices8, name):
    ring, ref = RING_CASES[name]

    def loss(f):
        return lambda v: jnp.sum(jnp.sin(f(v)))

    a = _run(jax.grad(loss(ring)), P("i"), P("i"), _x())
    b = _run(jax.grad(loss(ref)), P("i"), P("i"), _x())
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


MM_CASES = {
    "ar_chunked": (
        lambda v, w: overlap.overlap_matmul_ar(v, w, "i", D, 4),
        lambda v, w: lax.psum(jnp.einsum("...k,kn->...n", v, w), "i")),
    "ar_uneven": (
        lambda v, w: overlap.overlap_matmul_ar(v, w, "i", D, 3),
        lambda v, w: lax.psum(jnp.einsum("...k,kn->...n", v, w), "i")),
    "reduce_scatter": (
        lambda v, w: overlap.overlap_matmul_rs(v, w, "i", D, 1),
        lambda v, w: lax.psum_scatter(jnp.einsum("...k,kn->...n", v, w),
                                      "i", scatter_dimension=1, tiled=True)),
    "all_gather": (
        lambda v, w: overlap.overlap_matmul_ag(v, w, "i", D, 1),
        lambda v, w: jnp.einsum(
            "...k,kn->...n", lax.all_gather(v, "i", axis=1, tiled=True), w)),
}


@pytest.mark.parametrize("name", sorted(MM_CASES))
def test_collective_matmul_matches_monolithic(devices8, name):
    ring, ref = MM_CASES[name]
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 24)) * 0.1
    a = _run(ring, (P("i"), P()), P("i"), _x(), w)
    b = _run(ref, (P("i"), P()), P("i"), _x(), w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)

    def loss(f):
        return lambda v, ww: jnp.sum(jnp.sin(f(v, ww)))

    ga = _run(jax.grad(loss(ring), argnums=(0, 1)), (P("i"), P()),
              (P("i"), P()), _x(), w)
    gb = _run(jax.grad(loss(ref), argnums=(0, 1)), (P("i"), P()),
              (P("i"), P()), _x(), w)
    for x1, x2 in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# atp_linear chunking satellites: uneven array_split + fused bias epilogue.
# ---------------------------------------------------------------------------


def test_atp_linear_uneven_chunks_and_fused_bias(devices8):
    topo = MeshTopo((("tp1", 2), ("tp2", 2)))
    mesh = topo.build(jax.devices()[:4])
    X = jax.random.normal(jax.random.PRNGKey(0), (7, 16))  # 7 % 3 != 0
    A = jax.random.normal(jax.random.PRNGKey(1), (16, 32)) * 0.1
    bA = jax.random.normal(jax.random.PRNGKey(2), (32,)) * 0.1

    def run(chunks):
        ctx = make_context(topo, chunks=chunks)

        def f(x, a, b):
            return atp_linear(ctx, x, a, b, kind="col")

        g = shard_map(f, mesh=mesh,
                      in_specs=(P(None, "tp2"), P("tp2", "tp1"), P("tp1")),
                      out_specs=P(None, "tp1"), check_vma=False)
        return jax.jit(g)(X, A, bA)

    base = run(1)
    for chunks in (2, 3, 5):  # none divide 7: jnp.array_split fallback
        np.testing.assert_allclose(np.asarray(run(chunks)), np.asarray(base),
                                   rtol=1e-5, atol=1e-6)


def test_pallas_matmul_fused_bias_epilogue():
    from repro.kernels.matmul import matmul

    a = jax.random.normal(jax.random.PRNGKey(0), (48, 96), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 56), jnp.float32) * 0.1
    bias = jax.random.normal(jax.random.PRNGKey(2), (56,), jnp.float32)
    got = matmul(a, b, bias, activation="gelu", block_m=32, block_n=32,
                 block_k=32, interpret=True)
    want = jax.nn.gelu(a @ b + bias, approximate=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Sequence-parallel block I/O: bitwise logits parity on a 2x2 mesh.
# ---------------------------------------------------------------------------


def _logits(cfg, topo, mesh, params, batch, **ctx_kwargs):
    from repro.models import lm

    ctx = make_context(topo, **ctx_kwargs)
    specs = lm.param_specs(cfg, ctx)

    def f(p, b):
        logits = lm.prefill_logits(ctx, cfg, p, b)
        return lax.all_gather(logits, "tp1", axis=-1, tiled=True)

    g = shard_map(f, mesh=mesh, in_specs=(specs, P()), out_specs=P(),
                  check_vma=False)
    return jax.jit(g)(params, batch)


@pytest.mark.parametrize("mode_kwargs", [
    dict(seq_parallel=True),
    dict(seq_parallel=True, boundary_mode="ring"),
    dict(boundary_mode="ring"),
], ids=["seq-parallel", "seq-parallel-ring", "ring"])
def test_seq_parallel_logits_bitwise_match(devices8, mode_kwargs):
    from repro.configs.registry import get_config
    from repro.models import lm

    cfg = dataclasses.replace(get_config("llama3-8b").reduced(), num_layers=2)
    topo = MeshTopo((("tp1", 2), ("tp2", 2)))
    mesh = topo.build(jax.devices()[:4])
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)}
    base = _logits(cfg, topo, mesh, params, batch)
    got = _logits(cfg, topo, mesh, params, batch, **mode_kwargs)
    assert bool((np.asarray(base) == np.asarray(got)).all()), \
        f"{mode_kwargs}: logits differ (max |d| = " \
        f"{np.abs(np.asarray(base) - np.asarray(got)).max()})"


def test_seq_parallel_gated_per_segment(devices8):
    """The whole-network 'seq_parallel is dense-only' error became
    per-segment gating: unsupported kinds mask the knob in their
    ``for_segment`` view instead of failing the whole forward."""
    from repro.configs.registry import get_config
    from repro.models import lm

    cfg = get_config("dbrx-132b").reduced()  # moe segments: sp masked
    topo = MeshTopo((("tp1", 2), ("tp2", 2)))
    ctx = make_context(topo, seq_parallel=True)
    (seg,) = lm.segments(cfg)
    assert seg.kind == "moe"
    assert ctx.for_segment("moe").seq_parallel is False
    assert ctx.for_segment("dense").seq_parallel is True
    # and decode still refuses an (explicitly forced) seq-parallel segment
    import dataclasses as dc

    from repro.core.atp import SegmentPlan

    forced = dc.replace(ctx, segment_plans=(
        SegmentPlan("dense", seq_parallel=True),))
    with pytest.raises(NotImplementedError, match="decode"):
        lm.forward(forced, get_config("qwen1.5-0.5b").reduced(), {},
                   jnp.zeros((1, 8), jnp.int32),
                   jnp.zeros((1, 8), jnp.int32), caches={})


# ---------------------------------------------------------------------------
# Overlap-aware cost model + search.
# ---------------------------------------------------------------------------

PROF = LayerCommProfile.gpt(4096)


def test_seq_parallel_halves_modeled_ax1_boundary_bytes():
    rep = t_comm_overlap(cm.ic4_ib_cluster_16gpu(), 8, 2, layers=4, batch=4,
                         seq=2048, profile=PROF, seq_parallel=False)
    sp = t_comm_overlap(cm.ic4_ib_cluster_16gpu(), 8, 2, layers=4, batch=4,
                        seq=2048, profile=PROF, seq_parallel=True)
    assert rep.ax1_boundary_bytes / sp.ax1_boundary_bytes >= 1.9
    # total fwd+bwd ax1 volume (boundary + conjugate gathers) is conserved
    assert sp.ax1_total_bytes == pytest.approx(rep.ax1_boundary_bytes)


def test_chunking_strictly_cheaper_when_gemm_covers_ring():
    hits = 0
    # sweep compute speeds: slow devices (big GEMM time) must fully
    # overlap; latency-dominated fast ones must not claim the property
    for peak in (5.0, 50.0, 500.0):
        for chunks in (2, 4, 8):
            base = t_comm_overlap(cm.ic4_ib_cluster_16gpu(), 8, 2, layers=4,
                                  batch=4, seq=2048, profile=PROF, chunks=1,
                                  peak_tflops=peak, alpha_s=2e-6)
            c = t_comm_overlap(cm.ic4_ib_cluster_16gpu(), 8, 2, layers=4,
                               batch=4, seq=2048, profile=PROF, chunks=chunks,
                               peak_tflops=peak, alpha_s=2e-6)
            if c.fully_overlapped:
                hits += 1
                assert c.t_exposed < base.t_exposed
    assert hits > 0  # the property must actually be exercised


def test_overlap_search_parity_with_seed_when_disabled():
    """With chunking/seq-parallel off and Rabenseifner accounting, the
    (d1, d2) optimum matches the seed Eq. 2 search on every preset."""
    for matrix, n in ((cm.ic3_nvswitch_8gpu(), 8),
                      (cm.ic4_ib_cluster_16gpu(), 16),
                      (cm.tpu_v5e_pod(), 16)):
        seed = search_strategy(matrix, n, layers=4, batch=4, seq=2048,
                               profile=PROF)
        ov = search_strategy_overlap(
            matrix, n, layers=4, batch=4, seq=2048, profile=PROF,
            chunks_options=(1,), seq_parallel_options=(False,),
            algo="rabenseifner", alpha_s=0.0)
        assert ov.mesh() == seed.mesh(), matrix.name
        seed_rank = [(c.d1, c.d2) for c in seed.ranked]
        ov_rank = [(c.d1, c.d2) for c in ov.ranked]
        assert ov_rank == seed_rank, matrix.name


def test_overlap_search_explores_chunks_and_seq_parallel():
    r = search_strategy_overlap(cm.ic4_ib_cluster_16gpu(), 16, layers=4,
                                batch=4, seq=2048, profile=PROF,
                                peak_tflops=50.0, alpha_s=2e-6)
    explored = {(c.chunks, c.seq_parallel) for c in r.ranked}
    assert len(explored) > 1
    cfgs = r.config()
    assert set(cfgs) == {"d1", "d2", "chunks", "seq_parallel"}
    # exposed time never exceeds raw comm time anywhere in the ranking
    assert all(c.t_exposed <= c.t_comm + 1e-12 for c in r.ranked)
