"""Fault-domain runtime: budgeted recalibration, robust timing, the
server degradation ladder, torn-checkpoint accounting, FaultPlan.

Server tests drive the REAL scheduler with a fake compiled step — a
pure function of (token, absolute position) — so admission backoff,
deadline expiry, pool drain and reshape replay are exercised without an
XLA compile, and greedy-token parity across a reshape is exact by
construction iff the scheduler replays positions faithfully.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core import comm_matrix
from repro.core.calibrate import (CalibEntry, CalibrationTable,
                                  analytic_entry, recalibrate_surviving,
                                  robust_seconds, sensitivity_order)
from repro.core.plan import ParallelPlan, plan_search
from repro.models.paging import PagedConfig
from repro.runtime.faults import (KINDS, BackpressureAllocator, FaultEvent,
                                  FaultPlan, TornCheckpointWrites,
                                  VirtualStepClock, delivery_schedule,
                                  trainer_injector)
from repro.runtime.server import Request, Server, ServerConfig


# ---------------------------------------------------------------------------
# Robust micro-benchmark timing (satellite: median-of-k + outlier trim).
# ---------------------------------------------------------------------------


class TestRobustSeconds:
    def test_median_of_clean_samples(self):
        assert robust_seconds([0.012, 0.010, 0.011]) == pytest.approx(0.011)

    def test_high_outlier_trimmed(self):
        # a 25x GC-pause sample must not drag the estimate
        assert robust_seconds([0.010, 0.011, 0.25]) == pytest.approx(0.0105)

    def test_single_sample_passthrough(self):
        assert robust_seconds([0.3]) == pytest.approx(0.3)

    def test_outlier_does_not_flip_ic1_factorization(self):
        """The regression this satellite pins: one polluted sample in the
        (2, 2) micro-benchmark used to flip the ic1 search to (4, 1)."""
        from repro.configs.base import ModelConfig

        cfg = ModelConfig(name="t", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=256, head_dim=16)
        payload = 1e6
        # ground truth: (2,2) genuinely fastest; one 200x outlier sample
        # (a GC pause mid-benchmark) pollutes its set
        samples = {(4, 1): [5e-3, 5e-3, 5e-3],
                   (2, 2): [5e-4, 5e-4, 1e-1],
                   (1, 4): [1e-2, 1e-2, 1e-2]}

        def table(estimate):
            entries = []
            for (d1, d2), ss in samples.items():
                b = payload / estimate(ss)
                entries.append(((d1, d2), CalibEntry(
                    b1=b if d1 > 1 else float("inf"),
                    b2=b if d2 > 1 else float("inf"))))
            return CalibrationTable(entries=tuple(sorted(entries)),
                                    source="measured")

        def best(tbl):
            p = plan_search("ic1", 4, model=cfg, batch=8, seq=64,
                            calibration=tbl).best
            return (p.d1, p.d2)

        clean = best(table(lambda ss: sorted(ss)[1]))
        assert best(table(robust_seconds)) == clean
        assert best(table(lambda ss: sum(ss) / len(ss))) != clean


# ---------------------------------------------------------------------------
# Deadline-budgeted recalibration.
# ---------------------------------------------------------------------------


def budget_fixture():
    old = CalibrationTable(entries=(
        ((4, 1), CalibEntry(b1=10.0, b2=float("inf"))),
        ((2, 2), CalibEntry(b1=9.0, b2=8.0)),
    ), source="measured")
    plan = ParallelPlan(d1=4, d2=1, dp=1, topology="ic3", calibration=old)
    clock = [0.0]

    def timer():
        return clock[0]

    def measure(d1, d2):
        clock[0] += 1.0
        return CalibEntry(b1=100.0, b2=100.0)

    return plan, clock, timer, measure


class TestDeadlineBudget:
    def test_spend_never_exceeds_deadline(self):
        # the budget is checked before each micro-benchmark and a running
        # one cannot be preempted, so the hard bound is deadline_s plus
        # at most ONE measurement quantum (here each costs 1.0s); any
        # deadline past the first quantum is respected exactly
        plan, clock, timer, measure = budget_fixture()
        for deadline in (0.0, 0.5, 1.0, 1.5, 2.5, 10.0):
            clock[0] = 0.0
            recalibrate_surviving(plan, devices=list(range(4)),
                                  measure=measure, deadline_s=deadline,
                                  timer=timer)
            assert clock[0] <= deadline + 1.0, f"deadline_s={deadline}"
            if deadline >= 1.0 or deadline == 0.0:
                assert clock[0] <= deadline, f"deadline_s={deadline}"

    def test_sensitivity_order_spends_budget_first(self):
        plan, clock, timer, measure = budget_fixture()
        new = recalibrate_surviving(plan, devices=list(range(4)),
                                    measure=measure, deadline_s=1.5,
                                    timer=timer)
        by_key = dict(new.calibration.entries)
        order = sensitivity_order(list(by_key), comm_matrix.PRESETS["ic3"]())
        assert by_key[order[0]].provenance == "measured"
        assert all(by_key[k].provenance != "measured" for k in order[1:])

    def test_carried_and_analytic_fallbacks(self):
        plan, clock, timer, measure = budget_fixture()
        new = recalibrate_surviving(plan, devices=list(range(4)),
                                    measure=measure, deadline_s=0.0,
                                    timer=timer)
        by_key = dict(new.calibration.entries)
        # old table had (4,1) and (2,2) -> carried; (1,4) never measured
        # -> analytic from the topology model
        assert by_key[(4, 1)].provenance == "carried"
        assert by_key[(4, 1)].b1 == 10.0
        assert by_key[(1, 4)].provenance == "analytic"
        # the merged table keeps the old table's lineage in its source
        assert "deadline-budgeted" in new.calibration.source
        # an exhausted budget must not claim a recalibration happened
        assert not any(v.startswith("recalibrated")
                       for _, v in new.provenance)
        assert any(k == "calibration" and v.startswith("budget")
                   for k, v in new.provenance)

    def test_unbudgeted_path_all_measured(self):
        plan, clock, timer, measure = budget_fixture()
        new = recalibrate_surviving(plan, devices=list(range(4)),
                                    measure=measure)
        counts = new.calibration.provenance_counts()
        assert counts == {"measured": len(new.calibration.entries)}
        assert new.calibration.source == "measured"

    def test_describe_shows_counts_only_when_degraded(self):
        plan, clock, timer, measure = budget_fixture()
        budgeted = recalibrate_surviving(plan, devices=list(range(4)),
                                         measure=measure, deadline_s=1.5,
                                         timer=timer)
        assert " calib[" in budgeted.describe()
        # fully-measured, unbudgeted plans keep their historical describe
        # string (other tests pin it)
        full = recalibrate_surviving(plan, devices=list(range(4)),
                                     measure=measure)
        assert " calib[" not in full.describe()

    def test_analytic_entry_matches_topology_model(self):
        matrix = comm_matrix.PRESETS["ic3"]()
        e = analytic_entry(matrix, 2, 2)
        assert e.provenance == "analytic"
        assert np.isfinite(e.b1) and np.isfinite(e.b2)
        assert analytic_entry(matrix, 1, 4).b1 == float("inf")


# ---------------------------------------------------------------------------
# FaultPlan + adapters.
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_json_round_trip(self, tmp_path):
        plan = FaultPlan.scripted(
            FaultEvent("device_loss", at=5, hosts=(2, 3)),
            FaultEvent("straggler", at=2, duration=3, severity=8.0),
            seed=7)
        assert FaultPlan.from_dict(plan.to_dict()) == plan
        p = tmp_path / "plan.json"
        plan.dump(str(p))
        assert FaultPlan.load(str(p)) == plan

    def test_sample_is_seeded_and_never_kills_host_zero(self):
        for seed in range(20):
            plan = FaultPlan.sample(seed, n_events=6, n_hosts=4)
            assert plan == FaultPlan.sample(seed, n_events=6, n_hosts=4)
            for ev in plan.by_kind("device_loss"):
                assert 0 not in ev.hosts
            assert all(ev.kind in KINDS for ev in plan.events)

    def test_events_sorted_and_validated(self):
        plan = FaultPlan.scripted(FaultEvent("torn_ckpt", at=9),
                                  FaultEvent("straggler", at=1, duration=1))
        assert [e.at for e in plan.events] == [1, 9]
        with pytest.raises(ValueError):
            FaultEvent("disk_on_fire", at=1)
        with pytest.raises(ValueError):
            FaultEvent("torn_ckpt", at=-1)
        with pytest.raises(ValueError):
            plan.by_kind("disk_on_fire")

    def test_virtual_step_clock_manufactures_stragglers(self):
        plan = FaultPlan.scripted(
            FaultEvent("straggler", at=1, duration=1, severity=5.0))
        clock = VirtualStepClock(plan, base_dt=0.01)
        reads = [clock() for _ in range(6)]   # three (t0, t1) step pairs
        dts = [reads[2 * i + 1] - reads[2 * i] for i in range(3)]
        assert dts == pytest.approx([0.01, 0.05, 0.01])

    def test_backpressure_allocator_windows_and_delegates(self):
        class StubAlloc:
            free_pages = 11

            def ensure(self, slot, n):
                return True

        ticks = [0]
        bp = BackpressureAllocator(
            StubAlloc(), FaultPlan.scripted(
                FaultEvent("backpressure", at=2, duration=3)),
            lambda: ticks[0])
        got = []
        for ticks[0] in range(7):
            got.append(bp.ensure(0, 4))
        assert got == [True, True, False, False, False, True, True]
        assert bp.denied == 3
        assert bp.free_pages == 11   # everything else delegates

    def test_delivery_schedule_delays_named_senders(self):
        plan = FaultPlan.scripted(
            FaultEvent("lease_delay", at=1.0, hosts=(2,), duration=0.5,
                       severity=0.3))
        delivery = delivery_schedule(plan, base_delay=0.01)
        assert delivery(2, 0, 1.2) == pytest.approx(0.31)
        assert delivery(1, 0, 1.2) == pytest.approx(0.01)   # other senders
        assert delivery(2, 0, 2.0) == pytest.approx(0.01)   # window over

    def test_trainer_injector_fires_once_per_event(self):
        plan = FaultPlan.scripted(FaultEvent("device_loss", at=3))
        inject = trainer_injector(plan)
        inject(2)
        with pytest.raises(RuntimeError):
            inject(3)
        inject(3)   # the replayed step after recovery must survive


# ---------------------------------------------------------------------------
# Trainer: torn checkpoint writes share the failure budget.
# ---------------------------------------------------------------------------


def make_fake_trainer(ckpt_dir, total=6, every=2, max_failures=2):
    from repro.data.pipeline import DataConfig, TokenSource
    from repro.runtime.trainer import Trainer, TrainerConfig

    def build_step():
        def step(params, opt, batch):
            return params, opt, {"loss": 1.0}
        return step

    return Trainer(
        TrainerConfig(total_steps=total, ckpt_dir=str(ckpt_dir),
                      ckpt_every=every, max_failures=max_failures),
        build_step,
        TokenSource(DataConfig(vocab_size=64, seq_len=8, global_batch=2)),
        init_state=lambda: ({"w": np.zeros(3, np.float32)},
                            {"m": np.zeros(3, np.float32)}),
        put_batch=lambda b: b)


class TestTornCheckpoint:
    def test_torn_save_counted_swept_and_retried(self, tmp_path):
        from repro.checkpoint import manager as ckpt

        trainer = make_fake_trainer(tmp_path)
        plan = FaultPlan.scripted(FaultEvent("torn_ckpt", at=4))
        with TornCheckpointWrites(plan) as torn:
            trainer.run()
        assert torn.torn == [4]
        assert trainer.total_failures == 1
        assert len(trainer.history) == 6
        assert ckpt.latest_step(str(tmp_path)) == 6
        assert not [n for n in os.listdir(tmp_path)
                    if n.startswith(".tmp_")]

    def test_consecutive_failure_reset_after_commit(self, tmp_path):
        trainer = make_fake_trainer(tmp_path)
        with TornCheckpointWrites(FaultPlan.scripted(
                FaultEvent("torn_ckpt", at=2), FaultEvent("torn_ckpt", at=4))):
            trainer.run()
        # each torn save recovered, and the committed step between them
        # decayed the consecutive counter — the lifetime count keeps both
        assert trainer.total_failures == 2
        assert trainer.failures == 0

    def test_budget_exhaustion_raises(self, tmp_path, monkeypatch):
        trainer = make_fake_trainer(tmp_path, max_failures=2)
        monkeypatch.setattr(
            "repro.checkpoint.manager.save",
            lambda *a, **k: (_ for _ in ()).throw(OSError("disk gone")))
        with pytest.raises(OSError):
            trainer.run()
        assert trainer.failures == trainer.cfg.max_failures + 1


# ---------------------------------------------------------------------------
# Server degradation ladder + reshape, on a fake compiled step.
# ---------------------------------------------------------------------------

VOCAB = 97


def fake_step(tokens, start, table, caches):
    """Greedy 'model': output at absolute position p is a pure function
    of (input token at p, p) — so a faithful replay reproduces the exact
    token stream, and any position bookkeeping bug breaks parity."""
    tokens = np.asarray(tokens)
    out = np.zeros_like(tokens)
    for b in range(tokens.shape[0]):
        for j in range(tokens.shape[1]):
            out[b, j] = (int(tokens[b, j]) * 31
                         + (int(start[b]) + j) * 7 + 13) % VOCAB
    return out, caches


def make_server(num_pages=40, **kw):
    pcfg = PagedConfig(page_size=4, num_pages=num_pages, pages_per_slot=8)
    scfg = ServerConfig(batch_slots=2, prefill_chunk=4, paged=pcfg, **kw)
    return Server(scfg, fake_step,
                  lambda: np.zeros((1, pcfg.num_pages, pcfg.page_size),
                                   np.float32))


def submit_all(server, n, max_new=6, deadline=None, seed=0):
    # prompt lengths 5..7: admission reserves 2 pages (one rounded
    # chunk), decode grows each request to 3 — so the tiny num_pages=4
    # pool (3 usable) can run any ONE request but never two, and every
    # queued request fails admission while one runs (sustained,
    # recoverable backpressure rather than a deadlock)
    rng = np.random.default_rng(seed)
    for rid in range(n):
        server.submit(Request(
            rid=rid,
            prompt=rng.integers(0, VOCAB, size=5 + rid % 3,
                                dtype=np.int32),
            max_new=max_new,
            deadline_ticks=deadline(rid) if deadline else None))


class TestServerDegradation:
    def test_deadlines_expire_and_pool_drains(self):
        # 3 usable pages for 2 slots: the pool itself is the fault
        server = make_server(num_pages=4)
        submit_all(server, 4, deadline=lambda rid: 15)
        server.run_until_drained()
        st = server.stats()
        assert st["expired"] > 0
        assert st["admission_retries"] > 0
        assert len(server.completed) + len(server.expired) == 4
        for r in server.expired:
            assert r.expired and not r.done
        assert server.alloc.held_pages == 0 and not server.busy

    def test_no_deadline_waits_out_the_pressure(self):
        server = make_server(num_pages=4)
        submit_all(server, 4)
        server.run_until_drained()
        assert len(server.completed) == 4 and not server.expired

    def test_backoff_reduces_doomed_retries(self):
        def retries(**kw):
            server = make_server(num_pages=4, **kw)
            submit_all(server, 4)
            server.run_until_drained()
            assert len(server.completed) == 4
            return server.stats()["admission_retries"]

        eager = retries(admission_backoff_base=1, admission_backoff_max=1)
        backed = retries()
        assert 0 < backed < eager

    def test_expiry_frees_pages_for_the_queue(self):
        # with deadlines, the doomed front-runners die and the rest are
        # served; without eager expiry the pool would wedge on them
        server = make_server(num_pages=4)
        submit_all(server, 6, deadline=lambda rid: 12 if rid < 4 else None)
        server.run_until_drained()
        assert sorted(r.rid for r in server.completed)[-2:] == [4, 5]

    def test_low_water_evicts_pinned_prefix_pages(self):
        server = make_server(num_pages=8, prefix_cache=True,
                             eviction_low_water=6)
        server.submit(Request(rid=0,
                              prompt=np.arange(8, dtype=np.int32),
                              max_new=1))
        server.run_until_drained()   # registers a 2-page pinned prefix
        pinned = server.alloc.pinned_pages
        assert pinned > 0
        server.step()                # free 5 < low-water 6 -> evict
        # only the shortfall is shed (leaf-first), not the whole prefix
        assert server.stats()["evicted_pages"] == 1
        assert server.alloc.pinned_pages == pinned - 1
        assert server.alloc.free_pages >= 6


class TestServerReshape:
    def run_baseline(self, n=4, max_new=6, **kw):
        server = make_server(**kw)
        submit_all(server, n, max_new=max_new, seed=11)
        server.run_until_drained()
        return {r.rid: list(r.out) for r in server.completed}

    def test_greedy_parity_across_reshape(self):
        baseline = self.run_baseline()
        server = make_server()
        submit_all(server, 4, seed=11)
        for _ in range(6):
            server.step()    # leave requests mid-prefill and mid-decode
        assert any(s is not None for s in server.slots)
        server.reshape(fake_step, lambda: None)
        server.run_until_drained()
        assert {r.rid: list(r.out) for r in server.completed} == baseline
        assert server.stats()["reshapes"] == 1
        assert server.alloc.held_pages == 0

    def test_reshape_at_every_tick_preserves_parity(self):
        # the drain-and-remesh replay must be parity-exact no matter
        # where in the request lifecycle the mesh change lands
        baseline = self.run_baseline(n=3, max_new=4)
        full = make_server()
        submit_all(full, 3, max_new=4, seed=11)
        total = full.run_until_drained()
        for cut in range(1, total):
            server = make_server()
            submit_all(server, 3, max_new=4, seed=11)
            for _ in range(cut):
                server.step()
            server.reshape(fake_step, lambda: None)
            server.run_until_drained()
            got = {r.rid: list(r.out) for r in server.completed}
            assert got == baseline, f"parity broke at cut={cut}"

    def test_reshape_keeps_deadlines_and_counters(self):
        server = make_server(num_pages=4)
        submit_all(server, 4, deadline=lambda rid: 15)
        for _ in range(4):
            server.step()
        server.reshape(fake_step, lambda: None)
        server.run_until_drained()
        st = server.stats()
        assert st["reshapes"] == 1
        assert len(server.completed) + len(server.expired) == 4
        assert server.alloc.held_pages == 0
