"""End-to-end quantization: int8/fp8 wire collectives (parity + STE
grads), quantized page pools (margin-filtered greedy parity), the
quantized matmul epilogue, error-feedback DP gradient state, and the
planner pricing the quantized wire (format_version 4, search flips)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.core import comm_matrix, overlap
from repro.core.atp import make_context
from repro.core.calibrate import CalibEntry, CalibrationTable, calibrate_mesh
from repro.core.compat import shard_map
from repro.core.cost_model import LayerCommProfile, wire_bytes_per_elem
from repro.core.mesh import MeshTopo, atp_topo
from repro.core.plan import PLAN_FORMAT_VERSION, ParallelPlan, plan_search
from repro.core.search import search_strategy_overlap
from repro.models import lm
from repro.models.paging import PageAllocator, PagedConfig
from repro.optim import adamw
from repro.optim.grad_compress import compressed_psum_mean_ef

D = 8
GPT = LayerCommProfile.gpt(4096)


def _mesh8():
    return MeshTopo((("i", D),)).build()


def _run(f, in_specs, out_specs, *args):
    g = shard_map(f, mesh=_mesh8(), in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    return jax.jit(g)(*args)


def _x(seed=0, shape=(D, 16, 32)):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def _wire_bound(x, wire_dtype):
    """Worst-case absolute error of a quantized all-reduce of ``x``.

    Shared scale = global amax / qmax; each rank contributes at most half
    a grid step (int8) / half the top-of-range ulp (e4m3: 32 at 448)."""
    amax = float(jnp.max(jnp.abs(x)))
    per_rank = (amax / 448.0) * 16.0 if (
        wire_dtype == "fp8" and overlap._FP8_DTYPE is not None
    ) else (amax / 127.0) * 0.5
    return D * per_rank * 1.01


# ---------------------------------------------------------------------------
# Wire collectives: quantized ~= full-width within the grid-error bound.
# ---------------------------------------------------------------------------


QUANT_CASES = {
    "psum": (
        lambda v, wd: overlap.quant_psum(v, "i", wd),
        lambda v: lax.psum(v, "i")),
    "ring_ar": (
        lambda v, wd: overlap.quant_ring_all_reduce(v, "i", D, wd),
        lambda v: lax.psum(v, "i")),
    "reduce_scatter": (
        lambda v, wd: overlap.quant_reduce_scatter(v, "i", D, 1, wd),
        lambda v: lax.psum_scatter(v, "i", scatter_dimension=1, tiled=True)),
    "ring_rs": (
        lambda v, wd: overlap.quant_reduce_scatter(v, "i", D, 1, wd,
                                                   ring=True),
        lambda v: lax.psum_scatter(v, "i", scatter_dimension=1, tiled=True)),
}


@pytest.mark.parametrize("wd", ["int8", "fp8"])
@pytest.mark.parametrize("name", sorted(QUANT_CASES))
def test_quant_collective_within_grid_bound(devices8, name, wd):
    quant, ref = QUANT_CASES[name]
    x = _x()
    a = np.asarray(_run(lambda v: quant(v, wd), P("i"), P("i"), x))
    b = np.asarray(_run(ref, P("i"), P("i"), x))
    err = np.max(np.abs(a - b))
    assert err <= _wire_bound(x, wd), (name, wd, err)
    # and the wire really was quantized (not a full-width fallback)
    assert err > 0.0


@pytest.mark.parametrize("wd", ["int8", "fp8"])
def test_quant_collective_ste_grads(devices8, wd):
    """Backward is the mirrored quantized collective on the cotangent —
    a straight-through estimator.  A linear loss makes the cotangent
    exactly the weight tensor, so the grad difference IS one quantized
    all-reduce's grid error (nonlinear losses would additionally amplify
    the forward error, which is not what this pins)."""
    x, w = _x(), _x(seed=7)

    def loss(f):
        return lambda v, wt: jnp.sum(f(v) * wt)

    a = _run(jax.grad(loss(lambda v: overlap.quant_psum(v, "i", wd))),
             (P("i"), P("i")), P("i"), x, w)
    b = _run(jax.grad(loss(lambda v: lax.psum(v, "i"))),
             (P("i"), P("i")), P("i"), x, w)
    # grad = (quant_)psum(w): bounded by w's wire grid
    assert float(jnp.max(jnp.abs(a - b))) <= _wire_bound(w, wd)


@pytest.mark.parametrize("wd", ["int8", "fp8"])
def test_quant_overlap_matmul_ar_parity(devices8, wd):
    """Chunked collective matmul on the quantized wire: dequant rides the
    chunk epilogue, result stays within a few percent of full width."""
    x, w = _x(), jax.random.normal(jax.random.PRNGKey(1), (32, 24)) * 0.1
    b = jnp.ones((24,)) * 0.5

    def quant(v, wt):
        return overlap.overlap_matmul_ar(v, wt, "i", D, 4, b=b,
                                         wire_dtype=wd)

    def full(v, wt):
        return overlap.overlap_matmul_ar(v, wt, "i", D, 4, b=b)

    a = np.asarray(_run(quant, (P("i"), P()), P("i"), x, w))
    r = np.asarray(_run(full, (P("i"), P()), P("i"), x, w))
    rel = np.max(np.abs(a - r)) / np.max(np.abs(r))
    assert 0.0 < rel < 0.05, rel

    # grads flow through the quantized ring (STE), close to full width
    def lossq(v):
        return jnp.sum(jnp.sin(quant(v, w)))

    def lossf(v):
        return jnp.sum(jnp.sin(full(v, w)))

    ga = np.asarray(_run(jax.grad(lossq), P("i"), P("i"), x))
    gr = np.asarray(_run(jax.grad(lossf), P("i"), P("i"), x))
    assert np.all(np.isfinite(ga))
    grel = np.max(np.abs(ga - gr)) / (np.max(np.abs(gr)) + 1e-12)
    assert grel < 0.1, grel


# ---------------------------------------------------------------------------
# Quantized page pools: margin-filtered teacher-forced greedy parity.
# ---------------------------------------------------------------------------

TOPO1 = MeshTopo((("data", 1),))


def _teacher_forced_paged_logits(cfg, params, tokens, pcfg):
    """Feed the true token at every step through the paged cache; return
    [B, S, V] last-position logits."""
    B, S = tokens.shape
    mesh = TOPO1.build(jax.devices()[:1])
    ctx = make_context(TOPO1)
    alloc = PageAllocator(pcfg, slots=B)
    caches, _ = lm.init_paged_caches(cfg, ctx, pcfg, dtype=jnp.float32)

    def step(p, tok, start, table, caches):
        return lm.paged_step(ctx, cfg, p, tok, start, table, caches)

    g = jax.jit(shard_map(step, mesh=mesh,
                          in_specs=(P(), P(), P(), P(), P()),
                          out_specs=(P(), P()), check_vma=True))
    outs = []
    for t in range(S):
        for s in range(B):
            alloc.ensure(s, t + 1)
        start = jnp.full((B,), t, jnp.int32)
        logits, caches = g(params, tokens[:, t: t + 1], start,
                           jnp.asarray(alloc.table()), caches)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


# measured on this model/trace: worst argmax flip sits at margin 0.018
# (int8) / 0.149 (fp8, coarser e4m3 grid); thresholds leave ~3x headroom
_PARITY_MARGIN = {"int8": 0.05, "fp8": 0.25}


@pytest.mark.parametrize("page_dtype", ["int8", "fp8"])
def test_paged_decode_quant_greedy_parity(page_dtype):
    """Greedy argmax through int8/fp8 page pools matches the full-width
    pool wherever the full-width decision margin exceeds the quantization
    perturbation.  Near-ties below the threshold are the ONLY places
    quantization may flip the pick."""
    cfg = get_config("llama3-8b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 112
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    geom = dict(page_size=8, num_pages=2 * (S // 8 + 1) + 2,
                pages_per_slot=S // 8 + 1)
    ref = _teacher_forced_paged_logits(cfg, params, tokens,
                                       PagedConfig(**geom))
    got = _teacher_forced_paged_logits(
        cfg, params, tokens, PagedConfig(page_dtype=page_dtype, **geom))

    ref = np.asarray(ref, np.float32)
    got = np.asarray(got, np.float32)
    top2 = np.sort(ref, axis=-1)[..., -2:]
    margin = top2[..., 1] - top2[..., 0]          # [B, S]
    compared = margin > _PARITY_MARGIN[page_dtype]
    assert int(compared.sum()) >= 64, int(compared.sum())
    agree = ref.argmax(-1) == got.argmax(-1)
    assert bool(np.all(agree[compared])), (
        f"{int((~agree & compared).sum())} confident-argmax flips")
    # the pools really are narrow (+ fp16 scale tensors ride along)
    caches, _ = lm.init_paged_caches(
        cfg, make_context(TOPO1), PagedConfig(page_dtype=page_dtype, **geom),
        dtype=jnp.float32)
    assert any(x.dtype.itemsize == 1 for x in jax.tree.leaves(caches))


def test_quant_pool_bytes_ratio():
    """int8 pages + fp16 per-position scales cut pool bytes >= 1.8x vs a
    bf16 pool of the same geometry."""
    cfg = get_config("llama3-8b").reduced()
    ctx = make_context(TOPO1)
    geom = dict(page_size=8, num_pages=32, pages_per_slot=8)

    def nbytes(page_dtype, dtype):
        caches = jax.eval_shape(
            lambda: lm.init_paged_caches(
                cfg, ctx, PagedConfig(page_dtype=page_dtype, **geom),
                dtype=dtype)[0])
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree.leaves(caches))

    ratio = nbytes("bf16", jnp.bfloat16) / nbytes("int8", jnp.bfloat16)
    assert ratio >= 1.8, ratio


# ---------------------------------------------------------------------------
# Quantized matmul kernel epilogue (interpret mode).
# ---------------------------------------------------------------------------


def test_quant_matmul_epilogue_interpret():
    from repro.kernels.matmul import matmul, quantize_for_matmul

    k = jax.random.PRNGKey(0)
    a = jax.random.normal(k, (64, 96), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 48), jnp.float32) * 0.2
    bias = jnp.linspace(-1, 1, 48, dtype=jnp.float32)
    ref = jax.nn.gelu(a @ b + bias, approximate=True)

    qa, sa = quantize_for_matmul(a)
    qb, sb = quantize_for_matmul(b)
    assert qa.dtype == jnp.int8
    out = matmul(qa, qb, bias, scale=sa * sb, activation="gelu",
                 out_dtype=jnp.float32, block_m=32, block_n=32, block_k=32,
                 interpret=True)
    rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.03, rel

    # full-width path is untouched by the new operand plumbing
    full = matmul(a, b, bias, activation="gelu", block_m=32, block_n=32,
                  block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Error-feedback DP gradient state.
# ---------------------------------------------------------------------------


def test_compressed_ef_residual_invariant(devices8):
    """The carried residual is REPLICATED over dp (it leaves a
    replication-checked shard_map with out_specs=P()) and approximates
    exactly what the quantized mean dropped:
    ``new_err ~= pmean(g) + err_in - mean_grad`` to one grid step."""
    topo = MeshTopo((("data", 8),))
    mesh = topo.build()
    g = jax.random.normal(jax.random.PRNGKey(3), (8, 64)) * 0.1
    err_in = (jax.random.normal(jax.random.PRNGKey(4), (8, 64)) * 0.01)[0]

    def f(g, err):
        mean, new_err = compressed_psum_mean_ef(g, err, ("data",))
        exact = lax.pmean(g.astype(jnp.float32) + err, "data")
        return mean, new_err, exact

    # out_specs=P() for new_err IS the replication assertion
    h = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("data"), P()),
                          out_specs=(P(), P(), P()), check_vma=True))
    mean, new_err, exact = h(g, err_in)
    grid = float(jnp.max(jnp.abs(g)) + jnp.max(jnp.abs(err_in))) / 127.0
    dropped = np.asarray(exact) - np.asarray(mean)
    np.testing.assert_allclose(np.asarray(new_err), dropped,
                               atol=1.01 * grid)
    assert float(jnp.max(jnp.abs(mean - exact))) < 0.02
    assert float(jnp.max(jnp.abs(new_err))) > 0.0


def _ef_toy():
    topo = MeshTopo((("data", 4), ("tp1", 2)))
    mesh = topo.build(jax.devices()[: topo.size])
    ctx = make_context(topo)
    W = jax.random.normal(jax.random.PRNGKey(0), (8, 16)) * 0.1
    pspecs = {"W": P(None, "tp1")}
    return mesh, ctx, {"W": W}, pspecs


def test_opt_state_compressed_carries_err(devices8):
    mesh, ctx, params, pspecs = _ef_toy()
    opt = adamw.init_opt_state(params, pspecs, ctx, "compressed")
    assert "err" in opt
    assert opt["err"]["W"].shape == params["W"].shape
    assert float(jnp.max(jnp.abs(opt["err"]["W"]))) == 0.0
    specs = adamw.opt_state_specs(pspecs, ctx, "compressed")
    assert specs["err"] == pspecs
    # plain/zero1 states stay err-free (checkpoint layout unchanged)
    assert "err" not in adamw.init_opt_state(params, pspecs, ctx, "plain")
    assert "err" not in adamw.opt_state_specs(pspecs, ctx, "zero1")


def test_apply_adamw_threads_error_feedback(devices8):
    """One compressed step leaves a nonzero residual in opt_state['err'];
    a legacy state without 'err' still applies (memoryless fallback)."""
    mesh, ctx, params, pspecs = _ef_toy()
    cfg = adamw.AdamWConfig(lr=1e-2, mode="compressed", grad_clip=0.0,
                            warmup_steps=1, total_steps=10)
    opt = adamw.init_opt_state(params, pspecs, ctx, "compressed")
    ospecs = adamw.opt_state_specs(pspecs, ctx, "compressed")
    rep = adamw.replication_factors(pspecs, ctx)
    X = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    Y = jax.random.normal(jax.random.PRNGKey(2), (16, 16))

    def step(params, opt, X, Y):
        def loss(p):
            l = jnp.sum((X @ p["W"] - Y) ** 2)
            return jax.lax.psum(l, ("data", "tp1"))

        grads = jax.grad(loss)(params)
        newp, newo, _ = adamw.apply_adamw(cfg, ctx, params, grads, opt, rep)
        return newp, newo

    f = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ospecs, P("data", None), P("data", "tp1")),
        out_specs=(pspecs, ospecs), check_vma=True))
    newp, newo = f(params, opt, X, Y)
    assert "err" in newo
    assert float(jnp.max(jnp.abs(newo["err"]["W"]))) > 0.0
    assert not np.allclose(np.asarray(newp["W"]), np.asarray(params["W"]))

    # legacy checkpoint state: no 'err' key -> memoryless compression
    legacy = {k: v for k, v in opt.items() if k != "err"}
    lspecs = {k: v for k, v in ospecs.items() if k != "err"}
    g = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, lspecs, P("data", None), P("data", "tp1")),
        out_specs=(pspecs, lspecs), check_vma=True))
    lp, lo = g(params, legacy, X, Y)
    assert "err" not in lo
    assert not np.allclose(np.asarray(lp["W"]), np.asarray(params["W"]))


# ---------------------------------------------------------------------------
# Planner: the search prices the quantized wire (and can flip its pick).
# ---------------------------------------------------------------------------


def test_wire_bytes_per_elem():
    assert wire_bytes_per_elem("bf16", 2) == 2
    assert wire_bytes_per_elem("int8", 2) == 1
    assert wire_bytes_per_elem("fp8", 4) == 1
    with pytest.raises(ValueError, match="wire_dtype"):
        wire_bytes_per_elem("int4", 2)


def test_ic1_analytic_mesh_flip_under_int8():
    """The acceptance pin: PCIe 8-GPU box, llama3-8b dense profile.

    Full width the search folds all TP into the fast leaf axis (8, 1);
    halving the wire bytes shrinks that comm-volume advantage below the
    (4, 2) factorization's larger ring-overlap credit, flipping the
    winning mesh — quantization changes the PLAN, not just the bytes."""
    m = comm_matrix.ic1_pcie_8gpu()
    cfg = get_config("llama3-8b")
    kw = dict(layers=cfg.num_layers, batch=4, seq=2048,
              profile=LayerCommProfile.dense(cfg))
    full = search_strategy_overlap(m, 8, **kw)
    quant = search_strategy_overlap(m, 8, wire_dtype="int8", **kw)
    assert (full.best.d1, full.best.d2) == (8, 1)
    assert (quant.best.d1, quant.best.d2) == (4, 2)
    # quantized wire is strictly cheaper, and (8,1) is still ranked —
    # just beaten by the overlap credit at (4,2)
    assert quant.best.t_exposed < full.best.t_exposed
    q81 = next(c for c in quant.ranked if (c.d1, c.d2) == (8, 1))
    assert quant.best.t_exposed < q81.t_exposed


def test_calibrated_quant_bandwidths_steer_search(devices8):
    """Measured b1_q/b2_q override the full-width table for quantized
    plans: a fabric whose quantized path is slow on one factorization
    demotes it ONLY under wire_dtype=int8."""
    m = comm_matrix.ic1_pcie_8gpu()
    kw = dict(layers=8, batch=8, seq=1024, profile=GPT,
              chunks_options=(1,), seq_parallel_options=(False,))
    table = CalibrationTable(entries=(
        # (8,1): superb full-width axis, terrible quantized path
        ((8, 1), CalibEntry(b1=200.0, b2=float("inf"),
                            b1_q=1.0, b2_q=float("inf"))),
        # (4,2): mediocre full width, fast quantized collectives
        ((4, 2), CalibEntry(b1=20.0, b2=20.0, b1_q=60.0, b2_q=60.0)),
        ((2, 4), CalibEntry(b1=10.0, b2=10.0)),
        ((1, 8), CalibEntry(b1=float("inf"), b2=10.0)),
    ))
    full = search_strategy_overlap(m, 8, calibration=table, **kw)
    quant = search_strategy_overlap(m, 8, calibration=table,
                                    wire_dtype="int8", **kw)
    assert (full.best.d1, full.best.d2) == (8, 1)
    assert (quant.best.d1, quant.best.d2) == (4, 2)


def test_measured_launch_cost_steers_chunks_to_one(devices8):
    """Satellite pin (double-count fix): chunk_eff is pure bandwidth
    efficiency now, so a big measured per-chunk launch cost must come
    from launch_s — eff=1.0 plus large launch_s forces chunks=1."""
    m = comm_matrix.ic4_ib_cluster_16gpu()
    kw = dict(layers=24, batch=64, seq=2048, profile=GPT, peak_tflops=5.0,
              alpha_s=2e-6, chunks_options=(1, 2, 4),
              seq_parallel_options=(False,))
    base = search_strategy_overlap(m, 16, **kw)
    assert base.best.chunks > 1
    entry = CalibEntry(b1=25.0, b2=25.0, launch_s=0.05,
                       chunk_eff=((2, 1.0, 1.0), (4, 1.0, 1.0)))
    table = CalibrationTable(entries=tuple(
        ((d1, d2), entry) for d1, d2 in
        ((1, 16), (2, 8), (4, 4), (8, 2), (16, 1))))
    steered = search_strategy_overlap(m, 16, calibration=table, **kw)
    assert steered.best.chunks == 1
    # zero launch cost with perfect chunk efficiency leaves chunking on
    free = dataclasses.replace(entry, launch_s=0.0)
    table0 = CalibrationTable(entries=tuple(
        ((d1, d2), free) for d1, d2 in
        ((1, 16), (2, 8), (4, 4), (8, 2), (16, 1))))
    kept = search_strategy_overlap(m, 16, calibration=table0, **kw)
    assert kept.best.chunks == base.best.chunks


def test_calibrate_mesh_measures_quant_and_launch(devices8):
    """The on-device micro-benchmark fills launch_s and b1_q/b2_q and
    they survive the JSON round trip."""
    t = calibrate_mesh(4, payload_kb=8, repeats=1)
    for key in ((4, 1), (2, 2), (1, 4)):
        e = dict(t.entries)[key]
        assert e.launch_s is not None and e.launch_s >= 0.0
        q = t.quant_bandwidths(*key)
        assert q is not None
        assert all(b > 0 for b in q)
    back = CalibrationTable.from_dict(json.loads(json.dumps(t.to_dict())))
    assert back == t


# ---------------------------------------------------------------------------
# format_version 4 schema + migration discipline.
# ---------------------------------------------------------------------------


def test_v3_fixture_still_loads():
    """PR-5-era format_version 3 files load under the current version:
    decode sub-plan intact, wire_dtype defaulting to full width
    everywhere."""
    plan = ParallelPlan.load("tests/data/plan_v3_pr5.json")
    assert plan.wire_dtype == "bf16"
    assert plan.decode is not None and plan.decode.wire_dtype == "bf16"
    assert all(s.wire_dtype == "bf16" for s in plan.segments)
    e = dict(plan.calibration.entries)[(4, 2)]
    assert e.launch_s is None and e.b1_q is None  # pre-v4 table fields
    d = plan.to_dict()
    assert d["format_version"] == PLAN_FORMAT_VERSION == 5
    assert ParallelPlan.from_dict(d) == plan


def test_newer_format_version_rejected():
    plan = ParallelPlan.load("tests/data/plan_v3_pr5.json")
    d = plan.to_dict()
    d["format_version"] = PLAN_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="format_version"):
        ParallelPlan.from_dict(d)


def test_plan_search_emits_quantized_v4_plans():
    res = plan_search("ic1", 8, layers=16, batch=8, seq=2048, profile=GPT,
                      wire_dtype="int8", decode_batch=8)
    best = res.best
    assert best.wire_dtype == "int8"
    assert all(s.wire_dtype == "int8" for s in best.segments)
    assert best.decode is not None and best.decode.wire_dtype == "int8"
    q = ParallelPlan.from_json(best.to_json())
    assert q == best
    assert q.decode_view().wire_dtype == "int8"
    with pytest.raises(ValueError, match="wire_dtype"):
        dataclasses.replace(best, wire_dtype="int4")


def test_resolve_ctx_threads_wire_dtype():
    from repro.launch.steps import resolve_ctx

    plan = plan_search("ic1", 8, layers=16, batch=8, seq=2048, profile=GPT,
                       wire_dtype="int8", decode_batch=8).best
    ctx = resolve_ctx(atp_topo(1, plan.d1, plan.d2), plan)
    assert ctx.wire_dtype == "int8"
    assert all(s.wire_dtype == "int8" for s in ctx.segment_plans)
    # serving executes the decode mesh via decode_view (serve.py path)
    view = plan.decode_view()
    dctx = resolve_ctx(atp_topo(1, view.d1, view.d2), view, decode=True)
    assert dctx.wire_dtype == "int8"
    assert dctx.chunks == 1
    assert (dctx.d1, dctx.d2) == (plan.decode.d1, plan.decode.d2)
