"""Membership protocol: leases, quorum commits, planner election.

The split-brain probe in every test is :meth:`MembershipFabric.epochs`
— for each epoch number the set of committed alive-sets must be a
singleton — plus the quorum evidence recorded on every
:class:`CommitRecord` (acks from a majority of the electorate, proposal
stable for ``quorum_views`` consecutive reviews).  The property test at
the bottom drives the fabric through arbitrary seeded failure/delivery
interleavings via the hypothesis shim.
"""
from __future__ import annotations

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.membership import (MembershipConfig, MembershipFabric,
                                      MembershipRuntime,
                                      SingleObserverMembership, View,
                                      fabric_over_devices)


def assert_quorum_evidence(fabric: MembershipFabric):
    """Every originating commit carries majority + stability evidence."""
    for c in fabric.commits:
        majority = len(c.electorate) // 2 + 1
        assert c.acks >= majority, c
        assert c.stable >= fabric.cfg.quorum_views, c
        assert c.rank in c.view.alive or c.rank not in c.electorate, c


def assert_no_split_brain(fabric: MembershipFabric):
    for epoch, views in fabric.epochs().items():
        assert len(views) == 1, f"epoch {epoch} split-brain: {views}"


class TestView:
    def test_planner_is_lowest_surviving_rank(self):
        assert View(epoch=1, alive=(2, 5, 3)).planner == 2

    def test_empty_view_has_no_planner(self):
        with pytest.raises(ValueError):
            View(epoch=1, alive=()).planner


class TestFabric:
    def test_intact_cluster_stays_at_epoch_zero(self):
        fabric = MembershipFabric(4)
        view = fabric.converge()
        assert view == View(epoch=0, alive=(0, 1, 2, 3))
        assert fabric.commits == []

    def test_single_failure_converges_on_survivors(self):
        fabric = MembershipFabric(4)
        fabric.fail_host(2)
        view = fabric.converge()
        assert view.alive == (0, 1, 3)
        assert view.epoch == 1
        assert view.planner == 0
        assert_no_split_brain(fabric)
        assert_quorum_evidence(fabric)

    def test_majority_loss_converges_through_hard_expiry(self):
        # suspicion alone can never assemble a majority of the old
        # electorate here — only dead_after_s expiry shrinks the
        # denominator enough for the lone survivor to commit
        fabric = MembershipFabric(4)
        for r in (1, 2, 3):
            fabric.fail_host(r)
        view = fabric.converge()
        assert view.alive == (0,) and view.planner == 0
        assert_no_split_brain(fabric)
        assert_quorum_evidence(fabric)
        [c] = [c for c in fabric.commits if c.rank == 0]
        assert c.electorate == (0,)   # the dead were expired, not out-voted

    def test_cascading_failures_one_view_per_epoch(self):
        fabric = MembershipFabric(4)
        fabric.fail_host(3)
        v1 = fabric.converge()
        fabric.fail_host(1)
        v2 = fabric.converge()
        assert v1.alive == (0, 1, 2) and v2.alive == (0, 2)
        assert v2.epoch > v1.epoch
        assert_no_split_brain(fabric)
        assert_quorum_evidence(fabric)

    def test_election_follows_lowest_rank(self):
        fabric = MembershipFabric(3)
        fabric.fail_host(0)
        view = fabric.converge()
        assert view.alive == (1, 2) and view.planner == 1
        rt1 = MembershipRuntime(fabric, local_rank=1)
        rt2 = MembershipRuntime(fabric, local_rank=2)
        assert rt1.is_planner(view) and not rt2.is_planner(view)

    def test_short_delay_never_commits(self):
        # beats lagging UNDER the lease never even raise suspicion
        cfg = MembershipConfig()
        fabric = MembershipFabric(
            4, cfg, delivery=lambda s, d, t: cfg.lease_s * 0.5)
        fabric.run_until(5.0)
        assert fabric.commits == []
        assert fabric.converge().epoch == 0

    def test_false_suspicion_heals_by_readmission(self):
        # host 1's beats are DROPPED for a while: the quorum may evict it
        # (that is correct — the evidence said dead), but once beats
        # resume the cluster must re-admit it in a later epoch, and no
        # epoch may ever hold two views
        def delivery(src, dst, t):
            if src == 1 and t < 1.0:
                return None
            return 0.0

        fabric = MembershipFabric(4, delivery=delivery)
        fabric.run_until(2.0)   # live through the deaf window + healing
        view = fabric.converge()
        assert view.alive == (0, 1, 2, 3)
        assert_no_split_brain(fabric)
        assert_quorum_evidence(fabric)
        # the deaf window really did evict it on the way
        assert any(c.view.alive == (0, 2, 3) for c in fabric.commits)

    def test_revive_rejoins_in_new_epoch(self):
        fabric = MembershipFabric(3)
        fabric.fail_host(2)
        v1 = fabric.converge()
        fabric.revive_host(2)
        v2 = fabric.converge()
        assert v1.alive == (0, 1) and v2.alive == (0, 1, 2)
        assert v2.epoch > v1.epoch
        assert_no_split_brain(fabric)

    def test_no_survivors_fails_loudly(self):
        fabric = MembershipFabric(2)
        fabric.fail_host(0)
        fabric.fail_host(1)
        with pytest.raises(TimeoutError):
            fabric.converge(timeout_s=1.0)

    def test_deterministic_replay(self):
        def script(fabric):
            fabric.run_until(0.12)
            fabric.fail_host(3)
            fabric.run_until(0.3)
            fabric.fail_host(1)
            fabric.converge()
            return fabric.commits

        assert script(MembershipFabric(4)) == script(MembershipFabric(4))


class TestFabricOverDevices:
    def test_even_slices_and_survivor_concatenation(self):
        devices = [f"d{i}" for i in range(8)]
        fabric = fabric_over_devices(4, devices)
        assert fabric.host_devices[1] == ["d2", "d3"]
        fabric.fail_host(1)
        fabric.fail_host(3)
        view = fabric.converge()
        assert fabric.surviving_devices(view) == ["d0", "d1", "d4", "d5"]

    def test_indivisible_pool_rejected(self):
        with pytest.raises(ValueError):
            fabric_over_devices(3, list(range(8)))


class TestSingleObserverShim:
    def test_always_planner_epoch_bumps_on_pool_change(self):
        pool = [object(), object()]
        shim = SingleObserverMembership(lambda: pool)
        v0 = shim.converged_view()
        assert shim.is_planner(v0) and v0.epoch == 0
        assert shim.devices(v0) == pool
        pool.pop()
        assert shim.converged_view().epoch == 1


# ---------------------------------------------------------------------------
# Property: arbitrary failure/delivery interleavings keep the invariants.
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(n_hosts=st.integers(3, 5),
       kill_mask=st.integers(0, 15),
       stagger_ds=st.integers(0, 3),
       delay_cs=st.integers(0, 35),       # 0..0.35s, under dead_after_s
       delayed_src=st.integers(0, 4),
       delay_until_ds=st.integers(0, 12))
def test_property_membership_invariants(n_hosts, kill_mask, stagger_ds,
                                        delay_cs, delayed_src,
                                        delay_until_ds):
    """Single elected planner per epoch, convergence on the healthy set,
    and no commit without quorum — for any seeded interleaving of
    failures (simultaneous or staggered) and bounded heartbeat delays."""
    kills = [r for r in range(1, n_hosts) if (kill_mask >> (r - 1)) & 1]

    def delivery(src, dst, t):
        if src == delayed_src % n_hosts and t < delay_until_ds / 10.0:
            return delay_cs / 100.0
        return 0.0

    fabric = MembershipFabric(n_hosts, delivery=delivery)
    t = 0.0
    for r in kills:
        fabric.run_until(t)
        fabric.fail_host(r)
        t += stagger_ds / 10.0
    view = fabric.converge(timeout_s=30.0)

    healthy = tuple(r for r in range(n_hosts) if r not in kills)
    assert view.alive == healthy
    assert view.planner == min(healthy)
    assert_no_split_brain(fabric)
    assert_quorum_evidence(fabric)
    # the election is a pure function of the view, so a singleton view
    # per epoch IS a single elected re-planner per epoch
    planners: dict[int, set[int]] = {}
    for c in fabric.commits:
        planners.setdefault(c.view.epoch, set()).add(c.view.planner)
    assert all(len(p) == 1 for p in planners.values())
