"""ATP row/column-first layers: numerical equivalence vs dense reference,
forward + grads, with and without chunk-based overlapping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.core.atp import (atp_linear, core_gather, core_scatter,
                            make_context, plan_core_sharding)
from repro.core.mesh import MeshTopo

TOPO = MeshTopo((("data", 2), ("tp1", 2), ("tp2", 2)))


def _setup():
    mesh = TOPO.build()
    X = jax.random.normal(jax.random.PRNGKey(0), (8, 16), jnp.float32)
    A = jax.random.normal(jax.random.PRNGKey(1), (16, 32), jnp.float32) * 0.1
    bA = jax.random.normal(jax.random.PRNGKey(4), (32,), jnp.float32) * 0.1
    B = jax.random.normal(jax.random.PRNGKey(2), (32, 16), jnp.float32) * 0.1
    bB = jax.random.normal(jax.random.PRNGKey(5), (16,), jnp.float32) * 0.1
    return mesh, (A, bA, B, bB), X


def _ref_loss(params, x):
    A, bA, B, bB = params
    return jnp.sum((jax.nn.gelu(x @ A + bA) @ B + bB) ** 2)


def _local_loss(ctx, params, x):
    """Per-rank PARTIAL of the dense loss: z is replicated over tp1 (post-f4
    psum), so divide by d1 so the partials sum to the global loss over every
    mesh axis.  Differentiating the partial keeps grads exact under jax's
    per-rank cotangent convention (grad-through-psum is only exact under the
    0.6 vma system; 0.4.x transposes psum to psum)."""
    A, bA, B, bB = params
    y = jax.nn.gelu(atp_linear(ctx, x, A, bA, kind="col"))
    z = atp_linear(ctx, y, B, bB, kind="row")
    return jnp.sum(z ** 2) / ctx.d1


def _grad_psums(grads):
    """Conjugate reductions over each param's replicated mesh axes."""
    gA, gbA, gB, gbB = grads
    return (jax.lax.psum(gA, ("data",)),
            jax.lax.psum(gbA, ("data", "tp2")),
            jax.lax.psum(gB, ("data",)),
            jax.lax.psum(gbB, ("data", "tp1")))


@pytest.mark.parametrize("chunks", [1, 2, 4])
def test_mlp_forward_and_grads_match_dense(devices8, chunks):
    mesh, params, X = _setup()
    ctx = make_context(TOPO, chunks=chunks)

    def step(params, x):
        loss, grads = jax.value_and_grad(
            lambda p: _local_loss(ctx, p, x))(params)
        loss = jax.lax.psum(loss, ("data", "tp1", "tp2"))
        return loss, _grad_psums(grads)

    in_specs = ((P("tp2", "tp1"), P("tp1"), P("tp1", "tp2"), P("tp2")),
                P("data", "tp2"))
    f = shard_map(step, mesh=mesh,
                  in_specs=in_specs, out_specs=(P(), in_specs[0]),
                  check_vma=True)
    loss, grads = jax.jit(f)(params, X)
    rloss, rgrads = jax.value_and_grad(_ref_loss)(params, X)
    np.testing.assert_allclose(loss, rloss, rtol=1e-5)
    for g, rg in zip(grads, rgrads):
        np.testing.assert_allclose(np.asarray(g), np.asarray(rg),
                                   rtol=1e-4, atol=1e-5)


def test_eq2_collective_count(devices8):
    """The lowered HLO of one MLP block contains exactly the paper's two
    forward boundaries (f3 psum(ax2), f4 psum(ax1)) + their two backward
    conjugates: 4 all-reduces of activation tensors, plus the explicit
    DP/replication grad reductions (up to 4 more, partially fused by XLA)."""
    mesh, params, X = _setup()
    ctx = make_context(TOPO)

    def grads(params, x):
        return _grad_psums(jax.grad(lambda p: _local_loss(ctx, p, x))(params))

    in_specs = ((P("tp2", "tp1"), P("tp1"), P("tp1", "tp2"), P("tp2")),
                P("data", "tp2"))
    f = jax.jit(shard_map(grads, mesh=mesh, in_specs=in_specs,
                          out_specs=in_specs[0], check_vma=True))
    hlo = f.lower(params, X).compile().as_text()
    n_ar = hlo.count(" all-reduce(") + hlo.count(" all-reduce-start(")
    # f3 fwd, f4 fwd, f4 bwd, f3 bwd + explicit grad psums
    assert 4 <= n_ar <= 9, f"expected the Eq.2 schedule, got {n_ar} all-reduces"


def test_core_scatter_gather_roundtrip(devices8):
    mesh = TOPO.build()
    ctx = make_context(TOPO)
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 3, 4, 5))

    def f(x):
        cs = plan_core_sharding(ctx, heads_after_ax1=2, batch_local=4)
        y = core_scatter(ctx, x, cs, head_dim=2, batch_dim=0)
        rt = core_gather(ctx, y, cs, head_dim=2, batch_dim=0)
        err = jnp.max(jnp.abs(rt - x))
        return jax.lax.pmax(jax.lax.pmax(err, ("tp1", "tp2")), "data")

    g = shard_map(f, mesh=mesh, in_specs=P("data", None, "tp1"),
                  out_specs=P(), check_vma=False)
    assert float(jax.jit(g)(x)) < 1e-6


def test_batch_factor_roundtrip(devices8):
    mesh = TOPO.build()
    ctx = make_context(TOPO)
    x = jax.random.normal(jax.random.PRNGKey(8), (8, 3, 2, 5))

    def f(x):
        cs = plan_core_sharding(ctx, heads_after_ax1=1, batch_local=4)
        assert cs.b2 == 2 and cs.h2 == 1
        y = core_scatter(ctx, x, cs, head_dim=2, batch_dim=0)
        rt = core_gather(ctx, y, cs, head_dim=2, batch_dim=0)
        err = jnp.max(jnp.abs(rt - x))
        return jax.lax.pmax(jax.lax.pmax(err, ("tp1", "tp2")), "data")

    g = shard_map(f, mesh=mesh, in_specs=P("data", None, "tp1"),
                  out_specs=P(), check_vma=False)
    assert float(jax.jit(g)(x)) < 1e-6
