"""Plan-conformance static analysis: signature vs expectation vs vma lint.

Covers every segment kind, the two pinned mesh flips (their winning
plans must lint clean), the corrupted-plan diagnostics, and the
replication lint's ability to catch a lying out_spec.
"""
import dataclasses
import re

import jax
import jax.numpy as jnp
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.analysis.expect import (PlanConformanceError, check_conformance,
                                   expected_signature, lint_conformance)
from repro.analysis.lint import lint_build
from repro.analysis.replication import verify_replication
from repro.analysis.signature import extract
from repro.configs.base import segments
from repro.configs.registry import get_config
from repro.core import comm_matrix
from repro.core.plan import ParallelPlan, plan_search
from repro.core import atp
from repro.core.compat import shard_map

B, S = 4, 32

PLAN_2x2 = ParallelPlan(d1=2, d2=2, dp=2, chunks=2, boundary_mode="psum",
                        seq_parallel=True)
PLAN_RING = ParallelPlan(d1=4, d2=1, dp=2, boundary_mode="ring",
                         seq_parallel=True)


def _zamba_with_tail():
    """zamba2 with a trailing pure-mamba segment (num_layers % super != 0)
    so the sweep covers the standalone 'mamba' kind too."""
    cfg = get_config("zamba2-7b").reduced()
    return dataclasses.replace(cfg, num_layers=5)


#: (config, expected segment kinds) — all seven kinds between them
KIND_CASES = [
    ("llama3-8b", {"dense"}),
    ("dbrx-132b", {"moe"}),
    ("deepseek-v3-671b", {"mla_dense", "mla_moe"}),
    ("xlstm-1.3b", {"xlstm"}),
]


@pytest.mark.parametrize("name,kinds", KIND_CASES,
                         ids=[c[0] for c in KIND_CASES])
def test_segment_kind_conformance(devices8, name, kinds):
    cfg = get_config(name).reduced()
    assert {s.kind for s in segments(cfg)} == kinds
    for phase in ("train", "prefill", "decode"):
        errors, op_bytes = lint_build(cfg, PLAN_2x2, phase)
        assert not errors, f"{name} {phase}: {errors[:4]}"
        assert sum(op_bytes.values()) > 0


def test_zamba_and_mamba_kinds_conform(devices8):
    cfg = _zamba_with_tail()
    assert [s.kind for s in segments(cfg)] == ["zamba", "mamba"]
    for phase in ("train", "prefill", "decode"):
        errors, _ = lint_build(cfg, PLAN_2x2, phase)
        assert not errors, f"{phase}: {errors[:4]}"


def test_ring_plan_conformance_and_replication(devices8):
    """Ring boundaries: ppermute schedules forward AND backward, with
    every shard_map out_spec claim proven by the jaxpr walk (upstream's
    check_vma cannot certify these builds at all)."""
    cfg = get_config("llama3-8b").reduced()
    for phase in ("train", "prefill", "decode"):
        errors, _ = lint_build(cfg, PLAN_RING, phase)
        assert not errors, f"{phase}: {errors[:4]}"


# ---------------------------------------------------------------------------
# Pinned mesh flips: the searched winners must lint clean.
# ---------------------------------------------------------------------------


def test_ic1_int8_flip_plans_lint_clean(devices8):
    """The quant acceptance pin: int8 wire flips ic1 train (8,1)->(4,2).
    BOTH winning plans must conform once built."""
    cfg = get_config("llama3-8b")
    kw = dict(layers=cfg.num_layers, batch=4, seq=2048,
              profile=__import__("repro.core.cost_model",
                                 fromlist=["LayerCommProfile"])
              .LayerCommProfile.dense(cfg))
    full = plan_search("ic1", 8, **kw).best
    quant = plan_search("ic1", 8, wire_dtype="int8", **kw).best
    assert (full.d1, full.d2) == (8, 1)
    assert (quant.d1, quant.d2) == (4, 2)
    red = get_config("llama3-8b").reduced()
    for plan in (full, quant):
        errors, _ = lint_build(red, plan, "train")
        assert not errors, errors[:4]


def test_ic1_dbrx_decode_read_flip_lints_clean(devices8):
    """The serving pin: pricing the paged KV gather flips the dbrx decode
    mesh to (4,2) ring — the re-meshed decode build must conform to the
    decode view, quantified collectives and all."""
    from repro.core.cost_model import paged_read_model

    cfg = get_config("dbrx-132b")
    pr = paged_read_model(cfg, avg_len=4096, tp=8)
    plan = plan_search("ic1", 8, model=cfg, batch=4, seq=2048,
                       decode_batch=64, decode_paged_read=pr).best
    assert (plan.decode.d1, plan.decode.d2) == (4, 2)
    assert plan.decode.boundary_mode == "ring"
    # the default reduction keeps 4 experts — too few to dispatch over
    # the flipped flat tp=8 decode mesh, so widen the expert pool only
    red = get_config("dbrx-132b").reduced()
    red = dataclasses.replace(
        red, moe=dataclasses.replace(red.moe, num_experts=8))
    errors, _ = lint_build(red, plan, "decode")
    assert not errors, errors[:4]


# ---------------------------------------------------------------------------
# Diagnostics: corrupted plans fail with segment-specific messages.
# ---------------------------------------------------------------------------


def _corrupt_boundary(plan: ParallelPlan, mode: str) -> ParallelPlan:
    return dataclasses.replace(
        plan, boundary_mode=mode,
        segments=tuple(dataclasses.replace(s, boundary_mode=mode)
                       for s in plan.segments))


def test_corrupted_boundary_mode_fails_with_diagnostic(devices8):
    """A plan claiming ring boundaries over a psum-built step must name
    the offending segment and the missing ppermute schedule."""
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import batch_struct, build_train_step
    from repro.models import lm
    from repro.optim import adamw

    cfg = get_config("llama3-8b").reduced()
    fn, info = build_train_step(cfg, plan=PLAN_2x2)
    params = lm.abstract_params(cfg)
    pspecs = lm.param_specs(cfg, info.ctx)
    opt = adamw.init_opt_state(params, pspecs, info.ctx, abstract=True)
    batch = batch_struct(cfg, ShapeConfig("x", S, B, "train"), "train")
    sig = extract(fn, params, opt, batch)

    lying = _corrupt_boundary(PLAN_2x2, "ring")
    errors = check_conformance(sig, expected_signature(cfg, lying, "train",
                                                       B, S))
    assert errors
    assert any(re.search(r"seg0:dense fwd: expected \d+x ppermute", e)
               for e in errors), errors[:6]
    with pytest.raises(PlanConformanceError, match="seg0:dense"):
        lint_conformance(sig, cfg, lying, "train", B, S)
    # and the true plan still passes on the same signature
    assert lint_conformance(sig, cfg, PLAN_2x2, "train", B, S) == []


def test_wire_dtype_mismatch_diagnostic(devices8):
    """An int8-planned boundary emitting full-width payloads is a lint
    error with the quantization called out explicitly."""
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import batch_struct, build_prefill
    from repro.models import lm

    cfg = get_config("llama3-8b").reduced()
    bf16 = ParallelPlan(d1=2, d2=2, dp=2)
    fn, _ = build_prefill(cfg, plan=bf16)
    params = lm.abstract_params(cfg)
    batch = batch_struct(cfg, ShapeConfig("x", S, B, "prefill"), "prefill")
    sig = extract(fn, params, batch)

    int8 = ParallelPlan(d1=2, d2=2, dp=2, wire_dtype="int8")
    errors = check_conformance(sig, expected_signature(cfg, int8, "prefill",
                                                       B, S))
    assert any("quantized" in e for e in errors), errors[:6]


# ---------------------------------------------------------------------------
# Expectation engine consistency + replication lint unit coverage.
# ---------------------------------------------------------------------------


def test_seq_parallel_kinds_match_execution():
    from repro.analysis import expect

    assert expect.SEQ_PARALLEL_KINDS == atp.SEQ_PARALLEL_KINDS


def test_replication_lint_proves_psum_and_catches_lies(devices8):
    mesh = jax.sharding.Mesh(jax.devices()[:4], ("m",))

    def honest(x):
        return lax.psum(x, "m")

    def lying(x):
        # varies over 'm' but the out_spec P() claims replication
        return x * (1.0 + lax.axis_index("m"))

    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    ok_fn = jax.jit(shard_map(honest, mesh=mesh, in_specs=P("m"),
                              out_specs=P(), check_vma=False))
    assert verify_replication(ok_fn, x) == []

    bad_fn = jax.jit(shard_map(lying, mesh=mesh, in_specs=P(),
                               out_specs=P(), check_vma=False))
    errs = verify_replication(bad_fn, x, strict=False)
    assert errs and "claims replication over 'm'" in errs[0]
    with pytest.raises(AssertionError, match="replication lint failed"):
        verify_replication(bad_fn, x)


def test_replication_lint_understands_rings(devices8):
    """A completed ppermute ring IS an all-reduce: per-hop dataflow says
    'varying', the ring-scope algebra restores the axis."""
    from repro.core.overlap import ring_all_reduce

    mesh = jax.sharding.Mesh(jax.devices()[:4], ("m",))

    def ring(x):
        return ring_all_reduce(x, "m", 4)

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    fn = jax.jit(shard_map(ring, mesh=mesh, in_specs=P("m"),
                           out_specs=P(), check_vma=False))
    assert verify_replication(fn, x) == []
