"""ParallelPlan v2: heterogeneous per-segment overlap strategies.

Pins this PR's acceptance criteria:
  - plan-format migration: a checked-in PR-2-era v1 plan JSON loads by
    broadcasting its global knobs to every segment, and newer-than-
    supported versions still fail loudly;
  - v1/v2 parity: for a homogeneous dense network the per-segment search
    selects the identical strategy (same d1/d2/chunks/boundary_mode/
    seq_parallel, same predicted cost) as the v1 profile-based search;
  - per-segment knob threading: on a mixed dense+MoE stack the dense
    segment honors seq_parallel while the MoE segment masks it, in both
    the train and decode step builders, and different per-segment knobs
    actually reach execution (logit parity between mixed and replicated
    plans through the real prefill builder);
  - per-kind comm profiles derive from ModelConfig (MoE dispatch bytes,
    MLA compressed-KV dims, mamba recurrent-state volume);
  - measured alpha_s reaches the chunk-count choice;
  - replan_elastic keeps the calibration table and tags it stale.
"""
import dataclasses
import math
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, MoEConfig, segments
from repro.configs.registry import get_config
from repro.core import comm_matrix as cm
from repro.core.atp import (SEQ_PARALLEL_KINDS, SegmentPlan, make_context)
from repro.core.calibrate import CalibEntry, CalibrationTable, calibrate_mesh
from repro.core.cost_model import (LayerCommProfile, segment_workloads,
                                   t_comm_overlap)
from repro.core.mesh import atp_topo
from repro.core.plan import (PLAN_FORMAT_VERSION, ParallelPlan, plan_search,
                             replan_elastic)
from repro.core.search import search_strategy_overlap, search_strategy_segments

V1_FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                          "plan_v1_pr2.json")


def mixed_cfg() -> ModelConfig:
    """DBRX-style MoE stack with a DeepSeek-style dense prefix."""
    return ModelConfig(
        name="t-mixed", family="moe", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      first_dense_layers=1))


def mixed_plan(**dense_kw) -> ParallelPlan:
    return ParallelPlan(
        d1=2, d2=2, dp=2,
        segments=(SegmentPlan("dense", **dense_kw), SegmentPlan("moe")))


# ---------------------------------------------------------------------------
# Plan-format migration (v1 -> v2).
# ---------------------------------------------------------------------------


def test_v1_fixture_loads_and_broadcasts_global_knobs():
    plan = ParallelPlan.load(V1_FIXTURE)
    assert (plan.d1, plan.d2, plan.dp, plan.pods) == (2, 4, 3, 2)
    assert plan.segments == ()          # v1 files carry no per-segment entries
    # broadcast rule: every kind sees the file's global knobs
    for kind in ("dense", "moe", "mla_moe", "mamba"):
        seg = plan.segment_plan(kind)
        assert (seg.chunks, seg.boundary_mode, seg.seq_parallel) == \
            (4, "ring", True)
    # the calibration table came through intact (alpha_s absent -> None)
    assert plan.calibration.get(8, 1).b2 == math.inf
    assert plan.calibration.alpha(2, 4) is None
    # and the execution view applies the per-kind seq_parallel gate
    ctx = plan.context()
    assert ctx.for_segment("dense").seq_parallel is True
    assert ctx.for_segment("moe").seq_parallel is False
    assert ctx.for_segment("moe").chunks == 4


def test_v1_fixture_roundtrips_as_current():
    plan = ParallelPlan.load(V1_FIXTURE)
    d = plan.to_dict()
    assert d["format_version"] == PLAN_FORMAT_VERSION == 5
    assert d["segments"] == []
    assert d["decode"] is None       # v1 files carry no decode sub-plan
    assert ParallelPlan.from_dict(d) == plan


def test_newer_than_supported_version_fails_loudly():
    d = ParallelPlan.load(V1_FIXTURE).to_dict()
    d["format_version"] = PLAN_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="format_version"):
        ParallelPlan.from_dict(d)


def test_v2_segments_roundtrip_exact():
    plan = ParallelPlan(
        d1=2, d2=2, chunks=2, topology="ic3",
        segments=(SegmentPlan("dense", chunks=4, boundary_mode="ring",
                              seq_parallel=True),
                  SegmentPlan("moe", chunks=1)))
    q = ParallelPlan.from_json(plan.to_json())
    assert q == plan
    assert q.segment_plan("dense").seq_parallel is True
    assert q.segment_plan("moe").chunks == 1
    # an unknown kind falls back to the plan's global knobs
    assert q.segment_plan("mamba").chunks == plan.chunks


def test_segment_plan_validation():
    with pytest.raises(ValueError, match="chunks"):
        SegmentPlan("dense", chunks=0)
    with pytest.raises(ValueError, match="boundary_mode"):
        SegmentPlan("dense", boundary_mode="laser")
    with pytest.raises(ValueError, match="duplicate"):
        ParallelPlan(d1=2, d2=2, segments=(SegmentPlan("dense"),
                                           SegmentPlan("dense", chunks=2)))


# ---------------------------------------------------------------------------
# v1/v2 search parity (the pin) + per-kind profiles.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preset", ("ic1", "ic3", "ic4"))
def test_single_dense_segment_parity_with_v1_search(preset):
    cfg = get_config("llama3-8b")
    assert [s.kind for s in segments(cfg)] == ["dense"]
    v1 = plan_search(preset, cm.PRESETS[preset]().num_devices,
                     layers=cfg.num_layers, batch=4, seq=2048,
                     profile=LayerCommProfile.dense(cfg))
    v2 = plan_search(preset, cm.PRESETS[preset]().num_devices,
                     model=cfg, batch=4, seq=2048)
    a, b = v1.best, v2.best
    assert (a.d1, a.d2, a.chunks, a.boundary_mode, a.seq_parallel) == \
        (b.d1, b.d2, b.chunks, b.boundary_mode, b.seq_parallel)
    assert b.predicted.t_exposed == pytest.approx(a.predicted.t_exposed,
                                                  rel=1e-12)
    assert b.predicted.t_comm == pytest.approx(a.predicted.t_comm, rel=1e-12)
    # the v2 plan additionally names its one segment
    assert [s.kind for s in b.segments] == ["dense"]
    assert b.segments[0].chunks == a.chunks


def test_segmented_search_masks_seq_parallel_per_kind():
    cfg = mixed_cfg()
    res = search_strategy_segments(
        cm.PRESETS["ic3"](), 4, workloads=segment_workloads(cfg),
        batch=8, seq=256)
    for mesh in res.ranked:
        by_kind = {c.kind: c for c in mesh.segments}
        assert not by_kind["moe"].seq_parallel
    assert "moe" not in SEQ_PARALLEL_KINDS
    assert {"dense", "mla_dense"} <= SEQ_PARALLEL_KINDS


def test_per_kind_profiles_derive_from_config():
    cfg = mixed_cfg()
    moe_p = LayerCommProfile.for_segment("moe", cfg)
    assert moe_p.flat_dispatch_out == pytest.approx(
        2.0 * cfg.moe.top_k * cfg.moe.capacity_factor * cfg.d_model)
    assert moe_p.col_first_out == pytest.approx(cfg.q_dim + 2 * cfg.kv_dim)

    ds = get_config("deepseek-v3-671b")
    mla_p = LayerCommProfile.for_segment("mla_dense", ds)
    m = ds.mla
    assert mla_p.col_full_out == pytest.approx(
        m.q_lora_rank + m.kv_lora_rank + m.qk_rope_head_dim)
    assert LayerCommProfile.for_segment("mla_moe", ds).flat_dispatch_out > 0

    za = get_config("zamba2-7b")
    mam = LayerCommProfile.for_segment("mamba", za)
    d_inner = za.ssm.expand * za.d_model
    assert mam.col_full_out == pytest.approx(
        2 * d_inner + 2 * za.ssm.d_state + d_inner // za.ssm.head_dim)
    # full-width ax1 psums (zamba regather / xlstm recurrent h) are priced
    # on the ROW (ax1) pool, not lumped into the ax2 pool
    assert LayerCommProfile.for_segment("zamba", za).row_full_out == \
        pytest.approx(za.d_model)
    xl = get_config("xlstm-1.3b")
    assert LayerCommProfile.for_segment("xlstm", xl).row_full_out == \
        pytest.approx(xl.ssm.slstm_every * xl.d_model)
    # ...and a d2==1 mesh still pays for them (ax1 traffic exists there)
    zprof = LayerCommProfile.for_segment("zamba", za)
    c = t_comm_overlap(cm.PRESETS["ic3"](), 4, 1, layers=2, batch=4,
                       seq=256, profile=zprof)
    assert c.ax1_boundary_bytes > 0 and c.t_comm > 0

    with pytest.raises(ValueError, match="no comm profile"):
        LayerCommProfile.for_segment("laser", cfg)

    # segment_workloads covers every kind in the zoo without error
    from repro.configs.registry import ARCHS
    for name, acfg in ARCHS.items():
        ws = segment_workloads(acfg)
        assert sum(w.layers for w in ws) >= 1
        assert all(w.profile.hidden for w in ws)


def test_moe_dispatch_bytes_priced_into_cost():
    cfg = mixed_cfg()
    prof = LayerCommProfile.for_segment("moe", cfg)
    with_flat = t_comm_overlap(cm.PRESETS["ic3"](), 2, 4, layers=4, batch=8,
                               seq=256, profile=prof)
    without = t_comm_overlap(
        cm.PRESETS["ic3"](), 2, 4, layers=4, batch=8, seq=256,
        profile=dataclasses.replace(prof, flat_dispatch_out=0.0))
    assert with_flat.t_comm > without.t_comm
    assert with_flat.t_exposed > without.t_exposed
    assert with_flat.flat_dispatch_bytes > 0 == without.flat_dispatch_bytes


# ---------------------------------------------------------------------------
# Measured alpha_s (per-step latency) -> chunk-count choice.
# ---------------------------------------------------------------------------


def test_calibrate_mesh_measures_alpha(devices8):
    tab = calibrate_mesh(2, payload_kb=4, repeats=1)
    for _, e in tab.entries:
        assert e.alpha_s is not None and e.alpha_s >= 0.0
    assert CalibrationTable.from_dict(tab.to_dict()) == tab
    assert tab.alpha(2, 1) == tab.get(2, 1).alpha_s


def test_measured_alpha_steers_chunk_count():
    prof = LayerCommProfile.gpt(8192)
    m = cm.PRESETS["ic4"]()

    def best_chunks(alpha):
        tab = CalibrationTable(
            entries=tuple(((d1, d2), CalibEntry(b1=5.0, b2=5.0,
                                                alpha_s=alpha))
                          for d1, d2 in ((1, 16), (2, 8), (4, 4), (8, 2),
                                         (16, 1))),
            source="unit")
        return search_strategy_overlap(
            m, 16, layers=4, batch=4, seq=2048, profile=prof,
            calibration=tab).best.chunks

    # latency-free chunking always pays; a huge measured per-step latency
    # (each chunk re-pays alpha) must push the choice back to 1
    assert best_chunks(0.0) > 1
    assert best_chunks(10.0) == 1


# ---------------------------------------------------------------------------
# Per-segment knob threading: builders + execution.
# ---------------------------------------------------------------------------


def test_builders_thread_per_segment_knobs(devices8):
    from repro.launch.steps import build_decode_step, build_train_step

    cfg = mixed_cfg()
    plan = mixed_plan(chunks=2, seq_parallel=True)
    _, t_info = build_train_step(cfg, plan=plan)
    dense = t_info.ctx.for_segment("dense")
    moe = t_info.ctx.for_segment("moe")
    assert (dense.chunks, dense.seq_parallel) == (2, True)
    assert (moe.chunks, moe.seq_parallel) == (1, False)
    # decode masks seq_parallel in EVERY segment entry but keeps chunks
    _, d_info = build_decode_step(cfg, B=4, s_max=16, plan=plan)
    assert all(not s.seq_parallel for s in d_info.ctx.segment_plans)
    assert d_info.ctx.for_segment("dense").chunks == 2
    assert d_info.ctx.for_segment("dense").seq_parallel is False


def test_mixed_plan_prefill_logits_match_replicated(devices8):
    """Different per-segment knobs must reach execution without changing
    the math: greedy prefill tokens agree between the heterogeneous plan
    (dense segment seq-parallel + chunked) and the all-replicated one."""
    import numpy as np

    from repro.launch.steps import build_prefill
    from repro.models import lm

    cfg = mixed_cfg()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size)

    def run(plan):
        fn, info = build_prefill(cfg, plan=plan)
        p = jax.device_put(params, info.sharding(info.pspecs))
        batch = jax.device_put({"tokens": tokens},
                               info.sharding(info.bspecs))
        return np.asarray(fn(p, batch))

    base = run(mixed_plan())
    het = run(mixed_plan(chunks=2, seq_parallel=True))
    assert (base == het).all()


def test_mixed_plan_decode_runs_with_per_segment_chunks(devices8):
    from repro.launch.steps import build_decode_step
    from repro.models import lm

    cfg = mixed_cfg()
    plan = mixed_plan(chunks=2, seq_parallel=True)
    step, info = build_decode_step(cfg, B=4, s_max=16, plan=plan)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    params = jax.device_put(params, info.sharding(info.pspecs))
    caches, cache_specs = lm.init_decode_caches(cfg, info.ctx, 4, 16)
    caches = jax.device_put(caches, info.sharding(cache_specs))
    toks = jnp.zeros((4, 1), jnp.int32)
    out, caches = step(params, toks, jnp.int32(0), caches)
    assert out.shape == (4,)
    assert jnp.all((out >= 0) & (out < cfg.vocab_size))


def test_deepseek_style_mla_dense_prefix_trains_seq_parallel(devices8):
    """DeepSeek-shaped stack (mla_dense prefix + mla_moe + MTP head): the
    prefix runs sequence-parallel while the MoE segment masks it, through
    the real train builder."""
    from repro.data.pipeline import DataConfig, TokenSource
    from repro.launch.steps import build_train_step
    from repro.models import lm
    from repro.optim import adamw

    cfg = get_config("deepseek-v3-671b").reduced()
    kinds = [s.kind for s in segments(cfg)]
    assert kinds == ["mla_dense", "mla_moe"]
    plan = ParallelPlan(
        d1=2, d2=2, dp=2,
        segments=(SegmentPlan("mla_dense", seq_parallel=True),
                  SegmentPlan("mla_moe", chunks=2)))
    step, info = build_train_step(cfg, plan=plan)
    assert info.ctx.for_segment("mla_dense").seq_parallel is True
    assert info.ctx.for_segment("mla_moe").seq_parallel is False
    src = TokenSource(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                 global_batch=4))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw.init_opt_state(params, info.pspecs, info.ctx, "zero1")
    params = jax.device_put(params, info.sharding(info.pspecs))
    opt = jax.device_put(opt, info.sharding(info.ospecs))
    batch = jax.device_put(
        {k: jnp.asarray(v) for k, v in src.global_batch(0).items()},
        info.sharding(info.bspecs))
    _, _, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])


def test_embeds_entry_respects_masked_first_segment(devices8):
    """Regression: a global seq_parallel=True knob on a model whose first
    segment masks it (pure-MoE stack) must NOT seq-slice externally
    supplied embeds — the entry follows the first segment's masked view."""
    import numpy as np

    from repro.core.compat import shard_map
    from repro.core.mesh import MeshTopo
    from repro.models import lm

    cfg = mixed_cfg()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, first_dense_layers=0),
        num_layers=2)
    assert [s.kind for s in segments(cfg)] == ["moe"]
    topo = MeshTopo((("tp1", 2), ("tp2", 2)))
    ctx = make_context(topo, seq_parallel=True)   # v1-style global knob
    mesh = topo.build(jax.devices()[:4])
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    pspecs = lm.param_specs(cfg, ctx)
    b, s = 2, 8
    embeds = jax.random.normal(jax.random.PRNGKey(1),
                               (b, s, cfg.d_model), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def local(p, e):
        h, _, _, _ = lm.forward(ctx, cfg, p, None, positions, embeds=e)
        return h

    from jax.sharding import PartitionSpec as P

    fn = shard_map(local, mesh=mesh,
                   in_specs=(pspecs, P(None, None, "tp2")),
                   out_specs=P(None, None, "tp2"), check_vma=False)
    h = fn(params, embeds)
    # full sequence out (the bug sliced it to s/d1) and finite values
    assert h.shape == (b, s, cfg.d_model)
    assert np.isfinite(np.asarray(h)).all()


def test_for_segment_fallback_and_ring_summary():
    topo = atp_topo(1, 2, 2)
    ctx = make_context(topo, chunks=3, seq_parallel=True)
    # no segment entries: the view is the context itself (v1 behavior)
    assert ctx.for_segment("dense") == ctx
    assert ctx.for_segment("moe").seq_parallel is False
    assert not ctx.any_ring
    ctx2 = dataclasses.replace(ctx, segment_plans=(
        SegmentPlan("moe", boundary_mode="ring"),))
    assert ctx2.any_ring
    # an entry-less kind under segment plans falls back to global knobs
    assert ctx2.for_segment("dense").chunks == 3
    assert ctx2.for_segment("dense").segment_plans == ()


# ---------------------------------------------------------------------------
# Elastic re-plan: calibration kept but visibly stale.
# ---------------------------------------------------------------------------


def _calibrated_plan() -> ParallelPlan:
    tab = CalibrationTable.from_pairs(
        {(2, 4): (1.2, 4.95), (8, 1): (0.97, 0.97)}, source="unit")
    return ParallelPlan(d1=4, d2=2, dp=1, calibration=tab)


def test_replan_elastic_keeps_calibration_tagged_stale():
    plan = _calibrated_plan()             # 8 devices
    new = replan_elastic(plan, 4)         # tp halves -> table is stale
    assert new.tp == 4
    assert new.calibration == plan.calibration   # kept, not dropped
    assert new.calibration_stale
    assert "[calibration:stale]" in new.describe()
    # dp-only shrink does NOT stale the table
    same_tp = replan_elastic(ParallelPlan(d1=2, d2=2, dp=2,
                                          calibration=plan.calibration), 4)
    assert not same_tp.calibration_stale


def test_replan_elastic_researched_plan_keeps_stale_tag():
    cfg = get_config("llama3-8b")
    plan = plan_search("ic4", 16, model=cfg, batch=4, seq=2048,
                       calibration=CalibrationTable.from_pairs(
                           {(4, 4): (10.0, 10.0)}, source="unit")).best
    new = replan_elastic(plan, 8, model=cfg, batch=4, seq=2048)
    assert new.tp == 8
    assert new.calibration == plan.calibration
    assert new.calibration_stale
    assert any(k == "elastic" for k, _ in new.provenance)
    # the re-searched plan still carries per-segment knobs
    assert [s.kind for s in new.segments] == ["dense"]
