"""Shared fixtures.  Tests that need a multi-device mesh run in a subprocess
spawned with XLA_FLAGS (device count is locked at first jax init), EXCEPT
we set a modest 8-device count here for the whole test session — smoke
tests and benches are told to expect it.
"""
import os

# 8 virtual CPU devices for every test in the session (NOT 512 — the
# dry-run owns that configuration in its own process).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

try:  # hypothesis is optional in the container; fall back to the shim
    import hypothesis  # noqa: F401  # noqa: E402
except ImportError:
    import os.path as _osp  # noqa: E402
    import sys as _sys  # noqa: E402

    _sys.path.insert(0, _osp.dirname(__file__))
    import _hypothesis_shim  # noqa: E402

    _hypothesis_shim.install()


@pytest.fixture(scope="session")
def devices8():
    d = jax.devices()
    assert len(d) >= 8, "test session expects 8 virtual CPU devices"
    return d


def assert_trees_close(a, b, rtol=1e-4, atol=1e-5, what=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=rtol, atol=atol, err_msg=f"{what} leaf {i}")
