"""Minimal deterministic stand-in for `hypothesis` when it isn't installed.

The container for this repo does not ship hypothesis and installing deps is
off-limits; the property tests only use a tiny slice of its API
(`given`, `settings`, `strategies.integers/sampled_from/booleans`, `.map`).
This shim replays each property with a fixed-seed PRNG for
``settings(max_examples=...)`` iterations — strictly weaker than real
hypothesis (no shrinking, no database) but deterministic and dependency-free.

Installed into ``sys.modules["hypothesis"]`` by conftest only when the real
package is missing.
"""
from __future__ import annotations

import inspect
import random
import sys
import types


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def map(self, f):
        return _Strategy(lambda rnd: f(self._sample(rnd)))


def integers(min_value, max_value):
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rnd: rnd.choice(elements))


def booleans():
    return _Strategy(lambda rnd: rnd.random() < 0.5)


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        n = getattr(fn, "_shim_max_examples", 20)
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # hypothesis fills positional strategies from the rightmost params
        # and keyword strategies by name; the remaining (self, fixtures)
        # must stay visible to pytest.
        drop = {p.name for p in params[len(params) - len(arg_strats):]}
        drop |= set(kw_strats)
        kept = [p for p in params if p.name not in drop]
        arg_names = [p.name for p in params if p.name in drop
                     and p.name not in kw_strats]

        def wrapper(*args, **kwargs):
            rnd = random.Random(0)
            for _ in range(n):
                drawn = dict(zip(arg_names, (s._sample(rnd) for s in arg_strats)))
                drawn.update({k: s._sample(rnd) for k, s in kw_strats.items()})
                fn(*args, **kwargs, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper

    return deco


def install() -> None:
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.sampled_from = sampled_from
    strategies.booleans = booleans
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
