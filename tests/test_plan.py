"""ParallelPlan lifecycle: search -> calibrate -> serialize -> execute.

Pins the PR's acceptance criteria:
  - plan_search with overlap disabled reproduces the seed Eq. 2 ranking
    exactly on every IC1-IC6 preset;
  - a plan JSON round-trips exactly (calibration tables included) and a
    loaded plan yields a bitwise-identical ATPContext to the in-process
    one, through the train AND decode builders;
  - calibrated search prefers the measured-faster factorization (§5.3);
  - the retired use_reduce_scatter knob raises a loud TypeError;
  - build_train_step(plan=...) runs end-to-end on the 8-device host mesh.
"""
import dataclasses
import math
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core import comm_matrix as cm
from repro.core.atp import ATPContext, make_context
from repro.core.calibrate import CalibEntry, CalibrationTable, calibrate_mesh
from repro.core.cost_model import LayerCommProfile, t_comm, t_comm_overlap
from repro.core.mesh import MeshTopo, atp_topo, factorizations
from repro.core.plan import (ParallelPlan, PredictedCost, plan_search,
                             replan_elastic)
from repro.core.search import search_strategy

PROF = LayerCommProfile.gpt(8192)
IC_PRESETS = ("ic1", "ic2", "ic3", "ic4", "ic5", "ic6")


# ---------------------------------------------------------------------------
# Serialization.
# ---------------------------------------------------------------------------


def _full_plan() -> ParallelPlan:
    calib = CalibrationTable(
        entries=(((2, 4), CalibEntry(b1=1.2, b2=4.95, t_psum=2e-3,
                                     t_ring=1e-3)),
                 ((8, 1), CalibEntry(b1=0.97, b2=math.inf))),
        source="unit-test")
    return ParallelPlan(
        d1=2, d2=4, dp=3, pods=2, chunks=4, boundary_mode="ring",
        seq_parallel=True, topology="ic1", calibration=calib,
        predicted=PredictedCost(t_comm=1e-3, t_exposed=5e-4, t_gemm=2e-3),
        provenance=(("searcher", "unit"), ("note", "x")))


def test_plan_json_roundtrip_exact():
    p = _full_plan()
    assert ParallelPlan.from_json(p.to_json()) == p
    # calibration metadata survives, including inf encoding
    q = ParallelPlan.from_json(p.to_json())
    assert q.calibration.get(8, 1).b2 == math.inf
    assert q.calibration.boundary_mode(2, 4) == "ring"
    assert q.predicted.t_exposed == pytest.approx(5e-4)


def test_plan_roundtrip_keeps_duplicate_provenance_tags():
    """Two successive elastic resizes must both survive serialization."""
    p = ParallelPlan(d1=2, d2=2, provenance=(
        ("elastic", "replanned 16->8 devices"),
        ("elastic", "replanned 8->4 devices"),
        ("searcher", "plan_search")))
    q = ParallelPlan.from_json(p.to_json())
    assert q == p
    assert sum(1 for k, _ in q.provenance if k == "elastic") == 2


def test_plan_save_load(tmp_path):
    p = _full_plan()
    path = p.save(os.path.join(tmp_path, "plan.json"))
    assert ParallelPlan.load(path) == p


def test_plan_validation():
    with pytest.raises(ValueError):
        ParallelPlan(d1=0, d2=4)
    with pytest.raises(ValueError):
        ParallelPlan(d1=2, d2=2, chunks=0)
    with pytest.raises(ValueError):
        ParallelPlan(d1=2, d2=2, boundary_mode="laser")


def test_newer_format_version_rejected():
    d = _full_plan().to_dict()
    d["format_version"] = 999
    with pytest.raises(ValueError, match="format_version"):
        ParallelPlan.from_dict(d)


def test_calibration_table_roundtrip_and_pairs():
    t = CalibrationTable.from_pairs({(2, 4): (1.2, 4.95), (8, 1): (0.97, 0.97)})
    assert CalibrationTable.from_dict(t.to_dict()) == t
    assert t.as_pairs()[(2, 4)] == (1.2, 4.95)
    assert t.bandwidths(3, 3) is None


# ---------------------------------------------------------------------------
# Search parity + calibration semantics.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preset", IC_PRESETS)
def test_plan_search_seed_parity_when_overlap_disabled(preset):
    """Acceptance: degraded plan_search == seed Eq. 2 ranking, exactly."""
    matrix = cm.PRESETS[preset]()
    n = matrix.num_devices
    seed = search_strategy(matrix, n, layers=4, batch=4, seq=2048,
                           profile=PROF)
    res = plan_search(preset, n, layers=4, batch=4, seq=2048, profile=PROF,
                      chunks_options=(1,), seq_parallel_options=(False,),
                      algo="rabenseifner", alpha_s=0.0)
    assert [(p.d1, p.d2) for p in res.ranked] == \
        [(c.d1, c.d2) for c in seed.ranked]
    assert all(p.chunks == 1 and not p.seq_parallel for p in res.ranked)
    # and the modelled totals agree to fp round-off
    for p, c in zip(res.costs, seed.ranked):
        assert p.t_exposed == pytest.approx(c.t_comm, rel=1e-9)


def test_calibrated_search_prefers_measured_faster_mesh():
    """Paper §5.3: IC1's analytic model picks (8,1); the measured table
    flips the choice to the factorization that is actually faster."""
    uncal = plan_search("ic1", 8, layers=4, batch=4, seq=2048, profile=PROF,
                        chunks_options=(1,), seq_parallel_options=(False,),
                        algo="rabenseifner", alpha_s=0.0)
    calib = CalibrationTable.from_pairs(
        {(2, 4): (1.20, 4.95), (8, 1): (0.97, 0.97),
         (4, 2): (1.10, 2.5), (1, 8): (0.97, 0.97)}, source="paper")
    cal = plan_search("ic1", 8, layers=4, batch=4, seq=2048, profile=PROF,
                      chunks_options=(1,), seq_parallel_options=(False,),
                      algo="rabenseifner", alpha_s=0.0, calibration=calib)
    assert uncal.mesh() == (8, 1)
    assert cal.mesh() == (2, 4)
    assert cal.best.calibration == calib  # the winning plan carries it
    assert dict(cal.best.provenance)["calibrated"] == "yes"


def test_calibrated_overlap_cost_matches_seed_eq2_path():
    """t_comm_overlap(calibrated=) must price an all-reduce at payload/B —
    the identical convention as the seed t_comm(calibrated=)."""
    m = cm.ic1_pcie_8gpu()
    cal = (1.20, 4.95)
    seed = t_comm(m, 2, 4, layers=4, batch=4, seq=2048, profile=PROF,
                  calibrated=cal)
    ov = t_comm_overlap(m, 2, 4, layers=4, batch=4, seq=2048, profile=PROF,
                        chunks=1, algo="rabenseifner", alpha_s=0.0,
                        calibrated=cal)
    assert ov.t_comm == pytest.approx(seed.t_comm, rel=1e-9)


def test_search_strategy_accepts_calibration_table():
    tab = CalibrationTable.from_pairs({(2, 4): (1.20, 4.95),
                                       (8, 1): (0.97, 0.97)})
    r_tab = search_strategy(cm.ic1_pcie_8gpu(), 8, layers=4, batch=4,
                            seq=2048, profile=PROF, calibration=tab)
    r_dict = search_strategy(cm.ic1_pcie_8gpu(), 8, layers=4, batch=4,
                             seq=2048, profile=PROF,
                             calibration=tab.as_pairs())
    assert [(c.d1, c.d2) for c in r_tab.ranked] == \
        [(c.d1, c.d2) for c in r_dict.ranked]


def test_measured_boundary_mode_reaches_plan():
    measure = {
        (1, 4): CalibEntry(b1=math.inf, b2=50.0, t_psum=1e-3, t_ring=2e-3),
        (2, 2): CalibEntry(b1=40.0, b2=40.0, t_psum=2e-3, t_ring=1e-3),
        (4, 1): CalibEntry(b1=60.0, b2=math.inf, t_psum=1e-3, t_ring=2e-3),
    }
    tab = calibrate_mesh(4, measure=lambda d1, d2: measure[(d1, d2)])
    assert len(tab) == 3
    res = plan_search("ic3", 4, layers=4, batch=4, seq=2048, profile=PROF,
                      calibration=tab, chunks_options=(1,),
                      seq_parallel_options=(False,))
    by_mesh = {(p.d1, p.d2): p for p in res.ranked}
    assert by_mesh[(2, 2)].boundary_mode == "ring"   # ring measured faster
    assert by_mesh[(4, 1)].boundary_mode == "psum"


def test_calibrate_mesh_on_host_devices(devices8):
    """Real micro-benchmark plumbing: tiny payload, tp=2 (cheap)."""
    tab = calibrate_mesh(2, payload_kb=4, repeats=1)
    assert {k for k, _ in tab.entries} == {(1, 2), (2, 1)}
    e = tab.get(2, 1)
    assert e.b1 > 0 and math.isinf(e.b2)
    assert e.boundary_mode in ("psum", "ring")
    assert CalibrationTable.from_dict(tab.to_dict()) == tab


# ---------------------------------------------------------------------------
# Plan -> context -> builders.
# ---------------------------------------------------------------------------


def test_context_from_plan_bitwise_identical_after_json(tmp_path):
    plan = plan_search("ic4", 4, layers=2, batch=4, seq=128, profile=PROF,
                       dp=2).best
    path = plan.save(os.path.join(tmp_path, "p.json"))
    loaded = ParallelPlan.load(path)
    assert loaded.context() == plan.context()
    assert dataclasses.asdict(loaded.context()) == \
        dataclasses.asdict(plan.context())


def test_make_context_plan_topo_mismatch_raises():
    plan = ParallelPlan(d1=2, d2=2)
    with pytest.raises(ValueError, match="plan/topology mismatch"):
        make_context(atp_topo(1, 4, 1), plan=plan)


def test_make_context_requires_topo_or_plan():
    with pytest.raises(TypeError):
        make_context()


def test_use_reduce_scatter_is_retired():
    topo = MeshTopo((("tp1", 2),))
    with pytest.raises(TypeError, match="seq_parallel"):
        make_context(topo, use_reduce_scatter=True)
    with pytest.raises(TypeError, match="seq_parallel"):
        ATPContext(topo=topo, ax1="tp1", ax2=None, dp_axes=(),
                   use_reduce_scatter=False)
    # the sentinel default stays invisible and replace() keeps working
    ctx = make_context(topo, chunks=2)
    assert "use_reduce_scatter" not in repr(ctx)
    assert dataclasses.replace(ctx, chunks=3).chunks == 3
    # seed-era POSITIONAL use_reduce_scatter now lands in the
    # boundary_mode slot — must fail loudly, not silently no-op
    with pytest.raises(TypeError, match="seq_parallel"):
        make_context(topo, 2, True)
    with pytest.raises(ValueError, match="boundary_mode"):
        make_context(topo, boundary_mode="laser")


def test_builders_thread_plan_knobs(devices8):
    """Decode/prefill builders must not drop plan knobs (the seed bug)."""
    from repro.configs.base import ModelConfig
    from repro.launch.steps import (build_decode_step, build_prefill,
                                    build_train_step)

    cfg = ModelConfig(name="t-plan", family="dense", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=64, head_dim=16, dtype="float32")
    plan = ParallelPlan(d1=2, d2=2, dp=2, chunks=4, seq_parallel=True)
    _, t_info = build_train_step(cfg, plan=plan)
    assert (t_info.ctx.chunks, t_info.ctx.seq_parallel) == (4, True)
    _, p_info = build_prefill(cfg, plan=plan)
    assert p_info.ctx.chunks == 4
    _, d_info = build_decode_step(cfg, B=4, s_max=8, plan=plan)
    assert d_info.ctx.chunks == 4
    # decode deliberately masks seq_parallel (undefined for cached decode)
    assert d_info.ctx.seq_parallel is False
    # train and decode contexts agree on everything decode supports
    assert dataclasses.replace(t_info.ctx, seq_parallel=False) == d_info.ctx


def test_train_step_from_plan_runs(devices8):
    """End-to-end: searched plan -> builder -> one real optimizer step."""
    from repro.configs.base import ModelConfig
    from repro.data.pipeline import DataConfig, TokenSource
    from repro.launch.steps import build_train_step
    from repro.models import lm
    from repro.optim import adamw

    cfg = ModelConfig(name="t-e2e", family="dense", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=64, head_dim=16, dtype="float32")
    plan = plan_search("ic3", 4, layers=cfg.num_layers, batch=4, seq=16,
                       profile=LayerCommProfile.gpt(cfg.d_model), dp=2,
                       chunks_options=(1, 2),
                       seq_parallel_options=(False,)).best
    step, info = build_train_step(cfg, plan=plan)
    src = TokenSource(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                 global_batch=4))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw.init_opt_state(params, info.pspecs, info.ctx, "zero1")
    params = jax.device_put(params, info.sharding(info.pspecs))
    opt = jax.device_put(opt, info.sharding(info.ospecs))
    batch = jax.device_put(
        {k: jnp.asarray(v) for k, v in src.global_batch(0).items()},
        info.sharding(info.bspecs))
    _, _, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])


# ---------------------------------------------------------------------------
# Elastic re-plan.
# ---------------------------------------------------------------------------


def test_replan_elastic_shrinks_dp_first():
    plan = ParallelPlan(d1=2, d2=2, dp=4)  # 16 devices
    new = replan_elastic(plan, 8)
    assert (new.d1, new.d2, new.dp) == (2, 2, 2)
    assert any(k == "elastic" for k, _ in new.provenance)


def test_replan_elastic_never_grows_the_job():
    """More surviving devices than the plan used must not inflate dp."""
    plan = ParallelPlan(d1=2, d2=1, dp=1)  # 2 devices
    new = replan_elastic(plan, 8)
    assert (new.d1, new.d2, new.dp) == (2, 1, 1)


def test_replan_elastic_halves_tp_when_needed():
    plan = ParallelPlan(d1=4, d2=2, dp=1)  # 8 devices
    new = replan_elastic(plan, 4)
    assert new.tp == 4 and new.devices <= 4
    assert new.calibration is None  # stale table dropped with the resize


def test_replan_elastic_researches_with_workload():
    plan = plan_search("ic4", 16, layers=4, batch=4, seq=2048,
                       profile=PROF).best
    new = replan_elastic(plan, 8, layers=4, batch=4, seq=2048, profile=PROF)
    assert new.tp == 8
    assert dict(new.provenance)["searcher"] == "plan_search"
    # the surviving-tp search is a real ranking over ic4's factorizations
    assert (new.d1, new.d2) in factorizations(8)


def test_trainer_replan_hook_called_on_failure():
    from repro.runtime.trainer import Trainer, TrainerConfig
    from repro.data.pipeline import DataConfig, TokenSource

    calls = []

    def step_ok(params, opt, batch):
        return params, opt, {"loss": jnp.float32(1.0)}

    def step_fail(params, opt, batch):
        raise RuntimeError("injected device loss")

    live = {"step": step_fail}

    def replan():
        calls.append(1)
        live["step"] = step_ok
        return step_ok

    src = TokenSource(DataConfig(vocab_size=16, seq_len=4, global_batch=2))
    tr = Trainer(
        TrainerConfig(total_steps=2, ckpt_dir="/tmp/repro_test_replan",
                      ckpt_every=100, max_failures=2),
        build_step=lambda: live["step"], source=src,
        init_state=lambda: ({}, {}), put_batch=lambda b: b,
        replan=replan)
    import shutil
    shutil.rmtree("/tmp/repro_test_replan", ignore_errors=True)
    tr.run()
    assert calls == [1]
    assert tr.replans == [0]
