"""Serving fast path: page allocator, continuous-batching scheduler,
latency-aware decode search, DecodePlan schema (format_version 3), and
the chunked-overlap calibration feed."""
import json

import numpy as np
import pytest

from repro.core import comm_matrix
from repro.core.atp import DecodePlan, SegmentPlan
from repro.core.calibrate import CalibEntry, CalibrationTable
from repro.core.cost_model import (LayerCommProfile, SegmentWorkload,
                                   t_comm_decode)
from repro.core.plan import PLAN_FORMAT_VERSION, ParallelPlan, plan_search
from repro.core.search import (search_strategy_decode,
                               search_strategy_overlap,
                               search_strategy_segments)
from repro.models.paging import GARBAGE_PAGE, PageAllocator, PagedConfig
from repro.runtime.server import Request, Server, ServerConfig

GPT = LayerCommProfile.gpt(4096)
WORKLOADS = (SegmentWorkload("dense", 24, GPT),)


# ---------------------------------------------------------------------------
# Page allocator (host-side bookkeeping).
# ---------------------------------------------------------------------------


def test_allocator_ensure_release_cycle():
    cfg = PagedConfig(page_size=4, num_pages=9, pages_per_slot=4)
    a = PageAllocator(cfg, slots=2)
    assert a.free_pages == 8            # page 0 is reserved
    assert a.ensure(0, 9)               # 3 pages
    assert len(a.slot_pages(0)) == 3
    assert a.ensure(0, 9)               # idempotent
    assert len(a.slot_pages(0)) == 3
    assert a.ensure(1, 16)              # 4 pages
    assert a.free_pages == 1
    assert a.ensure(0, 13)              # 3 -> 4 pages: takes the last one
    assert a.free_pages == 0
    a.release(0)
    assert a.free_pages == 4
    t = a.table()
    assert (t[0] == GARBAGE_PAGE).all()
    assert (t[1] != GARBAGE_PAGE).all()


def test_allocator_table_width_guard():
    cfg = PagedConfig(page_size=4, num_pages=32, pages_per_slot=2)
    a = PageAllocator(cfg, slots=1)
    with pytest.raises(ValueError, match="pages_per_slot"):
        a.ensure(0, 9)


def test_paged_config_geometry():
    cfg = PagedConfig(page_size=8, num_pages=16, pages_per_slot=4)
    assert cfg.max_seq == 32
    assert cfg.capacity_tokens == 120
    assert cfg.pages_for(1) == 1 and cfg.pages_for(8) == 1
    assert cfg.pages_for(9) == 2
    with pytest.raises(ValueError):
        PagedConfig(page_size=0)


# ---------------------------------------------------------------------------
# Continuous-batching scheduler (fake compiled step: no jax needed).
# ---------------------------------------------------------------------------


class _FakeStep:
    """Greedy model stub: next token = (last input token + 1) % 1000.
    Records every call so tests can assert the schedule."""

    def __init__(self):
        self.calls = []

    def __call__(self, tokens, start, table, caches):
        self.calls.append((tokens.shape, tuple(int(s) for s in start)))
        return (tokens + 1) % 1000, caches


def _server(slots=2, chunk=4, pages=64, page=4, per_slot=8, **kw):
    scfg = ServerConfig(
        batch_slots=slots, prefill_chunk=chunk,
        paged=PagedConfig(page_size=page, num_pages=pages,
                          pages_per_slot=per_slot), **kw)
    fake = _FakeStep()
    return Server(scfg, fake, lambda: None), fake


def test_scheduler_chunked_admission_and_completion():
    server, fake = _server()
    server.submit(Request(rid=0, prompt=np.arange(10, dtype=np.int32),
                          max_new=3))
    ticks = server.run_until_drained()
    assert ticks > 0 and len(server.completed) == 1
    out = server.completed[0].out
    # stub: first token = last prompt token (9) + 1; decode feeds back
    assert out == [10, 11, 12]
    # 10-token prompt at chunk 4 = 3 prefill chunks (b=1) + 2 decode ticks
    prefills = [c for c in fake.calls if c[0] == (1, 4)]
    decodes = [c for c in fake.calls if c[0] == (2, 1)]
    assert len(prefills) == 3 and len(decodes) == 2
    # chunk starts are chunk-rounded natural positions, not slot budgets
    assert [c[1][0] for c in prefills] == [0, 4, 8]
    # pages: chunk-rounded 10 -> 12 tokens -> 3 pages, all released
    assert server.alloc.free_pages == 63


def test_scheduler_interleaves_prefill_with_decode():
    """A long admission must not stall a live decode stream: at most
    prefill_chunks_per_tick chunks run between decode ticks."""
    server, fake = _server(slots=2, chunk=4)
    server.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                          max_new=4))
    server.step()   # r0 prefills (1 chunk) and starts decoding
    server.submit(Request(rid=1, prompt=np.arange(16, dtype=np.int32),
                          max_new=2))
    server.run_until_drained()
    assert [r.rid for r in server.completed] == [0, 1]
    # liveness: while request 0 is decoding, every one of request 1's
    # prefill chunks is followed by a decode tick before the next chunk
    # (prefill_chunks_per_tick=1).  r0 contributes 3 decode ticks (max_new
    # 4, first token from prefill); back-to-back chunks may only appear
    # after those are done.
    kinds = "".join("P" if c[0] == (1, 4) else "D" for c in fake.calls)
    first_pp = kinds.find("PP")
    assert first_pp == -1 or kinds[:first_pp + 1].count("D") >= 3, kinds


def test_scheduler_backpressure_defers_admission():
    """With a pool that only fits one request, the second waits but the
    server still drains (no deadlock, no corruption)."""
    server, _ = _server(slots=2, chunk=4, pages=3, page=4)  # 2 usable pages
    server.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                          max_new=2))
    server.submit(Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                          max_new=2))
    server.run_until_drained()
    assert sorted(r.rid for r in server.completed) == [0, 1]
    assert server.alloc.free_pages == 2


def test_scheduler_rejects_oversized_request():
    server, _ = _server(per_slot=2, page=4)   # ceiling: 8 positions
    with pytest.raises(ValueError, match="ceiling"):
        server.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                              max_new=4))


def test_scheduler_rejects_chunk_rounded_overflow():
    """Admission writes whole chunks: a prompt whose CHUNK-ROUNDED length
    exceeds the table ceiling must be rejected at submit, not crash the
    scheduler mid-tick."""
    server, _ = _server(chunk=8, page=4, per_slot=3)   # ceiling: 12
    with pytest.raises(ValueError, match="ceiling"):
        server.submit(Request(rid=0, prompt=np.arange(9, dtype=np.int32),
                              max_new=2))              # rounds to 16 > 12


def test_scheduler_max_new_one_completes_at_prefill():
    """max_new=1 finishes at the prefill pick: exactly one token, no
    decode tick, and a ceiling-length prompt stays in bounds."""
    server, fake = _server(chunk=4, page=4, per_slot=3)  # ceiling: 12
    server.submit(Request(rid=0, prompt=np.arange(12, dtype=np.int32),
                          max_new=1))
    server.run_until_drained()
    assert [r.out for r in server.completed] == [[12]]
    assert all(c[0] == (1, 4) for c in fake.calls)   # prefill chunks only
    assert server.alloc.free_pages == 63


def test_scheduler_mixed_lengths_independent_positions():
    server, fake = _server(slots=3, chunk=4)
    for rid, n in enumerate((3, 9, 5)):
        server.submit(Request(rid=rid, prompt=np.arange(n, dtype=np.int32),
                              max_new=3))
    server.run_until_drained()
    outs = {r.rid: r.out for r in server.completed}
    assert outs[0] == [3, 4, 5]      # last prompt token 2 -> 3...
    assert outs[1] == [9, 10, 11]
    assert outs[2] == [5, 6, 7]
    # decode ticks carried per-slot starts (not one lockstep position)
    starts = {c[1] for c in fake.calls if c[0] == (3, 1)}
    assert any(len(set(s)) > 1 for s in starts), starts


# ---------------------------------------------------------------------------
# Latency-aware decode cost model + search.
# ---------------------------------------------------------------------------


def test_decode_cost_degenerate_dims_drop_collectives():
    m = comm_matrix.ic4_ib_cluster_16gpu()
    row_only = t_comm_decode(m, 16, 1, workloads=WORKLOADS, batch=8)
    col_only = t_comm_decode(m, 1, 16, workloads=WORKLOADS, batch=8)
    both = t_comm_decode(m, 4, 4, workloads=WORKLOADS, batch=8)
    assert row_only.collectives == col_only.collectives == 24
    assert both.collectives == 48    # two boundary families per layer
    # fewer launches: a degenerate factorization halves fixed overheads
    assert row_only.t_launch == pytest.approx(both.t_launch / 2)
    # GPT row volume (2h) < col volume (7h): (16,1) beats (1,16) on bytes
    assert row_only.t_bytes < col_only.t_bytes


def test_decode_prefers_psum_over_ring_steps():
    """O(log d) monolithic psum beats the O(d) ring under the latency
    model — the opposite pressure from training's bandwidth ranking."""
    m = comm_matrix.ic4_ib_cluster_16gpu()
    c = t_comm_decode(m, 16, 1, workloads=WORKLOADS, batch=8)
    assert c.boundary_mode == "psum"
    ring = t_comm_decode(m, 16, 1, workloads=WORKLOADS, batch=8,
                         boundary_mode="ring")
    assert c.t_step < ring.t_step


def test_decode_objective_differs_from_train_on_ic4():
    """The acceptance pin: flat IB at tp=16 — training balances payload
    across (8,2); decode folds everything into one boundary (16,1)."""
    m = comm_matrix.ic4_ib_cluster_16gpu()
    dec = search_strategy_decode(m, 16, workloads=WORKLOADS, batch=8)
    tr = search_strategy_segments(m, 16, workloads=WORKLOADS,
                                  batch=256, seq=4096)
    assert tr.mesh() == (8, 2)
    assert dec.mesh() == (16, 1)
    assert dec.mesh() != tr.mesh()


def test_decode_ranking_sorted_and_alpha_dominated():
    m = comm_matrix.ic1_pcie_8gpu()
    dec = search_strategy_decode(m, 8, workloads=WORKLOADS, batch=8)
    ts = [c.t_step for c in dec.ranked]
    assert ts == sorted(ts)
    # decode is latency-bound: launch+alpha outweigh the byte term for
    # every factorization (training is the mirror image at seq=4096)
    assert all(c.t_launch + c.t_alpha > c.t_bytes for c in dec.ranked)


def test_decode_search_uses_calibrated_alpha():
    """A huge measured per-step latency on one factorization must demote
    it below the analytic ranking."""
    m = comm_matrix.ic4_ib_cluster_16gpu()
    base = search_strategy_decode(m, 16, workloads=WORKLOADS, batch=8)
    assert base.mesh() == (16, 1)
    slow = CalibrationTable(entries=(
        ((16, 1), CalibEntry(b1=25.0, b2=float("inf"), alpha_s=1.0)),))
    steered = search_strategy_decode(m, 16, workloads=WORKLOADS, batch=8,
                                     calibration=slow)
    assert steered.mesh() != (16, 1)


def test_axis_alpha_factors_span_slowest_layer():
    m = comm_matrix.ic1_pcie_8gpu()   # socket 8x / switch 3x / gpu 2x
    a1, a2 = m.axis_alpha_factors(1, 2)
    assert (a1, a2) == (1.0, 2.0)     # innermost only
    a1, a2 = m.axis_alpha_factors(2, 4)
    assert (a1, a2) == (8.0, 3.0)     # d1 spans the socket layer
    a1, a2 = m.axis_alpha_factors(8, 1)
    assert (a1, a2) == (8.0, 1.0)


# ---------------------------------------------------------------------------
# DecodePlan schema (format_version 3) + migration discipline.
# ---------------------------------------------------------------------------


def test_decode_plan_validation():
    with pytest.raises(ValueError, match="chunks=1"):
        DecodePlan(d1=2, d2=2, chunks=4)
    with pytest.raises(ValueError, match="boundary_mode"):
        DecodePlan(d1=2, d2=2, boundary_mode="nope")
    with pytest.raises(ValueError, match=">= 1"):
        DecodePlan(d1=0, d2=2)


def test_plan_search_attaches_decode_subplan():
    res = plan_search("ic4", 16, layers=24, batch=256, seq=4096,
                      profile=GPT, decode_batch=8)
    assert all(p.decode is not None for p in res.ranked)
    best = res.best
    assert (best.decode.d1, best.decode.d2) == (16, 1)
    assert (best.d1, best.d2) == (8, 2)
    assert best.decode.predicted_t_step > 0
    assert any(k == "decode" for k, _ in best.provenance)
    # decode sub-plan survives the JSON round trip exactly
    q = ParallelPlan.from_json(best.to_json())
    assert q == best and q.decode == best.decode


def test_plan_search_without_decode_batch_has_no_subplan():
    res = plan_search("ic4", 16, layers=24, batch=256, seq=4096, profile=GPT)
    assert all(p.decode is None for p in res.ranked)
    assert res.best.decode_view() is res.best


def test_v2_fixture_still_loads(tmp_path):
    """PR-3-era format_version 2 files load under v3: segments intact,
    decode sub-plan absent (pre-v3 behavior: serve with train knobs)."""
    plan = ParallelPlan.load("tests/data/plan_v2_pr3.json")
    assert plan.decode is None
    assert [s.kind for s in plan.segments] == ["dense", "moe"]
    assert plan.segment_plan("dense").seq_parallel is True
    assert plan.calibration.alpha(2, 2) == 2e-06
    # round-trips at the CURRENT version with decode recorded as null
    d = plan.to_dict()
    assert d["format_version"] == PLAN_FORMAT_VERSION == 5
    assert d["decode"] is None
    assert ParallelPlan.from_dict(d) == plan


def test_v4_fixture_still_loads():
    """PR-6-era format_version 4 files (decode sub-plan, no spec/prefix
    knobs) load under v5 with both new DecodePlan fields defaulting off."""
    plan = ParallelPlan.load("tests/data/plan_v4_pr6.json")
    assert plan.decode is not None
    assert plan.decode.speculate is False
    assert plan.decode.prefix_cache is False
    d = plan.to_dict()
    assert d["format_version"] == PLAN_FORMAT_VERSION
    assert d["decode"]["speculate"] is False
    assert ParallelPlan.from_dict(d) == plan


def test_newer_format_version_fails_loudly():
    d = ParallelPlan(d1=2, d2=2).to_dict()
    d["format_version"] = PLAN_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="format_version"):
        ParallelPlan.from_dict(d)


def test_decode_view_collapses_knobs():
    plan = ParallelPlan(
        d1=2, d2=4, dp=2, chunks=4, boundary_mode="ring", seq_parallel=True,
        segments=(SegmentPlan("dense", chunks=4, boundary_mode="ring",
                              seq_parallel=True),
                  SegmentPlan("moe", chunks=2, boundary_mode="ring")),
        decode=DecodePlan(d1=8, d2=1, boundary_mode="psum"))
    v = plan.decode_view()
    assert (v.d1, v.d2, v.dp) == (8, 1, 2)
    assert v.tp == plan.tp            # same device budget, re-factored
    assert (v.chunks, v.boundary_mode, v.seq_parallel) == (1, "psum", False)
    assert all((s.chunks, s.boundary_mode, s.seq_parallel)
               == (1, "psum", False) for s in v.segments)
    assert [s.kind for s in v.segments] == ["dense", "moe"]
    assert v.decode == plan.decode    # kept for audit
    assert any(k == "decode_view" for k, _ in v.provenance)


# ---------------------------------------------------------------------------
# Chunked-overlap calibration feed (satellite: ROADMAP open item).
# ---------------------------------------------------------------------------


def _all_factorizations_table(entry):
    return CalibrationTable(entries=tuple(
        ((d1, d2), entry) for d1, d2 in
        ((1, 16), (2, 8), (4, 4), (8, 2), (16, 1))))


def test_slow_measured_chunk_path_steers_search_to_one():
    m = comm_matrix.ic4_ib_cluster_16gpu()
    kw = dict(layers=24, batch=64, seq=2048, profile=GPT, peak_tflops=5.0,
              algo="ring", alpha_s=2e-6, chunks_options=(1, 2, 4),
              seq_parallel_options=(False,))
    base = search_strategy_overlap(m, 16, **kw)
    assert base.best.chunks > 1       # the analytic model loves chunking
    slow = _all_factorizations_table(CalibEntry(
        b1=25.0, b2=25.0, chunk_eff=((2, 0.05, 0.05), (4, 0.05, 0.05))))
    steered = search_strategy_overlap(m, 16, calibration=slow, **kw)
    assert steered.best.chunks == 1
    # a free measured chunk path (eff=1.0) leaves the choice alone
    free = _all_factorizations_table(CalibEntry(
        b1=25.0, b2=25.0, chunk_eff=((2, 1.0, 1.0), (4, 1.0, 1.0))))
    kept = search_strategy_overlap(m, 16, calibration=free, **kw)
    assert kept.best.chunks == base.best.chunks


def test_chunk_eff_json_round_trip():
    e = CalibEntry(b1=3.0, b2=7.0, alpha_s=1e-6,
                   chunk_eff=((2, 0.9, 0.8), (4, 0.7, 0.6)))
    t = CalibrationTable(entries=(((2, 2), e),))
    s = json.dumps(t.to_dict())
    back = CalibrationTable.from_dict(json.loads(s))
    assert back == t
    assert back.chunk_efficiency(2, 2) == {2: (0.9, 0.8), 4: (0.7, 0.6)}
    assert back.chunk_efficiency(4, 1) is None


def test_measured_chunk_eff_reaches_table():
    """calibrate_mesh's injectable measure path carries chunk_eff through
    merge + JSON exactly like the bandwidth fields."""
    from repro.core.calibrate import calibrate_mesh

    def fake_measure(d1, d2):
        return CalibEntry(b1=float(d1), b2=float(d2),
                          chunk_eff=((2, 0.5, 0.5), (4, 0.25, 0.25)))

    t = calibrate_mesh(4, measure=fake_measure)
    assert t.chunk_efficiency(2, 2) == {2: (0.5, 0.5), 4: (0.25, 0.25)}
    merged = t.merged(CalibrationTable(entries=(
        ((2, 2), CalibEntry(b1=9.0, b2=9.0)),)))
    assert merged.chunk_efficiency(2, 2) is None   # fresher entry wins
    assert merged.chunk_efficiency(4, 1) == {2: (0.5, 0.5), 4: (0.25, 0.25)}


# ---------------------------------------------------------------------------
# Paged-read + speculation terms in the decode cost model (PR 8).
# ---------------------------------------------------------------------------


def test_paged_read_flips_decode_mesh_on_ic1():
    """Pricing the per-tick paged KV gather changes the chosen decode mesh.

    On the PCIe box the latency-only objective picks the pure column mesh
    (8,1) under monolithic psum; with each of 64 slots gathering a
    4k-token paged history per tick, the ring's streamed transfers hide
    the gather in bandwidth slack (exposed = max(0, t_read - t_bytes))
    while psum's bursty log-steps expose it fully — and (4,2) ring wins.
    """
    from repro.configs.registry import get_config
    from repro.core.cost_model import paged_read_model, segment_workloads

    cfg = get_config("dbrx-132b")
    w = segment_workloads(cfg)
    m = comm_matrix.PRESETS["ic1"]()
    base = search_strategy_decode(m, 8, workloads=w, batch=64)
    assert (base.best.d1, base.best.d2, base.best.boundary_mode) == \
        (8, 1, "psum")
    pr = paged_read_model(cfg, avg_len=4096, tp=8)
    priced = search_strategy_decode(m, 8, workloads=w, batch=64,
                                    paged_read=pr)
    assert (priced.best.d1, priced.best.d2, priced.best.boundary_mode) == \
        (4, 2, "ring")
    assert priced.best.t_read > 0.0
    # the knob off is byte-identical to the seed ranking
    again = search_strategy_decode(m, 8, workloads=w, batch=64)
    assert again.ranked == base.ranked


def test_paged_read_model_kinds():
    """Attention kinds pay 2*kv_dim/tp per token, MLA pays the replicated
    latent, recurrent kinds pay nothing (O(1) state, nothing to page)."""
    from repro.configs.registry import get_config
    from repro.core.cost_model import paged_read_model

    qcfg = get_config("qwen1.5-0.5b")
    attn = paged_read_model(qcfg, avg_len=100, tp=2)
    assert attn.layers > 0
    assert attn.kv_bytes_per_token == pytest.approx(2.0 * qcfg.kv_dim)
    mla = paged_read_model(get_config("deepseek-v3-671b"), avg_len=100,
                           tp=2)
    m = get_config("deepseek-v3-671b").mla
    assert mla.kv_bytes_per_token == pytest.approx(
        2.0 * (m.kv_lora_rank + m.qk_rope_head_dim))   # replicated, not /tp
    rec = paged_read_model(get_config("xlstm-1.3b"), avg_len=100, tp=2)
    assert rec.layers == 0 and rec.t_read(8) == 0.0


def test_speculation_wins_only_when_acceptance_pays():
    """The MTP self-speculative tick costs 2x payloads + one extra head
    block but amortizes over 1 + accept_rate tokens: at zero acceptance
    the plain tick wins (speculation is pure overhead), at 0.8 the
    speculative candidate takes the ranking and t_step drops."""
    from repro.configs.registry import get_config
    from repro.core.cost_model import segment_workloads

    cfg = get_config("qwen1.5-0.5b").reduced()
    w = segment_workloads(cfg)
    m = comm_matrix.PRESETS["ic4"]()
    plain = search_strategy_decode(m, 8, workloads=w, batch=8)
    assert plain.best.speculate is False
    lo = search_strategy_decode(m, 8, workloads=w, batch=8,
                                spec_accept_rate=0.0)
    assert lo.best.speculate is False
    assert lo.best.t_step == pytest.approx(plain.best.t_step)
    hi = search_strategy_decode(m, 8, workloads=w, batch=8,
                                spec_accept_rate=0.8)
    assert hi.best.speculate is True
    assert hi.best.t_step < plain.best.t_step


def test_plan_search_records_decode_knobs():
    """plan_search threads the paged-read model + acceptance prior into
    the decode objective and stamps the winning knobs on the DecodePlan
    (v5 schema), which round-trips through JSON."""
    from repro.configs.registry import get_config
    from repro.core.cost_model import paged_read_model

    cfg = get_config("dbrx-132b")
    pr = paged_read_model(cfg, avg_len=4096, tp=8)
    res = plan_search("ic1", 8, model=cfg, batch=64, seq=4096,
                      decode_batch=64, decode_paged_read=pr,
                      decode_prefix_cache=True)
    dec = res.best.decode
    assert (dec.d1, dec.d2, dec.boundary_mode) == (4, 2, "ring")
    assert dec.prefix_cache is True and dec.speculate is False
    back = ParallelPlan.from_dict(json.loads(json.dumps(
        res.best.to_dict())))
    assert back == res.best
    assert "+pfx" in back.describe()
