"""ATP cost model (Eq. 2/3/4) + strategy search vs the paper's own numbers."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.comm_matrix import (CommLayer, HierarchicalCommMatrix,
                                    ic1_pcie_8gpu, ic2_dual_nvlink_8gpu,
                                    ic3_nvswitch_8gpu, ic4_ib_cluster_16gpu,
                                    ic5_nvlink_network, ic6_torus_2d,
                                    tpu_v5e_pod)
from repro.core.cost_model import (LayerCommProfile, axis_algorithm_bw,
                                   rabenseifner_bw, t_comm)
from repro.core.mesh import factorizations
from repro.core.search import recommend_chunks, search_strategy

PROF = LayerCommProfile.gpt(8192)


def fig7a_matrix():
    """Paper Fig. 7a: 4 nodes x 4 GPUs (NVLink-v3 in, 200Gb HDR out)."""
    return HierarchicalCommMatrix("fig7a", (
        CommLayer("node", 4, 25.0, 25.0),
        CommLayer("gpu", 4, 200.0, 600.0),
    ))


class TestPaperWorkedExamples:
    def test_fig7a_devicemesh_8x2(self):
        """§3.5 worked example: B2'=200 (P2P-limited pair), B1'=12.5."""
        b1, b2 = fig7a_matrix().axis_bandwidths(8, 2)
        assert b2 == pytest.approx(200.0)
        assert b1 == pytest.approx(12.5)

    def test_ic3_selects_atp1(self):
        """§5.3: NVSwitch 8-GPU -> ATP-1 == DeviceMesh(8,1)."""
        r = search_strategy(ic3_nvswitch_8gpu(), 8, layers=4, batch=4,
                            seq=2048, profile=PROF)
        assert r.mesh() == (8, 1)

    def test_ic4_selects_atp2(self):
        """§5.3: flat IB 16-GPU -> ATP-2 == DeviceMesh(8,2)."""
        r = search_strategy(ic4_ib_cluster_16gpu(), 16, layers=4, batch=4,
                            seq=2048, profile=PROF)
        assert r.mesh() == (8, 2)

    def test_ic1_calibrated_atp4_wins_by_46pct(self):
        """§5.3: calibrated IC1 -> ATP-4 T_comm ~46% below ATP-1."""
        calib = {(2, 4): (1.20, 4.95), (8, 1): (0.97, 0.97)}
        r = search_strategy(ic1_pcie_8gpu(), 8, layers=4, batch=4, seq=2048,
                            profile=PROF, calibration=calib)
        t24 = next(c.t_comm for c in r.ranked if (c.d1, c.d2) == (2, 4))
        t81 = next(c.t_comm for c in r.ranked if (c.d1, c.d2) == (8, 1))
        assert 1 - t24 / t81 == pytest.approx(0.46, abs=0.03)

    def test_ic6_torus_b1_eq_b2_eq_groupbw(self):
        """§5.4: 4x4 2D torus -> B1' == B2' == GroupBW (=50)."""
        b1, b2 = ic6_torus_2d().axis_bandwidths(4, 4)
        assert b1 == pytest.approx(50.0)
        assert b2 == pytest.approx(50.0)

    def test_fig12_comm_decreases_with_scale(self):
        """§5.4/Fig 12: optimal ATP T_comm decreases with N on IC5."""
        costs = []
        for n in (8, 16, 32, 64):
            r = search_strategy(ic5_nvlink_network(n), n, layers=4, batch=4,
                                seq=2048, profile=PROF)
            costs.append(r.best.t_comm)
        assert all(a > b for a, b in zip(costs, costs[1:]))

    def test_megatron_is_atp1_point(self):
        """DeviceMesh(N,1) == Megatron: T = 4*L*b*s*h*bytes/B1."""
        m = ic3_nvswitch_8gpu()
        c = t_comm(m, 8, 1, layers=2, batch=4, seq=128, profile=PROF)
        _, _, b1, _ = axis_algorithm_bw(m, 8, 1)
        expect = 4 * 2 * 4 * 128 * 8192 * 2 / b1 / 1e9
        assert c.t_comm == pytest.approx(expect, rel=1e-6)


class TestInvariants:
    @given(st.integers(1, 6).map(lambda k: 2 ** k))
    @settings(max_examples=20, deadline=None)
    def test_factorizations_cover_powers_of_two(self, n):
        f = factorizations(n)
        assert len(f) == int(math.log2(n)) + 1
        assert all(a * b == n for a, b in f)

    @given(st.integers(2, 64))
    @settings(max_examples=30, deadline=None)
    def test_rabenseifner_factor_in_half_to_one(self, d):
        b = rabenseifner_bw(d, 100.0)
        assert 50.0 <= b <= 100.0

    @given(st.integers(1, 4), st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_tcomm_positive_and_monotone_in_volume(self, i, j):
        d1, d2 = 2 ** i, 2 ** j
        m = ic5_nvlink_network(d1 * d2)
        small = t_comm(m, d1, d2, layers=1, batch=1, seq=128,
                       profile=LayerCommProfile.gpt(1024)).t_comm
        big = t_comm(m, d1, d2, layers=2, batch=1, seq=128,
                     profile=LayerCommProfile.gpt(1024)).t_comm
        assert 0 <= small <= big

    def test_search_space_contains_all_meshes(self):
        r = search_strategy(tpu_v5e_pod(), 16, layers=2, batch=2, seq=128,
                            profile=PROF)
        assert {(c.d1, c.d2) for c in r.ranked} == set(factorizations(16))

    def test_chunk_recommendation(self):
        assert recommend_chunks(ic4_ib_cluster_16gpu(), 8, 2) == 4  # slow
        assert recommend_chunks(ic3_nvswitch_8gpu(), 8, 1) == 2     # fast
