"""Paper-notation sharding specs (Shard/Replicate/Partial) — §3.1."""
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.mesh import MeshTopo
from repro.core.sharding import (PARTIAL_SUM, REPLICATE, Shard, ShardingSpec,
                                 spec)

TOPO = MeshTopo((("tp1", 2), ("tp2", 4)))


class TestPaperFigure4:
    """Figure 4: sharding a 2D tensor on DeviceMesh(2,2)."""

    def test_shard1_shard0(self):
        # [Shard(1), Shard(0)]: column-split at level 1, row-split at level 2
        s = spec(("tp1", "tp2"), Shard(1), Shard(0))
        assert s.partition_spec(2) == P("tp2", "tp1")

    def test_replicate_shard0(self):
        s = spec(("tp1", "tp2"), REPLICATE, Shard(0))
        assert s.partition_spec(2) == P("tp2")

    def test_row_first_weight(self):
        # W: [Shard(0), Shard(1)] (paper Fig. 5 left)
        s = spec(("tp1", "tp2"), Shard(0), Shard(1))
        assert s.partition_spec(2) == P("tp1", "tp2")

    def test_local_shape(self):
        s = spec(("tp1", "tp2"), Shard(0), Shard(1))
        assert s.local_shape(TOPO, (8, 8)) == (4, 2)

    def test_both_levels_same_dim_stack(self):
        # two mesh levels splitting the same tensor dim
        s = spec(("tp1", "tp2"), Shard(0), Shard(0))
        assert s.partition_spec(2) == P(("tp1", "tp2"))
        assert s.local_shape(TOPO, (8, 8)) == (1, 8)

    def test_partial_cannot_materialize(self):
        s = spec(("tp1", "tp2"), PARTIAL_SUM, Shard(1))
        with pytest.raises(ValueError):
            s.partition_spec(2)
        assert s.partial_axes() == ("tp1",)

    def test_indivisible_rejected(self):
        s = spec(("tp1", "tp2"), Shard(0), Shard(1))
        with pytest.raises(ValueError):
            s.local_shape(TOPO, (7, 8))


@given(d0=st.integers(0, 2), d1=st.integers(0, 2))
@settings(max_examples=20, deadline=None)
def test_local_shape_product_invariant(d0, d1):
    """prod(local) * prod(shard counts) == prod(global) for any placement."""
    s = spec(("tp1", "tp2"), Shard(d0), Shard(d1))
    g = (8, 8, 8)
    loc = s.local_shape(TOPO, g)
    counts = s.shard_counts(TOPO, 3)
    import math
    assert math.prod(loc) * math.prod(counts) == math.prod(g)