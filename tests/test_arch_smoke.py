"""Per-architecture smoke tests (deliverable f): REDUCED config of the same
family, one forward/train step on CPU, output shapes + no NaNs; key archs
also checked distributed-vs-single-device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.configs.registry import ARCHS, get_config
from repro.core.atp import make_context
from repro.core.mesh import MeshTopo
from repro.models import lm

ALL_ARCHS = sorted(ARCHS)

TOPO1 = MeshTopo((("data", 1),))
TOPO8 = MeshTopo((("data", 2), ("tp1", 2), ("tp2", 2)))
TOPO_MEG = MeshTopo((("data", 2), ("model", 4)))  # ATP (4,1) baseline shape


def _batch(cfg, B=4, S=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    b = {}
    if cfg.frontend == "vision_patches":
        b["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                        jnp.float32) * 0.02
        b["positions3"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    else:
        b["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    b["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    return b


def _loss_on(topo, cfg, params, batch, remat=False):
    mesh = topo.build(jax.devices()[: topo.size])
    ctx = make_context(topo)
    specs = lm.param_specs(cfg, ctx)
    bspec = {k: P("data") if topo.axis_size("data") > 1 else P()
             for k in batch}
    if "positions3" in batch:
        bspec["positions3"] = (P(None, "data") if topo.axis_size("data") > 1
                               else P())
    if "embeds" in batch:
        ax2 = "tp2" if topo.has_axis("tp2") else None
        bspec["embeds"] = (P("data", None, ax2)
                           if topo.axis_size("data") > 1 else P(None, None, ax2))

    def f(p, b):
        return lm.train_loss(ctx, cfg, p, b, remat=remat)

    g = shard_map(f, mesh=mesh, in_specs=(specs, bspec), out_specs=P(),
                  check_vma=True)
    return jax.jit(g)(params, batch)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg)
    loss = _loss_on(TOPO1, cfg, params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    assert 0.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_prefill_shapes(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    topo = TOPO1
    mesh = topo.build(jax.devices()[:1])
    ctx = make_context(topo)

    def f(p, b):
        return lm.prefill_logits(ctx, cfg, p, b)

    g = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                  check_vma=True)
    logits = jax.jit(g)(params, batch)
    assert logits.shape == (4, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# distributed == single device, per family representative
DIST_ARCHS = ["llama3-8b", "gemma2-2b", "dbrx-132b", "deepseek-v3-671b",
              "zamba2-7b", "xlstm-1.3b", "qwen2-vl-7b", "musicgen-medium"]


@pytest.mark.parametrize("arch", DIST_ARCHS)
def test_distributed_matches_reference(devices8, arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # avoid capacity-drop divergence between layouts
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg)
    ref = _loss_on(TOPO1, cfg, params, batch)
    dist = _loss_on(TOPO8, cfg, params, batch, remat=True)
    np.testing.assert_allclose(float(dist), float(ref), rtol=5e-3)


@pytest.mark.parametrize("arch", ["llama3-8b", "musicgen-medium"])
def test_megatron_mesh_matches_reference(devices8, arch):
    """ATP (N,1) degenerate point (single 'model' axis) == reference.
    musicgen exercises the q_regroup path (24 heads % 4 != 0)."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg)
    ref = _loss_on(TOPO1, cfg, params, batch)
    meg = _loss_on(TOPO_MEG, cfg, params, batch)
    np.testing.assert_allclose(float(meg), float(ref), rtol=5e-3)


def test_param_counts_match_analytic():
    """init param count ~= ModelConfig.param_count (exact for dense)."""
    for arch in ("llama3-8b", "qwen3-8b", "gemma2-2b"):
        cfg = get_config(arch)
        abstract = lm.abstract_params(cfg)
        got = lm.count_params(abstract)
        expect = cfg.param_count()
        assert abs(got - expect) / expect < 0.02, (arch, got, expect)
