"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from results/dryrun/*.json:
    compute term    = HLO_dot_FLOPs_per_device / peak_FLOPs      [s]
    memory term     = HLO_traffic_per_device / HBM_bw            [s]
    collective term = collective_bytes_per_device / link_bw      [s]
(FLOPs/traffic/collectives are trip-count-aware HLO sums; see
launch/hlo_analysis.py.)  Dominant term == bottleneck; useful-compute
ratio = MODEL_FLOPS / (HLO_FLOPs * chips).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs.base import shape_by_name
from repro.configs.registry import get_config

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link (1 link assumed: conservative)

RESULTS = os.path.join(os.path.dirname(__file__), "../results/dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_act * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.seq_len * shape.global_batch
    return 2.0 * n_act * shape.global_batch  # decode: one token per stream


def analyze(rec: dict, chips: int = 256) -> dict:
    comp = rec["flops"] / PEAK_FLOPS
    mem = rec["traffic_bytes"] / HBM_BW
    coll = rec["collectives"]["total_bytes"] / LINK_BW
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = rec["flops"] * chips
    return {
        **{f"{k}_s": v for k, v in terms.items()},
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": comp / max(terms.values()) if max(terms.values()) else 0.0,
        "step_lower_bound_s": max(terms.values()),
    }


def load_cells(pattern: str = "*__pod1__atp16x1.json"):
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            rec = json.load(f)
        cells.append(rec)
    return cells


def table(cells, chips: int = 256) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful FLOPs ratio | roofline frac |")
    sep = "|" + "---|" * 8
    rows = [hdr, sep]
    for rec in cells:
        if rec.get("status") == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped (sub-quadratic rule) | — | — |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"ERROR | — | — |")
            continue
        a = analyze(rec, chips)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {a['compute_s']:.3f} | "
            f"{a['memory_s']:.3f} | {a['collective_s']:.3f} | {a['dominant']} | "
            f"{a['useful_ratio']:.2f} | {a['roofline_fraction']:.2f} |")
    return "\n".join(rows)


def main():
    cells = load_cells()
    print(table(cells))
    interesting = []
    for rec in cells:
        if rec.get("status") != "ok":
            continue
        a = analyze(rec)
        interesting.append((a["roofline_fraction"], a["dominant"],
                            rec["arch"], rec["shape"]))
    interesting.sort()
    print("\nworst roofline fractions:")
    for frac, dom, arch, shape in interesting[:6]:
        print(f"  {arch} x {shape}: {frac:.3f} ({dom}-bound)")


if __name__ == "__main__":
    main()
