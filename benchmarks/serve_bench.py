"""Serving fast-path benchmark: paged continuous batching vs the seed
wave loop on a mixed-prompt-length workload.  Writes BENCH_serve.json.

    PYTHONPATH=src python -m benchmarks.serve_bench

Measured side (CPU host mesh — numbers validate the scheduling win, not
accelerator speedups):
  - the seed-style wave loop: equal-length waves, every prompt padded to
    the longest, one whole-prompt prefill per admission, lockstep decode
    over dense ``[B, s_max]`` caches;
  - the paged continuous server: chunk-rounded prefill interleaved with
    per-slot decode over block-paged caches.
Both must emit IDENTICAL greedy tokens per request; tokens/sec, per-tick
wall times and cache-memory footprints are recorded.

Modeled side (the latency-aware decode objective): per-(d1, d2) decode
step latency rankings on the pinned interconnect presets, asserting that
the decode objective picks a different factorization than the train
objective on at least one preset (ic4 — the acceptance pin).
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

SLOTS = 4
MAX_NEW = 8
MAX_SEQ = 64
CHUNK = 8
PAGE = 8
#: mixed prompt lengths — short prompts dominate, exactly the workload
#: the seed wave loop pads to the longest prompt
PROMPT_LENS = [6, 22, 9, 48, 12, 7, 30, 10, 5, 17]


def _setup():
    import jax
    import numpy as np

    from repro.configs.registry import get_config
    from repro.models import lm

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in PROMPT_LENS]
    return cfg, params, prompts


def run_wave(cfg, params, prompts) -> dict:
    """Seed wave loop: pad everything to the longest prompt, serve in
    equal-length waves of SLOTS, decode in lockstep to MAX_NEW."""
    import numpy as np

    from repro.core.mesh import atp_topo
    from repro.launch.serve import serve

    topo = atp_topo(1, 1, 1)
    pad_to = max(len(p) for p in prompts)
    padded = []
    for p in prompts:
        buf = np.zeros((pad_to,), np.int32)
        buf[: len(p)] = p
        padded.append(buf)

    # warm-up wave compiles prefill + decode
    serve(cfg, topo, params, padded[:SLOTS], MAX_NEW, MAX_SEQ)
    t0 = time.perf_counter()
    outs = []
    pending = list(padded)
    waves = 0
    while pending:
        batch = pending[:SLOTS]
        pending = pending[SLOTS:]
        n_real = len(batch)
        while len(batch) < SLOTS:
            batch.append(np.zeros(pad_to, np.int32))
        res = serve(cfg, topo, params, batch, MAX_NEW, MAX_SEQ)
        outs.extend(res[i].tolist() for i in range(n_real))
        waves += 1
    wall = time.perf_counter() - t0
    # NOTE: wave parity caveat — prompts shorter than pad_to see padding
    # zeros inside their sequence, so per-request token parity uses the
    # per-request wave reference below, not these padded outputs.
    new_tokens = MAX_NEW * len(prompts)
    return {
        "mode": "wave",
        "waves": waves,
        "pad_to": pad_to,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(new_tokens / wall, 2),
        "cache_bytes": _dense_cache_bytes(cfg, SLOTS, MAX_SEQ),
        "outs": outs,
    }


def run_reference(cfg, params, prompts) -> list[list[int]]:
    """Per-request B=1 wave runs: the unpadded greedy ground truth."""
    from repro.core.mesh import atp_topo
    from repro.launch.serve import serve

    topo = atp_topo(1, 1, 1)
    return [serve(cfg, topo, params, [p], MAX_NEW, MAX_SEQ)[0].tolist()
            for p in prompts]


def run_paged(cfg, params, prompts) -> dict:
    import numpy as np

    from repro.core.mesh import atp_topo
    from repro.launch.serve import make_paged_server
    from repro.models.paging import PagedConfig
    from repro.runtime.server import Request, ServerConfig

    # pool sized to the worst-case LIVE tokens: the SLOTS largest requests
    # resident simultaneously (admission backpressure covers transients).
    # This is the paged win: the dense cache pays slots x s_max regardless.
    per_req = sorted((-(-(len(p) + MAX_NEW) // PAGE) for p in prompts),
                     reverse=True)
    pool = 1 + sum(per_req[:SLOTS])
    pcfg = PagedConfig(page_size=PAGE, num_pages=pool,
                       pages_per_slot=-(-MAX_SEQ // PAGE))
    scfg = ServerConfig(batch_slots=SLOTS, prefill_chunk=CHUNK, paged=pcfg)
    topo = atp_topo(1, 1, 1)

    def fresh():
        server, _ = make_paged_server(cfg, scfg, params, topo=topo)
        for rid, p in enumerate(prompts):
            server.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
        return server

    # warm-up run compiles the two step shapes
    fresh().run_until_drained()

    server = fresh()
    tick_times = []
    t0 = time.perf_counter()
    while server.busy:
        ts = time.perf_counter()
        server.step()
        tick_times.append(time.perf_counter() - ts)
    wall = time.perf_counter() - t0
    outs = [r.out for r in sorted(server.completed, key=lambda r: r.rid)]
    new_tokens = MAX_NEW * len(prompts)
    tick_ms = sorted(t * 1e3 for t in tick_times)
    return {
        "mode": "paged-continuous",
        "ticks": len(tick_times),
        "wall_s": round(wall, 4),
        "tokens_per_s": round(new_tokens / wall, 2),
        "tick_ms": {
            "mean": round(sum(tick_ms) / len(tick_ms), 3),
            "p50": round(tick_ms[len(tick_ms) // 2], 3),
            "max": round(tick_ms[-1], 3),
        },
        "cache_bytes": _paged_cache_bytes(cfg, pcfg),
        "page_pool": {"pages": pool, "page_size": PAGE,
                      "capacity_tokens": pcfg.capacity_tokens},
        "outs": outs,
    }


def _dense_cache_bytes(cfg, B, s_max) -> int:
    import jax

    from repro.core.atp import make_context
    from repro.core.mesh import MeshTopo
    from repro.models import lm

    ctx = make_context(MeshTopo((("data", 1),)))
    caches, _ = lm.init_decode_caches(cfg, ctx, B, s_max, abstract=True)
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches)))


def _paged_cache_bytes(cfg, pcfg) -> int:
    import jax

    from repro.core.atp import make_context
    from repro.core.mesh import MeshTopo
    from repro.models import lm

    ctx = make_context(MeshTopo((("data", 1),)))
    caches, _ = lm.init_paged_caches(cfg, ctx, pcfg, abstract=True)
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches)))


def run_prefix(cfg, params) -> dict:
    """Copy-on-write prefix cache on a shared-system-prompt workload.

    Eight requests share a 48-token system prompt (6 full pages) over
    short per-request suffixes, ``max_new=1`` so the measurement is pure
    prefill.  A warmer request (submitted and drained first, which also
    compiles the chunk shape) registers the prefix in the radix index;
    the measured batch then admits against a warm cache.  With the cache
    off every request prefills all ~7 chunks; with it on, admission maps
    the 6 shared pages and feeds only the suffix chunk.
    """
    import numpy as np

    from repro.core.mesh import atp_topo
    from repro.launch.serve import make_paged_server
    from repro.models.paging import PagedConfig
    from repro.runtime.server import Request, ServerConfig

    SYS_LEN, N_REQ = 48, 8
    rng = np.random.default_rng(7)
    system = rng.integers(0, cfg.vocab_size, size=SYS_LEN, dtype=np.int32)
    prompts = [np.concatenate([system, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(4, 8)), dtype=np.int32)])
        for _ in range(N_REQ + 1)]           # +1: the warmer
    pool = 1 + sum(-(-(len(p) + 1) // PAGE) for p in prompts)
    topo = atp_topo(1, 1, 1)

    out = {}
    for on in (False, True):
        scfg = ServerConfig(
            batch_slots=SLOTS, prefill_chunk=PAGE,
            paged=PagedConfig(page_size=PAGE, num_pages=pool,
                              pages_per_slot=-(-MAX_SEQ // PAGE)),
            prefix_cache=on)
        server, _ = make_paged_server(cfg, scfg, params, topo=topo)
        server.submit(Request(rid=0, prompt=prompts[0], max_new=1))
        server.run_until_drained()           # warm: compile + register
        t0 = time.perf_counter()
        for rid, p in enumerate(prompts[1:], start=1):
            server.submit(Request(rid=rid, prompt=p, max_new=1))
        server.run_until_drained()
        wall = time.perf_counter() - t0
        toks = sum(len(p) for p in prompts[1:])
        st = server.stats()
        out["on" if on else "off"] = {
            "wall_s": round(wall, 4),
            "prefill_tokens_per_s": round(toks / wall, 2),
            "prefix_hit_rate": round(st["prefix_hit_rate"], 4),
            "pages_shared_peak": st["pages_shared"],
            "outs": [r.out for r in sorted(server.completed,
                                           key=lambda r: r.rid)],
        }
    # the cache must be invisible in the tokens
    assert out["on"]["outs"] == out["off"]["outs"], \
        "prefix cache changed greedy tokens"
    for d in out.values():
        d.pop("outs")
    out["speedup_x"] = round(out["on"]["prefill_tokens_per_s"]
                             / out["off"]["prefill_tokens_per_s"], 3)
    out["workload"] = {"system_tokens": SYS_LEN, "requests": N_REQ,
                      "page_size": PAGE, "max_new": 1}
    return out


def _oracle_params(cfg, params):
    """A parametrization whose MTP head is an exact next-step oracle.

    Zeroing every block's output projections (attn ``wo``, mlp
    ``w_down``) collapses the residual stream to the token embedding, so
    greedy decode becomes a fixed chain t -> argmax lm_head(norm(emb(t)))
    ; with ``proj_h = 0`` and ``proj_e = I`` the draft head computes the
    SAME chain one step ahead, making every draft acceptable.  Random
    init gives acceptance ~1/vocab (the parity leg still exercises the
    rollback machinery); this harness pins the accept path itself.
    """
    import copy

    import jax.numpy as jnp

    p = copy.deepcopy(params)

    def zero_block(bp):
        bp["attn"]["wo"] = jnp.zeros_like(bp["attn"]["wo"])
        bp["mlp"]["w_down"] = jnp.zeros_like(bp["mlp"]["w_down"])

    for k in list(p):
        if k.startswith("seg"):
            zero_block(p[k])
    zero_block(p["mtp"]["block"])
    p["mtp"]["proj_h"] = jnp.zeros_like(p["mtp"]["proj_h"])
    p["mtp"]["proj_e"] = jnp.eye(cfg.d_model,
                                 dtype=p["mtp"]["proj_e"].dtype)
    return p


def run_speculative(cfg) -> dict:
    """MTP self-speculative decode: greedy parity + acceptance rate.

    Serves the mixed workload twice (plain paged vs ``speculate=True``)
    on random init — tokens must match EXACTLY — then re-serves with the
    oracle parametrization where every draft is acceptable, pinning a
    positive mean accepted-draft rate and the tick savings it buys.
    """
    import dataclasses

    import jax
    import numpy as np

    from repro.core.mesh import atp_topo
    from repro.launch.serve import make_paged_server
    from repro.models import lm
    from repro.models.paging import PagedConfig
    from repro.runtime.server import Request, ServerConfig

    mcfg = dataclasses.replace(cfg, mtp=True)
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, mcfg.vocab_size, size=n, dtype=np.int32)
               for n in PROMPT_LENS]
    per_req = sorted((-(-(len(p) + MAX_NEW) // PAGE) for p in prompts),
                     reverse=True)
    pool = 1 + sum(per_req[:SLOTS])
    topo = atp_topo(1, 1, 1)

    def serve_all(ps, speculate):
        scfg = ServerConfig(
            batch_slots=SLOTS, prefill_chunk=CHUNK,
            paged=PagedConfig(page_size=PAGE, num_pages=pool,
                              pages_per_slot=-(-MAX_SEQ // PAGE)),
            speculate=speculate)
        server, _ = make_paged_server(mcfg, scfg, ps, topo=topo)
        for rid, p in enumerate(prompts):
            server.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
        ticks = server.run_until_drained()
        outs = [r.out for r in sorted(server.completed,
                                      key=lambda r: r.rid)]
        return outs, ticks, server.stats()

    plain, plain_ticks, _ = serve_all(params, False)
    spec, spec_ticks, st = serve_all(params, True)
    parity = spec == plain
    assert parity, f"speculative decode broke greedy parity:\n{spec}\nvs\n{plain}"

    oparams = _oracle_params(mcfg, params)
    oplain, oplain_ticks, _ = serve_all(oparams, False)
    ospec, ospec_ticks, ost = serve_all(oparams, True)
    assert ospec == oplain, "oracle speculative decode broke parity"
    assert ost["spec_accept_rate"] > 0.0, \
        f"oracle drafts must be accepted (got {ost['spec_accept_rate']})"

    return {
        "random_init": {
            "greedy_parity": parity,
            "accept_rate": round(st["spec_accept_rate"], 4),
            "plain_ticks": plain_ticks, "spec_ticks": spec_ticks,
        },
        "oracle": {
            "accept_rate": round(ost["spec_accept_rate"], 4),
            "drafts": ost["spec_drafts"],
            "accepted": ost["spec_accepted"],
            "plain_ticks": oplain_ticks, "spec_ticks": ospec_ticks,
            "tick_reduction_x": round(oplain_ticks / ospec_ticks, 3),
        },
    }


def modeled_decode_rankings() -> dict:
    """Decode-vs-train objective rankings per preset (pure cost model)."""
    from repro.core import comm_matrix as cm
    from repro.core.cost_model import LayerCommProfile, SegmentWorkload
    from repro.core.search import (search_strategy_decode,
                                   search_strategy_segments)

    workloads = (SegmentWorkload("dense", 24, LayerCommProfile.gpt(4096)),)
    out = {}
    for preset in ("ic1", "ic2", "ic3", "ic4", "ic6"):
        m = cm.PRESETS[preset]()
        tp = min(16, m.num_devices)
        dec = search_strategy_decode(m, tp, workloads=workloads, batch=SLOTS)
        tr = search_strategy_segments(m, tp, workloads=workloads,
                                      batch=256, seq=4096)
        out[preset] = {
            "tp": tp,
            "train_mesh": list(tr.mesh()),
            "decode_mesh": list(dec.mesh()),
            "decode_boundary_mode": dec.best.boundary_mode,
            "decode_differs": list(tr.mesh()) != list(dec.mesh()),
            "decode_ranking": [
                {"d1": c.d1, "d2": c.d2, "t_step_us": round(c.t_step * 1e6, 2),
                 "t_launch_us": round(c.t_launch * 1e6, 2),
                 "t_alpha_us": round(c.t_alpha * 1e6, 2),
                 "t_bytes_us": round(c.t_bytes * 1e6, 2)}
                for c in dec.ranked],
        }
    return out


def modeled_paged_read_flip() -> dict:
    """The paged-read term changing the chosen decode mesh (the pinned
    ic1 + dbrx case from tests/test_serving.py, recorded as data)."""
    from repro.configs.registry import get_config
    from repro.core import comm_matrix as cm
    from repro.core.cost_model import paged_read_model, segment_workloads
    from repro.core.search import search_strategy_decode

    cfg = get_config("dbrx-132b")
    w = segment_workloads(cfg)
    m = cm.PRESETS["ic1"]()
    base = search_strategy_decode(m, 8, workloads=w, batch=64)
    pr = paged_read_model(cfg, avg_len=4096, tp=8)
    priced = search_strategy_decode(m, 8, workloads=w, batch=64,
                                    paged_read=pr)
    return {
        "preset": "ic1", "arch": "dbrx-132b", "tp": 8, "batch": 64,
        "avg_len": 4096,
        "kv_bytes_per_token_per_layer": round(pr.kv_bytes_per_token, 1),
        "unpriced_mesh": [base.best.d1, base.best.d2],
        "unpriced_mode": base.best.boundary_mode,
        "priced_mesh": [priced.best.d1, priced.best.d2],
        "priced_mode": priced.best.boundary_mode,
        "exposed_read_us": round(priced.best.t_read * 1e6, 2),
        "mesh_flipped": (base.best.d1, base.best.d2)
        != (priced.best.d1, priced.best.d2),
    }


def main() -> None:
    cfg, params, prompts = _setup()

    wave = run_wave(cfg, params, prompts)
    paged = run_paged(cfg, params, prompts)
    ref = run_reference(cfg, params, prompts)

    # greedy-token parity: the paged continuous server must reproduce the
    # per-request unpadded reference exactly
    assert paged["outs"] == ref, (
        f"paged tokens diverge from reference:\n{paged['outs']}\nvs\n{ref}")
    full = [i for i, p in enumerate(prompts)
            if len(p) == wave["pad_to"]]
    assert all(wave["outs"][i] == ref[i] for i in full), \
        "wave loop diverges from reference on unpadded prompts"

    prefix = run_prefix(cfg, params)
    spec = run_speculative(cfg)

    speedup = wave["wall_s"] / paged["wall_s"]
    rankings = modeled_decode_rankings()
    differs = [p for p, r in rankings.items() if r["decode_differs"]]
    read_flip = modeled_paged_read_flip()

    summary = {
        "workload": {"requests": len(prompts), "prompt_lens": PROMPT_LENS,
                     "max_new": MAX_NEW, "slots": SLOTS,
                     "prefill_chunk": CHUNK},
        "wave_tokens_per_s": wave["tokens_per_s"],
        "paged_tokens_per_s": paged["tokens_per_s"],
        "paged_speedup_x": round(speedup, 3),
        "token_parity": True,
        "dense_cache_bytes": wave["cache_bytes"],
        "paged_cache_bytes": paged["cache_bytes"],
        "cache_bytes_ratio": round(wave["cache_bytes"]
                                   / paged["cache_bytes"], 3),
        "decode_objective_differs_on": differs,
        "prefix_prefill_speedup_x": prefix["speedup_x"],
        "prefix_hit_rate": prefix["on"]["prefix_hit_rate"],
        "spec_greedy_parity": spec["random_init"]["greedy_parity"],
        "spec_accept_rate": spec["oracle"]["accept_rate"],
        "paged_read_flips_mesh": read_flip["mesh_flipped"],
    }
    assert speedup > 1.0, (
        f"paged continuous batching must beat the wave loop: {speedup:.3f}x")
    assert summary["cache_bytes_ratio"] > 1.0, (
        "live-token page pool must undercut the dense slots x s_max cache")
    assert "ic4" in differs, (
        "decode objective must differ from train on the pinned ic4 preset")
    assert summary["prefix_prefill_speedup_x"] >= 1.5, (
        "shared-system-prompt prefill must speed up >= 1.5x with the "
        f"prefix cache (got {summary['prefix_prefill_speedup_x']}x)")
    assert summary["spec_accept_rate"] > 0.0
    assert read_flip["mesh_flipped"], (
        "the paged-read term must change the chosen decode mesh on ic1")

    for r in (wave, paged):
        r.pop("outs")  # tokens verified above; keep the artifact small
    payload = {
        "bench": "serve",
        "arch": "qwen1.5-0.5b (reduced)",
        "wave": wave,
        "paged": paged,
        "prefix_cache": prefix,
        "speculative": spec,
        "modeled_decode": rankings,
        "modeled_paged_read": read_flip,
        "summary": summary,
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"summary: {json.dumps(summary)}")
    print(f"wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
