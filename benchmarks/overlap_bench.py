"""Overlap engine benchmark: chunk counts x boundary modes on a simulated
8-device mesh (4x2 ATP).  Writes BENCH_overlap.json.

    PYTHONPATH=src python -m benchmarks.overlap_bench

Per config it records
  - measured wall time of one pre-norm + MLP block (CPU host mesh: the
    numbers validate plumbing, not speedups — there is no async collective
    engine on the CPU backend), and
  - the overlap-aware cost model's view on a real interconnect (IC4 flat
    IB): exposed comm time and modeled ax1/ax2 boundary wire bytes.

Acceptance properties asserted and stored in "summary":
  - sequence-parallel reduces modeled ax1 *boundary* bytes by >= 1.9x vs
    the replicated block I/O spec (reduce-scatter vs all-reduce; the
    conjugate block-entry gather is reported separately in
    ax1_total_bytes — total fwd+bwd volume is conserved, the win is
    per-op wire size, overlap granularity, and d1x activation memory);
  - whenever per-chunk GEMM time exceeds per-chunk ring time, the model
    ranks chunks > 1 strictly cheaper than chunks = 1.
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_overlap.json")

D1, D2 = 4, 2
BATCH, SEQ, HIDDEN, FF = 4, 64, 256, 512
LAYERS = 2


def _modes():
    return [
        ("replicated", dict(boundary_mode="psum", seq_parallel=False)),
        ("replicated-ring", dict(boundary_mode="ring", seq_parallel=False)),
        ("seq-parallel", dict(boundary_mode="psum", seq_parallel=True)),
        ("seq-parallel-ring", dict(boundary_mode="ring", seq_parallel=True)),
    ]


def measure_block(mode_kwargs, chunks: int) -> float:
    """Wall time (us) of pre-norm + MLP (f3/f4 boundaries) on the host mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.atp import atp_linear, make_context
    from repro.core.compat import shard_map
    from repro.core.mesh import MeshTopo
    from repro.models import layers as L

    topo = MeshTopo((("tp1", D1), ("tp2", D2)))
    mesh = topo.build(jax.devices()[: topo.size])
    ctx = make_context(topo, chunks=chunks, **mode_kwargs)

    x = jax.random.normal(jax.random.PRNGKey(0), (BATCH, SEQ, HIDDEN))
    gamma = jnp.ones((HIDDEN,))
    A = jax.random.normal(jax.random.PRNGKey(1), (HIDDEN, FF)) * 0.05
    B = jax.random.normal(jax.random.PRNGKey(2), (FF, HIDDEN)) * 0.05

    def block(x, gamma, A, B):
        h = L.rms_norm(ctx, x, gamma, gather_seq=ctx.seq_parallel)
        y = jax.nn.gelu(atp_linear(ctx, h, A, kind="col"))
        return x + atp_linear(ctx, y, B, kind="row")

    seq_ax = "tp1" if ctx.seq_parallel else None
    xspec = P(None, seq_ax, "tp2")
    f = jax.jit(shard_map(
        block, mesh=mesh,
        in_specs=(xspec, P("tp2"), P("tp2", "tp1"), P("tp1", "tp2")),
        out_specs=xspec, check_vma=False))
    xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, xspec))
    f(xs, gamma, A, B).block_until_ready()
    t0 = time.perf_counter()
    iters = 20
    for _ in range(iters):
        out = f(xs, gamma, A, B)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def modeled(mode_kwargs, chunks: int):
    from repro.core import comm_matrix as cm
    from repro.core.cost_model import LayerCommProfile, t_comm_overlap

    profile = LayerCommProfile(FF, HIDDEN, hidden=HIDDEN)
    c = t_comm_overlap(
        cm.ic4_ib_cluster_16gpu(), D1, D2,
        layers=LAYERS, batch=BATCH, seq=SEQ, profile=profile,
        chunks=chunks, seq_parallel=mode_kwargs["seq_parallel"],
        peak_tflops=50.0, algo="ring", alpha_s=2e-6)
    return {
        "t_comm_s": c.t_comm,
        "t_exposed_s": c.t_exposed,
        "t_gemm_s": c.t_gemm,
        "ax1_boundary_bytes": c.ax1_boundary_bytes,
        "ax1_total_bytes": c.ax1_total_bytes,
        "ax2_boundary_bytes": c.ax2_boundary_bytes,
    }


def chunk_ranking_property() -> dict:
    """Model property: chunks>1 strictly cheaper whenever per-chunk GEMM
    time exceeds per-chunk ring time (swept over payload scales)."""
    from repro.core import comm_matrix as cm
    from repro.core.cost_model import LayerCommProfile, t_comm_overlap

    checked = violations = applicable = 0
    for scale, peak in ((1, 50.0), (16, 50.0), (64, 5.0), (64, 1.0)):
        profile = LayerCommProfile(FF * scale, HIDDEN, hidden=HIDDEN * scale)
        base = t_comm_overlap(cm.ic4_ib_cluster_16gpu(), D1, D2,
                              layers=LAYERS, batch=BATCH, seq=SEQ,
                              profile=profile, chunks=1, peak_tflops=peak,
                              algo="ring", alpha_s=2e-6)
        for chunks in (2, 4, 8):
            c = t_comm_overlap(cm.ic4_ib_cluster_16gpu(), D1, D2,
                               layers=LAYERS, batch=BATCH, seq=SEQ,
                               profile=profile, chunks=chunks,
                               peak_tflops=peak, algo="ring", alpha_s=2e-6)
            checked += 1
            if c.fully_overlapped:
                applicable += 1
                if not c.t_exposed < base.t_exposed:
                    violations += 1
    return {"checked": checked, "applicable": applicable,
            "violations": violations}


def main() -> None:
    results = []
    for mode_name, kwargs in _modes():
        for chunks in (1, 2, 4):
            wall = measure_block(kwargs, chunks)
            m = modeled(kwargs, chunks)
            results.append({"mode": mode_name, "chunks": chunks,
                            "wall_us": round(wall, 1), **{"modeled": m}})
            print(f"{mode_name:>18} chunks={chunks}: {wall:8.1f} us  "
                  f"exposed={m['t_exposed_s']*1e3:.3f} ms  "
                  f"ax1_boundary={m['ax1_boundary_bytes']/1e6:.2f} MB")

    rep = next(r for r in results
               if r["mode"] == "replicated" and r["chunks"] == 1)
    sp = next(r for r in results
              if r["mode"] == "seq-parallel" and r["chunks"] == 1)
    ratio = (rep["modeled"]["ax1_boundary_bytes"]
             / sp["modeled"]["ax1_boundary_bytes"])
    ranking = chunk_ranking_property()

    summary = {
        "ax1_boundary_bytes_replicated": rep["modeled"]["ax1_boundary_bytes"],
        "ax1_boundary_bytes_seq_parallel": sp["modeled"]["ax1_boundary_bytes"],
        "ax1_boundary_reduction_x": round(ratio, 3),
        "ax1_total_bytes_seq_parallel": sp["modeled"]["ax1_total_bytes"],
        "chunk_ranking": ranking,
    }
    assert ratio >= 1.9, f"seq-parallel boundary reduction {ratio:.2f}x < 1.9x"
    assert ranking["violations"] == 0, ranking

    payload = {
        "bench": "overlap",
        "mesh": {"devices": D1 * D2, "d1": D1, "d2": D2},
        "shape": {"batch": BATCH, "seq": SEQ, "hidden": HIDDEN, "ff": FF,
                  "layers": LAYERS},
        "configs": results,
        "summary": summary,
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"summary: {json.dumps(summary)}")
    print(f"wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
