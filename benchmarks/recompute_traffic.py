"""Recompute HLO-derived roofline inputs from the saved .hlo.gz artifacts
(no recompilation) after accounting-rule changes in hlo_analysis."""
import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))
from repro.launch import hlo_analysis  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "../results/dryrun")


def main():
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        hlo_path = os.path.join(RESULTS, "hlo",
                                os.path.basename(path)[:-5] + ".hlo.gz")
        if not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            hlo = f.read()
        full = hlo_analysis.full_analysis(hlo)
        rec["flops"] = full["dot_flops"]
        rec["traffic_bytes"] = full["traffic_bytes"]
        rec["collectives"] = full["collectives"]
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"{os.path.basename(path):60s} traffic={rec['traffic_bytes']:.3e}")


if __name__ == "__main__":
    main()
