"""Replay checked-in bench baselines and fail on >10% regression.

    PYTHONPATH=src python -m benchmarks.bench_regress            # replay
    PYTHONPATH=src python -m benchmarks.bench_regress --freeze   # re-pin

Replay reads each BENCH_*.json artifact at the repo root and compares the
tracked metrics against ``benchmarks/baselines.json``:

  - ``ratio`` metrics (higher is better, deterministic byte/volume
    ratios — NOT wall-clock timings, which are too noisy on shared CI
    hosts) fail when the current value drops below 0.9x the baseline;
  - ``flag`` metrics are pinned invariants (token parity, the search
    flip) and fail on ANY change from the baseline;
  - ``drift`` metrics are deterministic absolute quantities (the
    per-preset extracted collective byte totals from ``make lint-plans``)
    that fail on >10% movement in EITHER direction — comm volume cannot
    silently grow between PRs, and a shrink means the sweep changed and
    the baseline must be consciously re-pinned.

A missing BENCH artifact skips its metrics (benches are not re-run
here — ``make bench`` produces the artifacts), so ``make test`` stays
green on a fresh checkout; a missing baselines.json fails loudly since
that file is checked in.
"""
import argparse
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
BASELINES = os.path.join(os.path.dirname(__file__), "baselines.json")

#: bench artifact -> tracked metrics (path into the JSON, kind)
TRACKED = {
    "BENCH_overlap.json": [
        ("summary.ax1_boundary_reduction_x", "ratio"),
    ],
    "BENCH_serve.json": [
        ("summary.cache_bytes_ratio", "ratio"),
        ("summary.token_parity", "flag"),
        ("summary.prefix_prefill_speedup_x", "ratio"),
        ("summary.prefix_hit_rate", "ratio"),
        ("summary.spec_greedy_parity", "flag"),
        ("summary.spec_accept_rate", "ratio"),
        ("summary.paged_read_flips_mesh", "flag"),
    ],
    "BENCH_quant.json": [
        ("summary.wire_bytes_ratio", "ratio"),
        ("summary.pool_bytes_ratio", "ratio"),
        ("summary.greedy_parity", "flag"),
        ("summary.search_flips_mesh", "flag"),
    ],
    "BENCH_chaos.json": [
        # recovery invariants from the scripted chaos scenarios
        # (launch.chaos_smoke): any flip means a degradation-ladder or
        # membership regression
        ("loss_continuity", "flag"),
        ("single_replanner", "flag"),
        ("budget_respected", "flag"),
        ("pool_drained", "flag"),
        ("remesh_parity", "flag"),
        ("torn_ckpt_recovered", "flag"),
        # deterministic recovery metrics: sim-seconds from failure to the
        # first quorum commit, and the served/expired split under the
        # scripted backpressure window
        ("recovery_sim_s", "drift"),
        ("served_fraction", "ratio"),
        ("expired_request_rate", "drift"),
    ],
    "BENCH_analysis.json": [
        ("summary.conformant", "flag"),
    ] + [
        (f"per_preset_raw_bytes.{p}", "drift")
        for p in ("ic1", "ic2", "ic3", "ic4", "ic5", "ic6", "v5e",
                  "v5e-multipod")
    ],
}

TOLERANCE = 0.9   # current ratio must stay >= 90% of the frozen baseline


def _lookup(doc, path):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _collect():
    """{artifact: {path: value}} for every artifact present on disk."""
    out = {}
    for fname, metrics in TRACKED.items():
        fpath = os.path.join(ROOT, fname)
        if not os.path.exists(fpath):
            continue
        with open(fpath) as fh:
            doc = json.load(fh)
        vals = {}
        for path, kind in metrics:
            v = _lookup(doc, path)
            if v is None:
                print(f"ERROR: {fname} is missing tracked metric {path}")
                sys.exit(2)
            vals[path] = v
        out[fname] = vals
    return out


def freeze() -> None:
    current = _collect()
    if not current:
        print("no BENCH_*.json artifacts found; run the benches first")
        sys.exit(2)
    with open(BASELINES, "w") as fh:
        json.dump(current, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"froze {sum(len(v) for v in current.values())} metrics from "
          f"{len(current)} artifacts -> {os.path.relpath(BASELINES)}")


def replay() -> None:
    if not os.path.exists(BASELINES):
        print(f"ERROR: {BASELINES} is missing (it is checked in; "
              f"re-pin with --freeze)")
        sys.exit(2)
    with open(BASELINES) as fh:
        base = json.load(fh)
    current = _collect()
    kinds = {p: k for ms in TRACKED.values() for p, k in ms}
    failures, checked, skipped = [], 0, 0
    for fname, metrics in base.items():
        if fname not in current:
            skipped += len(metrics)
            print(f"skip {fname}: artifact not present")
            continue
        for path, frozen in metrics.items():
            got = current[fname].get(path)
            checked += 1
            if kinds.get(path) == "flag":
                ok = got == frozen
                verdict = "ok" if ok else f"FLIPPED (was {frozen!r})"
            elif kinds.get(path) == "drift":
                lo, hi = TOLERANCE * float(frozen), float(frozen) / TOLERANCE
                ok = lo <= float(got) <= hi
                verdict = ("ok" if ok else
                           f"DRIFTED >{(1 - TOLERANCE) * 100:.0f}% "
                           f"(baseline {frozen})")
            else:
                ok = float(got) >= TOLERANCE * float(frozen)
                verdict = ("ok" if ok else
                           f"REGRESSED >{(1 - TOLERANCE) * 100:.0f}% "
                           f"(baseline {frozen})")
            print(f"{'ok  ' if ok else 'FAIL'} {fname}:{path} = {got}"
                  f"  [{verdict}]")
            if not ok:
                failures.append(f"{fname}:{path}")
    print(f"bench-regress: {checked} checked, {skipped} skipped, "
          f"{len(failures)} failed")
    if failures:
        sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--freeze", action="store_true",
                    help="re-pin baselines.json from the current artifacts")
    args = ap.parse_args()
    freeze() if args.freeze else replay()


if __name__ == "__main__":
    main()
