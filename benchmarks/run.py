"""Benchmark runner: one section per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.

    PYTHONPATH=src python -m benchmarks.run
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main() -> None:
    from benchmarks import paper_tables, roofline

    print("name,us_per_call,derived")

    # --- Fig. 10: SOTA comparison (comm-model, IC1..IC4 x M1..M4) ----------
    for ic, m, d1, d2, t_atp, t_meg, gain, plan_js in paper_tables.fig10_sota():
        print(f"fig10/{ic}/{m},{t_atp*1e3:.1f},mesh=({d1}x{d2});"
              f"megatron_ms={t_meg:.2f};gain_pct={gain:.1f};plan={plan_js}")

    # --- Table 3: chunk-based overlapping (measured on host mesh) ----------
    base = None
    for chunks, us in paper_tables.table3_overlap():
        base = base or us
        print(f"table3/chunks={chunks},{us:.1f},rel={us/base:.3f}")

    # --- Fig. 11: device-mesh sweep ----------------------------------------
    for ic, d1, d2, t in paper_tables.fig11_mesh_sweep():
        print(f"fig11/{ic}/mesh{d1}x{d2},{t*1e3:.1f},t_comm_ms={t:.2f}")

    # --- Fig. 12: scaling ---------------------------------------------------
    for ic, n, d1, d2, t_opt, t_meg in paper_tables.fig12_scaling():
        print(f"fig12/{ic}/n={n},{t_opt*1e3:.1f},best=({d1}x{d2});"
              f"megatron_ms={t_meg:.2f}")

    # --- Roofline summary (from the dry-run artifacts, if present) ---------
    try:
        cells = roofline.load_cells()
        for rec in cells:
            if rec.get("status") != "ok":
                continue
            a = roofline.analyze(rec)
            print(f"roofline/{rec['arch']}/{rec['shape']},"
                  f"{a['step_lower_bound_s']*1e6:.0f},"
                  f"dom={a['dominant']};frac={a['roofline_fraction']:.2f};"
                  f"useful={a['useful_ratio']:.2f}")
    except Exception as e:  # dry-run artifacts are optional for the bench
        print(f"roofline/unavailable,0,{type(e).__name__}")

    # every row's chosen ParallelPlan, as one auditable artifact
    path = paper_tables.write_plan_log()
    print(f"plans/artifact,0,{path}")


if __name__ == "__main__":
    main()
