"""Quantized wire + quantized pages benchmark.  Writes BENCH_quant.json.

    PYTHONPATH=src python -m benchmarks.quant_bench

Three sections:
  - wire: boundary-collective bytes per layer under bf16 vs int8 pricing
    (the cost-model volumes the search ranks with) and the pinned ic1
    mesh flip — quantization changes the chosen (d1, d2), not just the
    byte count;
  - pages: paged-cache pool bytes at identical geometry, bf16 pool vs
    int8 pool + fp16 per-position scales (>= 1.8x required);
  - serve: the paged continuous server on a mixed-length workload with
    full-width vs int8 vs fp8 page pools — greedy tokens must match the
    full-width pool EXACTLY on this pinned workload, tokens/sec recorded
    (host-CPU numbers validate plumbing cost, not accelerator bandwidth).
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_quant.json")
SERVE_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_serve.json")

SLOTS = 4
MAX_NEW = 8
CHUNK = 8
PAGE = 8
#: pinned workload (prompt seed 14): greedy argmax decisions on this
#: trace keep a margin above the ~0.025-logit quantization perturbation,
#: so int8 AND fp8 pools reproduce the full-width tokens exactly.  Near-
#: tie prompts exist (see tests/test_quant.py's margin-filtered parity);
#: this workload pins an end-to-end-exact one.
PROMPT_SEED = 14
PROMPT_LENS = [6, 22, 9, 12]


def wire_section() -> dict:
    """Cost-model wire bytes + the pinned ic1 mesh flip."""
    from repro.configs.registry import get_config
    from repro.core import comm_matrix as cm
    from repro.core.cost_model import LayerCommProfile, wire_bytes_per_elem
    from repro.core.search import search_strategy_overlap

    cfg = get_config("llama3-8b")
    prof = LayerCommProfile.dense(cfg)
    batch, seq = 4, 2048
    # boundary elements per layer on the full-width winner (8, 1): only
    # the row family is collective (d2=1 drops the column all-reduces)
    d1, d2 = 8, 1
    elems = batch * seq * (
        (prof.col_first_out / d1 if d2 > 1 else 0.0)
        + (prof.row_first_out / d2 if d1 > 1 else 0.0))
    full_bytes = elems * wire_bytes_per_elem("bf16", 2)
    quant_bytes = elems * wire_bytes_per_elem("int8", 2)
    ratio = full_bytes / quant_bytes

    m = cm.ic1_pcie_8gpu()
    kw = dict(layers=cfg.num_layers, batch=batch, seq=seq, profile=prof)
    full = search_strategy_overlap(m, 8, **kw)
    quant = search_strategy_overlap(m, 8, wire_dtype="int8", **kw)
    return {
        "workload": {"arch": "llama3-8b", "batch": batch, "seq": seq,
                     "preset": "ic1"},
        "boundary_elems_per_layer": int(elems),
        "wire_bytes_per_layer_bf16": int(full_bytes),
        "wire_bytes_per_layer_int8": int(quant_bytes),
        "wire_bytes_ratio": round(ratio, 3),
        "mesh_bf16": [full.best.d1, full.best.d2],
        "mesh_int8": [quant.best.d1, quant.best.d2],
        "t_exposed_bf16_s": round(full.best.t_exposed, 5),
        "t_exposed_int8_s": round(quant.best.t_exposed, 5),
        "search_flips_mesh": (full.best.d1, full.best.d2)
                             != (quant.best.d1, quant.best.d2),
    }


def pages_section(cfg) -> dict:
    """Pool bytes at identical geometry: bf16 vs int8 (+fp16 scales)."""
    import jax

    from repro.core.atp import make_context
    from repro.core.mesh import MeshTopo
    from repro.models import lm
    from repro.models.paging import PagedConfig

    ctx = make_context(MeshTopo((("data", 1),)))
    geom = dict(page_size=PAGE, num_pages=32, pages_per_slot=8)

    def nbytes(page_dtype):
        pcfg = PagedConfig(page_dtype=page_dtype, **geom)
        caches, _ = lm.init_paged_caches(cfg, ctx, pcfg, abstract=True)
        return int(sum(x.size * x.dtype.itemsize
                       for x in jax.tree.leaves(caches)))

    full, quant = nbytes("bf16"), nbytes("int8")
    return {
        "geometry": geom,
        "pool_bytes_bf16": full,
        "pool_bytes_int8": quant,
        "pool_bytes_ratio": round(full / quant, 3),
    }


def serve_section(cfg, params, prompts) -> dict:
    """Paged server, identical workload, three page dtypes."""
    from repro.core.mesh import atp_topo
    from repro.launch.serve import make_paged_server
    from repro.models.paging import PagedConfig
    from repro.runtime.server import Request, ServerConfig

    topo = atp_topo(1, 2, 2)
    pool = 1 + sum(-(-(len(p) + MAX_NEW) // PAGE) for p in prompts)
    runs = {}
    for page_dtype in ("bf16", "int8", "fp8"):
        pcfg = PagedConfig(page_size=PAGE, num_pages=pool,
                           pages_per_slot=-(-(max(PROMPT_LENS) + MAX_NEW)
                                            // PAGE),
                           page_dtype=page_dtype)
        scfg = ServerConfig(batch_slots=SLOTS, prefill_chunk=CHUNK,
                            paged=pcfg)

        def fresh():
            server, _ = make_paged_server(cfg, scfg, params, topo=topo)
            for rid, p in enumerate(prompts):
                server.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
            return server

        fresh().run_until_drained()          # compile both step shapes
        server = fresh()
        t0 = time.perf_counter()
        server.run_until_drained()
        wall = time.perf_counter() - t0
        outs = [r.out for r in sorted(server.completed, key=lambda r: r.rid)]
        stats = server.stats()
        runs[page_dtype] = {
            "wall_s": round(wall, 4),
            "tokens_per_s": round(MAX_NEW * len(prompts) / wall, 2),
            "cache_bytes": stats["cache_bytes"],
            "outs": outs,
        }

    for wd in ("int8", "fp8"):
        assert runs[wd]["outs"] == runs["bf16"]["outs"], (
            f"{wd} pool diverges from full width on the pinned workload:\n"
            f"{runs[wd]['outs']}\nvs\n{runs['bf16']['outs']}")
    out = {
        "workload": {"prompt_lens": PROMPT_LENS, "prompt_seed": PROMPT_SEED,
                     "max_new": MAX_NEW, "slots": SLOTS,
                     "prefill_chunk": CHUNK, "mesh": [2, 2]},
        "greedy_parity": True,
        "cache_bytes_ratio_int8": round(runs["bf16"]["cache_bytes"]
                                        / runs["int8"]["cache_bytes"], 3),
    }
    for wd, r in runs.items():
        r.pop("outs")
        out[wd] = r
    return out


def main() -> None:
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_config
    from repro.models import lm

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(PROMPT_SEED)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in PROMPT_LENS]

    wire = wire_section()
    pages = pages_section(cfg)
    serve = serve_section(cfg, params, prompts)

    baseline_tps = None
    if os.path.exists(SERVE_BASELINE):
        with open(SERVE_BASELINE) as fh:
            baseline_tps = json.load(fh)["paged"].get("tokens_per_s")

    summary = {
        "wire_bytes_ratio": wire["wire_bytes_ratio"],
        "pool_bytes_ratio": pages["pool_bytes_ratio"],
        "search_flips_mesh": wire["search_flips_mesh"],
        "greedy_parity": serve["greedy_parity"],
        "tokens_per_s": {wd: serve[wd]["tokens_per_s"]
                         for wd in ("bf16", "int8", "fp8")},
        "pr5_paged_tokens_per_s": baseline_tps,
    }
    assert summary["wire_bytes_ratio"] >= 1.8, summary
    assert summary["pool_bytes_ratio"] >= 1.8, summary
    assert summary["search_flips_mesh"], "ic1 flip pin regressed"

    payload = {
        "bench": "quant",
        "arch": "qwen1.5-0.5b (reduced) / llama3-8b (modeled)",
        "wire": wire,
        "pages": pages,
        "serve": serve,
        "summary": summary,
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"summary: {json.dumps(summary)}")
    print(f"wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    import jax  # noqa: E402  (after XLA_FLAGS)

    main()
