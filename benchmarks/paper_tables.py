"""Reproductions of the paper's tables/figures (one function per artifact).

Fig. 10  SOTA comparison      — ATP vs Megatron(=ATP-1) vs 2D-TP, per IC1-4
Table 3  chunk overlapping    — measured CPU wall time of the chunked MLP
Fig. 11  device-mesh sweep    — T_comm of ATP-1/2/4(/8) per interconnect
Fig. 12  scaling theory       — T_comm vs N on IC5/IC6 (decreasing for ATP)

The GPU interconnects are evaluated through the hierarchical-comm-matrix
model (the paper's own §3.5 machinery; DESIGN.md §9: our measured axis is
the TPU dry-run).  Fig. 10's "improvement over Megatron-LM" compares
T_comm of the ATP-selected mesh vs DeviceMesh(N,1); compute time is
strategy-invariant, so comm-time ratios bound the end-to-end gain.
"""
from __future__ import annotations

import json
import os
import time

from repro.configs.registry import PAPER_MODELS
from repro.core import comm_matrix as cm
from repro.core.calibrate import CalibrationTable
from repro.core.cost_model import LayerCommProfile, t_comm
from repro.core.mesh import factorizations
from repro.core.plan import plan_search
from repro.core.search import search_strategy

BATCH, SEQ = 4, 2048  # paper defaults

#: every emitted table row's chosen plan, keyed "artifact/ic/model" —
#: flushed to BENCH_paper_plans.json so the numbers are reproducible
PLAN_LOG: dict[str, dict] = {}


def _profile(m):
    return LayerCommProfile.gpt(m.d_model)


def _log_plan(key: str, plan) -> str:
    """Record the full plan JSON behind a table row (keyed by the row name);
    returns a compact comma-free tag safe for the CSV ``derived`` column.
    v2 plans carry per-segment knobs; the tag appends them when they
    differ from a single homogeneous segment."""
    PLAN_LOG[key] = plan.to_dict()
    sp = "+sp" if plan.seq_parallel else ""
    tag = (f"{plan.d1}x{plan.d2}ck{plan.chunks}"
           f"{plan.boundary_mode}{sp}")
    if len(plan.segments) > 1:
        tag += "[" + ";".join(s.describe() for s in plan.segments) + "]"
    return tag


def write_plan_log(path: str | None = None) -> str:
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_paper_plans.json")
    with open(path, "w") as f:
        json.dump(PLAN_LOG, f, indent=1, sort_keys=True)
    return os.path.abspath(path)


def fig10_sota(rows=None):
    """ATP strategy vs Megatron (ATP-1) comm time per interconnect/model.

    Both search paths see the measured-calibration table (paper §5.3):
    the Eq. 2 ranking produces the headline numbers and the overlap-aware
    ``plan_search`` (same calibration) records the executable plan per row.
    """
    ics = {
        "IC1(PCIe)": (cm.ic1_pcie_8gpu(), 8,
                      {(2, 4): (1.20, 4.95), (8, 1): (0.97, 0.97),
                       (4, 2): (1.10, 2.5), (1, 8): (0.97, 0.97)}),
        "IC2(dualNVL)": (cm.ic2_dual_nvlink_8gpu(), 8, None),
        "IC3(NVSwitch)": (cm.ic3_nvswitch_8gpu(), 8, None),
        "IC4(IB)": (cm.ic4_ib_cluster_16gpu(), 16, None),
    }
    out = []
    for ic_name, (matrix, n, calib) in ics.items():
        table = (CalibrationTable.from_pairs(calib, source="paper-measured")
                 if calib else None)
        for mname, mcfg in PAPER_MODELS.items():
            r = search_strategy(matrix, n, layers=mcfg.num_layers,
                                batch=BATCH, seq=SEQ, profile=_profile(mcfg),
                                calibration=table)
            t_meg = next(c.t_comm for c in r.ranked if (c.d1, c.d2) == (n, 1))
            best = r.best
            gain = (t_meg - best.t_comm) / max(t_meg, 1e-12)
            plan = plan_search(matrix, n, model=mcfg, batch=BATCH,
                               seq=SEQ, calibration=table).best
            out.append((ic_name, mname, best.d1, best.d2,
                        best.t_comm * 1e3, t_meg * 1e3, 100 * gain,
                        _log_plan(f"fig10/{ic_name}/{mname}", plan)))
    return out


def table3_overlap():
    """Measured wall time of the chunked ATP MLP on the host mesh
    (chunk=1/2/4) — the mechanism of §4.1; on CPU the effect is the
    schedule's independence structure, reported as relative time."""
    import jax
    import jax.numpy as jnp
    from repro.core.compat import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.atp import atp_linear, make_context
    from repro.core.mesh import MeshTopo

    n = min(8, len(jax.devices()))
    topo = MeshTopo((("tp1", max(1, n // 4)), ("tp2", min(4, n))))
    topo = MeshTopo((("tp1", 2), ("tp2", 2))) if n >= 4 else MeshTopo((("tp1", 1),))
    mesh = topo.build(jax.devices()[: topo.size])
    X = jax.random.normal(jax.random.PRNGKey(0), (64, 512))
    A = jax.random.normal(jax.random.PRNGKey(1), (512, 1024)) * 0.05
    B = jax.random.normal(jax.random.PRNGKey(2), (1024, 512)) * 0.05
    rows = []
    for chunks in (1, 2, 4):
        ctx = make_context(topo, chunks=chunks)

        def mlp(x, a, b):
            y = jax.nn.gelu(atp_linear(ctx, x, a, kind="col"))
            return atp_linear(ctx, y, b, kind="row")

        f = jax.jit(shard_map(mlp, mesh=mesh,
                              in_specs=(P(None, "tp2"), P("tp2", "tp1"),
                                        P("tp1", "tp2")),
                              out_specs=P(None, "tp2"), check_vma=True))
        f(X, A, B).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            out = f(X, A, B)
        out.block_until_ready()
        rows.append((chunks, (time.perf_counter() - t0) / 20 * 1e6))
    return rows


def fig11_mesh_sweep():
    """T_comm of every DeviceMesh(N/i, i) per interconnect (paper Fig.11).

    The calibration table reaches both rankings; each interconnect's
    overlap-searched winning plan lands in the PLAN_LOG artifact.
    """
    ics = {
        "IC1(PCIe,calib)": (cm.ic1_pcie_8gpu(), 8,
                            {(2, 4): (1.20, 4.95), (8, 1): (0.97, 0.97)}),
        "IC2(dualNVL)": (cm.ic2_dual_nvlink_8gpu(), 8, None),
        "IC3(NVSwitch)": (cm.ic3_nvswitch_8gpu(), 8, None),
        "IC4(IB)": (cm.ic4_ib_cluster_16gpu(), 16, None),
        "TPUv5e-row": (cm.tpu_v5e_pod(), 16, None),
    }
    m = PAPER_MODELS["gpt-m3"]
    out = []
    for ic_name, (matrix, n, calib) in ics.items():
        table = (CalibrationTable.from_pairs(calib, source="paper-measured")
                 if calib else None)
        r = search_strategy(matrix, n, layers=m.num_layers, batch=BATCH,
                            seq=SEQ, profile=_profile(m), calibration=table)
        plan = plan_search(matrix, n, model=m, batch=BATCH, seq=SEQ,
                           calibration=table).best
        _log_plan(f"fig11/{ic_name}", plan)
        for c in r.ranked:
            out.append((ic_name, c.d1, c.d2, c.t_comm * 1e3))
    return out


def fig12_scaling():
    """T_comm vs device count on IC5/IC6 (paper: decreasing for ATP-opt)."""
    m = PAPER_MODELS["gpt-m3"]
    out = []
    for n in (4, 8, 16, 32, 64, 128):
        matrices = [("IC5", cm.ic5_nvlink_network(n))]
        side = int(round(n ** 0.5))
        if side * side == n:
            matrices.append(("IC6", cm.ic6_torus_2d(side)))
        for ic_name, matrix in matrices:
            try:
                r = search_strategy(matrix, n, layers=m.num_layers,
                                    batch=BATCH, seq=SEQ, profile=_profile(m))
            except ValueError:
                continue
            meg = next((c.t_comm for c in r.ranked if (c.d1, c.d2) == (n, 1)),
                       None)
            out.append((ic_name, n, r.best.d1, r.best.d2,
                        r.best.t_comm * 1e3,
                        meg * 1e3 if meg else float("nan")))
    return out
