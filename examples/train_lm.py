"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on an ATP DeviceMesh(2,2) x DP(2), with ZeRO-1, checkpointing, and the
deterministic data pipeline (deliverable b).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cost_model import LayerCommProfile
from repro.core.plan import plan_search
from repro.data.pipeline import DataConfig, TokenSource
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

# ~100M-param config (deliverable b); --small swaps in a CPU-quick ~24M one
CFG_100M = ModelConfig(
    name="demo-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32000, head_dim=64,
    dtype="float32",
)
CFG_SMALL = ModelConfig(
    name="demo-24m", family="dense", num_layers=8, d_model=256,
    num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=32000, head_dim=32,
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--small", action="store_true",
                    help="CPU-quick ~24M config instead of the ~100M one")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    global CFG
    CFG = CFG_SMALL if args.small else CFG_100M
    if args.ckpt_dir is None:
        args.ckpt_dir = f"/tmp/repro_train_lm_{CFG.name}"

    # the searched ParallelPlan is the one strategy artifact: ranked on the
    # v5e comm model for this workload, then handed to the step builder
    plan = plan_search(
        "v5e", 4, layers=CFG.num_layers, batch=args.batch, seq=args.seq,
        profile=LayerCommProfile.gpt(CFG.d_model), dp=2,
        chunks_options=(1,), seq_parallel_options=(False,)).best
    topo = plan.topo()
    mesh = topo.build()
    ctx = plan.context(topo)
    print(f"params: {CFG.param_count()/1e6:.1f}M  mesh: {topo.shape} "
          f"{topo.names}  plan: {plan.describe()}")

    opt_cfg = adamw.AdamWConfig(lr=1e-3, mode="zero1", warmup_steps=20,
                                total_steps=args.steps)
    step_fn, info = build_train_step(CFG, topo, opt_cfg, mesh=mesh, plan=plan)
    source = TokenSource(DataConfig(vocab_size=CFG.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))

    def init_state():
        params = lm.init_params(CFG, jax.random.PRNGKey(0), jnp.float32)
        opt = adamw.init_opt_state(params, info.pspecs, ctx, "zero1")
        return (jax.device_put(params, info.sharding(info.pspecs)),
                jax.device_put(opt, info.sharding(info.ospecs)))

    def put_batch(host_batch):
        return jax.device_put({k: jnp.asarray(v) for k, v in host_batch.items()},
                              info.sharding(info.bspecs))

    t0 = time.time()
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=100, log_every=20),
        build_step=lambda: step_fn, source=source,
        init_state=init_state, put_batch=put_batch)
    import logging
    logging.basicConfig(level=logging.INFO)
    trainer.run()
    losses = [h["loss"] for h in trainer.history]
    if not losses:
        print("nothing to do: checkpoint already at final step "
              f"(rm -r {args.ckpt_dir} to restart)")
        return
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps "
          f"({time.time()-t0:.0f}s)")
    if len(losses) >= 50:
        assert losses[-1] < losses[0], "training should reduce the loss"


if __name__ == "__main__":
    main()
