"""Serve a small model with batched requests: wave-batched prefill-into-
cache + lockstep greedy decode on an ATP mesh (deliverable b).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/serve_lm.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.mesh import atp_topo
from repro.launch.serve import serve
from repro.models import lm


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    topo = atp_topo(dp=1, d1=2, d2=2)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
               for _ in range(4)]
    outs = serve(cfg, topo, params, prompts, max_new=8, max_seq=32)
    print("generated (greedy):")
    for i, o in enumerate(outs):
        print(f"  request {i}: {o.tolist()}")
    assert outs.shape == (4, 8)
    assert (outs >= 0).all() and (outs < cfg.vocab_size).all()
    print("OK")


if __name__ == "__main__":
    main()
