"""Quickstart: the paper's Figure-9 API in JAX.

The paper's snippet:
    mesh = init_mesh(ndevice=4, mesh_shape=(2, 2))
    fc1 = ATPLinear(in_dim, out_dim, mesh, strategy="col")

Here: build a DeviceMesh(2,2), shard a two-layer MLP with column- and
row-first tensor parallelism, and verify against the dense computation.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.core.atp import atp_linear, make_context
from repro.core.mesh import MeshTopo


def main():
    # DeviceMesh(2, 2): d1 = d2 = 2 (the paper's Figure 4/9 example)
    topo = MeshTopo((("tp1", 2), ("tp2", 2)))
    mesh = topo.build()
    ctx = make_context(topo)
    print(f"device mesh: {topo.shape} axes={topo.names} "
          f"(d1={ctx.d1}, d2={ctx.d2})")

    in_dim, hidden, out_dim, batch = 16, 32, 16, 8
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (batch, in_dim))
    w1 = jax.random.normal(jax.random.PRNGKey(1), (in_dim, hidden)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(2), (hidden, out_dim)) * 0.1

    def mlp(x, w1, w2):
        # column-first ATPLinear -> GeLU -> row-first ATPLinear (Fig. 6)
        y = jax.nn.gelu(atp_linear(ctx, x, w1, kind="col"))
        return atp_linear(ctx, y, w2, kind="row")

    f = shard_map(
        mlp, mesh=mesh,
        in_specs=(P(None, "tp2"),      # activations: [Replicate, Shard(1)]
                  P("tp2", "tp1"),     # W1: [Shard(1), Shard(0)] col-first
                  P("tp1", "tp2")),    # W2: [Shard(0), Shard(1)] row-first
        out_specs=P(None, "tp2"),
        check_vma=True)
    out = jax.jit(f)(x, w1, w2)
    ref = jax.nn.gelu(x @ w1) @ w2
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"ATP(2,2) output matches dense reference: max|err| = {err:.2e}")
    assert err < 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    print("OK")


if __name__ == "__main__":
    main()
