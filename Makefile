PYTHON ?= python
XLA_DEVICES ?= 8

# Tier-1 verify: the whole suite on a simulated multi-device host mesh.
.PHONY: test
test:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m pytest -x -q

.PHONY: bench-overlap
bench-overlap:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	$(PYTHON) -m benchmarks.overlap_bench

.PHONY: bench
bench:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	$(PYTHON) -m benchmarks.run
