PYTHON ?= python
XLA_DEVICES ?= 8

# Tier-1 verify: the whole suite on a simulated multi-device host mesh,
# then the plan-lifecycle smoke gate (search -> calibrate -> save -> load
# -> execute must agree bit-for-bit), the heterogeneous-segment gate
# (per-segment knobs reach execution on a mixed dense+MoE stack), the
# elastic-restart gate (failure -> shrink -> recalibrate -> re-search ->
# resharded restore -> loss continuity), the serving gate (decode-
# searched plan -> paged continuous batching -> wave-loop token parity),
# the chaos gate (scripted fault scenarios: membership quorum, deadline
# budget, server degradation, remesh parity, torn checkpoints), the
# plan-conformance lint (every searched plan's built step must emit
# exactly the collectives the cost model priced) and the bench-baseline
# replay (checked-in BENCH_*.json metrics must not regress >10%).
.PHONY: test
test:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m pytest -x -q
	$(MAKE) plan-smoke
	$(MAKE) segment-smoke
	$(MAKE) elastic-smoke
	$(MAKE) serve-smoke
	$(MAKE) chaos-smoke
	$(MAKE) lint-plans
	$(MAKE) bench-regress

# Static plan-conformance sweep: config zoo x topology presets x
# {train, prefill, decode} x {bf16, int8, fp8}, each searched plan's
# build checked for collective conformance + proven out_spec
# replication, plus the jaxpr-vs-HLO byte cross-check per preset.
# Narrow with LINT_ARGS="--configs llama3-8b --presets ic1".
.PHONY: lint-plans
lint-plans:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m repro.analysis.lint --hlo-check $(LINT_ARGS)

.PHONY: plan-smoke
plan-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m repro.launch.plan_smoke

.PHONY: segment-smoke
segment-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m repro.launch.segment_smoke

.PHONY: elastic-smoke
elastic-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m repro.launch.elastic_smoke

# Chaos gate: five seeded fault scenarios through the production hooks
# (membership-elastic shrink under lease delay, deadline-budgeted
# recalibration, server degradation ladder, decode-mesh remesh parity,
# torn checkpoint writes); writes BENCH_chaos.json for bench-regress.
.PHONY: chaos-smoke
chaos-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m repro.launch.chaos_smoke

.PHONY: serve-smoke
serve-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m repro.launch.serve_smoke

.PHONY: bench-serve
bench-serve:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m benchmarks.serve_bench

.PHONY: bench-overlap
bench-overlap:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	$(PYTHON) -m benchmarks.overlap_bench

.PHONY: bench-quant
bench-quant:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m benchmarks.quant_bench

# Replay the checked-in bench baselines (benchmarks/baselines.json)
# against whatever BENCH_*.json artifacts exist; >10% regression on a
# tracked ratio or any flipped invariant fails.  Re-pin with
#   PYTHONPATH=src $(PYTHON) -m benchmarks.bench_regress --freeze
.PHONY: bench-regress
bench-regress:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m benchmarks.bench_regress

.PHONY: bench
bench:
	XLA_FLAGS=--xla_force_host_platform_device_count=$(XLA_DEVICES) \
	$(PYTHON) -m benchmarks.run
