"""Dense transformer block with ATP row/column-first tensor parallelism.

Per-block communication schedule (paper Fig. 6):
  f1: psum(ax2) after the column-first q/k/v projections
  f2: psum(ax1) after the row-first output projection
  f3: psum(ax2) after the column-first MLP up(+gate) projection
  f4: psum(ax1) after the row-first MLP down projection
plus the core scatter (free slice) / gather (all-gather over ax2).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.atp import (ATPContext, atp_boundary, atp_linear, grad_sync,
                            shard_slice)
from repro.models import layers as L
from repro.models import paging


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def attn_params(key, cfg: ModelConfig, dtype) -> dict[str, Any]:
    h, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(h)
    p = {
        "wq": _init(ks[0], (h, qd), s, dtype),
        "wk": _init(ks[1], (h, kvd), s, dtype),
        "wv": _init(ks[2], (h, kvd), s, dtype),
        "wo": _init(ks[3], (qd, h), 1.0 / math.sqrt(qd), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.hd,), jnp.float32)
    return p


def attn_param_specs(ctx: ATPContext, cfg: ModelConfig) -> dict[str, Any]:
    sp = {
        "wq": L.col_w_spec(ctx), "wk": L.col_w_spec(ctx), "wv": L.col_w_spec(ctx),
        "wo": L.row_w_spec(ctx),
    }
    if cfg.qkv_bias:
        sp["bq"] = L.col_b_spec(ctx)
        sp["bk"] = L.col_b_spec(ctx)
        sp["bv"] = L.col_b_spec(ctx)
    if cfg.qk_norm:
        sp["q_norm"] = L.replicated_spec()
        sp["k_norm"] = L.replicated_spec()
    return sp


def mlp_params(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict[str, Any]:
    h = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(h)
    p = {"w_up": _init(ks[0], (h, ff), s, dtype),
         "w_down": _init(ks[1], (ff, h), 1.0 / math.sqrt(ff), dtype)}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["w_gate"] = _init(ks[2], (h, ff), s, dtype)
    return p


def mlp_param_specs(ctx: ATPContext, cfg: ModelConfig) -> dict[str, Any]:
    sp = {"w_up": L.col_w_spec(ctx), "w_down": L.row_w_spec(ctx)}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        sp["w_gate"] = L.col_w_spec(ctx)
    return sp


def mlp_block(ctx: ATPContext, cfg: ModelConfig, p, x):
    """Feed-forward with column-first up(+gate), row-first down (f3/f4)."""
    if cfg.mlp_kind in ("swiglu", "geglu"):
        # fuse up+gate into one column-first GEMM + single f3 boundary
        w_cat = jnp.concatenate([p["w_up"], p["w_gate"]], axis=1)
        ug = atp_linear(ctx, x, w_cat, kind="col")
        u, g = jnp.split(ug, 2, axis=-1)
        act = jax.nn.silu(g) if cfg.mlp_kind == "swiglu" else jax.nn.gelu(g, approximate=True)
        y = u * act
    else:
        y = jax.nn.gelu(atp_linear(ctx, x, p["w_up"], kind="col"), approximate=True)
    return atp_linear(ctx, y, p["w_down"], kind="row")


def _qk_norm(q, gamma, eps):
    qf = q.astype(jnp.float32)
    inv = lax.rsqrt(jnp.mean(qf * qf, axis=-1, keepdims=True) + eps)
    return (qf * inv * gamma).astype(q.dtype)


def attn_block(
    ctx: ATPContext,
    cfg: ModelConfig,
    p,
    x,                      # [b, s, h/d2]
    positions,              # [b, s] (or [3, b, s] for M-RoPE)
    plan: L.AttnPlan,
    layer_window: int = 0,  # sliding window for this layer (0 = global)
    cache=None,             # decode: dict(k=[b,S,kvb,hd], v=..., len=scalar)
                            # or paged pools dict(k=[np,pg,kvb,hd], v=...)
    paged=None,             # paged serving: dict(table=[b,mp], start=[b])
):
    """Returns (attn output [b, s, h/d2], new_cache)."""
    # f1: column-first q/k/v projections, one fused boundary psum(ax2)
    parts = jnp.concatenate([p["wq"], p["wk"], p["wv"]], axis=1)
    qkv = atp_boundary(jnp.einsum("...k,kn->...n", x, parts), ctx.ax2)
    d1 = ctx.d1
    qd, kvd = cfg.q_dim // d1, cfg.kv_dim // d1
    qp, kp, vp = (qkv[..., :qd], qkv[..., qd:qd + kvd], qkv[..., qd + kvd:])
    if cfg.qkv_bias:
        # bias shards are ax2-replicated (P(ax1)) but consumed by the
        # rank-local head/seq split below, so their cotangent is ax2-partial
        qp = qp + grad_sync(ctx, p["bq"], ctx.ax2)
        kp = kp + grad_sync(ctx, p["bk"], ctx.ax2)
        vp = vp + grad_sync(ctx, p["bv"], ctx.ax2)

    q, k, v, bid, rid = L.split_qkv_heads(ctx, cfg, qp, kp, vp, plan)

    if cfg.qk_norm:
        # per-head norm gains see only the rank-local heads' cotangent
        q = _qk_norm(q, grad_sync(ctx, p["q_norm"], ctx.tp_axes), cfg.norm_eps)
        k = _qk_norm(k, grad_sync(ctx, p["k_norm"], ctx.tp_axes), cfg.norm_eps)

    decode = cache is not None
    sq_offset = 0
    if not decode and plan.r > 1:
        # seq-split the q rows over the r leftover ranks (k/v keep full seq)
        s_r = q.shape[1] // plan.r
        q = lax.dynamic_slice_in_dim(q, rid * s_r, s_r, axis=1)
        sq_offset = rid * s_r

    if cfg.use_rope or cfg.mrope_sections:
        if cfg.mrope_sections:
            qpos = (lax.dynamic_slice_in_dim(positions, sq_offset, q.shape[1], axis=2)
                    if not decode else positions)
            q = L.apply_mrope(q, qpos, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            qpos = (lax.dynamic_slice_in_dim(positions, sq_offset, q.shape[1], axis=1)
                    if not decode else positions)
            q = L.apply_rope(q, qpos, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if decode and paged is not None:
        # paged serving: scatter this run's k/v through the slot page
        # tables, then attend over each slot's MAPPED pages only (per-slot
        # positions; garbage-page reads are masked by start + s)
        table, start = paged["table"], paged["start"]
        pd = paging.pool_page_dtype(cache["k"])
        ck, cks = paging.append_tokens_q(cache["k"], cache.get("k_scale"),
                                         table, start, k, pd)
        cv, cvs = paging.append_tokens_q(cache["v"], cache.get("v_scale"),
                                         table, start, v, pd)
        new_cache = {"k": ck, "v": cv}
        if cks is not None:
            new_cache["k_scale"], new_cache["v_scale"] = cks, cvs
        kk = paging.gather_pages_q(ck, cks, table, out_dtype=k.dtype)
        vv = paging.gather_pages_q(cv, cvs, table, out_dtype=v.dtype)
        o = L.attention_core(cfg, q, kk, vv, q_offset=start,
                             kv_len=start + q.shape[1], window=layer_window)
    elif decode:
        # append this step's k/v at cache['len'] (s >= 1: also serves as
        # prefill-into-cache for the serving loop)
        klen = cache["len"]
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), klen, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), klen, axis=1)
        new_cache = {"k": ck, "v": cv, "len": klen + q.shape[1]}
        o = L.attention_core(cfg, q, ck, cv, q_offset=klen,
                             kv_len=klen + q.shape[1], window=layer_window)
    else:
        o = L.attention_core(cfg, q, k, v, q_offset=sq_offset, window=layer_window)

    o = L.core_output_gather(ctx, cfg, o, plan, seq_split=not decode)
    # f2: row-first output projection, boundary psum(ax1)
    out = atp_linear(ctx, o, p["wo"], kind="row")
    return out, new_cache


def dense_block_params(key, cfg: ModelConfig, dtype, d_ff: int | None = None):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # norm params are created at GLOBAL size; sharded by spec
    p = {
        "ln_attn": L.norm_params(cfg, cfg.d_model),
        "attn": attn_params(k1, cfg, dtype),
        "ln_mlp": L.norm_params(cfg, cfg.d_model),
        "mlp": mlp_params(k2, cfg, dtype, d_ff),
    }
    if cfg.post_block_norms:
        p["ln_post_attn"] = L.norm_params(cfg, cfg.d_model)
        p["ln_post_mlp"] = L.norm_params(cfg, cfg.d_model)
    return p


def dense_block_specs(ctx: ATPContext, cfg: ModelConfig):
    nspec = {"scale": L.feat_spec(ctx)}
    if cfg.norm_kind == "layernorm":
        nspec = {"scale": L.feat_spec(ctx), "bias": L.feat_spec(ctx)}
    sp = {
        "ln_attn": dict(nspec),
        "attn": attn_param_specs(ctx, cfg),
        "ln_mlp": dict(nspec),
        "mlp": mlp_param_specs(ctx, cfg),
    }
    if cfg.post_block_norms:
        sp["ln_post_attn"] = dict(nspec)
        sp["ln_post_mlp"] = dict(nspec)
    return sp


def dense_block(
    ctx: ATPContext, cfg: ModelConfig, p, x, positions, plan,
    layer_window: int = 0, cache=None, paged=None,
):
    """With ``ctx.seq_parallel`` the residual stream x is seq-sharded over
    ax1: the entry norms fold the all-gather to full sequence, and the
    row-first output projections (f2/f4) psum_scatter back — post-block
    norms and residual adds stay in the seq-sharded domain."""
    sp = ctx.seq_parallel and cache is None
    h = L.norm(ctx, cfg, x, p["ln_attn"], gather_seq=sp)
    a, new_cache = attn_block(ctx, cfg, p["attn"], h, positions, plan,
                              layer_window=layer_window, cache=cache,
                              paged=paged)
    if cfg.post_block_norms:
        a = L.norm(ctx, cfg, a, p["ln_post_attn"])
    x = x + a
    h = L.norm(ctx, cfg, x, p["ln_mlp"], gather_seq=sp)
    m = mlp_block(ctx, cfg, p["mlp"], h)
    if cfg.post_block_norms:
        m = L.norm(ctx, cfg, m, p["ln_post_mlp"])
    return x + m, new_cache
