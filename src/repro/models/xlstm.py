"""xLSTM blocks (mLSTM + sLSTM), ATP-sharded.

mLSTM: matrix-memory recurrence C_t = f_t C_{t-1} + i_t v_t k_t^T with
readout h_t = (C_t q_t) / max(|n_t . q_t|, 1), implemented chunkwise (same
structure as the SSD scan: per-head scalar decay).

Sharding (v2 layout — the §Perf hillclimb result; v1 all-gathered the full
up-projection and re-gathered the output, making xlstm the most
collective-bound arch in the baseline table):
  - up/z projections: column-first with a (head-major, value-dim) column
    order, so each flat TP rank's natural column slice IS its
    (head-block, dv-slice) shard — no gather.
  - q/k (+ i/f gates): computed from the block input with a
    replicated-output projection (rows over ax2, psum(ax2)); every rank
    holds full per-head q/k (tiny: 2*nh*dk) and slices its head.
    v is the conv'd up-projection slice directly (as in official mLSTM).
  - down projection: rows are flat-sharded, so the boundary all-reduces
    over BOTH mesh dims at once ([b,s,h/d2] — same volume as f4).
  - conv is depthwise -> sharding-transparent on the local channel slice.

sLSTM: inherently sequential, small -> replicated across TP (documented
applicability boundary of the paper's technique), 1 block in 8.

Deviations from official xLSTM (documented in DESIGN.md): sigmoid input
gate (bounded; removes the max-stabilizer state); q/k projected from the
block input rather than the conv'd up-projection.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.atp import (ATPContext, atp_boundary, grad_sync,
                            shard_slice, vma_rewrite_active)
from repro.models import layers as L


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def mlstm_dims(cfg: ModelConfig):
    d_inner = int(cfg.ssm.proj_factor * cfg.d_model)
    nh = cfg.num_heads
    dv = d_inner // nh          # value/head dim
    dk = dv // 2                # query/key dim (official mLSTM uses dv/2)
    return d_inner, nh, dk, dv


def mlstm_plan(ctx: ATPContext, cfg: ModelConfig):
    """(head shard g, value-dim shard r): g*r == flat tp."""
    _, nh, _, dv = mlstm_dims(cfg)
    g = math.gcd(nh, ctx.tp)
    r = ctx.tp // g
    assert dv % r == 0, "mLSTM value dim must divide leftover TP factor"
    assert (nh // g) == 1 or r == 1, \
        "flat column slicing needs one head per block (or r == 1)"
    return g, r


def mlstm_params(key, cfg: ModelConfig, dtype) -> dict[str, Any]:
    h = cfg.d_model
    d_inner, nh, dk, dv = mlstm_dims(cfg)
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(h)
    return {
        "ln": jnp.ones((h,), jnp.float32),
        # columns ordered (head-major, dv): rank slice == (head, dv) shard
        "w_up": _init(ks[0], (h, d_inner), s, dtype),     # v path
        "w_z": _init(ks[1], (h, d_inner), s, dtype),      # output gate path
        "conv": _init(ks[2], (cfg.ssm.conv_kernel, d_inner), 0.5, jnp.float32),
        # q/k from the block input: column-first sharded over ax1, gathered
        # (small: 2*nh*dk == d_inner/1); i/f gates replicated-out (tiny)
        "w_qk": _init(ks[3], (h, 2 * nh * dk), s, dtype),
        "w_if": _init(jax.random.fold_in(ks[3], 1), (h, 2 * nh), s, dtype),
        "b_if": jnp.zeros((2 * nh,), jnp.float32),
        "w_down": _init(ks[4], (d_inner, h), 1.0 / math.sqrt(d_inner), dtype),
        "gn": jnp.ones((d_inner,), jnp.float32),
    }


def mlstm_param_specs(ctx: ATPContext, cfg: ModelConfig) -> dict[str, Any]:
    flat = ctx.tp_axes or None
    return {
        "ln": L.feat_spec(ctx),
        # columns over ax1; the ax2 sub-slice happens in-code (a spec may
        # not name tp2 on two dims), yielding the flat (head, dv) shard
        "w_up": L.col_w_spec(ctx),
        "w_z": L.col_w_spec(ctx),
        "conv": P(None, flat),
        "w_qk": L.col_w_spec(ctx),
        "w_if": P(ctx.ax2, None),     # replicated output (tiny)
        "b_if": L.replicated_spec(),
        "w_down": P(flat, None),      # rows flat-sharded, cols whole
        "gn": P(flat),
    }


def _mlstm_chunked(q, k, v, li, lf, chunk: int, state=None):
    """Chunkwise mLSTM.  q,k: [b,s,nh,dk]; v: [b,s,nh,dv];
    li/lf: [b,s,nh] log input/forget gates.  state: [b,nh,dk,dv+1].

    The normalizer n is folded in as an extra value channel of ones.
    Returns (h [b,s,nh,dv], state_out)."""
    b, s, nh, dk = q.shape
    dv = v.shape[-1]
    nc = max(1, s // chunk)
    cl = s // nc
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    va = jnp.concatenate([v, ones], axis=-1)                     # [b,s,nh,dv+1]

    qr = q.reshape(b, nc, cl, nh, dk).astype(jnp.float32)
    kr = k.reshape(b, nc, cl, nh, dk).astype(jnp.float32)
    vr = va.reshape(b, nc, cl, nh, dv + 1).astype(jnp.float32)
    lir = li.reshape(b, nc, cl, nh)
    lfr = lf.reshape(b, nc, cl, nh)

    lc = jnp.cumsum(lfr, axis=2)                                 # cumulative log f
    seg = lc[:, :, :, None, :] - lc[:, :, None, :, :]            # [b,nc,t,u,nh]
    causal = jnp.tril(jnp.ones((cl, cl), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    qk = jnp.einsum("bnthd,bnuhd->bntuh", qr, kr) / math.sqrt(dk)
    w = qk * decay * jnp.exp(lir)[:, :, None, :, :]
    h_intra = jnp.einsum("bntuh,bnuhe->bnthe", w, vr)

    dec_end = jnp.exp(lc[:, :, -1:, :] - lc + lir)               # [b,nc,cl,nh]
    S = jnp.einsum("bnuhd,bnuhe->bnhde", kr * dec_end[..., None], vr)
    gain = jnp.exp(lc[:, :, -1, :])

    def step(carry, inp):
        S_n, g_n = inp
        return carry * g_n[:, :, None, None] + S_n, carry

    Sm = jnp.moveaxis(S, 1, 0)
    # zeros_like keeps the vma type of S (varying over the right mesh axes)
    init = (jnp.zeros_like(Sm[0]) if state is None
            else state.astype(jnp.float32))
    state_out, entering = lax.scan(step, init, (Sm, jnp.moveaxis(gain, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)
    h_cross = jnp.einsum("bnthd,bnhde->bnthe", qr, entering) * \
        jnp.exp(lc)[..., None] / math.sqrt(dk)

    ha = (h_intra + h_cross).reshape(b, s, nh, dv + 1)
    num, den = ha[..., :dv], ha[..., dv:]
    out = num / jnp.maximum(jnp.abs(den), 1.0)
    return out.astype(q.dtype), state_out


def _mlstm_step(q, k, v, li, lf, state):
    """Decode step.  q,k: [b,1,nh,dk]; state [b,nh,dk,dv+1]."""
    b, _, nh, dk = q.shape
    dv = v.shape[-1]
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    va = jnp.concatenate([v, ones], -1)[:, 0].astype(jnp.float32)
    f = jnp.exp(lf[:, 0])[:, :, None, None]
    i = jnp.exp(li[:, 0])[:, :, None, None]
    new = state.astype(jnp.float32) * f + i * jnp.einsum(
        "bhd,bhe->bhde", k[:, 0].astype(jnp.float32), va)
    ha = jnp.einsum("bhd,bhde->bhe", q[:, 0].astype(jnp.float32), new) / math.sqrt(dk)
    num, den = ha[..., :dv], ha[..., dv:]
    out = (num / jnp.maximum(jnp.abs(den), 1.0))[:, None]
    return out.astype(q.dtype), new


def mlstm_block(ctx: ATPContext, cfg: ModelConfig, p, x, state=None):
    """x: [b, s, h/d2] -> (same spec, new_state).

    state (decode): dict(conv=[b,k-1,d_inner/n], C=[b,1,nh_loc,dk,dv_loc+1])."""
    d_inner, nh, dk, dv = mlstm_dims(cfg)
    g, r = mlstm_plan(ctx, cfg)
    flat = ctx.tp_index()
    hb = flat // r       # head block (nh_loc == 1 when r > 1)
    nh_loc = nh // g
    dv_loc = dv // r

    h_in = L.rms_norm(ctx, x, p["ln"], cfg.norm_eps)

    # up/z: column-first (ax1) + in-code ax2 sub-slice: with the head-major
    # (head, dv) column order, the flat slice i1*d2+i2 IS this rank's
    # (head-block, dv-slice) shard — no gather
    w_cat = jnp.concatenate([p["w_up"], p["w_z"]], axis=1)
    ug = atp_boundary(jnp.einsum("...k,kn->...n", h_in, w_cat), ctx.ax2)
    u_loc, z_loc = jnp.split(ug, 2, axis=-1)          # [b, s, d_inner/d1]
    if ctx.ax2 is not None:
        u_loc = shard_slice(u_loc, ctx.index2(), ctx.d2, dim=-1)
        z_loc = shard_slice(z_loc, ctx.index2(), ctx.d2, dim=-1)
    # u_loc/z_loc: [b, s, d_inner/n]

    # depthwise conv on the local channel slice (spec-sliced weights)
    cstate = state["conv"] if state is not None else None
    u_c, conv_ns = _conv_local(u_loc, p["conv"], cstate)
    v = jax.nn.silu(u_c).reshape(u_c.shape[0], u_c.shape[1], nh_loc, dv_loc)

    # q/k: column-first sharded over ax1, then a small all-gather (the qk
    # tensor is 2*nh*dk ~= d_model wide — ~8x less than the v1 full-u gather)
    qk = atp_boundary(jnp.einsum("...k,kn->...n", h_in, p["w_qk"]), ctx.ax2)
    if ctx.ax1 is not None:
        qk = lax.all_gather(qk, ctx.ax1, axis=-1, tiled=True)
    qf = qk[..., : nh * dk].reshape(*qk.shape[:2], nh, dk)
    kf = qk[..., nh * dk:].reshape(*qk.shape[:2], nh, dk)
    # i/f gates: replicated-output projection (tiny).  The gate cotangent
    # is rank-head-partial: w_if (ax1-replicated storage) needs the ax1
    # barrier after the boundary transpose's psum(ax2); b_if (fully
    # replicated, added past the boundary) needs the whole flat group.
    if_pre = atp_boundary(jnp.einsum("...k,kn->...n", h_in,
                                     grad_sync(ctx, p["w_if"], ctx.ax1)),
                          ctx.ax2).astype(jnp.float32) \
        + grad_sync(ctx, p["b_if"], ctx.tp_axes)
    li_all = jax.nn.log_sigmoid(if_pre[..., :nh])
    lf_all = jax.nn.log_sigmoid(if_pre[..., nh:])
    q = lax.dynamic_slice_in_dim(qf, hb * nh_loc, nh_loc, axis=2)
    k = lax.dynamic_slice_in_dim(kf, hb * nh_loc, nh_loc, axis=2)
    li = lax.dynamic_slice_in_dim(li_all, hb * nh_loc, nh_loc, axis=-1)
    lf = lax.dynamic_slice_in_dim(lf_all, hb * nh_loc, nh_loc, axis=-1)

    if state is None:
        y, _ = _mlstm_chunked(q, k, v, li, lf, cfg.ssm.chunk)
        new_state = None
    else:
        if q.shape[1] == 1:
            y, C_new = _mlstm_step(q, k, v, li, lf, state["C"][:, 0])
        else:  # prefill-into-state
            y, C_new = _mlstm_chunked(q, k, v, li, lf, cfg.ssm.chunk,
                                      state=state["C"][:, 0])
        new_state = {"conv": conv_ns,
                     "C": C_new[:, None].astype(state["C"].dtype)}

    gn = p["gn"].reshape(nh_loc, dv_loc)              # spec-sliced
    yf = y.astype(jnp.float32)
    inv = lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + 1e-6)
    y = (yf * inv * gn).astype(y.dtype)

    # output gate from the local z slice
    zh = z_loc.reshape(z_loc.shape[0], z_loc.shape[1], nh_loc, dv_loc)
    y = (y * jax.nn.silu(zh)).reshape(y.shape[0], y.shape[1], nh_loc * dv_loc)

    # down projection: rows flat-sharded (spec-sliced) -> one all-reduce
    # over both mesh dims, then the free ax2 feature slice.  (At d2>1 a
    # reduce-scatter(ax2)+psum(ax1) pair would halve the bytes — noted in
    # EXPERIMENTS §Perf; the production (16,1) baseline is already optimal.)
    out = atp_boundary(jnp.einsum("...k,kn->...n", y, p["w_down"]),
                       ctx.tp_axes if ctx.tp_axes else None)
    if ctx.ax2 is not None:
        out = shard_slice(out, ctx.index2(), ctx.d2, dim=-1)
    return x + out, new_state


def _conv_local(x, w, state=None):
    from repro.models.mamba2 import _causal_conv
    return _causal_conv(x, w, state)


# ---------------------------------------------------------------------------
# sLSTM (replicated across TP; sequential lax.scan over time).
# ---------------------------------------------------------------------------


def slstm_params(key, cfg: ModelConfig, dtype) -> dict[str, Any]:
    h = cfg.d_model
    nh = cfg.num_heads
    dh = h // nh
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(h)
    d_ff = int(1.3 * h)
    return {
        "ln": jnp.ones((h,), jnp.float32),
        "w_gates": _init(ks[0], (h, 4 * h), s, jnp.float32),      # z i f o
        "r_gates": _init(ks[1], (nh, dh, 4 * dh), 1 / math.sqrt(dh), jnp.float32),
        "b_gates": jnp.zeros((4 * h,), jnp.float32),
        "gn": jnp.ones((h,), jnp.float32),
        "w_ff1": _init(ks[2], (h, d_ff), s, dtype),
        "w_ff2": _init(ks[3], (d_ff, h), 1 / math.sqrt(d_ff), dtype),
    }


def slstm_param_specs(ctx: ATPContext, cfg: ModelConfig) -> dict[str, Any]:
    # replicated: inherently sequential recurrence, small block
    return {k: P() for k in
            ("ln", "w_gates", "r_gates", "b_gates", "gn", "w_ff1", "w_ff2")}


def slstm_block(ctx: ATPContext, cfg: ModelConfig, p, x, state=None):
    """x: [b, s, h/d2]; recurrence runs on full-h replicated activations."""
    nh = cfg.num_heads
    h = cfg.d_model
    dh = h // nh
    xg = x
    if ctx.ax2 is not None:  # need full h for the recurrent mixing
        xg = lax.all_gather(x, ctx.ax2, axis=-1, tiled=True)
    # All sLSTM params are fully replicated (P()) while the block's
    # cotangent is rank-partial over the whole flat group (residual ct is
    # ax1-partial by the row-boundary convention and ax2-chunked by the
    # exit shard_slice), so every param grad needs the full-group barrier.
    h_in = _rms_full(xg, grad_sync(ctx, p["ln"], ctx.tp_axes), cfg.norm_eps)
    r_gates = grad_sync(ctx, p["r_gates"], ctx.tp_axes)
    pre = h_in.astype(jnp.float32) @ grad_sync(ctx, p["w_gates"], ctx.tp_axes) \
        + grad_sync(ctx, p["b_gates"], ctx.tp_axes)                # [b,s,4h]

    def step(carry, u):
        c, n, hs = carry                                # [b, nh, dh] each
        rec = jnp.einsum("bhd,hde->bhe", hs, r_gates)   # [b, nh, 4dh]
        gts = u.reshape(u.shape[0], nh, 4 * dh) + rec
        z, i, f, o = jnp.split(gts, 4, axis=-1)
        z, i = jnp.tanh(z), jax.nn.sigmoid(i)
        f, o = jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        c_new = f * c + i * z
        n_new = f * n + i
        hs_new = o * (c_new / jnp.maximum(n_new, 1.0))
        return (c_new, n_new, hs_new), hs_new

    b = x.shape[0]
    if state is None:
        # zeros_like(slice of pre) keeps the vma type (varying over data/ax2)
        z0 = jnp.zeros_like(pre[:, 0, : nh * dh]).reshape(b, nh, dh)
        init = (z0, z0, z0)
    else:
        init = (state["c"], state["n"], state["h"])
    # KNOWN LIMIT (EXPERIMENTS §Perf): the scan transpose still all-reduces
    # d(r_gates) once per time step (16.8 MB x 4096/block); the production
    # fix is a custom-vjp backward scan that accumulates dW locally and
    # reduces once — left as the documented next iteration.
    (c, n, hs), ys = lax.scan(step, init, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, x.shape[1], h)
    # §Perf: cotangent barrier — psum the incoming (Partial-over-ax1)
    # cotangent ONCE here, so the scan transpose runs fully invariant and
    # does NOT emit a psum of d(r_gates) per TIME STEP (the baseline's
    # dominant collective: 4096 all-reduces per sLSTM block).  vma builds
    # only: there the rewrite would otherwise insert those per-step psums
    # and the barrier's early reduction is absorbed by the invariant type.
    # On legacy jax no psums are auto-inserted, so a mid-chain psum would
    # BREAK the rank-partial cotangent convention (over-counting every
    # grad upstream of it); the per-param grad_sync barriers handle the
    # reduction instead, once per leaf.
    if vma_rewrite_active(ctx):
        y = _ct_psum_barrier(y, ctx.ax1)
    new_state = {"c": c, "n": n, "h": hs} if state is not None else None

    y = _rms_full(y, grad_sync(ctx, p["gn"], ctx.tp_axes),
                  cfg.norm_eps).astype(x.dtype)
    y = jax.nn.gelu(y @ grad_sync(ctx, p["w_ff1"], ctx.tp_axes),
                    approximate=True) @ grad_sync(ctx, p["w_ff2"], ctx.tp_axes)
    if ctx.ax2 is not None:  # back to the block I/O feature shard
        y = shard_slice(y, ctx.index2(), ctx.d2, dim=-1)
    return x + y, new_state


def _rms_full(x, gamma, eps):
    xf = x.astype(jnp.float32)
    inv = lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return xf * inv * gamma


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _ct_psum_barrier(y, axis):
    """Identity forward; backward all-reduces the cotangent over `axis`.

    Used where a replicated (invariant) computation zone meets a sharded
    consumer: the consumer's cotangent is Partial over `axis`, and without
    this barrier the lazy psum placement pushes the reduction inside the
    upstream scan — one all-reduce per time step."""
    return y


def _barrier_fwd(y, axis):
    return y, None


def _barrier_bwd(axis, _, g):
    if axis is None:
        return (g,)
    return (lax.psum(g, axis),)


_ct_psum_barrier.defvjp(_barrier_fwd, _barrier_bwd)
