"""Mamba2 (SSD) block, ATP-sharded, with chunked scan.

Sharding (DESIGN.md §5): SSD heads shard embarrassingly over the flat
d1*d2 TP ranks (no contraction over a sharded dim inside the recurrence);
ATP applies to the in/out projections:
  - z/x projection: column-first over ax1, d2 sub-slice per rank
  - B/C/dt projection: replicated output (rows over ax2, psum(ax2)) —
    B/C are shared across heads (single group), dt sliced per head block
  - out projection: row-first (f2-style psum(ax1))

The chunked scan (`ssd_chunked`) is the pure-jnp oracle for the Pallas
kernel in kernels/ssd_scan.py.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.atp import (ATPContext, atp_boundary, atp_linear, grad_sync,
                            shard_slice)
from repro.models import layers as L


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def mamba_dims(cfg: ModelConfig):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    nheads = d_inner // sc.head_dim
    return d_inner, nheads


def mamba_params(key, cfg: ModelConfig, dtype) -> dict[str, Any]:
    sc = cfg.ssm
    h = cfg.d_model
    d_inner, nheads = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(h)
    return {
        "w_z": _init(ks[0], (h, d_inner), s, dtype),
        "w_x": _init(ks[4], (h, d_inner), s, dtype),
        "w_bcdt": _init(ks[1], (h, 2 * sc.d_state + nheads), s, dtype),
        "conv": _init(ks[2], (sc.conv_kernel, d_inner + 2 * sc.d_state), 0.5, jnp.float32),
        "A_log": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "w_out": _init(ks[3], (d_inner, h), 1.0 / math.sqrt(d_inner), dtype),
        "ln": jnp.ones((h,), jnp.float32),
        "gn": jnp.ones((d_inner,), jnp.float32),  # grouped RMSNorm pre-out
    }


def mamba_param_specs(ctx: ATPContext, cfg: ModelConfig) -> dict[str, Any]:
    return {
        "w_z": L.col_w_spec(ctx),
        "w_x": L.col_w_spec(ctx),
        "w_bcdt": P(ctx.ax2, None),
        "conv": P(None, None),       # xin channels sliced locally below
        "A_log": L.replicated_spec(),
        "D": L.replicated_spec(),
        "dt_bias": L.replicated_spec(),
        "w_out": L.row_w_spec(ctx),
        "ln": L.feat_spec(ctx),
        "gn": L.replicated_spec(),   # sliced per-rank channels locally
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv1d.  x: [b, s, c]; w: [k, c].

    state (decode): [b, k-1, c] previous inputs; returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    windows = jnp.stack([pad[:, i: i + x.shape[1]] for i in range(k)], axis=-1)
    y = jnp.einsum("bsck,kc->bsc", windows, w.astype(x.dtype))
    new_state = pad[:, -(k - 1):] if k > 1 else None
    return y, new_state


def ssd_chunked(x, dt, A_log, B, C, D, chunk: int, state_in=None):
    """Chunked selective-state-space scan (SSD).

    x:  [b, s, nh, hd]   inputs (already gated/conv'd)
    dt: [b, s, nh]       softplus'd step sizes
    A_log: [nh]          per-head decay (A = -exp(A_log))
    B, C: [b, s, ds]     input/output projections (single group)
    D: [nh]              skip
    state_in: [b, nh, hd, ds] initial state (decode/continuation)

    Returns (y [b, s, nh, hd], state_out [b, nh, hd, ds]).
    Pure-jnp oracle for kernels/ssd_scan.py.
    """
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    nc = max(1, s // chunk)
    cl = s // nc
    A = -jnp.exp(A_log.astype(jnp.float32))                     # [nh]
    dt = dt.astype(jnp.float32)
    dA = dt * A                                                  # [b, s, nh]
    xr = x.reshape(b, nc, cl, nh, hd).astype(jnp.float32)
    dtr = dt.reshape(b, nc, cl, nh)
    dAr = dA.reshape(b, nc, cl, nh)
    Br = B.reshape(b, nc, cl, ds).astype(jnp.float32)
    Cr = C.reshape(b, nc, cl, ds).astype(jnp.float32)

    la = jnp.cumsum(dAr, axis=2)                                 # [b,nc,cl,nh]
    # intra-chunk: y[t] = sum_{u<=t} exp(la[t]-la[u]) dt[u] (C_t.B_u) x[u]
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]            # [b,nc,t,u,nh]
    causal = jnp.tril(jnp.ones((cl, cl), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bntd,bnud->bntu", Cr, Br)                   # [b,nc,t,u]
    w = cb[..., None] * decay * dtr[:, :, None, :, :]            # [b,nc,t,u,nh]
    y_intra = jnp.einsum("bntuh,bnuhd->bnthd", w, xr)

    # chunk summaries: S_n = sum_u exp(la[end]-la[u]) dt[u] x[u] B_u^T
    dec_end = jnp.exp(la[:, :, -1:, :] - la)                     # [b,nc,cl,nh]
    contrib = xr * (dtr * dec_end)[..., None]                    # [b,nc,cl,nh,hd]
    S = jnp.einsum("bnuhd,bnus->bnhds", contrib, Br)             # [b,nc,nh,hd,ds]

    # inter-chunk scan: state_{n} = state_{n-1} * exp(la_end_n) + S_n
    gain = jnp.exp(la[:, :, -1, :])                              # [b,nc,nh]

    def step(carry, inp):
        S_n, g_n = inp
        new = carry * g_n[:, :, None, None] + S_n
        return new, carry  # emit the state *entering* chunk n

    Sm = jnp.moveaxis(S, 1, 0)
    # zeros_like keeps the vma type of S (varying over the right mesh axes)
    init = (jnp.zeros_like(Sm[0]) if state_in is None
            else state_in.astype(jnp.float32))
    state_out, entering = lax.scan(step, init, (Sm, jnp.moveaxis(gain, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)                      # [b,nc,nh,hd,ds]

    # cross-chunk: y_cross[t] = exp(la[t]) * C_t . state_in^T
    y_cross = jnp.einsum("bnts,bnhds->bnthd", Cr, entering) * jnp.exp(la)[..., None]
    y = (y_intra + y_cross).reshape(b, s, nh, hd)
    y = y + D[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), state_out


def ssd_step(x, dt, A_log, B, C, D, state):
    """Single-token decode step.  x: [b, 1, nh, hd]; state [b, nh, hd, ds]."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                           # [b, nh]
    g = jnp.exp(dtf * A)                                         # [b, nh]
    xf = x[:, 0].astype(jnp.float32)
    upd = jnp.einsum("bhd,bs->bhds", xf * dtf[..., None], B[:, 0].astype(jnp.float32))
    new_state = state.astype(jnp.float32) * g[:, :, None, None] + upd
    y = jnp.einsum("bhds,bs->bhd", new_state, C[:, 0].astype(jnp.float32))
    y = y + D[None, :, None] * xf
    return y[:, None].astype(x.dtype), new_state


def _group_rmsnorm(y, gamma, eps=1e-6):
    """RMSNorm over each head's channels (y: [b, s, nh, hd])."""
    yf = y.astype(jnp.float32)
    inv = lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + eps)
    return (yf * inv * gamma).astype(y.dtype)


def mamba_block(ctx: ATPContext, cfg: ModelConfig, p, x, state=None):
    """x: [b, s, h/d2] -> (same spec, new_state or None).

    state (decode): dict(conv=[b,k-1,c_loc], ssd=[b,nh_loc,hd,ds]).
    """
    sc = cfg.ssm
    d_inner, nheads = mamba_dims(cfg)
    n = ctx.tp
    assert nheads % n == 0, "mamba heads must divide flat TP"
    nh_loc = nheads // n
    hd = sc.head_dim
    i2, flat = ctx.index2(), ctx.tp_index()

    h_in = L.rms_norm(ctx, x, p["ln"], cfg.norm_eps)

    # z/x projections: column-first over ax1 (one fused boundary), then split
    # per part *before* the d2 sub-slice so shard boundaries stay part-aligned
    w_cat = jnp.concatenate([p["w_z"], p["w_x"]], axis=1)
    zx = atp_boundary(jnp.einsum("...k,kn->...n", h_in, w_cat), ctx.ax2)
    z, xin = jnp.split(zx, 2, axis=-1)                  # each [b, s, d_inner/d1]
    z = shard_slice(z, i2, ctx.d2, dim=-1)              # [b, s, d_inner/n]
    xin = shard_slice(xin, i2, ctx.d2, dim=-1)

    # B/C/dt: replicated output via psum(ax2).  w_bcdt's storage is
    # ax1-replicated (P(ax2, None)) while its cotangent — local heads'
    # B/C/dt use, ax2-completed by the boundary transpose — stays
    # ax1-partial, so its grad needs the ax1 barrier; the replicated
    # per-head leaves (dt_bias/conv/A_log/D/gn) are shard_slice'd to the
    # flat-rank head block, so their grads assemble over the whole group.
    bcdt = atp_boundary(jnp.einsum("...k,kn->...n", h_in,
                                   grad_sync(ctx, p["w_bcdt"], ctx.ax1)),
                        ctx.ax2)
    B = bcdt[..., : sc.d_state]
    C = bcdt[..., sc.d_state: 2 * sc.d_state]
    dt_all = bcdt[..., 2 * sc.d_state:]                 # [b, s, nheads]
    dt = shard_slice(dt_all, flat, n, dim=-1)           # [b, s, nh_loc]
    dt_bias = grad_sync(ctx, p["dt_bias"], ctx.tp_axes)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + shard_slice(dt_bias, flat, n, 0))

    # causal conv on (xin | B | C); xin channels are this rank's slice
    conv = grad_sync(ctx, p["conv"], ctx.tp_axes)
    conv_x = shard_slice(conv[:, : d_inner], flat, n, dim=1)
    conv_bc = conv[:, d_inner:]
    cs_x = state["conv_x"] if state is not None else None
    cs_bc = state["conv_bc"] if state is not None else None
    xin_c, ns_x = _causal_conv(xin, conv_x, cs_x)
    bc_c, ns_bc = _causal_conv(jnp.concatenate([B, C], -1), conv_bc, cs_bc)
    xin_c = jax.nn.silu(xin_c)
    bc_c = jax.nn.silu(bc_c)
    B_c, C_c = jnp.split(bc_c, 2, axis=-1)

    xh = xin_c.reshape(xin_c.shape[0], xin_c.shape[1], nh_loc, hd)
    A_log = shard_slice(grad_sync(ctx, p["A_log"], ctx.tp_axes), flat, n, 0)
    D = shard_slice(grad_sync(ctx, p["D"], ctx.tp_axes), flat, n, 0)

    if state is None:
        y, _ = ssd_chunked(xh, dt, A_log, B_c, C_c, D, sc.chunk)
        new_state = None
    else:
        if xh.shape[1] == 1:
            y, ssd_new = ssd_step(xh, dt, A_log, B_c, C_c, D, state["ssd"])
        else:  # prefill-into-state
            y, ssd_new = ssd_chunked(xh, dt, A_log, B_c, C_c, D, sc.chunk,
                                     state_in=state["ssd"])
        new_state = {"conv_x": ns_x, "conv_bc": ns_bc,
                     "ssd": ssd_new.astype(state["ssd"].dtype)}

    gn = shard_slice(grad_sync(ctx, p["gn"], ctx.tp_axes), flat, n, 0).reshape(nh_loc, hd)
    y = _group_rmsnorm(y, gn)
    y = y.reshape(y.shape[0], y.shape[1], nh_loc * hd)
    y = y * jax.nn.silu(z)

    # gather heads over ax2 back to ax1-sharded layout for row-first out proj
    if ctx.ax2 is not None:
        y = lax.all_gather(y, ctx.ax2, axis=-1, tiled=True)
    out = atp_linear(ctx, y, p["w_out"], kind="row")
    return x + out, new_state
