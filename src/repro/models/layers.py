"""Shared model layers, ATP-sharded.  All code runs inside shard_map.

Activation convention between blocks (paper Fig. 6): spec
[Replicate@ax1, Shard(feature)@ax2] — local shape [..., d_model/d2].
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.atp import (ATPContext, atp_boundary, grad_sync,
                            seq_gather, shard_slice)

# ---------------------------------------------------------------------------
# Param spec helpers (global tensor -> PartitionSpec over ATP axes).
# ---------------------------------------------------------------------------


def col_w_spec(ctx: ATPContext) -> P:
    """Column-first weight [K, N]: [Shard(1)@ax1, Shard(0)@ax2]."""
    return P(ctx.ax2, ctx.ax1)


def row_w_spec(ctx: ATPContext) -> P:
    """Row-first weight [K, N]: [Shard(0)@ax1, Shard(1)@ax2]."""
    return P(ctx.ax1, ctx.ax2)


def col_b_spec(ctx: ATPContext) -> P:
    return P(ctx.ax1)


def row_b_spec(ctx: ATPContext) -> P:
    return P(ctx.ax2)


def feat_spec(ctx: ATPContext) -> P:
    """1D feature param (norm scale): sharded like activations (ax2)."""
    return P(ctx.ax2)


def embed_spec(ctx: ATPContext) -> P:
    """Embedding [V, h]: vocab over ax1, features over ax2."""
    return P(ctx.ax1, ctx.ax2)


def replicated_spec() -> P:
    return P()


# ---------------------------------------------------------------------------
# Norms (duplicated per TP worker per the paper; feature dim is ax2-sharded
# so the variance reduction needs one tiny psum over ax2).
#
# Under the sequence-parallel block I/O spec the norm input is additionally
# seq-sharded over ax1; normalisation is per-row, so the math is unchanged
# and runs on 1/d1 of the rows.  ``gather_seq=True`` folds the conjugate
# all-gather back to full sequence into the norm epilogue (block-entry
# norms gather; post-block norms stay in the seq-sharded domain).
# ---------------------------------------------------------------------------


def rms_norm(ctx: ATPContext, x, gamma, eps: float = 1e-6,
             plus_one: bool = False, gather_seq: bool = False):
    # ax2-sharded scale, but its cotangent is ax1-PARTIAL: the norm output
    # feeds a column boundary whose out dim is ax1-sharded, so each rank's
    # scale grad sums only its columns (and, under sp, its tokens).
    gamma = grad_sync(ctx, gamma, ctx.ax1)
    xf = x.astype(jnp.float32)
    ss = jnp.sum(xf * xf, axis=-1, keepdims=True)
    ss = atp_boundary(ss, ctx.ax2)  # full-feature sum of squares
    d = x.shape[-1] * ctx.d2
    inv = lax.rsqrt(ss / d + eps)
    g = (1.0 + gamma) if plus_one else gamma
    out = (xf * inv * g).astype(x.dtype)
    return seq_gather(ctx, out, dim=out.ndim - 2) if gather_seq else out


def layer_norm(ctx: ATPContext, x, gamma, beta, eps: float = 1e-5,
               gather_seq: bool = False):
    gamma = grad_sync(ctx, gamma, ctx.ax1)
    beta = grad_sync(ctx, beta, ctx.ax1)
    xf = x.astype(jnp.float32)
    d = x.shape[-1] * ctx.d2
    s = atp_boundary(jnp.sum(xf, axis=-1, keepdims=True), ctx.ax2)
    mu = s / d
    ss = atp_boundary(jnp.sum((xf - mu) ** 2, axis=-1, keepdims=True), ctx.ax2)
    inv = lax.rsqrt(ss / d + eps)
    out = ((xf - mu) * inv * gamma + beta).astype(x.dtype)
    return seq_gather(ctx, out, dim=out.ndim - 2) if gather_seq else out


def norm(ctx: ATPContext, cfg: ModelConfig, x, p, gather_seq: bool = False):
    if cfg.norm_kind == "layernorm":
        return layer_norm(ctx, x, p["scale"], p["bias"], cfg.norm_eps,
                          gather_seq=gather_seq)
    plus_one = cfg.name.startswith("gemma2")
    return rms_norm(ctx, x, p["scale"], cfg.norm_eps, plus_one=plus_one,
                    gather_seq=gather_seq)


def norm_params(cfg: ModelConfig, d_local: int):
    if cfg.norm_kind == "layernorm":
        return {"scale": jnp.ones((d_local,), jnp.float32),
                "bias": jnp.zeros((d_local,), jnp.float32)}
    init = jnp.zeros if cfg.name.startswith("gemma2") else jnp.ones
    return {"scale": init((d_local,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl).
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: [b, s, heads, hd]; positions: [b, s] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [b, s, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """qwen2-vl M-RoPE: positions3 [3, b, s] (t/h/w ids), per-section bands."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    sec = jnp.asarray(
        sum(([i] * s for i, s in enumerate(sections)), []), dtype=jnp.int32
    )  # [hd/2] -> which of t/h/w drives this band
    pos = jnp.take(positions3, sec, axis=0)  # [hd/2, b, s]
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # [b, s, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention sharding plan (DESIGN.md §6).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnPlan:
    """Static plan for sharding the attention core over d1*d2 flat ranks.

    g          : number of head blocks (ranks holding distinct q heads)
    q_loc      : q heads per block
    r          : leftover rank factor (seq-split in train/prefill,
                 redundant-compute in decode)
    q_regroup  : q must be all-gathered over ax1 (Hq % d1 != 0)
    kv_regroup : k/v must be all-gathered over ax1 (KV % d1 != 0)
    kv_start_of/kv_count: per-block kv head selection (GQA replication)
    """

    g: int
    q_loc: int
    r: int
    h2: int
    q_regroup: bool
    kv_regroup: bool
    kv_count: int
    ratio: int  # q heads per kv head


def make_attn_plan(ctx: ATPContext, num_heads: int, num_kv: int) -> AttnPlan:
    n, d1, d2 = ctx.tp, ctx.d1, ctx.d2
    q_regroup = num_heads % d1 != 0
    if q_regroup:
        g = math.gcd(num_heads, n)
        h2 = 1
    else:
        h2 = math.gcd(num_heads // d1, d2)
        g = d1 * h2
    q_loc = num_heads // g
    r = n // g
    ratio = max(1, num_heads // num_kv)
    kv_count = max(1, q_loc // ratio)
    kv_regroup = num_kv % d1 != 0
    return AttnPlan(g=g, q_loc=q_loc, r=r, h2=h2, q_regroup=q_regroup,
                    kv_regroup=kv_regroup, kv_count=kv_count, ratio=ratio)


def _block_and_r_index(ctx: ATPContext, plan: AttnPlan):
    """(head-block id, r-index) for this rank."""
    if plan.q_regroup:
        i = ctx.tp_index()
        return i // plan.r, i % plan.r
    i2 = ctx.index2()
    r2 = plan.r  # r divides d2 in the aligned case
    return ctx.index1() * plan.h2 + i2 // r2, i2 % r2


def split_qkv_heads(ctx: ATPContext, cfg: ModelConfig, qp, kp, vp, plan: AttnPlan):
    """qp/kp/vp: per-part GEMM outputs, each [..., part_dim/d1] ax1-sharded
    and ax2-replicated (q/k/v use separate weights so each part shards over
    d1 independently even when head counts don't divide d1).

    Returns this core rank's (q [b,s,q_loc,hd], k/v [b,s,kv_count,hd],
    block id, r index).
    """
    hd = cfg.hd
    d1 = ctx.d1
    bid, rid = _block_and_r_index(ctx, plan)

    if plan.q_regroup:
        q = lax.all_gather(qp, ctx.ax1, axis=-1, tiled=True) if ctx.ax1 else qp
        q = q.reshape(q.shape[:-1] + (cfg.num_heads, hd))
        q = lax.dynamic_slice_in_dim(q, bid * plan.q_loc, plan.q_loc, axis=-2)
    else:
        q = qp.reshape(qp.shape[:-1] + (cfg.num_heads // d1, hd))
        sub = (bid % plan.h2) if plan.h2 > 1 else 0
        q = lax.dynamic_slice_in_dim(q, sub * plan.q_loc, plan.q_loc, axis=-2)

    if plan.kv_regroup:
        k = lax.all_gather(kp, ctx.ax1, axis=-1, tiled=True) if ctx.ax1 else kp
        v = lax.all_gather(vp, ctx.ax1, axis=-1, tiled=True) if ctx.ax1 else vp
        k = k.reshape(k.shape[:-1] + (cfg.num_kv_heads, hd))
        v = v.reshape(v.shape[:-1] + (cfg.num_kv_heads, hd))
        kv_start = (bid * plan.q_loc) // plan.ratio
        k = lax.dynamic_slice_in_dim(k, kv_start, plan.kv_count, axis=-2)
        v = lax.dynamic_slice_in_dim(v, kv_start, plan.kv_count, axis=-2)
    else:
        k = kp.reshape(kp.shape[:-1] + (cfg.num_kv_heads // d1, hd))
        v = vp.reshape(vp.shape[:-1] + (cfg.num_kv_heads // d1, hd))
        local_q_start = (bid % plan.h2) * plan.q_loc if plan.h2 > 1 else 0
        kv_start = local_q_start // plan.ratio
        k = lax.dynamic_slice_in_dim(k, kv_start, plan.kv_count, axis=-2)
        v = lax.dynamic_slice_in_dim(v, kv_start, plan.kv_count, axis=-2)
    return q, k, v, bid, rid


def core_output_gather(ctx: ATPContext, cfg: ModelConfig, o, plan: AttnPlan, seq_split: bool):
    """o: [b, s_r, q_loc, hd] core output -> [b, s, q_dim/d1] ax2-replicated.

    seq_split: whether the r factor sliced seq (train/prefill) or produced
    redundant copies (decode).
    """
    b = o.shape[0]
    o = o.reshape(b, o.shape[1], plan.q_loc * cfg.hd)
    if ctx.tp == 1:
        return o
    if plan.q_regroup:
        gathered = lax.all_gather(o, ctx.tp_axes, axis=0, tiled=False)
        # entries ordered by flat index = bid * r + rid
        gathered = gathered.reshape((plan.g, plan.r) + o.shape)
        if seq_split and plan.r > 1:
            # [g, r, b, s_r, F] -> [g, b, r*s_r, F]
            gathered = jnp.moveaxis(gathered, 1, 3).reshape(
                plan.g, b, plan.r * o.shape[1], o.shape[2])
        else:
            gathered = gathered[:, 0]
        # heads: [g, b, s, F] -> [b, s, g*F], then slice this rank's ax1 part
        full = jnp.moveaxis(gathered, 0, 2).reshape(b, gathered.shape[2], plan.g * o.shape[2])
        return shard_slice(full, ctx.index1(), ctx.d1, dim=2)
    if ctx.ax2 is None:
        return o
    gathered = lax.all_gather(o, ctx.ax2, axis=0, tiled=False)  # [d2, b, s_r, F]
    gathered = gathered.reshape((plan.h2, plan.r) + o.shape)
    if seq_split and plan.r > 1:
        gathered = jnp.moveaxis(gathered, 1, 3).reshape(
            plan.h2, b, plan.r * o.shape[1], o.shape[2])
    else:
        gathered = gathered[:, 0]
    return jnp.moveaxis(gathered, 0, 2).reshape(b, gathered.shape[2], plan.h2 * o.shape[2])


# ---------------------------------------------------------------------------
# Attention core math (GQA + causal/local masks + softcap).
# ---------------------------------------------------------------------------


def attention_core(
    cfg: ModelConfig,
    q, k, v,                      # q: [b, sq, hq, hd]; k/v: [b, skv, hkv, hd]
    q_offset,                     # absolute position of q[0]: scalar, or
                                  # [b] per-slot (paged continuous batching)
    kv_len=None,                  # decode: valid cache length (scalar or [b])
    window: int = 0,              # sliding window (0 = global)
):
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(cfg.hd if cfg.mla is None else q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
    # per-slot offsets/lengths (paged serving) build a [b, 1, sq, skv]
    # mask; the scalar path keeps its original [1, 1, sq, skv] shape
    q_off = jnp.asarray(q_offset)
    per_slot = q_off.ndim > 0
    if per_slot:
        qpos = q_off[:, None, None] + jnp.arange(sq)[None, :, None]
        kpos = jnp.arange(skv)[None, None, :]
    else:
        qpos = q_off + jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
    mask = kpos <= qpos
    # window may be a traced per-layer scalar (scanned); 0 means global
    win = jnp.asarray(window, jnp.int32)
    win_eff = jnp.where(win > 0, win, jnp.int32(2**30))
    mask &= kpos > qpos - win_eff
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        mask = mask & (kpos < (kl[:, None, None] if kl.ndim else kl))
    # [b, sq, skv] -> [b, 1, sq, skv]; scalar path [sq, skv] -> [1, 1, ...]
    mask = mask[:, None] if mask.ndim == 3 else mask[None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)
