"""DeepSeek-V3 Multi-head Latent Attention, ATP-sharded.

Sharding decisions (DESIGN.md §5):
  - down-projections to the tiny latents (q: 1536, kv: 512+64) produce
    *replicated* latents: rows sharded over ax2, psum(ax2) -> replicated.
  - up-projections shard their per-head outputs over ax1 (column-first with
    no row sharding: input is replicated, so no boundary psum is needed).
  - attention core: heads over the flat d1*d2 ranks (128 % 256-rank meshes
    always divide for the assigned meshes: 128/16 = 8).
  - decode caches the *latent* (c_kv + k_rope), replicated over TP —
    that is MLA's entire point; the absorbed form computes scores directly
    against the latent.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.atp import (ATPContext, atp_boundary, atp_linear, grad_sync,
                            shard_slice)
from repro.models import layers as L
from repro.models import paging


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def mla_params(key, cfg: ModelConfig, dtype) -> dict[str, Any]:
    m = cfg.mla
    h, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(h)
    return {
        "w_dq": _init(ks[0], (h, m.q_lora_rank), s, dtype),
        "w_uq": _init(ks[1], (m.q_lora_rank, H * qk), 1 / math.sqrt(m.q_lora_rank), dtype),
        "w_dkv": _init(ks[2], (h, m.kv_lora_rank + m.qk_rope_head_dim), s, dtype),
        "w_ukv": _init(ks[3], (m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)),
                       1 / math.sqrt(m.kv_lora_rank), dtype),
        "wo": _init(ks[4], (H * m.v_head_dim, h), 1 / math.sqrt(H * m.v_head_dim), dtype),
        "q_ln": jnp.ones((m.q_lora_rank,), jnp.float32),
        "kv_ln": jnp.ones((m.kv_lora_rank,), jnp.float32),
    }


def mla_param_specs(ctx: ATPContext, cfg: ModelConfig) -> dict[str, Any]:
    return {
        "w_dq": P(ctx.ax2, None),    # rows over ax2, replicated output
        "w_uq": P(None, ctx.ax1),    # latent replicated, heads over ax1
        "w_dkv": P(ctx.ax2, None),
        "w_ukv": P(None, ctx.ax1),
        "wo": L.row_w_spec(ctx),
        "q_ln": L.replicated_spec(),
        "kv_ln": L.replicated_spec(),
    }


def _latent_norm(x, gamma, eps):
    xf = x.astype(jnp.float32)
    inv = lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * gamma).astype(x.dtype)


def _heads_per_rank(ctx: ATPContext, cfg: ModelConfig) -> int:
    assert cfg.num_heads % ctx.tp == 0, "MLA heads must divide flat TP"
    return cfg.num_heads // ctx.tp


def mla_block(
    ctx: ATPContext,
    cfg: ModelConfig,
    p,
    x,                  # [b, s, h/d2]
    positions,          # [b, s]
    cache=None,         # decode: dict(ckv=[b,S,rank], krope=[b,S,rd], len=..)
                        # or paged pools dict(ckv=[np,pg,rank], krope=...)
    paged=None,         # paged serving: dict(table=[b,mp], start=[b])
):
    """Returns ([b, s, h/d2], new_cache)."""
    m = cfg.mla
    qk_nope, qk_rope, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    h_loc = _heads_per_rank(ctx, cfg)
    i2 = ctx.index2()

    # ---- latents (replicated): rows of w_d* are ax2-sharded -> psum(ax2).
    # Grad barriers: the latents' cotangent flows back from the rank-local
    # (ax1-col x ax2-subslice) head shard, so the replicated latent-norm
    # gains are tp-partial; the down-proj weights (ax1-replicated storage,
    # ax2-completed ct via the boundary transpose) are ax1-partial.
    cq = atp_boundary(jnp.einsum("...k,kn->...n", x,
                                 grad_sync(ctx, p["w_dq"], ctx.ax1)), ctx.ax2)
    cq = _latent_norm(cq, grad_sync(ctx, p["q_ln"], ctx.tp_axes), cfg.norm_eps)
    ckv_full = atp_boundary(jnp.einsum("...k,kn->...n", x,
                                       grad_sync(ctx, p["w_dkv"], ctx.ax1)),
                            ctx.ax2)
    ckv = _latent_norm(ckv_full[..., : m.kv_lora_rank],
                       grad_sync(ctx, p["kv_ln"], ctx.tp_axes), cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank:]             # [b, s, rope_dim]

    # ---- q up-projection: heads over ax1, extra d2 factor sliced from ax1's
    # block (w_uq columns are ax1-sharded; slice the ax2 sub-block locally —
    # the slice makes the ax2-replicated up-proj grads ax2-partial)
    uq = jnp.einsum("...k,kn->...n", cq, grad_sync(ctx, p["w_uq"], ctx.ax2))
    uq = shard_slice(uq, i2, ctx.d2, dim=-1)            # [b, s, H*(qk)/n]
    q = uq.reshape(uq.shape[:-1] + (h_loc, qk_nope + qk_rope))
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]
    q_pe = L.apply_rope(q_pe, positions if cache is None else positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        # ---- train/prefill: expand latent to per-head k/v
        ukv = jnp.einsum("...k,kn->...n", ckv, grad_sync(ctx, p["w_ukv"], ctx.ax2))
        ukv = shard_slice(ukv, i2, ctx.d2, dim=-1)
        kv = ukv.reshape(ukv.shape[:-1] + (h_loc, qk_nope + dv))
        k_nope, v = kv[..., :qk_nope], kv[..., qk_nope:]
        k_pe = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
        k_pe = jnp.broadcast_to(k_pe, k_nope.shape[:-1] + (qk_rope,))
        k = jnp.concatenate([k_nope, k_pe], axis=-1)
        qq = jnp.concatenate([q_nope, q_pe], axis=-1)
        o = L.attention_core(cfg, qq, k, v, q_offset=0)           # [b,s,h_loc,dv]
    else:
        # ---- decode (absorbed): score against the latent directly
        sq = x.shape[1]
        k_pe_new = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
        if paged is not None:
            # paged serving: the latent pools are position-paged exactly
            # like K/V; scatter this run, gather the slot's mapped pages
            table, start = paged["table"], paged["start"]
            pd = paging.pool_page_dtype(cache["ckv"])
            pckv, pckv_s = paging.append_tokens_q(
                cache["ckv"], cache.get("ckv_scale"), table, start, ckv, pd)
            pkr, pkr_s = paging.append_tokens_q(
                cache["krope"], cache.get("krope_scale"), table, start,
                k_pe_new, pd)
            new_cache = {"ckv": pckv, "krope": pkr}
            if pckv_s is not None:
                new_cache["ckv_scale"], new_cache["krope_scale"] = pckv_s, pkr_s
            cckv = paging.gather_pages_q(pckv, pckv_s, table,
                                         out_dtype=ckv.dtype)  # [b,S_alloc,rank]
            ckr = paging.gather_pages_q(pkr, pkr_s, table,
                                        out_dtype=k_pe_new.dtype)
            klen = start                                 # [b] per-slot
        else:
            klen = cache["len"]
            cckv = lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), klen, axis=1)
            ckr = lax.dynamic_update_slice_in_dim(
                cache["krope"], k_pe_new.astype(cache["krope"].dtype), klen, axis=1)
            new_cache = {"ckv": cckv, "krope": ckr, "len": klen + sq}
        # absorb W_ukv(k-part) into q:  q_abs = q_nope @ W_uk^T  [b,1,hl,rank]
        w_ukv = p["w_ukv"].reshape(m.kv_lora_rank, cfg.num_heads // ctx.d1, qk_nope + dv)
        w_ukv = shard_slice(w_ukv, i2, ctx.d2, dim=1)   # [rank, h_loc, qk+dv]
        w_uk, w_uv = w_ukv[..., :qk_nope], w_ukv[..., qk_nope:]
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
        scores = (
            jnp.einsum("bqhr,bkr->bhqk", q_abs.astype(jnp.float32),
                       cckv.astype(jnp.float32))
            + jnp.einsum("bqhd,bkd->bhqk", q_pe.astype(jnp.float32),
                         ckr.astype(jnp.float32))
        ) / math.sqrt(qk_nope + qk_rope)
        kpos = jnp.arange(cckv.shape[1])[None, None, None, :]
        if paged is not None:
            qpos = klen[:, None, None, None] + jnp.arange(sq)[None, None, :, None]
        else:
            qpos = klen + jnp.arange(sq)[None, None, :, None]
        scores = jnp.where(kpos <= qpos, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhqk,bkr->bqhr", probs, cckv.astype(jnp.float32))
        o = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv.astype(jnp.float32)).astype(x.dtype)

    o = o.reshape(o.shape[0], o.shape[1], h_loc * dv)
    # gather core output over ax2 back to ax1-sharded layout for row-first wo
    if ctx.ax2 is not None:
        o = lax.all_gather(o, ctx.ax2, axis=-1, tiled=True)
    return atp_linear(ctx, o, p["wo"], kind="row"), new_cache
