"""Block-paged KV-cache storage: fixed-size pages + per-slot page tables.

The dense decode caches (``lm.init_decode_caches``) pay ``B * s_max``
tokens of memory per layer no matter how long each slot's sequence
actually is.  Paged storage replaces the per-slot ``s_max`` axis with a
shared *page pool*: every attention-cache tensor stores
``num_pages * page_size`` token positions, and each serving slot maps its
logical positions onto physical pages through a small int32 page table.
Short sequences hold few pages, long ones hold many, and the pool is
sized to the expected *total* live tokens across slots — not to
``slots x s_max``.

Three parties cooperate:

  - :class:`PagedConfig` fixes the geometry (page size, pool size, table
    width) shared by host and device;
  - :class:`PageAllocator` is the HOST-side bookkeeper: a free list plus
    per-slot page lists; the continuous-batching scheduler
    (``runtime.server``) allocates on admission/growth, frees on slot
    recycle, and ships the resulting ``[B, pages_per_slot]`` tables to
    the device as plain arrays;
  - :func:`gather_pages` / :func:`append_tokens` are the DEVICE-side
    accessors (pure jax, run inside shard_map): attention reads only the
    pages a slot has mapped, and cache writes scatter tokens through the
    table.

Physical page 0 is reserved as the *garbage page*: unmapped table entries
point at it, so inactive slots and padded chunk tails scatter there
harmlessly (every read is masked by the slot's length before softmax).

Only O(s) caches are paged — attention K/V and MLA's compressed-KV
latents.  Mamba/xLSTM recurrent state is O(1) per slot and lives in
per-slot state rows alongside the pools (see ``lm.init_paged_caches``).

**Copy-on-write prefix sharing** (``prefix_cache=True``): the allocator
keeps per-page refcounts plus a radix index over *page contents* — each
node is keyed by (parent page, the page_size token ids written into it),
so a chain of index hits proves the full token prefix matches and the
cached KV values are exactly what prefill would recompute.  Admission
(``runtime.server``) adopts the matched pages read-only into the new
slot's table and skips their prefill chunks entirely; pages are freed
only when their refcount drops to zero, and the index itself pins
completed prompts' full pages (evicted leaf-first under pool pressure).
Shared pages are never written: adopters only append at positions past
the matched (page-aligned) prefix, i.e. strictly later pages.  The
quantized (int8/fp8) value+scale pools ride the same page tables, so
they share identically for free.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

#: physical page reserved for unmapped table entries / padded writes
GARBAGE_PAGE = 0

#: storage dtypes a page pool supports; "bf16" means "the model dtype"
#: (no quantization), the narrow ones store 1 byte/elem plus an fp16
#: per-position scale
PAGE_DTYPES = ("bf16", "int8", "fp8")

_INT8_QMAX = 127.0
_FP8_QMAX = 448.0  # float8_e4m3fn finite max
_FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)


@dataclasses.dataclass(frozen=True)
class PagedConfig:
    """Page-pool geometry shared by the scheduler and the compiled steps.

    ``num_pages`` INCLUDES the reserved garbage page 0, so the pool holds
    ``(num_pages - 1) * page_size`` usable token positions.
    ``pages_per_slot`` is the page-table width — the per-slot sequence
    ceiling is ``pages_per_slot * page_size`` (the paged analogue of
    ``s_max``, but it bounds only the *table*, not the memory: unmapped
    entries cost nothing).

    ``page_dtype`` picks the pool storage format: "bf16" stores the model
    dtype verbatim; "int8"/"fp8" store 1 byte per element plus an fp16
    per-position scale pool (symmetric, shared across the feature dim —
    see :func:`quantize_tokens`).  Quant/dequant happens at the pool
    boundary (:func:`append_tokens_q` / :func:`gather_pages_q`); attention
    itself always runs on dequantized full-width values.
    """

    page_size: int = 8
    num_pages: int = 64
    pages_per_slot: int = 8
    page_dtype: str = "bf16"

    def __post_init__(self):
        if self.page_size < 1 or self.num_pages < 2 or self.pages_per_slot < 1:
            raise ValueError(f"degenerate page geometry: {self}")
        if self.page_dtype not in PAGE_DTYPES:
            raise ValueError(f"page_dtype must be one of {PAGE_DTYPES}, "
                             f"got {self.page_dtype!r}")

    @property
    def quantized(self) -> bool:
        return self.page_dtype != "bf16"

    @property
    def max_seq(self) -> int:
        """Per-slot sequence ceiling (page-table width x page size)."""
        return self.pages_per_slot * self.page_size

    @property
    def capacity_tokens(self) -> int:
        """Usable pool capacity (excludes the garbage page)."""
        return (self.num_pages - 1) * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` positions."""
        return -(-n_tokens // self.page_size)


class PageAllocator:
    """Host-side page bookkeeping for one pool (numpy only, no jax).

    Not thread-safe; the scheduler owns it.  ``False`` returns mean the
    pool is exhausted — the caller defers (backpressure) rather than
    raising, because a continuous-batching scheduler can simply keep
    decoding its live slots until pages free up.

    With ``prefix_cache=True`` the allocator additionally maintains
    per-page refcounts and a radix index over page contents (copy-on-
    write prefix sharing — see the module docstring): ``match_prefix``
    walks the index, ``adopt`` maps shared pages into a slot, and
    ``register_prefix`` pins a completed prompt's full pages for future
    admissions.  ``release`` decrements refcounts and frees only at
    zero.  Without the flag every page has exactly one owner and the
    behavior is the seed allocator's, bit for bit.
    """

    def __init__(self, cfg: PagedConfig, slots: int,
                 prefix_cache: bool = False):
        self.cfg = cfg
        self.slots = slots
        self.prefix_cache = prefix_cache
        self._free = list(range(cfg.num_pages - 1, GARBAGE_PAGE, -1))
        self._owned: list[list[int]] = [[] for _ in range(slots)]
        #: page -> mapping count (slot mappings + 1 if pinned by the index)
        self._refs: dict[int, int] = {}
        #: radix node: (parent page id or -1, page-content tokens) -> page
        self._radix: dict[tuple[int, tuple[int, ...]], int] = {}
        self._radix_rev: dict[int, tuple[int, tuple[int, ...]]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def slot_pages(self, slot: int) -> tuple[int, ...]:
        return tuple(self._owned[slot])

    @property
    def live_pages(self) -> int:
        """Distinct pages mapped by at least one slot (shared counted once)."""
        return len({p for owned in self._owned for p in owned})

    @property
    def pages_shared(self) -> int:
        """Slot-mapped page references beyond each page's first mapping —
        the device pages copy-on-write sharing is currently saving."""
        counts: dict[int, int] = {}
        for owned in self._owned:
            for p in owned:
                counts[p] = counts.get(p, 0) + 1
        return sum(c - 1 for c in counts.values() if c > 1)

    @property
    def pinned_pages(self) -> int:
        """Pages held (only) by the prefix index, reusable or evictable."""
        return len(self._radix_rev)

    @property
    def held_pages(self) -> int:
        """Distinct non-free pages — slot-mapped or index-pinned, each
        counted once regardless of refcount (what honest cache-bytes
        accounting bills)."""
        return len({p for owned in self._owned for p in owned}
                   | set(self._radix_rev))

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s mapping to cover ``n_tokens`` positions.

        Returns False (allocating nothing) when the pool cannot satisfy
        the request — transient backpressure the caller retries.  A
        request exceeding the page-table WIDTH raises instead: no amount
        of waiting can map more than ``pages_per_slot`` pages, so the
        scheduler must reject it at submit time (``Server.submit``).
        Under pool pressure, index-pinned pages no slot maps are evicted
        (leaf-first, so the radix never strands unreachable children).
        """
        need = self.cfg.pages_for(n_tokens)
        if need > self.cfg.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens need {need} pages > "
                f"pages_per_slot={self.cfg.pages_per_slot}")
        grow = need - len(self._owned[slot])
        if grow <= 0:
            return True
        if grow > len(self._free):
            self._evict(grow - len(self._free))
        if grow > len(self._free):
            return False
        for _ in range(grow):
            p = self._free.pop()
            self._refs[p] = 1
            self._owned[slot].append(p)
        return True

    def release(self, slot: int) -> None:
        """Unmap all of ``slot``'s pages (slot recycle): refcounts drop by
        one and only pages nobody else maps (and the prefix index does
        not pin) return to the free list."""
        pages = self._owned[slot]
        for p in reversed(pages):
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)
        self._owned[slot] = []

    # -- copy-on-write prefix sharing (radix index over page contents) ----

    def match_prefix(self, tokens) -> tuple[int, ...]:
        """Longest chain of cached full pages covering a prefix of
        ``tokens``.  Each hop matches one page's exact contents under its
        parent, so a k-page hit proves tokens[:k*page_size] equality."""
        if not self.prefix_cache:
            return ()
        ps = self.cfg.page_size
        toks = [int(t) for t in tokens]
        out: list[int] = []
        parent = -1
        for j in range(len(toks) // ps):
            page = self._radix.get((parent, tuple(toks[j * ps:(j + 1) * ps])))
            if page is None:
                break
            out.append(page)
            parent = page
        return tuple(out)

    def adopt(self, slot: int, pages) -> None:
        """Map shared (prefix-cache) pages read-only into an empty slot.

        The pages come first in the slot's table — the caller must adopt
        before any private ``ensure`` growth, and must only write
        positions past the adopted prefix (COW: shared pages are never
        mutated; a diverging suffix lands in later, private pages)."""
        if self._owned[slot]:
            raise ValueError(
                f"slot {slot}: adopt() must precede private page growth "
                f"(owns {len(self._owned[slot])} pages)")
        for p in pages:
            self._refs[p] = self._refs.get(p, 0) + 1
            self._owned[slot].append(p)

    def register_prefix(self, slot: int, tokens) -> int:
        """Index ``slot``'s fully-written prompt pages for future reuse.

        Called when a prompt's prefill completes: every page whose
        page_size positions are all covered by prompt tokens becomes a
        radix node (+1 pin ref).  Pages already indexed under the same
        content chain are walked, not re-registered, so concurrent
        identical prompts converge on one physical copy.  Returns the
        number of newly indexed pages."""
        if not self.prefix_cache:
            return 0
        ps = self.cfg.page_size
        toks = [int(t) for t in tokens]
        owned = self._owned[slot]
        parent = -1
        added = 0
        for j in range(len(toks) // ps):
            if j >= len(owned):
                break
            key = (parent, tuple(toks[j * ps:(j + 1) * ps]))
            hit = self._radix.get(key)
            if hit is not None:
                parent = hit
                continue
            page = owned[j]
            if page in self._radix_rev:
                # already indexed under a different chain — re-keying
                # would corrupt both chains; stop here
                break
            self._radix[key] = page
            self._radix_rev[page] = key
            self._refs[page] = self._refs.get(page, 0) + 1
            parent = page
            added += 1
        return added

    def drop_prefix_index(self) -> int:
        """Unpin the whole prefix index (operator reset); pages nobody
        maps return to the free list.  Returns pages freed."""
        freed = 0
        for page in list(self._radix_rev):
            self._unpin(page)
            if self._refs.get(page) is None:
                freed += 1
        return freed

    def _unpin(self, page: int) -> None:
        key = self._radix_rev.pop(page)
        del self._radix[key]
        self._refs[page] -= 1
        if self._refs[page] == 0:
            del self._refs[page]
            self._free.append(page)

    def evict_pinned(self, n: int) -> int:
        """Pressure-eviction hook: free up to ``n`` index-only pages.

        The degradation ladder (``runtime.server``) calls this *before*
        pool exhaustion forces reactive eviction inside ``ensure`` — the
        same leaf-first, refcount-safe walk, surfaced so a scheduler can
        shed cache weight on a low-water-mark signal instead of on the
        first failed allocation.  Returns the number of pages freed
        (less than ``n`` when only slot-mapped or interior pages remain).
        """
        return self._evict(n)

    def _evict(self, n: int) -> int:
        """Free up to ``n`` pages held only by the prefix index —
        leaf-first (never a node with indexed children, so surviving
        chains stay reachable), newest-registered first.  Returns pages
        freed."""
        freed = 0
        while freed < n and self._radix:
            mapped = {p for owned in self._owned for p in owned}
            parents = {k[0] for k in self._radix}
            victim = None
            for page in reversed(list(self._radix_rev)):
                if page not in parents and page not in mapped:
                    victim = page
                    break
            if victim is None:
                return freed
            self._unpin(victim)
            freed += 1
        return freed

    def table(self) -> np.ndarray:
        """The ``[slots, pages_per_slot]`` int32 device table; unmapped
        entries point at the garbage page."""
        t = np.full((self.slots, self.cfg.pages_per_slot), GARBAGE_PAGE,
                    np.int32)
        for s, pages in enumerate(self._owned):
            t[s, : len(pages)] = pages
        return t


# ---------------------------------------------------------------------------
# Device-side accessors (pure jax; run inside shard_map on local shards).
# ---------------------------------------------------------------------------


def gather_pages(pages, table):
    """Materialize each slot's mapped positions from the pool.

    pages [num_pages, page, ...feat]; table [B, mp] ->
    [B, mp * page, ...feat].  Unmapped entries read the garbage page;
    callers mask those positions by the slot's length (exactly like the
    dense cache masks positions beyond ``len``), so the values never
    reach a softmax unmasked.
    """
    g = jnp.take(pages, table, axis=0)            # [B, mp, page, ...]
    return g.reshape((table.shape[0], table.shape[1] * pages.shape[1])
                     + pages.shape[2:])


def append_tokens(pages, table, start, values):
    """Scatter per-slot token runs into the pool through the page table.

    pages [num_pages, page, ...feat]; table [B, mp]; start [B] (each
    slot's first logical position for this run); values [B, s, ...feat].
    Position p of slot b lands in physical page ``table[b, p // page]``
    at offset ``p % page``.  Writes beyond a slot's valid length (padded
    chunk tails, inactive decode slots whose table rows are unmapped)
    land on pages that are either overwritten by the very next tokens of
    the same slot or are the garbage page — never read unmasked.
    """
    B, s = values.shape[:2]
    page = pages.shape[1]
    pos = start[:, None] + jnp.arange(s, dtype=start.dtype)[None, :]  # [B,s]
    logical = pos // page
    # clamp: positions past the table width scatter to the garbage page
    # (cannot happen for well-formed schedules; defensive for padding)
    ok = logical < table.shape[1]
    phys = jnp.where(
        ok, jnp.take_along_axis(table, jnp.minimum(
            logical, table.shape[1] - 1), axis=1), GARBAGE_PAGE)
    off = pos % page
    return pages.at[phys, off].set(values.astype(pages.dtype))


# ---------------------------------------------------------------------------
# Quantized pools: int8/fp8 storage + fp16 per-position scales.
# ---------------------------------------------------------------------------


def page_store_dtype(page_dtype: str):
    """The jnp storage dtype for a quantized pool (None = model dtype).

    "fp8" falls back to int8 storage on jax builds without
    ``float8_e4m3fn`` — same byte count, slightly different grid.
    """
    if page_dtype == "int8":
        return jnp.int8
    if page_dtype == "fp8":
        return _FP8_DTYPE if _FP8_DTYPE is not None else jnp.int8
    if page_dtype == "bf16":
        return None
    raise ValueError(f"page_dtype must be one of {PAGE_DTYPES}, "
                     f"got {page_dtype!r}")


def pool_page_dtype(pages) -> str:
    """Recover the PAGE_DTYPES tag from a pool tensor's storage dtype.

    The compiled step sees only the cache tree, not the PagedConfig, so
    the quant path keys off the pool dtype itself (fp8-fallback pools
    stored as int8 correctly report "int8" — their grid)."""
    if pages.dtype == jnp.int8:
        return "int8"
    if _FP8_DTYPE is not None and pages.dtype == _FP8_DTYPE:
        return "fp8"
    return "bf16"


def quantize_tokens(values, page_dtype: str):
    """values [..., feat] -> (quantized [..., feat], fp16 scales [...]).

    Symmetric per-position quantization: one scale per token position
    (shared over the trailing feature dim), so the scale pool is a
    parallel paged tensor with the feature dim dropped and rides the same
    page tables through :func:`append_tokens` / :func:`gather_pages`.
    Scales are stored fp16 — at head_dim >= 32 an f32 scale alone would
    eat the margin below a 1.8x pool-byte reduction.
    """
    vf = values.astype(jnp.float32)
    amax = jnp.max(jnp.abs(vf), axis=-1)
    fp8 = page_dtype == "fp8" and _FP8_DTYPE is not None
    qmax = _FP8_QMAX if fp8 else _INT8_QMAX
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = vf / scale[..., None]
    if fp8:
        q = q.astype(_FP8_DTYPE)
    else:
        q = jnp.clip(jnp.round(q), -_INT8_QMAX, _INT8_QMAX).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def append_tokens_q(pages, scales, table, start, values, page_dtype: str):
    """Quant-aware :func:`append_tokens`: returns (new_pages, new_scales).

    ``scales is None`` means the pool is full-width — plain append, scale
    pool untouched.  Otherwise the values are quantized per position and
    both the value pool and the parallel scale pool are scattered through
    the same table."""
    if scales is None:
        return append_tokens(pages, table, start, values), None
    q, s = quantize_tokens(values, page_dtype)
    return (append_tokens(pages, table, start, q),
            append_tokens(scales, table, start, s))


def gather_pages_q(pages, scales, table, out_dtype=jnp.bfloat16):
    """Quant-aware :func:`gather_pages`: dequantize at the pool boundary.

    ``scales is None`` -> plain gather.  Otherwise gathers values and
    scales through the same table and returns ``values * scale`` in
    ``out_dtype`` (attention always runs full-width)."""
    if scales is None:
        return gather_pages(pages, table)
    v = gather_pages(pages, table).astype(jnp.float32)
    s = gather_pages(scales, table).astype(jnp.float32)
    return (v * s[..., None]).astype(out_dtype)
