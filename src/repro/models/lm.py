"""Unified language model: embedding -> block stacks -> head, ATP-sharded.

Every architecture is expressed as a list of *segments*; each segment is a
scan over `count` identical blocks with stacked params (compile-time
compact HLO).  Segment kinds:

  dense       GQA attention + MLP (all dense archs; gemma2 via window array)
  moe         GQA attention + MoE FFN (dbrx)
  mla_dense   MLA attention + dense MLP (deepseek first 3 layers)
  mla_moe     MLA attention + MoE (deepseek)
  zamba       super-block: shared attention block + 5 mamba2 blocks
  mamba       plain mamba2 blocks (zamba tail)
  xlstm       super-block: 7 mLSTM + 1 sLSTM

All functions here run INSIDE shard_map (local shards + explicit
collectives) except the init/spec helpers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ModelConfig, Segment,  # noqa: F401 (re-export)
                                ShapeConfig, segments)
from repro.core import compat
from repro.core.atp import (ATPContext, atp_boundary,
                            atp_reduce_scatter, seq_gather, seq_scatter,
                            shard_slice)
from repro.models import layers as L
from repro.models import mamba2, mla, moe, paging, transformer, xlstm

# The segment plan (Segment / segments) lives in repro.configs.base so the
# strategy stack can derive per-segment workloads without importing model
# code; re-exported here because this module is its execution consumer.


# ---------------------------------------------------------------------------
# Per-kind params / specs / apply.
# ---------------------------------------------------------------------------


def _block_params(kind: str, key, cfg: ModelConfig, dtype):
    if kind == "dense":
        return transformer.dense_block_params(key, cfg, dtype)
    if kind == "moe":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln_attn": L.norm_params(cfg, cfg.d_model),
            "attn": transformer.attn_params(k1, cfg, dtype),
            "ln_mlp": L.norm_params(cfg, cfg.d_model),
            "moe": moe.moe_params(k2, cfg, dtype),
        }
    if kind == "mla_dense":
        k1, k2 = jax.random.split(key)
        return {
            "ln_attn": L.norm_params(cfg, cfg.d_model),
            "mla": mla.mla_params(k1, cfg, dtype),
            "ln_mlp": L.norm_params(cfg, cfg.d_model),
            "mlp": transformer.mlp_params(k2, cfg, dtype),
        }
    if kind == "mla_moe":
        k1, k2 = jax.random.split(key)
        return {
            "ln_attn": L.norm_params(cfg, cfg.d_model),
            "mla": mla.mla_params(k1, cfg, dtype),
            "ln_mlp": L.norm_params(cfg, cfg.d_model),
            "moe": moe.moe_params(k2, cfg, dtype),
        }
    if kind == "mamba":
        return mamba2.mamba_params(key, cfg, dtype)
    if kind == "zamba":
        # stacked part: (per-1) mamba blocks per super-block
        per = cfg.ssm.shared_attn_every
        ks = jax.random.split(key, per - 1)
        return {"mamba": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[mamba2.mamba_params(k, cfg, dtype) for k in ks])}
    if kind == "xlstm":
        per = cfg.ssm.slstm_every
        ks = jax.random.split(key, per)
        ml = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[xlstm.mlstm_params(k, cfg, dtype) for k in ks[:-1]])
        sl = xlstm.slstm_params(ks[-1], cfg, dtype)
        return {"mlstm": ml, "slstm": sl}
    raise ValueError(kind)


def _block_specs(kind: str, ctx: ATPContext, cfg: ModelConfig):
    nspec = {"scale": L.feat_spec(ctx)}
    if cfg.norm_kind == "layernorm":
        nspec["bias"] = L.feat_spec(ctx)
    if kind == "dense":
        return transformer.dense_block_specs(ctx, cfg)
    if kind == "moe":
        return {
            "ln_attn": dict(nspec),
            "attn": transformer.attn_param_specs(ctx, cfg),
            "ln_mlp": dict(nspec),
            "moe": moe.moe_param_specs(ctx, cfg),
        }
    if kind == "mla_dense":
        return {
            "ln_attn": dict(nspec),
            "mla": mla.mla_param_specs(ctx, cfg),
            "ln_mlp": dict(nspec),
            "mlp": transformer.mlp_param_specs(ctx, cfg),
        }
    if kind == "mla_moe":
        return {
            "ln_attn": dict(nspec),
            "mla": mla.mla_param_specs(ctx, cfg),
            "ln_mlp": dict(nspec),
            "moe": moe.moe_param_specs(ctx, cfg),
        }
    if kind == "mamba":
        return mamba2.mamba_param_specs(ctx, cfg)
    if kind == "zamba":
        return {"mamba": _stack_specs(mamba2.mamba_param_specs(ctx, cfg))}
    if kind == "xlstm":
        return {"mlstm": _stack_specs(xlstm.mlstm_param_specs(ctx, cfg)),
                "slstm": xlstm.slstm_param_specs(ctx, cfg)}
    raise ValueError(kind)


def _stack_specs(specs):
    return jax.tree.map(lambda s: P(None, *s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _apply_block(kind: str, ctx, cfg, p, x, positions, plan, window, cache,
                 emb0=None, shared=None, paged=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "dense":
        x, nc = transformer.dense_block(ctx, cfg, p, x, positions, plan,
                                        layer_window=window, cache=cache,
                                        paged=paged)
        return x, nc, aux
    if kind == "moe":
        h = L.norm(ctx, cfg, x, p["ln_attn"])
        a, nc = transformer.attn_block(ctx, cfg, p["attn"], h, positions, plan,
                                       layer_window=window, cache=cache,
                                       paged=paged)
        x = x + a
        h = L.norm(ctx, cfg, x, p["ln_mlp"])
        m, aux = moe.moe_block(ctx, cfg, p["moe"], h)
        return x + m, nc, aux
    if kind in ("mla_dense", "mla_moe"):
        # mla_dense supports the sequence-parallel spec: entry norms fold
        # the seq all-gather, and the wo / mlp-down row boundaries
        # psum_scatter back (mla_moe's ctx arrives with seq_parallel
        # masked — MoE dispatch needs ax1-replicated full-sequence I/O)
        sp = ctx.seq_parallel and cache is None
        h = L.norm(ctx, cfg, x, p["ln_attn"], gather_seq=sp)
        a, nc = mla.mla_block(ctx, cfg, p["mla"], h, positions, cache=cache,
                              paged=paged)
        x = x + a
        h = L.norm(ctx, cfg, x, p["ln_mlp"], gather_seq=sp)
        if kind == "mla_dense":
            m = transformer.mlp_block(ctx, cfg, p["mlp"], h)
        else:
            m, aux = moe.moe_block(ctx, cfg, p["moe"], h)
        return x + m, nc, aux
    if kind == "mamba":
        x, ns = mamba2.mamba_block(ctx, cfg, p, x, state=cache)
        return x, ns, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full model params/specs.
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key, dtype=None) -> dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 16)
    h = cfg.d_model
    p: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, h), jnp.float32)
                  * 0.02).astype(dtype),
        "final_norm": L.norm_params(cfg, h),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(keys[1], (h, cfg.vocab_size), jnp.float32)
                        / math.sqrt(h)).astype(dtype)
    for i, seg in enumerate(segments(cfg)):
        ks = jax.random.split(keys[2 + i], seg.count)
        p[f"seg{i}"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_block_params(seg.kind, k, cfg, dtype) for k in ks])
    if any(s.kind == "zamba" for s in segments(cfg)):
        k1, k2, k3 = jax.random.split(keys[14], 3)
        # two separate [h, h] projections (a single [2h, h] would break the
        # ax2 row sharding of the concatenated input)
        p["shared_attn"] = {
            "w_in_h": (jax.random.normal(k1, (h, h), jnp.float32)
                       / math.sqrt(2 * h)).astype(dtype),
            "w_in_e": (jax.random.normal(k3, (h, h), jnp.float32)
                       / math.sqrt(2 * h)).astype(dtype),
            "block": transformer.dense_block_params(k2, cfg, dtype),
        }
    if cfg.mtp:
        k1, k2 = jax.random.split(keys[15])
        p["mtp"] = {
            "proj_h": (jax.random.normal(k1, (h, h), jnp.float32)
                       / math.sqrt(2 * h)).astype(dtype),
            "proj_e": (jax.random.normal(k2, (h, h), jnp.float32)
                       / math.sqrt(2 * h)).astype(dtype),
            "block": _block_params("mla_dense" if cfg.mla else "dense",
                                   keys[13], cfg, dtype),
            "norm": L.norm_params(cfg, h),
        }
    return p


def param_specs(cfg: ModelConfig, ctx: ATPContext) -> dict[str, Any]:
    sp: dict[str, Any] = {
        "embed": L.embed_spec(ctx),
        "final_norm": {"scale": L.feat_spec(ctx)},
    }
    if cfg.norm_kind == "layernorm":
        sp["final_norm"]["bias"] = L.feat_spec(ctx)
    if not cfg.tie_embeddings:
        sp["lm_head"] = P(ctx.ax2, ctx.ax1)  # rows over ax2, vocab over ax1
    for i, seg in enumerate(segments(cfg)):
        sp[f"seg{i}"] = _stack_specs(_block_specs(seg.kind, ctx, cfg))
    if any(s.kind == "zamba" for s in segments(cfg)):
        sp["shared_attn"] = {
            "w_in_h": L.col_w_spec(ctx),
            "w_in_e": L.col_w_spec(ctx),
            "block": transformer.dense_block_specs(ctx, cfg),
        }
    if cfg.mtp:
        sp["mtp"] = {
            "proj_h": L.col_w_spec(ctx),
            "proj_e": L.col_w_spec(ctx),
            "block": _block_specs("mla_dense" if cfg.mla else "dense", ctx, cfg),
            "norm": {"scale": L.feat_spec(ctx)},
        }
    return sp


def abstract_params(cfg: ModelConfig, dtype=None):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Decode caches: global shapes + PartitionSpecs per segment kind.
# Replication (kv heads shared across r-group ranks, mLSTM conv) is stored
# explicitly in the global array — memory honesty for the dry-run.
# ---------------------------------------------------------------------------


def _flat_axes(ctx: ATPContext):
    return ctx.tp_axes if ctx.tp_axes else None


def _attn_cache_shape(cfg: ModelConfig, ctx: ATPContext, B: int, s_max: int):
    plan = L.make_attn_plan(ctx, cfg.num_heads, cfg.num_kv_heads)
    banks = ctx.tp * plan.kv_count
    return (B, s_max, banks, cfg.hd)


def init_decode_caches(cfg: ModelConfig, ctx: ATPContext, B: int, s_max: int,
                       dtype=jnp.bfloat16, abstract: bool = False):
    """Returns (caches, specs): per-segment stacked cache trees (GLOBAL
    shapes) and matching PartitionSpecs for shard_map."""
    n = ctx.tp
    # batch < DP degree (long_500k: B=1): replicate over the data axes —
    # DP ranks are idle for single-stream long-context decode
    dp_ok = ctx.dp_axes and B % ctx.dp == 0
    data_ax = ctx.dp_axes if dp_ok else None
    flat = _flat_axes(ctx)

    def arr(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    def attn_cache(count):
        shape = (count,) + _attn_cache_shape(cfg, ctx, B, s_max)
        c = {"k": arr(shape, dtype), "v": arr(shape, dtype),
             "len": arr((count,), jnp.int32)}
        sp = {"k": P(None, data_ax, None, flat, None),
              "v": P(None, data_ax, None, flat, None),
              "len": P(None)}
        return c, sp

    def mla_cache(count):
        m = cfg.mla
        c = {"ckv": arr((count, B, s_max, m.kv_lora_rank), dtype),
             "krope": arr((count, B, s_max, m.qk_rope_head_dim), dtype),
             "len": arr((count,), jnp.int32)}
        sp = {"ckv": P(None, data_ax, None, None),
              "krope": P(None, data_ax, None, None),
              "len": P(None)}
        return c, sp

    def mamba_cache(count):
        d_inner, nheads = mamba2.mamba_dims(cfg)
        k = cfg.ssm.conv_kernel
        c = {"conv_x": arr((count, B, k - 1, d_inner), dtype),
             "conv_bc": arr((count, B, k - 1, 2 * cfg.ssm.d_state), dtype),
             "ssd": arr((count, B, nheads, cfg.ssm.head_dim, cfg.ssm.d_state),
                        jnp.float32)}
        sp = {"conv_x": P(None, data_ax, None, flat),
              "conv_bc": P(None, data_ax, None, None),
              "ssd": P(None, data_ax, flat, None, None)}
        return c, sp

    def mlstm_cache(count):
        d_inner, nh, dk, dv = xlstm.mlstm_dims(cfg)
        g, r = xlstm.mlstm_plan(ctx, cfg)
        k = cfg.ssm.conv_kernel
        # conv state channels are flat-sharded (v2 head-major layout)
        c = {"conv": arr((count, B, k - 1, d_inner), dtype),
             "C": arr((count, B, n, nh // g, dk, dv // r + 1), jnp.float32)}
        sp = {"conv": P(None, data_ax, None, flat),
              "C": P(None, data_ax, flat, None, None, None)}
        return c, sp

    def slstm_cache(count):
        nh, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
        c = {k2: arr((count, B, nh, dh), jnp.float32) for k2 in ("c", "n", "h")}
        sp = {k2: P(None, data_ax, None, None) for k2 in ("c", "n", "h")}
        return c, sp

    caches, specs = {}, {}
    for i, seg in enumerate(segments(cfg)):
        if seg.kind in ("dense", "moe"):
            caches[f"seg{i}"], specs[f"seg{i}"] = attn_cache(seg.count)
        elif seg.kind in ("mla_dense", "mla_moe"):
            caches[f"seg{i}"], specs[f"seg{i}"] = mla_cache(seg.count)
        elif seg.kind == "mamba":
            caches[f"seg{i}"], specs[f"seg{i}"] = mamba_cache(seg.count)
        elif seg.kind == "zamba":
            ac, asp = attn_cache(seg.count)
            mc, msp = mamba_cache(seg.count)
            mc = jax.tree.map(
                lambda x: (jax.ShapeDtypeStruct(
                    (x.shape[0], seg.inner - 1) + x.shape[1:], x.dtype)
                    if abstract else
                    jnp.zeros((x.shape[0], seg.inner - 1) + x.shape[1:], x.dtype)),
                mc)
            msp = jax.tree.map(lambda s: P(None, *s), msp,
                               is_leaf=lambda x: isinstance(x, P))
            caches[f"seg{i}"] = {"attn": ac, "mamba": mc}
            specs[f"seg{i}"] = {"attn": asp, "mamba": msp}
        elif seg.kind == "xlstm":
            mc, msp = mlstm_cache(seg.count)
            mc = jax.tree.map(
                lambda x: (jax.ShapeDtypeStruct(
                    (x.shape[0], seg.inner - 1) + x.shape[1:], x.dtype)
                    if abstract else
                    jnp.zeros((x.shape[0], seg.inner - 1) + x.shape[1:], x.dtype)),
                mc)
            msp = jax.tree.map(lambda s: P(None, *s), msp,
                               is_leaf=lambda x: isinstance(x, P))
            sc, ssp = slstm_cache(seg.count)
            caches[f"seg{i}"] = {"mlstm": mc, "slstm": sc}
            specs[f"seg{i}"] = {"mlstm": msp, "slstm": ssp}
    return caches, specs


#: segment kinds whose O(s) caches live in a block-paged pool.
PAGED_CACHE_KINDS = frozenset({"dense", "moe", "mla_dense", "mla_moe"})

#: segment kinds holding O(1)-per-slot recurrent state.  They have no
#: token axis to page; instead ``init_paged_caches`` gives them per-slot
#: STATE POOLS (a ``slots`` axis where the dense cache has batch) and the
#: forward pass gathers/scatters each batch row's state by its slot id —
#: masked rows carry the sentinel id ``slots`` and their scatter drops,
#: which is what lets a b=1 prefill chunk or a partially-live decode tick
#: touch only its own slot's state.
RECURRENT_STATE_KINDS = frozenset({"mamba", "zamba", "xlstm"})


def init_paged_caches(cfg: ModelConfig, ctx: ATPContext,
                      pcfg: "paging.PagedConfig",
                      dtype=jnp.bfloat16, abstract: bool = False,
                      slots: int | None = None):
    """Block-paged decode caches: (caches, specs) page pools per segment.

    Unlike :func:`init_decode_caches` there is no per-slot ``s_max`` axis
    and no ``len`` leaf: every O(s) cache tensor stores
    ``num_pages x page_size`` token positions shared by all serving
    slots, and per-slot position state (page table rows + lengths) is
    passed into each step by the scheduler (``runtime.server``).  Memory
    scales with *live tokens*, not ``slots x s_max``.

    Per segment kind:
      attn (dense/moe)   k/v pools ``[count, np, pg, banks, hd]``, the
                         bank dim sharded over the flat TP axes exactly
                         like the dense cache;
      mla (mla_dense/moe) latent pools ``[count, np, pg, rank]`` +
                         ``[count, np, pg, rope_dim]``, TP-replicated
                         (caching the latent is MLA's whole point);
      mamba/zamba/xlstm  O(1)-per-slot recurrent state — not paged but
                         *pooled*: dense-cache shapes with the batch axis
                         replaced by a ``slots`` axis (slot-replicated,
                         so any batch row can address any slot).  These
                         kinds require ``slots`` (the scheduler's
                         ``batch_slots``) and a per-row ``slot`` id map
                         fed to each step.
    """
    n = ctx.tp
    flat = _flat_axes(ctx)
    np_, pg = pcfg.num_pages, pcfg.page_size
    store = paging.page_store_dtype(pcfg.page_dtype)
    pool_dtype = dtype if store is None else store

    def arr(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    def attn_pool(count):
        banks = _attn_cache_shape(cfg, ctx, 1, 1)[2]
        shape = (count, np_, pg, banks, cfg.hd)
        c = {"k": arr(shape, pool_dtype), "v": arr(shape, pool_dtype)}
        sp = {"k": P(None, None, None, flat, None),
              "v": P(None, None, None, flat, None)}
        if pcfg.quantized:
            # parallel scale pools: same paging, feature dim dropped
            c["k_scale"] = arr((count, np_, pg, banks), jnp.float16)
            c["v_scale"] = arr((count, np_, pg, banks), jnp.float16)
            sp["k_scale"] = P(None, None, None, flat)
            sp["v_scale"] = P(None, None, None, flat)
        return c, sp

    def mla_pool(count):
        m = cfg.mla
        c = {"ckv": arr((count, np_, pg, m.kv_lora_rank), pool_dtype),
             "krope": arr((count, np_, pg, m.qk_rope_head_dim), pool_dtype)}
        sp = {"ckv": P(None, None, None, None),
              "krope": P(None, None, None, None)}
        if pcfg.quantized:
            c["ckv_scale"] = arr((count, np_, pg), jnp.float16)
            c["krope_scale"] = arr((count, np_, pg), jnp.float16)
            sp["ckv_scale"] = P(None, None, None)
            sp["krope_scale"] = P(None, None, None)
        return c, sp

    # recurrent state pools: the dense-cache builders with B -> slots and
    # the slot axis replicated (a b=1 prefill row must reach ANY slot)
    def mamba_state(count):
        d_inner, nheads = mamba2.mamba_dims(cfg)
        k = cfg.ssm.conv_kernel
        c = {"conv_x": arr((count, slots, k - 1, d_inner), dtype),
             "conv_bc": arr((count, slots, k - 1, 2 * cfg.ssm.d_state), dtype),
             "ssd": arr((count, slots, nheads, cfg.ssm.head_dim,
                         cfg.ssm.d_state), jnp.float32)}
        sp = {"conv_x": P(None, None, None, flat),
              "conv_bc": P(None, None, None, None),
              "ssd": P(None, None, flat, None, None)}
        return c, sp

    def mlstm_state(count):
        d_inner, nh, dk, dv = xlstm.mlstm_dims(cfg)
        g, r = xlstm.mlstm_plan(ctx, cfg)
        k = cfg.ssm.conv_kernel
        c = {"conv": arr((count, slots, k - 1, d_inner), dtype),
             "C": arr((count, slots, n, nh // g, dk, dv // r + 1),
                      jnp.float32)}
        sp = {"conv": P(None, None, None, flat),
              "C": P(None, None, flat, None, None, None)}
        return c, sp

    def slstm_state(count):
        nh, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
        c = {k2: arr((count, slots, nh, dh), jnp.float32)
             for k2 in ("c", "n", "h")}
        sp = {k2: P(None, None, None, None) for k2 in ("c", "n", "h")}
        return c, sp

    def stack_inner(tree, sp_tree, inner):
        tree = jax.tree.map(
            lambda x: (jax.ShapeDtypeStruct(
                (x.shape[0], inner) + x.shape[1:], x.dtype)
                if abstract else
                jnp.zeros((x.shape[0], inner) + x.shape[1:], x.dtype)),
            tree)
        sp_tree = jax.tree.map(lambda s: P(None, *s), sp_tree,
                               is_leaf=lambda x: isinstance(x, P))
        return tree, sp_tree

    if slots is None and any(s.kind in RECURRENT_STATE_KINDS
                             for s in segments(cfg)):
        raise ValueError(
            "paged serving of recurrent kinds (mamba/zamba/xlstm) needs "
            "slots=<scheduler batch_slots> to size the per-slot state "
            "pools (init_paged_caches(..., slots=...))")

    caches, specs = {}, {}
    for i, seg in enumerate(segments(cfg)):
        if seg.kind in ("dense", "moe"):
            caches[f"seg{i}"], specs[f"seg{i}"] = attn_pool(seg.count)
        elif seg.kind in ("mla_dense", "mla_moe"):
            caches[f"seg{i}"], specs[f"seg{i}"] = mla_pool(seg.count)
        elif seg.kind == "mamba":
            caches[f"seg{i}"], specs[f"seg{i}"] = mamba_state(seg.count)
        elif seg.kind == "zamba":
            ac, asp = attn_pool(seg.count)
            mc, msp = stack_inner(*mamba_state(seg.count), seg.inner - 1)
            caches[f"seg{i}"] = {"attn": ac, "mamba": mc}
            specs[f"seg{i}"] = {"attn": asp, "mamba": msp}
        elif seg.kind == "xlstm":
            mc, msp = stack_inner(*mlstm_state(seg.count), seg.inner - 1)
            sc, ssp = slstm_state(seg.count)
            caches[f"seg{i}"] = {"mlstm": mc, "slstm": sc}
            specs[f"seg{i}"] = {"mlstm": msp, "slstm": ssp}
        else:
            raise ValueError(seg.kind)
    return caches, specs


def _state_take(pool, slot, fresh=None):
    """Gather per-slot recurrent state rows ``[b, ...]`` from a per-layer
    state pool ``[slots, ...]``.  Out-of-range ids (the masked-row
    sentinel ``slots``) read row 0 — harmless, because the conjugate
    :func:`_state_put` drops their writes.

    ``fresh`` ([b] bool) zeroes the gathered rows for requests whose fed
    window starts at position 0: a recycled slot's pool row still holds
    the previous occupant's state, and unlike the page table (which is
    remapped at admission) recurrent state has no per-token addressing to
    hide behind — it must be reset exactly when a new prompt begins."""
    def take(a):
        r = jnp.take(a, jnp.clip(slot, 0, a.shape[0] - 1), axis=0)
        if fresh is not None:
            keep = jnp.reshape(~fresh, (-1,) + (1,) * (r.ndim - 1))
            r = r * keep.astype(r.dtype)
        return r

    return jax.tree.map(take, pool)


def _state_put(pool, rows, slot):
    """Scatter updated state rows back into the pool.  Ids past the pool
    (sentinel = ``slots``; never negative — JAX wraps those) are dropped,
    so masked batch rows leave every slot's state untouched."""
    return jax.tree.map(
        lambda a, r: a.at[slot].set(r.astype(a.dtype), mode="drop"),
        pool, rows)


# ---------------------------------------------------------------------------
# Embedding / head / loss (vocab-parallel over ax1, feature over ax2).
# ---------------------------------------------------------------------------


def _gather_ax1_invariant(ctx: ATPContext, u):
    """Gather an ax1-sharded feature dim to full width with a provably
    ax1-invariant result (place + psum; all_gather output cannot be typed
    invariant under vma — see DESIGN.md)."""
    if ctx.ax1 is None:
        return u
    full = u.shape[-1] * ctx.d1
    placed = jnp.zeros(u.shape[:-1] + (full,), u.dtype)
    placed = lax.dynamic_update_slice_in_dim(
        placed, u, ctx.index1() * u.shape[-1], axis=u.ndim - 1)
    return lax.psum(placed, ctx.ax1)


def embed_tokens(ctx: ATPContext, cfg: ModelConfig, emb, tokens,
                 scatter_seq: bool = False):
    """emb local [V/d1, h/d2]; tokens [b, s] -> x [b, s, h/d2].

    With ``scatter_seq`` (sequence-parallel entry) the vocab-parallel
    all-reduce over ax1 is fused with the seq slice into one psum_scatter
    — half the ax1 wire bytes of psum-then-slice."""
    v_loc = emb.shape[0]
    rel = tokens - ctx.index1() * v_loc
    ok = (rel >= 0) & (rel < v_loc)
    safe = jnp.clip(rel, 0, v_loc - 1)
    x = jnp.take(emb, safe, axis=0) * ok[..., None].astype(emb.dtype)
    if scatter_seq and ctx.seq_parallel and ctx.ax1 is not None:
        if x.shape[1] % ctx.d1:
            raise ValueError(
                f"seq_parallel requires seq ({x.shape[1]}) divisible by "
                f"d1={ctx.d1}")
        x = atp_reduce_scatter(x, ctx.ax1, dim=1)
    else:
        x = atp_boundary(x, ctx.ax1)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return x


def lm_logits(ctx: ATPContext, cfg: ModelConfig, params, x):
    """x [b, s, h/d2] -> logits [b, s, V/d1] (ax2-replicated)."""
    if cfg.tie_embeddings:
        w = params["embed"].T  # [h/d2, V/d1] local (embed is [V/d1, h/d2])
    else:
        w = params["lm_head"]
    logits = atp_boundary(jnp.einsum("...k,kn->...n", x, w), ctx.ax2)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def vocab_parallel_ce(ctx: ATPContext, logits, labels, ignore: int = -1):
    """logits [b, s, V/d1] local; labels [b, s] global ids.

    Returns per-token loss [b, s] (invariant over TP axes)."""
    lf = logits.astype(jnp.float32)
    v_loc = lf.shape[-1]
    zmax = jnp.max(lax.stop_gradient(lf), axis=-1)
    if ctx.ax1 is not None:
        zmax = lax.pmax(zmax, ctx.ax1)
    sumexp = jnp.sum(jnp.exp(lf - zmax[..., None]), axis=-1)
    sumexp = atp_boundary(sumexp, ctx.ax1)
    lse = jnp.log(sumexp) + zmax
    rel = labels - ctx.index1() * v_loc
    ok = (rel >= 0) & (rel < v_loc)
    safe = jnp.clip(rel, 0, v_loc - 1)
    picked = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    picked = atp_boundary(picked * ok.astype(jnp.float32), ctx.ax1)
    loss = lse - picked
    return jnp.where(labels == ignore, 0.0, loss)


# ---------------------------------------------------------------------------
# Forward (inside shard_map).
# ---------------------------------------------------------------------------


def _gemma_window_array(cfg: ModelConfig, count: int):
    """Per-layer sliding window sizes (0 = global) for alternating archs."""
    if not cfg.local_global_period:
        return jnp.zeros((count,), jnp.int32)
    pat = [cfg.local_window if i % cfg.local_global_period == 0 else 0
           for i in range(count)]
    return jnp.asarray(pat, jnp.int32)


def forward(
    ctx: ATPContext,
    cfg: ModelConfig,
    params,
    tokens,                 # [b, s] int32, or None when embeds given
    positions,              # [b, s] ([3, b, s] for M-RoPE)
    embeds=None,            # [b, s, h/d2] (vision frontend stub)
    caches=None,            # decode: per-segment stacked cache trees
    remat: bool = False,
    paged=None,             # paged serving: dict(table=[b,mp], start=[b])
):
    """Returns (hidden [b, s, h/d2], new_caches, aux_sum, x_emb0).

    Per-segment execution (plan format_version 2): each segment runs under
    ``ctx.for_segment(kind)`` — its own (chunks, boundary_mode,
    seq_parallel) view of the shared mesh.  Kinds outside
    ``SEQ_PARALLEL_KINDS`` have seq_parallel masked by the view, so a
    dense-prefix + MoE stack runs its dense segments sequence-parallel
    while the MoE segment stays on replicated full-sequence block I/O;
    the loop inserts the conjugate seq scatter/gather at every domain
    transition.
    """
    segs = segments(cfg)
    slot = paged.get("slot") if paged is not None else None
    if paged is not None and slot is None and any(
            s.kind in RECURRENT_STATE_KINDS for s in segs):
        raise ValueError(
            "paged serving of recurrent kinds needs paged['slot'] — the "
            "per-row slot ids addressing the state pools (see "
            "launch.steps.build_paged_step)")
    # a row whose fed window starts at 0 is a NEW request in a possibly
    # recycled slot: its gathered state must read as zeros
    fresh = (paged["start"] == 0) if slot is not None else None
    seg_ctxs = tuple(ctx.for_segment(s.kind) for s in segs)
    entry_sp = bool(seg_ctxs) and seg_ctxs[0].seq_parallel
    if caches is not None and any(c.seq_parallel for c in seg_ctxs):
        raise NotImplementedError("seq_parallel does not apply to decode")
    # entry always uses the FIRST segment's (masked) view — the global
    # knobs may request seq_parallel that the first segment's kind masks,
    # and the scatter must follow the masked decision
    entry_ctx = seg_ctxs[0] if seg_ctxs else ctx
    # `shell:*` / `seg{i}:{kind}` scope names are load-bearing: the
    # repro.analysis conformance linter attributes collectives to plan
    # segments by reading them out of the jaxpr name stacks
    with jax.named_scope("shell:embed"):
        if embeds is not None:
            x = embeds
            x_emb0 = x
            # externally-supplied embeds are ax1-replicated: free local slice
            x = seq_scatter(entry_ctx, x, dim=1)
        else:
            # seq-parallel entry fuses the vocab-parallel psum(ax1) with the
            # seq slice into one psum_scatter (x_emb0 is then seq-sharded,
            # fine: its consumers — zamba/MTP — never run seq-parallel)
            x = embed_tokens(entry_ctx, cfg, params["embed"], tokens,
                             scatter_seq=entry_sp)
            x_emb0 = x
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.moe is not None and ctx.dp_axes:
        # MoE aux loss varies with this rank's tokens -> type it varying
        # over the data axes so the scan carry is consistent
        aux_total = compat.pcast(aux_total, ctx.dp_axes, to="varying")
    new_caches = {} if caches is not None else None

    plan = (L.make_attn_plan(ctx, cfg.num_heads, cfg.num_kv_heads)
            if cfg.family != "ssm" else None)

    cur_sp = entry_sp
    last_sp_ctx = seg_ctxs[0] if entry_sp else None
    for i, seg in enumerate(segs):
        sctx = seg_ctxs[i]
        # domain transition: the residual stream must enter each segment in
        # that segment's block I/O spec
        with jax.named_scope(f"shell:trans{i}"):
            if sctx.seq_parallel and not cur_sp:
                x = seq_scatter(sctx, x, dim=1)    # free slice (replicated in)
            elif cur_sp and not sctx.seq_parallel:
                x = seq_gather(last_sp_ctx, x, dim=1)  # conjugate all-gather
        cur_sp = sctx.seq_parallel
        if cur_sp:
            last_sp_ctx = sctx
        sp = params[f"seg{i}"]
        seg_cache = caches.get(f"seg{i}") if caches is not None else None

        if seg.kind in ("dense", "moe", "mla_dense", "mla_moe", "mamba"):
            windows = _gemma_window_array(cfg, seg.count)

            def body(carry, xs, _kind=seg.kind, _ctx=sctx):
                h, aux = carry
                bp, win, c = xs
                if _kind == "mamba" and paged is not None:
                    # paged recurrent: this batch row's state lives at its
                    # slot's pool row; gather, step, drop-mode scatter back
                    rows = _state_take(c, slot, fresh)
                    h, nr, a = _apply_block(_kind, _ctx, cfg, bp, h,
                                            positions, plan, win, rows,
                                            paged=paged)
                    return (h, aux + a), _state_put(c, nr, slot)
                h, nc, a = _apply_block(_kind, _ctx, cfg, bp, h, positions,
                                        plan, win, c, paged=paged)
                return (h, aux + a), nc

            fn = jax.checkpoint(body) if remat else body
            with jax.named_scope(f"seg{i}:{seg.kind}"):
                (x, aux_total), ncs = lax.scan(
                    fn, (x, aux_total), (sp, windows, seg_cache))
            if new_caches is not None:
                new_caches[f"seg{i}"] = ncs

        elif seg.kind == "zamba":
            shared = params["shared_attn"]

            def zbody(carry, xs, _ctx=sctx):
                h, aux = carry
                bp, c = xs
                # shared attention block on (h, emb0): two column-first
                # projections sharing one f-boundary psum(ax2)
                u = atp_boundary(
                    jnp.einsum("...k,kn->...n", h, shared["w_in_h"])
                    + jnp.einsum("...k,kn->...n", x_emb0, shared["w_in_e"]),
                    _ctx.ax2)                      # [.., h/d1] ax1-sharded
                u = _gather_ax1_invariant(_ctx, u)  # back to block I/O spec
                if _ctx.ax2 is not None:
                    u = shard_slice(u, _ctx.index2(), _ctx.d2, dim=-1)
                ac = c["attn"] if c is not None else None
                h2, nac = transformer.dense_block(_ctx, cfg, shared["block"], h + u,
                                                  positions, plan, cache=ac,
                                                  paged=paged)
                h = h2

                def mbody(hc, xs2):
                    hh = hc
                    mp, mc = xs2
                    if paged is not None:
                        rows = _state_take(mc, slot, fresh)
                        hh, nr = mamba2.mamba_block(_ctx, cfg, mp, hh,
                                                    state=rows)
                        return hh, _state_put(mc, nr, slot)
                    hh, nmc = mamba2.mamba_block(_ctx, cfg, mp, hh, state=mc)
                    return hh, nmc

                mc = c["mamba"] if c is not None else None
                h, nmc = lax.scan(mbody, h, (bp["mamba"], mc))
                ncs = {"attn": nac, "mamba": nmc} if c is not None else 0.0
                return (h, aux), ncs

            fn = jax.checkpoint(zbody) if remat else zbody
            with jax.named_scope(f"seg{i}:{seg.kind}"):
                (x, aux_total), ncs = lax.scan(fn, (x, aux_total),
                                               (sp, seg_cache))
            if new_caches is not None:
                new_caches[f"seg{i}"] = ncs

        elif seg.kind == "xlstm":
            def xbody(carry, xs, _ctx=sctx):
                h, aux = carry
                bp, c = xs

                def mb(hc, xs2):
                    mp, mc = xs2
                    if paged is not None:
                        rows = _state_take(mc, slot, fresh)
                        hh, ns = xlstm.mlstm_block(_ctx, cfg, mp, hc,
                                                   state=rows)
                        return hh, _state_put(mc, ns, slot)
                    hh, ns = xlstm.mlstm_block(_ctx, cfg, mp, hc, state=mc)
                    return hh, ns

                mc = c["mlstm"] if c is not None else None
                h, nms = lax.scan(mb, h, (bp["mlstm"], mc))
                sc = c["slstm"] if c is not None else None
                if paged is not None:
                    rows = _state_take(sc, slot, fresh)
                    h, nr = xlstm.slstm_block(_ctx, cfg, bp["slstm"], h,
                                              state=rows)
                    nss = _state_put(sc, nr, slot)
                else:
                    h, nss = xlstm.slstm_block(_ctx, cfg, bp["slstm"], h,
                                               state=sc)
                ncs = {"mlstm": nms, "slstm": nss} if c is not None else 0.0
                return (h, aux), ncs

            fn = jax.checkpoint(xbody) if remat else xbody
            with jax.named_scope(f"seg{i}:{seg.kind}"):
                (x, aux_total), ncs = lax.scan(fn, (x, aux_total),
                                               (sp, seg_cache))
            if new_caches is not None:
                new_caches[f"seg{i}"] = ncs
        else:
            raise ValueError(seg.kind)

    with jax.named_scope("shell:exit"):
        x = L.norm(ctx, cfg, x, params["final_norm"])
        # leave the sequence-parallel domain: heads/loss see the full sequence
        if cur_sp:
            x = seq_gather(last_sp_ctx, x, dim=1)
    return x, new_caches, aux_total, x_emb0


def train_loss(ctx: ATPContext, cfg: ModelConfig, params, batch, remat=True):
    """batch: tokens [b,s], labels [b,s] (+ embeds/positions3).  Scalar loss."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    if cfg.mrope_sections:
        positions = batch["positions3"]
        b, s = positions.shape[1], positions.shape[2]
    else:
        ref = tokens if tokens is not None else embeds
        b, s = ref.shape[0], ref.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h, _, aux, x_emb0 = forward(ctx, cfg, params, tokens, positions,
                                embeds=embeds, remat=remat)
    with jax.named_scope("shell:head"):
        logits = lm_logits(ctx, cfg, params, h)
        per_tok = vocab_parallel_ce(ctx, logits, batch["labels"])
    with jax.named_scope("shell:loss"):
        total = jnp.sum(per_tok)
        count = jnp.asarray(per_tok.size, jnp.float32)
        if ctx.dp_axes:
            total = lax.psum(total, ctx.dp_axes)
            count = lax.psum(count, ctx.dp_axes)
        loss = total / count

    if cfg.mtp and tokens is not None:
        # multi-token prediction: predict t+2 from (h_t, emb(t+1)).  h left
        # the sequence-parallel domain at forward()'s exit gather, so the
        # MTP head always runs on replicated full-sequence block I/O — use
        # an sp-free context view regardless of the plan's segment knobs.
        with jax.named_scope("shell:mtp"):
            mctx = dataclasses.replace(ctx, seq_parallel=False,
                                       segment_plans=())
            mp = params["mtp"]
            emb_next = embed_tokens(mctx, cfg, params["embed"],
                                    jnp.roll(tokens, -1, axis=1))
            u = atp_boundary(
                jnp.einsum("...k,kn->...n", h, mp["proj_h"])
                + jnp.einsum("...k,kn->...n", emb_next, mp["proj_e"]),
                mctx.ax2)
            if mctx.ax1 is not None:  # back to [.., h/d2] block I/O spec
                u = lax.all_gather(u, mctx.ax1, axis=-1, tiled=True)
            u = (shard_slice(u, mctx.index2(), mctx.d2, dim=-1)
                 if mctx.ax2 is not None else u)
            plan = L.make_attn_plan(mctx, cfg.num_heads, cfg.num_kv_heads)
            u, _, _ = _apply_block("mla_dense" if cfg.mla else "dense",
                                   mctx, cfg, mp["block"], u, positions,
                                   plan, 0, None)
            u = L.norm(mctx, cfg, u, mp["norm"])
            logits2 = lm_logits(mctx, cfg, params, u)
            mtp_labels = jnp.roll(batch["labels"], -1, axis=1)
            l2 = jnp.sum(vocab_parallel_ce(ctx, logits2, mtp_labels))
            if ctx.dp_axes:
                l2 = lax.psum(l2, ctx.dp_axes)
            loss = loss + cfg.mtp_loss_weight * l2 / count

    if cfg.moe is not None:
        with jax.named_scope("shell:loss"):
            if ctx.dp_axes:
                aux = lax.pmean(aux, ctx.dp_axes)
            loss = loss + cfg.moe.aux_loss_weight * aux / max(1, cfg.num_layers)
    return loss


def prefill_logits(ctx: ATPContext, cfg: ModelConfig, params, batch):
    """Forward only; returns last-position logits [b, V/d1]."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    if cfg.mrope_sections:
        positions = batch["positions3"]
        b, s = positions.shape[1], positions.shape[2]
    else:
        ref = tokens if tokens is not None else embeds
        b, s = ref.shape[0], ref.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h, _, _, _ = forward(ctx, cfg, params, tokens, positions, embeds=embeds)
    with jax.named_scope("shell:head"):
        logits = lm_logits(ctx, cfg, params, h[:, -1:])
    return logits[:, 0]


def decode_step(ctx: ATPContext, cfg: ModelConfig, params, tokens, pos, caches):
    """One token step.  tokens [b,1]; pos scalar; caches per-segment trees.

    Returns (next-token logits [b, V/d1], new caches)."""
    b, s = tokens.shape
    prange = (pos + jnp.arange(s)).astype(jnp.int32)
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(prange[None, None, :], (3, b, s))
    else:
        positions = jnp.broadcast_to(prange[None, :], (b, s))
    h, new_caches, _, _ = forward(ctx, cfg, params, tokens, positions,
                                  caches=caches)
    with jax.named_scope("shell:head"):
        logits = lm_logits(ctx, cfg, params, h[:, -1:])
    return logits[:, 0], new_caches


def paged_step(ctx: ATPContext, cfg: ModelConfig, params, tokens, start,
               table, caches, slot=None, with_hidden: bool = False):
    """One paged cache-write step — decode tick AND prefill chunk.

    tokens [b, s] (decode: b=slots, s=1; prefill chunk: b=1, s=chunk);
    start [b] per-slot absolute position of tokens[:, 0]; table [b, mp]
    page-table rows; caches from :func:`init_paged_caches`; slot [b]
    per-row slot ids (required for recurrent kinds — masked rows carry
    the sentinel id = pool slot count, whose state writes drop).

    Returns (logits [b, s, V/d1] for EVERY input position, new caches);
    ``with_hidden`` adds the final-norm hidden [b, s, h/d2] in the middle
    (speculative decode feeds it to :func:`mtp_draft_logits`).  Returning
    all positions keeps one compiled step reusable across prompt lengths:
    the scheduler picks the logits of the last *valid* token of a padded
    final chunk on the host, instead of forcing a recompile per length.
    """
    b, s = tokens.shape
    prange = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(prange[None], (3, b, s))
    else:
        positions = prange
    paged = {"table": table, "start": start}
    if slot is not None:
        paged["slot"] = slot
    h, new_caches, _, _ = forward(ctx, cfg, params, tokens, positions,
                                  caches=caches, paged=paged)
    with jax.named_scope("shell:head"):
        logits = lm_logits(ctx, cfg, params, h)
    if with_hidden:
        return logits, h, new_caches
    return logits, new_caches


def mtp_draft_logits(ctx: ATPContext, cfg: ModelConfig, params, h, positions,
                     next_tokens):
    """MTP head as a decode-time draft proposer.

    Training teaches the head p(t+2 | h_t, emb(t+1)); at decode time we
    feed the trunk hidden ``h`` [b, s, h/d2] (paged_step's
    ``with_hidden`` output) and the greedy picks ``next_tokens`` [b, s]
    just made from it, giving draft logits for the position AFTER each
    pick — a free extra token per tick for self-speculative decode.
    Mirrors the train head exactly (sp-free context, same block), except
    the draft block attends only within the fed window (cache=None over
    ``s`` positions): a weaker proposer, never a correctness issue —
    the trunk verifies every draft before it is kept.
    """
    with jax.named_scope("shell:mtp"):
        mctx = dataclasses.replace(ctx, seq_parallel=False, segment_plans=())
        mp = params["mtp"]
        emb_next = embed_tokens(mctx, cfg, params["embed"], next_tokens)
        u = atp_boundary(
            jnp.einsum("...k,kn->...n", h, mp["proj_h"])
            + jnp.einsum("...k,kn->...n", emb_next, mp["proj_e"]), mctx.ax2)
        if mctx.ax1 is not None:  # back to [.., h/d2] block I/O spec
            u = lax.all_gather(u, mctx.ax1, axis=-1, tiled=True)
        u = (shard_slice(u, mctx.index2(), mctx.d2, dim=-1)
             if mctx.ax2 is not None else u)
        plan = L.make_attn_plan(mctx, cfg.num_heads, cfg.num_kv_heads)
        u, _, _ = _apply_block("mla_dense" if cfg.mla else "dense",
                               mctx, cfg, mp["block"], u, positions, plan,
                               0, None)
        u = L.norm(mctx, cfg, u, mp["norm"])
        return lm_logits(mctx, cfg, params, u)
