"""Mixture-of-Experts with expert parallelism over the flat TP axis.

Scheme (DESIGN.md §5): experts are sharded over the d1*d2 flat TP ranks
(EP); ATP's grouped all-reduce has no role inside a (small) expert, so the
paper's technique applies to the surrounding dense layers while the MoE
layer uses EP all-to-all dispatch:

  1. token-scatter: every TP rank takes a 1/n slice of the local tokens
     (free slice over ax1 + all-gather(ax2) of the feature shards)
  2. route + capacity-bounded dispatch to [n_dst, cap, h] send buffer
  3. all_to_all over the flat TP axes
  4. local grouped expert FFN [E_loc, cap*n, h]
  5. all_to_all back + weighted combine
  6. token-gather back to the block I/O spec [Replicate, Shard(feature)]
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.atp import ATPContext, grad_sync, shard_slice
from repro.models import layers as L


def moe_params(key, cfg: ModelConfig, dtype) -> dict[str, Any]:
    mc = cfg.moe
    h, ff, e = cfg.d_model, mc.d_ff_expert, mc.num_experts
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(h)
    p = {
        "router": (jax.random.normal(ks[0], (h, e), jnp.float32) * 0.02),
        "w_up": (jax.random.normal(ks[1], (e, h, ff), jnp.float32) * s).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e, h, ff), jnp.float32) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, ff, h), jnp.float32) / math.sqrt(ff)).astype(dtype),
    }
    if mc.num_shared:
        from repro.models.transformer import mlp_params
        p["shared"] = mlp_params(ks[4], cfg, dtype, d_ff=mc.d_ff_expert * mc.num_shared)
    return p


def moe_param_specs(ctx: ATPContext, cfg: ModelConfig) -> dict[str, Any]:
    ep = ctx.tp_axes or None  # experts sharded over flat TP
    sp = {
        "router": L.replicated_spec(),
        "w_up": jax.sharding.PartitionSpec(ep),
        "w_gate": jax.sharding.PartitionSpec(ep),
        "w_down": jax.sharding.PartitionSpec(ep),
    }
    if cfg.moe.num_shared:
        from repro.models.transformer import mlp_param_specs
        sp["shared"] = mlp_param_specs(ctx, cfg)
    return sp


def _all_to_all(x, axes: tuple[str, ...], split_axis: int, concat_axis: int):
    if not axes:
        return x
    return lax.all_to_all(x, axes, split_axis=split_axis, concat_axis=concat_axis,
                          tiled=True)


def moe_block(ctx: ATPContext, cfg: ModelConfig, p, x):
    """x: [b, s, h/d2] -> same spec.  Capacity-dropped top-k routing."""
    mc = cfg.moe
    n = ctx.tp
    b, s, hl = x.shape
    h = cfg.d_model
    e = mc.num_experts
    e_loc = max(1, e // n)

    t = x.reshape(b * s, hl)
    replicated_dispatch = (b * s) % n != 0 or (b * s) // n == 0
    if replicated_dispatch:
        # decode-sized token counts (T < n): keep ALL tokens on every rank
        # (full-h via all_gather(ax2): safe here — no token slicing, so no
        # interleave hazard); each rank runs only its local experts and the
        # combine below assembles with a psum over the flat TP group.
        if ctx.ax2 is not None:
            t = lax.all_gather(t, ctx.ax2, axis=-1, tiled=True)
        tokens = t                                                   # [T, h]
    else:
        # ---- 1. token scatter: [b*s, h/d2] -> this rank's 1/n token slice,
        # full h.  all_to_all(ax2) swaps token-sharding for feature-gathering
        # *within the same ax2 ring* (a plain all_gather(ax2) would mix
        # feature shards of different token blocks); the ax1 slice is then
        # free (replicated).
        if ctx.ax2 is not None:
            t = _all_to_all(t, (ctx.ax2,), split_axis=0, concat_axis=1)
        tokens = shard_slice(t, ctx.index1(), ctx.d1, dim=0)         # [T/n, h]

    # ---- 2. route (router weight replicated; logits from full-h tokens)
    # each rank routes its own token shard (or combines only its local
    # experts), so the router's cotangent is TP-partial: sync its grad
    router = grad_sync(ctx, p["router"], ctx.tp_axes)
    logits = (tokens.astype(jnp.float32) @ router)            # [T/n, E]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(gates, mc.top_k)                   # [T/n, k]
    topv = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)

    # aux load-balance loss (Switch-style); tokens differ per TP rank here,
    # so average the per-rank partials over the flat TP group
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * e
    if ctx.tp_axes:
        aux = lax.psum(aux, ctx.tp_axes) / n

    # ---- capacity-bounded slot assignment
    tn = tokens.shape[0]
    cap = max(1, int(mc.capacity_factor * tn * mc.top_k / e))
    flat_e = topi.reshape(-1)                                 # [tn*k]
    flat_w = topv.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot - 1        # slot within expert
    slot = jnp.sum(pos_in_e * onehot, axis=-1)
    keep = slot < cap
    dst = flat_e // e_loc                                     # owning rank
    tok_rep = jnp.repeat(tokens, mc.top_k, axis=0)
    w_up, w_gate, w_down = p["w_up"], p["w_gate"], p["w_down"]

    if replicated_dispatch:
        # every rank holds all tokens; keep only slots owned by my experts
        mine = keep & (dst == ctx.tp_index())
        buf = jnp.zeros((e_loc, cap, h), tokens.dtype)
        buf = buf.at[jnp.where(mine, flat_e % e_loc, e_loc),
                     jnp.where(mine, slot, 0)].add(tok_rep, mode="drop")
        up = jnp.einsum("ech,ehf->ecf", buf, w_up)
        gate = jnp.einsum("ech,ehf->ecf", buf, w_gate)
        yb = jnp.einsum("ecf,efh->ech", up * jax.nn.silu(gate), w_down)
        gathered = yb[jnp.where(mine, flat_e % e_loc, 0),
                      jnp.where(mine, slot, 0)]
        gathered = jnp.where(mine[:, None], gathered, 0.0)
        combined = (gathered * flat_w[:, None].astype(gathered.dtype)).reshape(
            tn, mc.top_k, h).sum(axis=1)                      # partial over TP
        if ctx.tp_axes:
            combined = lax.psum(combined, ctx.tp_axes)        # [T, h] invariant
        if ctx.ax2 is not None:
            combined = shard_slice(combined, ctx.index2(), ctx.d2, dim=-1)
        out = combined.reshape(b, s, hl)
    else:
        # send buffer [n, e_loc * cap, h]
        send = jnp.zeros((n, e_loc * cap, h), tokens.dtype)
        buf_idx = (flat_e % e_loc) * cap + slot
        send = send.at[jnp.where(keep, dst, n),
                       jnp.where(keep, buf_idx, 0)].add(tok_rep, mode="drop")

        # ---- 3. all-to-all over flat TP
        recv = _all_to_all(send, ctx.tp_axes, split_axis=0, concat_axis=0)

        # ---- 4. local grouped expert FFN over [e_loc, n*cap, h]
        xin = recv.reshape(n, e_loc, cap, h).transpose(1, 0, 2, 3) \
            .reshape(e_loc, n * cap, h)
        up = jnp.einsum("ech,ehf->ecf", xin, w_up)
        gate = jnp.einsum("ech,ehf->ecf", xin, w_gate)
        y = jnp.einsum("ecf,efh->ech", up * jax.nn.silu(gate), w_down)
        y = y.reshape(e_loc, n, cap, h).transpose(1, 0, 2, 3) \
            .reshape(n, e_loc * cap, h)

        # ---- 5. return path + weighted combine
        back = _all_to_all(y, ctx.tp_axes, split_axis=0, concat_axis=0)
        gathered = back[jnp.where(keep, dst, 0), jnp.where(keep, buf_idx, 0)]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        combined = (gathered * flat_w[:, None].astype(gathered.dtype)).reshape(
            tn, mc.top_k, h).sum(axis=1)                      # [T/n, h]

        # ---- 6. token gather back to [b*s, h/d2]: exact inverse of step 1.
        # The ax1 gather uses place+psum (not all_gather) so the result is
        # provably ax1-invariant under vma typing — matching the block I/O
        # spec [Replicate@ax1, Shard@ax2] (all_gather output cannot be typed
        # invariant; costs 2x gather bytes, noted in DESIGN.md).
        if ctx.ax1 is not None:
            t_d2 = combined.shape[0] * ctx.d1
            placed = jnp.zeros((t_d2,) + combined.shape[1:], combined.dtype)
            placed = lax.dynamic_update_slice_in_dim(
                placed, combined, ctx.index1() * combined.shape[0], axis=0)
            combined = lax.psum(placed, ctx.ax1)                  # [T/d2, h]
        if ctx.ax2 is not None:
            combined = _all_to_all(combined, (ctx.ax2,),
                                   split_axis=1, concat_axis=0)
        out = combined.reshape(b, s, hl)

    # ---- shared experts (deepseek): plain ATP dense MLP path
    if mc.num_shared:
        from repro.models.transformer import mlp_block
        out = out + mlp_block(ctx, cfg, p["shared"], x)
    return out, aux
