"""Plan-conformance lint sweep (``make lint-plans``).

    PYTHONPATH=src python -m repro.analysis.lint [options]

For every (config, topology preset, wire dtype) this runs the same
strategy search the deployment path runs (``plan_search(model=cfg)`` on
the 8-way host mesh: tp=4, dp=2, with a decode sub-plan), builds the
train / prefill / decode steps from the winning plan, and statically
checks each build without executing it:

  - **conformance** — the extracted collective signature
    (:mod:`repro.analysis.signature`) must equal the expectation derived
    from the plan (:mod:`repro.analysis.expect`): per-region ops, mesh
    axes, counts, raw payload bytes and quantized-wire tagging forward;
    structural ring/psum/quant rules backward;
  - **replication** — every shard_map ``out_spec`` replication claim
    must be proven by the jaxpr walk (:mod:`repro.analysis.replication`),
    including the build paths where jax's own ``check_vma`` is off.

Different presets frequently elect the *same* plan; identical
(config, plan, phase) builds are linted once and the verdict attributed
to every preset that produced them, so the full zoo x preset x wire
sweep stays tractable.  Results land in ``BENCH_analysis.json`` with
per-preset extracted byte totals — ``benchmarks/bench_regress.py``
tracks those as drift metrics so comm volume cannot silently grow.

``--hlo-check`` additionally compiles one pinned config's step per
preset and cross-checks the jaxpr-level byte totals against the
optimized-HLO totals from :mod:`repro.launch.hlo_analysis` (the second
extraction backend).
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import sys
import traceback
from collections import defaultdict

#: sweep geometry: one host mesh every preset's search can be traced on
TP, DP, B, S, S_MAX = 4, 2, 4, 32, 64

WIRES = ("bf16", "int8", "fp8")
PHASES = ("train", "prefill", "decode")

#: config whose compiled step anchors the jaxpr-vs-HLO byte cross-check
HLO_CHECK_CONFIG = "qwen1.5-0.5b"

#: jaxpr collective -> optimized-HLO op kind
_HLO_KIND = {"psum": "all-reduce", "pmax": "all-reduce", "pmin": "all-reduce",
             "all_gather": "all-gather", "reduce_scatter": "reduce-scatter",
             "all_to_all": "all-to-all", "ppermute": "collective-permute"}


def _zoo() -> list[str]:
    from repro.configs.registry import ARCHS

    return sorted(ARCHS)


#: plan-document keys that record where a plan came from / what the cost
#: model predicted for it — not what the build will execute
_PROVENANCE_KEYS = frozenset({"topology", "calibration", "predicted",
                              "provenance", "predicted_t_step"})


def _fingerprint(plan) -> str:
    """Plan identity for dedupe: the searched knobs, not the provenance
    (topology preset name, calibration table and predicted timings
    differ per preset even when the elected strategy is identical)."""
    def strip(node):
        if isinstance(node, dict):
            return {k: strip(v) for k, v in node.items()
                    if k not in _PROVENANCE_KEYS}
        if isinstance(node, list):
            return [strip(v) for v in node]
        return node

    return json.dumps(strip(json.loads(plan.to_json())), sort_keys=True)


def searched_plan(cfg, preset: str, wire: str):
    from repro.core.plan import plan_search

    return plan_search(preset, TP, model=cfg, batch=B, seq=S, dp=DP,
                       wire_dtype=wire, decode_batch=B).best


def lint_build(cfg, plan, phase: str):
    """Build one step and run both static checkers.

    Returns ``(errors, op_bytes)`` — empty errors == the build conforms
    to the plan and every replication claim is proven; ``op_bytes`` is
    the extracted {op: raw bytes} inventory (fwd+bwd).
    """
    import jax
    import numpy as np

    from repro.analysis.expect import check_conformance, expected_signature
    from repro.analysis.replication import verify_replication
    from repro.analysis.signature import extract, trace_jaxpr
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import (batch_struct, build_decode_step,
                                    build_prefill, build_train_step)
    from repro.models import lm
    from repro.optim import adamw

    params = lm.abstract_params(cfg)
    if phase == "train":
        fn, info = build_train_step(cfg, plan=plan)
        pspecs = lm.param_specs(cfg, info.ctx)
        opt = adamw.init_opt_state(params, pspecs, info.ctx, abstract=True)
        batch = batch_struct(cfg, ShapeConfig("x", S, B, "train"), "train")
        args = (params, opt, batch)
        exp_plan, seq = plan, S
    elif phase == "prefill":
        fn, info = build_prefill(cfg, plan=plan)
        batch = batch_struct(cfg, ShapeConfig("x", S, B, "prefill"),
                             "prefill")
        args = (params, batch)
        exp_plan, seq = plan, S
    else:
        # serve.py builds the decode stack from plan.decode_view() (the
        # decode factorization may flip the mesh) — lint what it runs
        view = plan.decode_view() if getattr(plan, "decode", None) else plan
        fn, info = build_decode_step(cfg, B=B, s_max=S_MAX, plan=view)
        caches, _ = lm.init_decode_caches(cfg, info.ctx, B, S_MAX,
                                          abstract=True)
        tokens = jax.ShapeDtypeStruct((B, 1), np.int32)
        pos = jax.ShapeDtypeStruct((), np.int32)
        args = (params, tokens, pos, caches)
        exp_plan, seq = view, 1

    jaxpr = trace_jaxpr(fn, *args)
    sig = extract(jaxpr)
    exp = expected_signature(cfg, exp_plan, phase, B, seq)
    errors = check_conformance(sig, exp)
    errors += verify_replication(jaxpr, strict=False)
    return errors, sig.op_bytes()


def hlo_cross_check(cfg, plan) -> list[str]:
    """Compile the prefill step and require the optimized-HLO collective
    byte totals (:mod:`repro.launch.hlo_analysis`) to agree with the
    jaxpr-level signature per mapped op kind.

    Runs the model at float32: the CPU backend upcasts bf16 collectives
    to f32 wholesale, which would skew every payload 2x against the
    jaxpr-level bytes — at f32 both backends measure identical widths,
    so totals must match EXACTLY."""
    import dataclasses

    import jax

    from repro.analysis.signature import extract
    from repro.configs.base import ShapeConfig
    from repro.launch import hlo_analysis
    from repro.launch.steps import batch_struct, build_prefill
    from repro.models import lm

    cfg = dataclasses.replace(cfg, dtype="float32")
    fn, info = build_prefill(cfg, plan=plan)
    params = lm.abstract_params(cfg)
    batch = batch_struct(cfg, ShapeConfig("x", S, B, "prefill"), "prefill")
    sig = extract(fn, params, batch)
    want: dict[str, float] = defaultdict(float)
    for op, byts in sig.op_bytes().items():
        want[_HLO_KIND[op]] += byts

    hlo = (jax.jit(fn).lower(params, batch)
           .compile().as_text())
    got = hlo_analysis.collective_bytes(hlo)["per_op_bytes"]
    errors = []
    for kind in sorted(set(want) | set(got)):
        w, g = want.get(kind, 0.0), got.get(kind, 0.0)
        if w != g:
            errors.append(f"{kind}: jaxpr says {int(w)} raw bytes, "
                          f"optimized HLO says {int(g)}")
    return errors


def main(argv=None) -> int:
    from repro.core.comm_matrix import PRESETS
    from repro.configs.registry import get_config

    ap = argparse.ArgumentParser(
        description="lint every (config, preset, wire, phase) build "
                    "against the plan that priced it")
    ap.add_argument("--configs", default=None,
                    help="comma-separated zoo subset (default: all)")
    ap.add_argument("--presets", default=None,
                    help="comma-separated topology presets (default: all)")
    ap.add_argument("--wires", default=",".join(WIRES))
    ap.add_argument("--phases", default=",".join(PHASES))
    ap.add_argument("--hlo-check", action="store_true",
                    help="compile %s per preset and cross-check jaxpr vs "
                         "HLO byte totals" % HLO_CHECK_CONFIG)
    ap.add_argument("--out", default="BENCH_analysis.json",
                    help="result artifact path ('' disables)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    configs = (args.configs.split(",") if args.configs else _zoo())
    presets = (args.presets.split(",") if args.presets
               else sorted(PRESETS))
    wires = tuple(args.wires.split(","))
    phases = tuple(args.phases.split(","))

    plan_cache: dict[tuple, object] = {}
    lint_cache: dict[tuple, tuple] = {}
    preset_bytes: dict[str, float] = defaultdict(float)
    failures, cases, built = [], 0, 0

    for name in configs:
        cfg = get_config(name).reduced()
        for preset in presets:
            for wire in wires:
                try:
                    key = (name, preset, wire)
                    if key not in plan_cache:
                        plan_cache[key] = searched_plan(cfg, preset, wire)
                    plan = plan_cache[key]
                except Exception as ex:  # search itself must not break
                    failures.append(f"{name} [{preset} {wire}] search: "
                                    f"{type(ex).__name__}: {ex}")
                    continue
                fp = _fingerprint(plan)
                for phase in phases:
                    if phase == "decode" and cfg.frontend == "vision_patches":
                        continue
                    cases += 1
                    ck = (name, fp, phase)
                    if ck not in lint_cache:
                        built += 1
                        try:
                            lint_cache[ck] = lint_build(cfg, plan, phase)
                        except Exception as ex:
                            lint_cache[ck] = (
                                [f"build/trace error: {type(ex).__name__}: "
                                 f"{ex}"], {})
                            if args.verbose:
                                traceback.print_exc(limit=6)
                    errors, op_bytes = lint_cache[ck]
                    label = f"{name} [{preset} {wire}] {phase}"
                    if wire == wires[0]:
                        preset_bytes[preset] += sum(op_bytes.values())
                    if errors:
                        failures.append(label)
                        print(f"FAIL {label}")
                        for e in errors[:8]:
                            print(f"     {e}")
                    elif args.verbose:
                        print(f"ok   {label}")

    hlo_errs: list[str] = []
    if args.hlo_check:
        cfg = get_config(HLO_CHECK_CONFIG).reduced()
        seen: set[str] = set()
        for preset in presets:
            plan = plan_cache.get((HLO_CHECK_CONFIG, preset, wires[0]))
            if plan is None:
                plan = searched_plan(cfg, preset, wires[0])
            fp = _fingerprint(plan)
            if fp in seen:
                continue
            seen.add(fp)
            errs = hlo_cross_check(cfg, plan)
            tag = f"hlo-check [{preset}]"
            if errs:
                hlo_errs += [f"{tag}: {e}" for e in errs]
                print(f"FAIL {tag}")
                for e in errs:
                    print(f"     {e}")
            else:
                print(f"ok   {tag} (jaxpr == HLO byte totals)")
        failures += hlo_errs

    print(f"lint-plans: {cases} cases ({built} unique builds, "
          f"{len(plan_cache)} searches), {len(failures)} failures")
    if args.out:
        doc = {
            "summary": {
                "cases": cases,
                "unique_builds": built,
                "failures": len(failures),
                "conformant": not failures,
            },
            "per_preset_raw_bytes": {k: preset_bytes[k]
                                     for k in sorted(preset_bytes)},
            "failing": failures[:50],
        }
        with open(args.out, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
