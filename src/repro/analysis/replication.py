"""Replication (vma) lint: prove shard_map ``out_specs`` honest.

ATP runs several build paths with jax's own per-eqn replication checker
disabled (``check_vma=False``): every build on the legacy-jax floor
(where the upstream checker rejects ppermute rings outright) and every
ring/collective-matmul plan even on current jax.  This module closes
that gap: it walks the traced jaxpr of a built step, finds each
``shard_map`` eqn, and data-flows a *replication set* — the mesh axes
over which a value is guaranteed identical across ranks — from the
``in_names`` to every output, then checks each output's ``out_names``:
an axis the spec does NOT mention is a claim of replication, and the
lint errors if the value may actually vary over it.

Transfer rules (``rep`` = set of axes a value is replicated over):

  - default eqn: intersection of the operands' sets (a value derived
    from inputs is replicated over an axis only if all inputs are);
  - ``psum/pmax/pmin`` over ``axes``: union in ``axes`` (reduction
    restores invariance); ``all_gather``: union in its axis;
  - ``reduce_scatter/all_to_all/ppermute/pvary/pbroadcast``: difference
    with their axes (ranks now hold different data);
  - ``axis_index``: everything but its axis;
  - HOPs recurse (``pjit``/``remat2``/``custom_*`` map operands 1:1;
    ``scan``/``while`` iterate the carry to a fixpoint — monotone
    decreasing, so it terminates; ``cond`` intersects branches and the
    predicate); unknown sub-jaxpr shapes fall back to the permissive
    operand intersection.

Ring schedules need one extra ingredient: a completed ppermute ring IS
an all-reduce/all-gather, but per-hop data flow only ever sees the
varying intermediates.  The named scopes ``core.overlap`` wraps every
ring in (``ring_ar[ax]``/``ring_ag[ax]``/``ring_rs[ax]``/``cm_rs[ax]``/
``cm_ag[ax]``) mark the algebra: values are tagged with the scopes that
produced them, and when a value ESCAPES a ring scope the scope's net
effect is applied once — ``ring_ar``/``ring_ag`` restore the axis (up
to reduction reassociation, the same equivalence the cost model prices),
``ring_rs``/``cm_rs`` scatter over it.  Quantized wires need nothing
special: ``quant[ax]`` payloads flow through the same psum / ring /
scatter rules on the grid values, and the shared scale is a ``pmax``.
"""
from __future__ import annotations

import dataclasses
import re
from itertools import chain
from typing import Any

import jax
from jax import core as jcore

#: scope -> net effect on the replication set when a value escapes it
_SCOPE_RE = re.compile(r"^(ring_ar|ring_ag|ring_rs|cm_rs|cm_ag)\[(.+)\]$")
_SCOPE_EFFECT = {"ring_ar": "add", "ring_ag": "add",
                 "ring_rs": "drop", "cm_rs": "drop", "cm_ag": "none"}

_REDUCE_PRIMS = frozenset({"psum", "pmax", "pmin"})
_VARY_PRIMS = frozenset({"reduce_scatter", "all_to_all", "ppermute",
                         "pvary", "pbroadcast"})


@dataclasses.dataclass(frozen=True)
class ReplicationError:
    out_index: int
    axis: str
    claimed: tuple[str, ...]
    actual: tuple[str, ...]

    def __str__(self) -> str:
        return (f"shard_map out[{self.out_index}]: out_spec claims "
                f"replication over '{self.axis}' but the value may vary "
                f"over it (proven replicated: "
                f"{sorted(self.actual) or ['<none>']})")


@dataclasses.dataclass
class ShardMapReport:
    """Lint result for one shard_map eqn inside a traced step."""

    mesh_axes: tuple[str, ...]
    errors: tuple[ReplicationError, ...]
    out_rep: tuple[frozenset, ...]
    check_rep: bool

    @property
    def ok(self) -> bool:
        return not self.errors


def _axes_param(params: dict) -> tuple[str, ...]:
    for k in ("axes", "axis_name"):
        if k in params:
            ax = params[k]
            return tuple(ax) if isinstance(ax, (tuple, list)) else (str(ax),)
    return ()


def _sub_jaxpr(x):
    if isinstance(x, jcore.ClosedJaxpr):
        return x.jaxpr
    if isinstance(x, jcore.Jaxpr):
        return x
    return None


def _stack_components(eqn) -> tuple[str, ...]:
    ns = getattr(eqn.source_info, "name_stack", None)
    s = str(ns) if ns is not None else ""
    return tuple(p for p in s.split("/") if p)


def _scopes_in(path: tuple[str, ...]) -> tuple[str, ...]:
    return tuple(p for p in path if _SCOPE_RE.match(p))


@dataclasses.dataclass(frozen=True)
class _Val:
    """A replication fact: the base set + the ring scopes that produced
    the value (applied lazily when the value escapes them)."""

    rep: frozenset
    tags: tuple[str, ...] = ()

    def read(self, consumer_scopes: tuple[str, ...]) -> frozenset:
        rep = self.rep
        for tag in self.tags:
            if tag in consumer_scopes:
                continue
            m = _SCOPE_RE.match(tag)
            effect = _SCOPE_EFFECT[m.group(1)]
            if effect == "add":
                rep = rep | {m.group(2)}
            elif effect == "drop":
                rep = rep - {m.group(2)}
        return rep

    def escaped(self, consumer_scopes: tuple[str, ...]) -> "_Val":
        kept = tuple(t for t in self.tags if t in consumer_scopes)
        return _Val(self.read(consumer_scopes), kept)


class _RepWalker:
    """Forward data-flow of replication sets over one jaxpr."""

    def __init__(self, axes: frozenset):
        self.axes = axes
        self.full = _Val(frozenset(axes))

    def run(self, jaxpr: jcore.Jaxpr, in_vals: list[_Val],
            path: tuple[str, ...]) -> list[_Val]:
        env: dict[Any, _Val] = {}
        drop = getattr(jcore, "DropVar", ())
        for v in jaxpr.constvars:
            env[v] = self.full
        for v, val in zip(jaxpr.invars, in_vals):
            env[v] = val

        def read(v, scopes) -> frozenset:
            if isinstance(v, jcore.Literal):
                return frozenset(self.axes)
            return env.get(v, self.full).read(scopes)

        for eqn in jaxpr.eqns:
            p = path + _stack_components(eqn)
            scopes = _scopes_in(p)
            name = eqn.primitive.name
            ins = [read(v, scopes) for v in eqn.invars]
            inter = frozenset.intersection(*ins) if ins \
                else frozenset(self.axes)
            if name in _REDUCE_PRIMS:
                out = inter | set(_axes_param(eqn.params))
                outs = [_Val(out, scopes)] * len(eqn.outvars)
            elif name == "all_gather":
                outs = [_Val(inter | set(_axes_param(eqn.params)), scopes)]
            elif name in _VARY_PRIMS:
                out = inter - set(_axes_param(eqn.params))
                outs = [_Val(out, scopes)] * len(eqn.outvars)
            elif name == "axis_index":
                outs = [_Val(frozenset(self.axes)
                             - set(_axes_param(eqn.params)), scopes)]
            elif name == "scan":
                outs = self._scan(eqn, p, scopes, env, read)
            elif name == "while":
                outs = self._while(eqn, p, scopes, read)
            elif name == "cond":
                outs = self._cond(eqn, p, scopes, read)
            else:
                outs = self._generic(eqn, p, scopes, env, inter, read)
            for v, val in zip(eqn.outvars, outs):
                if not isinstance(v, drop):
                    env[v] = val
        return [_Val(read(v, ()), ()) if isinstance(v, jcore.Literal)
                else env.get(v, self.full).escaped(())
                for v in jaxpr.outvars]

    # -- HOPs ---------------------------------------------------------------

    def _scan(self, eqn, path, scopes, env, read):
        body = eqn.params["jaxpr"].jaxpr
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        ins = [_Val(read(v, scopes), scopes) for v in eqn.invars]
        consts, carry, xs = ins[:nc], ins[nc:nc + ncar], ins[nc + ncar:]
        for _ in range(len(self.axes) * max(1, ncar) + 2):
            outs = self.run(body, consts + carry + xs, path)
            new_carry = [_Val(c.read(scopes) & o.read(scopes), scopes)
                         for c, o in zip(carry, outs[:ncar])]
            if all(n.rep == c.read(scopes) for n, c in zip(new_carry, carry)):
                carry = new_carry
                break
            carry = new_carry
        outs = self.run(body, consts + carry + xs, path)
        return [_Val(o.read(scopes), scopes) for o in outs]

    def _while(self, eqn, path, scopes, read):
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        body = eqn.params["body_jaxpr"].jaxpr
        ins = [_Val(read(v, scopes), scopes) for v in eqn.invars]
        bconsts = ins[cn:cn + bn]
        carry = ins[cn + bn:]
        for _ in range(len(self.axes) * max(1, len(carry)) + 2):
            outs = self.run(body, bconsts + carry, path)
            new_carry = [_Val(c.read(scopes) & o.read(scopes), scopes)
                         for c, o in zip(carry, outs)]
            if all(n.rep == c.read(scopes) for n, c in zip(new_carry, carry)):
                return new_carry
            carry = new_carry
        return carry

    def _cond(self, eqn, path, scopes, read):
        pred = read(eqn.invars[0], scopes)
        ops = [_Val(read(v, scopes), scopes) for v in eqn.invars[1:]]
        per_branch = [self.run(br.jaxpr, ops, path)
                      for br in eqn.params["branches"]]
        outs = []
        for i in range(len(eqn.outvars)):
            rep = frozenset.intersection(
                pred, *[b[i].read(scopes) for b in per_branch])
            outs.append(_Val(rep, scopes))
        return outs

    def _generic(self, eqn, path, scopes, env, inter, read):
        # single-sub-jaxpr HOPs whose operands map 1:1 (pjit, remat2,
        # custom_jvp/vjp call jaxprs, closed_call) recurse; anything else
        # falls back to the permissive operand intersection
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            body = _sub_jaxpr(eqn.params.get(key))
            if body is not None and len(body.invars) == len(eqn.invars):
                ins = [_Val(read(v, scopes), scopes) for v in eqn.invars]
                outs = self.run(body, ins, path)
                return [_Val(o.read(scopes), scopes) for o in outs]
        return [_Val(inter, scopes)] * len(eqn.outvars)


def _names_to_axes(names: dict) -> frozenset:
    return frozenset(chain.from_iterable(names.values()))


def _find_shard_maps(jaxpr: jcore.Jaxpr, out: list) -> None:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "shard_map":
            out.append(eqn)
            continue
        for v in eqn.params.values():
            j = _sub_jaxpr(v)
            if j is not None:
                _find_shard_maps(j, out)
        if "branches" in eqn.params:
            for br in eqn.params["branches"]:
                _find_shard_maps(br.jaxpr, out)


def analyze_shard_maps(fn_or_jaxpr: Any, *abstract_args) -> list[ShardMapReport]:
    """Find every shard_map in a built step and lint its out_specs."""
    from repro.analysis.signature import trace_jaxpr

    j = _sub_jaxpr(fn_or_jaxpr)
    if j is None:
        j = trace_jaxpr(fn_or_jaxpr, *abstract_args).jaxpr
    eqns: list = []
    _find_shard_maps(j, eqns)
    reports = []
    for eqn in eqns:
        mesh = eqn.params["mesh"]
        axes = tuple(mesh.axis_names)
        auto = set(eqn.params.get("auto", ()) or ())
        manual = frozenset(a for a in axes if a not in auto)
        # a size-1 mesh axis cannot carry variance: specs may still name
        # it (they are written against the axis NAMES, not the degrees),
        # so it is replicated by construction everywhere
        trivial = frozenset(a for a in manual
                            if dict(mesh.shape).get(a, 1) == 1)
        body = _sub_jaxpr(eqn.params["jaxpr"])
        in_vals = [_Val((manual - _names_to_axes(nm)) | trivial)
                   for nm in eqn.params["in_names"]]
        walker = _RepWalker(manual)
        out_vals = walker.run(body, in_vals, ())
        errors = []
        out_rep = []
        for i, (nm, val) in enumerate(zip(eqn.params["out_names"], out_vals)):
            rep = val.read(()) | trivial
            out_rep.append(rep)
            claimed = manual - _names_to_axes(nm)
            for ax in sorted(claimed - rep):
                errors.append(ReplicationError(
                    out_index=i, axis=ax,
                    claimed=tuple(sorted(claimed)),
                    actual=tuple(sorted(rep))))
        reports.append(ShardMapReport(
            mesh_axes=axes, errors=tuple(errors), out_rep=tuple(out_rep),
            check_rep=bool(eqn.params.get("check_rep", False))))
    return reports


def verify_replication(fn_or_jaxpr: Any, *abstract_args,
                       strict: bool = True) -> list[str]:
    """Lint every shard_map out_spec in a built step.

    Returns error strings (empty == every replication claim is proven);
    raises AssertionError when ``strict`` and a claim fails.  This is the
    checker that stands in for jax's ``check_vma`` on the build paths
    where that one is off — the legacy-jax floor and all ppermute-ring /
    collective-matmul plans (see module docstring for the ring algebra).
    """
    reports = analyze_shard_maps(fn_or_jaxpr, *abstract_args)
    if not reports:
        errs = ["no shard_map found in traced step"]
    else:
        errs = [str(e) for r in reports for e in r.errors]
    if errs and strict:
        raise AssertionError("replication lint failed:\n  "
                             + "\n  ".join(errs))
    return errs
