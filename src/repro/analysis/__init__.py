"""Plan-conformance static analysis (no execution required).

Three checkers over built steps:

  - :mod:`repro.analysis.signature` — trace a compiled step to its jaxpr
    and extract the collective signature (op, mesh axes, payload bytes,
    count, segment attribution via the load-bearing named scopes);
  - :mod:`repro.analysis.expect` — derive the signature a
    :class:`~repro.core.plan.ParallelPlan` + ModelConfig SHOULD emit and
    diff it against the extracted one with segment-specific diagnostics;
  - :mod:`repro.analysis.replication` — jaxpr-walking replication (vma)
    lint that certifies shard_map ``out_specs`` even where upstream's
    checker is disabled (legacy jax, ppermute rings, quantized wires).

``python -m repro.analysis.lint`` sweeps the config zoo; ``make
lint-plans`` gates it in CI.  See docs/analysis.md.
"""
from repro.analysis.expect import (assert_step_conforms, check_conformance,
                                   expected_signature, lint_conformance)
from repro.analysis.signature import Collective, StepSignature, extract
from repro.analysis.replication import verify_replication

__all__ = [
    "Collective", "StepSignature", "extract",
    "expected_signature", "check_conformance", "lint_conformance",
    "assert_step_conforms", "verify_replication",
]
