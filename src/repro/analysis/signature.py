"""Collective signature extraction: jaxpr -> what the program really emits.

The extractor traces a built step (any callable — the jitted functions
from ``launch.steps`` builders, or a bare shard_map'd function) with
abstract arguments and recursively walks the jaxpr: through ``pjit`` /
``shard_map`` bodies, ``scan`` bodies multiplied by their trip count,
``remat2`` / checkpoint replays, ``custom_vjp`` call jaxprs (forward-only
steps; AD inlines them in differentiated ones) and ``cond`` branches.

Every collective primitive is recorded with its mesh axes, payload
element count, dtype and an attribution read from the jaxpr name stack:

  - ``seg{i}:{kind}`` / ``shell:*`` scopes (``models/lm.py``) attribute a
    collective to a plan segment or to the model shell;
  - ``transpose(...)`` entries mark the backward (cotangent) region;
  - ``ring_rs/ring_ag/ring_ar/cm_rs/cm_ag[axis]`` scopes
    (``core/overlap.py``) mark ppermutes belonging to a ring schedule;
  - ``quant[axis]`` scopes mark payloads that ride the quantized wire —
    the grid values are *held* in f32 (so the unmodified collectives sum
    them exactly) but each element carries 1 byte of information, which
    is what ``wire_bytes`` prices (and what the cost model priced).

Byte conventions match ``launch/hlo_analysis.py`` so the two extraction
backends cross-check: all-reduce/all-gather/permute/all-to-all count
result bytes, reduce-scatter counts result x group (== operand) bytes.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Any, Callable, Iterable

import jax
import numpy as np
from jax import core as jcore

#: primitives the extractor records (axis_index is free; pmean lowers to
#: psum + divide so it never appears as its own primitive)
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmax", "pmin", "ppermute", "all_gather", "reduce_scatter",
    "all_to_all",
})

_SEG_RE = re.compile(r"^seg\d+:[a-z_]+$")
_SITE_RE = re.compile(r"^(ring_rs|ring_ag|ring_ar|cm_rs|cm_ag|quant|wireq)\[")


@dataclasses.dataclass(frozen=True)
class Collective:
    """One aggregated collective: ``count`` invocations of ``op`` over
    ``axes`` moving ``elems`` elements of ``dtype`` each."""

    op: str
    axes: tuple[str, ...]
    elems: int
    dtype: str
    quant: bool
    region: str          # "seg0:dense", "shell:embed", ... ("" = outside)
    backward: bool
    site: str            # innermost ring/quant scope ("" = monolithic)
    count: int = 1

    @property
    def raw_bytes(self) -> int:
        """Wire bytes at the dtype the payload is held in."""
        return self.count * self.elems * _dtype_bytes(self.dtype)

    @property
    def wire_bytes(self) -> int:
        """Information bytes on the wire: quantized payloads carry one
        byte per element regardless of the f32 container."""
        per = 1 if self.quant else _dtype_bytes(self.dtype)
        return self.count * self.elems * per

    @property
    def key(self):
        return (self.region, self.backward, self.op, self.axes)

    def describe(self) -> str:
        ax = "+".join(self.axes) or "-"
        q = " quant" if self.quant else ""
        bwd = " bwd" if self.backward else ""
        return (f"{self.count}x{self.op}[{ax}] {self.elems}elem "
                f"{self.dtype}{q}{bwd}")


@dataclasses.dataclass
class StepSignature:
    """All collectives of one traced step, scan-trip multiplied."""

    collectives: tuple[Collective, ...]
    warnings: tuple[str, ...] = ()

    def filter(self, region: str | None = None,
               backward: bool | None = None,
               op: str | None = None) -> "StepSignature":
        out = [c for c in self.collectives
               if (region is None or c.region == region)
               and (backward is None or c.backward == backward)
               and (op is None or c.op == op)]
        return StepSignature(tuple(out), self.warnings)

    def regions(self) -> tuple[str, ...]:
        return tuple(sorted({c.region for c in self.collectives}))

    def count(self, op: str | None = None) -> int:
        return sum(c.count for c in self.collectives
                   if op is None or c.op == op)

    def raw_bytes(self, op: str | None = None) -> int:
        return sum(c.raw_bytes for c in self.collectives
                   if op is None or c.op == op)

    def wire_bytes(self) -> int:
        return sum(c.wire_bytes for c in self.collectives)

    def by_key(self) -> dict[tuple, tuple[int, int, int]]:
        """{(region, backward, op, axes): (count, raw_bytes, wire_bytes)}."""
        agg: dict[tuple, list[int]] = defaultdict(lambda: [0, 0, 0])
        for c in self.collectives:
            a = agg[c.key]
            a[0] += c.count
            a[1] += c.raw_bytes
            a[2] += c.wire_bytes
        return {k: tuple(v) for k, v in agg.items()}

    def op_bytes(self) -> dict[str, int]:
        """{op: raw bytes} — the cross-check currency vs the HLO backend
        (XLA's all-reduce combiner merges ops, so counts don't compare)."""
        agg: dict[str, int] = defaultdict(int)
        for c in self.collectives:
            agg[c.op] += c.raw_bytes
        return dict(agg)

    def describe(self, prefix: str = "") -> str:
        lines = []
        for key, (n, rb, wb) in sorted(self.by_key().items()):
            region, bwd, op, axes = key
            ax = "+".join(axes) or "-"
            lines.append(f"{prefix}{region or '<top>'}"
                         f"{'.bwd' if bwd else '.fwd'}: {n}x{op}[{ax}] "
                         f"raw={rb} wire={wb}")
        return "\n".join(lines)


def _dtype_bytes(name: str) -> float:
    return np.dtype(name).itemsize


def _axes_of(params: dict) -> tuple[str, ...]:
    for k in ("axes", "axis_name"):
        if k in params:
            ax = params[k]
            return tuple(ax) if isinstance(ax, (tuple, list)) else (str(ax),)
    return ()


def _aval_elems(var) -> int:
    return int(np.prod(var.aval.shape)) if var.aval.shape else 1


def _payload(eqn) -> tuple[int, str]:
    """(elements, dtype) under the HLO-matching byte convention."""
    name = eqn.primitive.name
    if name in ("psum", "pmax", "pmin", "reduce_scatter"):
        # all-reduce: result == operand; reduce-scatter: result x group
        elems = sum(_aval_elems(v) for v in eqn.invars
                    if hasattr(v.aval, "shape"))
        dt = eqn.invars[0].aval.dtype.name
        return elems, dt
    elems = sum(_aval_elems(v) for v in eqn.outvars)
    return elems, eqn.outvars[0].aval.dtype.name


def _stack_components(eqn) -> tuple[str, ...]:
    ns = getattr(eqn.source_info, "name_stack", None)
    s = str(ns) if ns is not None else ""
    return tuple(p for p in s.split("/") if p)


def _attribution(path: tuple[str, ...]) -> tuple[str, bool, bool, str]:
    """(region, backward, quant, site) from a composed scope path."""
    region, site, quant = "", "", False
    backward = any("transpose(" in p for p in path)
    for p in path:
        bare = _strip_transforms(p)
        if _SEG_RE.match(bare) or bare.startswith("shell:"):
            region = bare
        if bare.startswith("quant["):
            quant = True
        if _SITE_RE.match(bare):
            site = bare
    return region, backward, quant, site


def _strip_transforms(comp: str) -> str:
    """'transpose(jvp(seg0:dense))' -> 'seg0:dense'."""
    out = comp
    while True:
        m = re.match(r"^[a-z_0-9]+\((.*)\)$", out)
        if not m:
            return out
        out = m.group(1)


def _sub_jaxpr(x):
    if isinstance(x, jcore.ClosedJaxpr):
        return x.jaxpr
    if isinstance(x, jcore.Jaxpr):
        return x
    return None


class _Walker:
    def __init__(self):
        self.hits: list[Collective] = []
        self.warnings: list[str] = []

    def walk(self, jaxpr: jcore.Jaxpr, mult: int,
             path: tuple[str, ...]) -> None:
        for eqn in jaxpr.eqns:
            p = path + _stack_components(eqn)
            name = eqn.primitive.name
            if name in COLLECTIVE_PRIMS:
                elems, dtype = _payload(eqn)
                region, backward, quant, site = _attribution(p)
                self.hits.append(Collective(
                    op=name, axes=_axes_of(eqn.params), elems=elems,
                    dtype=dtype, quant=quant, region=region,
                    backward=backward, site=site, count=mult))
            elif name == "scan":
                body = eqn.params["jaxpr"].jaxpr
                self.walk(body, mult * int(eqn.params["length"]), p)
            elif name == "while":
                # trip count is dynamic at the jaxpr level; record once and
                # flag it (the HLO backend reads known_trip_count instead)
                if self._has_collectives(eqn.params["body_jaxpr"].jaxpr):
                    self.warnings.append(
                        f"while loop with collectives at {'/'.join(p)}: "
                        f"counted for ONE trip")
                self.walk(eqn.params["body_jaxpr"].jaxpr, mult, p)
                self.walk(eqn.params["cond_jaxpr"].jaxpr, mult, p)
            elif name == "cond":
                self._walk_cond(eqn, mult, p)
            else:
                self._walk_generic(eqn, mult, p)

    def _walk_cond(self, eqn, mult: int, path: tuple[str, ...]) -> None:
        branches = eqn.params["branches"]
        sub = []
        for br in branches:
            w = _Walker()
            w.walk(br.jaxpr, mult, path)
            sub.append(w)
        sigs = [StepSignature(tuple(w.hits)).by_key() for w in sub]
        if any(s != sigs[0] for s in sigs[1:]):
            self.warnings.append(
                f"cond branches disagree on collectives at "
                f"{'/'.join(path)}: counted branch 0 only")
        self.hits.extend(sub[0].hits)
        for w in sub:
            self.warnings.extend(w.warnings)

    def _walk_generic(self, eqn, mult: int, path: tuple[str, ...]) -> None:
        for v in eqn.params.values():
            j = _sub_jaxpr(v)
            if j is not None:
                self.walk(j, mult, path)

    def _has_collectives(self, jaxpr: jcore.Jaxpr) -> bool:
        w = _Walker()
        w.walk(jaxpr, 1, ())
        return bool(w.hits)


def trace_jaxpr(fn: Callable, *abstract_args) -> jcore.ClosedJaxpr:
    """Trace a built step (jitted or bare) with ShapeDtypeStruct args."""
    if hasattr(fn, "trace"):  # jitted
        return fn.trace(*abstract_args).jaxpr
    return jax.make_jaxpr(fn)(*abstract_args)


def extract(fn_or_jaxpr: Any, *abstract_args) -> StepSignature:
    """Extract the collective signature of a built step.

    Accepts a (jitted or bare) callable plus its abstract arguments, or a
    ready ClosedJaxpr/Jaxpr.
    """
    j = _sub_jaxpr(fn_or_jaxpr)
    if j is None:
        j = trace_jaxpr(fn_or_jaxpr, *abstract_args).jaxpr
    w = _Walker()
    w.walk(j, 1, ())
    return StepSignature(tuple(w.hits), tuple(w.warnings))


def aggregate(collectives: Iterable[Collective]) -> StepSignature:
    """Merge identical entries (same full identity) summing counts."""
    agg: dict[tuple, int] = defaultdict(int)
    for c in collectives:
        k = (c.op, c.axes, c.elems, c.dtype, c.quant, c.region,
             c.backward, c.site)
        agg[k] += c.count
    return StepSignature(tuple(
        Collective(op=k[0], axes=k[1], elems=k[2], dtype=k[3], quant=k[4],
                   region=k[5], backward=k[6], site=k[7], count=n)
        for k, n in agg.items()))
