"""Expected collective signatures: what a plan SHOULD emit.

This module is the other half of the conformance linter: given a
ModelConfig + :class:`~repro.core.plan.ParallelPlan` + phase it derives,
in pure Python (no tracing), the exact collective inventory the cost
model priced — per region (``seg{i}:{kind}`` / ``shell:*``), per op, per
mesh axes, with payload element counts at the dtype each payload is held
in.  ``check_conformance`` diffs it against an extracted
:class:`~repro.analysis.signature.StepSignature` and reports
segment-specific errors, e.g.::

    seg1:moe fwd: expected 2x all_to_all[tp1+tp2], found 4
    seg0:dense fwd: psum[tp2] raw bytes 32768 != expected 16384
    seg0:dense fwd: expected quantized psum[tp2] (int8 wire), found
    full-width

The emitters mirror the execution dispatch *decision for decision*:
``ATPContext.for_segment`` (per-segment knob views, seq_parallel
masking), ``resolve_ctx(decode=True)`` (decode sub-plan knob
application), ``atp_linear`` (sp-row reduce-scatter vs ring vs quant;
chunk clamp ``c = min(chunks, local_batch)``), ``overlap.ring_all_reduce``
(``_pick_ring_dim`` + the bidirectional split rule) and every model
block's boundary schedule.  Payload byte conventions match
``analysis.signature`` / ``launch.hlo_analysis``: all-reduce counts
operand bytes, all-gather/all-to-all/ppermute count result bytes,
reduce-scatter counts operand (result x group) bytes.  Quantized
payloads are *held* in f32 (the grid trick in ``core.overlap``) — the
expectation prices them at f32 raw bytes with ``quant=True``, exactly
like the extractor.

Forward regions are checked exactly (counts + bytes); the backward pass
is checked structurally (a ring-planned segment must run ppermute rings
backward, a psum-planned one must not, a quantized boundary's cotangent
must ride the quantized wire) — AD owns the exact backward schedule.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

from repro.configs.base import ModelConfig, segments

ACT = "bfloat16"
F32 = "float32"
I32 = "int32"

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "int32": 4}

#: phases the expectation engine understands (paged steps carry extra
#: scheduler plumbing and are covered by the byte-drift benchmarks, not
#: the exact linter)
PHASES = ("train", "prefill", "decode")


class PlanConformanceError(AssertionError):
    """A compiled step's collectives disagree with the plan that priced it."""


@dataclasses.dataclass(frozen=True)
class Exp:
    """One expected line item: ``count`` invocations of ``op`` over
    ``axes`` moving ``elems`` elements TOTAL (summed across the count) of
    ``dtype``.  ``elems=None`` is the pressure valve: count is checked,
    bytes are not."""

    op: str
    axes: tuple[str, ...]
    count: int
    elems: int | None
    dtype: str = ACT
    quant: bool = False

    @property
    def raw_bytes(self) -> int:
        if self.elems is None:
            return 0
        return self.elems * _DTYPE_BYTES[self.dtype]


@dataclasses.dataclass(frozen=True)
class View:
    """Pure-Python mirror of one segment's ``ATPContext.for_segment``
    view: mesh degrees + effective knobs (after per-segment override,
    seq-parallel masking and decode sub-plan application)."""

    d1: int
    d2: int
    dp: int
    chunks: int = 1
    boundary_mode: str = "psum"
    seq_parallel: bool = False
    wire_dtype: str = "bf16"
    act: str = ACT

    @property
    def ax1(self) -> str | None:
        return "tp1" if self.d1 > 1 else None

    @property
    def ax2(self) -> str | None:
        return "tp2" if self.d2 > 1 else None

    @property
    def tp(self) -> int:
        return self.d1 * self.d2

    @property
    def tp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in (self.ax1, self.ax2) if a)

    @property
    def quant(self) -> bool:
        return self.wire_dtype != "bf16"


@dataclasses.dataclass(frozen=True)
class Expectation:
    """Per-region expected collectives + structural backward rules."""

    regions: dict[str, tuple[Exp, ...]]
    phase: str
    notes: tuple[str, ...] = ()

    def by_key(self) -> dict[tuple, tuple[int, int, bool]]:
        """{(region, op, axes, quant): (count, raw_bytes, bytes_known)}."""
        agg: dict[tuple, list] = defaultdict(lambda: [0, 0, True])
        for region, exps in self.regions.items():
            for e in exps:
                a = agg[(region, e.op, e.axes, e.quant)]
                a[0] += e.count
                a[1] += e.raw_bytes
                if e.elems is None:
                    a[2] = False
        return {k: (v[0], v[1], v[2]) for k, v in agg.items()}

    def op_bytes(self) -> dict[str, int]:
        """{op: raw bytes} — comparable with StepSignature.op_bytes()."""
        agg: dict[str, int] = defaultdict(int)
        for exps in self.regions.values():
            for e in exps:
                agg[e.op] += e.raw_bytes
        return dict(agg)

    def describe(self) -> str:
        lines = []
        for key, (n, rb, known) in sorted(self.by_key().items()):
            region, op, axes, quant = key
            ax = "+".join(axes) or "-"
            q = " quant" if quant else ""
            b = f"raw={rb}" if known else "raw=?"
            lines.append(f"{region}: {n}x{op}[{ax}]{q} {b}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Knob resolution (mirrors ATPContext.for_segment + resolve_ctx).
# ---------------------------------------------------------------------------

#: kinds whose block I/O may run the sequence-parallel spec — must match
#: repro.core.atp.SEQ_PARALLEL_KINDS (asserted by tests)
SEQ_PARALLEL_KINDS = frozenset({"dense", "mla_dense"})


def _segment_view(plan, kind: str, decode: bool, act: str = ACT) -> View:
    sp = plan.segment_plan(kind)
    chunks, bm, seqp, wd = (sp.chunks, sp.boundary_mode, sp.seq_parallel,
                            sp.wire_dtype)
    if decode and getattr(plan, "decode", None) is not None:
        dec = plan.decode
        chunks, bm, wd = dec.chunks, dec.boundary_mode, dec.wire_dtype
    if decode or kind not in SEQ_PARALLEL_KINDS:
        seqp = False
    return View(d1=plan.d1, d2=plan.d2, dp=plan.dp * plan.pods,
                chunks=chunks, boundary_mode=bm, seq_parallel=seqp,
                wire_dtype=wd, act=act)


def _shell_view(plan, decode: bool, act: str = ACT) -> View:
    """The scalar-knob context the model shell (embed/exit/head/mtp) runs
    under — the plan's global knobs with decode overrides, sp as-is (the
    shell consults per-site sp decisions separately)."""
    chunks, bm, wd = plan.chunks, plan.boundary_mode, plan.wire_dtype
    if decode and getattr(plan, "decode", None) is not None:
        dec = plan.decode
        chunks, bm, wd = dec.chunks, dec.boundary_mode, dec.wire_dtype
    return View(d1=plan.d1, d2=plan.d2, dp=plan.dp * plan.pods,
                chunks=chunks, boundary_mode=bm, seq_parallel=False,
                wire_dtype=wd, act=act)


# ---------------------------------------------------------------------------
# Low-level boundary emitters (mirror core.atp / core.overlap dispatch).
# ---------------------------------------------------------------------------


def _pick_ring_dim(shape, d: int):
    best, best_size = None, 0
    for i, s in enumerate(shape):
        if s % d == 0 and s > best_size:
            best, best_size = i, s
    return best


def _prod(shape) -> int:
    return math.prod(shape)


def _wireq(ax: str) -> Exp:
    """wire_quantize's shared-scale pmax (scalar amax, f32).  It runs
    inside the ``quant[axis]`` scope so the extractor tags it quantized —
    mirror that here so the keys line up."""
    return Exp("pmax", (ax,), 1, 1, F32, quant=True)


def _ring_ar(shape, d: int, ax: str, dtype: str, quant: bool) -> list[Exp]:
    """overlap.ring_all_reduce on a local tensor of ``shape``."""
    E = _prod(shape)
    dim = _pick_ring_dim(shape, d)
    if dim is None:  # monolithic fallback inside the ring_ar scope
        return [Exp("psum", (ax,), 1, E, dtype, quant)]
    if shape[dim] % (2 * d) == 0:  # bidirectional: halves circle both ways
        return [Exp("ppermute", (ax,), 4 * (d - 1),
                    4 * (d - 1) * (E // (2 * d)), dtype, quant)]
    return [Exp("ppermute", (ax,), 2 * (d - 1), 2 * (d - 1) * (E // d),
                dtype, quant)]


def _one_boundary(v: View, shape, ax: str, d: int) -> list[Exp]:
    """One monolithic boundary all-reduce of a local ``shape`` payload
    (atp_linear's non-sp tail: ring / quant / plain psum)."""
    E = _prod(shape)
    if v.boundary_mode == "ring":
        out = [_wireq(ax)] if v.quant else []
        return out + _ring_ar(shape, d, ax, F32 if v.quant else v.act, v.quant)
    if v.quant:
        return [_wireq(ax), Exp("psum", (ax,), 1, E, F32, True)]
    return [Exp("psum", (ax,), 1, E, v.act)]


def _chunk_sizes(b: int, chunks: int) -> list[int]:
    """jnp.split / jnp.array_split sizes for the leading (batch) dim."""
    c = max(1, min(chunks, b))
    if b % c == 0:
        return [b // c] * c
    hi, rem = divmod(b, c)
    return [hi + 1] * rem + [hi] * (c - rem)


def _linear(v: View, b: int, s: int, out_loc: int, kind: str) -> list[Exp]:
    """atp_linear's boundary collectives for a [b, s, K_loc] @ W GEMM with
    local output width ``out_loc``."""
    ax = v.ax2 if kind == "col" else v.ax1
    d = v.d2 if kind == "col" else v.d1
    if ax is None:
        return []
    E = b * s * out_loc
    if v.seq_parallel and kind == "row":
        ring = v.boundary_mode == "ring" and s % v.d1 == 0
        if v.quant:
            out = [_wireq(ax)]
            if ring:  # quant ring reduce-scatter: d-1 hops of one block
                out.append(Exp("ppermute", (ax,), d - 1,
                               (d - 1) * (E // d), F32, True))
            else:
                out.append(Exp("reduce_scatter", (ax,), 1, E, F32, True))
            return out
        if ring:  # collective matmul (cm_rs): d-1 hops of the acc block
            return [Exp("ppermute", (ax,), d - 1, (d - 1) * (E // d), v.act)]
        return [Exp("reduce_scatter", (ax,), 1, E, v.act)]
    if v.chunks > 1:
        out = []
        for bc in _chunk_sizes(b, v.chunks):
            out += _one_boundary(v, (bc, s, out_loc), ax, d)
        return out
    return _one_boundary(v, (b, s, out_loc), ax, d)


def _norm(v: View, cfg: ModelConfig, b: int, s_norm: int,
          gather: bool = False, feat: int | None = None) -> list[Exp]:
    """layers.norm: 1 (rms) / 2 (layernorm) tiny f32 psum(ax2) over the
    keepdims reduction, optionally folding the conjugate seq all-gather."""
    out = []
    n_psum = 2 if cfg.norm_kind == "layernorm" else 1
    if v.ax2:
        out.append(Exp("psum", (v.ax2,), n_psum, n_psum * b * s_norm, F32))
    if gather:
        out += _seq_gather(v, b, s_norm, feat)
    return out


def _seq_gather(v: View, b: int, s_loc: int, feat: int) -> list[Exp]:
    """atp.seq_gather: AG(ax1) back to full sequence (ring_ag when the
    segment runs ring boundaries)."""
    if not v.seq_parallel or v.ax1 is None:
        return []
    if v.boundary_mode == "ring":
        return [Exp("ppermute", (v.ax1,), v.d1 - 1,
                    (v.d1 - 1) * b * s_loc * feat, v.act)]
    return [Exp("all_gather", (v.ax1,), 1, b * s_loc * v.d1 * feat, v.act)]


# ---------------------------------------------------------------------------
# Attention / block emitters (mirror models.*).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _AttnPlan:
    g: int
    q_loc: int
    r: int
    h2: int
    q_regroup: bool
    kv_regroup: bool


def _attn_plan(H: int, KV: int, d1: int, d2: int) -> _AttnPlan:
    n = d1 * d2
    q_regroup = H % d1 != 0
    if q_regroup:
        g = math.gcd(H, n)
        h2 = 1
    else:
        h2 = math.gcd(H // d1, d2)
        g = d1 * h2
    return _AttnPlan(g=g, q_loc=H // g, r=n // g, h2=h2,
                     q_regroup=q_regroup, kv_regroup=KV % d1 != 0)


def _attn(v: View, cfg: ModelConfig, b: int, s: int, decode: bool) -> list[Exp]:
    """transformer.attn_block: fused f1 psum, head-regroup gathers, the
    core output gather, the f2 row boundary."""
    out = []
    hd = cfg.hd
    if v.ax2:  # f1: fused qkv boundary (always a plain psum)
        out.append(Exp("psum", (v.ax2,), 1,
                       b * s * (cfg.q_dim + 2 * cfg.kv_dim) // v.d1, v.act))
    ap = _attn_plan(cfg.num_heads, cfg.num_kv_heads, v.d1, v.d2)
    if ap.q_regroup and v.ax1:
        out.append(Exp("all_gather", (v.ax1,), 1, b * s * cfg.q_dim, v.act))
    if ap.kv_regroup and v.ax1:
        out.append(Exp("all_gather", (v.ax1,), 2, 2 * b * s * cfg.kv_dim, v.act))
    # core output gather (layers.core_output_gather)
    seq_split = not decode
    s_r = s // ap.r if (seq_split and ap.r > 1) else s
    F = ap.q_loc * hd
    if v.tp > 1:
        if ap.q_regroup:  # untiled AG over BOTH tp axes
            out.append(Exp("all_gather", v.tp_axes, 1,
                           v.tp * b * s_r * F, v.act))
        elif v.ax2:       # untiled AG over ax2
            out.append(Exp("all_gather", (v.ax2,), 1,
                           v.d2 * b * s_r * F, v.act))
    # f2: row-first output projection
    out += _linear(v, b, s, cfg.d_model // v.d2, "row")
    return out


def _mlp(v: View, cfg: ModelConfig, b: int, s: int,
         d_ff: int | None = None) -> list[Exp]:
    """transformer.mlp_block: fused up(+gate) col boundary + row down."""
    ff = d_ff if d_ff is not None else cfg.d_ff
    n_cols = 2 * ff if cfg.mlp_kind in ("swiglu", "geglu") else ff
    out = _linear(v, b, s, n_cols // v.d1, "col")
    out += _linear(v, b, s, cfg.d_model // v.d2, "row")
    return out


def _dense_layer(v: View, cfg: ModelConfig, b: int, S: int, decode: bool,
                 d_ff: int | None = None) -> list[Exp]:
    sp = v.seq_parallel and not decode
    s_norm = S // v.d1 if sp else S
    hl = cfg.d_model // v.d2
    nv = dataclasses.replace(v, seq_parallel=sp)
    out = _norm(nv, cfg, b, s_norm, gather=sp, feat=hl)
    out += _attn(nv, cfg, b, S, decode)
    if cfg.post_block_norms:
        out += _norm(nv, cfg, b, s_norm)
    out += _norm(nv, cfg, b, s_norm, gather=sp, feat=hl)
    out += _mlp(nv, cfg, b, S, d_ff)
    if cfg.post_block_norms:
        out += _norm(nv, cfg, b, s_norm)
    return out


def _moe_ffn(v: View, cfg: ModelConfig, b: int, s: int) -> list[Exp]:
    """moe.moe_block: EP dispatch over the flat TP group."""
    mc = cfg.moe
    n, h = v.tp, cfg.d_model
    hl = h // v.d2
    T = b * s
    out = []
    if T % n != 0 or T // n == 0:  # replicated dispatch (decode-sized)
        if v.ax2:
            out.append(Exp("all_gather", (v.ax2,), 1, T * h, v.act))
        if v.tp_axes:
            out.append(Exp("psum", v.tp_axes, 1, 1, F32))        # aux loss
            out.append(Exp("psum", v.tp_axes, 1, T * h, v.act))    # combine
    else:
        if v.ax2:  # token scatter: swap token-shard for feature-gather
            out.append(Exp("all_to_all", (v.ax2,), 1, T * hl, v.act))
        if v.tp_axes:
            out.append(Exp("psum", v.tp_axes, 1, 1, F32))        # aux loss
            tn = T // n
            cap = max(1, int(mc.capacity_factor * tn * mc.top_k
                             / mc.num_experts))
            e_loc = max(1, mc.num_experts // n)
            buf = n * e_loc * cap * h
            out.append(Exp("all_to_all", v.tp_axes, 2, 2 * buf, v.act))
        if v.ax1:  # token gather back: place + psum (ax1-invariant)
            out.append(Exp("psum", (v.ax1,), 1, (T // v.d2) * h, v.act))
        if v.ax2:
            out.append(Exp("all_to_all", (v.ax2,), 1, T * hl, v.act))
    if mc.num_shared:
        out += _mlp(v, cfg, b, s, d_ff=mc.d_ff_expert * mc.num_shared)
    return out


def _moe_layer(v: View, cfg: ModelConfig, b: int, S: int,
               decode: bool) -> list[Exp]:
    out = _norm(v, cfg, b, S)
    out += _attn(v, cfg, b, S, decode)
    out += _norm(v, cfg, b, S)
    out += _moe_ffn(v, cfg, b, S)
    return out


def _mla_layer(v: View, cfg: ModelConfig, b: int, S: int, decode: bool,
               moe: bool) -> list[Exp]:
    m = cfg.mla
    sp = v.seq_parallel and not decode
    s_norm = S // v.d1 if sp else S
    hl = cfg.d_model // v.d2
    nv = dataclasses.replace(v, seq_parallel=sp)
    out = _norm(nv, cfg, b, s_norm, gather=sp, feat=hl)
    if v.ax2:  # latent down-projections: replicated outputs via psum(ax2)
        out.append(Exp("psum", (v.ax2,), 1, b * S * m.q_lora_rank, v.act))
        out.append(Exp("psum", (v.ax2,), 1,
                       b * S * (m.kv_lora_rank + m.qk_rope_head_dim), v.act))
    if v.ax2:  # core output gather back to ax1-sharded layout
        out.append(Exp("all_gather", (v.ax2,), 1,
                       b * S * (cfg.num_heads // v.d1) * m.v_head_dim, v.act))
    out += _linear(nv, b, S, cfg.d_model // v.d2, "row")   # wo
    out += _norm(nv, cfg, b, s_norm, gather=sp, feat=hl)
    if moe:
        out += _moe_ffn(v, cfg, b, S)
    else:
        out += _mlp(nv, cfg, b, S)
    return out


def _mamba_layer(v: View, cfg: ModelConfig, b: int, S: int) -> list[Exp]:
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    nheads = d_inner // sc.head_dim
    out = []
    if v.ax2:
        out.append(Exp("psum", (v.ax2,), 1, b * S, F32))              # rms
        out.append(Exp("psum", (v.ax2,), 1,
                       b * S * 2 * d_inner // v.d1, v.act))             # z|x
        out.append(Exp("psum", (v.ax2,), 1,
                       b * S * (2 * sc.d_state + nheads), v.act))       # B/C/dt
        out.append(Exp("all_gather", (v.ax2,), 1,
                       b * S * d_inner // v.d1, v.act))                 # heads
    out += _linear(v, b, S, cfg.d_model // v.d2, "row")               # w_out
    return out


def _zamba_super(v: View, cfg: ModelConfig, b: int, S: int, decode: bool,
                 inner: int) -> list[Exp]:
    h = cfg.d_model
    out = []
    if v.ax2:  # shared-attn entry: two fused column projections, one psum
        out.append(Exp("psum", (v.ax2,), 1, b * S * h // v.d1, v.act))
    if v.ax1:  # _gather_ax1_invariant: place + psum
        out.append(Exp("psum", (v.ax1,), 1, b * S * h, v.act))
    out += _dense_layer(v, cfg, b, S, decode)
    for _ in range(inner - 1):
        out += _mamba_layer(v, cfg, b, S)
    return out


def _xlstm_super(v: View, cfg: ModelConfig, b: int, S: int,
                 inner: int) -> list[Exp]:
    d_inner = int(cfg.ssm.proj_factor * cfg.d_model)
    nh = cfg.num_heads
    dk = (d_inner // nh) // 2
    h = cfg.d_model
    mlstm: list[Exp] = []
    if v.ax2:
        mlstm.append(Exp("psum", (v.ax2,), 1, b * S, F32))            # rms
        mlstm.append(Exp("psum", (v.ax2,), 1,
                         b * S * 2 * d_inner // v.d1, v.act))           # up|z
        mlstm.append(Exp("psum", (v.ax2,), 1,
                         b * S * 2 * nh * dk // v.d1, v.act))           # q|k
        mlstm.append(Exp("psum", (v.ax2,), 1, b * S * 2 * nh, v.act))   # i|f
    if v.ax1:
        mlstm.append(Exp("all_gather", (v.ax1,), 1,
                         b * S * 2 * nh * dk, v.act))                   # q|k
    if v.tp_axes:  # down projection: all-reduce over BOTH mesh dims
        mlstm.append(Exp("psum", v.tp_axes, 1, b * S * h, v.act))
    out = _times(mlstm, inner - 1)
    if v.ax2:  # sLSTM runs on full-h replicated activations
        out.append(Exp("all_gather", (v.ax2,), 1, b * S * h, v.act))
    return out


def _layer_exps(seg, v: View, cfg: ModelConfig, b: int, S: int,
                decode: bool) -> list[Exp]:
    if seg.kind == "dense":
        return _dense_layer(v, cfg, b, S, decode)
    if seg.kind == "moe":
        return _moe_layer(v, cfg, b, S, decode)
    if seg.kind in ("mla_dense", "mla_moe"):
        return _mla_layer(v, cfg, b, S, decode, moe=seg.kind == "mla_moe")
    if seg.kind == "mamba":
        return _mamba_layer(v, cfg, b, S)
    if seg.kind == "zamba":
        return _zamba_super(v, cfg, b, S, decode, seg.inner)
    if seg.kind == "xlstm":
        return _xlstm_super(v, cfg, b, S, seg.inner)
    raise ValueError(seg.kind)


def _times(exps: list[Exp], k: int) -> list[Exp]:
    if k <= 0:
        return []
    return [dataclasses.replace(
        e, count=e.count * k,
        elems=None if e.elems is None else e.elems * k) for e in exps]


# ---------------------------------------------------------------------------
# Whole-step expectation.
# ---------------------------------------------------------------------------


def expected_signature(cfg: ModelConfig, plan, phase: str, batch: int,
                       seq: int) -> Expectation:
    """Derive the collective signature a built step SHOULD have.

    ``batch`` is the GLOBAL batch (the builders shard it over the data
    axes); ``seq`` is the full sequence for train/prefill and the token
    step width (normally 1) for decode.  Decode expectations mirror
    ``resolve_ctx(decode=True)``: the plan's :class:`DecodePlan` knobs
    replace chunks/boundary_mode/wire_dtype in every segment view and
    seq_parallel is masked everywhere.  A deployment serving on the
    decode mesh passes ``plan.decode_view()`` here, exactly as it does to
    the builders.
    """
    if phase not in PHASES:
        raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
    decode = phase == "decode"
    segs = segments(cfg)
    # activations are held at the model compute dtype (the reduced smoke
    # configs run float32; everything production-sized runs bf16)
    act = cfg.dtype if cfg.dtype in _DTYPE_BYTES else ACT
    views = [_segment_view(plan, s.kind, decode, act) for s in segs]
    sv = _shell_view(plan, decode, act)
    dpn = plan.dp * plan.pods
    b = batch // dpn if (dpn > 1 and batch % dpn == 0) else batch
    S = seq
    hl = cfg.d_model // plan.d2
    notes = []

    regions: dict[str, list[Exp]] = {}
    entry_view = views[0] if views else sv
    entry_sp = entry_view.seq_parallel

    # -- shell:embed -------------------------------------------------------
    emb: list[Exp] = []
    if cfg.frontend != "vision_patches":
        if entry_sp and entry_view.ax1:
            emb.append(Exp("reduce_scatter", (entry_view.ax1,), 1,
                           b * S * hl, entry_view.act))
        elif entry_view.ax1:
            emb.append(Exp("psum", (entry_view.ax1,), 1, b * S * hl, entry_view.act))
    regions["shell:embed"] = emb

    # -- segments + transitions -------------------------------------------
    cur_sp = entry_sp
    last_sp_view = entry_view if entry_sp else None
    for i, (seg, v) in enumerate(zip(segs, views)):
        trans: list[Exp] = []
        if cur_sp and not v.seq_parallel:
            trans = _seq_gather(last_sp_view, b, S // last_sp_view.d1, hl)
        regions[f"shell:trans{i}"] = trans
        cur_sp = v.seq_parallel
        if cur_sp:
            last_sp_view = v
        regions[f"seg{i}:{seg.kind}"] = _times(
            _layer_exps(seg, v, cfg, b, S, decode), seg.count)

    # -- shell:exit --------------------------------------------------------
    ex: list[Exp] = []
    s_loc = S // last_sp_view.d1 if cur_sp else S
    n_psum = 2 if cfg.norm_kind == "layernorm" else 1
    if sv.ax2:
        ex.append(Exp("psum", (sv.ax2,), n_psum, n_psum * b * s_loc, F32))
    if cur_sp:
        ex += _seq_gather(last_sp_view, b, s_loc, hl)
    regions["shell:exit"] = ex

    # -- shell:head / shell:loss / shell:pick ------------------------------
    v_loc = cfg.vocab_size // plan.d1
    head: list[Exp] = []
    s_head = S if phase == "train" else 1
    if sv.ax2:
        head.append(Exp("psum", (sv.ax2,), 1, b * s_head * v_loc, sv.act))
    if phase == "train" and sv.ax1:  # vocab-parallel CE
        head.append(Exp("pmax", (sv.ax1,), 1, b * S, F32))
        head.append(Exp("psum", (sv.ax1,), 2, 2 * b * S, F32))
    regions["shell:head"] = head

    if phase == "train":
        loss: list[Exp] = []
        dp_axes = ("data",) if dpn > 1 else ()
        if dp_axes:
            loss.append(Exp("psum", dp_axes, 2, 2, F32))
            if cfg.moe is not None:  # pmean of the aux loss lowers to psum
                loss.append(Exp("psum", dp_axes, 1, 1, F32))
        regions["shell:loss"] = loss
        if cfg.mtp and cfg.frontend != "vision_patches":
            regions["shell:mtp"] = _mtp_exps(sv, cfg, b, S, dp_axes)
    else:
        regions["shell:pick"] = _pick_exps(sv, b)

    if any("while" in n for n in notes):
        pass
    return Expectation(regions={k: tuple(vv) for k, vv in regions.items()},
                       phase=phase, notes=tuple(notes))


def _pick_exps(sv: View, b: int) -> list[Exp]:
    """launch.steps._greedy_pick: vocab-parallel argmax over ax1."""
    if sv.ax1 is None:
        return []
    return [Exp("pmax", (sv.ax1,), 1, b, F32),
            Exp("pmin", (sv.ax1,), 1, b, I32)]


def _mtp_exps(sv: View, cfg: ModelConfig, b: int, S: int,
              dp_axes: tuple[str, ...]) -> list[Exp]:
    """models.lm train MTP head: embed + fused proj + ax1 regather + one
    dense/mla block on the GLOBAL scalar knobs + norm + logits + CE."""
    h = cfg.d_model
    hl = h // sv.d2
    out: list[Exp] = []
    if sv.ax1:
        out.append(Exp("psum", (sv.ax1,), 1, b * S * hl, sv.act))   # emb(t+1)
    if sv.ax2:
        out.append(Exp("psum", (sv.ax2,), 1, b * S * h // sv.d1, sv.act))
    if sv.ax1:
        out.append(Exp("all_gather", (sv.ax1,), 1, b * S * h, sv.act))
    seg = _FakeSeg("mla_dense" if cfg.mla else "dense")
    out += _layer_exps(seg, sv, cfg, b, S, False)
    n_psum = 2 if cfg.norm_kind == "layernorm" else 1
    if sv.ax2:
        out.append(Exp("psum", (sv.ax2,), n_psum, n_psum * b * S, F32))
        out.append(Exp("psum", (sv.ax2,), 1,
                       b * S * cfg.vocab_size // sv.d1, sv.act))
    if sv.ax1:
        out.append(Exp("pmax", (sv.ax1,), 1, b * S, F32))
        out.append(Exp("psum", (sv.ax1,), 2, 2 * b * S, F32))
    if dp_axes:
        out.append(Exp("psum", dp_axes, 1, 1, F32))
    return out


@dataclasses.dataclass(frozen=True)
class _FakeSeg:
    kind: str
    count: int = 1
    inner: int = 1


# ---------------------------------------------------------------------------
# Diff engine.
# ---------------------------------------------------------------------------


def _fmt_key(op: str, axes: tuple[str, ...], quant: bool) -> str:
    ax = "+".join(axes) or "-"
    return f"{'quant ' if quant else ''}{op}[{ax}]"


def check_conformance(sig, exp: Expectation) -> list[str]:
    """Diff an extracted StepSignature against an Expectation.

    Returns a list of human-readable errors (empty == conformant):
    forward regions are compared exactly by (op, axes, quantized) —
    counts and raw payload bytes — and the backward pass is checked
    structurally against rules derived from the forward expectation
    (ring segments must run ppermutes backward, psum segments must not,
    quantized boundaries must quantize the cotangent).
    """
    errors: list[str] = []

    # ---- forward: exact ---------------------------------------------------
    found: dict[tuple, list[int]] = defaultdict(lambda: [0, 0])
    fwd_regions = set()
    for c in sig.collectives:
        if c.backward or not c.region:
            continue
        fwd_regions.add(c.region)
        a = found[(c.region, c.op, c.axes, c.quant)]
        a[0] += c.count
        a[1] += c.raw_bytes
    want = exp.by_key()

    for key in sorted(set(found) | set(want)):
        region, op, axes, quant = key
        if region not in exp.regions:
            continue  # whole-region mismatch reported below
        fc, fb = found.get(key, (0, 0))
        wc, wb, known = want.get(key, (0, 0, True))
        if fc == wc and (not known or fb == wb or wc == 0):
            continue
        k = _fmt_key(op, axes, quant)
        if wc == 0:
            # special-case the quant-flag flip for a sharper diagnostic
            flip = (region, op, axes, not quant)
            if flip in want and flip not in found:
                wire = "full-width" if quant else "quantized"
                have = "quantized" if quant else "full-width"
                errors.append(
                    f"{region} fwd: expected {wire} {_fmt_key(op, axes, False)}"
                    f" payloads, found {have}")
                continue
            errors.append(f"{region} fwd: unexpected {fc}x {k}")
        elif fc != wc:
            errors.append(f"{region} fwd: expected {wc}x {k}, found {fc}")
        else:
            errors.append(
                f"{region} fwd: {k} raw bytes {fb} != expected {wb}")
    for region in sorted(set(exp.regions) - fwd_regions):
        if any(e.count for e in exp.regions[region]):
            errors.append(
                f"{region} fwd: region missing from trace (expected "
                + ", ".join(f"{e.count}x {_fmt_key(e.op, e.axes, e.quant)}"
                            for e in exp.regions[region]) + ")")
    for region in sorted(fwd_regions - set(exp.regions)):
        errors.append(f"{region} fwd: unexpected region in trace")

    # ---- backward: structural --------------------------------------------
    bwd_ppermute: dict[str, int] = defaultdict(int)
    bwd_quant: dict[str, int] = defaultdict(int)
    bwd_any: dict[str, int] = defaultdict(int)
    for c in sig.collectives:
        if not c.backward or not c.region:
            continue
        bwd_any[c.region] += c.count
        if c.op == "ppermute":
            bwd_ppermute[c.region] += c.count
        if c.quant:
            bwd_quant[c.region] += c.count
    if any(bwd_any.values()):  # differentiated step: apply structural rules
        for region, exps in exp.regions.items():
            ring = any(e.op == "ppermute" for e in exps)
            quant = any(e.quant for e in exps)
            if ring and bwd_any[region] and not bwd_ppermute[region]:
                errors.append(
                    f"{region} bwd: ring-planned segment ran no ppermute "
                    f"ring in the backward pass")
            if not ring and bwd_ppermute[region]:
                errors.append(
                    f"{region} bwd: psum-planned segment ran "
                    f"{bwd_ppermute[region]}x ppermute in the backward pass")
            if quant and bwd_any[region] and not bwd_quant[region]:
                errors.append(
                    f"{region} bwd: quantized-wire segment sent a "
                    f"full-width cotangent")
    return errors


def lint_conformance(sig, cfg: ModelConfig, plan, phase: str, batch: int,
                     seq: int, strict: bool = True) -> list[str]:
    """Expected-vs-extracted diff for one built step; raises
    :class:`PlanConformanceError` on mismatch when ``strict``."""
    exp = expected_signature(cfg, plan, phase, batch, seq)
    errors = check_conformance(sig, exp)
    if errors and strict:
        raise PlanConformanceError(
            f"{cfg.name} [{phase}] does not conform to its plan "
            f"({plan.describe()}):\n  " + "\n  ".join(errors))
    return errors


def assert_step_conforms(fn, cfg: ModelConfig, plan, phase: str, batch: int,
                         seq: int, *abstract_args) -> None:
    """One-call gate for the smokes: trace a built step, then require
    BOTH plan conformance (extracted == expected collectives) and proven
    out_spec replication.  Raises on the first violation."""
    from repro.analysis.replication import verify_replication
    from repro.analysis.signature import extract, trace_jaxpr

    jaxpr = trace_jaxpr(fn, *abstract_args)
    lint_conformance(extract(jaxpr), cfg, plan, phase, batch, seq)
    verify_replication(jaxpr)
