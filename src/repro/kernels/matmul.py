"""Blocked matmul Pallas TPU kernel with optional fused activation.

Grid (M/bm, N/bn, K/bk), K fastest; fp32 accumulator persists in VMEM
across K steps (MXU-aligned 128 tiles).  The fused-GeLU variant is the
compute side of the paper's chunk-based overlapping: one chunk's GEMM+act
is a single kernel launch whose output feeds the grouped all-reduce while
the next chunk computes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc, *, activation: str | None):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _fin():
        out = acc[...]
        if activation == "gelu":
            out = jax.nn.gelu(out, approximate=True)
        elif activation == "silu":
            out = jax.nn.silu(out)
        o_ref[...] = out.astype(o_ref.dtype)


def matmul(a, b, *, activation: str | None = None,
           block_m: int = 128, block_n: int = 128, block_k: int = 128,
           interpret: bool = False):
    """a: [M, K] @ b: [K, N] -> [M, N] (+fused activation)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    out = pl.pallas_call(
        functools.partial(_mm_kernel, activation=activation),
        grid=(a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:M, :N] if (pm or pn) else out
