"""Blocked matmul Pallas TPU kernel with optional fused activation.

Grid (M/bm, N/bn, K/bk), K fastest; fp32 accumulator persists in VMEM
across K steps (MXU-aligned 128 tiles).  The fused-GeLU variant is the
compute side of the paper's chunk-based overlapping: one chunk's GEMM+act
is a single kernel launch whose output feeds the grouped all-reduce while
the next chunk computes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, *rest, activation: str | None):
    bias_ref, o_ref, acc = rest if len(rest) == 3 else (None, *rest)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _fin():
        bias = None if bias_ref is None else bias_ref[...].astype(jnp.float32)
        out = _epilogue(acc[...], bias, activation)
        o_ref[...] = out.astype(o_ref.dtype)


def _epilogue(out, bias, activation: str | None):
    """Fused K-loop epilogue: bias add (broadcast over rows), then act."""
    if bias is not None:
        out = out + bias
    if activation == "gelu":
        out = jax.nn.gelu(out, approximate=True)
    elif activation == "silu":
        out = jax.nn.silu(out)
    return out


def matmul(a, b, bias=None, *, activation: str | None = None,
           block_m: int = 128, block_n: int = 128, block_k: int = 128,
           interpret: bool = False):
    """a: [M, K] @ b: [K, N] -> [M, N] (+fused bias [N] and activation).

    The bias rides the last K-step's epilogue (applied before the
    activation) instead of a separate post-GEMM elementwise kernel."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert bias is None or bias.shape == (N,)
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    operands = [a, b]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(jnp.pad(bias, (0, pn)).reshape(1, b.shape[1]))
    out = pl.pallas_call(
        functools.partial(_mm_kernel, activation=activation),
        grid=(a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:M, :N] if (pm or pn) else out
