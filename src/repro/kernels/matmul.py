"""Blocked matmul Pallas TPU kernel with optional fused activation.

Grid (M/bm, N/bn, K/bk), K fastest; fp32 accumulator persists in VMEM
across K steps (MXU-aligned 128 tiles).  The fused-GeLU variant is the
compute side of the paper's chunk-based overlapping: one chunk's GEMM+act
is a single kernel launch whose output feeds the grouped all-reduce while
the next chunk computes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(*refs, activation: str | None, has_scale: bool,
               has_bias: bool):
    a_ref, b_ref = refs[0], refs[1]
    i = 2
    scale_ref = refs[i] if has_scale else None
    i += has_scale
    bias_ref = refs[i] if has_bias else None
    i += has_bias
    o_ref, acc = refs[i], refs[i + 1]
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _fin():
        scale = None if scale_ref is None else scale_ref[0, 0]
        bias = None if bias_ref is None else bias_ref[...].astype(jnp.float32)
        out = _epilogue(acc[...], scale, bias, activation)
        o_ref[...] = out.astype(o_ref.dtype)


def _epilogue(out, scale, bias, activation: str | None):
    """Fused K-loop epilogue: dequant, bias add (broadcast over rows), act.

    Dequant comes FIRST — the f32 accumulator holds the integer-grid
    product, and bias/activation are defined on real-valued activations."""
    if scale is not None:
        out = out * scale
    if bias is not None:
        out = out + bias
    if activation == "gelu":
        out = jax.nn.gelu(out, approximate=True)
    elif activation == "silu":
        out = jax.nn.silu(out)
    return out


def matmul(a, b, bias=None, *, scale=None, activation: str | None = None,
           out_dtype=None, block_m: int = 128, block_n: int = 128,
           block_k: int = 128, interpret: bool = False):
    """a: [M, K] @ b: [K, N] -> [M, N] (+fused dequant/bias/activation).

    The bias rides the last K-step's epilogue (applied before the
    activation) instead of a separate post-GEMM elementwise kernel.

    ``scale`` enables the quantized path: a/b hold integer-grid values
    (int8, accumulated in fp32 by the same K loop) and ``scale`` is the
    combined dequant factor ``a_scale * b_scale`` applied in the epilogue
    BEFORE bias/activation — dequant rides the last K step exactly like
    the bias does.  Pass ``out_dtype`` when the inputs are int8 (the
    output must be a float dtype; defaults to a.dtype otherwise).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert bias is None or bias.shape == (N,)
    if out_dtype is None:
        out_dtype = jnp.bfloat16 if a.dtype == jnp.int8 else a.dtype
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    operands = [a, b]
    if scale is not None:
        # one (1,1) f32 scalar operand, broadcast to every grid cell
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)))
        operands.append(jnp.asarray(scale, jnp.float32).reshape(1, 1))
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(jnp.pad(bias, (0, pn)).reshape(1, b.shape[1]))
    out = pl.pallas_call(
        functools.partial(_mm_kernel, activation=activation,
                          has_scale=scale is not None,
                          has_bias=bias is not None),
        grid=(a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:M, :N] if (pm or pn) else out


def quantize_for_matmul(x, qmax: float = 127.0):
    """Tensor-wise symmetric int8 quantization for the quantized matmul.

    Returns (q int8 [M, K], scale f32 scalar) with ``q * scale ~= x``;
    feed two quantized operands and ``scale=a_scale * b_scale`` to
    :func:`matmul`."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)) / qmax, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale
