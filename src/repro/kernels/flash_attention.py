"""Flash attention Pallas TPU kernel (online softmax, causal/local/softcap).

Grid: (batch*heads, num_q_blocks, num_k_blocks) — the k axis iterates
fastest, so the fp32 accumulator / running-max / running-sum scratch in
VMEM persists across k steps of one (bh, q) tile.  Q/K/V tiles are staged
HBM->VMEM by BlockSpec; the probability matrix never touches HBM — that is
the entire point vs. the materialized jnp reference (see EXPERIMENTS.md
§Perf: the memory roofline term of naive attention).

Block sizes default to MXU-aligned 128.  TARGET is TPU; correctness is
validated in interpret mode on CPU against kernels/ref.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_k: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # [bq, d]
    k = k_ref[0].astype(jnp.float32)              # [bk, d]
    v = v_ref[0].astype(jnp.float32)              # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    m_scr[...] = m_cur
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q: [bh, sq, d]; k, v: [bh, sk, d] -> [bh, sq, d].

    GQA repetition / head-batch flattening is done by ops.py."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k
    scale = 1.0 / math.sqrt(d)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, seq_k=sk)

    out = pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
            pltpu.VMEM((block_q, d), jnp.float32),    # fp32 accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :sq]
    return out
