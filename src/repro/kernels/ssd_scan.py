"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid: (batch, heads, num_chunks) — chunk axis fastest; the running state
[hd, ds] persists in VMEM scratch across chunks of one (b, h) stream.
Per chunk: intra-chunk lower-triangular mix + cross-chunk read of the
carried state + state update — the [cl, cl] decay matrix and the state
never touch HBM (vs the jnp oracle, which materializes both per chunk).

Inputs are pre-arranged by ops.py into chunk-major layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref, state,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, 0, 0].astype(jnp.float32)         # [cl, hd]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)       # [cl]
    A = -jnp.exp(a_ref[0].astype(jnp.float32))     # scalar (this head)
    B = b_ref[0, 0].astype(jnp.float32)            # [cl, ds]
    C = c_ref[0, 0].astype(jnp.float32)            # [cl, ds]
    D = d_ref[0].astype(jnp.float32)               # scalar

    dA = dt * A                                    # [cl]
    la = jnp.cumsum(dA)                            # [cl]
    seg = la[:, None] - la[None, :]                # [cl, cl]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    decay = jnp.where(u_idx <= t_idx, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # [cl(t), cl(u)]
    w = cb * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))    # [cl, hd]

    # cross-chunk from carried state: y += exp(la)[:,None] * (C @ state^T)
    cross = jax.lax.dot_general(C, state[...], (((1,), (1,)), ((), ())))
    y += jnp.exp(la)[:, None] * cross

    o_ref[0, 0, 0] = (y + D * x).astype(o_ref.dtype)

    # state' = state * exp(la[-1]) + sum_u exp(la[-1]-la[u]) dt_u x_u B_u^T
    dec_end = jnp.exp(la[-1] - la) * dt            # [cl]
    upd = jax.lax.dot_general(x * dec_end[:, None], B,
                              (((0,), (0,)), ((), ())))        # [hd, ds]
    state[...] = state[...] * jnp.exp(la[-1]) + upd


def ssd_scan(x, dt, A_log, B, C, D, *, chunk: int = 64, interpret: bool = False):
    """x: [b, s, nh, hd]; dt: [b, s, nh]; B/C: [b, s, ds]; A_log/D: [nh].

    Returns y: [b, s, nh, hd] (state output handled by the jnp oracle in
    training; the kernel targets the long-sequence prefill hot spot)."""
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    assert s % chunk == 0, "seq must divide the chunk size"
    nc = s // chunk
    # chunk-major layouts
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, nh, hd), 3, 1)     # [b,nh,nc,cl,hd]
    dtc = jnp.moveaxis(dt.reshape(b, nc, chunk, nh), 3, 1)       # [b,nh,nc,cl]
    Bc = B.reshape(b, nc, chunk, ds)
    Cc = C.reshape(b, nc, chunk, ds)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, hd), lambda i, h, c: (i, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1,), lambda i, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, ds), lambda i, h, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, ds), lambda i, h, c: (i, c, 0, 0)),
            pl.BlockSpec((1,), lambda i, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, hd), lambda i, h, c: (i, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(xc.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, A_log, Bc, Cc, D)
    return jnp.moveaxis(out, 1, 3).reshape(b, s, nh, hd)
