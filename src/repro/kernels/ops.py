"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU so correctness tests run on CPU;
on a real TPU deployment set REPRO_KERNEL_INTERPRET=0 (or pass
interpret=False) to execute the compiled kernels.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=None):
    """q: [b, sq, hq, d]; k/v: [b, sk, hkv, d] (GQA-repeated here)."""
    interpret = _default_interpret() if interpret is None else interpret
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                            softcap=softcap, block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return o.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, gamma, *, eps=1e-6, block_rows=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    shape = x.shape
    out = _rn.rmsnorm(x.reshape(-1, shape[-1]), gamma, eps=eps,
                      block_rows=block_rows, interpret=interpret)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("activation", "block_m",
                                             "block_n", "block_k", "interpret"))
def matmul(a, b, bias=None, *, activation=None, block_m=128, block_n=128,
           block_k=128, interpret=None):
    """a @ b with optional fused bias [N] + activation epilogue."""
    interpret = _default_interpret() if interpret is None else interpret
    return _mm.matmul(a, b, bias, activation=activation, block_m=block_m,
                      block_n=block_n, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A_log, B, C, D, *, chunk=64, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, A_log, B, C, D, chunk=chunk,
                         interpret=interpret)
