"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: [bh, sq, d]; k/v: [bh, sk, d] — materialized-softmax reference."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, gamma, eps=1e-6):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * gamma).astype(x.dtype)


def matmul_ref(a, b, *, activation=None):
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    if activation == "gelu":
        out = jax.nn.gelu(out, approximate=True)
    elif activation == "silu":
        out = jax.nn.silu(out)
    return out.astype(a.dtype)


def ssd_ref(x, dt, A_log, B, C, D, state_in=None):
    """Sequential (step-by-step) SSD reference.

    x: [b, s, nh, hd]; dt: [b, s, nh]; B/C: [b, s, ds]; A_log/D: [nh].
    Returns (y, state_out [b, nh, hd, ds])."""
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))
    state = (jnp.zeros((b, nh, hd, ds), jnp.float32) if state_in is None
             else state_in.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(st, t):
        g = jnp.exp(dtf[:, t] * A)                       # [b, nh]
        upd = jnp.einsum("bhd,bs->bhds", xf[:, t] * dtf[:, t][..., None], Bf[:, t])
        st = st * g[:, :, None, None] + upd
        y = jnp.einsum("bhds,bs->bhd", st, Cf[:, t])
        return st, y

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1) + D[None, None, :, None] * xf
    return y.astype(x.dtype), state
