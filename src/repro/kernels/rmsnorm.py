"""Fused RMSNorm Pallas TPU kernel.

Grid over row blocks; one row block [block_rows, h] is staged into VMEM,
normalized in fp32, scaled by gamma and written back — one HBM round trip
instead of the separate square/mean/rsqrt/mul op chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * inv * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, gamma, *, eps: float = 1e-6, block_rows: int = 128,
            interpret: bool = False):
    """x: [rows, h]; gamma: [h]."""
    rows, h = x.shape
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(x.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, gamma)
    return out[:rows] if pad else out
