"""Lease/heartbeat membership for elastic recovery (simulated hosts).

PR 4's elastic loop had one omniscient observer: a ``devices_fn`` poll
that *is* the surviving pool.  Real fleets have no such oracle — each
host sees only its local devices and whatever its peers manage to tell
it, and the job must still agree on ONE surviving pool and ONE host that
runs the (expensive) re-search before any reshard commits.  This module
is that agreement layer, as a deterministic simulation:

  - every simulated host broadcasts a **heartbeat** every
    ``heartbeat_s`` carrying its current *proposed* surviving set and
    its latest *committed* view;
  - a peer silent for ``lease_s`` is **suspected** (dropped from the
    proposal); silent for ``dead_after_s`` it is **hard-expired**
    (dropped from the quorum denominator too — suspicion is fast,
    removal from the electorate is deliberately slow);
  - a host **commits** a new view only when (a) its proposal has been
    stable for ``quorum_views`` consecutive reviews (the *two-view
    quorum*: one glitched review can never reshard the job), and (b) a
    majority of the previous committed view's non-hard-expired members
    gossip the *same* proposal (so two healthy hosts that merely can't
    hear each other cannot both commit — one of them lacks the
    majority);
  - committed views are **epoch-numbered**; followers adopt any higher
    committed epoch they hear, and the **re-planner is the lowest rank
    of the committed view** — a pure function of the view, so the
    election needs no extra round-trips and "exactly one planner per
    epoch" reduces to "exactly one committed view per epoch".

Split-brain bound: with all links delayed below ``dead_after_s``, two
different views can never commit the same epoch (the majorities are
taken over the same electorate and would have to intersect in a host
proposing both sets at once).  A full partition longer than
``dead_after_s`` is indistinguishable from death on both sides — the
classic impossibility — and is exactly what the config knob trades
against recovery latency.

Everything is injectable for determinism: the clock (:class:`SimClock`),
the per-link delivery schedule (``delivery(src, dst, t) -> delay seconds
or None to drop``, the hook ``runtime.faults`` scripts), and the
host→device mapping.  :class:`MembershipRuntime` adapts a fabric to what
``launch.train.make_elastic_trainer`` consumes;
:class:`SingleObserverMembership` keeps the deprecated ``devices_fn``
path alive behind the same interface.
"""
from __future__ import annotations

import dataclasses
import heapq
import logging
import math
from typing import Callable, Sequence

log = logging.getLogger("repro.membership")


class SimClock:
    """Injectable simulated clock (seconds).  The fabric advances it;
    fault scripts and tests read/advance it too — one shared notion of
    'now' keeps failure injection and lease expiry deterministic."""

    def __init__(self, now: float = 0.0):
        self.now = float(now)

    def time(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self.now += dt
        return self.now


@dataclasses.dataclass(frozen=True)
class MembershipConfig:
    #: heartbeat (and proposal-review) cadence per healthy host
    heartbeat_s: float = 0.05
    #: silence after which a peer is suspected (leaves the proposal)
    lease_s: float = 0.2
    #: silence after which a peer leaves the quorum *denominator* — the
    #: slow threshold that lets a shrunken survivor set reach majority
    dead_after_s: float = 0.6
    #: consecutive identical proposal reviews required before commit
    quorum_views: int = 2

    def __post_init__(self):
        if not (0 < self.heartbeat_s <= self.lease_s <= self.dead_after_s):
            raise ValueError(
                f"need heartbeat_s <= lease_s <= dead_after_s, got "
                f"{self.heartbeat_s}/{self.lease_s}/{self.dead_after_s}")
        if self.quorum_views < 1:
            raise ValueError("quorum_views must be >= 1")


@dataclasses.dataclass(frozen=True)
class View:
    """An epoch-numbered committed membership view."""

    epoch: int
    alive: tuple[int, ...]

    @property
    def planner(self) -> int:
        """The deterministically elected re-planner: lowest surviving
        rank.  A pure function of the view — agreeing on the view IS
        the election."""
        if not self.alive:
            raise ValueError("empty view has no planner")
        return min(self.alive)


@dataclasses.dataclass(frozen=True)
class CommitRecord:
    """One originating commit (adoptions via gossip are not recorded):
    who committed what, with how much evidence."""

    t: float
    rank: int
    view: View
    acks: int
    electorate: tuple[int, ...]
    stable: int


class _Host:
    def __init__(self, rank: int, peers: Sequence[int], t0: float,
                 initial: View):
        self.rank = rank
        self.healthy = True
        # start with a full lease grace for every peer (a fresh cluster
        # must not instantly suspect everyone before the first beats land)
        self.last_heard = {p: t0 for p in peers if p != rank}
        self.peer_proposed: dict[int, tuple[int, ...]] = {}
        self.committed = initial
        self.proposed: tuple[int, ...] | None = None
        self.stable = 0


class MembershipFabric:
    """The simulated cluster: hosts + in-flight heartbeats + the clock.

    ``delivery(src, dst, t)`` returns the link delay in seconds for a
    heartbeat sent at ``t`` (None drops it); default is instantaneous.
    ``host_devices`` maps each rank to the accelerator slice it owns —
    ``surviving_devices`` of a committed view is the concatenation over
    its ranks, which is what the elastic trainer rebuilds its mesh from.
    """

    def __init__(self, n_hosts: int, cfg: MembershipConfig | None = None,
                 *, clock: SimClock | None = None,
                 delivery: Callable[[int, int, float], float | None]
                 | None = None,
                 host_devices: dict[int, Sequence] | None = None):
        if n_hosts < 1:
            raise ValueError("need at least one host")
        self.cfg = cfg or MembershipConfig()
        self.clock = clock or SimClock()
        self.delivery = delivery or (lambda src, dst, t: 0.0)
        self.host_devices = dict(host_devices or {})
        t0 = self.clock.time()
        ranks = tuple(range(n_hosts))
        initial = View(epoch=0, alive=ranks)
        self.hosts = {r: _Host(r, ranks, t0, initial) for r in ranks}
        self.commits: list[CommitRecord] = []
        self._msgs: list[tuple[float, int, int, dict]] = []  # heap
        self._seq = 0
        self._next_beat = {r: t0 for r in ranks}

    # -- fault hooks (runtime.faults drives these) -------------------------

    def fail_host(self, rank: int) -> None:
        """Local device failure: the host stops heartbeating and stops
        receiving — its peers only ever learn through lease expiry (no
        oracle announces the death)."""
        self.hosts[rank].healthy = False

    def revive_host(self, rank: int) -> None:
        h = self.hosts[rank]
        h.healthy = True
        now = self.clock.time()
        # fresh lease grace: a revived host must re-learn the cluster,
        # not instantly suspect everyone it missed while down
        h.last_heard = {p: now for p in self.hosts if p != rank}
        h.proposed, h.stable = None, 0
        self._next_beat[rank] = now

    def healthy_ranks(self) -> tuple[int, ...]:
        return tuple(sorted(r for r, h in self.hosts.items() if h.healthy))

    # -- the event loop ----------------------------------------------------

    def step(self, dt: float) -> None:
        """Advance simulated time by ``dt``, delivering heartbeats and
        running proposal reviews in deterministic event order."""
        self.run_until(self.clock.time() + dt)

    def run_until(self, t_end: float) -> None:
        while True:
            t_msg = self._msgs[0][0] if self._msgs else math.inf
            beats = [self._next_beat[r] for r in sorted(self.hosts)
                     if self.hosts[r].healthy]
            t_beat = min(beats) if beats else math.inf
            t_next = min(t_msg, t_beat)
            if t_next > t_end:
                break
            self.clock.now = max(self.clock.now, t_next)
            now = self.clock.time()
            while self._msgs and self._msgs[0][0] <= now:
                deliver_t, _, dst, hb = heapq.heappop(self._msgs)
                self._receive(dst, hb, deliver_t)
            for r in sorted(self.hosts):
                h = self.hosts[r]
                if h.healthy and self._next_beat[r] <= now:
                    self._broadcast(h, now)
                    self._review(h, now)
                    self._next_beat[r] = now + self.cfg.heartbeat_s
        self.clock.now = max(self.clock.now, t_end)

    def _broadcast(self, h: _Host, now: float) -> None:
        hb = {"src": h.rank,
              # before the first review the honest proposal is "nobody
              # suspected yet" — the committed view, not a self-singleton
              "proposed": h.proposed or h.committed.alive,
              "committed": h.committed}
        for dst in self.hosts:
            if dst == h.rank:
                continue
            delay = self.delivery(h.rank, dst, now)
            if delay is None:
                continue
            self._seq += 1
            heapq.heappush(self._msgs,
                           (now + max(0.0, delay), self._seq, dst, hb))

    def _receive(self, dst: int, hb: dict, t: float) -> None:
        h = self.hosts[dst]
        if not h.healthy:
            return  # a dead host's NIC hears nothing
        src = hb["src"]
        h.last_heard[src] = t
        h.peer_proposed[src] = hb["proposed"]
        other: View = hb["committed"]
        if other.epoch > h.committed.epoch:
            # follower catch-up: adopt the newer committed view (its
            # committer had the quorum evidence); restart local stability
            h.committed = other
            h.proposed, h.stable = None, 0

    def _review(self, h: _Host, now: float) -> None:
        cfg = self.cfg
        cand = tuple(sorted(
            {h.rank} | {p for p, t in h.last_heard.items()
                        if now - t <= cfg.lease_s}))
        if cand == h.proposed:
            h.stable += 1
        else:
            h.proposed, h.stable = cand, 1
        if cand == h.committed.alive or h.stable < cfg.quorum_views:
            return
        # the electorate: the previous committed view minus hard-expired
        # members (suspicion alone never shrinks the denominator — that
        # asymmetry is what blocks a transiently-deaf host from
        # committing a minority view with itself as the whole majority)
        electorate = tuple(sorted(
            r for r in h.committed.alive
            if r == h.rank or now - h.last_heard.get(r, now) <= cfg.dead_after_s))
        acks = sum(1 for r in electorate
                   if r == h.rank or h.peer_proposed.get(r) == cand)
        if acks < len(electorate) // 2 + 1:
            return
        view = View(epoch=h.committed.epoch + 1, alive=cand)
        h.committed = view
        self.commits.append(CommitRecord(
            t=now, rank=h.rank, view=view, acks=acks,
            electorate=electorate, stable=h.stable))
        log.info("host %d committed epoch %d view %s (%d/%d acks)",
                 h.rank, view.epoch, cand, acks, len(electorate))

    # -- convergence -------------------------------------------------------

    def converge(self, timeout_s: float = 60.0) -> View:
        """Drive the protocol until every healthy host's committed view
        equals the healthy set, and return it (the shared surviving-pool
        view the re-planner acts on).  Raises TimeoutError after
        ``timeout_s`` simulated seconds — an unreachable agreement (e.g.
        a majority died at once) must fail loudly, not spin."""
        target = self.healthy_ranks()
        if not target:
            raise TimeoutError("no healthy hosts left to converge")
        deadline = self.clock.time() + timeout_s
        while True:
            views = {self.hosts[r].committed for r in target}
            if len(views) == 1 and next(iter(views)).alive == target:
                return next(iter(views))
            if self.clock.time() >= deadline:
                raise TimeoutError(
                    f"membership did not converge on {target} within "
                    f"{timeout_s}s (views: "
                    f"{ {r: self.hosts[r].committed for r in target} })")
            self.run_until(min(deadline,
                               self.clock.time() + self.cfg.heartbeat_s))

    def surviving_devices(self, view: View | None = None) -> list:
        """The accelerator pool of a committed view (host order)."""
        view = view if view is not None else self.converge()
        out: list = []
        for r in view.alive:
            out.extend(self.host_devices.get(r, ()))
        return out

    def epochs(self) -> dict[int, set[tuple[int, ...]]]:
        """{epoch: set of committed alive-sets} — the split-brain probe
        (every value must be a singleton)."""
        out: dict[int, set[tuple[int, ...]]] = {}
        for c in self.commits:
            out.setdefault(c.view.epoch, set()).add(c.view.alive)
        return out


class MembershipRuntime:
    """What ``make_elastic_trainer`` consumes, answered by the protocol:
    *what is the agreed surviving pool, and is this host the elected
    re-planner?*  This process plays ``local_rank`` — a single-process
    stand-in for the planner host (the simulation cannot run a step it
    lost the driver of, so scenarios keep the local host alive)."""

    def __init__(self, fabric: MembershipFabric, local_rank: int = 0,
                 *, converge_timeout_s: float = 60.0):
        self.fabric = fabric
        self.local_rank = local_rank
        self.converge_timeout_s = converge_timeout_s

    def converged_view(self) -> View:
        return self.fabric.converge(self.converge_timeout_s)

    def devices(self, view: View | None = None) -> list:
        view = view if view is not None else self.converged_view()
        return self.fabric.surviving_devices(view)

    def is_planner(self, view: View | None = None) -> bool:
        view = view if view is not None else self.converged_view()
        return view.planner == self.local_rank


class SingleObserverMembership:
    """Deprecation shim for the PR-4 ``devices_fn`` poll: one omniscient
    observer, no leases, no quorum, no election — every answer is "the
    pool is whatever my poll says and I am the planner".  Kept so old
    callers keep working (behind a loud warning in ``make_elastic_
    trainer``); new code should drive a :class:`MembershipFabric`."""

    def __init__(self, devices_fn: Callable[[], Sequence]):
        self._devices_fn = devices_fn
        self._epoch = 0
        self._last_ids: tuple | None = None

    def converged_view(self) -> View:
        ids = tuple(sorted(getattr(d, "id", i)
                           for i, d in enumerate(self._devices_fn())))
        if self._last_ids is not None and ids != self._last_ids:
            self._epoch += 1  # the poll changed: call it a new epoch
        self._last_ids = ids
        return View(epoch=self._epoch, alive=(0,))

    def devices(self, view: View | None = None) -> list:
        return list(self._devices_fn())

    def is_planner(self, view: View | None = None) -> bool:
        return True


def fabric_over_devices(n_hosts: int, devices: Sequence,
                        cfg: MembershipConfig | None = None,
                        *, clock: SimClock | None = None,
                        delivery=None) -> MembershipFabric:
    """Partition an attached device pool evenly over ``n_hosts``
    simulated hosts (rank r owns the r-th contiguous slice) — the
    standard smoke/test wiring for a single-process multi-device run."""
    devices = list(devices)
    if n_hosts < 1 or len(devices) % n_hosts:
        raise ValueError(
            f"{len(devices)} devices do not split over {n_hosts} hosts")
    per = len(devices) // n_hosts
    return MembershipFabric(
        n_hosts, cfg, clock=clock, delivery=delivery,
        host_devices={r: devices[r * per:(r + 1) * per]
                      for r in range(n_hosts)})
