"""Batched inference server loop: continuous prefill + decode scheduling.

Single-host reference implementation of the serving pattern the dry-run
shapes exercise (prefill_32k / decode_32k): a request queue, a fixed
decode batch with slot recycling, greedy sampling.  Prefill currently
processes one request per admission at its natural length (padded to the
slot seq budget); decode advances all active slots one token per step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [s] int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServerConfig:
    batch_slots: int = 4
    max_seq: int = 128


class Server:
    """Drives (prefill_fn, decode_fn) over a request stream.

    prefill_fn(tokens [1, s]) -> (next_token [1], caches-delta for slot)
    decode_fn(tokens [B, 1], pos, caches) -> (next [B], caches)

    The cache plumbing is intentionally slot-batched: caches hold
    `batch_slots` sequences; prefill writes one slot, decode advances all.
    """

    def __init__(self, cfg: ServerConfig, prefill_fn: Callable,
                 decode_fn: Callable, init_caches: Callable[[], Any]):
        self.cfg = cfg
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.caches = init_caches()
        self.slots: list[Request | None] = [None] * cfg.batch_slots
        self.queue: list[Request] = []
        self.completed: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                req = self.queue.pop(0)
                first, self.caches = self.prefill_fn(req.prompt, i, self.caches)
                req.out.append(int(first))
                self.slots[i] = req

    def step(self):
        """One scheduler tick: admit then advance decode one token."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return False
        tokens = np.zeros((self.cfg.batch_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].out[-1]
        nxt, self.caches = self.decode_fn(tokens, self.caches)
        for i in active:
            req = self.slots[i]
            req.out.append(int(nxt[i]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.completed.append(req)
                self.slots[i] = None
        return True

    def run_until_drained(self, max_ticks: int = 1000):
        ticks = 0
        while (self.queue or any(self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
