"""Serving scheduler: paged continuous batching with chunked prefill.

:class:`Server` is the serving fast path — a real scheduler over the
block-paged KV caches (``models.paging`` / ``lm.init_paged_caches``):

  - **admission** pops queued requests into free slots and allocates
    pages for the *chunk-rounded natural* prompt length (never the
    padded slot budget — a 9-token prompt with chunk=8 pays 16 tokens of
    prefill compute, not ``max_seq``);
  - **chunked prefill** feeds each admitted prompt through a fixed-size
    compiled ``prefill chunk`` step (b=1), interleaved with decode ticks
    so long prompts cannot stall live streams (at most
    ``prefill_chunks_per_tick`` chunks between decode ticks);
  - **continuous decode** advances every decode-ready slot one token per
    tick with per-slot positions — slots carry independent lengths and
    recycle the moment a request finishes, returning their pages to the
    pool (no wave barriers);
  - **backpressure**: when the page pool cannot cover an admission or a
    decode append, the request waits (admission) while live slots keep
    decoding into their already-mapped pages.

Both compiled callables come from one ``launch.steps.build_paged_step``
function used at two shapes, so mixed prompt lengths never trigger a
per-length recompile.

The seed's wave-batched loop (one whole-prompt prefill per admission,
lockstep decode over dense ``s_max`` caches) lives on as the measured
baseline in ``launch.serve.serve`` / ``benchmarks/serve_bench.py``; this
scheduler replaces it as the serving fast path, fixing the seed
admission bug along the way (prompts are admitted at the chunk-rounded
natural length, never the padded slot budget).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.models.paging import GARBAGE_PAGE, PageAllocator, PagedConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [s] int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServerConfig:
    batch_slots: int = 4
    prefill_chunk: int = 8
    paged: PagedConfig = dataclasses.field(default_factory=PagedConfig)
    #: prefill chunks fed between consecutive decode ticks (keeps prompt
    #: ingestion from starving live decode streams)
    prefill_chunks_per_tick: int = 1


@dataclasses.dataclass
class _Slot:
    req: Request
    fed: int = 0          # prompt tokens already prefilled (chunk-rounded)
    length: int = 0       # valid cache length (excludes padded chunk tail)
    decoding: bool = False


class Server:
    """Drives one compiled paged step over a request stream.

    paged_step_fn(tokens [b, s], start [b], table [b, mp], caches)
        -> (greedy tokens [b, s], caches)

    called at two shapes: (1, prefill_chunk) while prefilling and
    (batch_slots, 1) for decode ticks.  The scheduler owns the page
    allocator; the compiled step sees positions/tables as runtime data.
    """

    def __init__(self, cfg: ServerConfig, paged_step_fn: Callable,
                 init_caches: Callable[[], Any]):
        self.cfg = cfg
        self.step_fn = paged_step_fn
        self.caches = init_caches()
        self.alloc = PageAllocator(cfg.paged, cfg.batch_slots)
        self.slots: list[_Slot | None] = [None] * cfg.batch_slots
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.ticks = 0

    # -- bookkeeping -------------------------------------------------------

    def submit(self, req: Request):
        # the slot's page table must cover BOTH the chunk-rounded prefill
        # (admission reserves/writes whole chunks incl. the padded tail)
        # and decode growth: each decode tick writes its input token's KV
        # at `length`, touching natural + (max_new - 1) positions
        need = max(self._chunk_rounded(len(req.prompt)),
                   len(req.prompt) + max(0, req.max_new - 1))
        if need > self.cfg.paged.max_seq:
            raise ValueError(
                f"request {req.rid}: {len(req.prompt)} prompt + "
                f"{req.max_new} new tokens need {need} positions, over "
                f"the page-table ceiling {self.cfg.paged.max_seq}")
        self.queue.append(req)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def cache_bytes(self) -> int:
        """Device bytes held by the page pools — value leaves plus, for
        quantized pools, the fp16 scale leaves (the honest total the
        quantization ratio is measured against)."""
        import jax

        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree.leaves(self.caches))

    def stats(self) -> dict:
        """Scheduler/pool counters for benches and operators."""
        return {"ticks": self.ticks,
                "live_tokens": sum(s.length for s in self.slots
                                   if s is not None),
                "free_pages": self.alloc.free_pages,
                "page_dtype": self.cfg.paged.page_dtype,
                "cache_bytes": self.cache_bytes()}

    def _chunk_rounded(self, n: int) -> int:
        c = self.cfg.prefill_chunk
        return -(-n // c) * c

    # -- scheduling --------------------------------------------------------

    def _admit(self):
        """Fill free slots from the queue — reserving pages for the
        chunk-rounded natural length only (the satellite fix: short
        prompts stop paying the padded slot budget)."""
        for i, s in enumerate(self.slots):
            if s is not None or not self.queue:
                continue
            req = self.queue[0]
            rounded = self._chunk_rounded(len(req.prompt))
            # reserve the prompt's pages up front so a half-prefilled
            # prompt can never deadlock the pool mid-flight
            if not self.alloc.ensure(i, rounded):
                break  # backpressure: keep decoding, retry next tick
            self.queue.pop(0)
            self.slots[i] = _Slot(req=req)

    def _prefill_some(self):
        """Feed up to ``prefill_chunks_per_tick`` chunks (FCFS over
        slots), each one a b=1 compiled step at the fixed chunk size."""
        fed = 0
        C = self.cfg.prefill_chunk
        for i, s in enumerate(self.slots):
            if fed >= self.cfg.prefill_chunks_per_tick:
                break
            if s is None or s.decoding:
                continue
            prompt = s.req.prompt
            while s.fed < len(prompt) and fed < self.cfg.prefill_chunks_per_tick:
                chunk = np.zeros((1, C), np.int32)
                n_valid = min(C, len(prompt) - s.fed)
                chunk[0, :n_valid] = prompt[s.fed: s.fed + n_valid]
                table = self.alloc.table()[i: i + 1]
                start = np.array([s.fed], np.int32)
                toks, self.caches = self.step_fn(chunk, start, table,
                                                 self.caches)
                s.fed += C  # padded tail included; masked by `length`
                s.length = min(s.fed, len(prompt))
                fed += 1
                if s.length == len(prompt):
                    # first generated token = greedy pick at the last
                    # VALID position of this (possibly padded) chunk
                    first = int(np.asarray(toks)[0, n_valid - 1])
                    s.req.out.append(first)
                    if len(s.req.out) >= s.req.max_new:
                        # max_new=1: done at prefill — no decode tick
                        s.req.done = True
                        self.completed.append(s.req)
                        self.alloc.release(i)
                        self.slots[i] = None
                    else:
                        s.decoding = True
                    break

    def _decode_tick(self) -> bool:
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and s.decoding]
        if not active:
            return False
        B = self.cfg.batch_slots
        tokens = np.zeros((B, 1), np.int32)
        start = np.zeros((B,), np.int32)
        writing = []
        for i in active:
            s = self.slots[i]
            # the appended token needs its page mapped; reserved prompt
            # pages usually cover it, growth is page-at-a-time
            if not self.alloc.ensure(i, s.length + 1):
                continue  # pool exhausted: this slot skips a beat
            tokens[i, 0] = s.req.out[-1]
            start[i] = s.length
            writing.append(i)
        if not writing:
            return True  # every live stream is back-pressured this tick
        # slots NOT advancing this tick (free, mid-prefill, back-pressured)
        # must not see their mapped pages: the batched scatter would land
        # their dummy token at position `start` of a live sequence.  Route
        # their rows to the garbage page instead.
        table = self.alloc.table()
        mask = np.ones((B,), bool)
        mask[writing] = False
        table[mask] = GARBAGE_PAGE
        nxt, self.caches = self.step_fn(tokens, start, table, self.caches)
        nxt = np.asarray(nxt)[:, 0]
        for i in writing:
            s = self.slots[i]
            s.length += 1
            s.req.out.append(int(nxt[i]))
            if len(s.req.out) >= s.req.max_new:
                s.req.done = True
                self.completed.append(s.req)
                self.alloc.release(i)   # pages return to the pool
                self.slots[i] = None
        return True

    def step(self):
        """One scheduler tick: admit, feed prefill chunks, decode tick."""
        self._admit()
        self._prefill_some()
        decoded = self._decode_tick()
        self.ticks += 1
        return decoded or any(s is not None for s in self.slots)

    def run_until_drained(self, max_ticks: int = 10000) -> int:
        t0 = self.ticks
        while self.busy and self.ticks - t0 < max_ticks:
            self.step()
        return self.ticks - t0
