"""Serving scheduler: paged continuous batching with chunked prefill.

:class:`Server` is the serving fast path — a real scheduler over the
block-paged KV caches (``models.paging`` / ``lm.init_paged_caches``):

  - **admission** pops queued requests into free slots and allocates
    pages for the *chunk-rounded natural* prompt length (never the
    padded slot budget — a 9-token prompt with chunk=8 pays 16 tokens of
    prefill compute, not ``max_seq``);
  - **chunked prefill** feeds each admitted prompt through a fixed-size
    compiled ``prefill chunk`` step (b=1), interleaved with decode ticks
    so long prompts cannot stall live streams (at most
    ``prefill_chunks_per_tick`` chunks between decode ticks);
  - **continuous decode** advances every decode-ready slot one token per
    tick with per-slot positions — slots carry independent lengths and
    recycle the moment a request finishes, returning their pages to the
    pool (no wave barriers);
  - **backpressure**: when the page pool cannot cover an admission or a
    decode append, the request waits (admission) while live slots keep
    decoding into their already-mapped pages.

Three opt-in throughput modes compound on that base:

  - ``prefix_cache`` — copy-on-write prefix sharing: admission matches a
    new prompt's longest page-aligned prefix against the allocator's
    radix index, adopts those pages read-only and skips their prefill
    chunks; completed prompts register their full pages for future hits.
    Shared pages free only at refcount zero (``PageAllocator``).
  - ``recurrent`` — mamba/zamba/xlstm residency: the compiled step takes
    a per-row slot-id array addressing per-slot state pools, and prompt
    *tails* feed one token at a time through the decode-shaped step
    (a padded chunk tail would corrupt recurrent state — conv shifts and
    SSD decay apply to every fed position, valid or not).
  - ``speculate`` — MTP self-speculative decode: each tick feeds
    [previous, draft] (s=2); the trunk's pick at position 0 verifies the
    draft.  Accept keeps both tokens (the draft's KV is already
    written and already correct); reject keeps only the verified token —
    the stale draft KV at ``length+1`` is overwritten by the next tick's
    append before any gather can read it, so rollback is just "don't
    advance the length pointer".  Exact greedy parity by construction.

Both compiled callables come from one ``launch.steps.build_paged_step``
function used at two shapes, so mixed prompt lengths never trigger a
per-length recompile.

The seed's wave-batched loop (one whole-prompt prefill per admission,
lockstep decode over dense ``s_max`` caches) lives on as the measured
baseline in ``launch.serve.serve`` / ``benchmarks/serve_bench.py``; this
scheduler replaces it as the serving fast path, fixing the seed
admission bug along the way (prompts are admitted at the chunk-rounded
natural length, never the padded slot budget).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.models.paging import GARBAGE_PAGE, PageAllocator, PagedConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [s] int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    #: scheduler-tick budget from submit; None = no deadline.  A request
    #: still incomplete when the budget elapses is expired at the next
    #: tick: its pages/state return to the pool immediately and it lands
    #: in ``Server.expired`` (graceful degradation — under pressure the
    #: pool drains instead of wedging on doomed work)
    deadline_ticks: int | None = None
    #: set when the deadline fired (partial ``out`` is kept as-is)
    expired: bool = False


@dataclasses.dataclass
class ServerConfig:
    batch_slots: int = 4
    prefill_chunk: int = 8
    paged: PagedConfig = dataclasses.field(default_factory=PagedConfig)
    #: prefill chunks fed between consecutive decode ticks (keeps prompt
    #: ingestion from starving live decode streams)
    prefill_chunks_per_tick: int = 1
    #: copy-on-write prefix sharing across requests (radix index over
    #: page contents; see models.paging)
    prefix_cache: bool = False
    #: MTP self-speculative decode — the compiled step must return
    #: (tokens, drafts, caches) (build_paged_step(speculate=True))
    speculate: bool = False
    #: recurrent state pools (mamba/zamba/xlstm) — the compiled step
    #: takes a per-row slot-id array (build_paged_step(slots=...))
    recurrent: bool = False
    #: admission retry-with-backoff: after a back-pressured admission the
    #: scheduler waits ``base * 2**(consecutive_failures - 1)`` ticks
    #: (capped at ``max``) before retrying, so a saturated pool is not
    #: hammered with doomed ensure() calls every tick while live slots
    #: drain.  base=1, max=1 recovers the pre-backoff retry-every-tick
    #: behavior.
    admission_backoff_base: int = 1
    admission_backoff_max: int = 8
    #: pressure-triggered prefix-cache eviction: when the pool's free
    #: pages dip below this mark, index-only pages are evicted
    #: (leaf-first, refcount-safe) back up to it BEFORE allocation
    #: failures force reactive eviction.  0 disables (default).
    eviction_low_water: int = 0


@dataclasses.dataclass
class _Slot:
    req: Request
    fed: int = 0          # prompt tokens already prefilled (chunk-rounded)
    length: int = 0       # valid cache length (excludes padded chunk tail)
    decoding: bool = False
    draft: int | None = None   # speculative: MTP draft awaiting verify


class Server:
    """Drives one compiled paged step over a request stream.

    paged_step_fn(tokens [b, s], start [b], table [b, mp], caches)
        -> (greedy tokens [b, s], caches)

    (recurrent mode inserts a ``slot [b]`` arg before caches; speculate
    mode returns (tokens, drafts, caches))

    called at two shapes: (1, prefill_chunk) while prefilling and
    (batch_slots, 1 or 2) for decode ticks.  The scheduler owns the page
    allocator; the compiled step sees positions/tables as runtime data.
    """

    def __init__(self, cfg: ServerConfig, paged_step_fn: Callable,
                 init_caches: Callable[[], Any]):
        if cfg.speculate and cfg.recurrent:
            raise ValueError(
                "speculate + recurrent: draft rollback needs a KV length "
                "pointer; recurrent state has no position axis")
        if cfg.prefix_cache and cfg.recurrent:
            raise ValueError(
                "prefix_cache + recurrent: prefix sharing reuses cached "
                "KV pages; recurrent state is not page-addressable")
        self.cfg = cfg
        self.step_fn = paged_step_fn
        self.caches = init_caches()
        self.alloc = PageAllocator(cfg.paged, cfg.batch_slots,
                                   prefix_cache=cfg.prefix_cache)
        self.slots: list[_Slot | None] = [None] * cfg.batch_slots
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.expired: list[Request] = []
        self.ticks = 0
        self._prompt_tokens = 0
        self._prefix_hit_tokens = 0
        self._spec_drafts = 0
        self._spec_accepted = 0
        #: rid -> absolute expiry tick (set at submit from deadline_ticks)
        self._deadline: dict[int, int] = {}
        self._admit_fails = 0
        self._next_admit_tick = 0
        self._admission_retries = 0
        self._evicted_pages = 0
        self._reshapes = 0

    # -- bookkeeping -------------------------------------------------------

    def submit(self, req: Request):
        # the slot's page table must cover BOTH the chunk-rounded prefill
        # (admission reserves/writes whole chunks incl. the padded tail)
        # and decode growth: each decode tick writes its input token's KV
        # at `length`, touching natural + (max_new - 1) positions — one
        # more under speculation (the last tick's draft KV at length+1)
        grow = req.max_new if self.cfg.speculate else max(0, req.max_new - 1)
        need = max(self._chunk_rounded(len(req.prompt)),
                   len(req.prompt) + grow)
        if need > self.cfg.paged.max_seq:
            raise ValueError(
                f"request {req.rid}: {len(req.prompt)} prompt + "
                f"{req.max_new} new tokens need {need} positions, over "
                f"the page-table ceiling {self.cfg.paged.max_seq}")
        if req.deadline_ticks is not None:
            self._deadline[req.rid] = self.ticks + req.deadline_ticks
        self.queue.append(req)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def cache_bytes(self) -> int:
        """Device bytes held by the page pools — value leaves plus, for
        quantized pools, the fp16 scale leaves (the honest total the
        quantization ratio is measured against)."""
        import jax

        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree.leaves(self.caches))

    def used_cache_bytes(self) -> int:
        """Device bytes actually *referenced*: every distinct held page
        (slot-mapped or prefix-index-pinned) billed exactly once — a page
        shared by three slots under copy-on-write costs one page, not
        three — plus all non-pool leaves (recurrent state pools) in full.
        Pool leaves are recognized by their (count, num_pages, page_size,
        ...) geometry; scale pools ride along automatically."""
        import jax

        pcfg = self.cfg.paged
        pool_bytes = 0
        total = 0
        for x in jax.tree.leaves(self.caches):
            nbytes = int(np.prod(x.shape)) * x.dtype.itemsize
            total += nbytes
            if (getattr(x, "ndim", 0) >= 3 and x.shape[1] == pcfg.num_pages
                    and x.shape[2] == pcfg.page_size):
                pool_bytes += nbytes
        per_page = pool_bytes // max(1, pcfg.num_pages)
        return self.alloc.held_pages * per_page + (total - pool_bytes)

    def stats(self) -> dict:
        """Scheduler/pool counters for benches and operators."""
        hit = (self._prefix_hit_tokens / self._prompt_tokens
               if self._prompt_tokens else 0.0)
        acc = (self._spec_accepted / self._spec_drafts
               if self._spec_drafts else 0.0)
        return {"ticks": self.ticks,
                "live_tokens": sum(s.length for s in self.slots
                                   if s is not None),
                "free_pages": self.alloc.free_pages,
                "page_dtype": self.cfg.paged.page_dtype,
                "cache_bytes": self.cache_bytes(),
                "used_cache_bytes": self.used_cache_bytes(),
                "pages_shared": self.alloc.pages_shared,
                "prefix_hit_rate": hit,
                "spec_drafts": self._spec_drafts,
                "spec_accepted": self._spec_accepted,
                "spec_accept_rate": acc,
                "expired": len(self.expired),
                "admission_retries": self._admission_retries,
                "evicted_pages": self._evicted_pages,
                "reshapes": self._reshapes}

    def _chunk_rounded(self, n: int) -> int:
        c = self.cfg.prefill_chunk
        return -(-n // c) * c

    # -- compiled-step dispatch -------------------------------------------

    def _run(self, tokens, start, table, slot=None):
        """Call the compiled step with the mode-appropriate signature.
        Returns (tokens, drafts-or-None); caches update in place."""
        if self.cfg.recurrent:
            if slot is None:
                slot = np.full((tokens.shape[0],), self.cfg.batch_slots,
                               np.int32)
            out = self.step_fn(tokens, start, table, slot, self.caches)
        else:
            out = self.step_fn(tokens, start, table, self.caches)
        if self.cfg.speculate:
            toks, drafts, self.caches = out
            return toks, drafts
        toks, self.caches = out
        return toks, None

    # -- graceful degradation ---------------------------------------------

    def _expire_one(self, req: Request):
        req.expired = True
        self._deadline.pop(req.rid, None)
        self.expired.append(req)

    def _expire(self):
        """Deadline enforcement (ladder rung 3): every request whose tick
        budget has elapsed is dropped NOW — queued requests simply leave
        the queue; live slots release their pages/state back to the pool
        in the same tick, so expiry is also how a saturated pool drains.
        The partial ``out`` stays on the request (a client may still use
        a truncated stream)."""
        if not self._deadline:
            return

        def over(r):
            return self._deadline.get(r.rid, self.ticks + 1) <= self.ticks

        doomed = [r for r in self.queue if over(r)]
        self.queue = [r for r in self.queue if not over(r)]
        for r in doomed:
            self._expire_one(r)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            if self._deadline.get(s.req.rid, self.ticks + 1) <= self.ticks:
                self.alloc.release(i)
                self.slots[i] = None
                self._expire_one(s.req)

    def _evict_pressure(self):
        """Low-water prefix-cache eviction (ladder rung 2): shed
        index-only pages before the pool runs dry, instead of waiting for
        an allocation failure to force it."""
        lw = self.cfg.eviction_low_water
        if lw and self.cfg.prefix_cache and self.alloc.free_pages < lw:
            self._evicted_pages += self.alloc.evict_pinned(
                lw - self.alloc.free_pages)

    # -- scheduling --------------------------------------------------------

    def _admit(self):
        """Fill free slots from the queue — reserving pages for the
        chunk-rounded natural length only (the satellite fix: short
        prompts stop paying the padded slot budget).  With the prefix
        cache on, the longest page-aligned cached prefix is adopted
        read-only and its prefill is skipped entirely; the match is
        capped below the last prompt position because the first output
        token needs that position's logits from a real prefill step.

        Back-pressured admissions retry with exponential backoff (ladder
        rung 1): each consecutive failure doubles the wait before the
        next attempt (``admission_backoff_base``..``_max`` ticks), and
        any successful admission resets the clock."""
        if self.ticks < self._next_admit_tick:
            return
        for i, s in enumerate(self.slots):
            if s is not None or not self.queue:
                continue
            req = self.queue[0]
            prompt = req.prompt
            rounded = self._chunk_rounded(len(prompt))
            matched = ()
            if self.cfg.prefix_cache:
                ps = self.cfg.paged.page_size
                matched = self.alloc.match_prefix(prompt)
                matched = matched[:(len(prompt) - 1) // ps]
                if matched:
                    self.alloc.adopt(i, matched)
            # reserve the prompt's pages up front so a half-prefilled
            # prompt can never deadlock the pool mid-flight
            if not self.alloc.ensure(i, rounded):
                if matched:
                    self.alloc.release(i)   # roll the adoption back
                self._admit_fails += 1
                self._admission_retries += 1
                self._next_admit_tick = self.ticks + min(
                    self.cfg.admission_backoff_max,
                    self.cfg.admission_backoff_base
                    * 2 ** (self._admit_fails - 1))
                break  # backpressure: keep decoding, retry after backoff
            self.queue.pop(0)
            self._admit_fails = 0
            skip = len(matched) * self.cfg.paged.page_size
            self.slots[i] = _Slot(req=req, fed=skip, length=skip)
            self._prompt_tokens += len(prompt)
            self._prefix_hit_tokens += skip

    def _finish_prefill(self, i: int, s: _Slot, first: int):
        """Prompt fully fed: record the first output token, index the
        prompt's full pages for prefix reuse, flip to decode (or complete
        outright for max_new=1)."""
        s.req.out.append(first)
        if self.cfg.prefix_cache:
            self.alloc.register_prefix(i, s.req.prompt)
        if len(s.req.out) >= s.req.max_new:
            # max_new=1: done at prefill — no decode tick
            s.req.done = True
            self.completed.append(s.req)
            self.alloc.release(i)
            self.slots[i] = None
        else:
            s.decoding = True

    def _prefill_some(self):
        """Feed up to ``prefill_chunks_per_tick`` chunks (FCFS over
        slots), each one a b=1 compiled step at the fixed chunk size.
        Recurrent mode feeds whole chunks only while a full chunk of
        prompt remains, then the tail one token at a time through the
        decode-shaped step (each tail token charges one chunk of budget):
        exact state, no padded positions."""
        fed = 0
        C = self.cfg.prefill_chunk
        budget = self.cfg.prefill_chunks_per_tick
        for i, s in enumerate(self.slots):
            if fed >= budget:
                break
            if s is None or s.decoding:
                continue
            prompt = s.req.prompt
            while s.fed < len(prompt) and fed < budget:
                rem = len(prompt) - s.fed
                if self.cfg.recurrent and rem < C:
                    B = self.cfg.batch_slots
                    tokens = np.zeros((B, 1), np.int32)
                    tokens[i, 0] = prompt[s.fed]
                    start = np.zeros((B,), np.int32)
                    start[i] = s.fed
                    table = self.alloc.table()
                    mask = np.ones((B,), bool)
                    mask[i] = False
                    table[mask] = GARBAGE_PAGE
                    slot = np.full((B,), B, np.int32)  # sentinel: drop
                    slot[i] = i
                    toks, _ = self._run(tokens, start, table, slot)
                    s.fed += 1
                    s.length = s.fed
                    fed += 1
                    if s.length == len(prompt):
                        self._finish_prefill(i, s,
                                             int(np.asarray(toks)[i, 0]))
                        break
                    continue
                chunk = np.zeros((1, C), np.int32)
                n_valid = min(C, rem)
                chunk[0, :n_valid] = prompt[s.fed: s.fed + n_valid]
                table = self.alloc.table()[i: i + 1]
                start = np.array([s.fed], np.int32)
                slot = np.array([i], np.int32)
                toks, drafts = self._run(chunk, start, table, slot)
                s.fed += C  # padded tail included; masked by `length`
                s.length = min(s.fed, len(prompt))
                fed += 1
                if s.length == len(prompt):
                    # first generated token = greedy pick at the last
                    # VALID position of this (possibly padded) chunk
                    if drafts is not None:
                        # the chunk's free MTP draft: the token predicted
                        # to FOLLOW the first output token
                        s.draft = int(np.asarray(drafts)[0, n_valid - 1])
                    self._finish_prefill(
                        i, s, int(np.asarray(toks)[0, n_valid - 1]))
                    break

    def _decode_tick(self) -> bool:
        if self.cfg.speculate:
            return self._decode_tick_spec()
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and s.decoding]
        if not active:
            return False
        B = self.cfg.batch_slots
        tokens = np.zeros((B, 1), np.int32)
        start = np.zeros((B,), np.int32)
        writing = []
        for i in active:
            s = self.slots[i]
            # the appended token needs its page mapped; reserved prompt
            # pages usually cover it, growth is page-at-a-time
            if not self.alloc.ensure(i, s.length + 1):
                continue  # pool exhausted: this slot skips a beat
            tokens[i, 0] = s.req.out[-1]
            start[i] = s.length
            writing.append(i)
        if not writing:
            return True  # every live stream is back-pressured this tick
        # slots NOT advancing this tick (free, mid-prefill, back-pressured)
        # must not see their mapped pages: the batched scatter would land
        # their dummy token at position `start` of a live sequence.  Route
        # their rows to the garbage page instead.
        table = self.alloc.table()
        mask = np.ones((B,), bool)
        mask[writing] = False
        table[mask] = GARBAGE_PAGE
        slot = np.full((B,), B, np.int32)   # sentinel: state writes drop
        slot[writing] = writing
        nxt, _ = self._run(tokens, start, table, slot)
        nxt = np.asarray(nxt)[:, 0]
        for i in writing:
            s = self.slots[i]
            s.length += 1
            s.req.out.append(int(nxt[i]))
            if len(s.req.out) >= s.req.max_new:
                s.req.done = True
                self.completed.append(s.req)
                self.alloc.release(i)   # pages return to the pool
                self.slots[i] = None
        return True

    def _decode_tick_spec(self) -> bool:
        """Speculative decode tick at (B, 2): feed [prev, draft] per
        writing slot.  The trunk pick at position 0 is the TRUE next
        token (always kept); it also verifies the draft — on a match the
        pick at position 1 is the token after it (two tokens this tick,
        and the draft's KV written at length+1 is already correct).  On a
        mismatch the length pointer simply doesn't cover the stale draft
        KV, and the next tick's append overwrites it before any gather.
        The first tick after prefill without an MTP draft feeds prev as
        a dummy draft (an accidental match is still a correct accept);
        only real MTP drafts count toward the acceptance-rate stats."""
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and s.decoding]
        if not active:
            return False
        B = self.cfg.batch_slots
        tokens = np.zeros((B, 2), np.int32)
        start = np.zeros((B,), np.int32)
        writing = []
        had_draft = {}
        for i in active:
            s = self.slots[i]
            # this tick writes KV at length (prev) AND length+1 (draft)
            if not self.alloc.ensure(i, s.length + 2):
                continue
            had_draft[i] = s.draft is not None
            tokens[i, 0] = s.req.out[-1]
            tokens[i, 1] = s.draft if s.draft is not None else s.req.out[-1]
            start[i] = s.length
            writing.append(i)
        if not writing:
            return True
        table = self.alloc.table()
        mask = np.ones((B,), bool)
        mask[writing] = False
        table[mask] = GARBAGE_PAGE
        toks, drafts = self._run(tokens, start, table)
        toks = np.asarray(toks)
        drafts = np.asarray(drafts)
        for i in writing:
            s = self.slots[i]
            fed_draft = int(tokens[i, 1])
            t1 = int(toks[i, 0])
            s.length += 1
            s.req.out.append(t1)
            accept = fed_draft == t1 and len(s.req.out) < s.req.max_new
            if had_draft[i]:
                self._spec_drafts += 1
                self._spec_accepted += int(accept)
            if accept:
                s.length += 1
                s.req.out.append(int(toks[i, 1]))
                s.draft = int(drafts[i, 1])
            else:
                s.draft = int(drafts[i, 0])
            if len(s.req.out) >= s.req.max_new:
                s.req.done = True
                self.completed.append(s.req)
                self.alloc.release(i)
                self.slots[i] = None
        return True

    def step(self):
        """One scheduler tick: expire, evict, admit, feed prefill chunks,
        decode tick.  The first two are the degradation ladder's passive
        rungs — under pressure they run every tick so the pool can only
        drain, never wedge."""
        self._expire()
        self._evict_pressure()
        self._admit()
        self._prefill_some()
        decoded = self._decode_tick()
        self.ticks += 1
        return decoded or any(s is not None for s in self.slots)

    # -- elastic remesh ----------------------------------------------------

    def reshape(self, paged_step_fn: Callable,
                init_caches: Callable[[], Any]):
        """Drain-and-remesh (ladder rung 4): swap in a step compiled for
        a different decode mesh and replay in-flight work on it.

        The old mesh's caches are unreadable after a shrink (their pages
        lived on devices that may be gone), so every live slot's progress
        is converted back into *prompt* form: the request's feed sequence
        becomes ``original prompt + tokens emitted so far`` (``prompt``
        is extended in place; ``out`` keeps the already-delivered
        tokens), and the request re-queues for ordinary admission +
        chunked prefill on the survivors.  Greedy decode makes this
        exact: re-prefilling prompt+out reproduces bit-identical KV for
        those positions, and the argmax at the last valid position IS the
        next token of the uninterrupted stream — token parity for every
        replayed request, with no checkpoint of cache state.

        Speculative drafts are dropped (never delivered, cheap to
        re-derive); the prefix-cache radix index resets with the
        allocator (its pages died with the old pool).  A continuation
        whose chunk-rounded feed no longer fits the page table
        (``prompt+out`` rounds past ``max_seq``) cannot be replayed and
        is expired instead — the same contract as a deadline.
        """
        live = [s for s in self.slots if s is not None]
        self.step_fn = paged_step_fn
        self.caches = init_caches()
        self.alloc = PageAllocator(self.cfg.paged, self.cfg.batch_slots,
                                   prefix_cache=self.cfg.prefix_cache)
        self.slots = [None] * self.cfg.batch_slots
        self._admit_fails = 0
        self._next_admit_tick = 0
        self._reshapes += 1
        requeue = []
        for s in live:
            req = s.req
            if req.out:
                req.prompt = np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(req.out, np.int32)])
            remaining = req.max_new - len(req.out)
            grow = remaining if self.cfg.speculate else max(0, remaining - 1)
            need = max(self._chunk_rounded(len(req.prompt)),
                       len(req.prompt) + grow)
            if need > self.cfg.paged.max_seq:
                self._expire_one(req)
                continue
            requeue.append(req)
        self.queue = requeue + self.queue

    def run_until_drained(self, max_ticks: int = 10000) -> int:
        t0 = self.ticks
        while self.busy and self.ticks - t0 < max_ticks:
            self.step()
        return self.ticks - t0
