"""Fault-tolerant training loop: checkpoint/restart, failure recovery,
straggler watchdog, deterministic data replay.

Hardware failures on a real pod surface as raised exceptions from the jit'd
step (XLA device errors).  The loop's contract:

  - every step is a pure function of (params, opt_state, batch(step))
  - batches are pure functions of (seed, step)   -> replay is exact
  - on failure: restore last committed checkpoint, rebuild the step
    (possibly on a new mesh — elastic), continue from ckpt step
  - stragglers: per-step wall time is tracked with an EMA; a step slower
    than `straggler_factor` x EMA fires the mitigation hook (on a real
    cluster: re-shard away from the slow host / preemptively checkpoint)
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import TokenSource

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_ckpts: int = 3
    max_failures: int = 3
    straggler_factor: float = 3.0
    ema_beta: float = 0.9
    log_every: int = 10


@dataclasses.dataclass
class StragglerWatchdog:
    factor: float = 3.0
    beta: float = 0.9
    ema: float | None = None
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        if self.ema is None:
            self.ema = dt
            return False
        flagged = dt > self.factor * self.ema and self.ema > 0
        if flagged:
            self.events.append((step, dt, self.ema))
        else:
            # only fold non-outlier steps into the EMA
            self.ema = self.beta * self.ema + (1 - self.beta) * dt
        return flagged

    def reset(self):
        """Forget the EMA (keep the event log).

        Must be called when the per-step cost legitimately changes — e.g.
        an elastic re-plan onto a smaller/slower surviving mesh — or every
        first step on the new mesh is falsely flagged against the old
        mesh's EMA (and, flagged or not, the old EMA skews forever).
        """
        self.ema = None


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        build_step: Callable[[], Callable],  # returns jitted train_step
        source: TokenSource,
        init_state: Callable[[], tuple[Any, Any]],  # -> (params, opt_state)
        put_batch: Callable[[dict], Any],    # host batch -> device arrays
        mitigation_hook: Callable[[int], None] | None = None,
        time_fn: Callable[[], float] = time.monotonic,
        replan: Callable[[], Any] | None = None,
        restore_shardings: Callable[[], Any] | None = None,
        encode_ckpt: Callable[[Any, Any], Any] | None = None,
        decode_ckpt: Callable[[Any], tuple[Any, Any]] | None = None,
        ckpt_template: Callable[[], Any] | None = None,
    ):
        self.cfg = cfg
        self.build_step = build_step
        self.source = source
        self.init_state = init_state
        self.put_batch = put_batch
        self.watchdog = StragglerWatchdog(cfg.straggler_factor, cfg.ema_beta)
        self.mitigation_hook = mitigation_hook or (lambda step: None)
        self.time_fn = time_fn
        # elastic recovery: re-derive the ParallelPlan on the surviving mesh
        # and return a fresh step built from it (launch.train wires
        # plan.replan_elastic here); None keeps the rebuild-same-plan path.
        # The hook may return either a step, or (step, restore_shardings):
        # the sharding tree places the restored checkpoint directly onto
        # the re-planned mesh instead of replicated on the default device.
        self.replan = replan
        # current-plan sharding provider for every checkpoint restore
        # (resume-at-start included) — a zero-arg callable returning the
        # sharding tree of the CHECKPOINTED (encoded) state, or None for
        # host placement.
        self.restore_shardings = restore_shardings
        # state <-> checkpoint-tree codec.  encode maps (params, opt) to
        # the tree written to disk; decode inverts it after restore.
        # launch.train uses these to checkpoint the zero1 optimizer state
        # in its plan-independent param-shaped layout, so a restart can
        # re-bank it onto ANY surviving (d1, d2, dp) — without a codec the
        # raw (plan-dependent) state is written as-is.
        self.encode_ckpt = encode_ckpt or (lambda params, opt: (params, opt))
        self.decode_ckpt = decode_ckpt or (lambda tree: tree)
        # optional abstract (shape/dtype-only) view of the encoded tree:
        # restore only reads shapes and dtypes from its template, so this
        # avoids materializing (and device-placing) throwaway state on
        # every restore.  Fallback: encode a real init_state().
        self.ckpt_template = ckpt_template
        self.failures = 0        # consecutive: decays once recovery sticks
        self.total_failures = 0  # lifetime count (reporting only)
        self.replans: list[int] = []  # steps at which a re-plan happened
        self.history: list[dict] = []
        self._recovering = False

    def _restore_or_init(self, shardings=None):
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            params, opt_state = self.init_state()
            return params, opt_state, 0
        template = (self.ckpt_template() if self.ckpt_template is not None
                    else self.encode_ckpt(*self.init_state()))
        if shardings is None and self.restore_shardings is not None:
            shardings = self.restore_shardings()
        tree, meta = ckpt.restore(self.cfg.ckpt_dir, template,
                                  shardings=shardings)
        params, opt_state = self.decode_ckpt(tree)
        log.info("restored checkpoint at step %d%s", meta["step"],
                 " (resharded)" if shardings is not None else "")
        return params, opt_state, meta["step"]

    def _checkpoint(self, step: int, params, opt_state):
        """Save + prune under the SAME consecutive-failure budget as the
        train step.  A torn write (fail-injected OSError, device error
        while materializing leaves) used to escape ``run``'s guard and
        kill the job even though ``ckpt.save`` is atomic (tmp + rename:
        the committed checkpoint set is never corrupted, only the attempt
        is lost).  Here each failed attempt is counted, its orphan tmp is
        swept, and the save retries immediately — same-process retry is
        correct because the state being written is host-reachable and
        committed checkpoints are untouched.  Budget exhaustion raises,
        exactly like a step that cannot recover.
        """
        while True:
            try:
                ckpt.save(self.cfg.ckpt_dir, step,
                          self.encode_ckpt(params, opt_state))
                ckpt.prune(self.cfg.ckpt_dir, self.cfg.keep_ckpts)
                return
            except (RuntimeError, OSError,
                    jax.errors.JaxRuntimeError) as e:
                self.failures += 1
                self.total_failures += 1
                log.error("checkpoint at step %d failed (%s); retrying "
                          "(%d/%d)", step, e, self.failures,
                          self.cfg.max_failures)
                if self.failures > self.cfg.max_failures:
                    raise
                swept = ckpt.sweep_orphan_tmps(self.cfg.ckpt_dir)
                if swept:
                    log.info("swept %d torn checkpoint tmp(s)", swept)
                # like step recovery, the budget only decays once the
                # NEXT train step commits — a flapping disk still trips
                # max_failures
                self._recovering = True

    def run(self, fail_injector: Callable[[int], None] | None = None):
        train_step = self.build_step()
        params, opt_state, start = self._restore_or_init()
        step = start
        while step < self.cfg.total_steps:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                batch = self.put_batch(self.source.global_batch(step))
                t0 = self.time_fn()
                params, opt_state, metrics = train_step(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = self.time_fn() - t0
                if self.watchdog.observe(step, dt):
                    log.warning("straggler at step %d (%.3fs vs EMA %.3fs)",
                                step, dt, self.watchdog.ema)
                    self.mitigation_hook(step)
                self.history.append({"step": step, "loss": loss, "dt": dt})
                if self._recovering:
                    # a post-recovery step committed: the fault was
                    # transient, so the consecutive-failure budget resets
                    # (a long run with sporadic recovered faults must not
                    # eventually trip max_failures)
                    self._recovering = False
                    self.failures = 0
                if step % self.cfg.log_every == 0:
                    log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                self.failures += 1
                self.total_failures += 1
                log.error("step %d failed (%s); recovering (%d/%d)",
                          step, e, self.failures, self.cfg.max_failures)
                if self.failures > self.cfg.max_failures:
                    raise
                # full recovery path: rebuild step (fresh executables /
                # possibly a new mesh) + restore last committed state,
                # resharded onto whatever mesh the step now targets
                shardings = None
                if self.replan is not None:
                    out = self.replan()
                    new_step, shardings = (
                        out if isinstance(out, tuple) else (out, None))
                    if new_step is not train_step:
                        # an actual re-plan (the hook returns the live step
                        # unchanged for a transient fault on an intact
                        # mesh — that must not count as one)
                        self.replans.append(step)
                        # the surviving mesh's step cost is a new
                        # distribution; judging it against the old mesh's
                        # EMA would flag every first step (and skew the
                        # EMA permanently)
                        self.watchdog.reset()
                        log.info("elastic re-plan applied at step %d", step)
                    train_step = new_step
                else:
                    train_step = self.build_step()
                self._recovering = True
                params, opt_state, step = self._restore_or_init(shardings)
                continue
            step += 1
            if step % self.cfg.ckpt_every == 0 or step == self.cfg.total_steps:
                self._checkpoint(step, params, opt_state)
        return params, opt_state
