"""Scriptable, seeded fault injection for the fault-domain runtime.

A :class:`FaultPlan` is plain data — a seed plus a list of
:class:`FaultEvent`\\ s — that scripts *when* and *where* the cluster
misbehaves.  It deliberately owns no injection mechanism of its own:
every fault lands through a hook the runtime already exposes, so the
chaos path exercises exactly the production code paths:

  ===============  =====================================================
  kind             injected through
  ===============  =====================================================
  ``device_loss``  ``Trainer.run(fail_injector=)`` (+ ``MembershipFabric
                   .fail_host`` for the ranks named in ``hosts``)
  ``straggler``    ``Trainer(time_fn=)`` via :class:`VirtualStepClock`
  ``torn_ckpt``    ``checkpoint.manager.save`` via
                   :class:`TornCheckpointWrites` (orphan ``.tmp_`` +
                   OSError — a simulated hard kill mid-save)
  ``backpressure`` the server's ``PageAllocator`` via
                   :class:`BackpressureAllocator` (ensure() denied
                   inside the event window)
  ``lease_delay``  ``MembershipFabric(delivery=)`` via
                   :func:`delivery_schedule`
  ===============  =====================================================

Event coordinates are adapter-relative: trainer/server kinds read ``at``
/``duration`` as *steps*/*ticks*; ``lease_delay`` reads them as
simulated *seconds* on the membership clock.  Plans JSON round-trip so a
failing chaos scenario can be re-run byte-identically from its artifact,
and :meth:`FaultPlan.sample` draws a random-but-seeded plan for soak
runs (``random.Random(seed)`` — no global RNG state touched).
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import tempfile
from typing import Callable, Mapping, Sequence

KINDS = ("device_loss", "straggler", "torn_ckpt", "backpressure",
         "lease_delay")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault.

    ``at``: when it fires (step / tick / second — adapter-relative).
    ``hosts``: membership ranks it touches (``device_loss``: ranks to
    fail, empty = transient fault on an intact mesh; ``lease_delay``:
    senders whose heartbeats lag, empty = all).
    ``duration``: window length for windowed kinds (``straggler``,
    ``backpressure``, ``lease_delay``); 0 on one-shot kinds
    (``device_loss`` is persistent until a revive, ``torn_ckpt`` tears
    exactly one save).
    ``severity``: kind-specific magnitude — straggler slowdown factor,
    lease extra delay in seconds; unused otherwise.
    """

    kind: str
    at: float
    hosts: tuple[int, ...] = ()
    duration: float = 0.0
    severity: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.duration < 0 or self.at < 0:
            raise ValueError(f"negative fault coordinates: {self}")
        object.__setattr__(self, "hosts", tuple(self.hosts))

    def window(self, t: float) -> bool:
        """True when ``t`` falls inside this event's active window."""
        return self.at <= t < self.at + max(self.duration, 0.0)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "at": self.at,
                "hosts": list(self.hosts), "duration": self.duration,
                "severity": self.severity}

    @staticmethod
    def from_dict(d: Mapping) -> "FaultEvent":
        return FaultEvent(kind=d["kind"], at=d["at"],
                          hosts=tuple(d.get("hosts", ())),
                          duration=d.get("duration", 0.0),
                          severity=d.get("severity", 1.0))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    seed: int
    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events,
                                        key=lambda e: (e.at, e.kind))))

    @classmethod
    def scripted(cls, *events: FaultEvent, seed: int = 0) -> "FaultPlan":
        """A hand-written plan (the smoke scenarios use this)."""
        return cls(seed=seed, events=tuple(events))

    @classmethod
    def sample(cls, seed: int, *, n_events: int = 4, n_hosts: int = 4,
               horizon: float = 20.0,
               kinds: Sequence[str] = KINDS) -> "FaultPlan":
        """Draw a seeded random plan: ``n_events`` faults over
        ``[0, horizon)``.  Host 0 is never killed — the simulation plays
        rank 0 (the process driving the loop cannot lose itself)."""
        rng = random.Random(seed)
        events = []
        for _ in range(n_events):
            kind = rng.choice(list(kinds))
            at = rng.uniform(0, horizon)
            if kind == "device_loss":
                k = rng.randint(1, max(1, n_hosts - 1))
                hosts = tuple(rng.sample(range(1, n_hosts),
                                         min(k, n_hosts - 1)))
                events.append(FaultEvent(kind, round(at), hosts=hosts))
            elif kind == "torn_ckpt":
                events.append(FaultEvent(kind, round(at)))
            elif kind == "straggler":
                events.append(FaultEvent(
                    kind, round(at), duration=rng.randint(1, 4),
                    severity=rng.uniform(3.0, 10.0)))
            elif kind == "backpressure":
                events.append(FaultEvent(
                    kind, round(at), duration=rng.randint(1, 6)))
            else:  # lease_delay
                hosts = tuple(rng.sample(range(n_hosts),
                                         rng.randint(1, n_hosts)))
                events.append(FaultEvent(
                    kind, at, hosts=hosts,
                    duration=rng.uniform(0.1, 1.0),
                    severity=rng.uniform(0.05, 0.4)))
        return cls(seed=seed, events=tuple(events))

    def by_kind(self, kind: str) -> tuple[FaultEvent, ...]:
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r}")
        return tuple(e for e in self.events if e.kind == kind)

    def to_dict(self) -> dict:
        return {"seed": self.seed,
                "events": [e.to_dict() for e in self.events]}

    @staticmethod
    def from_dict(d: Mapping) -> "FaultPlan":
        return FaultPlan(seed=d.get("seed", 0),
                         events=tuple(FaultEvent.from_dict(e)
                                      for e in d.get("events", ())))

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)

    @staticmethod
    def load(path: str) -> "FaultPlan":
        with open(path) as f:
            return FaultPlan.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# Adapters: a FaultPlan -> the runtime's existing injection hooks.
# ---------------------------------------------------------------------------


def trainer_injector(plan: FaultPlan,
                     fabric=None) -> Callable[[int], None]:
    """``Trainer.run(fail_injector=)`` hook for the plan's device losses.

    At each event's step: the named hosts (if any) are failed on the
    membership fabric FIRST — peers must learn through lease expiry, the
    raise is only this process noticing its own step die — then a
    RuntimeError surfaces, driving the trainer's normal recovery path.
    ``hosts=()`` is a transient fault: the step dies but the pool is
    intact, so recovery must NOT re-plan.  Each event fires once (the
    replayed step after recovery must not re-die)."""
    fired: set[int] = set()

    def injector(step: int) -> None:
        for idx, ev in enumerate(plan.by_kind("device_loss")):
            if idx in fired or int(ev.at) != step:
                continue
            fired.add(idx)
            if fabric is not None:
                for r in ev.hosts:
                    fabric.fail_host(r)
            what = (f"hosts {list(ev.hosts)} lost"
                    if ev.hosts else "transient device fault")
            raise RuntimeError(
                f"injected device_loss at step {step}: {what}")

    return injector


def delivery_schedule(plan: FaultPlan, base_delay: float = 0.0,
                      ) -> Callable[[int, int, float], float]:
    """``MembershipFabric(delivery=)`` hook: heartbeat link delays.

    Each ``lease_delay`` event adds ``severity`` seconds to every
    heartbeat SENT by a host in ``hosts`` (empty = all hosts) during
    ``[at, at + duration)`` on the fabric clock — the knob that makes a
    healthy host look suspect and exercises the quorum's split-brain
    defenses."""
    events = plan.by_kind("lease_delay")

    def delivery(src: int, dst: int, t: float) -> float:
        delay = base_delay
        for ev in events:
            if ev.window(t) and (not ev.hosts or src in ev.hosts):
                delay += ev.severity
        return delay

    return delivery


class BackpressureAllocator:
    """Proxy over the server's ``PageAllocator`` denying ``ensure``
    inside the plan's backpressure windows (ticks, read from
    ``ticks_fn`` — pass ``lambda: server.ticks``).

    A denied ensure is indistinguishable from a genuinely exhausted pool,
    so the server walks its real degradation ladder: admission backoff,
    skipped decode beats, eventually deadline expiry.  Everything else
    delegates to the wrapped allocator (it IS the allocator — same page
    state before, during and after the window)."""

    def __init__(self, alloc, plan: FaultPlan,
                 ticks_fn: Callable[[], int]):
        self._alloc = alloc
        self._events = plan.by_kind("backpressure")
        self._ticks_fn = ticks_fn
        self.denied = 0

    def ensure(self, slot: int, n_tokens: int) -> bool:
        if any(ev.window(self._ticks_fn()) for ev in self._events):
            self.denied += 1
            return False
        return self._alloc.ensure(slot, n_tokens)

    def __getattr__(self, name):
        return getattr(self._alloc, name)


class TornCheckpointWrites:
    """Context manager tearing scripted checkpoint saves.

    Wraps ``checkpoint.manager.save``: when a save lands on a
    ``torn_ckpt`` event's step (each event tears once), a partial
    ``.tmp_`` staging dir is left in the ckpt_dir and an OSError raised
    WITHOUT running the real save — the on-disk signature of a hard kill
    mid-write (``manager.save`` cleans its own tmp on an exception it
    sees; a SIGKILL leaves one).  The trainer's ``_checkpoint`` retry
    must count the failure, sweep the orphan, and succeed on the next
    attempt."""

    def __init__(self, plan: FaultPlan):
        self._steps = {int(e.at) for e in plan.by_kind("torn_ckpt")}
        self.torn: list[int] = []
        self._orig = None

    def __enter__(self):
        from repro.checkpoint import manager

        self._orig = manager.save

        def torn_save(ckpt_dir, step, tree, extra=None):
            if step in self._steps and step not in self.torn:
                self.torn.append(step)
                os.makedirs(ckpt_dir, exist_ok=True)
                tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
                with open(os.path.join(tmp, "arr_0.npy"), "wb") as f:
                    f.write(b"\x93NUMPY torn")   # a torn partial leaf
                raise OSError(
                    f"injected torn checkpoint write at step {step}")
            return self._orig(ckpt_dir, step, tree, extra)

        manager.save = torn_save
        return self

    def __exit__(self, *exc):
        from repro.checkpoint import manager

        manager.save = self._orig
        return False


class VirtualStepClock:
    """``Trainer(time_fn=)`` stand-in that manufactures straggler steps.

    The trainer reads the clock twice per committed step (before/after
    the jit'd call).  This clock pairs those reads: every pair advances
    virtual time by ``base_dt``, scaled by the product of the severities
    of ``straggler`` events whose step window covers the pair's index —
    so a scripted straggler reliably trips the watchdog regardless of
    real host speed.  Limitation: a step that RAISES between the two
    reads skews the pairing by one; scenarios that mix stragglers with
    step failures should script the straggler window away from the
    failure step."""

    def __init__(self, plan: FaultPlan, base_dt: float = 0.01):
        self._events = plan.by_kind("straggler")
        self.base_dt = base_dt
        self._now = 0.0
        self._calls = 0

    def __call__(self) -> float:
        if self._calls % 2 == 1:      # closing read: charge the step
            step = self._calls // 2
            dt = self.base_dt
            for ev in self._events:
                if ev.window(step):
                    dt *= ev.severity
            self._now += dt
        self._calls += 1
        return self._now
