"""Sharded checkpointing with atomic commit and elastic re-sharding.

Layout:  <dir>/step_<N>/
             meta.json            (step, param tree structure, shapes)
             arr_<i>.npy          (one file per leaf, GLOBAL array)
             COMMITTED            (atomic marker, written last)

Arrays are stored as full global tensors (gathered via jax.device_get of
addressable shards); on restore they can be loaded under a *different*
mesh/sharding — elastic scaling across restarts.  A real multi-host
deployment would write per-shard files + a global index; the format here
keeps the same atomic-commit and reshard-on-load semantics single-host.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Atomically save a pytree of (global) arrays."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    leaves, treedef = _flatten(tree)
    try:
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        meta = {
            "step": step,
            "treedef": str(treedef),
            "num_leaves": len(leaves),
            "shapes": [list(np.shape(jax.device_get(l))) for l in leaves],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of `template`.

    `shardings`: optional tree of jax.sharding.Sharding — arrays are placed
    with jax.device_put under the *current* mesh, which may differ from the
    mesh at save time (elastic re-shard)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    t_leaves, treedef = _flatten(template)
    assert meta["num_leaves"] == len(t_leaves), \
        f"leaf count mismatch: ckpt {meta['num_leaves']} vs template {len(t_leaves)}"
    s_leaves = (jax.tree.leaves(shardings) if shardings is not None
                else [None] * len(t_leaves))
    out = []
    for i, (tmpl, shd) in enumerate(zip(t_leaves, s_leaves)):
        arr = np.load(os.path.join(d, f"arr_{i}.npy"))
        assert tuple(arr.shape) == tuple(np.shape(tmpl)), \
            f"leaf {i}: shape {arr.shape} != template {np.shape(tmpl)}"
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree.unflatten(treedef, out), meta


def prune(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
        and os.path.exists(os.path.join(ckpt_dir, n, "COMMITTED")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
