"""Sharded checkpointing with atomic commit and elastic re-sharding.

Layout:  <dir>/step_<N>/
             meta.json            (step, param tree structure, shapes)
             arr_<i>.npy          (one file per leaf, GLOBAL array)
             COMMITTED            (atomic marker, written last)

Arrays are stored as full global tensors (gathered via jax.device_get of
addressable shards); on restore they can be loaded under a *different*
mesh/sharding — elastic scaling across restarts.  A real multi-host
deployment would write per-shard files + a global index; the format here
keeps the same atomic-commit and reshard-on-load semantics single-host.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

#: restore() sharding-leaf sentinel: keep this leaf as host numpy.
HOST = "host"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _resolve_dtype(name: str) -> np.dtype:
    """Dtype from its recorded name, including extension dtypes numpy
    cannot resolve by string (bfloat16, float8_* live in ml_dtypes)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def sweep_orphan_tmps(ckpt_dir: str) -> int:
    """Remove ``.tmp_*`` staging dirs left by a crashed/killed ``save``.

    A hard kill between ``mkdtemp`` and ``os.replace`` (or a raise the
    except clause never sees, e.g. SIGKILL) orphans the staging dir; it is
    invisible to ``restore``/``latest_step`` but otherwise lives forever.
    The layout is single-writer (one trainer owns a ckpt_dir), so any
    ``.tmp_*`` present when a *new* save or prune runs is, by definition,
    dead.  Returns the number of dirs removed.
    """
    if not os.path.isdir(ckpt_dir):
        return 0
    n = 0
    for name in os.listdir(ckpt_dir):
        if name.startswith(".tmp_"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
            n += 1
    return n


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Atomically save a pytree of (global) arrays."""
    os.makedirs(ckpt_dir, exist_ok=True)
    sweep_orphan_tmps(ckpt_dir)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    leaves, treedef = _flatten(tree)
    try:
        dtypes, shapes = [], []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            dtypes.append(str(arr.dtype))
            shapes.append(list(arr.shape))
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        meta = {
            "step": step,
            "treedef": str(treedef),
            "num_leaves": len(leaves),
            "shapes": shapes,
            # np.save writes extension dtypes (bfloat16, fp8) as raw void
            # bytes; the recorded names let restore reinterpret them
            "dtypes": dtypes,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of `template`.

    `shardings`: optional tree of jax.sharding.Sharding — arrays are placed
    with jax.device_put under the *current* mesh, which may differ from the
    mesh at save time (elastic re-shard).  Leaves may be None (default
    jnp placement for that leaf; kept positionally, not dropped) or the
    `HOST` sentinel (the raw numpy array is returned untouched — for
    consumers that post-process on the host, e.g. re-banking zero1 state,
    and should not pay a device round trip)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    t_leaves, treedef = _flatten(template)
    assert meta["num_leaves"] == len(t_leaves), \
        f"leaf count mismatch: ckpt {meta['num_leaves']} vs template {len(t_leaves)}"
    if shardings is not None:
        # is_leaf keeps per-leaf Nones aligned (jax.tree.leaves drops them)
        s_leaves = jax.tree.flatten(
            shardings, is_leaf=lambda x: x is None)[0]
        assert len(s_leaves) == len(t_leaves), \
            f"shardings tree has {len(s_leaves)} leaves, template {len(t_leaves)}"
    else:
        s_leaves = [None] * len(t_leaves)
    saved_dtypes = meta.get("dtypes")
    out = []
    for i, (tmpl, shd) in enumerate(zip(t_leaves, s_leaves)):
        arr = np.load(os.path.join(d, f"arr_{i}.npy"))
        assert tuple(arr.shape) == tuple(np.shape(tmpl)), \
            f"leaf {i}: shape {arr.shape} != template {np.shape(tmpl)}"
        if arr.dtype.kind == "V":
            # an extension dtype came back as raw bytes — reinterpret with
            # the recorded dtype (same bits; older ckpts without the
            # record fall back to the template's dtype)
            dt = (_resolve_dtype(saved_dtypes[i]) if saved_dtypes
                  else np.dtype(tmpl.dtype))
            assert arr.dtype.itemsize == dt.itemsize, \
                f"leaf {i}: cannot reinterpret {arr.dtype} as {dt}"
            arr = arr.view(dt)
        # cast BEFORE placement in both branches: the on-disk npy dtype
        # must not leak through device_put (a bf16 template would silently
        # come back at the saved dtype on the sharded path)
        if arr.dtype != tmpl.dtype:
            arr = arr.astype(tmpl.dtype)
        if isinstance(shd, str) and shd == HOST:
            out.append(arr)
        elif shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree.unflatten(treedef, out), meta


def prune(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    sweep_orphan_tmps(ckpt_dir)
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
        and os.path.exists(os.path.join(ckpt_dir, n, "COMMITTED")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
