"""ATP analytic communication cost model (paper §3.3-§3.5, Eq. 2-4).

Beyond the paper's Eq. 2 (``t_comm``), ``t_comm_overlap`` models the
explicit overlap engine (repro.core.overlap + docs/overlap.md): per-chunk
effective communication time max(0, comm - overlappable GEMM), ring vs.
Rabenseifner algorithm step counts per hierarchy level, and the
sequence-parallel boundary (reduce-scatter wire bytes = half an
all-reduce's, plus the conjugate block-entry all-gather accounted
separately).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

from repro.core.comm_matrix import HierarchicalCommMatrix


def rabenseifner_bw(d: int, raw_bw: float) -> float:
    """Eq. 4: algorithm bandwidth of a d-rank all-reduce on raw link bw."""
    if d <= 1:
        return math.inf
    return d / (2.0 * (d - 1)) * raw_bw


#: wire-transfer factor and ring/rabenseifner step counts per collective
_COLLECTIVE_SHAPE = {
    # op: (transfer fraction of payload, ring steps fn, raben steps fn)
    "all_reduce": (lambda d: 2.0 * (d - 1) / d,
                   lambda d: 2 * (d - 1),
                   lambda d: 2 * math.ceil(math.log2(d))),
    "reduce_scatter": (lambda d: (d - 1) / d,
                       lambda d: d - 1,
                       lambda d: math.ceil(math.log2(d))),
    "all_gather": (lambda d: (d - 1) / d,
                   lambda d: d - 1,
                   lambda d: math.ceil(math.log2(d))),
}


def collective_seconds(
    vol_bytes: float,
    d: int,
    raw_bw_gbps: float,
    *,
    op: str = "all_reduce",
    algo: str = "ring",
    alpha_s: float = 0.0,
) -> float:
    """Time of one collective over a `d`-rank group on raw link bandwidth.

    vol_bytes is the per-device payload (the tensor size); the wire moves
    ``transfer_factor * vol_bytes`` of it.  ``alpha_s`` is the per-step
    latency, where ring uses O(d) steps and Rabenseifner O(log d) — the
    bandwidth term is identical (Eq. 4), so the algorithm choice only
    matters through latency and is what chunking has to amortise.
    """
    if d <= 1 or vol_bytes <= 0.0:
        return 0.0
    transfer, ring_steps, raben_steps = _COLLECTIVE_SHAPE[op]
    steps = ring_steps(d) if algo == "ring" else raben_steps(d)
    return vol_bytes * transfer(d) / (raw_bw_gbps * 1e9) + steps * alpha_s


def _ff_cols(cfg, d_ff: float) -> float:
    """Column-first output width of one MLP up(-and-gate) projection."""
    return 2.0 * d_ff if cfg.mlp_kind in ("swiglu", "geglu") else float(d_ff)


@dataclasses.dataclass(frozen=True)
class LayerCommProfile:
    """Per-layer TP communication volumes (generalizes Eq. 2 per segment kind).

    col_first_out : sum of output dims of column-first GEMMs (all-reduced
                    over mesh dim 2 at size dim/d1).  GPT: qkv 3h + mlp-up
                    4h = 7h.  SwiGLU archs: qkv_dim + 2*d_ff.
    row_first_out : sum of output dims of row-first GEMMs (all-reduced over
                    mesh dim 1 at size dim/d2).  GPT: attn-out h + mlp-down
                    h = 2h.
    col_full_out  : output dims all-reduced over mesh dim 2 at FULL width
                    (not d1-sharded): MLA's compressed-latent
                    down-projections, mamba's replicated zx/B/C/dt
                    projections (the recurrent-state inputs), xlstm's
                    replicated gate pre-activations.
    row_full_out  : output dims all-reduced over mesh dim 1 at FULL width
                    (not d2-sharded): zamba's shared-attention ax1
                    regather, xlstm's w_down/recurrent-h psum(ax1) parts.
                    Priced against B1 with no GEMM-overlap credit.
    flat_dispatch_out : per-token feature widths moved through flat-TP
                    (d1*d2) all-to-all — MoE expert dispatch + combine
                    (2 * top_k * capacity_factor * h); priced on the
                    bottleneck link and never credited with GEMM overlap.

    The per-kind constructors below derive these from a ``ModelConfig``;
    ``for_segment`` dispatches on the model's segment kinds (configs.base
    ``segments``), which is what the per-segment plan search prices.
    """

    col_first_out: float
    row_first_out: float
    hidden: float | None = None  # contraction dim (for GEMM-time modelling)
    col_full_out: float = 0.0
    row_full_out: float = 0.0
    flat_dispatch_out: float = 0.0

    @staticmethod
    def gpt(hidden: int) -> "LayerCommProfile":
        return LayerCommProfile(7.0 * hidden, 2.0 * hidden, hidden=hidden)

    # -- per-segment-kind constructors (derive volumes from ModelConfig) ----

    @staticmethod
    def dense(cfg) -> "LayerCommProfile":
        """GQA attention + dense MLP: fused qkv f1, attn-out f2, up(+gate)
        f3, down f4 (matches models.transformer.dense_block)."""
        col = cfg.q_dim + 2.0 * cfg.kv_dim + _ff_cols(cfg, cfg.d_ff)
        return LayerCommProfile(float(col), 2.0 * cfg.d_model,
                                hidden=float(cfg.d_model))

    @staticmethod
    def moe(cfg) -> "LayerCommProfile":
        """GQA attention + EP MoE FFN: the dense-MLP boundaries are replaced
        by flat-TP all-to-all dispatch bytes (models.moe.moe_block)."""
        mc = cfg.moe
        col = cfg.q_dim + 2.0 * cfg.kv_dim
        row = float(cfg.d_model)  # attn-out f2 only
        if mc.num_shared:  # deepseek shared experts run the dense MLP path
            col += _ff_cols(cfg, mc.d_ff_expert * mc.num_shared)
            row += cfg.d_model
        flat = 2.0 * mc.top_k * mc.capacity_factor * cfg.d_model
        return LayerCommProfile(float(col), row, hidden=float(cfg.d_model),
                                flat_dispatch_out=flat)

    @staticmethod
    def mla_dense(cfg) -> "LayerCommProfile":
        """MLA attention + dense MLP: the latent down-projections psum(ax2)
        at full compressed-KV width (models.mla.mla_block)."""
        m = cfg.mla
        latents = m.q_lora_rank + m.kv_lora_rank + m.qk_rope_head_dim
        return LayerCommProfile(
            _ff_cols(cfg, cfg.d_ff),            # f3 (up+gate)
            2.0 * cfg.d_model,                  # wo + mlp-down row boundaries
            hidden=float(cfg.d_model), col_full_out=float(latents))

    @staticmethod
    def mla_moe(cfg) -> "LayerCommProfile":
        mla = LayerCommProfile.mla_dense(cfg)
        moe = LayerCommProfile.moe(cfg)
        mc = cfg.moe
        col = (mla.col_first_out - _ff_cols(cfg, cfg.d_ff)  # MoE replaces MLP
               + (_ff_cols(cfg, mc.d_ff_expert * mc.num_shared)
                  if mc.num_shared else 0.0))
        row = cfg.d_model + (cfg.d_model if mc.num_shared else 0.0)
        return LayerCommProfile(col, float(row), hidden=float(cfg.d_model),
                                col_full_out=mla.col_full_out,
                                flat_dispatch_out=moe.flat_dispatch_out)

    @staticmethod
    def mamba(cfg) -> "LayerCommProfile":
        """Mamba2 block: replicated zx in-projection + the recurrent-state
        inputs (B/C at 2*d_state, dt at nheads) psum(ax2) at full width;
        out-projection is a standard row boundary."""
        sc = cfg.ssm
        d_inner = sc.expand * cfg.d_model
        nheads = d_inner // sc.head_dim
        state = 2.0 * sc.d_state + nheads       # recurrent-state volume/token
        return LayerCommProfile(
            0.0, float(cfg.d_model), hidden=float(cfg.d_model),
            col_full_out=2.0 * d_inner + state)

    @staticmethod
    def zamba(cfg) -> "LayerCommProfile":
        """One zamba super-block: shared-attention entry (two fused
        column-first h->h projections + full-width ax1 regather) + a dense
        block + (shared_attn_every - 1) mamba blocks."""
        inner = cfg.ssm.shared_attn_every
        d = LayerCommProfile.dense(cfg)
        m = LayerCommProfile.mamba(cfg)
        k = inner - 1
        return LayerCommProfile(
            d.col_first_out + cfg.d_model,               # shared entry proj
            d.row_first_out + k * m.row_first_out,
            hidden=float(cfg.d_model),
            col_full_out=k * m.col_full_out,
            row_full_out=float(cfg.d_model))             # ax1 regather

    @staticmethod
    def xlstm(cfg) -> "LayerCommProfile":
        """One xLSTM super-block: (slstm_every - 1) mLSTM blocks (replicated
        up/gate + qk pre-activations, full-width down psum over both axes)
        + one sLSTM (replicated gates + recurrent h psum(ax1))."""
        sc = cfg.ssm
        inner = sc.slstm_every
        d_up = int(sc.proj_factor * cfg.d_model)
        nh = cfg.num_heads
        dk = (d_up // nh) // 2
        mlstm_col_full = 2.0 * d_up + 2.0 * nh * dk + cfg.d_model
        slstm_col_full = 4.0 * cfg.d_model
        return LayerCommProfile(
            0.0, 0.0, hidden=float(cfg.d_model),
            col_full_out=(inner - 1) * mlstm_col_full + slstm_col_full,
            # per-block w_down / recurrent-h psum(ax1) at full width
            row_full_out=float(inner * cfg.d_model))

    _KIND_DISPATCH = {
        "dense": "dense", "moe": "moe", "mla_dense": "mla_dense",
        "mla_moe": "mla_moe", "mamba": "mamba", "zamba": "zamba",
        "xlstm": "xlstm",
    }

    @staticmethod
    def for_segment(kind: str, cfg) -> "LayerCommProfile":
        """Per-kind profile for one model segment (configs.base.segments)."""
        try:
            ctor = LayerCommProfile._KIND_DISPATCH[kind]
        except KeyError:
            raise ValueError(
                f"no comm profile for segment kind {kind!r}; have "
                f"{sorted(LayerCommProfile._KIND_DISPATCH)}") from None
        return getattr(LayerCommProfile, ctor)(cfg)


@dataclasses.dataclass(frozen=True)
class SegmentWorkload:
    """One model segment's search workload: ``layers`` scan steps of a
    ``profile``-shaped block (super-block kinds fold their inner blocks
    into the profile, so layers == scan count)."""

    kind: str
    layers: int
    profile: LayerCommProfile


def segment_workloads(cfg) -> tuple[SegmentWorkload, ...]:
    """Per-segment (kind, layers, profile) for a ModelConfig — the
    heterogeneous workload the v2 plan search prices and sums."""
    from repro.configs.base import segments

    return tuple(
        SegmentWorkload(kind=s.kind, layers=s.count,
                        profile=LayerCommProfile.for_segment(s.kind, cfg))
        for s in segments(cfg))


@dataclasses.dataclass(frozen=True)
class StrategyCost:
    d1: int
    d2: int
    b1_raw: float
    b2_raw: float
    b1: float
    b2: float
    t_comm: float  # seconds per step


def axis_algorithm_bw(
    matrix: HierarchicalCommMatrix, d1: int, d2: int
) -> tuple[float, float, float, float]:
    """(B1', B2', B1, B2): Eq. 3 raw then Eq. 4 algorithm bandwidths."""
    b1_raw, b2_raw = matrix.axis_bandwidths(d1, d2)
    return b1_raw, b2_raw, rabenseifner_bw(d1, b1_raw), rabenseifner_bw(d2, b2_raw)


def t_comm(
    matrix: HierarchicalCommMatrix,
    d1: int,
    d2: int,
    *,
    layers: int,
    batch: int,
    seq: int,
    profile: LayerCommProfile,
    bytes_per_elem: int = 2,
    calibrated: tuple[float, float] | None = None,
) -> StrategyCost:
    """Generalized Eq. 2, in seconds.

    T = 2*L*b*s * ( C_col/(d1*B2) + C_row/(d2*B1) ) * bytes

    `calibrated` optionally overrides (B1, B2) with measured values
    (paper §5.3, IC1 case).
    """
    b1_raw, b2_raw, b1, b2 = axis_algorithm_bw(matrix, d1, d2)
    if calibrated is not None:
        b1, b2 = calibrated
    tokens = 2.0 * layers * batch * seq * bytes_per_elem  # fwd+bwd factor 2
    term_col = (profile.col_first_out / (d1 * b2)) if d2 > 1 else 0.0
    term_row = (profile.row_first_out / (d2 * b1)) if d1 > 1 else 0.0
    t = tokens * (term_col + term_row) / 1e9  # GB/s -> bytes/s
    return StrategyCost(d1, d2, b1_raw, b2_raw, b1, b2, t)


def factorization_sensitivity(
    matrix: HierarchicalCommMatrix,
    d1: int,
    d2: int,
    *,
    workloads: tuple[SegmentWorkload, ...],
    batch: int,
    seq: int,
    bytes_per_elem: int = 2,
) -> float:
    """Modelled step-seconds riding on this factorization's bandwidth
    numbers: Eq. 2's comm time under the analytic (B1, B2), summed over
    the model's segment workloads.

    Because T is proportional to 1/B, the first-order |dT/d ln B| *is*
    the comm time itself — so this one number ranks how much the
    strategy ranking moves if the analytic bandwidths are wrong for
    this (d1, d2).  Deadline-budgeted recovery
    (``calibrate.recalibrate_surviving(deadline_s=...)``) measures
    factorizations in descending sensitivity: §5.3's IC1 mis-ranking is
    exactly a high-sensitivity entry being wrong, and those are the
    entries a shrinking budget must spend its micro-benchmarks on
    first.
    """
    return sum(
        t_comm(matrix, d1, d2, layers=w.layers, batch=batch, seq=seq,
               profile=w.profile, bytes_per_elem=bytes_per_elem).t_comm
        for w in workloads)


# ---------------------------------------------------------------------------
# Overlap-aware extension (docs/overlap.md).
# ---------------------------------------------------------------------------


def wire_bytes_per_elem(wire_dtype: str, bytes_per_elem: int) -> float:
    """Bytes per element a boundary collective actually moves.

    Mirrors ``overlap.WIRE_DTYPES`` without importing jax: "bf16" is the
    full-width baseline (whatever ``bytes_per_elem`` the caller models),
    int8/fp8 payloads are one byte on the wire (the shared per-chunk
    scale is O(1) per collective — negligible against the payload)."""
    if wire_dtype in ("int8", "fp8"):
        return 1.0
    if wire_dtype != "bf16":
        raise ValueError(
            f"wire_dtype must be 'bf16', 'int8' or 'fp8', got "
            f"{wire_dtype!r}")
    return float(bytes_per_elem)


@dataclasses.dataclass(frozen=True)
class OverlapStrategyCost:
    """Per-(d1, d2, chunks, seq_parallel) modelled step communication.

    t_comm          raw (un-overlapped) collective time per step [s]
    t_exposed       comm time left on the critical path after per-chunk
                    overlap with the producing GEMMs [s]
    t_gemm          boundary-producing GEMM time per step [s]
    ax1_boundary_bytes   wire bytes of the ax1 *boundary* collectives
                    (f2/f4: all-reduce, or reduce-scatter when seq-parallel)
    ax1_total_bytes      ax1 boundary + block-entry gather wire bytes
                    (seq-parallel conserves total fwd+bwd volume; the win is
                    per-op size, overlap granularity and activation memory)
    """

    d1: int
    d2: int
    chunks: int
    seq_parallel: bool
    b1_raw: float
    b2_raw: float
    t_comm: float
    t_exposed: float
    t_gemm: float
    ax1_boundary_bytes: float
    ax1_total_bytes: float
    ax2_boundary_bytes: float
    #: chunks > 1 and every chunk-credited boundary's per-chunk collective
    #: time (incl. per-step latency) fits inside its per-chunk GEMM time —
    #: when True, t_exposed is strictly below the chunks=1 exposure.
    fully_overlapped: bool = False
    #: flat-TP all-to-all wire bytes (MoE expert dispatch + combine)
    flat_dispatch_bytes: float = 0.0


def _exposed(vol_bytes: float, d: int, raw_bw: float, op: str, algo: str,
             alpha_s: float, chunks: int, t_gemm: float) -> float:
    """Critical-path comm after pipelining `chunks` chunks against the
    producing GEMM: chunk k's collective overlaps chunk k+1's GEMM; the
    last chunk's collective is always exposed.  Each chunk pays its own
    per-step latency (chunking amortises bandwidth, not alpha)."""
    if d <= 1:
        return 0.0
    c = max(1, chunks)
    tc = collective_seconds(vol_bytes / c, d, raw_bw, op=op, algo=algo,
                            alpha_s=alpha_s)
    return tc + (c - 1) * max(0.0, tc - t_gemm / c)


def t_comm_overlap(
    matrix: HierarchicalCommMatrix,
    d1: int,
    d2: int,
    *,
    layers: int,
    batch: int,
    seq: int,
    profile: LayerCommProfile,
    bytes_per_elem: int = 2,
    chunks: int = 1,
    seq_parallel: bool = False,
    peak_tflops: float = 200.0,
    algo: str = "ring",
    alpha_s: float = 0.0,
    calibrated: tuple[float, float] | None = None,
    chunk_eff: "Mapping[int, tuple[float, float]] | None" = None,
    chunk_launch_s: float | None = None,
    wire_dtype: str = "bf16",
) -> OverlapStrategyCost:
    """Generalised Eq. 2 with explicit-overlap accounting.

    Per layer and direction (fwd+bwd = factor 2):
      col boundary: payload b*s*C_col/d1 bytes all-reduced over ax2 (d2)
      row boundary: payload b*s*C_row/d2 bytes over ax1 (d1) — all-reduce
        under the replicated block I/O spec, reduce-scatter (+ the
        conjugate block-entry all-gather) under sequence-parallel.
    Effective comm per boundary = _exposed(comm, producing-GEMM, chunks).
    With chunks=1, algo="rabenseifner", alpha_s=0 this reduces exactly to
    Eq. 2 (the parity the strategy-search acceptance test pins down).

    ``calibrated`` overrides (B1, B2) with measured *algorithm* bandwidths
    in the same convention as ``t_comm`` (paper §5.3: all-reduce time =
    payload/B).  Internally the raw link bandwidth is recovered by
    inverting Eq. 4, so a calibrated all-reduce costs exactly payload/B
    regardless of ``algo`` — matching the seed Eq. 2 path bit-for-bit.

    ``chunk_eff`` optionally maps a chunk count to measured per-axis
    bandwidth-efficiency multipliers (ax1, ax2) from the chunked
    micro-benchmark (``calibrate``): splitting a collective into c pieces
    on a real fabric loses efficiency to per-piece overheads the analytic
    exposure model cannot see, so the *chunked* boundary collectives run
    at ``raw_bw * eff`` while the unchunked totals keep the full-payload
    bandwidth.  Absent (or for a chunk count with no entry) the analytic
    exposure model is used unchanged.

    ``chunk_launch_s`` is the measured per-extra-chunk launch cost
    (``CalibEntry.launch_s``): splitting a boundary into c collectives
    pays c-1 extra software launches that no amount of overlap hides.
    Kept separate from ``chunk_eff`` — which since the double-count fix
    prices pure bandwidth loss — and from ``alpha_s`` (per *ring step*
    wire latency, already charged per chunk by ``collective_seconds``).

    ``wire_dtype`` prices the boundary payloads at the quantized wire
    width: "int8"/"fp8" move 1 byte per element instead of
    ``bytes_per_elem``.  GEMM flops are unchanged (compute stays full
    precision) and the MoE flat dispatch keeps full-width activations
    (wire quantization rides the f1..f4 boundary collectives only).
    """
    if profile.hidden is None:
        raise ValueError(
            "t_comm_overlap needs profile.hidden to model GEMM time; use "
            "LayerCommProfile.gpt(...) or pass hidden= explicitly")
    b1_raw, b2_raw = matrix.axis_bandwidths(d1, d2)
    if calibrated is not None:
        cb1, cb2 = calibrated
        # invert Eq. 4: raw = B_alg * 2(d-1)/d (the all-reduce transfer
        # factor), so collective_seconds(vol, d, raw) == vol / B_alg
        if d1 > 1 and cb1 is not None and not math.isinf(cb1):
            b1_raw = cb1 * 2.0 * (d1 - 1) / d1
        if d2 > 1 and cb2 is not None and not math.isinf(cb2):
            b2_raw = cb2 * 2.0 * (d2 - 1) / d2
    steps = 2.0 * layers  # fwd + bwd per layer
    wire_bytes = wire_bytes_per_elem(wire_dtype, bytes_per_elem)
    # col boundary pool: d1-sharded column outputs + full-width (unsharded)
    # psum(ax2) outputs — MLA latents, SSM recurrent-state projections
    vol_col = batch * seq * (profile.col_first_out / max(1, d1)
                             + profile.col_full_out) * wire_bytes
    # row boundary pool: d2-sharded row outputs + full-width psum(ax1)
    # outputs (zamba regather, xlstm recurrent h) — no GEMM-overlap credit
    # is claimed for the full-width part (conservative: it stays exposed)
    vol_row = batch * seq * (profile.row_first_out / max(1, d2)
                             + profile.row_full_out) * wire_bytes

    # producing-GEMM time per boundary group (overlappable work); the
    # full-width outputs' GEMMs shard only over ax2 (K = hidden/d2)
    hidden = profile.hidden
    flops_col = 2.0 * batch * seq * hidden * (
        profile.col_first_out / (d1 * d2) + profile.col_full_out / d2)
    flops_row = 2.0 * batch * seq * hidden * profile.row_first_out / (d1 * d2)
    tg_col = flops_col / (peak_tflops * 1e12)
    tg_row = flops_row / (peak_tflops * 1e12)

    # flat-TP expert dispatch (MoE all-to-all, there + back): bottleneck
    # link, ring-step latency over the flat d1*d2 group, no overlap credit
    n_flat = d1 * d2
    t_flat = 0.0
    flat_bytes = 0.0
    if profile.flat_dispatch_out > 0.0 and n_flat > 1:
        vol_flat = (batch * seq * profile.flat_dispatch_out / n_flat
                    * bytes_per_elem)
        bw_flat = min(b for b, d in ((b1_raw, d1), (b2_raw, d2)) if d > 1)
        flat_steps = (n_flat - 1) if algo == "ring" \
            else math.ceil(math.log2(n_flat))
        t_flat = (vol_flat * (n_flat - 1) / n_flat / (bw_flat * 1e9)
                  + flat_steps * alpha_s)
        flat_bytes = steps * vol_flat * (n_flat - 1) / n_flat

    t_col = (collective_seconds(vol_col, d2, b2_raw, op="all_reduce",
                                algo=algo, alpha_s=alpha_s) if d2 > 1 else 0.0)
    if seq_parallel and d1 > 1:
        t_row = collective_seconds(vol_row, d1, b1_raw, op="reduce_scatter",
                                   algo=algo, alpha_s=alpha_s)
        t_gather = collective_seconds(vol_row, d1, b1_raw, op="all_gather",
                                      algo=algo, alpha_s=alpha_s)
    else:
        t_row = (collective_seconds(vol_row, d1, b1_raw, op="all_reduce",
                                    algo=algo, alpha_s=alpha_s)
                 if d1 > 1 else 0.0)
        t_gather = 0.0

    if seq_parallel and d1 > 1:
        # the psum_scatter row boundary is not batch-chunked by atp_linear
        # (the ring rs collective-matmul pipelines over its own d1 steps);
        # credit no chunk overlap to it — conservative for both modes
        row_boundary_op, row_chunks = "reduce_scatter", 1
    else:
        row_boundary_op, row_chunks = "all_reduce", chunks
    def chunked_bw(raw: float, axis: int, c: int) -> float:
        """Measured per-chunk bandwidth efficiency (1.0 when unmeasured)."""
        if chunk_eff is None or c <= 1:
            return raw
        eff = chunk_eff.get(c)
        if eff is None or eff[axis] is None:
            return raw
        return raw * eff[axis]

    # measured per-extra-chunk launch cost: software overhead paid once
    # per additional collective, never hidden by overlap (satellite fix:
    # this used to be baked into chunk_eff, double-counting alpha)
    launch = chunk_launch_s or 0.0
    t_launch = (max(0, chunks - 1) * launch * (1.0 if d2 > 1 else 0.0)
                + max(0, row_chunks - 1) * launch * (1.0 if d1 > 1 else 0.0))

    t_comm = steps * (t_col + t_row + t_gather + t_flat)
    t_exposed = steps * (
        _exposed(vol_col, d2, chunked_bw(b2_raw, 1, chunks), "all_reduce",
                 algo, alpha_s, chunks, tg_col)
        + _exposed(vol_row, d1, chunked_bw(b1_raw, 0, row_chunks),
                   row_boundary_op, algo, alpha_s, row_chunks, tg_row)
        + t_launch   # per-extra-chunk launches stay on the critical path
        + t_gather   # entry gathers overlap the norm only
        + t_flat)    # dispatch is on the routing critical path
    t_gemm = steps * (tg_col + tg_row)

    # does every chunk-credited boundary hide its per-chunk collective
    # (with its own per-step latency) inside the per-chunk GEMM?
    chunked_boundaries = [
        (vol_col, d2, chunked_bw(b2_raw, 1, chunks), "all_reduce", chunks,
         tg_col),
        (vol_row, d1, chunked_bw(b1_raw, 0, row_chunks), row_boundary_op,
         row_chunks, tg_row),
    ]
    active = [(v, d, bw, op, c, tg) for v, d, bw, op, c, tg
              in chunked_boundaries if d > 1 and c > 1 and v > 0]
    fully_overlapped = bool(active) and all(
        collective_seconds(v / c, d, bw, op=op, algo=algo, alpha_s=alpha_s)
        <= tg / c
        for v, d, bw, op, c, tg in active)

    def wire(vol, d, op):
        if d <= 1:
            return 0.0
        return vol * _COLLECTIVE_SHAPE[op][0](d)

    row_op = "reduce_scatter" if seq_parallel else "all_reduce"
    ax1_boundary = steps * wire(vol_row, d1, row_op)
    ax1_total = ax1_boundary + steps * wire(
        vol_row, d1, "all_gather") * (1.0 if seq_parallel else 0.0)
    ax2_boundary = steps * wire(vol_col, d2, "all_reduce")
    return OverlapStrategyCost(
        d1=d1, d2=d2, chunks=chunks, seq_parallel=seq_parallel,
        b1_raw=b1_raw, b2_raw=b2_raw,
        t_comm=t_comm, t_exposed=t_exposed, t_gemm=t_gemm,
        ax1_boundary_bytes=ax1_boundary, ax1_total_bytes=ax1_total,
        ax2_boundary_bytes=ax2_boundary, fully_overlapped=fully_overlapped,
        flat_dispatch_bytes=flat_bytes)


# ---------------------------------------------------------------------------
# Decode-time (serving) cost: latency-bound per-token boundary collectives.
# ---------------------------------------------------------------------------

#: analytic defaults for the decode objective when no calibration covers
#: the factorization: base per-collective-step latency (an NVLink-class
#: hop; each mesh dim scales it by the comm matrix's ``alpha_factor``) and
#: the fixed software launch/sync cost every collective pays regardless of
#: payload.  Training-side searches keep alpha_s=0 defaults untouched.
DECODE_ALPHA_S = 1.5e-6
DECODE_LAUNCH_S = 6.0e-6


@dataclasses.dataclass(frozen=True)
class PagedReadModel:
    """Per-tick paged-attention KV read cost (the decode cost-model debt:
    measured in BENCH_serve.json since PR 5, unmodeled until now).

    Every decode tick each live slot gathers its whole mapped history
    from the page pools — ``avg_len`` tokens x ``kv_bytes_per_token``
    per layer off HBM, plus the attention FLOPs over those tokens.  The
    per-DEVICE volume is factorization-independent (attention banks are
    sharded over the flat TP degree, MLA latents are replicated — either
    way d1 x d2 is fixed across candidates), so what makes the term
    mesh-RELEVANT is overlap with the boundary collectives: a ring
    pipelines its transfers and leaves bandwidth slack the gather can
    hide in (exposed = max(0, t_read - t_bytes)), while Rabenseifner
    psum's log-step bursts leave nothing to hide behind (fully exposed).
    Candidates with fatter wire terms therefore hide more of the read,
    and the (d1, d2) argmin can flip once the term is priced.

    Build one with :func:`paged_read_model` (derives the per-token bytes
    and FLOPs from a ModelConfig) or construct directly for what-ifs.
    """

    kv_bytes_per_token: float    # per layer, per device
    avg_len: float               # mean mapped history per live slot
    layers: int
    hbm_gbps: float = 800.0
    attn_flops_per_token: float = 0.0   # per layer, per device
    peak_tflops: float = 200.0

    def t_read(self, batch: int) -> float:
        """Seconds per decode tick spent gathering + scoring paged KV."""
        per_tok = (self.kv_bytes_per_token / (self.hbm_gbps * 1e9)
                   + self.attn_flops_per_token / (self.peak_tflops * 1e12))
        return batch * self.avg_len * self.layers * per_tok


def paged_read_model(cfg, *, avg_len: float, tp: int = 1,
                     page_dtype: str = "bf16", hbm_gbps: float = 800.0,
                     peak_tflops: float = 200.0) -> PagedReadModel:
    """Derive a :class:`PagedReadModel` from a ModelConfig.

    Per attention layer a token's cached KV costs ``2 * kv_dim`` elements
    (split over the flat TP degree — banks are tp-sharded); an MLA layer
    caches the replicated latent ``kv_lora_rank + qk_rope_head_dim``.
    Recurrent kinds (mamba/zamba's inner blocks/xlstm) hold O(1) state —
    no per-token read — so only their attention sub-blocks contribute.
    Attention FLOPs per cached token are ``4 * q_dim`` (QK dot + value
    weighting), tp-sharded.  ``page_dtype`` prices quantized pools at
    1 byte/elem (scale reads are per-page, negligible).
    """
    from repro.configs.base import segments

    elem = 1.0 if page_dtype in ("int8", "fp8") else 2.0
    layers = 0
    kv_bytes = 0.0
    flops = 0.0
    for s in segments(cfg):
        if s.kind in ("dense", "moe", "zamba"):
            # zamba: one shared attention block per super-block
            kv_bytes += s.count * 2.0 * cfg.kv_dim * elem / max(1, tp)
            flops += s.count * 4.0 * cfg.q_dim / max(1, tp)
            layers += s.count
        elif s.kind in ("mla_dense", "mla_moe"):
            m = cfg.mla
            kv_bytes += s.count * (m.kv_lora_rank + m.qk_rope_head_dim) * elem
            flops += s.count * 4.0 * cfg.q_dim / max(1, tp)
            layers += s.count
        # mamba / xlstm: O(1) recurrent state, nothing to page-read
    if layers == 0:
        return PagedReadModel(kv_bytes_per_token=0.0, avg_len=avg_len,
                              layers=0, hbm_gbps=hbm_gbps,
                              peak_tflops=peak_tflops)
    # normalize to per-layer averages so t_read(b) = b*len*layers*per_tok
    return PagedReadModel(
        kv_bytes_per_token=kv_bytes / layers, avg_len=avg_len,
        layers=layers, hbm_gbps=hbm_gbps,
        attn_flops_per_token=flops / layers, peak_tflops=peak_tflops)


@dataclasses.dataclass(frozen=True)
class DecodeStrategyCost:
    """Modelled per-decode-step (one token, whole model) cost of (d1, d2).

    Decode boundary all-reduces run on ``[B, 1, h]`` activations, so the
    Eq. 2 bandwidth term nearly vanishes and the cost splits into
    ``t_launch`` (fixed per-collective software overhead — minimized by
    factorizations that *eliminate* whole boundary families: d1=1 kills
    every row boundary, d2=1 every col boundary), ``t_alpha``
    (per-step wire latency: steps(d) x the dim's hop latency) and
    ``t_bytes`` (the residual small-message bandwidth term, which keeps
    the paper's Eq. 2 ranking as the tie-break).  ``boundary_mode`` is
    the cheaper of monolithic psum (Rabenseifner O(log d) steps) and the
    explicit ring (O(d) steps) under this latency model — decode
    virtually always answers "psum", the opposite pressure from the
    bandwidth-bound training objective.

    ``t_read`` is the EXPOSED part of the per-tick paged KV gather when a
    :class:`PagedReadModel` is priced (0.0 otherwise) — rings hide up to
    ``t_bytes`` of it, psum hides none, so it shifts the psum/ring break-
    even and with it the mesh choice.  ``speculate`` marks that this
    candidate's ``t_step`` is the per-ACCEPTED-token cost of the MTP
    self-speculative tick (s=2 payloads + one extra head block, amortized
    over ``1 + accept_rate`` tokens) and that speculation beat the plain
    tick on this interconnect.
    """

    d1: int
    d2: int
    boundary_mode: str
    t_step: float        # seconds per generated token (comm only)
    t_launch: float
    t_alpha: float
    t_bytes: float
    collectives: float   # collective launches per decode step
    t_read: float = 0.0  # exposed paged-read seconds per token
    speculate: bool = False


def t_comm_decode(
    matrix: HierarchicalCommMatrix,
    d1: int,
    d2: int,
    *,
    workloads: "tuple[SegmentWorkload, ...]",
    batch: int,
    bytes_per_elem: int = 2,
    alpha_s: float = DECODE_ALPHA_S,
    launch_s: float = DECODE_LAUNCH_S,
    calibrated: tuple[float, float] | None = None,
    boundary_mode: str | None = None,
    wire_dtype: str = "bf16",
    paged_read: PagedReadModel | None = None,
    spec_accept_rate: float | None = None,
) -> DecodeStrategyCost:
    """Per-token decode communication time of one (d1, d2) factorization.

    Forward-only (no backward factor 2), seq=1, summed over the model's
    segment workloads.  Per layer the same two boundary pools as
    ``t_comm_overlap`` apply, but each *active* pool now costs

        launch_s + steps(d) * alpha_s * alpha_factor(dim) + payload/BW

    and the ranking is dominated by the first two terms (ATP Eq. 4's
    latency split).  ``calibrated`` overrides the algorithm bandwidths as
    everywhere else; a calibrated ``alpha_s`` should be passed by the
    caller (the search threads the table's measured per-step latency).
    ``boundary_mode`` forces psum/ring; default picks the cheaper.
    ``wire_dtype`` prices the boundary payloads at the quantized wire
    width (int8/fp8 = 1 byte/elem), exactly as in ``t_comm_overlap``.

    ``paged_read`` adds the per-tick paged-attention KV gather: its raw
    seconds are factorization-independent, but a ring overlaps streamed
    chunks with the gather (exposed = max(0, t_read - t_bytes)) while
    Rabenseifner's bursty log-steps hide nothing (fully exposed), so the
    term shifts the psum/ring break-even — and with it the chosen mesh.
    ``spec_accept_rate`` additionally evaluates the MTP self-speculative
    tick for each mode: s=2 payloads (2x bandwidth term) plus one extra
    head block (x (L+1)/L on the latency terms), amortized over
    ``1 + accept_rate`` emitted tokens; the candidate wins whenever
    acceptance outruns the overhead, and ``speculate`` records which tick
    shape the returned cost describes.  Both default off (inert).
    """
    b1_raw, b2_raw = matrix.axis_bandwidths(d1, d2)
    if calibrated is not None:
        cb1, cb2 = calibrated
        if d1 > 1 and cb1 is not None and not math.isinf(cb1):
            b1_raw = cb1 * 2.0 * (d1 - 1) / d1
        if d2 > 1 and cb2 is not None and not math.isinf(cb2):
            b2_raw = cb2 * 2.0 * (d2 - 1) / d2
    a1, a2 = matrix.axis_alpha_factors(d1, d2)
    n_flat = d1 * d2
    wire_bytes = wire_bytes_per_elem(wire_dtype, bytes_per_elem)

    def mode_cost(algo: str) -> tuple[float, float, float, float]:
        launch = alpha = byte = coll = 0.0
        for w in workloads:
            p = w.profile
            vol_col = batch * (p.col_first_out / max(1, d1)
                               + p.col_full_out) * wire_bytes
            vol_row = batch * (p.row_first_out / max(1, d2)
                               + p.row_full_out) * wire_bytes
            for vol, d, bw, af in ((vol_col, d2, b2_raw, a2),
                                   (vol_row, d1, b1_raw, a1)):
                if d <= 1 or vol <= 0.0:
                    continue
                transfer, ring_steps, raben_steps = \
                    _COLLECTIVE_SHAPE["all_reduce"]
                steps = (ring_steps(d) if algo == "ring"
                         else raben_steps(d))
                launch += w.layers * launch_s
                alpha += w.layers * steps * alpha_s * af
                byte += w.layers * vol * transfer(d) / (bw * 1e9)
                coll += w.layers
            if p.flat_dispatch_out > 0.0 and n_flat > 1:
                # MoE dispatch+combine: two flat all-to-alls per layer
                vol_flat = (batch * p.flat_dispatch_out / n_flat
                            * bytes_per_elem)
                bw_flat = min(b for b, d in ((b1_raw, d1), (b2_raw, d2))
                              if d > 1)
                af_flat = max(a for a, d in ((a1, d1), (a2, d2)) if d > 1)
                fsteps = ((n_flat - 1) if algo == "ring"
                          else math.ceil(math.log2(n_flat)))
                launch += w.layers * 2 * launch_s
                alpha += w.layers * 2 * fsteps * alpha_s * af_flat
                byte += (w.layers * vol_flat * (n_flat - 1) / n_flat
                         / (bw_flat * 1e9))
                coll += 2 * w.layers
        return launch, alpha, byte, coll

    t_read_raw = paged_read.t_read(batch) if paged_read is not None else 0.0
    L_total = sum(w.layers for w in workloads)
    mtp_factor = (L_total + 1) / L_total if L_total > 0 else 1.0

    modes = ([boundary_mode] if boundary_mode is not None
             else ["psum", "ring"])
    best = None
    for bm in modes:
        algo = "ring" if bm == "ring" else "rabenseifner"
        launch, alpha, byte, coll = mode_cost(algo)
        # ring streams its transfers — the paged gather hides in the
        # bandwidth slack; psum's bursty log-steps expose it fully
        exposed = (max(0.0, t_read_raw - byte) if bm == "ring"
                   else t_read_raw)
        cands = [DecodeStrategyCost(
            d1=d1, d2=d2, boundary_mode=bm,
            t_step=launch + alpha + byte + exposed,
            t_launch=launch, t_alpha=alpha, t_bytes=byte, collectives=coll,
            t_read=exposed)]
        if spec_accept_rate is not None:
            # speculative tick: s=2 payloads double the bandwidth term,
            # the extra MTP head block scales the per-layer terms by
            # (L+1)/L, and 1 + accept_rate tokens come out per tick
            exposed_spec = (max(0.0, t_read_raw - 2.0 * byte)
                            if bm == "ring" else t_read_raw)
            t_tick = ((launch + alpha + 2.0 * byte) * mtp_factor
                      + exposed_spec)
            cands.append(DecodeStrategyCost(
                d1=d1, d2=d2, boundary_mode=bm,
                t_step=t_tick / (1.0 + spec_accept_rate),
                t_launch=launch * mtp_factor, t_alpha=alpha * mtp_factor,
                t_bytes=2.0 * byte * mtp_factor, collectives=coll,
                t_read=exposed_spec, speculate=True))
        for cand in cands:
            if best is None or cand.t_step < best.t_step:
                best = cand
    return best
