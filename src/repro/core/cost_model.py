"""ATP analytic communication cost model (paper §3.3-§3.5, Eq. 2-4)."""
from __future__ import annotations

import dataclasses
import math

from repro.core.comm_matrix import HierarchicalCommMatrix


def rabenseifner_bw(d: int, raw_bw: float) -> float:
    """Eq. 4: algorithm bandwidth of a d-rank all-reduce on raw link bw."""
    if d <= 1:
        return math.inf
    return d / (2.0 * (d - 1)) * raw_bw


@dataclasses.dataclass(frozen=True)
class LayerCommProfile:
    """Per-transformer-layer TP communication volumes (generalizes Eq. 2).

    col_first_out : sum of output dims of column-first GEMMs (all-reduced
                    over mesh dim 2 at size dim/d1).  GPT: qkv 3h + mlp-up
                    4h = 7h.  SwiGLU archs: qkv_dim + 2*d_ff.
    row_first_out : sum of output dims of row-first GEMMs (all-reduced over
                    mesh dim 1 at size dim/d2).  GPT: attn-out h + mlp-down
                    h = 2h.
    """

    col_first_out: float
    row_first_out: float

    @staticmethod
    def gpt(hidden: int) -> "LayerCommProfile":
        return LayerCommProfile(7.0 * hidden, 2.0 * hidden)


@dataclasses.dataclass(frozen=True)
class StrategyCost:
    d1: int
    d2: int
    b1_raw: float
    b2_raw: float
    b1: float
    b2: float
    t_comm: float  # seconds per step


def axis_algorithm_bw(
    matrix: HierarchicalCommMatrix, d1: int, d2: int
) -> tuple[float, float, float, float]:
    """(B1', B2', B1, B2): Eq. 3 raw then Eq. 4 algorithm bandwidths."""
    b1_raw, b2_raw = matrix.axis_bandwidths(d1, d2)
    return b1_raw, b2_raw, rabenseifner_bw(d1, b1_raw), rabenseifner_bw(d2, b2_raw)


def t_comm(
    matrix: HierarchicalCommMatrix,
    d1: int,
    d2: int,
    *,
    layers: int,
    batch: int,
    seq: int,
    profile: LayerCommProfile,
    bytes_per_elem: int = 2,
    calibrated: tuple[float, float] | None = None,
) -> StrategyCost:
    """Generalized Eq. 2, in seconds.

    T = 2*L*b*s * ( C_col/(d1*B2) + C_row/(d2*B1) ) * bytes

    `calibrated` optionally overrides (B1, B2) with measured values
    (paper §5.3, IC1 case).
    """
    b1_raw, b2_raw, b1, b2 = axis_algorithm_bw(matrix, d1, d2)
    if calibrated is not None:
        b1, b2 = calibrated
    tokens = 2.0 * layers * batch * seq * bytes_per_elem  # fwd+bwd factor 2
    term_col = (profile.col_first_out / (d1 * b2)) if d2 > 1 else 0.0
    term_row = (profile.row_first_out / (d2 * b1)) if d1 > 1 else 0.0
    t = tokens * (term_col + term_row) / 1e9  # GB/s -> bytes/s
    return StrategyCost(d1, d2, b1_raw, b2_raw, b1, b2, t)
