"""ParallelPlan: the single serializable strategy artifact (paper §3.5, §5.3).

ATP's thesis is that the *searched* strategy drives execution.  This module
makes that literal: ``plan_search`` ranks the whole strategy space —
DeviceMesh(d1, d2) x chunks x seq_parallel, optionally re-weighted by an
on-mesh :class:`~repro.core.calibrate.CalibrationTable` — and emits frozen,
JSON-round-trippable :class:`ParallelPlan` objects.  Every execution layer
(``make_context(plan=...)``, the ``launch/steps`` builders, the train /
serve / dryrun launchers, the elastic trainer restart path and the paper
benchmarks) consumes a plan instead of loose kwargs, so a strategy can be
saved, diffed, shipped and re-applied:

    plan = plan_search("ic4", 16, layers=..., batch=..., seq=...,
                       profile=prof).best
    plan.save("plan.json")                    # -> CI artifact / flag file
    ctx = make_context(plan=ParallelPlan.load("plan.json"))   # identical

``plan_search(..., chunks_options=(1,), seq_parallel_options=(False,),
algo="rabenseifner", alpha_s=0)`` degrades exactly to the seed Eq. 2
``search_strategy`` ranking (pinned by tests on IC1-IC6).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Mapping

from repro.core import comm_matrix
from repro.core.atp import DecodePlan, SegmentPlan
from repro.core.calibrate import CalibrationTable, surviving_tp
from repro.core.comm_matrix import HierarchicalCommMatrix
from repro.core.cost_model import (DECODE_ALPHA_S, DECODE_LAUNCH_S,
                                   LayerCommProfile, OverlapStrategyCost,
                                   SegmentWorkload, segment_workloads)
from repro.core.mesh import MeshTopo, atp_topo
from repro.core.overlap import WIRE_DTYPES
from repro.core.search import (search_strategy_decode,
                               search_strategy_overlap,
                               search_strategy_segments)

#: v2 added per-segment ``SegmentPlan`` tuples (heterogeneous per-segment
#: overlap strategies); v3 adds the optional ``decode`` sub-plan (the
#: latency-aware serve objective's factorization + boundary_mode); v4 adds
#: ``wire_dtype`` (quantized boundary collectives) on the plan, its
#: segments and its decode sub-plan; v5 adds the decode sub-plan's
#: ``speculate`` / ``prefix_cache`` serving knobs (MTP self-speculative
#: decode priced by the search, copy-on-write prefix sharing).  v1-v4
#: files load unchanged — v1 global knobs broadcast to every segment
#: (``segment_plan``), a missing ``decode`` means "serve with the train
#: knobs" (the pre-v3 behavior), a missing ``wire_dtype`` means
#: full-width "bf16" (the pre-v4 behavior), and missing
#: ``speculate``/``prefix_cache`` mean False (the pre-v5 behavior).
#: Newer versions still fail loudly.
PLAN_FORMAT_VERSION = 5


@dataclasses.dataclass(frozen=True)
class PredictedCost:
    """Modelled per-step seconds behind a plan choice (provenance, not input)."""

    t_comm: float
    t_exposed: float
    t_gemm: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Mapping) -> "PredictedCost":
        return PredictedCost(t_comm=float(d["t_comm"]),
                             t_exposed=float(d["t_exposed"]),
                             t_gemm=float(d["t_gemm"]))


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """One complete, serializable parallelization strategy.

    Only (d1, d2, dp, pods, chunks, boundary_mode, seq_parallel, segments)
    affect execution — ``context()`` is a pure function of them.
    ``topology``, ``calibration``, ``predicted`` and ``provenance`` record
    *why* the plan was chosen, so saved artifacts are auditable and
    re-searchable.

    ``segments`` (format_version 2) carries one :class:`SegmentPlan` per
    model segment kind over the shared (d1, d2, dp) mesh; the scalar
    (chunks, boundary_mode, seq_parallel) stay as the defaults broadcast
    to kinds with no dedicated entry — which is exactly how v1 files
    load.
    """

    d1: int
    d2: int
    dp: int = 1
    pods: int = 1
    chunks: int = 1
    boundary_mode: str = "psum"
    seq_parallel: bool = False
    #: boundary-collective payload dtype (format_version 4): "bf16" full
    #: width, "int8"/"fp8" quantized wire — the default broadcast to
    #: segments with no dedicated entry, exactly like the other knobs
    wire_dtype: str = "bf16"
    segments: tuple[SegmentPlan, ...] = ()
    #: decode-time sub-plan (format_version 3): the serve objective's
    #: factorization/boundary choice; None = serve with the train knobs
    decode: DecodePlan | None = None
    topology: str | None = None  # comm-matrix preset name (if any)
    calibration: CalibrationTable | None = None
    predicted: PredictedCost | None = None
    provenance: tuple[tuple[str, str], ...] = ()

    def __post_init__(self):
        if self.d1 < 1 or self.d2 < 1 or self.dp < 1 or self.pods < 1:
            raise ValueError(f"plan degrees must be >= 1: {self}")
        if self.chunks < 1:
            raise ValueError(f"plan chunks must be >= 1, got {self.chunks}")
        if self.boundary_mode not in ("psum", "ring"):
            raise ValueError(
                f"boundary_mode must be 'psum' or 'ring', got "
                f"{self.boundary_mode!r}")
        if self.wire_dtype not in WIRE_DTYPES:
            raise ValueError(
                f"wire_dtype must be one of {WIRE_DTYPES}, got "
                f"{self.wire_dtype!r}")
        object.__setattr__(self, "segments", tuple(self.segments))
        kinds = [s.kind for s in self.segments]
        if len(set(kinds)) != len(kinds):
            raise ValueError(f"duplicate segment kinds in plan: {kinds}")
        # canonical provenance ordering so equality survives JSON round-trips
        object.__setattr__(self, "provenance", tuple(sorted(
            (str(k), str(v)) for k, v in self.provenance)))

    # -- execution ---------------------------------------------------------

    @property
    def tp(self) -> int:
        return self.d1 * self.d2

    @property
    def devices(self) -> int:
        return self.pods * self.dp * self.tp

    def topo(self) -> MeshTopo:
        """The logical mesh this plan prescribes."""
        return atp_topo(self.dp, self.d1, self.d2, pods=self.pods)

    def context(self, topo: MeshTopo | None = None):
        """Build the ATPContext this plan prescribes (on ``topo`` if given)."""
        from repro.core.atp import make_context

        return make_context(topo if topo is not None else self.topo(),
                            plan=self)

    def segment_plan(self, kind: str) -> SegmentPlan:
        """This kind's knobs — a dedicated v2 entry, or the plan's global
        knobs broadcast (the v1-file migration rule)."""
        for seg in self.segments:
            if seg.kind == kind:
                return seg
        return SegmentPlan(kind=kind, chunks=self.chunks,
                           boundary_mode=self.boundary_mode,
                           seq_parallel=self.seq_parallel,
                           wire_dtype=self.wire_dtype)

    def decode_view(self) -> "ParallelPlan":
        """The plan a decode-dominated serving deployment executes.

        With no ``decode`` sub-plan this is the plan itself (pre-v3
        behavior: serve with the train knobs).  Otherwise the decode
        factorization replaces (d1, d2) — the serving stack builds its
        mesh from this view up front, since prefill and decode share one
        set of sharded params/caches — and every knob collapses to the
        decode choice: chunks=1, the decode boundary_mode, seq_parallel
        off globally and per segment.  The sub-plan and the carried
        calibration/provenance stay attached for audit.
        """
        if self.decode is None:
            return self
        dec = self.decode
        segs = tuple(SegmentPlan(kind=s.kind, chunks=dec.chunks,
                                 boundary_mode=dec.boundary_mode,
                                 seq_parallel=False,
                                 wire_dtype=dec.wire_dtype)
                     for s in self.segments)
        return self.with_(
            d1=dec.d1, d2=dec.d2, chunks=dec.chunks,
            boundary_mode=dec.boundary_mode, seq_parallel=False,
            wire_dtype=dec.wire_dtype, segments=segs,
            provenance=self.provenance + (
                ("decode_view", f"serving on DeviceMesh({dec.d1},{dec.d2})"),))

    @property
    def calibration_stale(self) -> bool:
        """True when the carried calibration table predates an elastic
        resize (it was measured on a mesh this plan no longer runs on)."""
        return ("calibration", "stale") in self.provenance

    def describe(self) -> str:
        sp = "+sp" if self.seq_parallel else ""
        wd = "" if self.wire_dtype == "bf16" else f" wire={self.wire_dtype}"
        out = (f"DeviceMesh({self.d1},{self.d2}) dp={self.dp} "
               f"chunks={self.chunks} {self.boundary_mode}{sp}{wd}")
        if self.segments:
            out += (" segments["
                    + " ".join(s.describe() for s in self.segments) + "]")
        if self.decode is not None:
            out += " " + self.decode.describe()
        if self.calibration_stale:
            out += " [calibration:stale]"
        if self.calibration is not None:
            counts = self.calibration.provenance_counts()
            budgeted = any(k == "calibration" and v.startswith("budget ")
                           for k, v in self.provenance)
            # only worth a line when recovery actually degraded something
            # (or a deadline budget ran): all-measured tables are the norm
            if budgeted or any(p != "measured" for p in counts):
                out += (" calib["
                        + " ".join(f"{k}={counts[k]}"
                                   for k in sorted(counts)) + "]")
        return out

    def with_(self, **changes) -> "ParallelPlan":
        """Functional update (e.g. re-binding dp to a new device count)."""
        return dataclasses.replace(self, **changes)

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "format_version": PLAN_FORMAT_VERSION,
            "d1": self.d1, "d2": self.d2, "dp": self.dp, "pods": self.pods,
            "chunks": self.chunks, "boundary_mode": self.boundary_mode,
            "seq_parallel": self.seq_parallel,
            "wire_dtype": self.wire_dtype,
            "segments": [s.to_dict() for s in self.segments],
            "decode": (self.decode.to_dict()
                       if self.decode is not None else None),
            "topology": self.topology,
            "calibration": (self.calibration.to_dict()
                            if self.calibration is not None else None),
            "predicted": (self.predicted.to_dict()
                          if self.predicted is not None else None),
            # list-of-pairs, not an object: tag keys may repeat (e.g. two
            # successive "elastic" resizes) and must all survive round-trip
            "provenance": [[k, v] for k, v in self.provenance],
        }

    @staticmethod
    def from_dict(d: Mapping) -> "ParallelPlan":
        ver = d.get("format_version", PLAN_FORMAT_VERSION)
        if ver > PLAN_FORMAT_VERSION:
            raise ValueError(
                f"plan format_version {ver} is newer than supported "
                f"({PLAN_FORMAT_VERSION}); upgrade the repro package")
        calib = d.get("calibration")
        pred = d.get("predicted")
        prov = d.get("provenance", ())
        prov_pairs = prov.items() if isinstance(prov, Mapping) else prov
        return ParallelPlan(
            d1=int(d["d1"]), d2=int(d["d2"]),
            dp=int(d.get("dp", 1)), pods=int(d.get("pods", 1)),
            chunks=int(d.get("chunks", 1)),
            boundary_mode=d.get("boundary_mode", "psum"),
            seq_parallel=bool(d.get("seq_parallel", False)),
            # absent in v1-v3 files: full-width boundary collectives
            wire_dtype=d.get("wire_dtype", "bf16"),
            # absent in v1 files: the global knobs above broadcast to every
            # segment through ``segment_plan`` / ``ATPContext.for_segment``
            segments=tuple(SegmentPlan.from_dict(s)
                           for s in d.get("segments", ())),
            # absent in v1/v2 files: no decode sub-plan — serving runs the
            # train knobs, exactly the pre-v3 behavior
            decode=(DecodePlan.from_dict(d["decode"])
                    if d.get("decode") is not None else None),
            topology=d.get("topology"),
            calibration=(CalibrationTable.from_dict(calib)
                         if calib is not None else None),
            predicted=(PredictedCost.from_dict(pred)
                       if pred is not None else None),
            provenance=tuple((str(k), str(v)) for k, v in prov_pairs),
        )

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "ParallelPlan":
        return ParallelPlan.from_dict(json.loads(s))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @staticmethod
    def load(path: str) -> "ParallelPlan":
        with open(path) as f:
            return ParallelPlan.from_json(f.read())


# ---------------------------------------------------------------------------
# Unified strategy search.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanSearchResult:
    best: ParallelPlan
    ranked: tuple[ParallelPlan, ...]          # ascending modelled cost
    costs: tuple[OverlapStrategyCost, ...]    # aligned with ``ranked``

    def mesh(self) -> tuple[int, int]:
        return (self.best.d1, self.best.d2)


def _resolve_matrix(matrix) -> tuple[HierarchicalCommMatrix, str | None]:
    if isinstance(matrix, str):
        if matrix not in comm_matrix.PRESETS:
            raise ValueError(f"unknown topology preset {matrix!r}; "
                             f"have {sorted(comm_matrix.PRESETS)}")
        return comm_matrix.PRESETS[matrix](), matrix
    return matrix, None


def plan_search(
    matrix: HierarchicalCommMatrix | str,
    tp_degree: int,
    *,
    batch: int,
    seq: int,
    layers: int | None = None,
    profile: LayerCommProfile | None = None,
    model=None,
    dp: int = 1,
    pods: int = 1,
    bytes_per_elem: int = 2,
    chunks_options: tuple[int, ...] = (1, 2, 4, 8),
    seq_parallel_options: tuple[bool, ...] = (False, True),
    peak_tflops: float = 200.0,
    algo: str = "ring",
    alpha_s: float = 0.0,
    calibration: CalibrationTable | Mapping | None = None,
    boundary_mode: str | None = None,
    wire_dtype: str = "bf16",
    decode_batch: int | None = None,
    decode_alpha_s: float = DECODE_ALPHA_S,
    decode_launch_s: float = DECODE_LAUNCH_S,
    decode_paged_read=None,
    decode_accept_rate: float | None = None,
    decode_prefix_cache: bool = False,
) -> PlanSearchResult:
    """Rank the full strategy space and emit ParallelPlans.

    The one entry point subsuming the seed's two searches:

      - overlap knobs wide open (the defaults) == ``search_strategy_overlap``
        extended with calibration;
      - ``chunks_options=(1,)``, ``seq_parallel_options=(False,)``,
        ``algo="rabenseifner"``, ``alpha_s=0`` == the seed Eq. 2
        ``search_strategy`` ranking, exactly.

    Two workload forms:

      - ``layers=`` + ``profile=``: one homogeneous per-layer profile (the
        v1 API) — emits plans with no ``segments`` (global knobs only);
      - ``model=`` (a ModelConfig): heterogeneous per-segment search — each
        model segment's (chunks, seq_parallel) is optimized against its
        per-kind comm profile (``cost_model.segment_workloads``) over the
        shared mesh, segment costs are summed, and the emitted plans carry
        one :class:`SegmentPlan` per segment.  For a single-dense-segment
        model this selects the identical strategy as the v1 form with
        ``profile=LayerCommProfile.dense(model)`` (the parity pin).

    ``calibration`` accepts a :class:`CalibrationTable` or a seed-style
    ``{(d1,d2): (B1,B2)}`` dict; measured bandwidths (and measured per-step
    latencies, when the table has them) override Eq. 3/4 for the
    factorizations they cover and the winning plan carries the table.
    ``boundary_mode`` forces psum/ring; by default it follows the
    calibration's measured preference (falling back to "psum").

    ``wire_dtype`` prices the boundary collectives at the quantized wire
    width ("int8"/"fp8" move 1 byte per element instead of
    ``bytes_per_elem``; quantized-collective bandwidths from the
    calibration table override Eq. 3/4 where measured), so quantization
    can flip the optimal (d1, d2)/chunks/boundary_mode — and the emitted
    plans carry the knob into execution.

    ``decode_batch`` (the serving slot count) additionally runs the
    latency-aware decode objective (``search_strategy_decode``) over the
    same strategy space and attaches its winner as a :class:`DecodePlan`
    to every emitted plan — decode boundary all-reduces on ``[B, 1, h]``
    activations are latency-bound, so the serve factorization may differ
    from the train/prefill one; ``ParallelPlan.decode_view`` is the
    execution side of that split.

    ``decode_paged_read`` (a :class:`cost_model.PagedReadModel`) adds the
    per-tick paged-attention KV read term — exposed under bursty psum
    boundaries, partially hidden behind a ring's pipelined transfers —
    which can flip the chosen decode mesh.  ``decode_accept_rate`` (the
    measured/expected MTP draft acceptance rate) makes the search price
    self-speculative decode per candidate; when it wins, the emitted
    DecodePlan records ``speculate=True``.  ``decode_prefix_cache``
    stamps the admission-time COW prefix sharing knob onto the sub-plan
    (an admission policy, not a per-mesh cost).
    """
    hm, preset = _resolve_matrix(matrix)
    calibration = CalibrationTable.coerce(calibration)
    if model is None and (layers is None or profile is None):
        raise TypeError("plan_search needs layers= + profile=, or model=")

    if model is not None:
        workloads = segment_workloads(model)
        res = search_strategy_segments(
            hm, tp_degree, workloads=workloads, batch=batch, seq=seq,
            bytes_per_elem=bytes_per_elem, chunks_options=chunks_options,
            seq_parallel_options=seq_parallel_options,
            peak_tflops=peak_tflops, algo=algo, alpha_s=alpha_s,
            calibration=calibration, wire_dtype=wire_dtype)
        workload_tag = (f"model={model.name} "
                        f"segments={'+'.join(f'{w.kind}x{w.layers}' for w in workloads)} "
                        f"batch={batch} seq={seq} bytes={bytes_per_elem}")
    else:
        res = search_strategy_overlap(
            hm, tp_degree, layers=layers, batch=batch, seq=seq,
            profile=profile, bytes_per_elem=bytes_per_elem,
            chunks_options=chunks_options,
            seq_parallel_options=seq_parallel_options,
            peak_tflops=peak_tflops, algo=algo, alpha_s=alpha_s,
            calibration=calibration, wire_dtype=wire_dtype)
        workload_tag = (f"layers={layers} batch={batch} seq={seq} "
                        f"bytes={bytes_per_elem}")

    decode_plan = None
    if decode_batch is not None:
        dworkloads = (segment_workloads(model) if model is not None else
                      (SegmentWorkload(kind="dense", layers=layers,
                                       profile=profile),))
        dres = search_strategy_decode(
            hm, tp_degree, workloads=dworkloads, batch=decode_batch,
            bytes_per_elem=bytes_per_elem, alpha_s=decode_alpha_s,
            launch_s=decode_launch_s, calibration=calibration,
            boundary_mode=boundary_mode, wire_dtype=wire_dtype,
            paged_read=decode_paged_read,
            spec_accept_rate=decode_accept_rate)
        decode_plan = DecodePlan(
            d1=dres.best.d1, d2=dres.best.d2,
            boundary_mode=dres.best.boundary_mode,
            wire_dtype=wire_dtype,
            speculate=getattr(dres.best, "speculate", False),
            prefix_cache=decode_prefix_cache,
            predicted_t_step=dres.best.t_step)

    prov = (
        ("searcher", "plan_search"),
        ("matrix", hm.name),
        ("algo", algo),
        ("alpha_s", repr(alpha_s)),
        ("peak_tflops", repr(peak_tflops)),
        ("workload", workload_tag),
        ("calibrated", "yes" if calibration is not None else "no"),
    )
    if wire_dtype != "bf16":
        prov += (("wire_dtype", wire_dtype),)
    if decode_plan is not None:
        extras = ""
        if decode_plan.speculate:
            extras += f" +spec(accept={decode_accept_rate})"
        if decode_plan.prefix_cache:
            extras += " +prefix_cache"
        if decode_paged_read is not None:
            extras += " +paged_read"
        prov += (("decode",
                  f"objective=serve batch={decode_batch} -> "
                  f"DeviceMesh({decode_plan.d1},{decode_plan.d2}) "
                  f"{decode_plan.boundary_mode}{extras}"),)

    def boundary_for(d1: int, d2: int) -> str:
        bm = boundary_mode
        if bm is None and calibration is not None:
            bm = calibration.boundary_mode(d1, d2)
        return bm or "psum"

    def to_plan(c) -> ParallelPlan:
        """c: OverlapStrategyCost (v1) or SegmentedStrategyCost (model=);
        both expose d1/d2/chunks/seq_parallel/t_* with the same meaning
        (segmented summary knobs are the dominant segment's)."""
        bm = boundary_for(c.d1, c.d2)
        segs = ()
        if model is not None:
            segs = tuple(SegmentPlan(
                kind=s.kind, chunks=s.chunks, boundary_mode=bm,
                seq_parallel=s.seq_parallel,
                wire_dtype=wire_dtype) for s in c.segments)
        return ParallelPlan(
            d1=c.d1, d2=c.d2, dp=dp, pods=pods, chunks=c.chunks,
            boundary_mode=bm, seq_parallel=c.seq_parallel,
            wire_dtype=wire_dtype, segments=segs,
            decode=decode_plan, topology=preset, calibration=calibration,
            predicted=PredictedCost(t_comm=c.t_comm, t_exposed=c.t_exposed,
                                    t_gemm=c.t_gemm),
            provenance=prov)

    ranked = tuple(to_plan(c) for c in res.ranked)
    return PlanSearchResult(best=ranked[0], ranked=ranked, costs=res.ranked)


def replan_elastic(
    plan: ParallelPlan,
    n_devices: int,
    *,
    layers: int | None = None,
    batch: int | None = None,
    seq: int | None = None,
    profile: LayerCommProfile | None = None,
    model=None,
) -> ParallelPlan:
    """Derive a plan for a surviving device pool (elastic restart).

    Data-parallel replicas absorb the loss first (they are fungible); the
    TP degree is halved only when even dp=1 no longer fits.  dp never
    *grows* past the original plan's dp*pods — a re-plan may only shrink
    the job, not silently expand it onto devices the user never asked
    for.  When the workload is known (``layers``+``profile``, or
    ``model``) and the plan records its topology preset, the surviving TP
    degree is re-searched from scratch; otherwise the mesh is
    re-factorized arithmetically and every other knob is kept.  The
    result records the resize in its provenance.

    The calibration table is *kept* across a TP-degree change — its
    measurements may still cover surviving factorizations — but the plan
    is tagged ``calibration: stale`` (visible in ``describe()`` and via
    ``calibration_stale``), so a consumer knows the numbers predate the
    resize and can re-run ``calibrate_mesh`` on the surviving mesh.  A
    plan whose provenance records a ``calibrate.recalibrate_surviving``
    pass for the surviving degree (and whose table ``covers_tp`` it) is
    not tagged: the re-search below then ranks with fresh measurements.
    Key coverage alone is deliberately not trusted — an external table
    may legitimately key several TP degrees without any of them having
    been measured on *this* surviving mesh.
    """
    if n_devices < 1:
        raise ValueError("no surviving devices to re-plan onto")
    tp = surviving_tp(plan.tp, n_devices)
    dp = max(1, min(plan.dp * plan.pods, n_devices // tp))
    tag = ("elastic", f"replanned {plan.devices}->{n_devices} devices")
    # a carried table goes (or stays) stale when the TP degree changed,
    # unless it has been recalibrated for the surviving degree
    recalibrated = (
        plan.calibration is not None and plan.calibration.covers_tp(tp)
        and any(k == "calibration"
                and v.startswith(f"recalibrated tp={tp} ")
                for k, v in plan.provenance))
    now_stale = plan.calibration is not None and not recalibrated and (
        tp != plan.tp or plan.calibration_stale)
    stale_tags = ((("calibration", "stale"),)
                  if now_stale and not plan.calibration_stale else ())
    workload_known = (model is not None and None not in (batch, seq)) or \
        None not in (layers, batch, seq, profile)
    if workload_known and plan.topology is not None:
        res = plan_search(
            plan.topology, tp, layers=layers, batch=batch, seq=seq,
            profile=profile, model=model, dp=dp,
            calibration=plan.calibration)
        best = res.best
        fresh_stale = ((("calibration", "stale"),) if now_stale else ())
        # re-searched provenance is fresh; keep the audit trail of any
        # recalibration tags the incoming plan carried
        carried = tuple(p for p in plan.provenance
                        if p[0] == "calibration" and p[1] != "stale")
        return best.with_(
            provenance=best.provenance + (tag,) + carried + fresh_stale)
    if tp == plan.tp:
        return plan.with_(dp=dp, pods=1,
                          provenance=plan.provenance + (tag,))
    import math as _math

    d1 = _math.gcd(plan.d1, tp)
    return plan.with_(d1=d1, d2=tp // d1, dp=dp, pods=1,
                      provenance=plan.provenance + (tag,) + stale_tags)
