"""Hierarchical communication matrix (paper §3.4).

A topology is described as an ordered list of layers, outermost (layer 1)
first.  Each layer has R ranks (sub-groups at that level), a P2P bandwidth
(aggregate GB/s between two ranks of the layer) and a *group bandwidth*
(aggregate GB/s from one rank-group to the rest of the world).

Effective all-reduce link bandwidth for a group of ``k`` ranks inside one
layer follows the paper's correction rule: the ring algorithm on k of R
ranks cannot exceed ``p2p * (k - 1)`` (a 2-rank group only has one peer
link), capped by the group bandwidth:

    eff(layer, k) = min(group_bw, p2p * (k - 1))      (k >= 2)

which reproduces both worked examples of Figure 7 (NVSwitch node: k=4 ->
600 GB/s; dual-GPU pair: k=2 -> 200 GB/s < 600 group).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class CommLayer:
    name: str
    ranks: int        # R_i sub-groups at this level
    p2p_bw: float     # GB/s between two ranks at this level
    group_bw: float   # GB/s one rank-group <-> everything else
    #: relative per-collective-step latency of a hop crossing this layer,
    #: as a multiple of the fabric's base alpha_s (NVLink-class hop = 1).
    #: Only the latency-bound decode objective reads this — the training
    #: cost model (Eq. 2-4) is bandwidth-bound and ignores it.
    alpha_factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class HierarchicalCommMatrix:
    """Layers ordered outermost -> innermost."""

    name: str
    layers: tuple[CommLayer, ...]

    @property
    def num_devices(self) -> int:
        return math.prod(l.ranks for l in self.layers)

    def effective_bw(self, layer: CommLayer, k: int) -> float:
        if k <= 1:
            return math.inf
        return min(layer.group_bw, layer.p2p_bw * (k - 1))

    def dim_layer_spans(self, d1: int, d2: int) -> tuple[list[tuple[int, int]], list[tuple[int, int]]]:
        """Assign mesh dims to layers: dim2 consumes innermost layers first.

        Returns, per dim, [(layer_index, k)] where k is the per-group rank
        factor the dim uses inside that layer.  A layer may be split between
        dims (k < R); `capacity[i]` tracks the unconsumed factor per layer.
        """
        n = self.num_devices
        if d1 * d2 > n:
            raise ValueError(f"mesh {d1}x{d2} larger than topology ({n})")
        capacity = [l.ranks for l in self.layers]

        def consume(need: int, spans: list[tuple[int, int]]):
            # innermost-first over layers with remaining capacity
            for i in range(len(self.layers) - 1, -1, -1):
                if need == 1:
                    break
                if capacity[i] == 1:
                    continue
                k = min(need, capacity[i])
                if capacity[i] % k:
                    k = math.gcd(need, capacity[i])
                    if k == 1:
                        continue
                spans.append((i, k))
                capacity[i] //= k
                need //= k
            if need != 1:
                raise ValueError(
                    f"mesh dim does not embed into topology {self.name}"
                )

        spans2: list[tuple[int, int]] = []
        consume(d2, spans2)
        spans1: list[tuple[int, int]] = []
        consume(d1, spans1)
        return spans1, spans2

    def axis_bandwidths(self, d1: int, d2: int) -> tuple[float, float]:
        """Paper Eq. 3: (B1', B2') raw link bandwidths for the two mesh dims.

        Sharing rule (generalizes the paper's "divide by d2"): when a dim
        spans layer j, every rank of layer j is a subtree; the groups of
        *this* dim whose members live inside one subtree all share that
        subtree's uplinks.  Their count is the product of the *other* dim's
        per-layer factors at layers strictly inner than j.  This reproduces
        the paper's worked examples: Fig. 7a DeviceMesh(8,2) -> B1'=12.5,
        B2'=200; flat IB-16 DeviceMesh(8,2) -> B1'=25 (no sharing, each
        device has its own port); IC6 4x4 torus (4,4) -> B1'=B2'=50.
        """
        spans1, spans2 = self.dim_layer_spans(d1, d2)

        def dim_bw(own: list[tuple[int, int]], other: list[tuple[int, int]]) -> float:
            best = math.inf
            for j, k in own:
                share = math.prod(k2 for i2, k2 in other if i2 > j)
                best = min(best, self.effective_bw(self.layers[j], k) / share)
            return best

        b1 = dim_bw(spans1, spans2)
        b2 = dim_bw(spans2, spans1)
        return b1, b2

    def axis_alpha_factors(self, d1: int, d2: int) -> tuple[float, float]:
        """Per-mesh-dim step-latency multipliers (decode objective).

        Every collective step on a dim pays the latency of the *slowest*
        layer the dim spans (a ring/butterfly step crossing a socket or IB
        hop cannot be faster than that hop), so each dim's factor is the
        max ``alpha_factor`` over its spanned layers; a singleton dim has
        no collectives and reports 1.0.
        """
        spans1, spans2 = self.dim_layer_spans(d1, d2)

        def dim_alpha(spans: list[tuple[int, int]]) -> float:
            if not spans:
                return 1.0
            return max(self.layers[j].alpha_factor for j, _ in spans)

        return dim_alpha(spans1), dim_alpha(spans2)


# ---------------------------------------------------------------------------
# Presets.  GPU presets reproduce the paper's IC1..IC6 analytically;
# TPU presets describe the deployment target of this repo.
# ---------------------------------------------------------------------------

def ic1_pcie_8gpu() -> HierarchicalCommMatrix:
    """Machine A with NVLink disabled (PCIe 4.0 tree, 2 sockets x 4 GPUs)."""
    return HierarchicalCommMatrix(
        "IC1-PCIe",
        (
            CommLayer("socket", 2, 16.0, 16.0, alpha_factor=8.0),  # QPI/GMI bridge
            CommLayer("pcie-switch", 2, 32.0, 32.0, alpha_factor=3.0),
            CommLayer("gpu", 2, 32.0, 32.0, alpha_factor=2.0),
        ),
    )


def ic2_dual_nvlink_8gpu() -> HierarchicalCommMatrix:
    """Machine B: 4 dual-GPU NVLink islands bridged by PCIe."""
    return HierarchicalCommMatrix(
        "IC2-dualNVLink",
        (
            CommLayer("pcie", 4, 32.0, 32.0, alpha_factor=3.0),
            CommLayer("nvlink-pair", 2, 200.0, 200.0),  # alpha_factor 1 (NVLink hop)
        ),
    )


def ic3_nvswitch_8gpu() -> HierarchicalCommMatrix:
    """Machine A: 8x A100 fully connected over NVSwitch (NVLink-v3)."""
    return HierarchicalCommMatrix(
        "IC3-NVSwitch",
        (CommLayer("nvswitch", 8, 200.0, 600.0),),
    )


def ic4_ib_cluster_16gpu() -> HierarchicalCommMatrix:
    """Cluster C: 16 GPUs, flat 200 Gbps InfiniBand (single layer)."""
    return HierarchicalCommMatrix(
        "IC4-IB",
        (CommLayer("ib", 16, 25.0, 25.0, alpha_factor=12.0),),
    )


def ic5_nvlink_network(n: int = 16) -> HierarchicalCommMatrix:
    """NVLink-Network Switch superpod: flat full-bandwidth fabric."""
    return HierarchicalCommMatrix(
        "IC5-NVLinkNet",
        (CommLayer("nvl-net", n, 450.0, 450.0),),
    )


def ic6_torus_2d(side: int = 4, link_gbps: float = 25.0) -> HierarchicalCommMatrix:
    """2D torus (Fig. 7b): rings of `side`, ring-of-rings above."""
    return HierarchicalCommMatrix(
        "IC6-2DTorus",
        (
            CommLayer("ring-of-rings", side, link_gbps * side,
                      2 * link_gbps * side, alpha_factor=2.0),
            CommLayer("ring", side, link_gbps, 2 * link_gbps),
        ),
    )


def tpu_v5e_pod(rows: int = 16, cols: int = 16, link_bw: float = 50.0) -> HierarchicalCommMatrix:
    """TPU v5e 16x16 pod, 2D torus ICI, ~50 GB/s per link per direction.

    Innermost layer: a torus row (ring of `cols`).  Outer layer: ring of
    rows; adjacent rows are joined by `cols` column links.
    """
    return HierarchicalCommMatrix(
        "TPUv5e-pod",
        (
            CommLayer("torus-rows", rows, link_bw * cols, 2 * link_bw * cols,
                      alpha_factor=2.0),
            CommLayer("torus-cols", cols, link_bw, 2 * link_bw),
        ),
    )


def tpu_multipod(pods: int = 2, dcn_bw: float = 100.0, **kw) -> HierarchicalCommMatrix:
    """Multi-pod: DCN layer above a v5e pod."""
    pod = tpu_v5e_pod(**kw)
    return HierarchicalCommMatrix(
        "TPUv5e-multipod",
        (CommLayer("dcn", pods, dcn_bw, dcn_bw, alpha_factor=40.0),)
        + pod.layers,
    )


PRESETS = {
    "ic1": ic1_pcie_8gpu,
    "ic2": ic2_dual_nvlink_8gpu,
    "ic3": ic3_nvswitch_8gpu,
    "ic4": ic4_ib_cluster_16gpu,
    "ic5": ic5_nvlink_network,
    "ic6": ic6_torus_2d,
    "v5e": tpu_v5e_pod,
    "v5e-multipod": tpu_multipod,
}
