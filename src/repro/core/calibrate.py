"""On-mesh calibration of the ATP cost model (paper §5.3).

The analytic hierarchical comm matrix (Eq. 3/4) predicts per-mesh-dim
algorithm bandwidths; §5.3 shows the prediction can be badly wrong on
messy fabrics (IC1: PCIe ACS/NUMA effects), and that re-ranking with
*measured* (B1, B2) recovers the right strategy.  This module produces
those measurements as a ``CalibrationTable``: for each (d1, d2)
factorization of the TP degree that fits the available devices, it
micro-benchmarks

  - the all-reduce over each mesh dim  -> effective algorithm bandwidths
    (B1, B2) in the seed convention (payload_bytes / measured_seconds),
    directly substitutable for Eq. 4's values in ``t_comm`` /
    ``t_comm_overlap``;
  - the psum vs explicit-ring boundary  -> preferred ``boundary_mode``.

Tables are plain data (JSON round-trippable) so a ``ParallelPlan`` can
carry them: a plan searched on one machine records exactly which measured
numbers drove the choice.  Measurement is injectable (``measure=``) so
tests and the cost-model path stay deterministic.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Mapping

from repro.core.comm_matrix import HierarchicalCommMatrix
from repro.core.mesh import atp_topo, factorizations


@dataclasses.dataclass(frozen=True)
class CalibEntry:
    """Measured numbers for one (d1, d2) factorization.

    b1 / b2 are *algorithm* bandwidths in GB/s (the seed ``calibration``
    convention: all-reduce time = payload_bytes / (B * 1e9)); inf means
    the dim is singleton.  t_psum / t_ring are measured seconds of one
    boundary all-reduce in each implementation (None when unmeasured).
    alpha_s is the measured per-collective-step latency in seconds (ring
    step convention: a d-rank all-reduce runs 2(d-1) steps), extracted
    from a latency-bound tiny-payload all-reduce; it feeds
    ``t_comm_overlap``'s ring-vs-Rabenseifner and chunk-count choices —
    chunking amortizes bandwidth but pays alpha per chunk, so a measured
    alpha is what keeps the search from over-chunking on real fabrics.

    chunk_eff holds the chunked-overlap *effective bandwidth* micro-
    benchmark (ROADMAP open item): tuples ``(chunks, eff1, eff2)`` where
    eff_i is the measured PURE-bandwidth efficiency of splitting one
    boundary all-reduce on mesh dim i into ``chunks`` back-to-back
    collectives of payload/chunks each —
    ``t_whole / (t_chunked - (chunks-1) * launch_s)``, 1.0 = free
    splitting.  The per-extra-chunk software launch cost is measured
    separately as ``launch_s`` (from the c=2 split: t_2 - t_whole) and
    charged additively by ``t_comm_overlap(chunk_launch_s=...)``; folding
    it into the bandwidth number — the pre-fix behavior — double-counted
    launch overhead against the alpha_s term.  A slow measured chunk path
    (either number) still steers the search back to chunks=1.

    ``provenance`` records where THIS entry's numbers came from:
    ``"measured"`` (on-mesh micro-benchmark), ``"carried"`` (copied from
    the pre-shrink table when a recovery deadline ran out before this
    factorization's turn), or ``"analytic"`` (Eq. 3/4 model values — the
    budget-exhausted fallback when there is nothing to carry).  Deadline-
    budgeted recovery (``recalibrate_surviving(deadline_s=...)``) is the
    writer; ``CalibrationTable.provenance_counts`` and
    ``ParallelPlan.describe`` surface it so a partially-calibrated
    recovery is visible in the artifact.

    b1_q / b2_q are the *quantized-collective* algorithm bandwidths: the
    same micro-benchmark run over the int8 wire
    (``overlap.quant_psum``), in the WIRE-byte convention — a quantized
    all-reduce of N elements takes ``N * 1 byte / (b_q * 1e9)`` seconds.
    They pair with ``t_comm_overlap(wire_dtype=..., calibrated=...)``:
    the search substitutes (b1_q, b2_q) for (b1, b2) when pricing a
    quantized plan, which is how measured quant/dequant overhead (or a
    fabric that accelerates small payloads sub-linearly) can flip the
    chosen factorization or chunk count.  None = unmeasured (the search
    falls back to the full-width bandwidths over the halved byte count).
    """

    b1: float
    b2: float
    t_psum: float | None = None
    t_ring: float | None = None
    alpha_s: float | None = None
    chunk_eff: tuple[tuple[int, float, float], ...] | None = None
    launch_s: float | None = None
    b1_q: float | None = None
    b2_q: float | None = None
    provenance: str = "measured"

    @property
    def boundary_mode(self) -> str | None:
        if self.t_psum is None or self.t_ring is None:
            return None
        return "ring" if self.t_ring < self.t_psum else "psum"

    def chunk_efficiency(self) -> dict[int, tuple[float, float]] | None:
        """{chunks: (eff1, eff2)} view for ``t_comm_overlap`` (None when
        the chunked micro-benchmark was not run)."""
        if self.chunk_eff is None:
            return None
        return {int(c): (e1, e2) for c, e1, e2 in self.chunk_eff}

    def to_dict(self) -> dict:
        return {"b1": _enc_inf(self.b1), "b2": _enc_inf(self.b2),
                "t_psum": self.t_psum, "t_ring": self.t_ring,
                "alpha_s": self.alpha_s,
                "chunk_eff": (None if self.chunk_eff is None
                              else [list(t) for t in self.chunk_eff]),
                "launch_s": self.launch_s,
                "b1_q": (None if self.b1_q is None else _enc_inf(self.b1_q)),
                "b2_q": (None if self.b2_q is None else _enc_inf(self.b2_q)),
                "provenance": self.provenance}

    @staticmethod
    def from_dict(d: Mapping) -> "CalibEntry":
        ce = d.get("chunk_eff")
        b1_q, b2_q = d.get("b1_q"), d.get("b2_q")
        return CalibEntry(b1=_dec_inf(d["b1"]), b2=_dec_inf(d["b2"]),
                          t_psum=d.get("t_psum"), t_ring=d.get("t_ring"),
                          alpha_s=d.get("alpha_s"),
                          chunk_eff=(None if ce is None else tuple(
                              (int(c), float(e1), float(e2))
                              for c, e1, e2 in ce)),
                          launch_s=d.get("launch_s"),
                          b1_q=(None if b1_q is None else _dec_inf(b1_q)),
                          b2_q=(None if b2_q is None else _dec_inf(b2_q)),
                          # absent in pre-v5 files: every entry was a
                          # real on-mesh measurement back then
                          provenance=d.get("provenance", "measured"))


def _enc_inf(v: float):
    return "inf" if math.isinf(v) else v


def _dec_inf(v) -> float:
    return math.inf if v == "inf" else float(v)


@dataclasses.dataclass(frozen=True)
class CalibrationTable:
    """Per-factorization measured entries; JSON round-trippable.

    ``source`` records where the numbers came from ("measured", "model",
    or a free-form label such as the paper's published IC1 values).
    """

    entries: tuple[tuple[tuple[int, int], CalibEntry], ...] = ()
    source: str = "measured"

    def get(self, d1: int, d2: int) -> CalibEntry | None:
        for (a, b), e in self.entries:
            if (a, b) == (d1, d2):
                return e
        return None

    def bandwidths(self, d1: int, d2: int) -> tuple[float, float] | None:
        e = self.get(d1, d2)
        return (e.b1, e.b2) if e is not None else None

    def boundary_mode(self, d1: int, d2: int) -> str | None:
        e = self.get(d1, d2)
        return e.boundary_mode if e is not None else None

    def alpha(self, d1: int, d2: int) -> float | None:
        """Measured per-step collective latency (None when unmeasured)."""
        e = self.get(d1, d2)
        return e.alpha_s if e is not None else None

    def chunk_efficiency(self, d1: int, d2: int) \
            -> dict[int, tuple[float, float]] | None:
        """Measured chunked-collective bandwidth efficiencies (or None)."""
        e = self.get(d1, d2)
        return e.chunk_efficiency() if e is not None else None

    def launch(self, d1: int, d2: int) -> float | None:
        """Measured per-extra-chunk launch cost (None when unmeasured)."""
        e = self.get(d1, d2)
        return e.launch_s if e is not None else None

    def quant_bandwidths(self, d1: int, d2: int) \
            -> tuple[float, float] | None:
        """Measured quantized-collective bandwidths (b1_q, b2_q) in the
        wire-byte convention, or None when the quantized micro-benchmark
        did not run for this factorization."""
        e = self.get(d1, d2)
        if e is None or (e.b1_q is None and e.b2_q is None):
            return None
        return (e.b1_q if e.b1_q is not None else e.b1,
                e.b2_q if e.b2_q is not None else e.b2)

    def provenance_counts(self) -> dict[str, int]:
        """Entry counts by provenance (measured / carried / analytic) —
        how calibrated this table actually is.  A deadline-budgeted
        recovery that ran out of time shows up here (and in
        ``ParallelPlan.describe``) instead of masquerading as fully
        measured."""
        out: dict[str, int] = {}
        for _, e in self.entries:
            out[e.provenance] = out.get(e.provenance, 0) + 1
        return out

    def covers_tp(self, tp_degree: int) -> bool:
        """True if any entry measures a factorization of ``tp_degree``.

        Necessary (not sufficient) evidence of a surviving-mesh
        recalibration: ``replan_elastic`` requires it together with the
        provenance tag ``recalibrate_surviving`` writes, since an
        external table may key several degrees without any having been
        measured on this mesh.
        """
        return any(d1 * d2 == tp_degree for (d1, d2), _ in self.entries)

    def merged(self, other: "CalibrationTable") -> "CalibrationTable":
        """This table with ``other``'s entries layered on top.

        ``other`` wins on key collisions — it is the *fresher* measurement
        (the elastic recalibration path merges surviving-mesh numbers into
        the carried table this way, keeping still-valid old keys around
        for audit).
        """
        d = dict(self.entries)
        d.update(dict(other.entries))
        source = (other.source if other.source == self.source
                  else f"{self.source}+{other.source}")
        return CalibrationTable(entries=tuple(sorted(d.items())),
                                source=source)

    def __len__(self) -> int:
        return len(self.entries)

    @staticmethod
    def from_pairs(pairs: Mapping[tuple[int, int], tuple[float, float]],
                   source: str = "external") -> "CalibrationTable":
        """Lift a seed-style {(d1,d2): (B1,B2)} dict into a table."""
        return CalibrationTable(
            entries=tuple(((d1, d2), CalibEntry(b1=b1, b2=b2))
                          for (d1, d2), (b1, b2) in sorted(pairs.items())),
            source=source)

    @staticmethod
    def coerce(calibration) -> "CalibrationTable | None":
        """Accept a table, a seed-style {(d1,d2): (B1,B2)} dict, or None —
        the one dispatch point for every calibration-taking API."""
        if calibration is None or isinstance(calibration, CalibrationTable):
            return calibration
        return CalibrationTable.from_pairs(calibration)

    def as_pairs(self) -> dict[tuple[int, int], tuple[float, float]]:
        """Seed-style {(d1,d2): (B1,B2)} view (for ``search_strategy``)."""
        return {(d1, d2): (e.b1, e.b2) for (d1, d2), e in self.entries}

    def to_dict(self) -> dict:
        return {"source": self.source,
                "entries": {f"{d1}x{d2}": e.to_dict()
                            for (d1, d2), e in self.entries}}

    @staticmethod
    def from_dict(d: Mapping) -> "CalibrationTable":
        entries = []
        for key, ed in d.get("entries", {}).items():
            d1, d2 = (int(p) for p in key.split("x"))
            entries.append(((d1, d2), CalibEntry.from_dict(ed)))
        return CalibrationTable(entries=tuple(sorted(entries)),
                                source=d.get("source", "measured"))


# ---------------------------------------------------------------------------
# On-mesh micro-benchmarks.
# ---------------------------------------------------------------------------


#: samples above this multiple of the raw median are treated as outliers
_TRIM_FACTOR = 2.5


def robust_seconds(samples) -> float:
    """Median-of-k with high-side outlier trimming.

    The pre-fix statistic was best-of-N (min) — robust against slow
    outliers but maximally credulous of FAST ones: a single spuriously
    quick sample (clock glitch, coalesced dispatch) becomes the measured
    time, inflates the derived bandwidth, and can flip ``plan_search``
    to a mesh the fabric cannot actually sustain (the ic1 pin in
    tests/test_robustness.py).  The median is robust on both sides as
    long as fewer than half the samples are outliers; samples more than
    ``_TRIM_FACTOR``x the raw median (stragglers: GC pause, scheduler
    preemption) are dropped first so they cannot drag the median of a
    small k either.
    """
    xs = sorted(float(s) for s in samples)
    if not xs:
        raise ValueError("no timing samples")
    med = xs[len(xs) // 2]
    kept = [x for x in xs if x <= _TRIM_FACTOR * med] or xs
    n = len(kept)
    return kept[n // 2] if n % 2 else 0.5 * (kept[n // 2 - 1] + kept[n // 2])


def _time_fn(fn, *args, repeats: int = 3,
             timer: Callable[[], float] = time.perf_counter,
             budget_s: float | None = None) -> float:
    """Robust wall time of a blocking call: up to ``repeats`` samples,
    stopping early once ``budget_s`` is spent (always at least one —
    a deadline bounds the repeat count k, never the truth of a sample),
    reduced by :func:`robust_seconds`."""
    import jax

    jax.block_until_ready(fn(*args))  # compile + warm up
    t_start = timer()
    samples = []
    for _ in range(max(1, repeats)):
        t0 = timer()
        jax.block_until_ready(fn(*args))
        samples.append(timer() - t0)
        if budget_s is not None and timer() - t_start >= budget_s:
            break
    return robust_seconds(samples)


def _measure_factorization(d1: int, d2: int, payload_bytes: int,
                           repeats: int, devices=None,
                           budget_s: float | None = None,
                           timer: Callable[[], float] = time.perf_counter
                           ) -> CalibEntry:
    """All-reduce timing over each TP mesh dim + psum-vs-ring boundary.

    ``budget_s`` (deadline-budgeted recovery) caps the wall time spent
    here: every inner timing loop sees the remaining budget and stops
    sampling once it is gone — k shrinks before coverage does, and the
    overrun is bounded by one sample per measurement kind.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.core import overlap
    from repro.core.compat import shard_map
    from repro.core.mesh import tp_axis_names

    topo = atp_topo(1, d1, d2)
    devices = devices if devices is not None else jax.devices()
    mesh = topo.build(devices[: topo.size])
    ax1, ax2 = tp_axis_names(topo)
    elems = max(1, payload_bytes // 4)
    t_begin = timer()

    def rem() -> float | None:
        if budget_s is None:
            return None
        return max(0.0, budget_s - (timer() - t_begin))

    def time_allreduce(axis: str, d: int, ring: bool = False,
                       n_elems: int | None = None,
                       quant: bool = False) -> float:
        x = jnp.ones((d, n_elems or elems), jnp.float32)
        if quant:
            red = lambda v: overlap.quant_psum(v, axis, "int8")  # noqa: E731
        elif ring:
            red = lambda v: overlap.ring_all_reduce(v, axis, d)  # noqa: E731
        else:
            red = lambda v: lax.psum(v, axis)  # noqa: E731
        f = jax.jit(shard_map(red, mesh=mesh, in_specs=P(axis),
                              out_specs=P(axis), check_vma=True))
        return _time_fn(f, x, repeats=repeats, budget_s=rem())

    def quant_bw(axis: str | None, d: int) -> float | None:
        """Quantized-collective bandwidth in the WIRE-byte convention:
        the int8 wire moves 1 byte per element, so b_q = elems / t — the
        number ``t_comm_overlap(wire_dtype="int8")`` divides its 1-byte
        volumes by.  Quant/dequant overhead lands in t, which is the
        point: a fabric (or emulation) where quantization does not pay
        shows up as b_q < b/2 and the search prices it honestly."""
        if axis is None:
            return None
        t = time_allreduce(axis, d, quant=True)
        return elems / t / 1e9 if t > 0.0 else None

    def alpha_from_tiny(axis: str, d: int) -> float:
        """Per-step latency: a 64-element all-reduce is latency-bound, so
        its wall time over the ring step count is alpha_s (ROADMAP open
        item — previously analytic-only)."""
        return max(0.0, time_allreduce(axis, d, n_elems=64)) / (2 * (d - 1))

    def time_chunked(axis: str, d: int, c: int) -> float:
        """One boundary payload split into c back-to-back collectives of
        payload/c each — the wire pattern the chunk-overlap engine issues
        per boundary (repro.core.atp._chunked_boundary_matmul)."""
        per = max(1, elems // c)
        x = jnp.ones((d, c, per), jnp.float32)

        def red(v):
            return jnp.stack([lax.psum(v[:, i], axis) for i in range(c)],
                             axis=1)

        f = jax.jit(shard_map(red, mesh=mesh, in_specs=P(axis),
                              out_specs=P(axis), check_vma=True))
        return _time_fn(f, x, repeats=repeats, budget_s=rem())

    def launch_axis(axis: str | None, d: int,
                    whole: float | None) -> float | None:
        """Per-extra-chunk software launch cost: the c=2 split issues
        exactly one extra collective, so t_2 - t_whole isolates it from
        the bandwidth term (the satellite fix for the chunk-eff
        double-count)."""
        if axis is None or whole is None or whole <= 0.0:
            return None
        return max(0.0, time_chunked(axis, d, 2) - whole)

    def chunk_eff_axis(axis: str | None, d: int, whole: float, c: int,
                       launch: float | None) -> float:
        """Measured PURE-bandwidth efficiency of splitting into c chunks
        on one axis (1.0 for singleton dims): the measured per-extra-chunk
        launch cost is subtracted from the chunked time first, so this
        number no longer double-counts what ``launch_s`` (and the alpha
        term) already charge."""
        if axis is None or whole is None or whole <= 0.0:
            return 1.0
        tc = time_chunked(axis, d, c) - (c - 1) * (launch or 0.0)
        return min(1.0, whole / tc) if tc > 0.0 else 1.0

    b1 = b2 = math.inf
    b1_q = b2_q = None
    t_psum = t_ring = alpha_s = None
    t1_whole = t2_whole = None
    if ax1 is not None:
        t_psum = time_allreduce(ax1, d1)
        t_ring = time_allreduce(ax1, d1, ring=True)
        b1 = payload_bytes / t_psum / 1e9
        alpha_s = alpha_from_tiny(ax1, d1)
        b1_q = quant_bw(ax1, d1)
        t1_whole = t_psum
        if ax2 is not None:
            t2_whole = time_allreduce(ax2, d2)
            b2 = payload_bytes / t2_whole / 1e9
            b2_q = quant_bw(ax2, d2)
            # one alpha serves every collective of this factorization —
            # keep the slower axis's latency (conservative: the cost model
            # must not over-chunk the slow axis on a two-level fabric)
            alpha_s = max(alpha_s, alpha_from_tiny(ax2, d2))
    elif ax2 is not None:
        # boundary collectives live on the only non-trivial dim here, so
        # the psum timing doubles as the b2 measurement
        t_psum = time_allreduce(ax2, d2)
        t_ring = time_allreduce(ax2, d2, ring=True)
        b2 = payload_bytes / t_psum / 1e9
        alpha_s = alpha_from_tiny(ax2, d2)
        b2_q = quant_bw(ax2, d2)
        t2_whole = t_psum
    launch1 = launch_axis(ax1, d1, t1_whole)
    launch2 = launch_axis(ax2, d2, t2_whole)
    launch_s = max((v for v in (launch1, launch2) if v is not None),
                   default=None)
    chunk_eff = tuple(
        (c,
         chunk_eff_axis(ax1, d1, t1_whole, c, launch1),
         chunk_eff_axis(ax2, d2, t2_whole, c, launch2))
        for c in (2, 4))
    return CalibEntry(b1=b1, b2=b2, t_psum=t_psum, t_ring=t_ring,
                      alpha_s=alpha_s, chunk_eff=chunk_eff,
                      launch_s=launch_s, b1_q=b1_q, b2_q=b2_q)


def calibrate_mesh(
    tp_degree: int,
    matrix: HierarchicalCommMatrix | None = None,
    *,
    payload_kb: int = 256,
    repeats: int = 3,
    measure: Callable[[int, int], CalibEntry] | None = None,
    devices=None,
) -> CalibrationTable:
    """Measure (B1, B2) + boundary latency for every runnable (d1, d2).

    ``matrix`` (optional) restricts the sweep to factorizations that embed
    into the modelled topology — the same filter the search applies — so
    the table's keys line up with the strategy space.  Factorizations
    needing more devices than are attached are skipped (the table is
    partial; the search falls back to the analytic model for missing
    keys).  ``measure`` overrides the on-mesh micro-benchmark with an
    arbitrary (d1, d2) -> CalibEntry function (tests, simulators).
    ``devices`` restricts the benchmark to a device subset (the elastic
    recovery path passes the surviving pool; default: all attached).
    """
    import jax

    devices = devices if devices is not None else jax.devices()
    ndev = len(devices)
    entries = []
    for d1, d2 in factorizations(tp_degree):
        if matrix is not None:
            try:
                matrix.axis_bandwidths(d1, d2)
            except ValueError:
                continue
        if measure is None and d1 * d2 > ndev:
            continue
        fn = measure or (lambda a, b: _measure_factorization(
            a, b, payload_kb * 1024, repeats, devices))
        entries.append(((d1, d2), fn(d1, d2)))
    return CalibrationTable(entries=tuple(entries), source="measured")


# ---------------------------------------------------------------------------
# Elastic recovery: recalibrate on the surviving mesh.
# ---------------------------------------------------------------------------


def surviving_tp(tp_degree: int, n_devices: int) -> int:
    """The TP degree an elastic shrink keeps on ``n_devices``.

    Mirrors ``plan.replan_elastic``: data-parallel replicas absorb device
    loss first, so TP only halves when even dp=1 no longer fits.
    """
    if n_devices < 1:
        raise ValueError("no surviving devices")
    tp = tp_degree
    while tp > n_devices:
        tp //= 2
    return tp


def analytic_entry(matrix: HierarchicalCommMatrix | None, d1: int,
                   d2: int) -> CalibEntry:
    """Eq. 3/4 model bandwidths lifted into a ``CalibEntry`` (provenance
    ``"analytic"``) — the budget-exhausted fallback when a recovery
    deadline leaves a factorization unmeasured and the carried table has
    nothing for it.  Only (b1, b2) are filled: the model has no opinion
    on boundary-mode timings or chunk efficiencies, and pretending it
    did would defeat the provenance record."""
    if matrix is None:
        return CalibEntry(b1=math.inf, b2=math.inf, provenance="analytic")
    from repro.core.cost_model import axis_algorithm_bw

    _, _, b1, b2 = axis_algorithm_bw(matrix, d1, d2)
    return CalibEntry(b1=b1, b2=b2, provenance="analytic")


def sensitivity_order(keys, matrix: HierarchicalCommMatrix | None, *,
                      model=None, batch: int | None = None,
                      seq: int | None = None) -> list[tuple[int, int]]:
    """Order factorization keys by descending cost-model sensitivity
    (``cost_model.factorization_sensitivity``): the entries whose
    bandwidth numbers move the strategy ranking most get measured first,
    so a recovery deadline degrades the *least important* entries to
    carried/analytic.  Without a matrix the natural order stands (there
    is no model to rank by); without a workload a generic dense block is
    assumed — the ordering across factorizations is dominated by the
    fabric's bandwidths, not the exact layer shape."""
    keys = list(keys)
    if matrix is None or len(keys) < 2:
        return keys
    from repro.core.cost_model import (LayerCommProfile, SegmentWorkload,
                                       factorization_sensitivity,
                                       segment_workloads)

    if model is not None:
        workloads = segment_workloads(model)
    else:
        workloads = (SegmentWorkload(kind="dense", layers=1,
                                     profile=LayerCommProfile.gpt(4096)),)
    b = batch if batch is not None else 8
    s = seq if seq is not None else 512
    return sorted(keys, key=lambda k: (-factorization_sensitivity(
        matrix, k[0], k[1], workloads=workloads, batch=b, seq=s), k))


def recalibrate_surviving(
    plan,
    devices=None,
    *,
    payload_kb: int = 256,
    repeats: int = 3,
    measure: Callable[[int, int], CalibEntry] | None = None,
    deadline_s: float | None = None,
    model=None,
    batch: int | None = None,
    seq: int | None = None,
    timer: Callable[[], float] = time.perf_counter,
):
    """Re-measure a plan's calibration on the surviving mesh (paper §5.3).

    After an elastic shrink the carried table is tagged
    ``calibration: stale`` — its (B1, B2)/alpha_s/boundary numbers were
    measured on a mesh the job no longer runs on, and §5.3 is exactly the
    story of how badly a mis-priced table can mis-rank strategies.  This
    re-runs the micro-benchmarks for every factorization of the
    *surviving* TP degree (``surviving_tp`` of the surviving pool), merges
    the fresh entries into the carried table (fresh keys win; old keys
    stay for audit), clears the stale tag and records the recalibration in
    provenance.  The returned plan is ready for ``replan_elastic``: the
    re-search ranks the surviving factorizations with fresh measurements
    and — because the provenance records this pass for the surviving
    degree (and the merged table covers its factorizations) — the
    re-planned artifact is not re-tagged stale.

    **Deadline budget** (``deadline_s``): recovery time is downtime, so
    instead of fixed repeat counts the micro-benchmarks spend a wall-
    clock budget — factorizations are visited in descending cost-model
    sensitivity (``sensitivity_order``, using ``model``/``batch``/``seq``
    when the caller knows the workload), each measurement's repeat count
    k shrinks as the budget drains (``_time_fn(budget_s=...)``), and once
    the budget is gone the remaining factorizations fall back to the
    carried table's entry (provenance ``"carried"``) or the analytic
    model (``"analytic"``).  The per-entry provenance rides the table,
    the plan's provenance records the budget spend, and the
    ``recalibrated tp=`` tag — what lets ``replan_elastic`` skip the
    stale tag — is only written when at least one entry was actually
    measured: a fully-exhausted budget yields a usable but honestly
    stale-tagged plan.

    ``plan`` is any ParallelPlan-shaped object (duck-typed to avoid a
    module cycle: plan.py imports this module).  ``measure`` injects the
    per-factorization benchmark (tests, simulators); ``devices`` is the
    surviving pool (default: all attached); ``timer`` injects the budget
    clock (tests script deterministic deadlines with it).
    """
    import jax

    from repro.core import comm_matrix

    devs = list(devices) if devices is not None else jax.devices()
    tp = surviving_tp(plan.tp, len(devs))
    matrix = None
    if plan.topology is not None:
        preset = comm_matrix.PRESETS.get(plan.topology)
        matrix = preset() if preset is not None else None
    keys = []
    for d1, d2 in factorizations(tp):
        if matrix is not None:
            try:
                matrix.axis_bandwidths(d1, d2)
            except ValueError:
                continue
        if measure is None and d1 * d2 > len(devs):
            continue
        keys.append((d1, d2))
    if deadline_s is not None:
        keys = sensitivity_order(keys, matrix, model=model, batch=batch,
                                 seq=seq)
    t0 = timer()
    entries = []
    counts = {"measured": 0, "carried": 0, "analytic": 0}
    # adaptive gate: once one factorization has been timed, a later one is
    # only measured if the remaining budget covers what the last one cost
    # — so the deadline is respected even through an injected ``measure``
    # that cannot see the budget (the real path additionally threads
    # budget_s down to every sampling loop).
    last_cost = 0.0
    for d1, d2 in keys:
        remaining = (None if deadline_s is None
                     else deadline_s - (timer() - t0))
        if remaining is not None and (remaining <= 0.0
                                      or remaining < last_cost):
            old = (plan.calibration.get(d1, d2)
                   if plan.calibration is not None else None)
            e = (dataclasses.replace(old, provenance="carried")
                 if old is not None else analytic_entry(matrix, d1, d2))
        else:
            t_meas = timer()
            if measure is not None:
                e = dataclasses.replace(measure(d1, d2),
                                        provenance="measured")
            else:
                e = dataclasses.replace(
                    _measure_factorization(d1, d2, payload_kb * 1024,
                                           repeats, devs,
                                           budget_s=remaining, timer=timer),
                    provenance="measured")
            last_cost = timer() - t_meas
        counts[e.provenance] += 1
        entries.append(((d1, d2), e))
    entries.sort()
    source = ("measured" if counts["measured"] == len(entries)
              else "deadline-budgeted")
    fresh = CalibrationTable(entries=tuple(entries), source=source)
    merged = fresh if plan.calibration is None \
        else plan.calibration.merged(fresh)
    prov = tuple(p for p in plan.provenance
                 if p != ("calibration", "stale"))
    if counts["measured"] > 0:
        prov += (("calibration",
                  f"recalibrated tp={tp} on {len(devs)} devices"),)
    if deadline_s is not None:
        spent = timer() - t0
        # key "calibration" so replan_elastic's re-search carries it
        prov += (("calibration",
                  f"budget deadline_s={deadline_s:g} spent_s={spent:.3f} "
                  f"measured={counts['measured']} "
                  f"carried={counts['carried']} "
                  f"analytic={counts['analytic']}"),)
    return plan.with_(calibration=merged, provenance=prov)
