"""On-mesh calibration of the ATP cost model (paper §5.3).

The analytic hierarchical comm matrix (Eq. 3/4) predicts per-mesh-dim
algorithm bandwidths; §5.3 shows the prediction can be badly wrong on
messy fabrics (IC1: PCIe ACS/NUMA effects), and that re-ranking with
*measured* (B1, B2) recovers the right strategy.  This module produces
those measurements as a ``CalibrationTable``: for each (d1, d2)
factorization of the TP degree that fits the available devices, it
micro-benchmarks

  - the all-reduce over each mesh dim  -> effective algorithm bandwidths
    (B1, B2) in the seed convention (payload_bytes / measured_seconds),
    directly substitutable for Eq. 4's values in ``t_comm`` /
    ``t_comm_overlap``;
  - the psum vs explicit-ring boundary  -> preferred ``boundary_mode``.

Tables are plain data (JSON round-trippable) so a ``ParallelPlan`` can
carry them: a plan searched on one machine records exactly which measured
numbers drove the choice.  Measurement is injectable (``measure=``) so
tests and the cost-model path stay deterministic.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Mapping

from repro.core.comm_matrix import HierarchicalCommMatrix
from repro.core.mesh import atp_topo, factorizations


@dataclasses.dataclass(frozen=True)
class CalibEntry:
    """Measured numbers for one (d1, d2) factorization.

    b1 / b2 are *algorithm* bandwidths in GB/s (the seed ``calibration``
    convention: all-reduce time = payload_bytes / (B * 1e9)); inf means
    the dim is singleton.  t_psum / t_ring are measured seconds of one
    boundary all-reduce in each implementation (None when unmeasured).
    alpha_s is the measured per-collective-step latency in seconds (ring
    step convention: a d-rank all-reduce runs 2(d-1) steps), extracted
    from a latency-bound tiny-payload all-reduce; it feeds
    ``t_comm_overlap``'s ring-vs-Rabenseifner and chunk-count choices —
    chunking amortizes bandwidth but pays alpha per chunk, so a measured
    alpha is what keeps the search from over-chunking on real fabrics.

    chunk_eff holds the chunked-overlap *effective bandwidth* micro-
    benchmark (ROADMAP open item): tuples ``(chunks, eff1, eff2)`` where
    eff_i is the measured PURE-bandwidth efficiency of splitting one
    boundary all-reduce on mesh dim i into ``chunks`` back-to-back
    collectives of payload/chunks each —
    ``t_whole / (t_chunked - (chunks-1) * launch_s)``, 1.0 = free
    splitting.  The per-extra-chunk software launch cost is measured
    separately as ``launch_s`` (from the c=2 split: t_2 - t_whole) and
    charged additively by ``t_comm_overlap(chunk_launch_s=...)``; folding
    it into the bandwidth number — the pre-fix behavior — double-counted
    launch overhead against the alpha_s term.  A slow measured chunk path
    (either number) still steers the search back to chunks=1.

    b1_q / b2_q are the *quantized-collective* algorithm bandwidths: the
    same micro-benchmark run over the int8 wire
    (``overlap.quant_psum``), in the WIRE-byte convention — a quantized
    all-reduce of N elements takes ``N * 1 byte / (b_q * 1e9)`` seconds.
    They pair with ``t_comm_overlap(wire_dtype=..., calibrated=...)``:
    the search substitutes (b1_q, b2_q) for (b1, b2) when pricing a
    quantized plan, which is how measured quant/dequant overhead (or a
    fabric that accelerates small payloads sub-linearly) can flip the
    chosen factorization or chunk count.  None = unmeasured (the search
    falls back to the full-width bandwidths over the halved byte count).
    """

    b1: float
    b2: float
    t_psum: float | None = None
    t_ring: float | None = None
    alpha_s: float | None = None
    chunk_eff: tuple[tuple[int, float, float], ...] | None = None
    launch_s: float | None = None
    b1_q: float | None = None
    b2_q: float | None = None

    @property
    def boundary_mode(self) -> str | None:
        if self.t_psum is None or self.t_ring is None:
            return None
        return "ring" if self.t_ring < self.t_psum else "psum"

    def chunk_efficiency(self) -> dict[int, tuple[float, float]] | None:
        """{chunks: (eff1, eff2)} view for ``t_comm_overlap`` (None when
        the chunked micro-benchmark was not run)."""
        if self.chunk_eff is None:
            return None
        return {int(c): (e1, e2) for c, e1, e2 in self.chunk_eff}

    def to_dict(self) -> dict:
        return {"b1": _enc_inf(self.b1), "b2": _enc_inf(self.b2),
                "t_psum": self.t_psum, "t_ring": self.t_ring,
                "alpha_s": self.alpha_s,
                "chunk_eff": (None if self.chunk_eff is None
                              else [list(t) for t in self.chunk_eff]),
                "launch_s": self.launch_s,
                "b1_q": (None if self.b1_q is None else _enc_inf(self.b1_q)),
                "b2_q": (None if self.b2_q is None else _enc_inf(self.b2_q))}

    @staticmethod
    def from_dict(d: Mapping) -> "CalibEntry":
        ce = d.get("chunk_eff")
        b1_q, b2_q = d.get("b1_q"), d.get("b2_q")
        return CalibEntry(b1=_dec_inf(d["b1"]), b2=_dec_inf(d["b2"]),
                          t_psum=d.get("t_psum"), t_ring=d.get("t_ring"),
                          alpha_s=d.get("alpha_s"),
                          chunk_eff=(None if ce is None else tuple(
                              (int(c), float(e1), float(e2))
                              for c, e1, e2 in ce)),
                          launch_s=d.get("launch_s"),
                          b1_q=(None if b1_q is None else _dec_inf(b1_q)),
                          b2_q=(None if b2_q is None else _dec_inf(b2_q)))


def _enc_inf(v: float):
    return "inf" if math.isinf(v) else v


def _dec_inf(v) -> float:
    return math.inf if v == "inf" else float(v)


@dataclasses.dataclass(frozen=True)
class CalibrationTable:
    """Per-factorization measured entries; JSON round-trippable.

    ``source`` records where the numbers came from ("measured", "model",
    or a free-form label such as the paper's published IC1 values).
    """

    entries: tuple[tuple[tuple[int, int], CalibEntry], ...] = ()
    source: str = "measured"

    def get(self, d1: int, d2: int) -> CalibEntry | None:
        for (a, b), e in self.entries:
            if (a, b) == (d1, d2):
                return e
        return None

    def bandwidths(self, d1: int, d2: int) -> tuple[float, float] | None:
        e = self.get(d1, d2)
        return (e.b1, e.b2) if e is not None else None

    def boundary_mode(self, d1: int, d2: int) -> str | None:
        e = self.get(d1, d2)
        return e.boundary_mode if e is not None else None

    def alpha(self, d1: int, d2: int) -> float | None:
        """Measured per-step collective latency (None when unmeasured)."""
        e = self.get(d1, d2)
        return e.alpha_s if e is not None else None

    def chunk_efficiency(self, d1: int, d2: int) \
            -> dict[int, tuple[float, float]] | None:
        """Measured chunked-collective bandwidth efficiencies (or None)."""
        e = self.get(d1, d2)
        return e.chunk_efficiency() if e is not None else None

    def launch(self, d1: int, d2: int) -> float | None:
        """Measured per-extra-chunk launch cost (None when unmeasured)."""
        e = self.get(d1, d2)
        return e.launch_s if e is not None else None

    def quant_bandwidths(self, d1: int, d2: int) \
            -> tuple[float, float] | None:
        """Measured quantized-collective bandwidths (b1_q, b2_q) in the
        wire-byte convention, or None when the quantized micro-benchmark
        did not run for this factorization."""
        e = self.get(d1, d2)
        if e is None or (e.b1_q is None and e.b2_q is None):
            return None
        return (e.b1_q if e.b1_q is not None else e.b1,
                e.b2_q if e.b2_q is not None else e.b2)

    def covers_tp(self, tp_degree: int) -> bool:
        """True if any entry measures a factorization of ``tp_degree``.

        Necessary (not sufficient) evidence of a surviving-mesh
        recalibration: ``replan_elastic`` requires it together with the
        provenance tag ``recalibrate_surviving`` writes, since an
        external table may key several degrees without any having been
        measured on this mesh.
        """
        return any(d1 * d2 == tp_degree for (d1, d2), _ in self.entries)

    def merged(self, other: "CalibrationTable") -> "CalibrationTable":
        """This table with ``other``'s entries layered on top.

        ``other`` wins on key collisions — it is the *fresher* measurement
        (the elastic recalibration path merges surviving-mesh numbers into
        the carried table this way, keeping still-valid old keys around
        for audit).
        """
        d = dict(self.entries)
        d.update(dict(other.entries))
        source = (other.source if other.source == self.source
                  else f"{self.source}+{other.source}")
        return CalibrationTable(entries=tuple(sorted(d.items())),
                                source=source)

    def __len__(self) -> int:
        return len(self.entries)

    @staticmethod
    def from_pairs(pairs: Mapping[tuple[int, int], tuple[float, float]],
                   source: str = "external") -> "CalibrationTable":
        """Lift a seed-style {(d1,d2): (B1,B2)} dict into a table."""
        return CalibrationTable(
            entries=tuple(((d1, d2), CalibEntry(b1=b1, b2=b2))
                          for (d1, d2), (b1, b2) in sorted(pairs.items())),
            source=source)

    @staticmethod
    def coerce(calibration) -> "CalibrationTable | None":
        """Accept a table, a seed-style {(d1,d2): (B1,B2)} dict, or None —
        the one dispatch point for every calibration-taking API."""
        if calibration is None or isinstance(calibration, CalibrationTable):
            return calibration
        return CalibrationTable.from_pairs(calibration)

    def as_pairs(self) -> dict[tuple[int, int], tuple[float, float]]:
        """Seed-style {(d1,d2): (B1,B2)} view (for ``search_strategy``)."""
        return {(d1, d2): (e.b1, e.b2) for (d1, d2), e in self.entries}

    def to_dict(self) -> dict:
        return {"source": self.source,
                "entries": {f"{d1}x{d2}": e.to_dict()
                            for (d1, d2), e in self.entries}}

    @staticmethod
    def from_dict(d: Mapping) -> "CalibrationTable":
        entries = []
        for key, ed in d.get("entries", {}).items():
            d1, d2 = (int(p) for p in key.split("x"))
            entries.append(((d1, d2), CalibEntry.from_dict(ed)))
        return CalibrationTable(entries=tuple(sorted(entries)),
                                source=d.get("source", "measured"))


# ---------------------------------------------------------------------------
# On-mesh micro-benchmarks.
# ---------------------------------------------------------------------------


def _time_fn(fn, *args, repeats: int = 3,
             timer: Callable[[], float] = time.perf_counter) -> float:
    """Best-of-N wall time of a blocking call (min filters scheduler noise)."""
    import jax

    jax.block_until_ready(fn(*args))  # compile + warm up
    best = math.inf
    for _ in range(max(1, repeats)):
        t0 = timer()
        jax.block_until_ready(fn(*args))
        best = min(best, timer() - t0)
    return best


def _measure_factorization(d1: int, d2: int, payload_bytes: int,
                           repeats: int, devices=None) -> CalibEntry:
    """All-reduce timing over each TP mesh dim + psum-vs-ring boundary."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.core import overlap
    from repro.core.compat import shard_map
    from repro.core.mesh import tp_axis_names

    topo = atp_topo(1, d1, d2)
    devices = devices if devices is not None else jax.devices()
    mesh = topo.build(devices[: topo.size])
    ax1, ax2 = tp_axis_names(topo)
    elems = max(1, payload_bytes // 4)

    def time_allreduce(axis: str, d: int, ring: bool = False,
                       n_elems: int | None = None,
                       quant: bool = False) -> float:
        x = jnp.ones((d, n_elems or elems), jnp.float32)
        if quant:
            red = lambda v: overlap.quant_psum(v, axis, "int8")  # noqa: E731
        elif ring:
            red = lambda v: overlap.ring_all_reduce(v, axis, d)  # noqa: E731
        else:
            red = lambda v: lax.psum(v, axis)  # noqa: E731
        f = jax.jit(shard_map(red, mesh=mesh, in_specs=P(axis),
                              out_specs=P(axis), check_vma=True))
        return _time_fn(f, x, repeats=repeats)

    def quant_bw(axis: str | None, d: int) -> float | None:
        """Quantized-collective bandwidth in the WIRE-byte convention:
        the int8 wire moves 1 byte per element, so b_q = elems / t — the
        number ``t_comm_overlap(wire_dtype="int8")`` divides its 1-byte
        volumes by.  Quant/dequant overhead lands in t, which is the
        point: a fabric (or emulation) where quantization does not pay
        shows up as b_q < b/2 and the search prices it honestly."""
        if axis is None:
            return None
        t = time_allreduce(axis, d, quant=True)
        return elems / t / 1e9 if t > 0.0 else None

    def alpha_from_tiny(axis: str, d: int) -> float:
        """Per-step latency: a 64-element all-reduce is latency-bound, so
        its wall time over the ring step count is alpha_s (ROADMAP open
        item — previously analytic-only)."""
        return max(0.0, time_allreduce(axis, d, n_elems=64)) / (2 * (d - 1))

    def time_chunked(axis: str, d: int, c: int) -> float:
        """One boundary payload split into c back-to-back collectives of
        payload/c each — the wire pattern the chunk-overlap engine issues
        per boundary (repro.core.atp._chunked_boundary_matmul)."""
        per = max(1, elems // c)
        x = jnp.ones((d, c, per), jnp.float32)

        def red(v):
            return jnp.stack([lax.psum(v[:, i], axis) for i in range(c)],
                             axis=1)

        f = jax.jit(shard_map(red, mesh=mesh, in_specs=P(axis),
                              out_specs=P(axis), check_vma=True))
        return _time_fn(f, x, repeats=repeats)

    def launch_axis(axis: str | None, d: int,
                    whole: float | None) -> float | None:
        """Per-extra-chunk software launch cost: the c=2 split issues
        exactly one extra collective, so t_2 - t_whole isolates it from
        the bandwidth term (the satellite fix for the chunk-eff
        double-count)."""
        if axis is None or whole is None or whole <= 0.0:
            return None
        return max(0.0, time_chunked(axis, d, 2) - whole)

    def chunk_eff_axis(axis: str | None, d: int, whole: float, c: int,
                       launch: float | None) -> float:
        """Measured PURE-bandwidth efficiency of splitting into c chunks
        on one axis (1.0 for singleton dims): the measured per-extra-chunk
        launch cost is subtracted from the chunked time first, so this
        number no longer double-counts what ``launch_s`` (and the alpha
        term) already charge."""
        if axis is None or whole is None or whole <= 0.0:
            return 1.0
        tc = time_chunked(axis, d, c) - (c - 1) * (launch or 0.0)
        return min(1.0, whole / tc) if tc > 0.0 else 1.0

    b1 = b2 = math.inf
    b1_q = b2_q = None
    t_psum = t_ring = alpha_s = None
    t1_whole = t2_whole = None
    if ax1 is not None:
        t_psum = time_allreduce(ax1, d1)
        t_ring = time_allreduce(ax1, d1, ring=True)
        b1 = payload_bytes / t_psum / 1e9
        alpha_s = alpha_from_tiny(ax1, d1)
        b1_q = quant_bw(ax1, d1)
        t1_whole = t_psum
        if ax2 is not None:
            t2_whole = time_allreduce(ax2, d2)
            b2 = payload_bytes / t2_whole / 1e9
            b2_q = quant_bw(ax2, d2)
            # one alpha serves every collective of this factorization —
            # keep the slower axis's latency (conservative: the cost model
            # must not over-chunk the slow axis on a two-level fabric)
            alpha_s = max(alpha_s, alpha_from_tiny(ax2, d2))
    elif ax2 is not None:
        # boundary collectives live on the only non-trivial dim here, so
        # the psum timing doubles as the b2 measurement
        t_psum = time_allreduce(ax2, d2)
        t_ring = time_allreduce(ax2, d2, ring=True)
        b2 = payload_bytes / t_psum / 1e9
        alpha_s = alpha_from_tiny(ax2, d2)
        b2_q = quant_bw(ax2, d2)
        t2_whole = t_psum
    launch1 = launch_axis(ax1, d1, t1_whole)
    launch2 = launch_axis(ax2, d2, t2_whole)
    launch_s = max((v for v in (launch1, launch2) if v is not None),
                   default=None)
    chunk_eff = tuple(
        (c,
         chunk_eff_axis(ax1, d1, t1_whole, c, launch1),
         chunk_eff_axis(ax2, d2, t2_whole, c, launch2))
        for c in (2, 4))
    return CalibEntry(b1=b1, b2=b2, t_psum=t_psum, t_ring=t_ring,
                      alpha_s=alpha_s, chunk_eff=chunk_eff,
                      launch_s=launch_s, b1_q=b1_q, b2_q=b2_q)


def calibrate_mesh(
    tp_degree: int,
    matrix: HierarchicalCommMatrix | None = None,
    *,
    payload_kb: int = 256,
    repeats: int = 3,
    measure: Callable[[int, int], CalibEntry] | None = None,
    devices=None,
) -> CalibrationTable:
    """Measure (B1, B2) + boundary latency for every runnable (d1, d2).

    ``matrix`` (optional) restricts the sweep to factorizations that embed
    into the modelled topology — the same filter the search applies — so
    the table's keys line up with the strategy space.  Factorizations
    needing more devices than are attached are skipped (the table is
    partial; the search falls back to the analytic model for missing
    keys).  ``measure`` overrides the on-mesh micro-benchmark with an
    arbitrary (d1, d2) -> CalibEntry function (tests, simulators).
    ``devices`` restricts the benchmark to a device subset (the elastic
    recovery path passes the surviving pool; default: all attached).
    """
    import jax

    devices = devices if devices is not None else jax.devices()
    ndev = len(devices)
    entries = []
    for d1, d2 in factorizations(tp_degree):
        if matrix is not None:
            try:
                matrix.axis_bandwidths(d1, d2)
            except ValueError:
                continue
        if measure is None and d1 * d2 > ndev:
            continue
        fn = measure or (lambda a, b: _measure_factorization(
            a, b, payload_kb * 1024, repeats, devices))
        entries.append(((d1, d2), fn(d1, d2)))
    return CalibrationTable(entries=tuple(entries), source="measured")


# ---------------------------------------------------------------------------
# Elastic recovery: recalibrate on the surviving mesh.
# ---------------------------------------------------------------------------


def surviving_tp(tp_degree: int, n_devices: int) -> int:
    """The TP degree an elastic shrink keeps on ``n_devices``.

    Mirrors ``plan.replan_elastic``: data-parallel replicas absorb device
    loss first, so TP only halves when even dp=1 no longer fits.
    """
    if n_devices < 1:
        raise ValueError("no surviving devices")
    tp = tp_degree
    while tp > n_devices:
        tp //= 2
    return tp


def recalibrate_surviving(
    plan,
    devices=None,
    *,
    payload_kb: int = 256,
    repeats: int = 3,
    measure: Callable[[int, int], CalibEntry] | None = None,
):
    """Re-measure a plan's calibration on the surviving mesh (paper §5.3).

    After an elastic shrink the carried table is tagged
    ``calibration: stale`` — its (B1, B2)/alpha_s/boundary numbers were
    measured on a mesh the job no longer runs on, and §5.3 is exactly the
    story of how badly a mis-priced table can mis-rank strategies.  This
    re-runs the micro-benchmarks for every factorization of the
    *surviving* TP degree (``surviving_tp`` of the surviving pool), merges
    the fresh entries into the carried table (fresh keys win; old keys
    stay for audit), clears the stale tag and records the recalibration in
    provenance.  The returned plan is ready for ``replan_elastic``: the
    re-search ranks the surviving factorizations with fresh measurements
    and — because the provenance records this pass for the surviving
    degree (and the merged table covers its factorizations) — the
    re-planned artifact is not re-tagged stale.

    ``plan`` is any ParallelPlan-shaped object (duck-typed to avoid a
    module cycle: plan.py imports this module).  ``measure`` injects the
    per-factorization benchmark (tests, simulators); ``devices`` is the
    surviving pool (default: all attached).
    """
    import jax

    from repro.core import comm_matrix

    devs = list(devices) if devices is not None else jax.devices()
    tp = surviving_tp(plan.tp, len(devs))
    matrix = None
    if plan.topology is not None:
        preset = comm_matrix.PRESETS.get(plan.topology)
        matrix = preset() if preset is not None else None
    fresh = calibrate_mesh(tp, matrix, payload_kb=payload_kb,
                           repeats=repeats, measure=measure, devices=devs)
    merged = fresh if plan.calibration is None \
        else plan.calibration.merged(fresh)
    prov = tuple(p for p in plan.provenance
                 if p != ("calibration", "stale"))
    prov += (("calibration",
              f"recalibrated tp={tp} on {len(devs)} devices"),)
    return plan.with_(calibration=merged, provenance=prov)
