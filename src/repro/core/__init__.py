# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# The strategy stack's public surface (docs/strategy.md):
#   plan.ParallelPlan / plan.plan_search — the one serializable strategy
#   calibrate.calibrate_mesh            — measured (B1,B2) + boundary mode
#   calibrate.recalibrate_surviving     — fresh table on the surviving mesh
#   atp.make_context(plan=...)          — plan -> execution context

from repro.core.atp import SegmentPlan  # noqa: F401
from repro.core.calibrate import (CalibrationTable, calibrate_mesh,  # noqa: F401
                                  recalibrate_surviving)
from repro.core.plan import (ParallelPlan, plan_search,  # noqa: F401
                             replan_elastic)
