"""Device-mesh abstractions for ATP.

The paper factorizes the tensor-parallel degree N into a 2D device mesh
(d1, d2).  On top of that, a real training job adds data-parallel and
(multi-pod) pod axes.  We keep the *logical* mesh description separate from
the jax.sharding.Mesh so the strategy search can enumerate factorizations
without touching device state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import numpy as np

from repro.core import compat

# Canonical axis names used throughout the framework.
AXIS_POD = "pod"      # across pods (DCN)
AXIS_DATA = "data"    # data parallel (within pod)
AXIS_TP1 = "tp1"      # first dim of the ATP 2D device mesh (d1)
AXIS_TP2 = "tp2"      # second dim of the ATP 2D device mesh (d2)
# The required production mesh uses a single "model" axis == ATP (N, 1).
AXIS_MODEL = "model"


def factorizations(n: int) -> list[tuple[int, int]]:
    """All (d1, d2) with d1 * d2 == n, d1 and d2 >= 1.

    For n == 2**k this gives the paper's k+1 meshes.
    """
    out = []
    for d1 in range(1, n + 1):
        if n % d1 == 0:
            out.append((d1, n // d1))
    return out


@dataclasses.dataclass(frozen=True)
class MeshTopo:
    """Logical mesh: ordered (axis_name, size) pairs."""

    axes: tuple[tuple[str, int], ...]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(a for a, _ in self.axes)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(s for _, s in self.axes)

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    def axis_size(self, name: str) -> int:
        for a, s in self.axes:
            if a == name:
                return s
        return 1  # absent axes behave as singleton

    def has_axis(self, name: str) -> bool:
        return any(a == name for a, _ in self.axes)

    @property
    def tp_degree(self) -> int:
        if self.has_axis(AXIS_MODEL):
            return self.axis_size(AXIS_MODEL)
        return self.axis_size(AXIS_TP1) * self.axis_size(AXIS_TP2)

    @property
    def dp_degree(self) -> int:
        d = self.axis_size(AXIS_DATA)
        if self.has_axis(AXIS_POD):
            d *= self.axis_size(AXIS_POD)
        return d

    def build(self, devices: Sequence[jax.Device] | None = None) -> jax.sharding.Mesh:
        """Materialize into a jax Mesh (touches device state)."""
        if devices is None:
            return compat.make_mesh(self.shape, self.names)
        return compat.mesh_from_devices(
            np.asarray(devices)[: self.size], self.shape, self.names)

    def abstract(self) -> jax.sharding.AbstractMesh:
        """AbstractMesh — enough for sharding specs / eval_shape, no devices."""
        return compat.abstract_mesh(self.shape, self.names)


def production_topo(multi_pod: bool = False) -> MeshTopo:
    """The assignment's required production mesh (ATP (16,1) baseline)."""
    if multi_pod:
        return MeshTopo(((AXIS_POD, 2), (AXIS_DATA, 16), (AXIS_MODEL, 16)))
    return MeshTopo(((AXIS_DATA, 16), (AXIS_MODEL, 16)))


def atp_topo(
    dp: int,
    d1: int,
    d2: int,
    pods: int = 1,
) -> MeshTopo:
    """ATP mesh: (pod?, data, tp1, tp2).  d1*d2 is the TP degree."""
    axes: list[tuple[str, int]] = []
    if pods > 1:
        axes.append((AXIS_POD, pods))
    axes.append((AXIS_DATA, dp))
    axes.append((AXIS_TP1, d1))
    axes.append((AXIS_TP2, d2))
    return MeshTopo(tuple(axes))


def tp_axis_names(topo: MeshTopo) -> tuple[str | None, str | None]:
    """(first, second) mesh-dim axis names for ATP collectives.

    On the required production mesh the single "model" axis is ATP (N, 1):
    tp1="model", tp2=None.  Size-1 axes are returned as None so collective
    code can skip no-op psums.
    """
    if topo.has_axis(AXIS_MODEL):
        return (AXIS_MODEL if topo.axis_size(AXIS_MODEL) > 1 else None, None)
    a1 = AXIS_TP1 if topo.axis_size(AXIS_TP1) > 1 else None
    a2 = AXIS_TP2 if topo.axis_size(AXIS_TP2) > 1 else None
    return (a1, a2)


def dp_axis_names(topo: MeshTopo) -> tuple[str, ...]:
    names = []
    if topo.has_axis(AXIS_POD) and topo.axis_size(AXIS_POD) > 1:
        names.append(AXIS_POD)
    if topo.has_axis(AXIS_DATA) and topo.axis_size(AXIS_DATA) > 1:
        names.append(AXIS_DATA)
    return tuple(names)
