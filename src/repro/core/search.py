"""ATP strategy search (paper §3.5): pick DeviceMesh(d1,d2) minimizing T_comm.

``search_strategy`` is the paper's Eq. 2 ranking over (d1, d2).
``search_strategy_overlap`` extends the space with the overlap engine's
knobs — ``chunks`` (§4.1 chunk-pipelining) and ``seq_parallel`` (the
reduce-scatter/all-gather block I/O spec) — ranked by *exposed* (post-
overlap) communication time from ``cost_model.t_comm_overlap``.
"""
from __future__ import annotations

import dataclasses

from repro.core.atp import SEQ_PARALLEL_KINDS
from repro.core.calibrate import CalibrationTable
from repro.core.cost_model import (DECODE_ALPHA_S, DECODE_LAUNCH_S,
                                   DecodeStrategyCost, LayerCommProfile,
                                   OverlapStrategyCost, SegmentWorkload,
                                   StrategyCost, t_comm, t_comm_decode,
                                   t_comm_overlap)
from repro.core.comm_matrix import HierarchicalCommMatrix
from repro.core.mesh import factorizations


@dataclasses.dataclass(frozen=True)
class SearchResult:
    best: StrategyCost
    ranked: tuple[StrategyCost, ...]  # ascending T_comm

    def mesh(self) -> tuple[int, int]:
        return (self.best.d1, self.best.d2)


def search_strategy(
    matrix: HierarchicalCommMatrix,
    tp_degree: int,
    *,
    layers: int,
    batch: int,
    seq: int,
    profile: LayerCommProfile,
    bytes_per_elem: int = 2,
    calibration: dict[tuple[int, int], tuple[float, float]] | None = None,
) -> SearchResult:
    """Enumerate all (d1,d2) factorizations of tp_degree and rank by Eq. 2.

    `calibration` maps (d1,d2) -> measured (B1,B2) overrides (paper §5.3);
    a ``calibrate.CalibrationTable`` is accepted in place of the dict.
    """
    calibration = CalibrationTable.coerce(calibration)
    costs = []
    for d1, d2 in factorizations(tp_degree):
        calib = (calibration.bandwidths(d1, d2)
                 if calibration is not None else None)
        try:
            costs.append(
                t_comm(
                    matrix, d1, d2,
                    layers=layers, batch=batch, seq=seq,
                    profile=profile, bytes_per_elem=bytes_per_elem,
                    calibrated=calib,
                )
            )
        except ValueError:
            continue  # factorization does not embed into the topology
    if not costs:
        raise ValueError(f"no valid (d1,d2) for tp={tp_degree} on {matrix.name}")
    ranked = tuple(sorted(costs, key=lambda c: c.t_comm))
    return SearchResult(ranked[0], ranked)


@dataclasses.dataclass(frozen=True)
class OverlapSearchResult:
    best: OverlapStrategyCost
    ranked: tuple[OverlapStrategyCost, ...]  # ascending t_exposed

    def mesh(self) -> tuple[int, int]:
        return (self.best.d1, self.best.d2)

    def config(self) -> dict:
        return {"d1": self.best.d1, "d2": self.best.d2,
                "chunks": self.best.chunks,
                "seq_parallel": self.best.seq_parallel}


def _calibration_lookups(calibration, alpha_s: float,
                         wire_dtype: str = "bf16"):
    """(calib_for, alpha_for, chunk_eff_for, launch_for) shared by every
    search — measured bandwidths / per-step latencies / chunked-collective
    efficiencies / per-chunk launch costs override the analytic defaults
    for the factorizations the table covers.  One implementation: the
    v1/v2 parity pin depends on all searches pricing calibration
    identically.

    Under ``wire_dtype`` "int8"/"fp8" the measured *quantized* wire
    bandwidths (``CalibEntry.b1_q``/``b2_q``, already in the 1-byte/elem
    convention the cost model uses for quantized volumes) replace the
    full-width ones where measured — this is what lets ``plan_search``
    pick a different factorization under quantization when the fabric's
    small-message behaviour differs from its large-message one."""

    def calib_for(d1: int, d2: int):
        if calibration is None:
            return None
        if wire_dtype != "bf16":
            q = calibration.quant_bandwidths(d1, d2)
            if q is not None:
                return q
        return calibration.bandwidths(d1, d2)

    def alpha_for(d1: int, d2: int) -> float:
        if calibration is not None:
            a = calibration.alpha(d1, d2)
            if a is not None:
                return a
        return alpha_s

    def chunk_eff_for(d1: int, d2: int):
        if calibration is not None:
            return calibration.chunk_efficiency(d1, d2)
        return None

    def launch_for(d1: int, d2: int):
        if calibration is not None:
            return calibration.launch(d1, d2)
        return None

    return calib_for, alpha_for, chunk_eff_for, launch_for


def search_strategy_overlap(
    matrix: HierarchicalCommMatrix,
    tp_degree: int,
    *,
    layers: int,
    batch: int,
    seq: int,
    profile: LayerCommProfile,
    bytes_per_elem: int = 2,
    chunks_options: tuple[int, ...] = (1, 2, 4, 8),
    seq_parallel_options: tuple[bool, ...] = (False, True),
    peak_tflops: float = 200.0,
    algo: str = "ring",
    alpha_s: float = 0.0,
    calibration=None,
    wire_dtype: str = "bf16",
) -> OverlapSearchResult:
    """Rank (d1, d2) x chunks x seq_parallel by exposed comm time.

    ``wire_dtype`` prices boundary collectives at 1 byte/elem for
    "int8"/"fp8" (MoE dispatch stays full width) and, when the
    calibration table carries measured quantized bandwidths, ranks
    against those instead of the full-width measurements.

    ``seq_parallel`` subsumes the retired ``ATPContext.use_reduce_scatter``
    knob: the fused psum+slice boundary it named is exactly the
    reduce-scatter row boundary the sequence-parallel spec uses (plus the
    conjugate entry gather), so ranking seq_parallel on/off covers that
    axis of the space.

    ``calibration`` maps (d1, d2) to measured (B1, B2) — either a
    ``calibrate.CalibrationTable`` or the seed-style dict ``t_comm``
    accepts — overriding the analytic Eq. 3/4 bandwidths (paper §5.3).

    With ``chunks_options=(1,)``, ``seq_parallel_options=(False,)``,
    ``algo="rabenseifner"`` and ``alpha_s=0`` the ranking over (d1, d2)
    coincides exactly with the seed's Eq. 2 ``search_strategy``.
    """

    calibration = CalibrationTable.coerce(calibration)
    calib_for, alpha_for, chunk_eff_for, launch_for = _calibration_lookups(
        calibration, alpha_s, wire_dtype)

    costs = []
    for d1, d2 in factorizations(tp_degree):
        try:
            matrix.axis_bandwidths(d1, d2)
        except ValueError:
            continue  # factorization does not embed into the topology
        for chunks in chunks_options:
            for sp in seq_parallel_options:
                costs.append(t_comm_overlap(
                    matrix, d1, d2, layers=layers, batch=batch, seq=seq,
                    profile=profile, bytes_per_elem=bytes_per_elem,
                    chunks=chunks, seq_parallel=sp,
                    peak_tflops=peak_tflops, algo=algo,
                    alpha_s=alpha_for(d1, d2),
                    calibrated=calib_for(d1, d2),
                    chunk_eff=chunk_eff_for(d1, d2),
                    chunk_launch_s=launch_for(d1, d2),
                    wire_dtype=wire_dtype))
    if not costs:
        raise ValueError(
            f"no valid (d1,d2) for tp={tp_degree} on {matrix.name}")
    ranked = tuple(sorted(costs, key=lambda c: (c.t_exposed, c.chunks,
                                                c.seq_parallel)))
    return OverlapSearchResult(ranked[0], ranked)


# ---------------------------------------------------------------------------
# Heterogeneous per-segment search (plan format_version 2).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SegmentChoice:
    """One segment's chosen knobs under a shared (d1, d2) mesh."""

    kind: str
    layers: int
    chunks: int
    seq_parallel: bool
    cost: OverlapStrategyCost

    @property
    def t_exposed(self) -> float:
        return self.cost.t_exposed


@dataclasses.dataclass(frozen=True)
class SegmentedStrategyCost:
    """Summed per-segment cost of one (d1, d2) factorization."""

    d1: int
    d2: int
    t_comm: float
    t_exposed: float
    t_gemm: float
    segments: tuple[SegmentChoice, ...]

    @property
    def chunks(self) -> int:
        """Dominant (most-layers) segment's chunk count — the summary knob."""
        return max(self.segments, key=lambda c: c.layers).chunks

    @property
    def seq_parallel(self) -> bool:
        return max(self.segments, key=lambda c: c.layers).seq_parallel


@dataclasses.dataclass(frozen=True)
class SegmentedSearchResult:
    best: SegmentedStrategyCost
    ranked: tuple[SegmentedStrategyCost, ...]  # ascending summed t_exposed

    def mesh(self) -> tuple[int, int]:
        return (self.best.d1, self.best.d2)


def search_strategy_segments(
    matrix: HierarchicalCommMatrix,
    tp_degree: int,
    *,
    workloads: tuple[SegmentWorkload, ...],
    batch: int,
    seq: int,
    bytes_per_elem: int = 2,
    chunks_options: tuple[int, ...] = (1, 2, 4, 8),
    seq_parallel_options: tuple[bool, ...] = (False, True),
    peak_tflops: float = 200.0,
    algo: str = "ring",
    alpha_s: float = 0.0,
    calibration=None,
    wire_dtype: str = "bf16",
) -> SegmentedSearchResult:
    """Per-segment knob search over a shared (d1, d2) mesh.

    The mesh is global (segment boundaries must agree on the activation
    layout) but (chunks, seq_parallel) are optimized independently per
    segment against that segment's per-kind comm profile, and the mesh
    ranking sums the per-segment exposed times.  ``seq_parallel`` is only
    explored for kinds in :data:`repro.core.atp.SEQ_PARALLEL_KINDS` —
    the same gate execution applies (``ATPContext.for_segment``).

    For a single-segment workload this selects exactly the strategy
    ``search_strategy_overlap`` would (identical knobs and cost): per-mesh
    knob minimization under the same (t_exposed, chunks, seq_parallel)
    key, then the same mesh ranking — the v1/v2 parity pin.
    """
    if not workloads:
        raise ValueError("search_strategy_segments needs >= 1 workload")
    calibration = CalibrationTable.coerce(calibration)
    calib_for, alpha_for, chunk_eff_for, launch_for = _calibration_lookups(
        calibration, alpha_s, wire_dtype)

    meshes = []
    for d1, d2 in factorizations(tp_degree):
        try:
            matrix.axis_bandwidths(d1, d2)
        except ValueError:
            continue
        choices = []
        for w in workloads:
            sp_opts = (seq_parallel_options if w.kind in SEQ_PARALLEL_KINDS
                       else (False,))
            cands = [t_comm_overlap(
                matrix, d1, d2, layers=w.layers, batch=batch, seq=seq,
                profile=w.profile, bytes_per_elem=bytes_per_elem,
                chunks=chunks, seq_parallel=sp, peak_tflops=peak_tflops,
                algo=algo, alpha_s=alpha_for(d1, d2),
                calibrated=calib_for(d1, d2),
                chunk_eff=chunk_eff_for(d1, d2),
                chunk_launch_s=launch_for(d1, d2),
                wire_dtype=wire_dtype)
                for chunks in chunks_options for sp in sp_opts]
            best = min(cands, key=lambda c: (c.t_exposed, c.chunks,
                                             c.seq_parallel))
            choices.append(SegmentChoice(
                kind=w.kind, layers=w.layers, chunks=best.chunks,
                seq_parallel=best.seq_parallel, cost=best))
        meshes.append(SegmentedStrategyCost(
            d1=d1, d2=d2,
            t_comm=sum(c.cost.t_comm for c in choices),
            t_exposed=sum(c.cost.t_exposed for c in choices),
            t_gemm=sum(c.cost.t_gemm for c in choices),
            segments=tuple(choices)))
    if not meshes:
        raise ValueError(
            f"no valid (d1,d2) for tp={tp_degree} on {matrix.name}")
    ranked = tuple(sorted(
        meshes, key=lambda m: (m.t_exposed,
                               tuple((c.chunks, c.seq_parallel)
                                     for c in m.segments))))
    return SegmentedSearchResult(ranked[0], ranked)


# ---------------------------------------------------------------------------
# Latency-aware decode (serving) search.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeSearchResult:
    best: DecodeStrategyCost
    ranked: tuple[DecodeStrategyCost, ...]  # ascending t_step

    def mesh(self) -> tuple[int, int]:
        return (self.best.d1, self.best.d2)


def search_strategy_decode(
    matrix: HierarchicalCommMatrix,
    tp_degree: int,
    *,
    workloads: tuple[SegmentWorkload, ...],
    batch: int,
    bytes_per_elem: int = 2,
    alpha_s: float = DECODE_ALPHA_S,
    launch_s: float = DECODE_LAUNCH_S,
    calibration=None,
    boundary_mode: str | None = None,
    wire_dtype: str = "bf16",
    paged_read=None,
    spec_accept_rate: float | None = None,
) -> DecodeSearchResult:
    """Rank (d1, d2) by modelled per-token decode latency (serve objective).

    Decode boundary all-reduces move ``[B, 1, h]`` activations — per ATP's
    Eq. 4 split the alpha*steps latency term dominates, not the bandwidth
    term the training search (Eq. 2) optimizes — so the winning
    factorization is generally NOT the training winner: eliminating a
    whole boundary family (d1=1 or d2=1) or keeping the TP degree on
    low-hop-latency fabric layers beats balancing payload bytes.  The
    per-factorization ``boundary_mode`` is chosen by the same model (psum
    O(log d) steps vs ring O(d) steps; a calibrated boundary preference
    from the table wins when measured).

    ``calibration`` threads measured (B1, B2) and per-step alpha exactly
    like the training searches; ``batch`` is the decode slot count.
    ``paged_read`` prices the per-tick paged KV gather (exposed only
    where the boundary algorithm can't hide it — see ``t_comm_decode``);
    ``spec_accept_rate`` lets each factorization also bid its MTP
    self-speculative tick.  Both default off, leaving rankings unchanged.
    """
    if not workloads:
        raise ValueError("search_strategy_decode needs >= 1 workload")
    calibration = CalibrationTable.coerce(calibration)
    calib_for, alpha_for, _, _ = _calibration_lookups(
        calibration, alpha_s, wire_dtype)

    costs = []
    for d1, d2 in factorizations(tp_degree):
        try:
            matrix.axis_bandwidths(d1, d2)
        except ValueError:
            continue
        bm = boundary_mode
        if bm is None and calibration is not None:
            bm = calibration.boundary_mode(d1, d2)
        costs.append(t_comm_decode(
            matrix, d1, d2, workloads=workloads, batch=batch,
            bytes_per_elem=bytes_per_elem, alpha_s=alpha_for(d1, d2),
            launch_s=launch_s, calibrated=calib_for(d1, d2),
            boundary_mode=bm, wire_dtype=wire_dtype,
            paged_read=paged_read, spec_accept_rate=spec_accept_rate))
    if not costs:
        raise ValueError(
            f"no valid (d1,d2) for tp={tp_degree} on {matrix.name}")
    ranked = tuple(sorted(costs, key=lambda c: (c.t_step, c.d1)))
    return DecodeSearchResult(ranked[0], ranked)


def recommend_chunks(matrix: HierarchicalCommMatrix, d1: int, d2: int) -> int:
    """Paper §4.1/§5.2 heuristic: chunk 4 on slow fabrics, 2 otherwise.

    Slow fabric := bottleneck algorithm bandwidth under ~30 GB/s (IB-class),
    where Table 3 shows chunk=4 keeps winning; on NVLink-class fabrics the
    gain saturates at chunk=2 and larger chunks hurt small GEMM efficiency.
    """
    from repro.core.cost_model import axis_algorithm_bw

    _, _, b1, b2 = axis_algorithm_bw(matrix, d1, d2)
    bottleneck = min(b for b in (b1, b2) if b != float("inf"))
    return 4 if bottleneck < 30.0 else 2
