"""ATP strategy search (paper §3.5): pick DeviceMesh(d1,d2) minimizing T_comm."""
from __future__ import annotations

import dataclasses

from repro.core.comm_matrix import HierarchicalCommMatrix
from repro.core.cost_model import LayerCommProfile, StrategyCost, t_comm
from repro.core.mesh import factorizations


@dataclasses.dataclass(frozen=True)
class SearchResult:
    best: StrategyCost
    ranked: tuple[StrategyCost, ...]  # ascending T_comm

    def mesh(self) -> tuple[int, int]:
        return (self.best.d1, self.best.d2)


def search_strategy(
    matrix: HierarchicalCommMatrix,
    tp_degree: int,
    *,
    layers: int,
    batch: int,
    seq: int,
    profile: LayerCommProfile,
    bytes_per_elem: int = 2,
    calibration: dict[tuple[int, int], tuple[float, float]] | None = None,
) -> SearchResult:
    """Enumerate all (d1,d2) factorizations of tp_degree and rank by Eq. 2.

    `calibration` maps (d1,d2) -> measured (B1,B2) overrides (paper §5.3).
    """
    costs = []
    for d1, d2 in factorizations(tp_degree):
        calib = calibration.get((d1, d2)) if calibration else None
        try:
            costs.append(
                t_comm(
                    matrix, d1, d2,
                    layers=layers, batch=batch, seq=seq,
                    profile=profile, bytes_per_elem=bytes_per_elem,
                    calibrated=calib,
                )
            )
        except ValueError:
            continue  # factorization does not embed into the topology
    if not costs:
        raise ValueError(f"no valid (d1,d2) for tp={tp_degree} on {matrix.name}")
    ranked = tuple(sorted(costs, key=lambda c: c.t_comm))
    return SearchResult(ranked[0], ranked)


def recommend_chunks(matrix: HierarchicalCommMatrix, d1: int, d2: int) -> int:
    """Paper §4.1/§5.2 heuristic: chunk 4 on slow fabrics, 2 otherwise.

    Slow fabric := bottleneck algorithm bandwidth under ~30 GB/s (IB-class),
    where Table 3 shows chunk=4 keeps winning; on NVLink-class fabrics the
    gain saturates at chunk=2 and larger chunks hurt small GEMM efficiency.
    """
    from repro.core.cost_model import axis_algorithm_bw

    _, _, b1, b2 = axis_algorithm_bw(matrix, d1, d2)
    bottleneck = min(b for b in (b1, b2) if b != float("inf"))
    return 4 if bottleneck < 30.0 else 2
