"""ATP row/column-first tensor-parallel layers (paper §3.2, Fig. 5/6).

Everything here runs *inside* ``jax.shard_map`` with ``check_vma=True``:
tensors are local shards, collectives are explicit, and JAX's
varying-manual-axes type system transposes them exactly (the backward
all-reduce of each boundary is mathematically forced — the cotangent
arrives Partial on the same mesh dim because the neighbouring GEMM's
contraction dim is sharded there).

Communication schedule per transformer layer (== paper Fig. 6 / Eq. 2):

    column-first GEMM -> boundary psum over mesh dim 2 (f1 fwd / f3 fwd)
    row-first GEMM    -> boundary psum over mesh dim 1 (f2 fwd / f4 fwd)
    + the mirrored backward psums inserted by AD

Summed per layer this is Eq. 2: 2Lbs*(7h/(d1 B2) + 2h/(d2 B1)) for GPT.

Activations between blocks carry the paper's spec [Replicate, Shard(1)]:
replicated over tp1 (mesh dim 1), feature-sharded over tp2 (mesh dim 2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.mesh import MeshTopo, dp_axis_names, tp_axis_names


@dataclasses.dataclass(frozen=True)
class ATPContext:
    """Static distribution context threaded through all model code."""

    topo: MeshTopo
    ax1: str | None          # device-mesh dim 1 (size d1)
    ax2: str | None          # device-mesh dim 2 (size d2)
    dp_axes: tuple[str, ...]  # data-parallel axes (pod, data)
    chunks: int = 1           # chunk-based overlapping factor (paper §4.1)
    use_reduce_scatter: bool = False  # beyond-paper: fuse psum+slice

    @property
    def d1(self) -> int:
        return self.topo.axis_size(self.ax1) if self.ax1 else 1

    @property
    def d2(self) -> int:
        return self.topo.axis_size(self.ax2) if self.ax2 else 1

    @property
    def tp(self) -> int:
        return self.d1 * self.d2

    @property
    def tp_axes(self) -> tuple[str, ...]:
        """Combined TP axes, mesh-dim-1 major (for EP / head sharding)."""
        return tuple(a for a in (self.ax1, self.ax2) if a)

    @property
    def dp(self) -> int:
        return math.prod(self.topo.axis_size(a) for a in self.dp_axes) if self.dp_axes else 1

    def index1(self):
        return lax.axis_index(self.ax1) if self.ax1 else 0

    def index2(self):
        return lax.axis_index(self.ax2) if self.ax2 else 0

    def tp_index(self):
        """Flattened TP rank, mesh-dim-1 major."""
        return self.index1() * self.d2 + self.index2()

    def dp_index(self):
        idx = 0
        for a in self.dp_axes:
            idx = idx * self.topo.axis_size(a) + lax.axis_index(a)
        return idx


def make_context(
    topo: MeshTopo, chunks: int = 1, use_reduce_scatter: bool = False
) -> ATPContext:
    ax1, ax2 = tp_axis_names(topo)
    return ATPContext(
        topo=topo, ax1=ax1, ax2=ax2, dp_axes=dp_axis_names(topo),
        chunks=chunks, use_reduce_scatter=use_reduce_scatter,
    )


# ---------------------------------------------------------------------------
# Boundary collectives (f1..f4).
# ---------------------------------------------------------------------------

def atp_boundary(x, axis: str | None):
    """Resolve a Partial(axis) activation: all-reduce over one mesh dim.

    This is the forward of the paper's conjugate f operator; AD inserts the
    conjugate backward all-reduce automatically (vma typing)."""
    if axis is None:
        return x
    return lax.psum(x, axis)


def atp_gather(x, axis: str | None, dim: int):
    """all-gather fwd (reduce-scatter bwd): the paper's 'gather the output
    tensor before the Output Linear'."""
    if axis is None:
        return x
    return lax.all_gather(x, axis, axis=dim, tiled=True)


def atp_reduce_scatter(x, axis: str | None, dim: int):
    """Beyond-paper fused boundary: psum+shard_slice as one reduce-scatter."""
    if axis is None:
        return x
    return lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)


# ---------------------------------------------------------------------------
# Row/column-first linear layers.
# ---------------------------------------------------------------------------

def _chunked_boundary_matmul(ctx: ATPContext, x, w, axis):
    """Chunk-based overlapping (paper §4.1).

    Split the leading (batch) dim into `ctx.chunks` chunks; each chunk's
    GEMM + all-reduce chain is data-independent of the others, so XLA's
    latency-hiding scheduler overlaps chunk k's collective with chunk
    k+1's GEMM.  Semantically identical to the unchunked op.
    """
    c = ctx.chunks
    if c <= 1 or x.shape[0] % c:
        return atp_boundary(jnp.einsum("...k,kn->...n", x, w), axis)
    xs = jnp.split(x, c, axis=0)
    ys = [atp_boundary(jnp.einsum("...k,kn->...n", xc, w), axis) for xc in xs]
    return jnp.concatenate(ys, axis=0)


def atp_linear(
    ctx: ATPContext,
    x,
    w,
    b=None,
    *,
    kind: Literal["col", "row"],
    chunked: bool = True,
):
    """Distributed Y = XW (+b) with ATP sharding.

    column-first (paper Fig. 5 right):
        W global [K, N] sharded [Shard(1)@ax1, Shard(0)@ax2]
        (local shard [K/d2, N/d1]); X local [..., K/d2] (block I/O spec
        [Replicate, Shard(-1)]); local GEMM output is Partial over ax2 ->
        boundary psum(ax2) -> [..., N/d1]: ax1-feature-sharded,
        ax2-replicated.
    row-first (paper Fig. 5 left):
        W global [K, N] sharded [Shard(0)@ax1, Shard(1)@ax2]
        (local [K/d1, N/d2]); X local [..., K/d1]; local GEMM output is
        Partial over ax1 -> boundary psum(ax1) -> [..., N/d2]: back to the
        block I/O spec [Replicate, Shard(-1)].

    Bias is sharded like the GEMM output dim and added after the boundary
    (psum is linear; keeps the bias gradient exact and local).
    """
    axis = ctx.ax2 if kind == "col" else ctx.ax1
    if chunked and ctx.chunks > 1 and x.ndim >= 2:
        y = _chunked_boundary_matmul(ctx, x, w, axis)
    else:
        y = atp_boundary(jnp.einsum("...k,kn->...n", x, w), axis)
    if b is not None:
        y = y + b
    return y


def shard_slice(x, index, nshards: int, dim: int):
    """Local slice of dim `dim` into `nshards` parts at `index` (the paper's
    free 'scatter' of a replicated tensor)."""
    if nshards == 1:
        return x
    size = x.shape[dim] // nshards
    return lax.dynamic_slice_in_dim(x, index * size, size, axis=dim)


# ---------------------------------------------------------------------------
# Attention-core scatter/gather (paper §3.2.1): fully shard the core over
# the *combined* d1*d2 ranks.  Head-count shortfall is covered by also
# sharding the batch dim (policy: DESIGN.md §6).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoreSharding:
    """How the attention/SSM core shards over ax2 (it is already sharded
    over ax1 by the column-first QKV projection): d2 = h2 (more heads) *
    b2 (batch)."""

    h2: int
    b2: int


def plan_core_sharding(ctx: ATPContext, heads_after_ax1: int, batch_local: int) -> CoreSharding:
    h2 = math.gcd(heads_after_ax1, ctx.d2)
    b2 = ctx.d2 // h2
    if batch_local % b2:
        raise ValueError(
            f"cannot shard attention core: {heads_after_ax1} heads vs d2={ctx.d2} "
            f"leaves batch factor {b2}, but local batch is {batch_local}"
        )
    return CoreSharding(h2=h2, b2=b2)


def core_scatter(ctx: ATPContext, x, cs: CoreSharding, head_dim: int, batch_dim: int = 0):
    """Slice (free) the ax2-replicated tensor to this rank's core shard."""
    if ctx.ax2 is None:
        return x
    i2 = ctx.index2()
    x = shard_slice(x, i2 // cs.b2, cs.h2, head_dim)
    x = shard_slice(x, i2 % cs.b2, cs.b2, batch_dim)
    return x


def core_gather(ctx: ATPContext, y, cs: CoreSharding, head_dim: int, batch_dim: int = 0):
    """all-gather the core output back to ax2-replicated layout."""
    if ctx.ax2 is None:
        return y
    if cs.b2 == 1:
        return atp_gather(y, ctx.ax2, head_dim)
    if cs.h2 == 1:
        return atp_gather(y, ctx.ax2, batch_dim)
    g = lax.all_gather(y, ctx.ax2, axis=0, tiled=False)  # [d2, ...]
    g = g.reshape((cs.h2, cs.b2) + y.shape)
    parts_b = jnp.concatenate([g[:, i] for i in range(cs.b2)], axis=batch_dim + 1)
    return jnp.concatenate([parts_b[i] for i in range(cs.h2)], axis=head_dim)
