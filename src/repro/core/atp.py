"""ATP row/column-first tensor-parallel layers (paper §3.2, Fig. 5/6).

Everything here runs *inside* ``jax.shard_map`` with ``check_vma=True``:
tensors are local shards, collectives are explicit, and JAX's
varying-manual-axes type system transposes them exactly (the backward
all-reduce of each boundary is mathematically forced — the cotangent
arrives Partial on the same mesh dim because the neighbouring GEMM's
contraction dim is sharded there).

Communication schedule per transformer layer (== paper Fig. 6 / Eq. 2):

    column-first GEMM -> boundary psum over mesh dim 2 (f1 fwd / f3 fwd)
    row-first GEMM    -> boundary psum over mesh dim 1 (f2 fwd / f4 fwd)
    + the mirrored backward psums inserted by AD

Summed per layer this is Eq. 2: 2Lbs*(7h/(d1 B2) + 2h/(d2 B1)) for GPT.

Activations between blocks carry the paper's spec [Replicate, Shard(1)]:
replicated over tp1 (mesh dim 1), feature-sharded over tp2 (mesh dim 2).

Beyond-paper boundary modes (see docs/overlap.md):

``boundary_mode``
    "psum"  — monolithic lax collectives at every boundary (paper Fig. 6).
    "ring"  — boundaries run as explicit ppermute rings from
              repro.core.overlap: the chunked GEMM is software-pipelined
              against the ring steps (a collective-matmul, §4.1 made
              structural), and jax.custom_vjp gives the backward pass the
              mirrored ring schedule instead of AD-inserted monolithic
              psums.

``seq_parallel``
    Opt-in sequence-parallel block I/O spec [Shard(seq)@ax1, Shard(f)@ax2]:
    the f2/f4 row boundaries become psum_scatter over ax1 along the
    sequence dim (half the wire bytes of the all-reduce they replace) and
    the block-entry norms fold the conjugate all-gather (rms_norm /
    layer_norm `gather_seq=True`).  Activation memory between blocks drops
    by d1.  Eq. 2's row term keeps its volume across fwd+bwd but every
    boundary op halves, which is what the overlap engine pipelines against.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import overlap
from repro.core.mesh import MeshTopo, dp_axis_names, tp_axis_names


class _Removed:
    """Sentinel singleton for retired knobs (copies compare identical)."""

    def __repr__(self):
        return "<removed>"

    def __deepcopy__(self, memo):
        return self

    def __copy__(self):
        return self

    def __eq__(self, other):
        return isinstance(other, _Removed)

    def __hash__(self):
        return hash(_Removed)


#: Segment kinds whose block I/O can run the sequence-parallel spec
#: [Shard(seq)@ax1, Shard(f)@ax2]: their block-entry norms fold the
#: conjugate all-gather and their row boundaries psum_scatter back.  MoE
#: dispatch, SSM scans and the zamba/xlstm super-blocks assume
#: ax1-replicated full-sequence I/O, so their segments mask seq_parallel
#: (per-segment gating, not a whole-network error).
SEQ_PARALLEL_KINDS = frozenset({"dense", "mla_dense"})


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """Per-segment execution knobs over the shared (d1, d2, dp) mesh.

    One entry per model segment kind (plan format_version 2): the mesh is
    global — activation layouts must agree at segment boundaries — but
    chunking, boundary implementation and the sequence-parallel spec are
    per-segment properties of each segment's communication profile.
    """

    kind: str
    chunks: int = 1
    boundary_mode: str = "psum"
    seq_parallel: bool = False
    #: boundary-collective payload dtype (plan format_version 4): "bf16"
    #: full width, "int8"/"fp8" quantized wire (overlap.WIRE_DTYPES)
    wire_dtype: str = "bf16"

    def __post_init__(self):
        if self.chunks < 1:
            raise ValueError(
                f"segment {self.kind!r}: chunks must be >= 1, got {self.chunks}")
        if self.boundary_mode not in ("psum", "ring"):
            raise ValueError(
                f"segment {self.kind!r}: boundary_mode must be 'psum' or "
                f"'ring', got {self.boundary_mode!r}")
        if self.wire_dtype not in overlap.WIRE_DTYPES:
            raise ValueError(
                f"segment {self.kind!r}: wire_dtype must be one of "
                f"{overlap.WIRE_DTYPES}, got {self.wire_dtype!r}")

    def describe(self) -> str:
        sp = "+sp" if self.seq_parallel else ""
        wd = "" if self.wire_dtype == "bf16" else f"@{self.wire_dtype}"
        return f"{self.kind}:ck{self.chunks}{self.boundary_mode}{sp}{wd}"

    def to_dict(self) -> dict:
        return {"kind": self.kind, "chunks": self.chunks,
                "boundary_mode": self.boundary_mode,
                "seq_parallel": self.seq_parallel,
                "wire_dtype": self.wire_dtype}

    @staticmethod
    def from_dict(d) -> "SegmentPlan":
        return SegmentPlan(kind=str(d["kind"]),
                           chunks=int(d.get("chunks", 1)),
                           boundary_mode=d.get("boundary_mode", "psum"),
                           seq_parallel=bool(d.get("seq_parallel", False)),
                           wire_dtype=d.get("wire_dtype", "bf16"))


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Decode-time (serving) knobs of a ParallelPlan (format_version 3).

    Decode boundary all-reduces move ``[B, 1, h]`` activations — latency-
    bound, not bandwidth-bound — so the serve objective
    (``core.search.search_strategy_decode``) may pick a *different*
    (d1, d2) factorization and boundary implementation than the
    train/prefill search did.  ``chunks`` is pinned to 1: there is no
    per-boundary payload worth splitting at seq=1, and the chunk engine's
    per-chunk alpha would be pure overhead.  ``seq_parallel`` is
    structurally absent (a one-token step has no sequence to shard).

    Executing a decode factorization that differs from the plan's mesh
    requires building the serving stack on the decode mesh up front
    (``ParallelPlan.decode_view``); ``resolve_ctx(decode=True)`` applies
    the mesh-layout-neutral knobs (boundary_mode, chunks) either way.
    """

    d1: int
    d2: int
    boundary_mode: str = "psum"
    chunks: int = 1
    #: boundary wire dtype for decode steps (format_version 4)
    wire_dtype: str = "bf16"
    #: MTP self-speculative decode pays on this interconnect: the tick
    #: costs one extra token of boundary traffic but amortizes over
    #: 1 + accept_rate tokens (format_version 5)
    speculate: bool = False
    #: copy-on-write prefix sharing at admission (format_version 5)
    prefix_cache: bool = False
    #: modelled seconds per generated token behind the choice (provenance)
    predicted_t_step: float | None = None

    def __post_init__(self):
        if self.d1 < 1 or self.d2 < 1:
            raise ValueError(f"decode plan degrees must be >= 1: {self}")
        if self.chunks != 1:
            raise ValueError(
                f"decode plans are chunks=1 by construction (got "
                f"{self.chunks}): one-token boundaries have nothing to "
                f"pipeline and pay alpha per chunk")
        if self.boundary_mode not in ("psum", "ring"):
            raise ValueError(
                f"decode boundary_mode must be 'psum' or 'ring', got "
                f"{self.boundary_mode!r}")
        if self.wire_dtype not in overlap.WIRE_DTYPES:
            raise ValueError(
                f"decode wire_dtype must be one of {overlap.WIRE_DTYPES}, "
                f"got {self.wire_dtype!r}")

    @property
    def tp(self) -> int:
        return self.d1 * self.d2

    def describe(self) -> str:
        wd = "" if self.wire_dtype == "bf16" else f" @{self.wire_dtype}"
        sp = " +spec" if self.speculate else ""
        pc = " +pfx" if self.prefix_cache else ""
        return f"decode[({self.d1},{self.d2}) {self.boundary_mode}{wd}{sp}{pc}]"

    def to_dict(self) -> dict:
        return {"d1": self.d1, "d2": self.d2,
                "boundary_mode": self.boundary_mode, "chunks": self.chunks,
                "wire_dtype": self.wire_dtype,
                "speculate": self.speculate,
                "prefix_cache": self.prefix_cache,
                "predicted_t_step": self.predicted_t_step}

    @staticmethod
    def from_dict(d) -> "DecodePlan":
        ts = d.get("predicted_t_step")
        return DecodePlan(d1=int(d["d1"]), d2=int(d["d2"]),
                          boundary_mode=d.get("boundary_mode", "psum"),
                          chunks=int(d.get("chunks", 1)),
                          wire_dtype=d.get("wire_dtype", "bf16"),
                          speculate=bool(d.get("speculate", False)),
                          prefix_cache=bool(d.get("prefix_cache", False)),
                          predicted_t_step=(None if ts is None
                                            else float(ts)))


_USE_REDUCE_SCATTER_REMOVED = _Removed()
_USE_REDUCE_SCATTER_MSG = (
    "ATPContext.use_reduce_scatter was retired: the fused psum+slice "
    "boundary it named is exactly the reduce-scatter row boundary of the "
    "sequence-parallel block I/O spec.  Pass seq_parallel=True (or a "
    "ParallelPlan with seq_parallel=True) instead; the strategy search "
    "ranks it as part of the plan space (core.plan.plan_search)."
)


@dataclasses.dataclass(frozen=True)
class ATPContext:
    """Static distribution context threaded through all model code."""

    topo: MeshTopo
    ax1: str | None          # device-mesh dim 1 (size d1)
    ax2: str | None          # device-mesh dim 2 (size d2)
    dp_axes: tuple[str, ...]  # data-parallel axes (pod, data)
    chunks: int = 1           # chunk-based overlapping factor (paper §4.1)
    boundary_mode: Literal["psum", "ring"] = "psum"  # see module docstring
    seq_parallel: bool = False  # block I/O [Shard(seq)@ax1, Shard(f)@ax2]
    wire_dtype: str = "bf16"  # boundary payload dtype (overlap.WIRE_DTYPES)
    # per-segment knob overrides (plan format_version 2): model code asks
    # for its segment's view via ``for_segment(kind)``; the scalar knobs
    # above are the defaults for kinds with no dedicated entry
    segment_plans: tuple[SegmentPlan, ...] = ()
    # retired knob: any explicit value raises (subsumed by seq_parallel)
    use_reduce_scatter: object = dataclasses.field(
        default=_USE_REDUCE_SCATTER_REMOVED, repr=False, compare=False)

    def __post_init__(self):
        if self.use_reduce_scatter is not _USE_REDUCE_SCATTER_REMOVED:
            raise TypeError(_USE_REDUCE_SCATTER_MSG)
        if self.boundary_mode not in ("psum", "ring"):
            # a bool here is almost certainly a seed-era positional
            # use_reduce_scatter (this slot used to hold that knob)
            if isinstance(self.boundary_mode, bool):
                raise TypeError(_USE_REDUCE_SCATTER_MSG)
            raise ValueError(
                f"boundary_mode must be 'psum' or 'ring', got "
                f"{self.boundary_mode!r}")
        if self.wire_dtype not in overlap.WIRE_DTYPES:
            raise ValueError(
                f"wire_dtype must be one of {overlap.WIRE_DTYPES}, got "
                f"{self.wire_dtype!r}")

    @property
    def d1(self) -> int:
        return self.topo.axis_size(self.ax1) if self.ax1 else 1

    @property
    def d2(self) -> int:
        return self.topo.axis_size(self.ax2) if self.ax2 else 1

    @property
    def tp(self) -> int:
        return self.d1 * self.d2

    @property
    def tp_axes(self) -> tuple[str, ...]:
        """Combined TP axes, mesh-dim-1 major (for EP / head sharding)."""
        return tuple(a for a in (self.ax1, self.ax2) if a)

    @property
    def dp(self) -> int:
        return math.prod(self.topo.axis_size(a) for a in self.dp_axes) if self.dp_axes else 1

    def index1(self):
        return lax.axis_index(self.ax1) if self.ax1 else 0

    def index2(self):
        return lax.axis_index(self.ax2) if self.ax2 else 0

    def tp_index(self):
        """Flattened TP rank, mesh-dim-1 major."""
        return self.index1() * self.d2 + self.index2()

    def dp_index(self):
        idx = 0
        for a in self.dp_axes:
            idx = idx * self.topo.axis_size(a) + lax.axis_index(a)
        return idx

    # -- per-segment views (plan format_version 2) -------------------------

    def for_segment(self, kind: str) -> "ATPContext":
        """This segment kind's execution view: same mesh, per-segment
        (chunks, boundary_mode, seq_parallel).

        Falls back to the context's scalar knobs when no dedicated
        :class:`SegmentPlan` entry exists (v1 plans broadcast their global
        knobs to every segment), and masks ``seq_parallel`` for kinds
        outside :data:`SEQ_PARALLEL_KINDS` — the per-segment replacement
        for the retired whole-network "seq_parallel is dense-only" error.
        The returned view carries no ``segment_plans`` of its own.
        """
        base = self
        for seg in self.segment_plans:
            if seg.kind == kind:
                base = dataclasses.replace(
                    self, chunks=seg.chunks, boundary_mode=seg.boundary_mode,
                    seq_parallel=seg.seq_parallel,
                    wire_dtype=seg.wire_dtype, segment_plans=())
                break
        else:
            if self.segment_plans:
                base = dataclasses.replace(self, segment_plans=())
        if base.seq_parallel and kind not in SEQ_PARALLEL_KINDS:
            base = dataclasses.replace(base, seq_parallel=False)
        return base

    @property
    def any_ring(self) -> bool:
        """True if any segment (or the default knobs) runs ring boundaries."""
        return (self.boundary_mode == "ring"
                or any(s.boundary_mode == "ring" for s in self.segment_plans))

    @property
    def any_seq_parallel(self) -> bool:
        """True if any knob — the scalar default (which broadcasts to
        uncovered kinds) or any per-segment entry — requests the
        sequence-parallel spec.  Capability gating is ``for_segment``'s
        job; this only answers "could any segment's view ask for it"."""
        return (self.seq_parallel
                or any(s.seq_parallel for s in self.segment_plans))


def make_context(
    topo: MeshTopo | None = None,
    chunks: int = 1,
    boundary_mode: Literal["psum", "ring"] = "psum",
    seq_parallel: bool = False,
    wire_dtype: str = "bf16",
    *,
    plan=None,
    **retired,
) -> ATPContext:
    """Build the execution context — from loose knobs or a ParallelPlan.

    ``make_context(plan=p)`` is the canonical path: the plan's topology
    (or an explicitly passed ``topo``, e.g. the dryrun's dp=16 mesh) plus
    the plan's chunks / boundary_mode / seq_parallel.  A plan whose
    (d1, d2) disagrees with the topology's TP axes is a hard error — the
    searched strategy and the executed mesh must be the same artifact.
    """
    if "use_reduce_scatter" in retired:
        raise TypeError(_USE_REDUCE_SCATTER_MSG)
    if retired:
        raise TypeError(f"make_context got unexpected kwargs "
                        f"{sorted(retired)}")
    segment_plans: tuple[SegmentPlan, ...] = ()
    if plan is not None:
        if topo is None:
            topo = plan.topo()
        chunks = plan.chunks
        boundary_mode = plan.boundary_mode
        seq_parallel = plan.seq_parallel
        wire_dtype = getattr(plan, "wire_dtype", "bf16")
        segment_plans = tuple(getattr(plan, "segments", ()) or ())
    if topo is None:
        raise TypeError("make_context needs a MeshTopo or a plan")
    ax1, ax2 = tp_axis_names(topo)
    ctx = ATPContext(
        topo=topo, ax1=ax1, ax2=ax2, dp_axes=dp_axis_names(topo),
        chunks=chunks, boundary_mode=boundary_mode, seq_parallel=seq_parallel,
        wire_dtype=wire_dtype, segment_plans=segment_plans,
    )
    if plan is not None and (ctx.d1, ctx.d2) != (plan.d1, plan.d2):
        raise ValueError(
            f"plan/topology mismatch: plan prescribes DeviceMesh"
            f"({plan.d1},{plan.d2}) but mesh TP axes give "
            f"({ctx.d1},{ctx.d2}) on {topo.axes}")
    return ctx


# ---------------------------------------------------------------------------
# Boundary collectives (f1..f4).
# ---------------------------------------------------------------------------

def atp_boundary(x, axis: str | None):
    """Resolve a Partial(axis) activation: all-reduce over one mesh dim.

    This is the forward of the paper's conjugate f operator; AD inserts the
    conjugate backward all-reduce automatically (vma typing)."""
    if axis is None:
        return x
    return lax.psum(x, axis)


def vma_rewrite_active(ctx) -> bool:
    """True when jax's vma rewrite types this build's shard_map bodies.

    With the rewrite active (jax>=0.6 AND no ring boundary in any
    segment's plan — the same condition under which whole-step shard_maps
    pass ``check_vma=True``), jax inserts ``pvary`` casts wherever a
    replicated value meets varying data, and the transpose of ``pvary``
    is exactly the gradient psum that :func:`grad_sync` supplies by hand.
    Callers use this to avoid double-reducing on rewrite builds and to
    decide ``check_vma`` for whole-step shard_maps (one source of truth).
    """
    from repro.core.compat import LEGACY_REP_CHECKER

    return not LEGACY_REP_CHECKER and not ctx.any_ring


def grad_sync(ctx, x, axes):
    """Identity forward, ``psum(ct, axes)`` backward.

    TP-replicated params whose cotangent is rank-partial (norm scales and
    biases — every norm feeds a column boundary whose output is
    ax1-sharded, so the scale grad sums only the local columns' / local
    tokens' contributions; MoE router and qk-norm gains, whose cotangent
    flows back from rank-local experts/heads) drift apart across ranks
    without an explicit gradient all-reduce.  Wrapping the param in this
    barrier at its use site restores the reduction the vma replication
    lint (``repro.analysis.replication``) demands — it is the classic
    Megatron sequence-parallel "grads of RMSNorm need all-reduce" fix,
    which applies to ATP's ax2-sharded-feature norms on every mesh with
    d1 > 1, sequence-parallel or not.

    No-op when the vma rewrite is active (see :func:`vma_rewrite_active`):
    there jax's own ``pvary`` transpose performs the identical reduction,
    and stacking this barrier on top would double-count the gradient.
    """
    if not axes or vma_rewrite_active(ctx):
        return x
    return _grad_sync(x, axes if isinstance(axes, str) else tuple(axes))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_sync(x, axes):
    return x


def _grad_sync_fwd(x, axes):
    return x, None


def _grad_sync_bwd(axes, _res, ct):
    return (lax.psum(ct, axes),)


_grad_sync.defvjp(_grad_sync_fwd, _grad_sync_bwd)


def atp_gather(x, axis: str | None, dim: int):
    """all-gather fwd (reduce-scatter bwd): the paper's 'gather the output
    tensor before the Output Linear'."""
    if axis is None:
        return x
    return lax.all_gather(x, axis, axis=dim, tiled=True)


def atp_reduce_scatter(x, axis: str | None, dim: int):
    """Beyond-paper fused boundary: psum+shard_slice as one reduce-scatter."""
    if axis is None:
        return x
    return lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)


# ---------------------------------------------------------------------------
# Sequence-parallel block I/O helpers (spec [Shard(seq)@ax1, Shard(f)@ax2]).
# ---------------------------------------------------------------------------

def seq_scatter(ctx: ATPContext, x, dim: int = 1):
    """Free slice of an ax1-replicated activation to this rank's seq shard
    (entry into the sequence-parallel domain, e.g. after the embedding)."""
    if not ctx.seq_parallel or ctx.ax1 is None:
        return x
    if x.shape[dim] % ctx.d1:
        raise ValueError(
            f"seq_parallel requires seq ({x.shape[dim]}) divisible by d1={ctx.d1}")
    return shard_slice(x, ctx.index1(), ctx.d1, dim)


def seq_gather(ctx: ATPContext, x, dim: int = 1):
    """all-gather a seq-sharded activation back to full sequence over ax1
    (the conjugate of the psum_scatter row boundary)."""
    if not ctx.seq_parallel or ctx.ax1 is None:
        return x
    if ctx.boundary_mode == "ring":
        return overlap.ring_all_gather(x, ctx.ax1, ctx.d1, dim)
    return lax.all_gather(x, ctx.ax1, axis=dim, tiled=True)


# ---------------------------------------------------------------------------
# Row/column-first linear layers.
# ---------------------------------------------------------------------------

def _chunked_boundary_matmul(ctx: ATPContext, x, w, axis, b=None):
    """Chunk-based overlapping (paper §4.1).

    Split the leading (batch) dim into `ctx.chunks` chunks (uneven leading
    dims use jnp.array_split); each chunk's GEMM + all-reduce chain is
    data-independent of the others.  In "psum" mode the overlap is left to
    XLA's latency-hiding scheduler; in "ring" mode the collective is an
    explicit ppermute ring issued between consecutive chunk GEMMs
    (overlap.overlap_matmul_ar).  The bias add is fused into each chunk's
    post-boundary epilogue rather than a separate full-tensor add.
    Semantically identical to the unchunked op.  ``ctx.wire_dtype`` swaps
    every boundary for its quantized-wire variant (scale-per-chunk; see
    overlap.wire_quantize).
    """
    d = ctx.d2 if axis == ctx.ax2 else ctx.d1
    if ctx.boundary_mode == "ring":
        return overlap.overlap_matmul_ar(x, w, axis, d, ctx.chunks, b=b,
                                         wire_dtype=ctx.wire_dtype)
    quant = ctx.wire_dtype != "bf16" and axis is not None

    def _boundary(y):
        if quant:
            return overlap.quant_psum(y, axis, ctx.wire_dtype)
        return atp_boundary(y, axis)

    c = max(1, min(ctx.chunks, x.shape[0]))
    if c <= 1:
        y = _boundary(jnp.einsum("...k,kn->...n", x, w))
        return y + b if b is not None else y
    xs = (jnp.split(x, c, axis=0) if x.shape[0] % c == 0
          else jnp.array_split(x, c, axis=0))
    ys = []
    for xc in xs:
        yc = _boundary(jnp.einsum("...k,kn->...n", xc, w))
        ys.append(yc + b if b is not None else yc)
    return jnp.concatenate(ys, axis=0)


def atp_linear(
    ctx: ATPContext,
    x,
    w,
    b=None,
    *,
    kind: Literal["col", "row"],
    chunked: bool = True,
):
    """Distributed Y = XW (+b) with ATP sharding.

    column-first (paper Fig. 5 right):
        W global [K, N] sharded [Shard(1)@ax1, Shard(0)@ax2]
        (local shard [K/d2, N/d1]); X local [..., K/d2] (block I/O spec
        [Replicate, Shard(-1)]); local GEMM output is Partial over ax2 ->
        boundary psum(ax2) -> [..., N/d1]: ax1-feature-sharded,
        ax2-replicated.
    row-first (paper Fig. 5 left):
        W global [K, N] sharded [Shard(0)@ax1, Shard(1)@ax2]
        (local [K/d1, N/d2]); X local [..., K/d1]; local GEMM output is
        Partial over ax1 -> boundary psum(ax1) -> [..., N/d2]: back to the
        block I/O spec [Replicate, Shard(-1)].

    With ``ctx.seq_parallel`` the row boundary becomes a psum_scatter over
    ax1 along the sequence dim (x.ndim - 2), leaving the output in the
    sequence-parallel block I/O spec [Shard(seq)@ax1, Shard(-1)@ax2].

    Bias is sharded like the GEMM output dim and applied in the boundary
    epilogue (psum is linear; keeps the bias gradient exact and local).
    """
    axis = ctx.ax2 if kind == "col" else ctx.ax1
    quant = ctx.wire_dtype != "bf16" and axis is not None
    if (ctx.seq_parallel and kind == "row" and axis is not None
            and x.ndim >= 3):
        seq_dim = x.ndim - 2
        ring = ctx.boundary_mode == "ring" and x.shape[seq_dim] % ctx.d1 == 0
        if quant:
            y = overlap.quant_reduce_scatter(
                jnp.einsum("...k,kn->...n", x, w), axis, ctx.d1, seq_dim,
                ctx.wire_dtype, ring)
        elif ring:
            y = overlap.overlap_matmul_rs(x, w, axis, ctx.d1, seq_dim)
        else:
            y = atp_reduce_scatter(
                jnp.einsum("...k,kn->...n", x, w), axis, seq_dim)
        return y + b if b is not None else y
    if chunked and ctx.chunks > 1 and x.ndim >= 2:
        return _chunked_boundary_matmul(ctx, x, w, axis, b)
    if ctx.boundary_mode == "ring" and axis is not None:
        d = ctx.d2 if kind == "col" else ctx.d1
        g = jnp.einsum("...k,kn->...n", x, w)
        y = (overlap.quant_ring_all_reduce(g, axis, d, ctx.wire_dtype)
             if quant else overlap.ring_all_reduce(g, axis, d))
    elif quant:
        y = overlap.quant_psum(jnp.einsum("...k,kn->...n", x, w), axis,
                               ctx.wire_dtype)
    else:
        y = atp_boundary(jnp.einsum("...k,kn->...n", x, w), axis)
    if b is not None:
        y = y + b
    return y


def shard_slice(x, index, nshards: int, dim: int):
    """Local slice of dim `dim` into `nshards` parts at `index` (the paper's
    free 'scatter' of a replicated tensor)."""
    if nshards == 1:
        return x
    size = x.shape[dim] // nshards
    return lax.dynamic_slice_in_dim(x, index * size, size, axis=dim)


# ---------------------------------------------------------------------------
# Attention-core scatter/gather (paper §3.2.1): fully shard the core over
# the *combined* d1*d2 ranks.  Head-count shortfall is covered by also
# sharding the batch dim (policy: DESIGN.md §6).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoreSharding:
    """How the attention/SSM core shards over ax2 (it is already sharded
    over ax1 by the column-first QKV projection): d2 = h2 (more heads) *
    b2 (batch)."""

    h2: int
    b2: int


def plan_core_sharding(ctx: ATPContext, heads_after_ax1: int, batch_local: int) -> CoreSharding:
    h2 = math.gcd(heads_after_ax1, ctx.d2)
    b2 = ctx.d2 // h2
    if batch_local % b2:
        raise ValueError(
            f"cannot shard attention core: {heads_after_ax1} heads vs d2={ctx.d2} "
            f"leaves batch factor {b2}, but local batch is {batch_local}"
        )
    return CoreSharding(h2=h2, b2=b2)


def core_scatter(ctx: ATPContext, x, cs: CoreSharding, head_dim: int, batch_dim: int = 0):
    """Slice (free) the ax2-replicated tensor to this rank's core shard."""
    if ctx.ax2 is None:
        return x
    i2 = ctx.index2()
    x = shard_slice(x, i2 // cs.b2, cs.h2, head_dim)
    x = shard_slice(x, i2 % cs.b2, cs.b2, batch_dim)
    return x


def core_gather(ctx: ATPContext, y, cs: CoreSharding, head_dim: int, batch_dim: int = 0):
    """all-gather the core output back to ax2-replicated layout."""
    if ctx.ax2 is None:
        return y
    if cs.b2 == 1:
        return atp_gather(y, ctx.ax2, head_dim)
    if cs.h2 == 1:
        return atp_gather(y, ctx.ax2, batch_dim)
    g = lax.all_gather(y, ctx.ax2, axis=0, tiled=False)  # [d2, ...]
    g = g.reshape((cs.h2, cs.b2) + y.shape)
    parts_b = jnp.concatenate([g[:, i] for i in range(cs.b2)], axis=batch_dim + 1)
    return jnp.concatenate([parts_b[i] for i in range(cs.h2)], axis=head_dim)
