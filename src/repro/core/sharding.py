"""Sharding specs in the paper's notation (Shard / Replicate / Partial).

The paper binds placement to *device-mesh dims* (not tensor dims):
a spec is a sequence [P_1 .. P_n], one placement per mesh dim.  We keep that
notation for the search/cost layer and provide lossless conversion to
jax.sharding.PartitionSpec for execution.  ``Partial`` never appears in a
materialized jax sharding — it marks pending all-reduces in the
propagation rules used by the analytic layer.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.mesh import MeshTopo


class Placement:
    """Base class for per-mesh-dim placements."""

    def is_shard(self) -> bool:
        return isinstance(self, Shard)


@dataclasses.dataclass(frozen=True)
class Shard(Placement):
    dim: int  # tensor dim that is split along this mesh dim

    def __repr__(self):
        return f"Shard({self.dim})"


@dataclasses.dataclass(frozen=True)
class Replicate(Placement):
    def __repr__(self):
        return "Replicate"


@dataclasses.dataclass(frozen=True)
class Partial(Placement):
    op: str = "sum"

    def __repr__(self):
        return f"Partial({self.op})"


REPLICATE = Replicate()
PARTIAL_SUM = Partial("sum")


@dataclasses.dataclass(frozen=True)
class ShardingSpec:
    """[P_1 .. P_n] over the mesh dims named in ``axes``."""

    axes: tuple[str, ...]
    placements: tuple[Placement, ...]

    def __post_init__(self):
        assert len(self.axes) == len(self.placements)

    def partition_spec(self, ndim: int) -> P:
        """Convert to a tensor-dim-major PartitionSpec.

        Mesh dims sharding the same tensor dim stack in mesh-dim order
        (matches the paper's two-level split, e.g. [Shard(0),Shard(1)] on
        (tp1,tp2) -> P(('tp1',), ('tp2',)) for a 2D weight).
        """
        per_dim: list[list[str]] = [[] for _ in range(ndim)]
        for axis, pl in zip(self.axes, self.placements):
            if isinstance(pl, Shard):
                if pl.dim >= ndim:
                    raise ValueError(f"Shard({pl.dim}) out of range for ndim={ndim}")
                per_dim[pl.dim].append(axis)
            elif isinstance(pl, Partial):
                raise ValueError("Partial cannot be materialized as a jax sharding")
        entries = [tuple(d) if len(d) > 1 else (d[0] if d else None) for d in per_dim]
        # Trim trailing Nones for canonical form.
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def named_sharding(self, mesh, ndim: int) -> NamedSharding:
        return NamedSharding(mesh, self.partition_spec(ndim))

    def shard_counts(self, topo: MeshTopo, ndim: int) -> tuple[int, ...]:
        """Per-tensor-dim total split factor."""
        counts = [1] * ndim
        for axis, pl in zip(self.axes, self.placements):
            if isinstance(pl, Shard):
                counts[pl.dim] *= topo.axis_size(axis)
        return tuple(counts)

    def local_shape(self, topo: MeshTopo, global_shape: Sequence[int]) -> tuple[int, ...]:
        counts = self.shard_counts(topo, len(global_shape))
        out = []
        for size, c in zip(global_shape, counts):
            if size % c:
                raise ValueError(f"dim of size {size} not divisible by {c}")
            out.append(size // c)
        return tuple(out)

    def partial_axes(self) -> tuple[str, ...]:
        return tuple(
            a for a, p in zip(self.axes, self.placements) if isinstance(p, Partial)
        )


def spec(axes: Sequence[str], *placements: Placement) -> ShardingSpec:
    return ShardingSpec(tuple(axes), tuple(placements))
