"""Version-compat shims between jax 0.4.x and 0.5+/0.6 APIs.

Every module in this repo that touches a version-sensitive jax surface
routes through here instead of importing from jax directly:

  shard_map    jax>=0.6 exports ``jax.shard_map`` with a ``check_vma``
               kwarg; 0.4.x has ``jax.experimental.shard_map.shard_map``
               with the same semantics under the older ``check_rep`` name.
  pcast        ``lax.pcast`` (varying-manual-axes cast) does not exist on
               0.4.x; the 0.4 replication checker infers the same typing,
               so the fallback is the identity.
  make_mesh    0.4.x ``jax.make_mesh``/``Mesh`` do not accept
               ``axis_types``; the kwarg is dropped there.
  AxisType     dummy enum stand-in on 0.4.x (only ``.Auto`` is used here).
  abstract_mesh  ``AbstractMesh`` takes ``(shape, names)`` on 0.5+ but a
               single ``((name, size), ...)`` tuple on 0.4.x.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Sequence

import jax

# --------------------------------------------------------------------------
# shard_map: jax.shard_map (>=0.6, check_vma) vs
# jax.experimental.shard_map.shard_map (0.4.x, check_rep).
# --------------------------------------------------------------------------

try:  # jax >= 0.6
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = set(inspect.signature(_shard_map).parameters)
_CHECK_KW = "check_vma" if "check_vma" in _SHARD_MAP_PARAMS else "check_rep"

#: True on jax 0.4.x/0.5.x, whose legacy replication checker predates the
#: vma rewrite: it has no rules for custom_vjp boundaries, so full train /
#: serve steps (gpipe_loss, ring collectives) cannot be statically typed
#: there even when numerically correct.  Callers building whole-step
#: shard_maps consult this to fall back to check=False on legacy jax.
LEGACY_REP_CHECKER = _CHECK_KW == "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
    """``jax.shard_map`` with the ``check_vma`` spelling on every version."""
    kw[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# --------------------------------------------------------------------------
# 0.4.x replication-checker fixes.  Two upstream bugs make check_rep=True
# reject valid programs there (both fixed by the 0.6 vma rewrite):
#   1. a multi-output primitive (top_k, sort, ...) whose inputs are all
#      constants gets a ``None`` rep from ``_standard_check`` and then
#      crashes ``_check_rep`` ("'NoneType' object is not iterable");
#   2. ``_scan_check`` does a single pass and requires the carry-in rep
#      (None for constant-initialised carries, e.g. ``jnp.zeros(())``) to
#      equal the inferred carry-out rep, instead of running the fixpoint
#      the rewrite pass itself uses.
# Patched only when running against the legacy checker.  NOTE: the patch
# applies process-wide on first `repro` import (the checker is module
# state in jax.experimental.shard_map).  It is strictly permissive: both
# fixes only affect programs the stock checker CRASHES or spuriously
# rejects on (multi-output-of-constants, constant-initialised scan
# carries); programs the stock checker accepts are typed identically.
# --------------------------------------------------------------------------


def _patch_legacy_rep_checker() -> None:
    if _CHECK_KW != "check_rep":
        return
    try:
        import jax.experimental.shard_map as _sm
        from jax._src import core as _core
        from jax._src.lax.control_flow import loops as _loops
        from jax._src.util import safe_map as _map
    except ImportError:  # internal layout moved; leave the checker alone
        return

    def _check_rep(mesh, jaxpr, in_rep):
        env: dict = {}

        def read(x):
            return env[x] if type(x) is _core.Var else None

        def write(v, val):
            env[v] = val

        _map(write, jaxpr.constvars, [set(mesh.axis_names)] * len(jaxpr.constvars))
        _map(write, jaxpr.invars, in_rep)
        last_used = _core.last_used(jaxpr)
        for e in jaxpr.eqns:
            rule = _sm._check_rules.get(
                e.primitive, functools.partial(_sm._rule_missing, e.primitive))
            out_rep = rule(mesh, *_map(read, e.invars), **e.params)
            if e.primitive.multiple_results:
                # fix (1): replicate a scalar set OR None across all outputs
                if type(out_rep) is set or out_rep is None:
                    out_rep = [out_rep] * len(e.outvars)
                _map(write, e.outvars, out_rep)
            else:
                write(e.outvars[0], out_rep)
            _core.clean_up_dead_vars(e, env, last_used)
        return _map(read, jaxpr.outvars)

    def _scan_check(mesh, *in_rep, jaxpr, num_consts, num_carry, **_):
        # fix (2): constants (rep None) are replicated everywhere; run the
        # same meet-fixpoint over the carry as the rewrite pass.
        top = set(mesh.axis_names)
        const_rep = list(in_rep[:num_consts])
        carry = [top if r is None else r
                 for r in in_rep[num_consts:num_consts + num_carry]]
        xs_rep = list(in_rep[num_consts + num_carry:])
        for _i in range(1 + num_carry):
            out_rep = _check_rep(mesh, jaxpr.jaxpr,
                                 [*const_rep, *carry, *xs_rep])
            carry_out = [top if r is None else r for r in out_rep[:num_carry]]
            new = [a & b for a, b in zip(carry, carry_out)]
            if new == carry:
                break
            carry = new
        return [*carry, *out_rep[num_carry:]]

    _sm._check_rep = _check_rep
    _sm._check_rules[_loops.scan_p] = _scan_check


_patch_legacy_rep_checker()


# --------------------------------------------------------------------------
# lax.pcast: identity fallback on 0.4.x (replication is inferred there).
# --------------------------------------------------------------------------

if hasattr(jax.lax, "pcast"):
    pcast = jax.lax.pcast
else:

    def pcast(x, axis_name, *, to: str = "varying"):  # noqa: ARG001
        return x


# --------------------------------------------------------------------------
# lax.axis_size: added after 0.4.x; psum of a python scalar is the classic
# statically-folded equivalent (returns size * 1 without tracing).
# --------------------------------------------------------------------------

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:

    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)


# --------------------------------------------------------------------------
# Mesh construction: axis_types exists only on 0.5+.
# --------------------------------------------------------------------------

if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
    _HAS_AXIS_TYPES = True
else:
    class AxisType:  # minimal stand-in: the repo only references .Auto
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _HAS_AXIS_TYPES = False


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Sequence[jax.Device] | None = None,
    axis_types: Any = None,
) -> jax.sharding.Mesh:
    kw: dict[str, Any] = {}
    if devices is not None:
        kw["devices"] = devices
    if _HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(tuple(axis_shapes))
        kw["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def mesh_from_devices(
    devices: Sequence[jax.Device],
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
) -> jax.sharding.Mesh:
    """Mesh over an explicit device array (axis_types dropped on 0.4.x)."""
    import numpy as np

    arr = np.asarray(devices).reshape(tuple(axis_shapes))
    if _HAS_AXIS_TYPES:
        return jax.sharding.Mesh(
            arr, tuple(axis_names),
            axis_types=(AxisType.Auto,) * len(tuple(axis_shapes)))
    return jax.sharding.Mesh(arr, tuple(axis_names))


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """jax.sharding.AbstractMesh across the 0.4/0.5 signature change."""
    try:  # 0.5+: AbstractMesh(shape, names)
        return jax.sharding.AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:  # 0.4.x: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(
            tuple(zip(tuple(axis_names), tuple(axis_shapes))))
