"""Explicit latency-hiding ring collectives for ATP boundaries (§4.1+).

The seed's chunk-based overlapping split the batch and hoped XLA's
latency-hiding scheduler would interleave each chunk's all-reduce with the
next chunk's GEMM.  This module makes the overlap *structural* instead:

  ring_all_reduce / ring_reduce_scatter / ring_all_gather
      d-1 step ``lax.ppermute`` rings (all-reduce optionally bidirectional:
      half the payload circles each direction, doubling link utilisation on
      full-duplex fabrics).  Each is wrapped in ``jax.custom_vjp`` so the
      backward pass runs the *mirrored* ring schedule instead of whatever
      monolithic collective AD would insert:

          all_reduce^T     = all_reduce
          reduce_scatter^T = all_gather
          all_gather^T     = reduce_scatter

  overlap_matmul_ar
      chunk-pipelined GEMM + ring all-reduce: chunk k's ring steps are
      issued between chunk k's and chunk k+1's GEMMs, so they are
      data-independent of every later GEMM — a collective-matmul pipeline,
      not a scheduler prayer.

  overlap_matmul_rs / overlap_matmul_ag
      true collective matmuls for the sequence-parallel boundary: the GEMM
      is decomposed over ring steps.  ``rs``: step t computes the block
      destined t hops away and accumulates into the rotating partial-sum
      buffer (== psum_scatter(x @ w)).  ``ag``: the local shard's GEMM runs
      while the raw activations rotate; each arriving shard is multiplied
      immediately (== all_gather(x) @ w).  Their VJPs are each other's
      schedule plus a rank-local weight-gradient GEMM.

Everything runs INSIDE shard_map on local shards.  ``ring_all_reduce``
falls back to monolithic ``lax.psum`` when no dimension divides by the
ring size; the scatter/gather ops require divisibility of the scatter
dim exactly like their ``lax`` counterparts (tiled psum_scatter) and
raise a clear error otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

#: wire dtypes the boundary collectives understand.  "bf16" is the
#: full-width baseline (whatever dtype the activations carry); "int8" and
#: "fp8" quantize the payload before it hits the ring/psum and dequantize
#: in the chunk epilogue.  The same names are the ``wire_dtype`` knob on
#: SegmentPlan / DecodePlan / ParallelPlan and the per-dtype byte
#: accounting in core.cost_model.
WIRE_DTYPES = ("bf16", "int8", "fp8")

#: fp8-e4m3 when this jax build has it; the quantizers fall back to the
#: int8 grid otherwise (gated, never an import error)
_FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)

#: symmetric quantization ceilings: int8 grid is +-127, fp8-e4m3 +-448
_INT8_QMAX = 127.0
_FP8_QMAX = 448.0


# ---------------------------------------------------------------------------
# Ring plumbing.  `axis_size` is threaded statically (the ATPContext knows
# mesh sizes without touching the axis env).
# ---------------------------------------------------------------------------


def _perm_next(d: int):
    return [(i, (i + 1) % d) for i in range(d)]


def _perm_prev(d: int):
    return [(i, (i - 1) % d) for i in range(d)]


def _take_block(xs, i, d):
    """xs: [d, ...] stacked blocks; i: traced block index (mod d)."""
    return lax.dynamic_index_in_dim(xs, jnp.mod(i, d), axis=0, keepdims=False)


def _split_stack(x, d: int, dim: int):
    return jnp.stack(jnp.split(x, d, axis=dim))


def _ring_reduce_scatter_raw(x, axis, d: int, dim: int, reverse: bool = False):
    """Rank i of the ring ends with block i of the full sum (tiled layout).

    The accumulator starts at block (i-1), travels to the next rank each
    step, and picks up that rank's matching local block; after d-1 hops it
    lands on its home rank fully reduced.
    """
    if d == 1:
        return x
    # the scope name is load-bearing: repro.analysis reads `ring_rs[axis]`
    # regions out of the jaxpr name stack for attribution + vma semantics
    with jax.named_scope(f"ring_rs[{axis}]"):
        xs = _split_stack(x, d, dim)
        idx = lax.axis_index(axis)
        sgn = -1 if reverse else 1
        perm = _perm_prev(d) if reverse else _perm_next(d)
        acc = _take_block(xs, idx - sgn, d)
        for t in range(1, d):
            acc = lax.ppermute(acc, axis, perm)
            acc = acc + _take_block(xs, idx - sgn * (1 + t), d)
        return acc


def _ring_all_gather_raw(x, axis, d: int, dim: int, reverse: bool = False):
    """Rank i's shard ends up in slot i of the concatenated output.

    ``reverse`` circulates the opposite direction (the bidirectional
    all-reduce's second half); after t hops the payload originated t
    ranks behind (ahead, when reversed)."""
    if d == 1:
        return x
    with jax.named_scope(f"ring_ag[{axis}]"):
        idx = lax.axis_index(axis)
        sgn = -1 if reverse else 1
        perm = _perm_prev(d) if reverse else _perm_next(d)
        buf = jnp.zeros((d,) + x.shape, x.dtype)
        buf = lax.dynamic_update_index_in_dim(buf, x, idx, axis=0)
        cur = x
        for t in range(1, d):
            cur = lax.ppermute(cur, axis, perm)
            buf = lax.dynamic_update_index_in_dim(
                buf, cur, jnp.mod(idx - sgn * t, d), axis=0)
        return jnp.concatenate([buf[i] for i in range(d)], axis=dim)


def _ring_all_reduce_raw(x, axis, d: int, bidirectional: bool = True):
    """reduce-scatter + all-gather ring; halves circle opposite directions
    when the payload splits cleanly (bidirectional ring)."""
    if d == 1:
        return x
    dim = _pick_ring_dim(x.shape, d)
    with jax.named_scope(f"ring_ar[{axis}]"):
        if dim is None:
            return lax.psum(x, axis)  # no dim divides: monolithic fallback
        if bidirectional and x.shape[dim] % (2 * d) == 0:
            lo, hi = jnp.split(x, 2, axis=dim)
            lo = _ring_reduce_scatter_raw(lo, axis, d, dim, reverse=False)
            hi = _ring_reduce_scatter_raw(hi, axis, d, dim, reverse=True)
            lo = _ring_all_gather_raw(lo, axis, d, dim)
            hi = _ring_all_gather_raw(hi, axis, d, dim, reverse=True)
            return jnp.concatenate([lo, hi], axis=dim)
        y = _ring_reduce_scatter_raw(x, axis, d, dim)
        return _ring_all_gather_raw(y, axis, d, dim)


def _pick_ring_dim(shape, d: int) -> int | None:
    """Largest dimension divisible by the ring size (None if none is)."""
    best, best_size = None, 0
    for i, s in enumerate(shape):
        if s % d == 0 and s > best_size:
            best, best_size = i, s
    return best


# ---------------------------------------------------------------------------
# custom_vjp wrappers: mirrored ring schedules in the backward pass.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ring_all_reduce(x, axis, axis_size):
    """== lax.psum(x, axis), decomposed into a (bidirectional) ppermute ring."""
    return _ring_all_reduce_raw(x, axis, axis_size)


def _ar_fwd(x, axis, axis_size):
    return _ring_all_reduce_raw(x, axis, axis_size), None


def _ar_bwd(axis, axis_size, _res, ct):
    # Sum the cotangents over the ring: correct under the per-rank
    # partial-cotangent convention that applies to this op on every jax
    # version — legacy (0.4.x) shard_map transposes lax.psum the same way
    # (tests pin the equivalence there), and under the 0.6 vma system the
    # ppermute decomposition types the output *varying* (unlike lax.psum's
    # invariant output), so each rank's cotangent is a per-rank partial and
    # the cross-ring sum is still the right transpose.
    return (_ring_all_reduce_raw(ct, axis, axis_size),)


ring_all_reduce.defvjp(_ar_fwd, _ar_bwd)


def _require_divisible(size: int, d: int, what: str) -> None:
    if size % d:
        raise ValueError(
            f"{what}: scatter dim size {size} must be divisible by the "
            f"ring size {d} (same constraint as tiled lax.psum_scatter)")


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def ring_reduce_scatter(x, axis, axis_size, dim):
    """== lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)."""
    _require_divisible(x.shape[dim], axis_size, "ring_reduce_scatter")
    return _ring_reduce_scatter_raw(x, axis, axis_size, dim)


def _rs_fwd(x, axis, axis_size, dim):
    return ring_reduce_scatter(x, axis, axis_size, dim), None


def _rs_bwd(axis, axis_size, dim, _res, ct):
    return (ring_all_gather(ct, axis, axis_size, dim),)


ring_reduce_scatter.defvjp(_rs_fwd, _rs_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def ring_all_gather(x, axis, axis_size, dim):
    """== lax.all_gather(x, axis, axis=dim, tiled=True)."""
    return _ring_all_gather_raw(x, axis, axis_size, dim)


def _ag_fwd(x, axis, axis_size, dim):
    return _ring_all_gather_raw(x, axis, axis_size, dim), None


def _ag_bwd(axis, axis_size, dim, _res, ct):
    return (ring_reduce_scatter(ct, axis, axis_size, dim),)


ring_all_gather.defvjp(_ag_fwd, _ag_bwd)


# ---------------------------------------------------------------------------
# Quantized wire: symmetric scale-shared int8 / fp8-e4m3 boundary payloads.
#
# The reduction itself must stay exact, so every rank in the group shares
# ONE scale (pmax of the local amax): the wire then carries values on the
# int8 (or fp8) grid, held in f32 so the existing ring/psum machinery sums
# them bit-exactly (<= 16 ranks x 127 is far inside f32's exact-integer
# range), and a single ``* scale`` dequantizes the reduced result in the
# chunk epilogue — riding the same position the bias add already does.
# Backward schedules are mirrored AND quantized: the cotangent ring is the
# same wire, so it pays (and saves) the same bytes — a straight-through
# estimator through the quantization grid.
# ---------------------------------------------------------------------------


def wire_quantize(x, axis, wire_dtype: str):
    """Quantize a boundary payload onto the shared-scale wire grid.

    Returns ``(q, scale)``: ``q`` holds the grid values in f32 (summable
    exactly by the unmodified collectives), ``scale`` is shared across the
    ``axis`` group (``pmax`` of the local amax) so every rank dequantizes
    the reduced tensor identically.  fp8 uses the e4m3 grid when this jax
    build ships the dtype and falls back to the int8 grid otherwise.
    """
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(
            f"wire_dtype must be one of {WIRE_DTYPES}, got {wire_dtype!r}")
    with jax.named_scope(f"wireq[{axis}]"):
        xf = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(xf))
        if axis is not None:
            amax = lax.pmax(amax, axis)
        if wire_dtype == "fp8" and _FP8_DTYPE is not None:
            scale = jnp.maximum(amax / _FP8_QMAX, 1e-12)
            q = (xf / scale).astype(_FP8_DTYPE).astype(jnp.float32)
        else:
            scale = jnp.maximum(amax / _INT8_QMAX, 1e-12)
            q = jnp.clip(jnp.round(xf / scale), -_INT8_QMAX, _INT8_QMAX)
        return q, scale


def _quant_ar_raw(x, axis, d, wire_dtype):
    # `quant[axis]` scopes mark every collective that carries a quantized
    # payload — repro.analysis prices those at 1 wire byte per element
    with jax.named_scope(f"quant[{axis}]"):
        q, scale = wire_quantize(x, axis, wire_dtype)
        return (_ring_all_reduce_raw(q, axis, d) * scale).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def quant_ring_all_reduce(x, axis, axis_size, wire_dtype):
    """~= lax.psum(x, axis) over a quantized ring wire.

    quantize (shared scale) -> ppermute ring on grid values -> dequantize.
    Backward runs the SAME quantized ring on the cotangent (mirrored
    schedule, straight-through estimator through the grid)."""
    return _quant_ar_raw(x, axis, axis_size, wire_dtype)


def _qar_fwd(x, axis, axis_size, wire_dtype):
    return _quant_ar_raw(x, axis, axis_size, wire_dtype), None


def _qar_bwd(axis, axis_size, wire_dtype, _res, ct):
    return (_quant_ar_raw(ct, axis, axis_size, wire_dtype),)


quant_ring_all_reduce.defvjp(_qar_fwd, _qar_bwd)


def _quant_psum_raw(x, axis, wire_dtype):
    with jax.named_scope(f"quant[{axis}]"):
        q, scale = wire_quantize(x, axis, wire_dtype)
        return (lax.psum(q, axis) * scale).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def quant_psum(x, axis, wire_dtype):
    """~= lax.psum(x, axis) with the payload quantized on the wire (the
    monolithic-collective counterpart of :func:`quant_ring_all_reduce`)."""
    return _quant_psum_raw(x, axis, wire_dtype)


def _qpsum_fwd(x, axis, wire_dtype):
    return _quant_psum_raw(x, axis, wire_dtype), None


def _qpsum_bwd(axis, wire_dtype, _res, ct):
    return (_quant_psum_raw(ct, axis, wire_dtype),)


quant_psum.defvjp(_qpsum_fwd, _qpsum_bwd)


def _quant_rs_raw(x, axis, d, dim, wire_dtype, ring):
    with jax.named_scope(f"quant[{axis}]"):
        q, scale = wire_quantize(x, axis, wire_dtype)
        if ring:
            y = _ring_reduce_scatter_raw(q, axis, d, dim)
        else:
            y = lax.psum_scatter(q, axis, scatter_dimension=dim, tiled=True)
        return (y * scale).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def quant_reduce_scatter(x, axis, axis_size, dim, wire_dtype, ring=False):
    """~= psum_scatter(x, axis, dim, tiled) on a quantized wire; the
    sequence-parallel row boundary under quantization.  Backward is the
    mirrored all-gather of the (re-quantized) cotangent."""
    if ring:
        _require_divisible(x.shape[dim], axis_size, "quant_reduce_scatter")
    return _quant_rs_raw(x, axis, axis_size, dim, wire_dtype, ring)


def _qrs_fwd(x, axis, axis_size, dim, wire_dtype, ring):
    return _quant_rs_raw(x, axis, axis_size, dim, wire_dtype, ring), None


def _qrs_bwd(axis, axis_size, dim, wire_dtype, ring, _res, ct):
    # all-gather moves bytes but reduces nothing: quantize the cotangent
    # for the wire, gather the grid values, dequantize locally
    with jax.named_scope(f"quant[{axis}]"):
        q, scale = wire_quantize(ct, axis, wire_dtype)
        if ring:
            g = _ring_all_gather_raw(q, axis, axis_size, dim)
        else:
            g = lax.all_gather(q, axis, axis=dim, tiled=True)
        return ((g * scale).astype(ct.dtype),)


quant_reduce_scatter.defvjp(_qrs_fwd, _qrs_bwd)


# ---------------------------------------------------------------------------
# Collective matmuls.
# ---------------------------------------------------------------------------


def _gemm(x, w):
    return jnp.einsum("...k,kn->...n", x, w)


def overlap_matmul_ar(x, w, axis, axis_size, chunks: int, b=None,
                      wire_dtype: str = "bf16"):
    """Chunk-pipelined ``psum(x @ w, axis)`` (+ fused per-chunk bias).

    Program order interleaves chunk k's ring with chunk k+1's GEMM; the two
    are data-independent, so the ring's ppermute chain overlaps the GEMM.
    Uneven leading dimensions fall back to ``jnp.array_split`` chunks.

    ``wire_dtype`` != "bf16" swaps each chunk's ring for the quantized
    wire: scale-per-chunk (every chunk computes its own shared amax), with
    the dequant multiply landing in the per-chunk epilogue directly before
    the bias add it already carries.
    """
    def _ar(y):
        if wire_dtype != "bf16":
            return quant_ring_all_reduce(y, axis, axis_size, wire_dtype)
        return ring_all_reduce(y, axis, axis_size)

    if axis is None:
        y = _gemm(x, w)
        return y + b if b is not None else y
    c = max(1, min(chunks, x.shape[0]))
    if c <= 1:
        y = _ar(_gemm(x, w))
        return y + b if b is not None else y
    xs = (jnp.split(x, c, axis=0) if x.shape[0] % c == 0
          else jnp.array_split(x, c, axis=0))

    def _epilogue(y):
        return y + b if b is not None else y

    ys = []
    pending = None
    for xc in xs:
        g = _gemm(xc, w)
        if pending is not None:
            ys.append(_epilogue(_ar(pending)))
        pending = g
    ys.append(_epilogue(_ar(pending)))
    return jnp.concatenate(ys, axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def overlap_matmul_rs(x, w, axis, axis_size, dim):
    """== lax.psum_scatter(x @ w, axis, scatter_dimension=dim, tiled=True).

    Decomposed per ring step: step t computes the GEMM for the output block
    destined t hops downstream and adds it to the rotating accumulator, so
    every ppermute is concurrent with the next block's GEMM.
    """
    return _rs_matmul_raw(x, w, axis, axis_size, dim)


def _rs_matmul_raw(x, w, axis, d, dim):
    if axis is None or d == 1:
        return _gemm(x, w)
    _require_divisible(x.shape[dim], d, "overlap_matmul_rs")
    with jax.named_scope(f"cm_rs[{axis}]"):
        xs = _split_stack(x, d, dim)
        idx = lax.axis_index(axis)
        acc = _gemm(_take_block(xs, idx - 1, d), w)
        perm = _perm_next(d)
        for t in range(1, d):
            acc = lax.ppermute(acc, axis, perm)
            acc = acc + _gemm(_take_block(xs, idx - 1 - t, d), w)
        return acc


def _rs_matmul_fwd(x, w, axis, axis_size, dim):
    return _rs_matmul_raw(x, w, axis, axis_size, dim), (x, w)


def _rs_matmul_bwd(axis, axis_size, dim, res, ct):
    x, w = res
    # mirrored schedule: ring-all-gather the scattered cotangent while both
    # backward GEMMs (dx blockwise, dw accumulated) run per arriving block.
    dx, ct_full = _ag_two_matmuls(ct, w.T, x, axis, axis_size, dim)
    dw = jnp.einsum("...k,...n->kn", x, ct_full)
    return dx, dw


overlap_matmul_rs.defvjp(_rs_matmul_fwd, _rs_matmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def overlap_matmul_ag(x, w, axis, axis_size, dim):
    """== lax.all_gather(x, axis, axis=dim, tiled=True) @ w.

    The local shard's GEMM runs while the raw activations rotate around the
    ring; each arriving shard is multiplied immediately.
    """
    return _ag_matmul_raw(x, w, axis, axis_size, dim)


def _ag_matmul_raw(x, w, axis, d, dim):
    if axis is None or d == 1:
        return _gemm(x, w)
    with jax.named_scope(f"cm_ag[{axis}]"):
        idx = lax.axis_index(axis)
        g0 = _gemm(x, w)
        buf = jnp.zeros((d,) + g0.shape, g0.dtype)
        buf = lax.dynamic_update_index_in_dim(buf, g0, idx, axis=0)
        cur = x
        perm = _perm_next(d)
        for t in range(1, d):
            cur = lax.ppermute(cur, axis, perm)
            buf = lax.dynamic_update_index_in_dim(
                buf, _gemm(cur, w), jnp.mod(idx - t, d), axis=0)
        return jnp.concatenate([buf[i] for i in range(d)], axis=dim)


def _ag_matmul_fwd(x, w, axis, axis_size, dim):
    return _ag_matmul_raw(x, w, axis, axis_size, dim), (x, w)


def _ag_matmul_bwd(axis, axis_size, dim, res, ct):
    x, w = res
    # dx: reduce-scatter collective matmul (the mirror of the forward AG);
    # dw: re-gather x (saved sharded, Megatron-style) for the local GEMM.
    dx = _rs_matmul_raw(ct, w.T, axis, axis_size, dim)
    x_full = (x if axis is None or axis_size == 1
              else _ring_all_gather_raw(x, axis, axis_size, dim))
    dw = jnp.einsum("...k,...n->kn", x_full, ct)
    return dx, dw


overlap_matmul_ag.defvjp(_ag_matmul_fwd, _ag_matmul_bwd)


def _ag_two_matmuls(ct, wt, x, axis, d, dim):
    """Ring all-gather of `ct` fused with both backward GEMMs of the
    rs-matmul: per arriving block j, emit dx_j = ct_j @ w^T and rebuild the
    gathered cotangent for the weight-gradient GEMM.  Returns (dx, ct_full).
    """
    if axis is None or d == 1:
        return _gemm(ct, wt), ct
    with jax.named_scope(f"cm_ag[{axis}]"):
        idx = lax.axis_index(axis)
        dx0 = _gemm(ct, wt)
        dxs = jnp.zeros((d,) + dx0.shape, dx0.dtype)
        cts = jnp.zeros((d,) + ct.shape, ct.dtype)
        dxs = lax.dynamic_update_index_in_dim(dxs, dx0, idx, axis=0)
        cts = lax.dynamic_update_index_in_dim(cts, ct, idx, axis=0)
        cur = ct
        perm = _perm_next(d)
        for t in range(1, d):
            cur = lax.ppermute(cur, axis, perm)
            j = jnp.mod(idx - t, d)
            dxs = lax.dynamic_update_index_in_dim(
                dxs, _gemm(cur, wt), j, axis=0)
            cts = lax.dynamic_update_index_in_dim(cts, cur, j, axis=0)
        dx = jnp.concatenate([dxs[i] for i in range(d)], axis=dim)
        ct_full = jnp.concatenate([cts[i] for i in range(d)], axis=dim)
        return dx, ct_full
