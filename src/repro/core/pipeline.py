"""GPipe-style pipeline parallelism over a mesh axis (the `pod` axis).

Inter-pod DCN links are slow relative to ICI, which is the textbook place
for pipeline parallelism: only activations at stage boundaries cross pods
(vs full gradients for inter-pod DP).  This module implements the
microbatched forward schedule inside shard_map:

  - each rank of `axis` holds ONE stage's parameters
  - microbatches enter at stage 0; stage boundaries move activations with
    collective_permute (shift-by-one ring, no wraparound)
  - the classic GPipe bubble: S-1 warmup + S-1 drain ticks; every stage
    computes every tick (idle ticks process zeros — wasted FLOPs are the
    bubble, exactly as on real hardware)
  - fully differentiable (ppermute transposes to the reverse shift), so
    jax.grad implements the 1F1B-equivalent backward automatically

Used with DP/TP inside each stage: the pipeline axis composes with the
ATP mesh (`atp_topo(..., pods=S)` + stage_fn built from ATP layers).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compat


def gpipe_forward(
    stage_fn: Callable,       # (stage_params, x_micro) -> y_micro
    stage_params,             # this rank's stage params (sliced by spec)
    x_micro,                  # [M, ...] microbatches (read at stage 0)
    axis: str,
):
    """Returns [M, ...] pipeline outputs (valid on the LAST stage; other
    stages return zeros — callers typically ppermute/psum the result or
    compute the loss on the last stage and psum it)."""
    S = compat.axis_size(axis)
    idx = lax.axis_index(axis)
    M = x_micro.shape[0]
    T = M + S - 1                      # total ticks incl. bubble
    micro_shape = x_micro.shape[1:]

    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    def tick(carry, t):
        buf, outs = carry
        # stage 0 ingests microbatch t (zeros once drained)
        take = jnp.clip(t, 0, M - 1)
        first_in = jnp.where(t < M, 1.0, 0.0) * \
            lax.dynamic_index_in_dim(x_micro, take, axis=0, keepdims=False)
        inp = jnp.where(idx == 0, first_in, buf)
        y = stage_fn(stage_params, inp)
        # last stage emits microbatch t-(S-1)
        emit_t = t - (S - 1)
        ok = (emit_t >= 0) & (emit_t < M) & (idx == S - 1)
        outs = lax.dynamic_update_index_in_dim(
            outs,
            jnp.where(ok, y, lax.dynamic_index_in_dim(
                outs, jnp.clip(emit_t, 0, M - 1), axis=0, keepdims=False)),
            jnp.clip(emit_t, 0, M - 1), axis=0)
        # shift activations to the next stage
        buf = lax.ppermute(y, axis, fwd_perm)
        return (buf, outs), None

    # init carries varying over `axis` to match the tick outputs (vma)
    buf0 = compat.pcast(jnp.zeros(micro_shape, x_micro.dtype), axis, to="varying")
    outs0 = compat.pcast(jnp.zeros((M,) + micro_shape, x_micro.dtype), axis,
                      to="varying")
    (_, outs), _ = lax.scan(tick, (buf0, outs0), jnp.arange(T))
    return outs


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_of_locals(x, axis):
    """psum whose backward is the identity.

    For a loss of the form ``global = sum over ranks of local_r`` the true
    cotangent of every ``local_r`` is the global cotangent itself.  Plain
    ``lax.psum`` only transposes that way under the 0.6 vma type system; on
    0.4.x its transpose inserts another psum (scaling grads by the axis
    size), so the correct rule is pinned here explicitly.
    """
    return lax.psum(x, axis)


def _psum_of_locals_fwd(x, axis):
    return lax.psum(x, axis), None


def _psum_of_locals_bwd(axis, _res, ct):
    return (ct,)


_psum_of_locals.defvjp(_psum_of_locals_fwd, _psum_of_locals_bwd)


def gpipe_loss(
    stage_fn: Callable,
    loss_fn: Callable,        # (y_micro) -> scalar (computed on last stage)
    stage_params,
    x_micro,
    axis: str,
):
    """Pipeline forward + last-stage loss, psum'd to every stage (so
    jax.grad drives the full pipeline backward)."""
    S = compat.axis_size(axis)
    idx = lax.axis_index(axis)
    outs = gpipe_forward(stage_fn, stage_params, x_micro, axis)
    local = jnp.where(idx == S - 1, loss_fn(outs), 0.0)
    return _psum_of_locals(local, axis)
