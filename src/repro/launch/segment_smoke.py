"""Heterogeneous-plan smoke gate (``make segment-smoke``).

Exercises the v2 per-segment strategy pipeline on the simulated 8-device
host mesh with a mixed dense-prefix + MoE stack (DeepSeek/DBRX-shaped) and
exits non-zero on any mismatch:

    per-segment search (model=cfg) -> save JSON -> load -> per-segment
    contexts identical -> train runs with DIFFERENT knobs per segment
    (dense: seq_parallel, MoE: masked) -> decode masks seq_parallel
    everywhere -> mixed-plan loss matches the all-replicated loss.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.segment_smoke
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import tempfile

import jax
import jax.numpy as jnp


def check(ok: bool, what: str):
    if not ok:
        print(f"[segment-smoke] FAIL: {what}")
        sys.exit(1)
    print(f"[segment-smoke] ok: {what}")


def main():
    from repro.configs.base import ModelConfig, MoEConfig, segments
    from repro.core.atp import SegmentPlan
    from repro.core.plan import ParallelPlan, plan_search
    from repro.data.pipeline import DataConfig, TokenSource
    from repro.launch.steps import build_decode_step, build_train_step

    ndev = len(jax.devices())
    check(ndev >= 8, f"8 simulated devices attached (have {ndev})")

    # DBRX-style MoE stack with a DeepSeek-style dense prefix: two segment
    # kinds with genuinely different comm profiles
    cfg = ModelConfig(
        name="smoke-mix", family="moe", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      first_dense_layers=1))
    kinds = [s.kind for s in segments(cfg)]
    check(kinds == ["dense", "moe"], f"mixed segments {kinds}")

    # 1. heterogeneous search: one SegmentPlan per segment, and the MoE
    #    segment must never be offered seq_parallel
    res = plan_search("ic3", 4, model=cfg, batch=8, seq=32, dp=2,
                      chunks_options=(1, 2))
    check(all(len(p.segments) == 2 for p in res.ranked),
          "every ranked plan carries per-segment knobs")
    check(all(not p.segment_plan("moe").seq_parallel for p in res.ranked),
          "search never assigns seq_parallel to the MoE segment")

    # 2. force a maximally heterogeneous plan (the search is free to pick
    #    homogeneous knobs on a toy workload; the gate must exercise the
    #    threading): dense = chunks 2 + seq-parallel, moe = chunks 1
    plan = res.best.with_(
        d1=2, d2=2,
        segments=(SegmentPlan("dense", chunks=2, seq_parallel=True),
                  SegmentPlan("moe", chunks=1)))
    with tempfile.TemporaryDirectory() as td:
        path = plan.save(os.path.join(td, "plan.json"))
        loaded = ParallelPlan.load(path)
    check(loaded == plan, "v2 plan JSON round-trip is exact")
    check("segments[" in loaded.describe(), f"describe: {loaded.describe()}")

    # 3. per-segment knobs reach the builders
    t_step, t_info = build_train_step(cfg, plan=loaded)
    dctx = t_info.ctx.for_segment("dense")
    mctx = t_info.ctx.for_segment("moe")
    check((dctx.chunks, dctx.seq_parallel) == (2, True),
          "train dense segment: chunks=2 seq_parallel=True")
    check((mctx.chunks, mctx.seq_parallel) == (1, False),
          "train moe segment: chunks=1 seq_parallel masked")
    d_step, d_info = build_decode_step(cfg, B=4, s_max=16, plan=loaded)
    check(not any(s.seq_parallel for s in d_info.ctx.segment_plans),
          "decode masks seq_parallel in every segment plan")

    # 4. static conformance: the mixed-knob builds must emit exactly the
    #    per-segment collectives the v2 plan priced (dense seq-parallel
    #    reduce-scatters, MoE all-to-alls, decode masking), with every
    #    out_spec replication claim proven
    from repro.analysis import assert_step_conforms
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import batch_struct
    from repro.models import lm
    from repro.optim import adamw

    aparams = lm.abstract_params(cfg)
    aopt = adamw.init_opt_state(aparams, t_info.pspecs, t_info.ctx, "zero1",
                                abstract=True)
    abatch = batch_struct(cfg, ShapeConfig("x", 32, 8, "train"), "train")
    assert_step_conforms(t_step, cfg, loaded, "train", 8, 32,
                         aparams, aopt, abatch)
    acaches, _ = lm.init_decode_caches(cfg, d_info.ctx, 4, 16, abstract=True)
    assert_step_conforms(d_step, cfg, loaded, "decode", 4, 1, aparams,
                         jax.ShapeDtypeStruct((4, 1), jnp.int32),
                         jax.ShapeDtypeStruct((), jnp.int32), acaches)
    check(True, "mixed-plan train + decode builds conform (static lint)")

    # 5. three real training steps under the mixed plan, and loss parity
    #    with the all-replicated plan (sequence parallelism is a layout
    #    change, not a math change)

    def run3(p):
        step, info = build_train_step(cfg, plan=p)
        src = TokenSource(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     global_batch=8))
        params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        opt = adamw.init_opt_state(params, info.pspecs, info.ctx, "zero1")
        params = jax.device_put(params, info.sharding(info.pspecs))
        opt = jax.device_put(opt, info.sharding(info.ospecs))
        losses = []
        for i in range(3):
            batch = jax.device_put(
                {k: jnp.asarray(v) for k, v in src.global_batch(i).items()},
                info.sharding(info.bspecs))
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
        return losses

    mixed = run3(loaded)
    check(all(jnp.isfinite(jnp.asarray(mixed))),
          f"3-step train under {loaded.describe()}: losses {mixed}")
    flat = run3(loaded.with_(segments=(
        SegmentPlan("dense", chunks=1), SegmentPlan("moe", chunks=1))))
    close = all(abs(a - b) < 1e-4 * max(1.0, abs(b))
                for a, b in zip(mixed, flat))
    check(close, f"mixed-plan losses match replicated plan: {mixed} ~ {flat}")
    print("[segment-smoke] PASS")


if __name__ == "__main__":
    main()
