"""Training launcher: ATP plan search -> mesh -> fault-tolerant loop.

The strategy is a ParallelPlan artifact end to end:

    # search (optionally after on-mesh calibration), save, train
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --steps 200 --dp 2 --d1 2 --d2 2 --seq 128 --batch 8 \
        [--auto-atp [--calibrate]] [--save-plan plan.json]

    # re-apply a saved plan bit-for-bit (train or serve)
    ... -m repro.launch.train --arch llama3-8b --plan plan.json

Device count comes from the environment (single host: set
XLA_FLAGS=--xla_force_host_platform_device_count=N before launch).
"""
from __future__ import annotations

import argparse
import logging
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.core import comm_matrix
from repro.core.calibrate import calibrate_mesh, recalibrate_surviving
from repro.core.cost_model import LayerCommProfile
from repro.core.plan import ParallelPlan, plan_search, replan_elastic
from repro.data.pipeline import DataConfig, TokenSource
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.optim import adamw
from repro.runtime.membership import SingleObserverMembership
from repro.runtime.trainer import Trainer, TrainerConfig

log = logging.getLogger("repro.train")


def comm_profile(cfg) -> LayerCommProfile:
    """Generalized Eq.2 coefficients for this architecture's dense block
    (one source of truth: the per-kind constructor in the cost model)."""
    return LayerCommProfile.dense(cfg)


def pick_plan(cfg, tp: int, seq: int, batch: int, topology: str = "v5e",
              dp: int = 1, calibrate: bool = False, overlap: bool = True):
    """Search the plan space for this workload (optionally calibrated).

    The default path is the heterogeneous per-segment search
    (``plan_search(model=cfg)``): each model segment gets its own
    (chunks, seq_parallel) against its per-kind comm profile over the
    shared mesh.  ``overlap=False`` restricts to the seed Eq. 2 space —
    the exact degradation path the acceptance tests pin down.
    """
    calib = None
    if calibrate:
        matrix = comm_matrix.PRESETS[topology]()
        calib = calibrate_mesh(tp, matrix)
        log.info("on-mesh calibration (%d factorizations): %s",
                 len(calib), {k: (round(e.b1, 2), round(e.b2, 2))
                              for k, e in calib.entries})
    if not overlap:
        return plan_search(topology, tp, layers=cfg.num_layers, batch=batch,
                           seq=seq, profile=comm_profile(cfg), dp=dp,
                           calibration=calib, chunks_options=(1,),
                           seq_parallel_options=(False,),
                           algo="rabenseifner", alpha_s=0.0)
    return plan_search(topology, tp, model=cfg, batch=batch, seq=seq,
                       dp=dp, calibration=calib)


def make_elastic_trainer(cfg, plan: ParallelPlan, opt_cfg, trainer_cfg,
                         source, *, batch: int, seq: int,
                         membership=None, devices_fn=None,
                         recalibrate: bool = True, measure=None,
                         recalib_deadline_s: float | None = None):
    """Wire plan -> builders -> fault-tolerant Trainer, elastic end to end.

    The recovery loop on a shrunken device pool is *complete* (the PR-2/3
    deferral): (1) ``recalibrate_surviving`` re-measures (B1,B2)/alpha_s/
    boundary latency for factorizations of the surviving TP degree and
    merges them into the carried table, (2) ``replan_elastic`` re-searches
    the surviving mesh ranking with those fresh numbers (the re-planned
    artifact carries no ``calibration: stale`` tag), (3) the rebuilt step's
    shardings are returned to the Trainer so the checkpoint restore lands
    params/opt_state sharded on the new (d1, d2) mesh instead of
    replicated on the default device.

    ``membership`` answers *what pool survived, and is this host the
    elected re-planner* — a ``runtime.membership.MembershipRuntime`` over
    a lease/heartbeat fabric (recovery waits for a converged, epoch-
    numbered, quorum-committed view, and only the elected planner runs
    the re-search), or any object with the same ``converged_view()/
    devices()/is_planner()`` surface.  ``devices_fn`` is the DEPRECATED
    PR-4 single-observer poll, kept behind
    ``SingleObserverMembership`` with a loud warning; default (neither
    given) is the single-observer view of ``jax.devices``.

    ``recalibrate=False`` skips the on-mesh micro-benchmarks (the
    re-search then ranks with the stale-tagged table, the pre-PR-4
    behavior).  ``measure`` forwards to ``recalibrate_surviving``
    (injectable benchmark for tests).  ``recalib_deadline_s`` budgets the
    recovery micro-benchmarks: most-sensitive factorizations measured
    first, the rest degraded to carried/analytic entries when the
    deadline runs out (provenance recorded in the plan).

    Returns ``(trainer, live)`` — ``live`` is the mutable holder the
    closures read, so callers can observe the post-recovery plan/step/info.
    """
    if membership is not None and devices_fn is not None:
        raise TypeError("pass membership= or devices_fn=, not both")
    if membership is None:
        if devices_fn is not None:
            warnings.warn(
                "devices_fn= is deprecated: it is the PR-4 single-"
                "observer poll — one omniscient host, no leases, no "
                "quorum, no planner election.  Pass membership= "
                "(runtime.membership.MembershipRuntime over a "
                "MembershipFabric) instead.",
                DeprecationWarning, stacklevel=2)
        membership = SingleObserverMembership(devices_fn or jax.devices)
    topo = plan.topo()
    devs = membership.devices()
    assert topo.size <= len(devs), \
        f"need {topo.size} devices, have {len(devs)}"
    mesh = topo.build(devs)
    step_fn, info = build_train_step(cfg, topo, opt_cfg, mesh=mesh, plan=plan)

    # live holder so the elastic re-plan path can swap plan/step/shardings
    # under the closures the Trainer holds
    live = {"plan": plan, "step": step_fn, "info": info, "ctx": info.ctx}

    def init_state():
        inf, c = live["info"], live["ctx"]
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_opt_state(params, inf.pspecs, c, opt_cfg.mode)
        params = jax.device_put(params, inf.sharding(inf.pspecs))
        opt = jax.device_put(opt, inf.sharding(inf.ospecs))
        return params, opt

    def put_batch(host_batch):
        inf = live["info"]
        return jax.device_put(
            {k: jnp.asarray(v) for k, v in host_batch.items()},
            inf.sharding(inf.bspecs))

    def encode_ckpt(params, opt_state):
        """Checkpoint tree: params as-is + the opt state in its
        plan-independent param-shaped layout (zero1 banks unbanked), so
        any restart can re-bank onto whatever plan survives."""
        inf = live["info"]
        return (params, adamw.unbank_opt_state(
            params, opt_state, inf.pspecs, live["ctx"], opt_cfg.mode))

    def decode_ckpt(tree):
        params, canonical = tree
        inf = live["info"]
        opt = adamw.rebank_opt_state(params, canonical, inf.pspecs,
                                     live["ctx"], opt_cfg.mode)
        return params, jax.device_put(opt, inf.sharding(inf.ospecs))

    # the checkpoint's canonical (plan-independent) opt layout: zero1's
    # banked state unbanks to "plain"; compressed keeps its own mode so
    # the error-feedback residual ("err") rides the checkpoint too
    canon_mode = "plain" if opt_cfg.mode == "zero1" else opt_cfg.mode

    def ckpt_template():
        """Abstract shape/dtype view of the checkpoint tree (params +
        canonical opt) — restore needs no materialized throwaway state."""
        inf = live["info"]
        params = jax.eval_shape(
            lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
        canon = adamw.init_opt_state(params, inf.pspecs, live["ctx"],
                                     canon_mode, abstract=True)
        return (params, canon)

    def restore_shardings():
        """The *current* plan's shardings for the CHECKPOINTED tree —
        every restore (resume at start, recovery) places params directly
        onto the mesh the live step expects.  The canonical opt state
        stays host-side (``ckpt.HOST``): decode_ckpt re-banks it on the
        host anyway, and device-placing the param-shaped fp32 moments
        first would be a wasted full round trip."""
        from repro.checkpoint import manager as ckpt

        inf = live["info"]
        canon_specs = adamw.opt_state_specs(inf.pspecs, live["ctx"],
                                            canon_mode)
        canon_host = jax.tree.map(lambda _: ckpt.HOST, canon_specs,
                                  is_leaf=lambda x: isinstance(x, P))
        return (inf.sharding(inf.pspecs), canon_host)

    def replan_step():
        """Elastic restart: re-plan only if the device pool actually
        changed.  A transient step failure on an intact mesh must NOT
        change the strategy — the executed plan stays the artifact the
        user saved.  'Intact' is membership, not a head-count: enough
        spare devices with a dead one still in the live mesh would
        otherwise hand back a step bound to the dead device forever.

        The pool itself comes from the membership layer: recovery blocks
        on a CONVERGED, quorum-committed view (a glitchy lease cannot
        trigger a reshard — the fabric needs ``quorum_views`` stable
        reviews plus a majority ack before any view commits), and only
        the view's elected planner may run the re-search."""
        view = membership.converged_view()
        surviving = membership.devices(view)
        alive = {d.id for d in surviving}
        mesh_alive = all(d.id in alive
                         for d in live["info"].mesh.devices.flat)
        if mesh_alive and len(surviving) >= live["plan"].devices:
            return live["step"], restore_shardings()
        if not membership.is_planner(view):
            # a real non-planner host would wait for the planner's plan
            # artifact; the single-process simulation has no one to wait
            # for, so losing the planner role is a scenario bug
            raise RuntimeError(
                f"epoch {view.epoch}: this host is not the elected "
                f"re-planner (view {view.alive}, planner {view.planner})")
        log.info("membership epoch %d committed view %s; this host is "
                 "the elected re-planner", view.epoch, view.alive)
        old = live["plan"]
        if recalibrate:
            old = recalibrate_surviving(old, devices=surviving,
                                        measure=measure,
                                        deadline_s=recalib_deadline_s,
                                        model=cfg, batch=batch, seq=seq)
            log.info("recalibrated on surviving mesh: %d entries (%s)",
                     len(old.calibration), old.calibration.source)
        new_plan = replan_elastic(old, len(surviving), model=cfg,
                                  batch=batch, seq=seq)
        log.info("elastic re-plan: %s -> %s",
                 live["plan"].describe(), new_plan.describe())
        new_topo = new_plan.topo()
        new_mesh = new_topo.build(surviving)
        new_step, new_info = build_train_step(cfg, new_topo, opt_cfg=opt_cfg,
                                              mesh=new_mesh, plan=new_plan)
        live.update(plan=new_plan, step=new_step, info=new_info,
                    ctx=new_info.ctx)
        return new_step, restore_shardings()

    trainer = Trainer(
        trainer_cfg,
        build_step=lambda: live["step"],
        source=source, init_state=init_state, put_batch=put_batch,
        replan=replan_step, restore_shardings=restore_shardings,
        encode_ckpt=encode_ckpt, decode_ckpt=decode_ckpt,
        ckpt_template=ckpt_template)
    return trainer, live


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--d1", type=int, default=2)
    ap.add_argument("--d2", type=int, default=1)
    ap.add_argument("--auto-atp", action="store_true",
                    help="search a ParallelPlan (paper §3.5 + overlap knobs)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="restrict --auto-atp to the seed Eq. 2 space")
    ap.add_argument("--calibrate", action="store_true",
                    help="micro-benchmark (B1,B2) on the attached mesh and "
                         "re-rank with the measured table (paper §5.3)")
    ap.add_argument("--plan", default=None,
                    help="load a saved ParallelPlan JSON instead of searching")
    ap.add_argument("--save-plan", default=None,
                    help="write the executed plan JSON here")
    ap.add_argument("--topology", default="v5e", choices=list(comm_matrix.PRESETS))
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--opt-mode", default="zero1",
                    choices=["plain", "zero1", "compressed"])
    ap.add_argument("--chunks", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    if args.plan:
        plan = ParallelPlan.load(args.plan)
        log.info("loaded plan %s: %s", args.plan, plan.describe())
    elif args.auto_atp:
        res = pick_plan(cfg, args.d1 * args.d2, args.seq, args.batch,
                        args.topology, dp=args.dp,
                        calibrate=args.calibrate,
                        overlap=not args.no_overlap)
        plan = res.best
        log.info("ATP plan search on %s picked %s; top of ranking: %s",
                 args.topology, plan.describe(),
                 [(c.d1, c.d2, c.chunks, c.seq_parallel,
                   round(c.t_exposed * 1e3, 2)) for c in res.costs[:4]])
    else:
        # manual knobs still produce a plan: one artifact, one code path
        plan = ParallelPlan(d1=args.d1, d2=args.d2, dp=args.dp,
                            chunks=args.chunks,
                            provenance=(("searcher", "manual-cli"),))
    if args.save_plan:
        plan.save(args.save_plan)
        log.info("saved plan -> %s", args.save_plan)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, mode=args.opt_mode,
                                total_steps=args.steps)
    source = TokenSource(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))
    trainer, live = make_elastic_trainer(
        cfg, plan, opt_cfg,
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every),
        source, batch=args.batch, seq=args.seq)
    params, _ = trainer.run()
    losses = [h["loss"] for h in trainer.history]
    log.info("done: first loss %.4f -> last loss %.4f (%d steps)",
             losses[0], losses[-1], len(losses))
    return params


if __name__ == "__main__":
    main()
