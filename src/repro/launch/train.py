"""Training launcher: ATP strategy search -> mesh -> fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --steps 200 --dp 2 --d1 2 --d2 2 --seq 128 --batch 8 [--auto-atp]

Device count comes from the environment (single host: set
XLA_FLAGS=--xla_force_host_platform_device_count=N before launch).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import comm_matrix
from repro.core.atp import make_context
from repro.core.cost_model import LayerCommProfile
from repro.core.mesh import atp_topo
from repro.core.search import search_strategy
from repro.data.pipeline import DataConfig, TokenSource
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

log = logging.getLogger("repro.train")


def comm_profile(cfg) -> LayerCommProfile:
    """Generalized Eq.2 coefficients for this architecture's block."""
    col = cfg.q_dim + 2 * cfg.kv_dim
    ff_cols = 2 * cfg.d_ff if cfg.mlp_kind in ("swiglu", "geglu") else cfg.d_ff
    col += ff_cols
    row = 2 * cfg.d_model
    return LayerCommProfile(float(col), float(row))


def pick_strategy(cfg, tp: int, seq: int, batch: int, topology: str = "v5e"):
    matrix = comm_matrix.PRESETS[topology]()
    return search_strategy(matrix, tp, layers=cfg.num_layers, batch=batch,
                           seq=seq, profile=comm_profile(cfg))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--d1", type=int, default=2)
    ap.add_argument("--d2", type=int, default=1)
    ap.add_argument("--auto-atp", action="store_true",
                    help="pick (d1,d2) with the ATP search (paper §3.5)")
    ap.add_argument("--topology", default="v5e", choices=list(comm_matrix.PRESETS))
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--opt-mode", default="zero1",
                    choices=["plain", "zero1", "compressed"])
    ap.add_argument("--chunks", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    d1, d2 = args.d1, args.d2
    if args.auto_atp:
        res = pick_strategy(cfg, d1 * d2, args.seq, args.batch, args.topology)
        d1, d2 = res.mesh()
        log.info("ATP search on %s picked DeviceMesh(%d, %d); ranking: %s",
                 args.topology, d1, d2,
                 [(c.d1, c.d2, round(c.t_comm * 1e3, 1)) for c in res.ranked])

    topo = atp_topo(args.dp, d1, d2)
    assert topo.size <= len(jax.devices()), \
        f"need {topo.size} devices, have {len(jax.devices())}"
    mesh = topo.build()
    ctx = make_context(topo, chunks=args.chunks)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, mode=args.opt_mode,
                                total_steps=args.steps)
    step_fn, info = build_train_step(cfg, topo, opt_cfg,
                                     chunks=args.chunks, mesh=mesh)

    source = TokenSource(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))

    def init_state():
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_opt_state(params, info.pspecs, ctx, args.opt_mode)
        params = jax.device_put(params, info.sharding(info.pspecs))
        opt = jax.device_put(opt, info.sharding(info.ospecs))
        return params, opt

    def put_batch(host_batch):
        return jax.device_put(
            {k: jnp.asarray(v) for k, v in host_batch.items()},
            info.sharding(info.bspecs))

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every),
        build_step=lambda: step_fn,
        source=source, init_state=init_state, put_batch=put_batch)
    params, _ = trainer.run()
    losses = [h["loss"] for h in trainer.history]
    log.info("done: first loss %.4f -> last loss %.4f (%d steps)",
             losses[0], losses[-1], len(losses))
    return params


if __name__ == "__main__":
    main()
