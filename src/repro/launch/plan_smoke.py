"""Plan-lifecycle smoke gate (``make plan-smoke``).

Exercises the whole strategy pipeline on the simulated 8-device host mesh
and exits non-zero on any plan/context mismatch:

    calibrate -> plan_search -> save JSON -> load -> contexts bitwise
    identical (train AND decode builders) -> 3 training steps run.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.plan_smoke
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import tempfile

import jax
import jax.numpy as jnp


def check(ok: bool, what: str):
    if not ok:
        print(f"[plan-smoke] FAIL: {what}")
        sys.exit(1)
    print(f"[plan-smoke] ok: {what}")


def main():
    from repro.configs.base import ModelConfig
    from repro.core import comm_matrix
    from repro.core.calibrate import calibrate_mesh
    from repro.core.cost_model import LayerCommProfile
    from repro.core.plan import ParallelPlan, plan_search
    from repro.data.pipeline import DataConfig, TokenSource
    from repro.launch.steps import build_decode_step, build_train_step

    ndev = len(jax.devices())
    check(ndev >= 8, f"8 simulated devices attached (have {ndev})")

    # 1. on-mesh calibration of every tp=4 factorization (tiny payload:
    #    the gate checks plumbing, not bandwidth accuracy)
    matrix = comm_matrix.PRESETS["ic3"]()
    calib = calibrate_mesh(4, matrix, payload_kb=16, repeats=1)
    check(len(calib) == 3, f"calibration covers (1,4)(2,2)(4,1): {len(calib)}")

    # 2. unified search, calibrated; keep the mesh, boundary and spec knobs
    #    in play so the executed context actually depends on the plan
    cfg = ModelConfig(name="smoke-2m", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16, dtype="float32")
    prof = LayerCommProfile.gpt(cfg.d_model)
    res = plan_search("ic3", 4, layers=cfg.num_layers, batch=8, seq=32,
                      profile=prof, dp=2, calibration=calib,
                      chunks_options=(1, 2), seq_parallel_options=(False,))
    plan = res.best
    check(plan.calibration == calib, "winning plan carries the table")

    # 3. JSON round-trip: the saved artifact is the strategy
    with tempfile.TemporaryDirectory() as td:
        path = plan.save(os.path.join(td, "plan.json"))
        loaded = ParallelPlan.load(path)
    check(loaded == plan, "plan JSON round-trip is exact")

    # 4. bitwise-identical contexts from the in-process and loaded plans,
    #    through the real builders (train + decode)
    t_step, t_info = build_train_step(cfg, plan=plan)
    t_step2, t_info2 = build_train_step(cfg, plan=loaded)
    check(t_info.ctx == t_info2.ctx, "train ATPContext identical (saved vs "
                                     "in-process plan)")
    d_step, d_info = build_decode_step(cfg, B=4, s_max=16, plan=plan)
    _, d_info2 = build_decode_step(cfg, B=4, s_max=16, plan=loaded)
    check(d_info.ctx == d_info2.ctx, "decode ATPContext identical")
    check((t_info.ctx.chunks, t_info.ctx.boundary_mode) ==
          (plan.chunks, plan.boundary_mode),
          "builder did not drop plan knobs")

    # 5. static conformance: the built steps must emit exactly the
    #    collectives the plan priced, with every out_spec claim proven
    from repro.analysis import assert_step_conforms
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import batch_struct
    from repro.models import lm
    from repro.optim import adamw

    aparams = lm.abstract_params(cfg)
    aopt = adamw.init_opt_state(aparams, t_info.pspecs, t_info.ctx, "zero1",
                                abstract=True)
    abatch = batch_struct(cfg, ShapeConfig("x", 32, 8, "train"), "train")
    assert_step_conforms(t_step, cfg, plan, "train", 8, 32,
                         aparams, aopt, abatch)
    acaches, _ = lm.init_decode_caches(cfg, d_info.ctx, 4, 16, abstract=True)
    atok = jax.ShapeDtypeStruct((4, 1), jnp.int32)
    apos = jax.ShapeDtypeStruct((), jnp.int32)
    assert_step_conforms(d_step, cfg, plan, "decode", 4, 1,
                         aparams, atok, apos, acaches)
    check(True, "train + decode builds conform to the plan (static lint)")

    # 6. three real training steps under the plan

    src = TokenSource(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                 global_batch=8))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw.init_opt_state(params, t_info.pspecs, t_info.ctx, "zero1")
    params = jax.device_put(params, t_info.sharding(t_info.pspecs))
    opt = jax.device_put(opt, t_info.sharding(t_info.ospecs))
    losses = []
    for step in range(3):
        batch = jax.device_put(
            {k: jnp.asarray(v) for k, v in src.global_batch(step).items()},
            t_info.sharding(t_info.bspecs))
        params, opt, metrics = t_step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    check(all(jnp.isfinite(jnp.asarray(losses))),
          f"3-step train under plan {plan.describe()}: losses {losses}")
    print("[plan-smoke] PASS")


if __name__ == "__main__":
    main()
