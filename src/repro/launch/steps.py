"""Step builders: train_step / prefill / decode, shard_map'd + jitted.

Shared by the dry-run, the trainer, and the server.  Every builder returns
(jitted_fn, StepInfo) where StepInfo carries the specs needed to construct
ShapeDtypeStruct inputs (dry-run) or to device_put host data (real run).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map

from repro.configs.base import ModelConfig, ShapeConfig, segments
from repro.core.atp import ATPContext, make_context
from repro.core.mesh import MeshTopo
from repro.models import lm
from repro.optim import adamw


@dataclasses.dataclass
class StepInfo:
    mesh: jax.sharding.Mesh
    ctx: ATPContext
    pspecs: Any
    bspecs: Any
    ospecs: Any = None
    cache_specs: Any = None

    def sharding(self, spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))


def _dp_axes_spec(topo: MeshTopo):
    names = [a for a in ("pod", "data")
             if topo.has_axis(a) and topo.axis_size(a) > 1]
    return tuple(names) if len(names) > 1 else (names[0] if names else None)


def batch_pspecs(cfg: ModelConfig, topo: MeshTopo, kind: str):
    dp = _dp_axes_spec(topo)
    if cfg.frontend == "vision_patches":
        ax2 = "tp2" if topo.has_axis("tp2") else None
        sp = {"embeds": P(dp, None, ax2), "positions3": P(None, dp, None)}
    else:
        sp = {"tokens": P(dp, None)}
    if kind == "train":
        sp["labels"] = P(dp, None)
    return sp


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, kind: str):
    """ShapeDtypeStruct stand-ins for every model input (dry-run §e.2)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision_patches":
        b = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
             "positions3": jax.ShapeDtypeStruct((3, B, S), jnp.int32)}
    else:
        b = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if kind == "train":
        b["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return b


# ---------------------------------------------------------------------------


def resolve_ctx(topo: MeshTopo | None, plan, chunks: int = 1,
                 decode: bool = False) -> ATPContext:
    """One context path for every builder: the plan wins when given.

    Keeping this single funnel is what guarantees a searched/saved plan
    reaches train, prefill AND decode identically (no builder hand-rolls
    its own defaults and silently drops knobs).  ``decode`` does two
    things:

      - masks seq_parallel — globally AND in every per-segment entry: the
        sequence-parallel block I/O spec is defined over a full sequence
        and does not apply to cached decode (the model raises if asked);
      - applies the plan's :class:`~repro.core.atp.DecodePlan` sub-plan
        (format_version 3) for the mesh-layout-NEUTRAL knobs: decode
        boundary_mode and chunks=1 replace the train knobs in every
        segment view.  The decode factorization (d1, d2) is NOT applied
        here — a builder cannot re-mesh mid-serving under shared params;
        a deployment that wants the decode mesh builds everything from
        ``plan.decode_view()`` up front (``launch/serve.py`` does).
    """
    if plan is not None:
        ctx = make_context(topo, plan=plan)
    elif topo is None:
        raise TypeError("builder needs a MeshTopo or a ParallelPlan")
    else:
        ctx = make_context(topo, chunks=chunks)
    if decode and plan is not None \
            and getattr(plan, "decode", None) is not None:
        dec = plan.decode
        wd = getattr(dec, "wire_dtype", "bf16")
        ctx = dataclasses.replace(
            ctx, chunks=dec.chunks, boundary_mode=dec.boundary_mode,
            wire_dtype=wd,
            segment_plans=tuple(
                dataclasses.replace(s, chunks=dec.chunks,
                                    boundary_mode=dec.boundary_mode,
                                    wire_dtype=wd)
                for s in ctx.segment_plans))
    if decode and ctx.any_seq_parallel:
        ctx = dataclasses.replace(
            ctx, seq_parallel=False,
            segment_plans=tuple(dataclasses.replace(s, seq_parallel=False)
                                for s in ctx.segment_plans))
    return ctx


def _check_vma(ctx: ATPContext) -> bool:
    """Ring boundaries decompose psums into ppermute rings whose outputs
    the vma type system labels *varying* (unlike lax.psum's invariant
    output), so the replication checker cannot certify them — numerical
    equivalence is pinned by the bitwise-parity tests instead.  The legacy
    (jax 0.4/0.5) checker additionally has no rep rules for the
    custom_vjp ops every whole-step program contains (gpipe_loss, the
    overlap collectives), so it is skipped wholesale there.  Ring in ANY
    segment's plan disqualifies the whole step.  Delegates to
    :func:`repro.core.atp.vma_rewrite_active` — the same predicate gates
    the manual ``grad_sync`` barriers (no rewrite => manual psums), so
    every build path has exactly one gradient reduction."""
    from repro.core.atp import vma_rewrite_active

    return vma_rewrite_active(ctx)


def build_train_step(cfg: ModelConfig, topo: MeshTopo | None = None,
                     opt_cfg: adamw.AdamWConfig | None = None,
                     chunks: int = 1, remat: bool = True,
                     mesh: jax.sharding.Mesh | None = None,
                     plan=None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    ctx = resolve_ctx(topo, plan, chunks)
    topo = ctx.topo
    mesh = mesh if mesh is not None else topo.build()
    pspecs = lm.param_specs(cfg, ctx)
    ospecs = adamw.opt_state_specs(pspecs, ctx, opt_cfg.mode)
    rep = adamw.replication_factors(pspecs, ctx)
    bspecs = batch_pspecs(cfg, topo, "train")
    mspecs = {"loss": P(), "lr": P(), "grad_norm": P()}

    def local_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm.train_loss(ctx, cfg, p, batch, remat=remat))(params)
        new_p, new_o, metrics = adamw.apply_adamw(
            opt_cfg, ctx, params, grads, opt_state, rep)
        metrics["loss"] = loss
        return new_p, new_o, metrics

    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(pspecs, ospecs, bspecs),
                   out_specs=(pspecs, ospecs, mspecs), check_vma=_check_vma(ctx))
    info = StepInfo(mesh, ctx, pspecs, bspecs, ospecs)
    jit_fn = jax.jit(
        fn,
        in_shardings=(info.sharding(pspecs), info.sharding(ospecs),
                      info.sharding(bspecs)),
        out_shardings=(info.sharding(pspecs), info.sharding(ospecs),
                       info.sharding(mspecs)),
        donate_argnums=(0, 1))
    return jit_fn, info


def build_prefill(cfg: ModelConfig, topo: MeshTopo | None = None,
                  chunks: int = 1,
                  mesh: jax.sharding.Mesh | None = None,
                  plan=None):
    """Forward-only serve step: batch -> greedy next token [B]."""
    ctx = resolve_ctx(topo, plan, chunks)
    topo = ctx.topo
    mesh = mesh if mesh is not None else topo.build()
    pspecs = lm.param_specs(cfg, ctx)
    bspecs = batch_pspecs(cfg, topo, "prefill")
    dp = _dp_axes_spec(topo)

    def local(params, batch):
        logits = lm.prefill_logits(ctx, cfg, params, batch)
        return _greedy_pick(ctx, cfg, logits)

    fn = shard_map(local, mesh=mesh, in_specs=(pspecs, bspecs),
                   out_specs=P(dp), check_vma=_check_vma(ctx))
    info = StepInfo(mesh, ctx, pspecs, bspecs)
    jit_fn = jax.jit(fn,
                     in_shardings=(info.sharding(pspecs), info.sharding(bspecs)),
                     out_shardings=NamedSharding(mesh, P(dp)))
    return jit_fn, info


def _greedy_pick(ctx: ATPContext, cfg: ModelConfig, logits):
    """Vocab-parallel greedy argmax.  logits [b, V/d1] -> token ids [b]."""
    with jax.named_scope("shell:pick"):
        v_loc = logits.shape[-1]
        lf = logits.astype(jnp.float32)
        local_max = jnp.max(lf, axis=-1)
        local_arg = (jnp.argmax(lf, axis=-1).astype(jnp.int32)
                     + ctx.index1() * v_loc)
        if ctx.ax1 is None:
            return local_arg
        gmax = lax.pmax(local_max, ctx.ax1)
        cand = jnp.where(local_max >= gmax, local_arg, jnp.int32(2**30))
        return lax.pmin(cand, ctx.ax1)


def build_paged_step(cfg: ModelConfig, topo: MeshTopo | None = None,
                     paged_cfg=None,
                     mesh: jax.sharding.Mesh | None = None,
                     plan=None, slots: int | None = None,
                     speculate: bool = False):
    """The compiled paged cache-write step (decode tick AND prefill chunk).

    Signature: (params, tokens [b, s], start [b], table [b, mp],
    caches) -> (greedy tokens [b, s], new caches).

    The serving fast path runs this one jitted function at exactly two
    shapes — prefill chunk (b=1, s=chunk) and decode tick (b=slots, s=1)
    — and reuses them across every request length: lengths/positions are
    runtime data (per-slot ``start`` + page-table rows), not shapes, so
    mixed-length continuous batching never recompiles.  Greedy picks for
    every input position come back so the scheduler can read the last
    *valid* position of a padded final chunk on the host.

    Two opt-in variants (the default signature is untouched):

      - recurrent archs (mamba/zamba/xlstm segments) need ``slots`` (the
        scheduler's ``batch_slots``, sizing the per-slot state pools) and
        the step gains a 4th positional input ``slot [b]`` — per-row slot
        ids, sentinel = ``slots`` for masked rows (state writes drop);
      - ``speculate=True`` (requires ``cfg.mtp``) returns
        (tokens, drafts, caches): ``drafts[b, s]`` is the MTP head's
        greedy pick for the position AFTER each trunk pick — the free
        draft token self-speculative decode verifies next tick.

    ``decode=True`` context resolution applies the plan's decode
    sub-plan knobs (boundary_mode, chunks=1) and masks seq_parallel.
    """
    from repro.models.paging import PagedConfig

    pcfg = paged_cfg if paged_cfg is not None else PagedConfig()
    needs_slot = any(s.kind in lm.RECURRENT_STATE_KINDS
                     for s in segments(cfg))
    if needs_slot and slots is None:
        raise ValueError(
            "recurrent kinds (mamba/zamba/xlstm) need "
            "build_paged_step(..., slots=<scheduler batch_slots>)")
    if speculate and not cfg.mtp:
        raise ValueError("speculate=True needs an MTP head (cfg.mtp)")
    if speculate and needs_slot:
        raise NotImplementedError(
            "self-speculative decode rolls rejected drafts back by KV "
            "length; recurrent state has no position axis to roll back")
    ctx = resolve_ctx(topo, plan, decode=True)
    topo = ctx.topo
    mesh = mesh if mesh is not None else topo.build()
    pspecs = lm.param_specs(cfg, ctx)
    _, cache_specs = lm.init_paged_caches(cfg, ctx, pcfg, abstract=True,
                                          slots=slots)
    tspec = P(None, None)
    info = StepInfo(mesh, ctx, pspecs, tspec, cache_specs=cache_specs)

    if needs_slot:
        def local(params, tokens, start, table, slot, caches):
            logits, new_caches = lm.paged_step(ctx, cfg, params, tokens,
                                               start, table, caches,
                                               slot=slot)
            return _greedy_pick(ctx, cfg, logits), new_caches

        fn = shard_map(local, mesh=mesh,
                       in_specs=(pspecs, tspec, P(None), tspec, P(None),
                                 cache_specs),
                       out_specs=(tspec, cache_specs),
                       check_vma=_check_vma(ctx))
        jit_fn = jax.jit(
            fn,
            in_shardings=(info.sharding(pspecs), NamedSharding(mesh, tspec),
                          NamedSharding(mesh, P(None)),
                          NamedSharding(mesh, tspec),
                          NamedSharding(mesh, P(None)),
                          info.sharding(cache_specs)),
            out_shardings=(NamedSharding(mesh, tspec),
                           info.sharding(cache_specs)),
            donate_argnums=(5,))
        return jit_fn, info

    if speculate:
        def local(params, tokens, start, table, caches):
            logits, h, new_caches = lm.paged_step(ctx, cfg, params, tokens,
                                                  start, table, caches,
                                                  with_hidden=True)
            toks = _greedy_pick(ctx, cfg, logits)
            b, s = tokens.shape
            prange = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(prange[None], (3, b, s))
            else:
                positions = prange
            dl = lm.mtp_draft_logits(ctx, cfg, params, h, positions, toks)
            return toks, _greedy_pick(ctx, cfg, dl), new_caches

        fn = shard_map(local, mesh=mesh,
                       in_specs=(pspecs, tspec, P(None), tspec, cache_specs),
                       out_specs=(tspec, tspec, cache_specs),
                       check_vma=_check_vma(ctx))
        jit_fn = jax.jit(
            fn,
            in_shardings=(info.sharding(pspecs), NamedSharding(mesh, tspec),
                          NamedSharding(mesh, P(None)),
                          NamedSharding(mesh, tspec),
                          info.sharding(cache_specs)),
            out_shardings=(NamedSharding(mesh, tspec),
                           NamedSharding(mesh, tspec),
                           info.sharding(cache_specs)),
            donate_argnums=(4,))
        return jit_fn, info

    def local(params, tokens, start, table, caches):
        logits, new_caches = lm.paged_step(ctx, cfg, params, tokens, start,
                                           table, caches)
        return _greedy_pick(ctx, cfg, logits), new_caches

    fn = shard_map(local, mesh=mesh,
                   in_specs=(pspecs, tspec, P(None), tspec, cache_specs),
                   out_specs=(tspec, cache_specs), check_vma=_check_vma(ctx))
    jit_fn = jax.jit(
        fn,
        in_shardings=(info.sharding(pspecs), NamedSharding(mesh, tspec),
                      NamedSharding(mesh, P(None)),
                      NamedSharding(mesh, tspec),
                      info.sharding(cache_specs)),
        out_shardings=(NamedSharding(mesh, tspec),
                       info.sharding(cache_specs)),
        donate_argnums=(4,))
    return jit_fn, info


def build_decode_step(cfg: ModelConfig, topo: MeshTopo | None = None,
                      B: int = 1, s_max: int = 64,
                      mesh: jax.sharding.Mesh | None = None,
                      seq_in: int = 1, plan=None):
    """One decode step (seq_in>1 = prefill-into-cache for serving).

    Signature: (params, tokens [B, seq_in], pos scalar, caches) ->
    (next tokens [B], new caches)."""
    ctx = resolve_ctx(topo, plan, decode=True)
    topo = ctx.topo
    mesh = mesh if mesh is not None else topo.build()
    pspecs = lm.param_specs(cfg, ctx)
    _, cache_specs = lm.init_decode_caches(cfg, ctx, B, s_max, abstract=True)
    dp = _dp_axes_spec(topo) if (ctx.dp and B % ctx.dp == 0) else None
    tspec = P(dp, None)

    def local(params, tokens, pos, caches):
        logits, new_caches = lm.decode_step(ctx, cfg, params, tokens, pos, caches)
        return _greedy_pick(ctx, cfg, logits), new_caches

    fn = shard_map(local, mesh=mesh,
                   in_specs=(pspecs, tspec, P(), cache_specs),
                   out_specs=(P(dp), cache_specs), check_vma=_check_vma(ctx))
    info = StepInfo(mesh, ctx, pspecs, tspec, cache_specs=cache_specs)
    jit_fn = jax.jit(
        fn,
        in_shardings=(info.sharding(pspecs), NamedSharding(mesh, tspec),
                      NamedSharding(mesh, P()), info.sharding(cache_specs)),
        out_shardings=(NamedSharding(mesh, P(dp)), info.sharding(cache_specs)),
        donate_argnums=(3,))
    return jit_fn, info
