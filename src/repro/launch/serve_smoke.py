"""Serving fast-path smoke gate (``make serve-smoke``).

Exercises the paged continuous-batching pipeline end to end on the
simulated 8-device host mesh and exits non-zero on any mismatch:

    decode-objective plan search (decode sub-plan attached, save/load
    round-trip) -> serving stack built on the decode view -> mixed-length
    requests through chunked prefill + continuous decode with slot
    recycling -> greedy tokens IDENTICAL to the wave loop baseline ->
    page accounting returns to empty.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve_smoke
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import tempfile

import jax
import numpy as np


def check(ok: bool, what: str):
    if not ok:
        print(f"[serve-smoke] FAIL: {what}")
        sys.exit(1)
    print(f"[serve-smoke] ok: {what}")


def main():
    from repro.configs.registry import get_config
    from repro.core.plan import ParallelPlan, plan_search
    from repro.launch.serve import make_paged_server, serve
    from repro.models import lm
    from repro.models.paging import PagedConfig
    from repro.runtime.server import Request, ServerConfig

    ndev = len(jax.devices())
    check(ndev >= 8, f"8 simulated devices attached (have {ndev})")

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    MAX_NEW = 6

    # 1. decode-objective search: the serve plan carries a decode sub-plan
    #    and (on the IB preset at tp=8) its factorization differs from
    #    train's — the bandwidth objective balances payload across both
    #    dims, the latency objective folds everything into one boundary
    res = plan_search("ic4", 8, model=cfg, batch=4, seq=16,
                      decode_batch=4)
    plan = res.best
    check(plan.decode is not None, f"decode sub-plan attached: {plan.describe()}")
    check((plan.decode.d1, plan.decode.d2) != (plan.d1, plan.d2),
          "decode objective picks a different factorization than train "
          f"on ic4: train ({plan.d1},{plan.d2}) vs decode "
          f"({plan.decode.d1},{plan.decode.d2})")
    with tempfile.TemporaryDirectory() as td:
        path = plan.save(os.path.join(td, "plan.json"))
        loaded = ParallelPlan.load(path)
    check(loaded == plan, "v3 plan JSON round-trip is exact")

    # 1b. static conformance: the plain prefill build (train-view) and the
    #     decode-view decode build — exactly what the wave-loop reference
    #     runs — must emit the collectives the serve plan priced
    from repro.analysis import assert_step_conforms
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import (batch_struct, build_decode_step,
                                    build_prefill)

    dview = loaded.decode_view()
    ap = lm.abstract_params(cfg)
    pfn, _ = build_prefill(cfg, plan=loaded)
    ab = batch_struct(cfg, ShapeConfig("x", 16, 4, "prefill"), "prefill")
    assert_step_conforms(pfn, cfg, loaded, "prefill", 4, 16, ap, ab)
    dfn, dinfo = build_decode_step(cfg, B=4, s_max=32, plan=dview)
    acaches, _ = lm.init_decode_caches(cfg, dinfo.ctx, 4, 32, abstract=True)
    assert_step_conforms(dfn, cfg, dview, "decode", 4, 1, ap,
                         jax.ShapeDtypeStruct((4, 1), np.int32),
                         jax.ShapeDtypeStruct((), np.int32), acaches)
    check(True, "prefill + decode-view builds conform to the serve plan "
                "(static lint)")

    # 2. mixed-length workload through the paged continuous server built
    #    on the decode view
    rng = np.random.default_rng(0)
    lens = [10, 7, 3, 12, 5, 9]
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in lens]
    pool = 1 + sum(-(-(n + MAX_NEW) // 4) for n in lens)
    scfg = ServerConfig(
        batch_slots=3, prefill_chunk=4,
        paged=PagedConfig(page_size=4, num_pages=pool, pages_per_slot=8))
    server, info = make_paged_server(cfg, scfg, params, plan=loaded)
    check((info.ctx.d1, info.ctx.d2) == (loaded.decode.d1, loaded.decode.d2),
          "serving mesh is the decode sub-plan's factorization")
    for rid, p in enumerate(prompts):
        server.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
    ticks = server.run_until_drained()
    check(len(server.completed) == len(prompts),
          f"all {len(prompts)} requests drained in {ticks} ticks")
    check(server.alloc.free_pages == pool - 1,
          "every page returned to the pool after drain")
    got = [r.out for r in sorted(server.completed, key=lambda r: r.rid)]

    # 3. wave-loop baseline (equal-length waves padded to the longest
    #    prompt) must emit the SAME greedy tokens per request
    view = loaded.decode_view()
    pad_to = max(lens)
    padded = []
    for p in prompts:
        buf = np.zeros((pad_to,), np.int32)
        buf[: len(p)] = p
        padded.append(buf)
    ref = []
    for i in range(0, len(prompts), 3):
        batch = padded[i: i + 3]
        while len(batch) < 3:
            batch.append(np.zeros(pad_to, np.int32))
        outs = serve(cfg, None, params, batch, MAX_NEW, 32, plan=view)
        ref.extend(o.tolist() for o in outs[: len(padded[i: i + 3])])
    ref = ref[: len(prompts)]
    # the wave loop left-pads with token 0 *inside* the sequence when a
    # prompt is shorter than the wave — compare only requests whose
    # natural length equals the wave pad (exact semantics); for the rest
    # compare against the per-request B=1 wave run
    exact = [i for i, n in enumerate(lens) if n == pad_to]
    check(all(got[i] == ref[i] for i in exact),
          f"wave-loop parity on full-length prompts {exact}")
    solo = []
    for p in prompts:
        outs = serve(cfg, None, params, [p], MAX_NEW, 32, plan=view)
        solo.append(outs[0].tolist())
    check(got == solo,
          "paged continuous greedy tokens == per-request wave reference "
          "for every mixed-length prompt")

    # 4. recurrent-kind paged serving: zamba's mixed attention+mamba
    #    super-blocks run the same continuous scheduler (slot-addressed
    #    state pools + paged KV for the shared attention block) at exact
    #    wave-loop token parity
    zcfg = get_config("zamba2-7b").reduced()
    zparams = lm.init_params(zcfg, jax.random.PRNGKey(1))
    zlens = [9, 4, 11, 6]
    zprompts = [rng.integers(0, zcfg.vocab_size, size=n, dtype=np.int32)
                for n in zlens]
    zpool = 1 + sum(-(-(n + MAX_NEW) // 4) for n in zlens)
    zscfg = ServerConfig(
        batch_slots=2, prefill_chunk=4,
        paged=PagedConfig(page_size=4, num_pages=zpool, pages_per_slot=8))
    zserver, zinfo = make_paged_server(zcfg, zscfg, zparams, plan=loaded)
    check(zserver.cfg.recurrent, "zamba server runs the slot-addressed step")
    for rid, p in enumerate(zprompts):
        zserver.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
    zticks = zserver.run_until_drained()
    check(len(zserver.completed) == len(zprompts)
          and zserver.alloc.free_pages == zpool - 1,
          f"zamba paged serve drained in {zticks} ticks, pages returned")
    zgot = [r.out for r in sorted(zserver.completed, key=lambda r: r.rid)]
    zsolo = []
    for p in zprompts:
        outs = serve(zcfg, None, zparams, [p], MAX_NEW, 32, plan=view)
        zsolo.append(outs[0].tolist())
    check(zgot == zsolo,
          "zamba paged continuous greedy tokens == wave reference")

    # 5. MTP self-speculative decode: same arch with the MTP head, served
    #    with --speculate, must emit EXACTLY the plain paged greedy tokens
    #    (speculation changes latency, never the argmax sequence)
    import dataclasses as _dc

    mcfg = _dc.replace(cfg, mtp=True)
    mparams = lm.init_params(mcfg, jax.random.PRNGKey(0))
    plain_server, _ = make_paged_server(mcfg, scfg, mparams, plan=loaded)
    for rid, p in enumerate(prompts):
        plain_server.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
    plain_server.run_until_drained()
    plain = [r.out for r in sorted(plain_server.completed,
                                   key=lambda r: r.rid)]
    sscfg = _dc.replace(scfg, speculate=True)
    spec_server, _ = make_paged_server(mcfg, sscfg, mparams, plan=loaded)
    check(spec_server.cfg.speculate, "speculative server enabled")
    for rid, p in enumerate(prompts):
        spec_server.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
    sticks = spec_server.run_until_drained()
    check(len(spec_server.completed) == len(prompts)
          and spec_server.alloc.free_pages == pool - 1,
          f"speculative serve drained in {sticks} ticks, pages returned")
    spec = [r.out for r in sorted(spec_server.completed,
                                  key=lambda r: r.rid)]
    st = spec_server.stats()
    check(spec == plain,
          f"speculative greedy tokens EXACTLY match plain paged decode "
          f"(accept_rate={st['spec_accept_rate']:.3f})")

    # 6. copy-on-write prefix cache: a shared system prompt admits with
    #    page-aligned reuse and still produces identical greedy tokens
    pscfg = _dc.replace(scfg, prefix_cache=True)
    sys_prefix = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    pprompts = [np.concatenate([sys_prefix, p]) for p in prompts[:4]]
    ppool = 1 + sum(-(-(len(p) + MAX_NEW) // 4) for p in pprompts)
    pscfg = _dc.replace(
        pscfg, paged=_dc.replace(scfg.paged, num_pages=ppool))
    pref_server, _ = make_paged_server(cfg, pscfg, params, plan=loaded)
    for rid, p in enumerate(pprompts):
        pref_server.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
    pref_server.run_until_drained()
    pst = pref_server.stats()
    check(pst["prefix_hit_rate"] > 0.0,
          f"prefix cache hit on the shared system prompt "
          f"(hit_rate={pst['prefix_hit_rate']:.3f})")
    pgot = [r.out for r in sorted(pref_server.completed,
                                  key=lambda r: r.rid)]
    psolo = []
    for p in pprompts:
        outs = serve(cfg, None, params, [p], MAX_NEW, 32, plan=view)
        psolo.append(outs[0].tolist())
    check(pgot == psolo,
          "prefix-cached greedy tokens == wave reference (COW is exact)")
    print("[serve-smoke] PASS")


if __name__ == "__main__":
    main()
