"""Parse optimized HLO text for collective communication volume.

cost_analysis() has FLOPs and memory bytes but not collective bytes, so we
walk the HLO: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute contributes its bytes, and ops inside while-loop bodies
(jax.lax.scan over layers) are multiplied by the loop trip count, read from
the op's ``backend_config={"known_trip_count":{"n":...}}`` annotation.

Byte conventions (per device):
    all-reduce         result bytes (== operand bytes)
    all-gather         result bytes (what lands on each device)
    reduce-scatter     result bytes * group size (operand contribution)
    all-to-all         result bytes
    collective-permute result bytes
These match the paper's T_comm accounting (tensor size entering the
collective) and are applied uniformly across strategies, so strategy
*ratios* — what the search and §Perf consume — are exact.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    # sub-byte quantized storage: XLA packs two nibbles per byte
    "s4": 0.5, "u4": 0.5,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
# async pairs are counted once, uniformly: the ``*-start`` op carries the
# payload, the matching ``*-done`` deliberately fails this pattern (the
# alternation requires '(' straight after the op name or its -start form)
_OP_KIND_RE = re.compile(
    r"=\s*[^=]*?\b(all-reduce(?:-start)?|all-gather(?:-start)?"
    r"|reduce-scatter(?:-start)?|all-to-all(?:-start)?"
    r"|collective-permute(?:-start)?)\(")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _component_bytes(shape_str: str) -> list[int]:
    """Per-array bytes for each typed component in a shape string
    (sub-byte dtypes round up per component: packed storage)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out.append(int(math.ceil(n * DTYPE_BYTES[dt])))
    return out


def shape_bytes(shape_str: str) -> int:
    """Bytes of the result shape(s) on an HLO op line (handles tuples)."""
    return sum(_component_bytes(shape_str))


def _async_start_bytes(kind: str, shape_str: str) -> int:
    """Payload bytes of an async ``*-start`` result tuple.

    Async starts return ``(operand, result, context...)``-style tuples
    (u32 context scalars included), so summing the whole tuple would
    double-count.  The destination buffer is the LARGEST component for
    every kind except reduce-scatter — there the result is the small
    shard (the caller re-multiplies by the group size, same as the sync
    form).
    """
    comps = _component_bytes(shape_str)
    if not comps:
        return 0
    return min(comps) if kind == "reduce-scatter" else max(comps)


def _result_shape(line: str) -> str:
    """Everything between '= ' and the op name: the result shape."""
    m = re.search(r"=\s*(.*?)\s*\b(?:all-reduce|all-gather|reduce-scatter"
                  r"|all-to-all|collective-permute)", line)
    return m.group(1) if m else ""


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def _parse(hlo: str):
    """-> (entry_name, comps{name: {'coll': [(kind, bytes, group)], 'whiles':
    [(body_name, trip)]}})"""
    comps: dict[str, dict] = {}
    cur = None
    entry = None
    depth = 0
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and _HEAD_RE.match(s):
                cur = _HEAD_RE.match(s).group(1)
                comps[cur] = {"coll": [], "whiles": []}
                if s.startswith("ENTRY"):
                    entry = cur
                depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            cur = None
            continue
        mo = _OP_KIND_RE.search(s)
        if mo:
            is_start = mo.group(1).endswith("-start")
            kind = mo.group(1).replace("-start", "")
            shape = _result_shape(s)
            b = (_async_start_bytes(kind, shape) if is_start
                 else shape_bytes(shape))
            g = _group_size(s)
            if kind == "reduce-scatter":
                b *= g
            comps[cur]["coll"].append((kind, b, g))
        if " while(" in s or s.startswith("while("):
            mb = _BODY_RE.search(s)
            mt = _TRIP_RE.search(s)
            if mb:
                comps[cur]["whiles"].append(
                    (mb.group(1), int(mt.group(1)) if mt else 1))
    return entry, comps


def collective_bytes(hlo: str) -> dict:
    """Sum collective bytes over the program, multiplying while bodies by
    their known trip count.  Returns per-op and total bytes."""
    entry, comps = _parse(hlo)

    def walk(name: str, mult: float, seen: frozenset) -> dict:
        out: dict[str, float] = defaultdict(float)
        if name not in comps or name in seen:
            return out
        for kind, b, _ in comps[name]["coll"]:
            out[kind] += b * mult
        for body, trip in comps[name]["whiles"]:
            sub = walk(body, mult * max(1, trip), seen | {name})
            for k, v in sub.items():
                out[k] += v
        return out

    totals = walk(entry, 1.0, frozenset()) if entry else {}
    # collectives inside non-while called computations (fusions can't hold
    # collectives; conditional branches counted once) — walk those too:
    per_op = {k: float(v) for k, v in totals.items()}
    tot = float(sum(per_op.values()))
    return {
        "per_op_bytes": per_op,
        "total_bytes": tot,
        "total_gbytes": tot / 1e9,
    }


def count_ops(hlo: str, names=("fusion", "while", "custom-call")) -> dict:
    out: dict[str, int] = defaultdict(int)
    for ln in hlo.splitlines():
        for n in names:
            if re.search(rf"=\s*\S+\s+{n}\(", ln):
                out[n] += 1
    return dict(out)


# ---------------------------------------------------------------------------
# Full trip-aware analysis: XLA's cost_analysis() counts while bodies ONCE
# (verified empirically), so the roofline terms are derived here instead:
# dot FLOPs + op-boundary traffic bytes + collective bytes, each multiplied
# by the enclosing loops' known_trip_count.
# ---------------------------------------------------------------------------

_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_TRAFFIC_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota",
}


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []


def _split_header_params(header: str) -> list[tuple[str, str]]:
    """'a: f32[2], b: (f32[2], s32[])' -> [(a, type), (b, type)]."""
    out, depth, cur = [], 0, ""
    for ch in header:
        if ch == "," and depth == 0:
            out.append(cur)
            cur = ""
            continue
        depth += ch in "([{"
        depth -= ch in ")]}"
        cur += ch
    if cur.strip():
        out.append(cur)
    pairs = []
    for item in out:
        if ":" in item:
            nm, ty = item.split(":", 1)
            pairs.append((nm.strip().lstrip("%"), ty.strip()))
    return pairs


def full_analysis(hlo: str) -> dict:
    """-> {dot_flops, traffic_bytes, collectives:{...}} (trip-multiplied)."""
    comps: dict[str, dict] = {}
    cur = None
    entry = None
    depth = 0
    header_re = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
    for raw in hlo.splitlines():
        s = raw.strip()
        if cur is None:
            hm = header_re.match(s)
            if hm and s.endswith("{"):
                cur = hm.group(1)
                comps[cur] = {"table": {}, "ops": [], "whiles": []}
                for nm, ty in _split_header_params(hm.group(2)):
                    comps[cur]["table"][nm] = ty
                if s.startswith("ENTRY"):
                    entry = cur
                depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            cur = None
            continue
        lm_ = _LINE_RE.match(s)
        if not lm_:
            continue
        var, rtype, op, rest = lm_.groups()
        comps[cur]["table"][var] = rtype
        comps[cur]["ops"].append((var, rtype, op, rest))
        if op == "while":
            mb = _BODY_RE.search(s)
            mt = _TRIP_RE.search(s)
            if mb:
                comps[cur]["whiles"].append(
                    (mb.group(1), int(mt.group(1)) if mt else 1))

    def _args(rest: str) -> list[str]:
        # operands up to the closing paren at depth 0
        out, depthp, curarg = [], 0, ""
        for ch in rest:
            if ch == "(":
                depthp += 1
            elif ch == ")":
                if depthp == 0:
                    break
                depthp -= 1
            if ch == "," and depthp == 0:
                out.append(curarg)
                curarg = ""
            else:
                curarg += ch
        if curarg.strip():
            out.append(curarg)
        # XLA prints operands either bare ("%name" / "name") or typed
        # ("f32[32,64]{1,0} %name" on older versions): name is the last token
        names = []
        for a in out:
            a = a.strip()
            if not a:
                continue
            tok = a.split()[-1]
            if tok.startswith("%"):
                names.append(tok.lstrip("%"))
            elif a == tok and re.fullmatch(r"[\w.\-]+", tok):
                names.append(tok)
        return names

    def comp_stats(name: str) -> tuple[float, float]:
        """(dot_flops, traffic_bytes) local to this computation.

        Traffic conventions (match XLA's in-place semantics):
          dynamic-slice / gather: only the slice read+written (result x2) —
              the source buffer is not streamed.
          dynamic-update-slice / scatter (incl. fusions whose output
              aliases their largest operand): 2x the update bytes.
          everything else: operands + result.
        """
        c = comps[name]
        table = c["table"]
        flops = 0.0
        traffic = 0.0
        for var, rtype, op, rest in c["ops"]:
            if op in _TRAFFIC_SKIP:
                continue
            rbytes = shape_bytes(rtype)
            arg_names = _args(rest)
            arg_bytes = [shape_bytes(table.get(a, "")) for a in arg_names]
            obytes = sum(arg_bytes)
            is_dus_fusion = op == "fusion" and arg_bytes and (
                "dynamic-update-slice" in var or "scatter" in var) and \
                max(arg_bytes) == rbytes
            if op in ("dynamic-slice", "gather"):
                traffic += 2 * rbytes
            elif op in ("dynamic-update-slice", "scatter") or is_dus_fusion:
                # in-place update: only the update slice moves
                traffic += 2 * (obytes - max(arg_bytes, default=0))
            elif op == "fusion" and "reduce" not in var:
                # kLoop fusions read each operand at most at the result's
                # footprint (big operands are sliced inside the fusion);
                # reduce-fusions keep full operand reads.
                traffic += rbytes + sum(min(a, rbytes) for a in arg_bytes)
            else:
                traffic += rbytes + obytes
            if op == "dot":
                dims_m = _DOT_DIMS_RE.search(rest)
                lhs_shape = (_shape_dims(table.get(arg_names[0], ""))
                             if arg_names else [])
                csize = 1
                if dims_m and lhs_shape:
                    for idx in dims_m.group(1).split(","):
                        if idx and int(idx) < len(lhs_shape):
                            csize *= lhs_shape[int(idx)]
                flops += 2.0 * max(1, _prod(_shape_dims(rtype))) * csize
        return flops, traffic

    _stat_cache: dict[str, tuple[float, float]] = {}

    def walk(name: str, mult: float, seen: frozenset) -> tuple[float, float]:
        if name not in comps or name in seen:
            return 0.0, 0.0
        if name not in _stat_cache:
            _stat_cache[name] = comp_stats(name)
        f, t = _stat_cache[name]
        f, t = f * mult, t * mult
        for body, trip in comps[name]["whiles"]:
            sf, st = walk(body, mult * max(1, trip), seen | {name})
            f += sf
            t += st
        return f, t

    flops, traffic = walk(entry, 1.0, frozenset()) if entry else (0.0, 0.0)
    return {
        "dot_flops": flops,
        "traffic_bytes": traffic,
        "collectives": collective_bytes(hlo),
    }


def _prod(xs):
    p = 1
    for x in xs:
        p *= x
    return p
