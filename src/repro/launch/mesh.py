"""Production meshes (assignment-specified) + ATP-factorized variants."""
from __future__ import annotations

import jax

from repro.core.mesh import MeshTopo, atp_topo, production_topo


def make_production_mesh(*, multi_pod: bool = False):
    """Required production mesh: 16x16 single pod / 2x16x16 multi-pod.

    The single "model" axis is the ATP DeviceMesh(16, 1) baseline
    (== Megatron tensor parallelism)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_mesh_topo(multi_pod: bool = False) -> MeshTopo:
    return production_topo(multi_pod)


def make_atp_mesh(d1: int, d2: int, *, dp: int = 16, pods: int = 1):
    """ATP-factorized production mesh: (pod?, data, tp1, tp2)."""
    topo = atp_topo(dp, d1, d2, pods=pods)
    return topo.build()


def atp_mesh_topo(d1: int, d2: int, dp: int = 16, pods: int = 1) -> MeshTopo:
    return atp_topo(dp, d1, d2, pods=pods)
