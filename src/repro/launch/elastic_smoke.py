"""Elastic-restart smoke gate (``make elastic-smoke``).

Exercises the *complete* failure -> shrink -> recover loop on the
simulated 8-device host mesh and exits non-zero on any mismatch:

    calibrated plan (dp=2 x tp=4 = 8 devices) -> fault-tolerant training
    -> injected failure that also SHRINKS the visible device pool to 2
    -> recalibrate on the surviving mesh (fresh (B1,B2)/alpha_s entries
    for tp=2, no ``calibration: stale`` tag) -> re-searched plan across a
    (d1,d2) change -> checkpoint restored SHARDED onto the new mesh ->
    loss trajectory matches an uninterrupted 8-device run (the strategy
    is a layout choice, not a math change).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.elastic_smoke
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import tempfile

import jax


def check(ok: bool, what: str):
    if not ok:
        print(f"[elastic-smoke] FAIL: {what}")
        sys.exit(1)
    print(f"[elastic-smoke] ok: {what}")


FAIL_STEP = 5
TOTAL_STEPS = 8


def run(cfg, plan, ckpt_dir, *, shrink: bool):
    """One fault-tolerant training run; optionally fail + shrink to 2.

    The pool is observed through the membership fabric (4 simulated
    hosts x 2 devices): the injected failure kills hosts 1-3 on the
    fabric and raises — recovery then waits for lease expiry + quorum
    commit before re-planning on the agreed 2-device survivor pool.
    """
    from repro.data.pipeline import DataConfig, TokenSource
    from repro.launch.train import make_elastic_trainer
    from repro.optim import adamw
    from repro.runtime.membership import (MembershipRuntime,
                                          fabric_over_devices)
    from repro.runtime.trainer import TrainerConfig

    fabric = fabric_over_devices(4, jax.devices()[:8])
    membership = MembershipRuntime(fabric, local_rank=0)
    fired = {"n": 0}

    def injector(step):
        if shrink and step == FAIL_STEP and fired["n"] == 0:
            fired["n"] = 1
            for r in (1, 2, 3):   # the pod lost 6 of 8 devices
                fabric.fail_host(r)
            raise RuntimeError("injected device loss")

    source = TokenSource(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))
    trainer, live = make_elastic_trainer(
        cfg, plan, adamw.AdamWConfig(lr=1e-3, mode="zero1",
                                     total_steps=TOTAL_STEPS),
        TrainerConfig(total_steps=TOTAL_STEPS, ckpt_dir=ckpt_dir,
                      ckpt_every=2, max_failures=2),
        source, batch=8, seq=32, membership=membership,
        recalibrate=True, recalib_deadline_s=120.0)
    params, opt = trainer.run(fail_injector=injector)
    # last loss per step (replayed steps overwrite their first attempt)
    losses = {h["step"]: h["loss"] for h in trainer.history}
    return trainer, live, fabric, (params, opt), losses


def main():
    from repro.checkpoint import manager as ckpt
    from repro.configs.base import ModelConfig
    from repro.core import comm_matrix
    from repro.core.calibrate import calibrate_mesh

    ndev = len(jax.devices())
    check(ndev >= 8, f"8 simulated devices attached (have {ndev})")

    cfg = ModelConfig(name="smoke-elastic", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16, dtype="float32")

    # a calibrated dp=2 x (2,2) plan over all 8 devices: the elastic path
    # must cross a genuine (d1,d2) change (tp 4 -> 2) AND refresh the table
    from repro.core.plan import plan_search
    matrix = comm_matrix.PRESETS["ic3"]()
    calib = calibrate_mesh(4, matrix, payload_kb=16, repeats=1)
    plan = plan_search("ic3", 4, model=cfg, batch=8, seq=32, dp=2,
                       calibration=calib, chunks_options=(1, 2)).best
    check(plan.devices == 8 and plan.tp == 4,
          f"initial plan uses the full pod: {plan.describe()}")
    check(plan.calibration is not None and plan.calibration.covers_tp(4),
          "initial plan carries a tp=4 calibration table")

    with tempfile.TemporaryDirectory() as td:
        base_dir = os.path.join(td, "base")
        elas_dir = os.path.join(td, "elastic")

        _, _, _, _, base_losses = run(cfg, plan, base_dir, shrink=False)
        tr, live, fabric, (params, opt), elas_losses = run(
            cfg, plan, elas_dir, shrink=True)

        # 1. the failure was recovered through the re-plan path
        check(tr.replans == [FAIL_STEP],
              f"one elastic re-plan at step {FAIL_STEP}: {tr.replans}")
        # 1b. the shrink was agreed through the membership protocol: one
        #     quorum-committed view per epoch, host 0 the elected planner
        epochs = fabric.epochs()
        check(all(len(v) == 1 for v in epochs.values()),
              f"one committed view per epoch (no split-brain): {epochs}")
        final = fabric.hosts[0].committed
        check(final.alive == (0,) and final.planner == 0,
              f"converged view is the survivor set: {final}")
        check(tr.total_failures == 1 and tr.failures == 0,
              "failure counter decayed after recovery "
              f"(total={tr.total_failures}, consecutive={tr.failures})")
        check(tr.watchdog.ema is not None,
              "watchdog EMA re-seeded from post-replan steps")

        # 2. the recovered job runs a re-searched plan over the surviving
        #    mesh, priced by FRESH surviving-mesh measurements
        new_plan = live["plan"]
        check(new_plan.tp == 2 and new_plan.devices <= 2,
              f"re-plan fits the surviving pool: {new_plan.describe()}")
        check((new_plan.d1, new_plan.d2) != (plan.d1, plan.d2),
              f"(d1,d2) actually changed: {plan.d1, plan.d2} -> "
              f"{new_plan.d1, new_plan.d2}")
        check(new_plan.calibration is not None
              and new_plan.calibration.covers_tp(2),
              "calibration table has fresh surviving-mesh (tp=2) entries")
        check(not new_plan.calibration_stale
              and "[calibration:stale]" not in new_plan.describe(),
              "no calibration:stale tag after recalibration")
        check(any(k == "calibration" and v.startswith("recalibrated")
                  for k, v in new_plan.provenance),
              "recalibration recorded in provenance")
        check(any(k == "calibration" and v.startswith("budget")
                  for k, v in new_plan.provenance),
              "recovery budget spend recorded in provenance")
        check(" calib[" in new_plan.describe(),
              f"describe() surfaces calibration provenance counts: "
              f"{new_plan.describe()}")

        # 2b. static conformance: both the original 8-device plan and the
        #     re-searched surviving-mesh plan must build steps that emit
        #     exactly the collectives they priced
        from repro.analysis import assert_step_conforms
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import batch_struct, build_train_step
        from repro.models import lm
        from repro.optim import adamw

        ap = lm.abstract_params(cfg)
        for p, tag in ((plan, "initial"), (new_plan, "re-searched")):
            fn, binfo = build_train_step(cfg, plan=p)
            aopt = adamw.init_opt_state(ap, binfo.pspecs, binfo.ctx,
                                        "zero1", abstract=True)
            ab = batch_struct(cfg, ShapeConfig("x", 32, 8, "train"),
                              "train")
            assert_step_conforms(fn, cfg, p, "train", 8, 32, ap, aopt, ab)
            check(True, f"{tag} plan's train build conforms (static lint)")

        # 3. restored state landed SHARDED on the new (d1,d2) mesh
        inf = live["info"]
        want = jax.tree.leaves(inf.sharding(inf.pspecs))
        got = [p.sharding for p in jax.tree.leaves(params)]
        check(all(g == w for g, w in zip(got, want)),
              "final params carry the new plan's shardings")
        check(all(len(g.device_set) == 2 for g in got),
              "params live on the 2-device surviving mesh")
        from repro.optim import adamw
        canonical = adamw.unbank_opt_state(params, opt, inf.pspecs,
                                           live["ctx"], "zero1")
        canon_sh = inf.sharding(
            adamw.opt_state_specs(inf.pspecs, live["ctx"], "plain"))
        restored, meta = ckpt.restore(
            elas_dir, (params, canonical),
            shardings=(inf.sharding(inf.pspecs), canon_sh))
        check(all(r.sharding == w for r, w in
                  zip(jax.tree.leaves(restored[0]), want)),
              f"manager.restore reshards step-{meta['step']} params onto "
              "the surviving mesh")

        # 4. loss continuity: the interrupted-and-shrunk run replays the
        #    identical trajectory (deterministic data + layout-only
        #    strategy change)
        check(sorted(elas_losses) == list(range(TOTAL_STEPS)),
              f"all {TOTAL_STEPS} steps committed: {sorted(elas_losses)}")
        drift = max(abs(elas_losses[s] - base_losses[s])
                    / max(1.0, abs(base_losses[s]))
                    for s in base_losses)
        check(drift < 5e-4,
              f"loss trajectory continuous vs uninterrupted run "
              f"(max rel drift {drift:.2e})")

        # 5. the deprecated devices_fn poll still works — behind the
        #    SingleObserverMembership shim and a loud warning
        import warnings

        from repro.data.pipeline import DataConfig, TokenSource
        from repro.launch.train import make_elastic_trainer
        from repro.optim import adamw
        from repro.runtime.trainer import TrainerConfig
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            make_elastic_trainer(
                cfg, plan, adamw.AdamWConfig(lr=1e-3, total_steps=1),
                TrainerConfig(total_steps=1,
                              ckpt_dir=os.path.join(td, "shim")),
                TokenSource(DataConfig(vocab_size=cfg.vocab_size,
                                       seq_len=32, global_batch=8)),
                batch=8, seq=32, devices_fn=lambda: jax.devices()[:8])
        check(any(issubclass(w.category, DeprecationWarning)
                  and "devices_fn" in str(w.message) for w in caught),
              "devices_fn= raises a DeprecationWarning (shimmed)")
    print("[elastic-smoke] PASS")


if __name__ == "__main__":
    main()
