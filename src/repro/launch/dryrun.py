import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
    jax.jit(step, in_shardings=..., out_shardings=...)\
        .lower(**ShapeDtypeStruct inputs).compile()
and record memory_analysis(), cost_analysis(), and collective bytes parsed
from the optimized HLO into results/dryrun/<cell>.json — the §Roofline
tables read from these.

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
          --shape train_4k [--multi-pod] [--d1 4 --d2 4] [--all]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ShapeConfig, shape_by_name
from repro.configs.registry import ARCHS, get_config
from repro.core.mesh import MeshTopo, atp_topo, production_topo
from repro.launch import hlo_analysis
from repro.launch.steps import (batch_struct, build_decode_step, build_prefill,
                                build_train_step)
from repro.models import lm
from repro.optim import adamw

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def cell_runnable(cfg, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: long_500k requires sub-quadratic decode; "
                       f"{cfg.name} is full-attention (DESIGN.md §5)")
    return True, ""


def make_topo(multi_pod: bool, d1: int | None, d2: int | None) -> MeshTopo:
    if d1 is None:
        return production_topo(multi_pod)
    return atp_topo(16, d1, d2, pods=2 if multi_pod else 1)


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               d1: int | None = None, d2: int | None = None,
               chunks: int = 1, opt_mode: str = "zero1",
               remat: bool = True, plan=None):
    """Lower + compile one cell; returns the result record dict.

    ``plan`` (a ParallelPlan) overrides d1/d2/chunks and is threaded into
    every builder, so the compiled HLO is certifiably the searched
    strategy; the record embeds the plan JSON for provenance.
    """
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    ok, why = cell_runnable(cfg, shape)
    if plan is not None:
        d1, d2, chunks = plan.d1, plan.d2, plan.chunks
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": f"(pod=2,)16x16" if multi_pod else "16x16",
        "atp": [d1, d2] if d1 else [16, 1],
        "chunks": chunks, "kind": shape.kind,
    }
    if plan is not None:
        rec["plan"] = plan.to_dict()
        if plan.segments:
            # compact per-segment knob summary next to the full v2 JSON
            rec["segment_plans"] = [s.describe() for s in plan.segments]
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    topo = make_topo(multi_pod, d1, d2)
    mesh = topo.build()
    t0 = time.time()
    try:
        if shape.kind == "train":
            step, info = build_train_step(
                cfg, topo, adamw.AdamWConfig(mode=opt_mode), chunks=chunks,
                remat=remat, mesh=mesh, plan=plan)
            params = lm.abstract_params(cfg)
            opt = adamw.init_opt_state(params, info.pspecs, info.ctx,
                                       opt_mode, abstract=True)
            batch = batch_struct(cfg, shape, "train")
            lowered = step.lower(params, opt, batch)
        elif shape.kind == "prefill":
            step, info = build_prefill(cfg, topo, chunks=chunks, mesh=mesh,
                                       plan=plan)
            params = lm.abstract_params(cfg)
            batch = batch_struct(cfg, shape, "prefill")
            lowered = step.lower(params, batch)
        else:  # decode
            step, info = build_decode_step(cfg, topo, shape.global_batch,
                                           shape.seq_len, mesh=mesh,
                                           plan=plan)
            params = lm.abstract_params(cfg)
            caches, _ = lm.init_decode_caches(
                cfg, info.ctx, shape.global_batch, shape.seq_len, abstract=True)
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = step.lower(params, tokens, pos, caches)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        full = hlo_analysis.full_analysis(hlo)
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        rec["memory"] = {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "peak_memory_in_bytes")
        }
        # cost_analysis counts while bodies once (verified) — kept only for
        # reference; the roofline uses the trip-aware HLO accounting below.
        rec["xla_cost_flops_1iter"] = float(cost.get("flops", 0.0)) if cost else 0.0
        rec["flops"] = full["dot_flops"]              # per device, trip-aware
        rec["traffic_bytes"] = full["traffic_bytes"]  # per device, trip-aware
        rec["collectives"] = full["collectives"]      # per device, trip-aware
        rec["params"] = lm.count_params(lm.abstract_params(cfg))
        _save_hlo(rec, hlo)
        print(f"[ok] {arch} x {shape_name} mesh={rec['mesh']} atp={rec['atp']} "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"flops={rec['flops']:.3e} traffic={rec['traffic_bytes']:.3e} "
              f"coll={rec['collectives']['total_gbytes']:.2f}GB")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[ERR] {arch} x {shape_name}: {rec['error'][:200]}")
    return rec


def cell_name(rec) -> str:
    atp = f"atp{rec['atp'][0]}x{rec['atp'][1]}"
    pod = "pod2" if rec["multi_pod"] else "pod1"
    ck = f"_ck{rec['chunks']}" if rec.get("chunks", 1) > 1 else ""
    return f"{rec['arch']}__{rec['shape']}__{pod}__{atp}{ck}"


def _save_hlo(rec, hlo: str):
    import gzip
    out_dir = os.path.join(RESULTS_DIR, "hlo")
    os.makedirs(out_dir, exist_ok=True)
    with gzip.open(os.path.join(out_dir, cell_name(rec) + ".hlo.gz"), "wt") as f:
        f.write(hlo)


def save_rec(rec, out_dir=None):
    out_dir = out_dir or RESULTS_DIR
    os.makedirs(out_dir, exist_ok=True)
    name = cell_name(rec) + ".json"
    rec = {k: v for k, v in rec.items() if k != "traceback"}
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    return name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--d1", type=int, default=None)
    ap.add_argument("--d2", type=int, default=None)
    ap.add_argument("--chunks", type=int, default=1)
    ap.add_argument("--opt-mode", default="zero1")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--plan", default=None,
                    help="saved ParallelPlan JSON driving d1/d2/chunks/"
                         "boundary_mode/seq_parallel for every cell")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell on this mesh")
    args = ap.parse_args()

    assert len(jax.devices()) >= 512, "dryrun needs the 512 virtual devices"

    plan = None
    if args.plan:
        from repro.core.plan import ParallelPlan
        plan = ParallelPlan.load(args.plan)

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in LM_SHAPES:
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        rec = lower_cell(arch, shape, multi_pod=args.multi_pod,
                         d1=args.d1, d2=args.d2, chunks=args.chunks,
                         opt_mode=args.opt_mode, remat=not args.no_remat,
                         plan=plan)
        save_rec(rec)


if __name__ == "__main__":
    main()
