"""Serving launcher: wave-batched prefill + decode over an ATP mesh.

Admits up to `--slots` requests per wave, prefills the whole wave with one
multi-token cache-write step, then decodes all streams in lockstep with
greedy sampling.  The distribution strategy comes from a ParallelPlan —
searched in-process (``--auto-atp``) or loaded from a saved artifact
(``--plan plan.json``), the same file ``train --save-plan`` writes — so a
searched strategy reaches inference unchanged.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 6 --max-new 8 [--plan plan.json | --auto-atp]
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.mesh import atp_topo
from repro.core.plan import ParallelPlan
from repro.launch.steps import resolve_ctx, build_decode_step
from repro.models import lm

log = logging.getLogger("repro.serve")


def serve(cfg, topo, params, prompts, max_new: int, max_seq: int,
          plan: ParallelPlan | None = None):
    """prompts: list of equal-length int arrays (one wave)."""
    topo = topo if topo is not None else plan.topo()
    mesh = topo.build()
    ctx = resolve_ctx(topo, plan, decode=True)
    B = len(prompts)
    plen = len(prompts[0])
    prefill_fn, info = build_decode_step(cfg, topo, B, max_seq, mesh=mesh,
                                         seq_in=plen, plan=plan)
    decode_fn, _ = build_decode_step(cfg, topo, B, max_seq, mesh=mesh,
                                     plan=plan)
    params = jax.device_put(params, info.sharding(info.pspecs))
    caches, cache_specs = lm.init_decode_caches(cfg, ctx, B, max_seq)
    caches = jax.device_put(caches, info.sharding(cache_specs))

    toks = jnp.asarray(np.stack(prompts))
    nxt, caches = prefill_fn(params, toks, jnp.int32(0), caches)
    outs = [np.asarray(nxt)]
    pos = plen
    for _ in range(max_new - 1):
        nxt, caches = decode_fn(params, jnp.asarray(outs[-1])[:, None],
                                jnp.int32(pos), caches)
        outs.append(np.asarray(nxt))
        pos += 1
    return np.stack(outs, axis=1)  # [B, max_new]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--d1", type=int, default=1)
    ap.add_argument("--d2", type=int, default=1)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--plan", default=None,
                    help="load a saved ParallelPlan JSON (train --save-plan)")
    ap.add_argument("--auto-atp", action="store_true",
                    help="search a plan for this arch/shape (paper §3.5)")
    ap.add_argument("--topology", default="v5e",
                    help="comm-matrix preset for --auto-atp")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    plan = None
    if args.plan:
        plan = ParallelPlan.load(args.plan)
        log.info("loaded plan %s: %s", args.plan, plan.describe())
    elif args.auto_atp:
        from repro.core.plan import plan_search

        plan = plan_search(
            args.topology, args.d1 * args.d2, model=cfg,
            batch=args.slots, seq=args.prompt_len + args.max_new,
            dp=args.dp).best
        log.info("ATP plan search picked %s", plan.describe())
    topo = plan.topo() if plan is not None else atp_topo(args.dp, args.d1,
                                                         args.d2)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    pending = [rng.integers(0, cfg.vocab_size, size=args.prompt_len,
                            dtype=np.int32) for _ in range(args.requests)]
    done = 0
    wave = 0
    while pending:
        batch = pending[: args.slots]
        pending = pending[args.slots:]
        while len(batch) < args.slots:   # pad the last wave
            batch.append(np.zeros(args.prompt_len, np.int32))
        outs = serve(cfg, topo, params, batch, args.max_new, args.max_seq,
                     plan=plan)
        for i, o in enumerate(outs[: min(args.slots, done + args.requests - done)]):
            log.info("wave %d slot %d -> %s", wave, i, o.tolist())
        done += len(batch)
        wave += 1
    log.info("served %d requests in %d waves", args.requests, wave)


if __name__ == "__main__":
    main()
