"""Serving launcher: paged continuous batching (fast path) or the wave loop.

Two modes:

  - ``--mode paged`` (default): chunked prefill + continuous batching
    over block-paged KV caches (``runtime.server.Server``).  Mixed-length
    requests share one compiled paged step (prefill chunks at b=1, decode
    ticks at b=slots) — no per-length recompiles, no wave barriers.
  - ``--mode wave``: the seed-era wave loop (kept as a baseline).

The distribution strategy comes from a ParallelPlan — searched in-process
(``--auto-atp``, which also runs the latency-aware DECODE objective and
attaches its sub-plan) or loaded from a saved artifact (``--plan``).
Serving is decode-dominated, so when the plan carries a decode sub-plan
whose factorization differs from the train mesh, the whole serving stack
is built on ``plan.decode_view()`` — the ATP thesis applied to inference:
the objective (here: per-token latency, not per-step bandwidth) picks the
mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 6 --max-new 8 [--plan plan.json | --auto-atp]
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.mesh import atp_topo
from repro.core.plan import ParallelPlan
from repro.launch.steps import (build_decode_step, build_paged_step,
                                resolve_ctx)
from repro.models import lm
from repro.models.paging import PagedConfig
from repro.runtime.server import Request, Server, ServerConfig

log = logging.getLogger("repro.serve")


def serve(cfg, topo, params, prompts, max_new: int, max_seq: int,
          plan: ParallelPlan | None = None):
    """Wave baseline.  prompts: list of equal-length int arrays (one wave)."""
    topo = topo if topo is not None else plan.topo()
    mesh = topo.build()
    ctx = resolve_ctx(topo, plan, decode=True)
    B = len(prompts)
    plen = len(prompts[0])
    prefill_fn, info = build_decode_step(cfg, topo, B, max_seq, mesh=mesh,
                                         seq_in=plen, plan=plan)
    decode_fn, _ = build_decode_step(cfg, topo, B, max_seq, mesh=mesh,
                                     plan=plan)
    params = jax.device_put(params, info.sharding(info.pspecs))
    caches, cache_specs = lm.init_decode_caches(cfg, ctx, B, max_seq)
    caches = jax.device_put(caches, info.sharding(cache_specs))

    toks = jnp.asarray(np.stack(prompts))
    nxt, caches = prefill_fn(params, toks, jnp.int32(0), caches)
    outs = [np.asarray(nxt)]
    pos = plen
    for _ in range(max_new - 1):
        nxt, caches = decode_fn(params, jnp.asarray(outs[-1])[:, None],
                                jnp.int32(pos), caches)
        outs.append(np.asarray(nxt))
        pos += 1
    return np.stack(outs, axis=1)  # [B, max_new]


def make_paged_server(cfg, scfg: ServerConfig, params,
                      plan: ParallelPlan | None = None, topo=None):
    """Build the paged continuous-batching server on the serving mesh.

    With a plan whose decode sub-plan prescribes a different (d1, d2)
    than the train mesh, the stack is built from ``plan.decode_view()``
    — serving is decode-dominated, and prefill/decode share one set of
    sharded params and caches, so the decode mesh wins.

    Mode resolution: recurrent archs (mamba/zamba/xlstm segments) get
    the slot-addressed step automatically; ``speculate``/``prefix_cache``
    come from the ServerConfig OR the plan's decode sub-plan (the search
    records when they pay), and are downgraded with a log line when the
    arch cannot support them (no MTP head, recurrent state).
    """
    from repro.configs.base import segments

    if plan is not None:
        view = plan.decode_view()
        if (view.d1, view.d2) != (plan.d1, plan.d2):
            log.info("decode sub-plan re-meshes serving: %s -> "
                     "DeviceMesh(%d,%d)", plan.describe(), view.d1, view.d2)
        topo = view.topo()
        dec = view.decode
        if dec is not None:
            scfg = dataclasses.replace(
                scfg, speculate=scfg.speculate or dec.speculate,
                prefix_cache=scfg.prefix_cache or dec.prefix_cache)
        plan = view
    elif topo is None:
        raise TypeError("make_paged_server needs a plan or a topo")
    recurrent = any(s.kind in lm.RECURRENT_STATE_KINDS
                    for s in segments(cfg))
    if scfg.speculate and (not cfg.mtp or recurrent):
        log.info("speculative decode off: %s",
                 "no MTP head" if not cfg.mtp else "recurrent state")
        scfg = dataclasses.replace(scfg, speculate=False)
    if scfg.prefix_cache and recurrent:
        log.info("prefix cache off: recurrent state is not page-addressable")
        scfg = dataclasses.replace(scfg, prefix_cache=False)
    scfg = dataclasses.replace(scfg, recurrent=recurrent)
    step_fn, init_caches, info = _build_paged_step_fn(cfg, scfg, params,
                                                      topo, plan)
    return Server(scfg, step_fn, init_caches), info


def _build_paged_step_fn(cfg, scfg: ServerConfig, params, topo,
                         plan: ParallelPlan | None, devices=None):
    """Compile the paged step for one mesh and wrap it in the Server's
    host-side calling convention.

    The mode flags in ``scfg`` must already be resolved (see
    ``make_paged_server``).  ``devices`` restricts the mesh to a device
    subset — the elastic remesh path passes the survivors.  Returns
    ``(step_fn, init_caches, info)``: everything ``Server(...)`` or
    ``Server.reshape(...)`` needs.
    """
    recurrent = scfg.recurrent
    mesh = topo.build(devices) if devices is not None else topo.build()
    step, info = build_paged_step(
        cfg, topo, paged_cfg=scfg.paged, mesh=mesh, plan=plan,
        slots=scfg.batch_slots if recurrent else None,
        speculate=scfg.speculate)
    params = jax.device_put(params, info.sharding(info.pspecs))

    def init_caches():
        caches, cache_specs = lm.init_paged_caches(
            cfg, info.ctx, scfg.paged,
            slots=scfg.batch_slots if recurrent else None)
        return jax.device_put(caches, info.sharding(cache_specs))

    if recurrent:
        def step_fn(tokens, start, table, slot, caches):
            toks, caches = step(params, jnp.asarray(tokens),
                                jnp.asarray(start), jnp.asarray(table),
                                jnp.asarray(slot), caches)
            return np.asarray(toks), caches
    elif scfg.speculate:
        def step_fn(tokens, start, table, caches):
            toks, drafts, caches = step(params, jnp.asarray(tokens),
                                        jnp.asarray(start),
                                        jnp.asarray(table), caches)
            return np.asarray(toks), np.asarray(drafts), caches
    else:
        def step_fn(tokens, start, table, caches):
            toks, caches = step(params, jnp.asarray(tokens),
                                jnp.asarray(start), jnp.asarray(table),
                                caches)
            return np.asarray(toks), caches

    return step_fn, init_caches, info


def remesh_paged_server(server: Server, cfg, params,
                        plan: ParallelPlan | None = None, topo=None,
                        devices=None):
    """Shrink (or re-mesh) a live paged server onto surviving devices.

    Recompiles the paged step on the new mesh — ``plan`` should be the
    re-searched survivors' plan (its ``decode_view`` wins, exactly as at
    construction) or ``topo`` an explicit topology; ``devices`` the
    surviving pool — and hands it to ``Server.reshape``, which replays
    every in-flight request's progress as prompt continuation on the new
    mesh (greedy-token parity; see its docstring).  The server keeps its
    queue, completed/expired lists, deadlines and counters: from the
    client's side a remesh is just a burst of re-prefill latency.
    Returns the new step ``info``.
    """
    if plan is not None:
        view = plan.decode_view()
        if (view.d1, view.d2) != (plan.d1, plan.d2):
            log.info("remesh: decode sub-plan wins: %s -> DeviceMesh(%d,%d)",
                     plan.describe(), view.d1, view.d2)
        topo = view.topo()
        plan = view
    elif topo is None:
        raise TypeError("remesh_paged_server needs a plan or a topo")
    step_fn, init_caches, info = _build_paged_step_fn(
        cfg, server.cfg, params, topo, plan, devices=devices)
    server.reshape(step_fn, init_caches)
    log.info("server remeshed onto %s: %d in-flight requests replaying",
             topo, len(server.queue))
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=("paged", "wave"), default="paged")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--d1", type=int, default=1)
    ap.add_argument("--d2", type=int, default=1)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool size (0 = sized to the workload)")
    ap.add_argument("--page-dtype", choices=("bf16", "int8", "fp8"),
                    default="bf16",
                    help="KV page-pool storage dtype (int8/fp8 store 1 "
                         "byte/elem + fp16 per-position scales)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="copy-on-write prefix sharing across requests "
                         "(radix index over page contents)")
    ap.add_argument("--speculate", action="store_true",
                    help="MTP self-speculative decode (needs cfg.mtp; "
                         "exact greedy parity)")
    ap.add_argument("--plan", default=None,
                    help="load a saved ParallelPlan JSON (train --save-plan)")
    ap.add_argument("--auto-atp", action="store_true",
                    help="search a plan for this arch/shape (paper §3.5), "
                         "including the latency-aware decode objective")
    ap.add_argument("--topology", default="v5e",
                    help="comm-matrix preset for --auto-atp")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    plan = None
    if args.plan:
        plan = ParallelPlan.load(args.plan)
        log.info("loaded plan %s: %s", args.plan, plan.describe())
    elif args.auto_atp:
        from repro.core.plan import plan_search

        plan = plan_search(
            args.topology, args.d1 * args.d2, model=cfg,
            batch=args.slots, seq=args.prompt_len + args.max_new,
            dp=args.dp, decode_batch=args.slots).best
        log.info("ATP plan search picked %s", plan.describe())
    topo = plan.topo() if plan is not None else atp_topo(args.dp, args.d1,
                                                         args.d2)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    if args.mode == "paged":
        # mixed prompt lengths: the workload the paged path is built for
        lens = [max(1, int(rng.integers(args.prompt_len // 2,
                                        args.prompt_len + 1)))
                for _ in range(args.requests)]
    else:
        # the wave loop decodes in lockstep from one shared position and
        # would condition shorter prompts on their padding — keep its
        # workload equal-length (mixed lengths are the paged mode's job)
        lens = [args.prompt_len] * args.requests
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in lens]

    if args.mode == "paged":
        mp = -(-(args.max_seq) // args.page_size)
        num_pages = args.num_pages or (
            1 + sum(-(-(n + args.max_new) // args.page_size)
                    for n in lens))
        scfg = ServerConfig(
            batch_slots=args.slots, prefill_chunk=args.prefill_chunk,
            paged=PagedConfig(page_size=args.page_size,
                              num_pages=num_pages, pages_per_slot=mp,
                              page_dtype=args.page_dtype),
            prefix_cache=args.prefix_cache, speculate=args.speculate)
        server, _ = make_paged_server(cfg, scfg, params, plan=plan,
                                      topo=topo)
        for rid, p in enumerate(prompts):
            server.submit(Request(rid=rid, prompt=p, max_new=args.max_new))
        ticks = server.run_until_drained()
        for req in sorted(server.completed, key=lambda r: r.rid):
            log.info("request %d (%d prompt tokens) -> %s",
                     req.rid, len(req.prompt), req.out)
        st = server.stats()
        log.info("served %d requests in %d ticks (continuous); "
                 "pages_shared=%d prefix_hit_rate=%.3f "
                 "spec_accept_rate=%.3f used_cache_bytes=%d",
                 len(server.completed), ticks, st["pages_shared"],
                 st["prefix_hit_rate"], st["spec_accept_rate"],
                 st["used_cache_bytes"])
        return

    # wave baseline: equal-length waves
    done = 0
    wave = 0
    pending = list(prompts)
    while pending:
        batch = pending[: args.slots]
        pending = pending[args.slots:]
        while len(batch) < args.slots:   # pad the last wave with dummies
            batch.append(np.zeros(args.prompt_len, np.int32))
        outs = serve(cfg, topo, params, batch, args.max_new, args.max_seq,
                     plan=plan)
        for i, o in enumerate(outs[: min(args.slots, done + args.requests - done)]):
            log.info("wave %d slot %d -> %s", wave, i, o.tolist())
        done += len(batch)
        wave += 1
    log.info("served %d requests in %d waves", args.requests, wave)


if __name__ == "__main__":
    main()
