"""Chaos smoke gate (``make chaos-smoke``): scripted faults, end to end.

Five seeded scenarios drive the fault-domain runtime through its
recovery invariants and exit non-zero on any violation:

  S1  membership-elastic: device loss kills 2 of 4 simulated hosts while
      a lease-delay fault makes a survivor look suspect — the loop must
      converge on ONE quorum-committed view per epoch (no double-reshard
      from concurrent detectors), re-plan once on the agreed 4-device
      pool, and replay to loss continuity vs an uninterrupted run.
  S2  deadline-budgeted recalibration under a scripted clock: the spend
      must stay within ``deadline_s``, most-sensitive factorizations
      measured first, the rest degraded to carried/analytic entries with
      provenance recorded in the plan artifact.
  S3  server degradation: a backpressure window + per-request deadlines
      walk the full ladder (admission backoff -> skipped beats ->
      expiry) and the page pool must fully drain.
  S4  decode-mesh shrink: ``remesh_paged_server`` replays in-flight
      prefill on the survivors with greedy-token parity for every
      request.
  S5  torn checkpoint write + straggler window: the torn save is
      counted/retried/swept by the trainer (not fatal), the straggler
      trips the watchdog.

Metrics land in ``BENCH_chaos.json`` (tracked by ``make bench-regress``).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.chaos_smoke
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import json
import sys
import tempfile

import jax
import numpy as np


def check(ok: bool, what: str):
    if not ok:
        print(f"[chaos-smoke] FAIL: {what}")
        sys.exit(1)
    print(f"[chaos-smoke] ok: {what}")


def tiny_cfg(num_kv_heads: int = 2):
    from repro.configs.base import ModelConfig

    return ModelConfig(name="smoke-chaos", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=num_kv_heads,
                       d_ff=128, vocab_size=256, head_dim=16,
                       dtype="float32")


# ---------------------------------------------------------------------------
# S1: membership-driven elastic recovery under device loss + lease delay.
# ---------------------------------------------------------------------------

FAIL_STEP = 5
TOTAL_STEPS = 8


def _train_run(cfg, plan, ckpt_dir, fplan=None):
    from repro.data.pipeline import DataConfig, TokenSource
    from repro.launch.train import make_elastic_trainer
    from repro.optim import adamw
    from repro.runtime.faults import delivery_schedule, trainer_injector
    from repro.runtime.membership import (MembershipRuntime,
                                          fabric_over_devices)
    from repro.runtime.trainer import TrainerConfig

    delivery = delivery_schedule(fplan) if fplan is not None else None
    fabric = fabric_over_devices(4, jax.devices()[:8], delivery=delivery)
    injector = (trainer_injector(fplan, fabric)
                if fplan is not None else None)
    source = TokenSource(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))
    trainer, live = make_elastic_trainer(
        cfg, plan, adamw.AdamWConfig(lr=1e-3, mode="zero1",
                                     total_steps=TOTAL_STEPS),
        TrainerConfig(total_steps=TOTAL_STEPS, ckpt_dir=ckpt_dir,
                      ckpt_every=2, max_failures=2),
        source, batch=8, seq=32,
        membership=MembershipRuntime(fabric, local_rank=0),
        recalibrate=True, recalib_deadline_s=120.0)
    trainer.run(fail_injector=injector)
    losses = {h["step"]: h["loss"] for h in trainer.history}
    return trainer, live, fabric, losses


def scenario_membership_elastic(metrics):
    from repro.core.plan import plan_search
    from repro.runtime.faults import FaultEvent, FaultPlan

    cfg = tiny_cfg()
    plan = plan_search("ic3", 4, model=cfg, batch=8, seq=32, dp=2).best
    check(plan.devices == 8, f"S1 plan uses the full pod: {plan.describe()}")

    # hosts 2+3 die at step 5; host 1's heartbeats lag 0.25s for the
    # first simulated second — long enough to flicker past lease_s, so
    # hosts 0 and 1 DISAGREE while both detect the death concurrently.
    # The quorum must hold the reshard until they agree on (0, 1).
    fplan = FaultPlan.scripted(
        FaultEvent("device_loss", at=FAIL_STEP, hosts=(2, 3)),
        FaultEvent("lease_delay", at=0.0, hosts=(1,), duration=1.0,
                   severity=0.25),
        seed=1001)
    # the scripted plan must survive a JSON round-trip byte-identically
    check(FaultPlan.from_dict(fplan.to_dict()) == fplan,
          "S1 FaultPlan JSON round-trips")

    with tempfile.TemporaryDirectory() as td:
        _, _, _, base_losses = _train_run(
            cfg, plan, os.path.join(td, "base"))
        tr, live, fabric, losses = _train_run(
            cfg, plan, os.path.join(td, "chaos"), fplan)

    check(tr.replans == [FAIL_STEP],
          f"S1 exactly one re-plan despite concurrent detectors: "
          f"{tr.replans}")
    epochs = fabric.epochs()
    check(bool(epochs) and all(len(v) == 1 for v in epochs.values()),
          f"S1 one committed view per epoch (no split-brain): {epochs}")
    final = fabric.hosts[0].committed
    check(final.alive == (0, 1) and final.planner == 0,
          f"S1 converged on the survivor set with host 0 planning: {final}")
    new_plan = live["plan"]
    check(new_plan.devices <= 4 and not new_plan.calibration_stale,
          f"S1 re-plan fits 4 survivors, recalibrated: "
          f"{new_plan.describe()}")
    check(any(k == "calibration" and v.startswith("budget")
              for k, v in new_plan.provenance),
          "S1 recovery budget spend recorded in plan provenance")
    drift = max(abs(losses[s] - base_losses[s])
                / max(1.0, abs(base_losses[s])) for s in base_losses)
    check(drift < 5e-4, f"S1 loss continuity after shrink "
                        f"(max rel drift {drift:.2e})")
    # first originating commit of epoch 1 = agreement latency (sim time)
    t_commit = min(c.t for c in fabric.commits if c.view.epoch == 1)
    metrics["loss_continuity"] = 1.0
    metrics["single_replanner"] = 1.0
    metrics["recovery_sim_s"] = round(t_commit, 3)


# ---------------------------------------------------------------------------
# S2: deadline-budgeted recalibration under a scripted clock.
# ---------------------------------------------------------------------------


def scenario_budget(metrics):
    from repro.core.calibrate import (CalibEntry, CalibrationTable,
                                      recalibrate_surviving)
    from repro.core.plan import ParallelPlan, replan_elastic

    cfg = tiny_cfg()
    old = CalibrationTable(entries=(
        ((4, 1), CalibEntry(b1=10.0, b2=float("inf"))),
        ((2, 2), CalibEntry(b1=9.0, b2=8.0)),
        ((1, 4), CalibEntry(b1=float("inf"), b2=7.0)),
    ), source="measured")
    plan = ParallelPlan(d1=4, d2=1, dp=2, topology="ic3", calibration=old,
                        provenance=(("calibration", "stale"),))
    clock = [0.0]

    def timer():
        return clock[0]

    def measure(d1, d2):
        clock[0] += 1.0   # every factorization costs 1 scripted second
        return CalibEntry(b1=100.0, b2=100.0)

    deadline = 1.5
    new = recalibrate_surviving(plan, devices=list(range(4)),
                                measure=measure, deadline_s=deadline,
                                timer=timer)
    spent = clock[0]
    check(spent <= deadline,
          f"S2 recalibration stayed within deadline_s "
          f"({spent:.1f}s <= {deadline}s)")
    counts = new.calibration.provenance_counts()
    check(counts.get("measured", 0) == 1 and counts.get("carried", 0) == 2,
          f"S2 budget degraded the tail to carried entries: {counts}")
    check(" calib[" in new.describe(),
          f"S2 describe() shows provenance counts: {new.describe()}")
    check(any(k == "calibration" and v.startswith("budget")
              for k, v in new.provenance),
          "S2 budget spend recorded in provenance")
    # the partially-calibrated artifact still re-searches cleanly and is
    # NOT re-tagged stale (>=1 fresh measurement covers the survivors)
    replanned = replan_elastic(new, 4, model=cfg, batch=8, seq=32)
    check(not replanned.calibration_stale,
          f"S2 re-planned artifact not stale: {replanned.describe()}")

    # exhausted budget: nothing measured -> honesty demands the stale tag
    clock[0] = 0.0
    empty = recalibrate_surviving(plan, devices=list(range(4)),
                                  measure=measure, deadline_s=0.0,
                                  timer=timer)
    check(empty.calibration.provenance_counts().get("measured", 0) == 0
          and not any(v.startswith("recalibrated")
                      for _, v in empty.provenance),
          "S2 fully-exhausted budget does not claim recalibration")
    metrics["budget_respected"] = 1.0


# ---------------------------------------------------------------------------
# S3 + S4: server degradation ladder and decode-mesh shrink parity.
# ---------------------------------------------------------------------------


def _make_server(cfg, params, topo, *, num_pages, devices=None):
    from repro.launch.serve import _build_paged_step_fn, make_paged_server
    from repro.models.paging import PagedConfig
    from repro.runtime.server import ServerConfig

    scfg = ServerConfig(batch_slots=2, prefill_chunk=4,
                        paged=PagedConfig(page_size=4, num_pages=num_pages,
                                          pages_per_slot=8))
    if devices is None:
        server, _ = make_paged_server(cfg, scfg, params, topo=topo)
        return server
    step_fn, init_caches, _ = _build_paged_step_fn(cfg, scfg, params, topo,
                                                   None, devices=devices)
    from repro.runtime.server import Server

    return Server(scfg, step_fn, init_caches)


def scenario_server_degradation(metrics):
    from repro.core.mesh import atp_topo
    from repro.models import lm
    from repro.runtime.faults import BackpressureAllocator, FaultEvent, \
        FaultPlan
    from repro.runtime.server import Request

    cfg = tiny_cfg(num_kv_heads=4)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    server = _make_server(cfg, params, atp_topo(1, 2, 1), num_pages=40)
    fplan = FaultPlan.scripted(
        FaultEvent("backpressure", at=2, duration=12), seed=1003)
    bp = BackpressureAllocator(server.alloc, fplan, lambda: server.ticks)
    server.alloc = bp

    for rid in range(6):
        p = rng.integers(0, cfg.vocab_size, size=6, dtype=np.int32)
        # 4 deadlined requests die inside the window; 2 patient ones must
        # survive it and complete
        server.submit(Request(rid=rid, prompt=p, max_new=6,
                              deadline_ticks=12 if rid < 4 else None))
    server.run_until_drained()
    st = server.stats()
    check(bp.denied > 0, f"S3 backpressure window denied allocations "
                         f"({bp.denied})")
    check(st["admission_retries"] > 0,
          f"S3 admissions retried with backoff "
          f"({st['admission_retries']} retries)")
    check(st["expired"] > 0,
          f"S3 deadlined requests expired under pressure "
          f"({st['expired']}/{6})")
    for r in server.expired:
        check(r.expired and not r.done, f"S3 request {r.rid} marked expired")
    check(len(server.completed) == 2
          and sorted(r.rid for r in server.completed) == [4, 5],
          f"S3 patient requests completed: "
          f"{sorted(r.rid for r in server.completed)}")
    check(server.alloc.held_pages == 0 and not server.busy,
          "S3 page pool fully drained (expired requests returned pages)")
    metrics["pool_drained"] = 1.0
    metrics["served_fraction"] = len(server.completed) / 6.0
    metrics["expired_request_rate"] = st["expired"] / 6.0


def scenario_remesh_parity(metrics):
    from repro.core.mesh import atp_topo
    from repro.launch.serve import remesh_paged_server
    from repro.models import lm
    from repro.runtime.server import Request

    cfg = tiny_cfg(num_kv_heads=4)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (5, 9, 7, 12)]

    base = _make_server(cfg, params, atp_topo(1, 2, 2), num_pages=40)
    for rid, p in enumerate(prompts):
        base.submit(Request(rid=rid, prompt=p.copy(), max_new=6))
    base.run_until_drained()
    base_out = {r.rid: list(r.out) for r in base.completed}

    srv = _make_server(cfg, params, atp_topo(1, 2, 2), num_pages=40)
    for rid, p in enumerate(prompts):
        srv.submit(Request(rid=rid, prompt=p.copy(), max_new=6))
    for _ in range(7):
        srv.step()   # leave some requests mid-prefill / mid-decode
    in_flight = sum(s is not None for s in srv.slots) + len(srv.queue)
    check(in_flight > 0, f"S4 requests in flight at the shrink "
                         f"({in_flight})")
    remesh_paged_server(srv, cfg, params, topo=atp_topo(1, 2, 1),
                        devices=jax.devices()[:2])
    srv.run_until_drained()
    out = {r.rid: list(r.out) for r in srv.completed}
    check(srv.stats()["reshapes"] == 1, "S4 reshape counted")
    check(out == base_out,
          f"S4 greedy-token parity across the remesh for all "
          f"{len(out)} requests")
    check(srv.alloc.held_pages == 0, "S4 pool drained after the remesh run")
    metrics["remesh_parity"] = 1.0


# ---------------------------------------------------------------------------
# S5: torn checkpoint write + straggler window.
# ---------------------------------------------------------------------------


def scenario_torn_checkpoint(metrics):
    from repro.checkpoint import manager as ckpt
    from repro.core.plan import ParallelPlan
    from repro.data.pipeline import DataConfig, TokenSource
    from repro.launch.train import make_elastic_trainer
    from repro.optim import adamw
    from repro.runtime.faults import (FaultEvent, FaultPlan,
                                      TornCheckpointWrites,
                                      VirtualStepClock)
    from repro.runtime.trainer import TrainerConfig

    cfg = tiny_cfg()
    plan = ParallelPlan(d1=2, d2=1, dp=1,
                        provenance=(("searcher", "chaos-smoke"),))
    fplan = FaultPlan.scripted(
        FaultEvent("torn_ckpt", at=4),
        FaultEvent("straggler", at=2, duration=1, severity=20.0),
        seed=1005)
    source = TokenSource(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))
    vclock = VirtualStepClock(fplan)
    mitigated = []
    with tempfile.TemporaryDirectory() as td:
        trainer, _ = make_elastic_trainer(
            cfg, plan, adamw.AdamWConfig(lr=1e-3, total_steps=6),
            TrainerConfig(total_steps=6, ckpt_dir=td, ckpt_every=2,
                          max_failures=2),
            source, batch=8, seq=32, recalibrate=False)
        trainer.time_fn = vclock
        trainer.mitigation_hook = mitigated.append
        with TornCheckpointWrites(fplan) as torn:
            trainer.run()
        check(torn.torn == [4], f"S5 save torn exactly once: {torn.torn}")
        check(trainer.total_failures == 1,
              f"S5 torn write counted in failure accounting "
              f"({trainer.total_failures})")
        check(ckpt.latest_step(td) == 6,
              f"S5 run completed through the torn save "
              f"(latest ckpt step {ckpt.latest_step(td)})")
        check(not [n for n in os.listdir(td) if n.startswith(".tmp_")],
              "S5 orphan .tmp_ staging dir swept on retry")
    check(len(trainer.history) == 6, "S5 all 6 steps committed")
    check(any(s == 2 for s, _, _ in trainer.watchdog.events),
          f"S5 scripted straggler tripped the watchdog: "
          f"{trainer.watchdog.events}")
    check(mitigated == [2], f"S5 mitigation hook fired: {mitigated}")
    metrics["torn_ckpt_recovered"] = 1.0


def main():
    ndev = len(jax.devices())
    check(ndev >= 8, f"8 simulated devices attached (have {ndev})")
    metrics: dict = {}
    scenario_budget(metrics)           # cheapest first: pure host code
    scenario_torn_checkpoint(metrics)
    scenario_server_degradation(metrics)
    scenario_remesh_parity(metrics)
    scenario_membership_elastic(metrics)
    out = os.environ.get("BENCH_CHAOS_OUT", "BENCH_chaos.json")
    with open(out, "w") as f:
        json.dump(metrics, f, indent=1, sort_keys=True)
    print(f"[chaos-smoke] metrics -> {out}: "
          f"{json.dumps(metrics, sort_keys=True)}")
    print("[chaos-smoke] PASS")


if __name__ == "__main__":
    main()
