"""Int8 gradient compression for the DP all-reduce (distributed-opt trick).

Per-tensor symmetric quantization: scale = max|g| over the DP group / 127,
int8 encode, integer all-reduce (exact in int32), dequantize, divide by DP
degree.  Halves-to-quarters the DP all-reduce bytes vs bf16/fp32 grads.

`compressed_psum_mean_ef` adds error feedback: the quantization residual
is carried to the next step (state threaded by the caller), which restores
convergence to near-lossless in practice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _dp_degree(axes):
    # resolved inside shard_map; psum of 1.0 gives the group size
    return lax.psum(jnp.ones((), jnp.float32), axes)


def compressed_psum_mean(g, axes, bits: int = 8):
    """Quantized DP mean of a gradient tensor (no error feedback)."""
    if not axes:
        return g
    gf = g.astype(jnp.float32)
    amax = lax.pmax(jnp.max(jnp.abs(gf)), axes)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax).astype(jnp.int32)
    total = lax.psum(q, axes).astype(jnp.float32) * scale
    return (total / _dp_degree(axes)).astype(g.dtype)


def compressed_psum_mean_ef(g, err, axes, bits: int = 8):
    """Error-feedback variant.  Returns (mean_grad, new_err).

    The raw quantization residual lives per DP rank (each rank quantized
    its OWN gradient), which would make the carried state unreplicated —
    impossible to emit from a replication-checked shard_map, to
    checkpoint under the parameter specs, or to survive an elastic dp
    change.  So the residuals are averaged over the group on a second
    int8 wire: ``new_err`` is the (quantized) DP-mean residual,
    replicated like the parameters.  Total wire cost 2 bytes/elem —
    still half of f32 gradients — and the carried state approximates
    ``true_mean - mean_grad`` to one residual-grid step.
    """
    if not axes:
        return g, err
    gf = g.astype(jnp.float32) + err
    amax = lax.pmax(jnp.max(jnp.abs(gf)), axes)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax)
    new_err = compressed_psum_mean(gf - q * scale, axes, bits=bits)
    total = lax.psum(q.astype(jnp.int32), axes).astype(jnp.float32) * scale
    return (total / _dp_degree(axes)).astype(g.dtype), new_err
