"""AdamW with optional ZeRO-1 sharding over the data-parallel axes.

Runs INSIDE shard_map.  Three gradient-reduction modes:

  plain   : psum(grads, dp) then full AdamW on every DP rank (ZeRO-0)
  zero1   : psum_scatter(grads) -> shard-local AdamW -> all_gather(updates).
            Optimizer state (m, v) lives only on the owning DP shard:
            1/dp of the fp32 state memory per rank.
  compressed : int8-quantized gradient all-reduce with error feedback
            (distributed-optimization trick; see grad_compress.py)

Every param leaf is flattened and padded to a multiple of the DP degree so
psum_scatter has a clean scatter dim.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.atp import ATPContext


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    mode: str = "zero1"          # plain | zero1 | compressed
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def _pad_to(x, mult):
    flat = x.reshape(-1)
    pad = (-flat.size) % mult
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def _tp_axes_in_spec(spec, ctx: ATPContext) -> tuple[str, ...]:
    """TP axes this leaf is actually sharded over (in (ax1, ax2) order)."""
    found = set()
    for entry in spec:
        names = entry if isinstance(entry, tuple) else (entry,)
        for nm in names:
            if nm is not None and nm in (ctx.ax1, ctx.ax2):
                found.add(nm)
    return tuple(a for a in (ctx.ax1, ctx.ax2) if a is not None and a in found)


def _shard_factor(spec, ctx: ATPContext) -> int:
    f = 1
    for entry in spec:
        names = entry if isinstance(entry, tuple) else (entry,)
        for nm in names:
            if nm:
                f *= ctx.topo.axis_size(nm)
    return f


def zero1_banked(mode: str, ctx: ATPContext) -> bool:
    """True when the zero1 banked [DP, TPs, k] state layout is in effect.

    Must agree with ``apply_adamw``'s dispatch: with no data-parallel axis
    the zero1 step degenerates to full-state AdamW, so the state must
    mirror the params there (banking it was a latent recovery-path bug —
    an elastic shrink to dp=1 handed banked state to the full-state path).
    """
    return mode == "zero1" and bool(ctx.dp_axes)


def init_opt_state(params, param_specs_tree, ctx: ATPContext,
                   mode: str = "zero1", abstract: bool = False):
    """fp32 m/v per leaf (GLOBAL arrays).

    plain/compressed (and zero1 at dp=1): m/v mirror the param shape and
    sharding.
    zero1: banked [DP, TPs, k] with k = ceil(local_param_size / DP); each
    (dp, tp) rank owns one bank — 1/DP of the fp32 state per rank.  The
    bank's TP dim only spans axes the param is sharded over, so banks of
    TP-replicated leaves stay provably replicated (vma invariance).
    """
    dp = ctx.dp
    banked = zero1_banked(mode, ctx)

    def mk(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    def leaf_state(x, spec):
        if not banked:
            return {"m": mk(x.shape, jnp.float32), "v": mk(x.shape, jnp.float32)}
        axes = _tp_axes_in_spec(spec, ctx)
        tpn = math.prod(ctx.topo.axis_size(a) for a in axes) if axes else 1
        local = x.size // _shard_factor(spec, ctx)
        k = math.ceil(local / dp)
        return {"m": mk((dp, tpn, k), jnp.float32),
                "v": mk((dp, tpn, k), jnp.float32)}

    leaves = jax.tree.map(leaf_state, params, param_specs_tree)
    state = {"step": mk((), jnp.int32), "leaves": leaves}
    if mode == "compressed":
        # persistent error-feedback residual: what int8 rounding dropped
        # this step is added back before quantizing the next step.  Param-
        # shaped f32 like plain m/v (compressed is never zero1-banked), so
        # it checkpoints and reshards exactly like the moments.
        state["err"] = jax.tree.map(
            lambda x: mk(x.shape, jnp.float32), params)
    return state


def opt_state_specs(param_specs_tree, ctx: ATPContext, mode: str = "zero1"):
    from jax.sharding import PartitionSpec as P
    dp_t = tuple(ctx.dp_axes) or None
    banked = zero1_banked(mode, ctx)

    def leaf_spec(spec):
        if not banked:
            return {"m": spec, "v": spec}
        axes = _tp_axes_in_spec(spec, ctx)
        s = P(dp_t, axes if axes else None, None)
        return {"m": s, "v": s}

    out = {"step": P(),
           "leaves": jax.tree.map(leaf_spec, param_specs_tree,
                                  is_leaf=lambda x: isinstance(x, P))}
    if mode == "compressed":
        out["err"] = param_specs_tree
    return out


# ---------------------------------------------------------------------------
# Checkpoint layout: the banked zero1 state is a *plan-dependent* runtime
# layout ([DP, TPs, k] depends on (d1, d2, dp)), so a checkpoint written in
# it cannot be restored under a different mesh.  unbank/rebank convert to
# and from the plan-independent param-shaped ("plain") layout on the host;
# the trainer checkpoints canonically and re-banks onto whatever plan is
# live at restore time (elastic reshard across a (d1, d2, dp) change).
# ---------------------------------------------------------------------------


def _tp_coord_of(j: int, axes, sizes) -> dict:
    """Bank index -> mesh coordinate.  The bank's TP dim is sharded
    P(..., axes, ...) with ``axes`` in (ax1, ax2) order, so j is row-major
    over them (first axis most significant)."""
    coord = {}
    for a, s in zip(reversed(axes), reversed(sizes)):
        coord[a] = j % s
        j //= s
    return coord


def _tp_block_slices(shape, spec, ctx: ATPContext, coord: dict):
    """The slices of the GLOBAL leaf owned by mesh coordinate ``coord``.

    A dim sharded over an axis tuple splits row-major in the tuple's own
    order (jax semantics), which need not match the bank's (ax1, ax2)
    order — hence the per-dim relinearization."""
    slices = []
    for d, size in enumerate(shape):
        entry = spec[d] if d < len(spec) else None
        names = entry if isinstance(entry, tuple) else \
            ((entry,) if entry is not None else ())
        names = [nm for nm in names if nm in coord]
        n, b = 1, 0
        for nm in names:
            s = ctx.topo.axis_size(nm)
            n *= s
            b = b * s + coord[nm]
        loc = size // n
        slices.append(slice(b * loc, (b + 1) * loc))
    return tuple(slices)


def unbank_opt_state(params, opt_state, param_specs_tree, ctx: ATPContext,
                     mode: str = "zero1"):
    """GLOBAL banked zero1 state -> param-shaped fp32 m/v (host numpy).

    Identity for layouts that already mirror the params (plain,
    compressed, zero1 at dp=1).  Bank [i, j, :] holds dp-rank i's slice of
    TP-shard j's padded flat moments; the pad region is provably zero
    (zero grads never move it), so unbank -> rebank round-trips exactly.
    """
    import numpy as np

    if not zero1_banked(mode, ctx):
        return opt_state

    def unbank_leaf(p, spec, st):
        axes = _tp_axes_in_spec(spec, ctx)
        sizes = [ctx.topo.axis_size(a) for a in axes]
        shape = tuple(np.shape(p))

        def one(banked):
            banked = np.asarray(jax.device_get(banked))
            dpn, tpn, k = banked.shape
            out = np.zeros(shape, np.float32)
            for j in range(tpn):
                sl = _tp_block_slices(shape, spec, ctx,
                                      _tp_coord_of(j, axes, sizes))
                block = out[sl]
                flat = banked[:, j, :].reshape(dpn * k)[: block.size]
                out[sl] = flat.reshape(block.shape)
            return out

        return {"m": one(st["m"]), "v": one(st["v"])}

    flat_p, tdef = jax.tree.flatten(params)
    flat_spec = tdef.flatten_up_to(param_specs_tree)
    flat_st = tdef.flatten_up_to(opt_state["leaves"])
    leaves = [unbank_leaf(p, s, st)
              for p, s, st in zip(flat_p, flat_spec, flat_st)]
    return {"step": opt_state["step"],
            "leaves": jax.tree.unflatten(tdef, leaves)}


def rebank_opt_state(params, canonical, param_specs_tree, ctx: ATPContext,
                     mode: str = "zero1"):
    """Param-shaped fp32 m/v -> the banked layout ``ctx``/``mode`` run
    under (host numpy; inverse of ``unbank_opt_state`` for the same plan,
    and the reshard path onto a *different* plan after an elastic resize).
    Identity when the runtime layout already mirrors the params."""
    import numpy as np

    if not zero1_banked(mode, ctx):
        return canonical
    dp = ctx.dp

    def rebank_leaf(p, spec, st):
        axes = _tp_axes_in_spec(spec, ctx)
        sizes = [ctx.topo.axis_size(a) for a in axes]
        tpn = math.prod(sizes) if sizes else 1
        shape = tuple(np.shape(p))
        local = int(np.prod(shape, dtype=np.int64)) // \
            (math.prod(sizes) if sizes else 1)
        k = math.ceil(local / dp)

        def one(canon):
            canon = np.asarray(jax.device_get(canon), np.float32)
            banked = np.zeros((dp, tpn, k), np.float32)
            for j in range(tpn):
                sl = _tp_block_slices(shape, spec, ctx,
                                      _tp_coord_of(j, axes, sizes))
                flat = canon[sl].reshape(-1)
                padded = np.zeros(dp * k, np.float32)
                padded[: flat.size] = flat
                banked[:, j, :] = padded.reshape(dp, k)
            return banked

        return {"m": one(st["m"]), "v": one(st["v"])}

    flat_p, tdef = jax.tree.flatten(params)
    flat_spec = tdef.flatten_up_to(param_specs_tree)
    flat_st = tdef.flatten_up_to(canonical["leaves"])
    leaves = [rebank_leaf(p, s, st)
              for p, s, st in zip(flat_p, flat_spec, flat_st)]
    return {"step": canonical["step"],
            "leaves": jax.tree.unflatten(tdef, leaves)}


def replication_factors(param_specs_tree, ctx: ATPContext):
    """Per-leaf TP replication factor = tp / prod(tp axis sizes in spec).

    Used to de-duplicate replicated leaves in the global grad norm."""
    from jax.sharding import PartitionSpec as P

    def factor(spec):
        sharded = 1
        for entry in spec:
            names = entry if isinstance(entry, tuple) else (entry,)
            for nm in names:
                if nm in (ctx.ax1, ctx.ax2):
                    sharded *= ctx.topo.axis_size(nm)
        return float(ctx.tp // sharded)

    return jax.tree.map(factor, param_specs_tree,
                        is_leaf=lambda x: isinstance(x, P))


def global_grad_norm(grads, ctx: ATPContext, rep=None):
    """L2 norm over the *global* gradient.  TP-sharded leaves contribute
    their shard once; replicated leaves are divided by their replication
    factor so the TP psum does not over-count them."""
    leaves = jax.tree.leaves(grads)
    reps = jax.tree.leaves(rep) if rep is not None else [1.0] * len(leaves)
    local = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) / r
                for g, r in zip(leaves, reps))
    axes = ctx.tp_axes
    if axes:
        local = lax.psum(local, axes)
    return jnp.sqrt(local)


def apply_adamw(
    cfg: AdamWConfig,
    ctx: ATPContext,
    params,
    grads,
    opt_state,
    replication_factor=None,
):
    """One optimizer step.  grads are LOCAL (pre-DP-reduction).

    Returns (new_params, new_opt_state, metrics)."""
    dp_axes = ctx.dp_axes
    step = opt_state["step"]
    lr = lr_at(cfg, step)

    new_err = None
    if cfg.mode == "compressed":
        from repro.optim.grad_compress import (compressed_psum_mean,
                                               compressed_psum_mean_ef)
        err = opt_state.get("err")
        if err is None:
            # legacy state (pre-error-feedback checkpoint): memoryless path
            grads = jax.tree.map(
                lambda g: compressed_psum_mean(g, dp_axes), grads)
        else:
            flat_g, gdef = jax.tree.flatten(grads)
            flat_e = gdef.flatten_up_to(err)
            res = [compressed_psum_mean_ef(g, e, dp_axes)
                   for g, e in zip(flat_g, flat_e)]
            grads = jax.tree.unflatten(gdef, [r[0] for r in res])
            new_err = jax.tree.unflatten(gdef, [r[1] for r in res])
    elif dp_axes and cfg.mode == "plain":
        grads = jax.tree.map(lambda g: lax.pmean(g, dp_axes), grads)

    if cfg.mode == "zero1" and dp_axes:
        return _zero1_step(cfg, ctx, params, grads, opt_state, lr,
                           replication_factor)

    # full-state AdamW (grads already DP-reduced); m/v mirror param shapes
    gnorm = global_grad_norm(grads, ctx, replication_factor)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, st):
        gf = g.astype(jnp.float32) * scale
        m = cfg.b1 * st["m"] + (1 - cfg.b1) * gf
        v = cfg.b2 * st["v"] + (1 - cfg.b2) * gf * gf
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        new = pf - lr * (u + cfg.weight_decay * pf)
        return new.astype(p.dtype), {"m": m, "v": v}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = tdef.flatten_up_to(opt_state["leaves"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_leaves = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_state = {"step": step + 1, "leaves": new_leaves}
    if new_err is not None:
        new_state["err"] = new_err
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def _zero1_step(cfg, ctx, params, grads, opt_state, lr, rep=None):
    """ZeRO-1: reduce-scatter grads over dp, shard-local Adam, all-gather."""
    dp_axes = ctx.dp_axes
    dp = ctx.dp
    step = opt_state["step"]
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    # grad norm from local (unreduced) grads requires the DP mean first;
    # compute it on the scattered shards to stay memory-light.
    def scatter(g):
        flat, _ = _pad_to(g.astype(jnp.float32).reshape(-1), dp)
        shard = lax.psum_scatter(flat, dp_axes, scatter_dimension=0, tiled=True)
        return shard / dp                     # mean over DP

    g_shards = jax.tree.map(scatter, grads)
    leaves = jax.tree.leaves(g_shards)
    reps = jax.tree.leaves(rep) if rep is not None else [1.0] * len(leaves)
    sq = sum(jnp.sum(jnp.square(g)) / r for g, r in zip(leaves, reps))
    sq = lax.psum(sq, dp_axes)
    tp_ax = ctx.tp_axes
    if tp_ax:
        sq = lax.psum(sq, tp_ax)
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0

    def upd(p, gs, st):
        gs = gs * scale
        m0, v0 = st["m"][0, 0], st["v"][0, 0]     # local bank [k]
        m = cfg.b1 * m0 + (1 - cfg.b1) * gs
        v = cfg.b2 * v0 + (1 - cfg.b2) * gs * gs
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        flat, pad = _pad_to(p.astype(jnp.float32).reshape(-1), dp)
        mine = lax.dynamic_slice_in_dim(
            flat, ctx.dp_index() * u.size, u.size, axis=0)
        new = mine - lr * (u + cfg.weight_decay * mine)
        # update-gather: each dp rank places its chunk, psum makes the
        # result provably dp-invariant under vma typing.  (an all_gather
        # would halve the bytes but its output cannot be typed invariant
        # without Explicit mesh axes; see DESIGN.md)
        placed = lax.dynamic_update_slice_in_dim(
            jnp.zeros_like(flat), new, ctx.dp_index() * u.size, axis=0)
        full = lax.psum(placed, ctx.dp_axes)
        if pad:
            full = full[: p.size]
        return (full.reshape(p.shape).astype(p.dtype),
                {"m": m[None, None], "v": v[None, None]})

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(g_shards)
    flat_s = tdef.flatten_up_to(opt_state["leaves"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_leaves = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_params, {"step": step + 1, "leaves": new_leaves}, \
        {"lr": lr, "grad_norm": gnorm}
